"""L2 graph tests: grad_fn / eval_fn composition, scaling, tensor orders."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import losses, ref

LOSSES = list(losses.LOSSES)


def _mk(rng, *shape):
    return jnp.array(0.4 * rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("loss", LOSSES)
@pytest.mark.parametrize("d_order", [3, 4])
def test_grad_fn_hadamard_composition(loss, d_order):
    """grad_fn(xs, a, u_1.., scale) == scale * ref_grad with H = prod u_k."""
    rng = np.random.default_rng(3)
    i_dim, s_dim, r_dim, scale = 40, 12, 5, 2.5
    xs, a = _mk(rng, i_dim, s_dim), _mk(rng, i_dim, r_dim)
    us = [_mk(rng, s_dim, r_dim) for _ in range(d_order - 1)]
    fn = model.make_grad_fn(loss, d_order, block_i=16)
    g, lsum = fn(xs, a, *us, jnp.float32(scale))
    h = ref.hadamard_rows(us)
    g_ref, l_ref = ref.ref_grad(xs, a, h, loss=loss)
    np.testing.assert_allclose(np.asarray(g), scale * np.asarray(g_ref), rtol=1e-4, atol=1e-4)
    assert math.isclose(float(lsum), float(l_ref), rel_tol=1e-4, abs_tol=1e-4)


@pytest.mark.parametrize("loss", LOSSES)
def test_grad_fn_jits(loss):
    rng = np.random.default_rng(4)
    fn = jax.jit(model.make_grad_fn(loss, 3, block_i=16))
    g, lsum = fn(_mk(rng, 32, 16), _mk(rng, 32, 4), _mk(rng, 16, 4), _mk(rng, 16, 4), jnp.float32(1.0))
    assert g.shape == (32, 4) and lsum.shape == ()


@pytest.mark.parametrize("loss", LOSSES)
@pytest.mark.parametrize("d_order", [3, 4])
def test_eval_fn_matches_manual(loss, d_order):
    rng = np.random.default_rng(5)
    b, r = 50, 6
    us = [_mk(rng, b, r) for _ in range(d_order)]
    x = _mk(rng, b)
    (got,) = model.make_eval_fn(loss, d_order)(x, *us)
    m = np.prod([np.asarray(u) for u in us], axis=0).sum(axis=1)
    want = float(jnp.sum(losses.loss_value(loss, jnp.array(m), x)))
    assert math.isclose(float(got), want, rel_tol=1e-4, abs_tol=1e-4)


def test_eval_fn_zero_factors_ls():
    """All-zero factors: ls loss over batch must equal sum x^2."""
    b, r, d = 17, 3, 3
    x = jnp.arange(b, dtype=jnp.float32) / 7.0
    us = [jnp.zeros((b, r), jnp.float32) for _ in range(d)]
    (got,) = model.make_eval_fn("ls", d)(x, *us)
    assert math.isclose(float(got), float(jnp.sum(x * x)), rel_tol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 64),
    r=st.integers(1, 12),
    d_order=st.integers(3, 5),
    loss=st.sampled_from(LOSSES),
    seed=st.integers(0, 2**31 - 1),
)
def test_eval_fn_hypothesis(b, r, d_order, loss, seed):
    rng = np.random.default_rng(seed)
    us = [_mk(rng, b, r) for _ in range(d_order)]
    x = _mk(rng, b)
    (got,) = model.make_eval_fn(loss, d_order)(x, *us)
    want = float(ref.ref_eval(us, x, loss=loss))
    denom = max(1.0, abs(want))
    assert abs(float(got) - want) / denom < 1e-4
