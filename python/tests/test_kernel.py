"""Kernel-vs-oracle correctness: the CORE build-time signal.

The Pallas fused GCP gradient (interpret mode) must agree with the pure-jnp
reference on every loss, shape, padding configuration, and tensor order the
artifacts can be built with. Hypothesis sweeps the shape space.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gcp_grad, losses, ref

LOSSES = list(losses.LOSSES)


def _rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


def _binary(rng, *shape):
    return (rng.random(size=shape) < 0.05).astype(np.float32)


@pytest.mark.parametrize("loss", LOSSES)
@pytest.mark.parametrize(
    "i_dim,s_dim,r_dim,block_i",
    [
        (32, 16, 4, 32),  # exact single tile
        (64, 16, 4, 32),  # multiple exact tiles
        (33, 16, 4, 32),  # padding, 1 extra row
        (130, 16, 4, 32),  # padding, partial last tile
        (7, 16, 4, 32),  # I < block -> single shrunken tile
        (128, 256, 16, 128),  # production shape (scaled)
        (1, 8, 2, 128),  # degenerate single row
    ],
)
def test_fused_grad_matches_ref(loss, i_dim, s_dim, r_dim, block_i):
    rng = np.random.default_rng(42)
    xs = _binary(rng, i_dim, s_dim) if loss == "logit" else _rand(rng, i_dim, s_dim)
    a = 0.3 * _rand(rng, i_dim, r_dim)
    h = 0.3 * _rand(rng, s_dim, r_dim)
    g1, l1 = gcp_grad.fused_gcp_grad(
        jnp.array(xs), jnp.array(a), jnp.array(h), loss=loss, block_i=block_i
    )
    g2, l2 = ref.ref_grad(jnp.array(xs), jnp.array(a), jnp.array(h), loss=loss)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-5)
    assert math.isclose(float(l1), float(l2), rel_tol=1e-4, abs_tol=1e-4)


@settings(max_examples=40, deadline=None)
@given(
    i_dim=st.integers(1, 96),
    s_dim=st.integers(1, 48),
    r_dim=st.integers(1, 24),
    block_i=st.sampled_from([8, 32, 128]),
    loss=st.sampled_from(LOSSES),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_grad_hypothesis_sweep(i_dim, s_dim, r_dim, block_i, loss, seed):
    rng = np.random.default_rng(seed)
    xs = 0.5 * _rand(rng, i_dim, s_dim)
    a = 0.5 * _rand(rng, i_dim, r_dim)
    h = 0.5 * _rand(rng, s_dim, r_dim)
    g1, l1 = gcp_grad.fused_gcp_grad(
        jnp.array(xs), jnp.array(a), jnp.array(h), loss=loss, block_i=block_i
    )
    g2, l2 = ref.ref_grad(jnp.array(xs), jnp.array(a), jnp.array(h), loss=loss)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-4, atol=2e-4)
    denom = max(1.0, abs(float(l2)))
    assert abs(float(l1) - float(l2)) / denom < 2e-4


def test_grad_is_true_derivative_ls():
    """Finite-difference check: G must be d/dA of the slice loss (ls)."""
    rng = np.random.default_rng(7)
    i_dim, s_dim, r_dim = 5, 6, 3
    xs, a, h = _rand(rng, i_dim, s_dim), _rand(rng, i_dim, r_dim), _rand(rng, s_dim, r_dim)
    g, _ = ref.ref_grad(jnp.array(xs), jnp.array(a), jnp.array(h), loss="ls")
    eps = 1e-3
    for (ii, rr) in [(0, 0), (2, 1), (4, 2)]:
        ap, am = a.copy(), a.copy()
        ap[ii, rr] += eps
        am[ii, rr] -= eps
        _, lp = ref.ref_grad(jnp.array(xs), jnp.array(ap), jnp.array(h), loss="ls")
        _, lm = ref.ref_grad(jnp.array(xs), jnp.array(am), jnp.array(h), loss="ls")
        fd = (float(lp) - float(lm)) / (2 * eps)
        assert math.isclose(fd, float(np.asarray(g)[ii, rr]), rel_tol=1e-2, abs_tol=1e-2)


def test_grad_is_true_derivative_logit():
    rng = np.random.default_rng(8)
    i_dim, s_dim, r_dim = 4, 5, 2
    xs = _binary(rng, i_dim, s_dim)
    a, h = 0.4 * _rand(rng, i_dim, r_dim), 0.4 * _rand(rng, s_dim, r_dim)
    g, _ = ref.ref_grad(jnp.array(xs), jnp.array(a), jnp.array(h), loss="logit")
    eps = 1e-3
    for (ii, rr) in [(0, 0), (3, 1)]:
        ap, am = a.copy(), a.copy()
        ap[ii, rr] += eps
        am[ii, rr] -= eps
        _, lp = ref.ref_grad(jnp.array(xs), jnp.array(ap), jnp.array(h), loss="logit")
        _, lm = ref.ref_grad(jnp.array(xs), jnp.array(am), jnp.array(h), loss="logit")
        fd = (float(lp) - float(lm)) / (2 * eps)
        assert math.isclose(fd, float(np.asarray(g)[ii, rr]), rel_tol=2e-2, abs_tol=2e-2)


def test_logit_loss_is_bernoulli_nll():
    """f(m, x) must equal the Bernoulli NLL with logit link (up to exact)."""
    m = jnp.array([-3.0, -0.5, 0.0, 0.5, 3.0])
    for x in (0.0, 1.0):
        f = losses.loss_value("logit", m, x)
        p = 1.0 / (1.0 + jnp.exp(-m))
        nll = -(x * jnp.log(p) + (1 - x) * jnp.log(1 - p))
        np.testing.assert_allclose(np.asarray(f), np.asarray(nll), rtol=1e-5, atol=1e-6)


def test_loss_at_zero_consistency():
    for loss in LOSSES:
        expected = float(losses.loss_value(loss, jnp.zeros(()), jnp.zeros(())))
        assert math.isclose(losses.loss_at_zero(loss), expected, rel_tol=1e-6, abs_tol=1e-9)
