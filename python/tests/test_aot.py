"""AOT path tests: HLO text round-trips through XLA and computes correctly.

These execute the *exact same artifacts* the Rust runtime loads, through the
same HLO-text parser path (text -> XlaComputation -> compile -> run), so a
pass here plus a pass of the Rust runtime_integration tests closes the loop.
"""

import json
import math
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref

HERE = os.path.dirname(os.path.abspath(__file__))


def _run_hlo_text(text: str, args):
    """Compile HLO text with the local CPU client and run it."""
    client = xc.make_cpu_client()
    # Same round-trip the Rust runtime performs: text -> HloModuleProto ->
    # compile. (This jaxlib compiles from StableHLO, so convert the proto.)
    proto = xc._xla.hlo_module_from_text(text).as_serialized_hlo_module_proto()
    mlir = xc._xla.mlir.hlo_to_stablehlo(proto)
    exe = client.compile_and_load(
        mlir, xc._xla.DeviceList(tuple(client.local_devices()[:1]))
    )
    bufs = [client.buffer_from_pyval(np.asarray(a)) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


@pytest.mark.parametrize("loss", ["ls", "logit"])
def test_grad_artifact_roundtrip(loss):
    i_dim, s_dim, r_dim, d_order = 32, 16, 4, 3
    text = aot.to_hlo_text(aot.lower_grad(loss, i_dim, s_dim, r_dim, d_order))
    rng = np.random.default_rng(11)
    xs = rng.normal(size=(i_dim, s_dim)).astype(np.float32)
    a = 0.3 * rng.normal(size=(i_dim, r_dim)).astype(np.float32)
    us = [0.3 * rng.normal(size=(s_dim, r_dim)).astype(np.float32) for _ in range(d_order - 1)]
    scale = np.float32(1.75)
    outs = _run_hlo_text(text, [xs, a, *us, scale])
    g, lsum = outs[0], outs[1]
    h = ref.hadamard_rows([jnp.array(u) for u in us])
    g_ref, l_ref = ref.ref_grad(jnp.array(xs), jnp.array(a), h, loss=loss)
    np.testing.assert_allclose(g, float(scale) * np.asarray(g_ref), rtol=1e-4, atol=1e-4)
    assert math.isclose(float(lsum), float(l_ref), rel_tol=1e-4, abs_tol=1e-3)


@pytest.mark.parametrize("loss", ["ls", "logit"])
def test_eval_artifact_roundtrip(loss):
    b, r_dim, d_order = 64, 4, 3
    text = aot.to_hlo_text(aot.lower_eval(loss, b, r_dim, d_order))
    rng = np.random.default_rng(12)
    us = [0.3 * rng.normal(size=(b, r_dim)).astype(np.float32) for _ in range(d_order)]
    x = rng.normal(size=(b,)).astype(np.float32)
    (lsum,) = _run_hlo_text(text, [x, *us])
    want = float(ref.ref_eval([jnp.array(u) for u in us], jnp.array(x), loss=loss))
    assert math.isclose(float(lsum), want, rel_tol=1e-4, abs_tol=1e-3)


def test_build_writes_manifest_and_is_incremental(tmp_path):
    spec = {
        "grads": [{"loss": "ls", "I": 8, "S": 4, "R": 2, "D": 3}],
        "evals": [{"loss": "ls", "B": 8, "R": 2, "D": 3}],
    }
    m1 = aot.build(spec, str(tmp_path))
    assert (tmp_path / "manifest.json").exists()
    names = {a["name"] for a in m1["artifacts"]}
    assert names == {"grad_ls_i8_s4_r2_d3", "eval_ls_b8_r2_d3"}
    for a in m1["artifacts"]:
        assert (tmp_path / a["file"]).exists()
        assert a["inputs"] and a["outputs"]
    # Second build skips existing files (names encode shapes).
    mtimes = {f.name: f.stat().st_mtime_ns for f in tmp_path.glob("*.hlo.txt")}
    aot.build(spec, str(tmp_path))
    for f in tmp_path.glob("*.hlo.txt"):
        assert f.stat().st_mtime_ns == mtimes[f.name]


def test_checked_in_spec_is_well_formed():
    with open(os.path.join(HERE, "..", "compile", "artifact_specs.json")) as f:
        spec = json.load(f)
    assert spec["grads"] and spec["evals"]
    seen = set()
    for g in spec["grads"]:
        key = aot.grad_name(g["loss"], g["I"], g["S"], g["R"], g["D"])
        assert key not in seen, f"duplicate artifact {key}"
        seen.add(key)
        assert g["loss"] in ("ls", "logit")
        assert g["I"] > 0 and g["S"] > 0 and g["R"] > 0 and g["D"] >= 3
