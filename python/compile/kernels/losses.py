"""Elementwise GCP losses f(m, x) and their derivatives df/dm.

The generalized CP objective (paper eq. 2) is a sum of an elementwise loss
over tensor entries, where ``m`` is the model value ``A(i)`` and ``x`` the
data value ``X(i)``:

* ``ls``      — least squares (eq. 3), Gaussian data:
                ``f = (m - x)^2``, ``df = 2 (m - x)``.
* ``logit``   — Bernoulli-logit for binary data. The paper's eq. (4) as
                printed (``log(1 + m) - x m``) is not the Bernoulli-logit
                loss (undefined for ``m <= -1``); we implement the loss of
                the cited GCP papers (Hong-Kolda-Duersch; Kolda-Hong):
                ``f = log(1 + exp(m)) - x m``, ``df = sigmoid(m) - x``.

All functions are pure jnp so they can be used both inside the Pallas
kernel body (interpret mode) and in the jnp reference oracle.
"""

import math

import jax
import jax.numpy as jnp

LOSSES = ("ls", "logit")


def loss_value(loss: str, m, x):
    """Elementwise loss f(m, x)."""
    if loss == "ls":
        d = m - x
        return d * d
    if loss == "logit":
        # log(1 + e^m) - x m, numerically stable via logaddexp.
        return jnp.logaddexp(0.0, m) - x * m
    raise ValueError(f"unknown loss {loss!r}")


def loss_grad(loss: str, m, x):
    """Elementwise derivative df/dm."""
    if loss == "ls":
        return 2.0 * (m - x)
    if loss == "logit":
        return jax.nn.sigmoid(m) - x
    raise ValueError(f"unknown loss {loss!r}")


def loss_at_zero(loss: str) -> float:
    """f(0, 0) — used to correct the loss sum for zero-padded rows.

    Must be a Python float (not jnp) so it stays a trace-time constant.
    """
    if loss == "ls":
        return 0.0
    if loss == "logit":
        return math.log(2.0)
    raise ValueError(f"unknown loss {loss!r}")
