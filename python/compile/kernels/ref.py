"""Pure-jnp correctness oracle for the Pallas kernel and the L2 graph.

Everything here is the straightforward dense math with no tiling, padding,
or fusion tricks — the ground truth pytest compares against.
"""

import jax.numpy as jnp

from . import losses as L


def ref_grad(xs, a, h, *, loss: str):
    """Reference fiber-sampled GCP gradient.

    Same contract as :func:`gcp_grad.fused_gcp_grad`:
    returns ``(g [I, R], loss_sum)``.
    """
    m = a @ h.T  # [I, S]
    g = L.loss_grad(loss, m, xs) @ h  # [I, R]
    return g, jnp.sum(L.loss_value(loss, m, xs))


def hadamard_rows(us):
    """Hadamard product of a list of ``[N, R]`` row-gather matrices."""
    out = us[0]
    for u in us[1:]:
        out = out * u
    return out


def ref_eval(us, x, *, loss: str):
    """Reference stratified-loss-estimator batch.

    ``us`` is a list of D ``[B, R]`` factor-row gathers (one per mode) for B
    sampled tensor entries; ``x [B]`` the data values. Returns the scalar
    sum of the elementwise loss over the batch.
    """
    m = jnp.sum(hadamard_rows(us), axis=1)  # [B]
    return jnp.sum(L.loss_value(loss, m, x))
