"""L1 Pallas kernel: fused fiber-sampled GCP gradient (the compute hot-spot).

One CiderTF local step on mode ``d`` needs (paper eq. 7-10)

    M  = A @ H^T          # model values on the sampled slice   [I, S]
    Y  = df(M, Xs)        # elementwise loss derivative         [I, S]
    G  = Y @ H            # partial (fiber-sampled) MTTKRP      [I, R]
    L  = sum f(M, Xs)     # loss on the slice (monitoring)

where ``A [I, R]`` is the mode-d factor, ``H [S, R]`` holds the Hadamard
products of the sampled Khatri-Rao rows of the other modes' factors, and
``Xs [I, S]`` is the dense gather of the sampled fibers.

TPU mapping (see DESIGN.md §Hardware-Adaptation): both GEMMs hit the MXU;
the elementwise ``df`` fuses between them so ``M`` never round-trips to
HBM. The grid tiles the I dimension; each step holds ``A_blk [bI, R]``,
``Xs_blk [bI, S]`` and the shared ``H [S, R]`` in VMEM (~0.6 MB at the
default shapes, far under budget, leaving headroom for double buffering).

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers the kernel to plain HLO that
any backend (including the Rust-side PJRT CPU client) runs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import losses as L

# Default I-tile. 128 rows keeps the VMEM working set small and matches the
# MXU systolic dimension; swept in the perf pass (see EXPERIMENTS.md §Perf).
DEFAULT_BLOCK_I = 128


def _kernel(xs_ref, a_ref, h_ref, g_ref, *loss_ref, loss: str):
    """One grid step: fused M -> df -> G over an I-tile.

    ``loss_ref`` is empty when the caller skips the monitoring loss — the
    elementwise ``f`` (a transcendental pass for logit) then never runs,
    which matters on the training hot path where only ``G`` is consumed.
    """
    a = a_ref[...]  # [bI, R]
    h = h_ref[...]  # [S, R]
    xs = xs_ref[...]  # [bI, S]
    # MXU GEMM 1: model values on the tile.
    m = jnp.dot(a, h.T, preferred_element_type=jnp.float32)  # [bI, S]
    # Fused elementwise loss derivative (VPU) — M never leaves VMEM.
    y = L.loss_grad(loss, m, xs)  # [bI, S]
    # MXU GEMM 2: partial MTTKRP.
    g_ref[...] = jnp.dot(y, h, preferred_element_type=jnp.float32)  # [bI, R]
    if loss_ref:
        # Per-tile loss partial (summed across the grid by the caller).
        loss_ref[0][...] = jnp.sum(L.loss_value(loss, m, xs)).reshape(1)


def fused_gcp_grad(
    xs, a, h, *, loss: str, block_i: int = DEFAULT_BLOCK_I, with_loss: bool = True
):
    """Fused fiber-sampled GCP gradient via Pallas.

    Args:
      xs: ``[I, S]`` dense slice of the local tensor at the sampled fibers.
      a:  ``[I, R]`` mode-d factor matrix.
      h:  ``[S, R]`` sampled Khatri-Rao rows (Hadamard product of the other
          modes' factor rows).
      loss: one of :data:`losses.LOSSES`.
      block_i: I-tile size; ``I`` is padded up to a multiple internally.
        Pass ``block_i >= I`` for a single tile — on the CPU interpret
        path the grid serializes into an XLA while-loop, so single-tile
        lowering is ~2x faster (see EXPERIMENTS.md §Perf); multi-tile is
        the real-TPU shape where the grid pipelines HBM<->VMEM.
      with_loss: also return the summed elementwise loss (costs an extra
        transcendental pass for logit; the training hot path skips it).

    Returns:
      ``(g, loss_sum)`` with ``g [I, R]`` the stochastic partial gradient
      (unscaled) and ``loss_sum`` the scalar sum of the elementwise loss
      over the slice (``None`` when ``with_loss=False``).
    """
    i_dim, s_dim = xs.shape
    r_dim = a.shape[1]
    assert a.shape[0] == i_dim and h.shape == (s_dim, r_dim), (
        xs.shape,
        a.shape,
        h.shape,
    )

    bi = min(block_i, i_dim)
    pad = (-i_dim) % bi
    if pad:
        # Zero rows give m = 0; the loss-sum pollution f(0, 0) * pad is
        # subtracted below and the gradient rows are sliced off.
        xs = jnp.pad(xs, ((0, pad), (0, 0)))
        a = jnp.pad(a, ((0, pad), (0, 0)))
    n_tiles = (i_dim + pad) // bi

    out_specs = [pl.BlockSpec((bi, r_dim), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((i_dim + pad, r_dim), jnp.float32)]
    if with_loss:
        out_specs.append(pl.BlockSpec((1,), lambda i: (i,)))
        out_shape.append(jax.ShapeDtypeStruct((n_tiles,), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_kernel, loss=loss),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((bi, s_dim), lambda i: (i, 0)),  # Xs tile
            pl.BlockSpec((bi, r_dim), lambda i: (i, 0)),  # A tile
            pl.BlockSpec((s_dim, r_dim), lambda i: (0, 0)),  # H (shared)
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(xs, a, h)

    if not with_loss:
        return outs[0][:i_dim], None
    g, loss_parts = outs
    # Each of the `pad` zero rows contributed s_dim entries of f(0, 0).
    loss_sum = jnp.sum(loss_parts) - L.loss_at_zero(loss) * pad * s_dim
    return g[:i_dim], loss_sum
