"""AOT compile path: lower the L2/L1 graphs to HLO text + manifest.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/.

Usage (normally via ``make artifacts``):

    python -m compile.aot --out-dir ../artifacts \
        [--spec compile/artifact_specs.json] [--force]

Artifacts are shape-specialized (XLA requires static shapes); the spec file
enumerates the (op, loss, shape) matrix the Rust experiment configs need.
Each artifact is skipped if its file already exists (names encode the full
shape signature, so this is safe); ``--force`` regenerates.

Outputs ``<out>/manifest.json`` describing every artifact (op, loss,
shapes, input/output order) — the Rust runtime's source of truth.
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def grad_name(loss, i, s, r, d):
    return f"grad_{loss}_i{i}_s{s}_r{r}_d{d}"


def eval_name(loss, b, r, d):
    return f"eval_{loss}_b{b}_r{r}_d{d}"


def lower_grad(loss, i, s, r, d, with_loss=True):
    # CPU artifacts lower with a single I-tile (block_i=None): the
    # interpret-mode grid serializes into an XLA while-loop, and one tile
    # is ~2x faster (EXPERIMENTS.md §Perf). The multi-tile schedule is the
    # real-TPU shape only.
    fn = model.make_grad_fn(loss, d, block_i=None, with_loss=with_loss)
    args = (
        jax.ShapeDtypeStruct((i, s), F32),  # xs
        jax.ShapeDtypeStruct((i, r), F32),  # a
        *[jax.ShapeDtypeStruct((s, r), F32) for _ in range(d - 1)],  # u_k
        jax.ShapeDtypeStruct((), F32),  # scale
    )
    return jax.jit(fn).lower(*args)


def lower_eval(loss, b, r, d):
    fn = model.make_eval_fn(loss, d)
    args = (
        jax.ShapeDtypeStruct((b,), F32),  # x
        *[jax.ShapeDtypeStruct((b, r), F32) for _ in range(d)],  # u_d
    )
    return jax.jit(fn).lower(*args)


def build(spec: dict, out_dir: str, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text-v1", "artifacts": []}
    n_built = n_skipped = 0

    def emit(name, lowered_thunk, entry):
        nonlocal n_built, n_skipped
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        entry = dict(entry, name=name, file=f"{name}.hlo.txt")
        manifest["artifacts"].append(entry)
        if os.path.exists(path) and not force:
            n_skipped += 1
            return
        t0 = time.time()
        text = to_hlo_text(lowered_thunk())
        with open(path, "w") as f:
            f.write(text)
        n_built += 1
        print(f"  {name}: {len(text) / 1e3:.0f} kB in {time.time() - t0:.1f}s")

    # grad_* : inputs xs[I,S], a[I,R], u_1..u_{D-1}[S,R], scale[]
    # "with_loss": true also emits the slice-loss sum (diagnostics /
    # differential tests); production shapes omit it — the engine's
    # training path only consumes G and the extra elementwise-f pass is
    # measurable (§Perf).
    for g in spec["grads"]:
        loss, i, s, r, d = g["loss"], g["I"], g["S"], g["R"], g["D"]
        with_loss = bool(g.get("with_loss", False))
        emit(
            grad_name(loss, i, s, r, d),
            lambda loss=loss, i=i, s=s, r=r, d=d, wl=with_loss: lower_grad(
                loss, i, s, r, d, with_loss=wl
            ),
            {
                "op": "grad",
                "loss": loss,
                "I": i,
                "S": s,
                "R": r,
                "D": d,
                "with_loss": with_loss,
                "inputs": [[i, s], [i, r]] + [[s, r]] * (d - 1) + [[]],
                "outputs": [[i, r], []] if with_loss else [[i, r]],
            },
        )

    # eval_* : inputs x[B], u_1..u_D[B,R]
    for e in spec["evals"]:
        loss, b, r, d = e["loss"], e["B"], e["R"], e["D"]
        emit(
            eval_name(loss, b, r, d),
            lambda loss=loss, b=b, r=r, d=d: lower_eval(loss, b, r, d),
            {
                "op": "eval",
                "loss": loss,
                "B": b,
                "R": r,
                "D": d,
                "inputs": [[b]] + [[b, r]] * d,
                "outputs": [[]],
            },
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"artifacts: {n_built} built, {n_skipped} up-to-date, "
        f"{len(manifest['artifacts'])} in manifest -> {out_dir}"
    )
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    here = os.path.dirname(os.path.abspath(__file__))
    ap.add_argument("--spec", default=os.path.join(here, "artifact_specs.json"))
    ap.add_argument("--out-dir", default=None, help="artifact output dir")
    ap.add_argument("--out", default=None, help="(compat) path inside out dir")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = args.out_dir or (os.path.dirname(args.out) if args.out else "../artifacts")
    with open(args.spec) as f:
        spec = json.load(f)
    build(spec, out_dir, force=args.force)


if __name__ == "__main__":
    main()
