"""L2: the generalized-CP compute graph around the L1 Pallas kernel.

Two jit-able entry points, both lowered to HLO text by :mod:`aot`:

* ``make_grad_fn(loss, d_order)`` — one CiderTF local gradient step on a
  sampled mode (paper eq. 7-10). Takes the dense fiber slice, the mode's
  factor, the D-1 row-gathered factor matrices of the other modes, and an
  unbiasedness ``scale`` (the |fibers|/|S| importance weight; the Rust
  coordinator controls it). The Khatri-Rao rows are combined by Hadamard
  product *here* (cheap, VPU-bound) and the hot GEMM pipeline runs in the
  Pallas kernel.

* ``make_eval_fn(loss, d_order)`` — stratified loss-estimator batch: model
  values of B sampled tensor entries from D row gathers, summed elementwise
  loss against the data values.

Python is build-time only: these functions exist to be lowered once; the
Rust runtime executes the resulting HLO on the PJRT CPU client.
"""

from .kernels import gcp_grad, ref


def make_grad_fn(
    loss: str,
    d_order: int,
    block_i: int | None = gcp_grad.DEFAULT_BLOCK_I,
    with_loss: bool = True,
):
    """Gradient-step graph for a D-order tensor.

    Signature of the returned fn:
      ``(xs [I,S], a [I,R], u_1 [S,R], ..., u_{D-1} [S,R], scale [])
        -> (g [I,R], loss_sum [])``  (or just ``(g,)`` when
        ``with_loss=False`` — the training hot path, which skips the
        monitoring loss's extra transcendental pass).

    ``block_i=None`` lowers with a single I-tile: on the CPU interpret
    path the Pallas grid serializes into an XLA while-loop, so one tile is
    ~2x faster (EXPERIMENTS.md §Perf); pass an explicit tile for the
    TPU-shaped multi-tile schedule.
    """
    n_u = d_order - 1

    def grad_fn(xs, a, *rest):
        us, scale = rest[:n_u], rest[n_u]
        h = ref.hadamard_rows(list(us))  # [S, R]
        bi = block_i if block_i is not None else xs.shape[0]
        g, loss_sum = gcp_grad.fused_gcp_grad(
            xs, a, h, loss=loss, block_i=bi, with_loss=with_loss
        )
        if with_loss:
            return scale * g, loss_sum
        return (scale * g,)

    return grad_fn


def make_eval_fn(loss: str, d_order: int):
    """Loss-estimator graph: ``(x [B], u_1 [B,R], ..., u_D [B,R]) -> loss_sum []``."""

    def eval_fn(x, *us):
        return (ref.ref_eval(list(us), x, loss=loss),)

    return eval_fn
