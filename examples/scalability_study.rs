//! Scalability study (paper Fig. 5): CiderTF with K = 8, 16, 32 hospitals
//! — computation speeds up with K (each client owns 1/K of the patients)
//! while total uplink bytes grow: the computation-communication trade-off.
//!
//!     cargo run --release --example scalability_study

use cidertf::engine::{train, AlgoConfig, TrainConfig};
use cidertf::harness::Ctx;
use cidertf::losses::Loss;
use cidertf::runtime::{default_artifact_dir, PjrtBackend};
use cidertf::tensor::synth::SynthConfig;
use cidertf::util::benchkit::{fmt_bytes, Table};

fn main() -> anyhow::Result<()> {
    let data = SynthConfig::mimic_like().generate();
    let mut backend = PjrtBackend::new(&default_artifact_dir())?;
    println!("CiderTF scalability on mimic_like {:?}\n", data.tensor.dims);
    // "par_s" = wall/K: the simulated-parallel wall-clock (the in-process
    // network executes clients sequentially; real deployments run them in
    // parallel, which is what the paper's Fig. 5 time axis shows).
    let table = Table::new(&["K", "tau", "final_loss", "wall_s", "par_s", "uplink", "bytes/K"]);
    for tau in [4usize, 8] {
        for k in [8usize, 16, 32] {
            let mut cfg = TrainConfig::new("mimic_like", Loss::Logit, AlgoConfig::cidertf(tau));
            cfg.gamma = Ctx::gamma_for("mimic_like", Loss::Logit);
            cfg.k = k;
            cfg.epochs = 3;
            cfg.iters_per_epoch = 250;
            let out = train(&cfg, &data, &mut backend, None)?;
            table.row(&[
                k.to_string(),
                tau.to_string(),
                format!("{:.3e}", out.record.final_loss()),
                format!("{:.1}", out.record.wall_s),
                format!("{:.2}", out.record.wall_s / k as f64),
                fmt_bytes(out.record.total.bytes as f64),
                fmt_bytes(out.record.total.bytes as f64 / k as f64),
            ]);
        }
    }
    println!("\n(paper: accuracy holds as K grows; total communication grows with K)");
    Ok(())
}
