//! Quickstart: decentralized tensor factorization in ~30 lines.
//!
//! Generates a synthetic EHR-like tensor, splits it across 8 simulated
//! hospitals on a ring, and runs CiderTF (sign compression + block
//! randomization + periodic + event-triggered communication) through the
//! AOT-compiled PJRT artifacts.
//!
//!     cargo run --release --example quickstart
//!
//! The default build compiles a stub `PjrtBackend` whose constructor
//! errors with instructions — to actually execute through PJRT, vendor
//! the `xla` crate from the rust_pallas toolchain image, wire it into
//! the `pjrt` feature (see rust/Cargo.toml `[features]`), run
//! `make artifacts`, and build with `--features pjrt`. For an
//! artifact-free run today, swap `PjrtBackend` for
//! `runtime::native::NativeBackend` — the bit-faithful pure-Rust mirror
//! (what every test and `examples/faulty_network.rs` use).
//!
//! Beyond this file: every run can also go through the unified
//! `net::driver::RoundDriver` entry point, which swaps the execution path
//! without touching the config — `seq` (this file), `par` (one thread per
//! hospital), `sim` (lock-step over a `net::sim::NetworkModel` with
//! latency/drops/stragglers/churn knobs), or `async` (event-driven gossip
//! with no barriers). See `examples/faulty_network.rs` and
//! `cidertf train --driver sim --network lossy:0.2`.

use cidertf::engine::{train, AlgoConfig, TrainConfig};
use cidertf::harness::Ctx;
use cidertf::losses::Loss;
use cidertf::runtime::{default_artifact_dir, PjrtBackend};
use cidertf::tensor::synth::SynthConfig;
use cidertf::util::benchkit::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // 1. data: synthetic binary EHR tensor (4096 patients x 256 x 256)
    let data = SynthConfig::synthetic().generate();
    println!(
        "tensor {:?}, {} nonzeros (density {:.2e})",
        data.tensor.dims,
        data.tensor.nnz(),
        data.tensor.density()
    );

    // 2. backend: the AOT-compiled gradient/eval artifacts via PJRT
    let mut backend = PjrtBackend::new(&default_artifact_dir())?;

    // 3. configure CiderTF with tau = 4 local rounds on an 8-client ring
    let mut cfg = TrainConfig::new("synthetic", Loss::Logit, AlgoConfig::cidertf(4));
    cfg.gamma = Ctx::gamma_for("synthetic", Loss::Logit);
    cfg.epochs = 4;
    cfg.iters_per_epoch = 250;

    // 4. train
    let out = train(&cfg, &data, &mut backend, None)?;
    for p in &out.record.points {
        println!(
            "epoch {:>2}  loss {:>12.4e}  uplink {:>10}  {:>6.1}s",
            p.epoch,
            p.loss,
            fmt_bytes(p.bytes as f64),
            p.time_s
        );
    }
    println!(
        "\nfinal: loss {:.4e} | total uplink {} | messages {} (triggered {}, suppressed {})",
        out.record.final_loss(),
        fmt_bytes(out.record.total.bytes as f64),
        out.record.total.messages,
        out.record.total.triggered,
        out.record.total.suppressed
    );
    Ok(())
}
