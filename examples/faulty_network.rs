//! Faulty-network demo: CiderTF when the network actually misbehaves.
//!
//! Runs the same 8-hospital ring configuration four ways — ideal network,
//! 20% i.i.d. message loss, one 4x compute straggler (async), and the
//! "hostile" everything-at-once envelope — and prints final loss,
//! delivery accounting, and simulated wall-clock side by side.
//!
//! Uses the pure-Rust native backend, so it needs **no artifacts**:
//!
//!     cargo run --release --example faulty_network
//!
//! Knobs to play with: `FaultConfig` (drop/burst/latency/straggler/churn),
//! the driver (`train_sim` = lock-step barriers, `train_async` =
//! event-driven, no barriers), and the topology.

use cidertf::engine::{AlgoConfig, TrainConfig};
use cidertf::harness::Ctx;
use cidertf::losses::Loss;
use cidertf::net::async_gossip::train_async;
use cidertf::net::driver::train_sim;
use cidertf::net::sim::{self, FaultConfig, NetworkModel};
use cidertf::runtime::native::NativeBackend;
use cidertf::tensor::synth::SynthConfig;
use cidertf::util::benchkit::{fmt_bytes, Table};

fn main() -> anyhow::Result<()> {
    let data = SynthConfig::tiny(42).generate();
    println!(
        "tensor {:?}, {} nonzeros — 8 hospitals on a ring, CiderTF tau=4\n",
        data.tensor.dims,
        data.tensor.nnz()
    );

    let mut cfg = TrainConfig::new("tiny", Loss::Logit, AlgoConfig::cidertf(4));
    cfg.rank = 4;
    cfg.fiber_samples = 16;
    cfg.k = 8;
    cfg.gamma = Ctx::gamma_for("tiny", Loss::Logit);
    cfg.eval_batch = 64;
    cfg.epochs = 4;
    cfg.iters_per_epoch = 150;

    let scenarios: Vec<(&str, &str, Box<dyn NetworkModel>)> = vec![
        ("sim", "ideal", sim::ideal()),
        ("sim", "20% loss", FaultConfig::lossy(0.2).with_seed(cfg.seed).boxed()),
        (
            "async",
            "1 straggler 4x",
            FaultConfig { straggler_ids: vec![0], straggler_slow: 4.0, ..Default::default() }
                .boxed(),
        ),
        ("async", "hostile", FaultConfig::hostile().with_seed(cfg.seed).boxed()),
    ];

    let table = Table::new(&[
        "driver", "network", "final_loss", "delivered", "dropped", "stale", "offline", "uplink",
        "sim_s",
    ]);
    for (driver, label, mut net) in scenarios {
        let mut backend = NativeBackend::new();
        let out = match driver {
            "sim" => train_sim(&cfg, &data, &mut backend, net.as_mut(), None)?,
            _ => train_async(&cfg, &data, &mut backend, net.as_mut(), None)?,
        };
        table.row(&[
            driver.to_string(),
            label.to_string(),
            format!("{:.4e}", out.record.final_loss()),
            out.record.net.delivered.to_string(),
            out.record.net.dropped.to_string(),
            out.record.net.stale.to_string(),
            out.record.net.offline_rounds.to_string(),
            fmt_bytes(out.record.total.bytes as f64),
            format!("{:.0}", out.record.wall_s),
        ]);
    }

    println!(
        "\nReading the table: drops leave peer estimates stale instead of\n\
         corrupting them (CHOCO-style difference encoding), so loss degrades\n\
         gracefully; the async driver hides stragglers in wall-clock terms\n\
         at the price of stale mixing, which the consensus step absorbs."
    );
    Ok(())
}
