//! Faulty-network demo: CiderTF when the network actually misbehaves —
//! now written against the one-pipeline Experiment API.
//!
//! Builds a single declarative `ExperimentSpec` for an 8-hospital ring
//! (CiderTF τ=4), then runs it four ways — ideal network, 20% i.i.d.
//! message loss, one 4x compute straggler (async), and the "hostile"
//! everything-at-once envelope — by swapping only the `driver` and
//! `fault` axes. Each variant runs through a `Session`; on the `sim`
//! rows an observer counts dropped-delta events live (the delegated
//! `async` driver reports its faults post-hoc through `RunRecord`),
//! while the table collects final loss, delivery accounting, and
//! simulated wall-clock side by side.
//!
//! Uses the pure-Rust native backend, so it needs **no artifacts**:
//!
//!     cargo run --release --example faulty_network
//!
//! Knobs to play with: the `FaultConfig` axes (drop/burst/latency/
//! straggler/churn), the driver (`sim` = lock-step barriers, `async` =
//! event-driven, no barriers), the topology — or print any variant as
//! JSON (`spec.to_json()`) and reuse it via `cidertf train --spec`.

use cidertf::engine::session::{NetFaultKind, Observer, Session, SessionEvent};
use cidertf::engine::spec::ExperimentSpec;
use cidertf::engine::AlgoConfig;
use cidertf::harness::Ctx;
use cidertf::losses::Loss;
use cidertf::net::driver::DriverKind;
use cidertf::net::sim::FaultConfig;
use cidertf::runtime::native::NativeBackend;
use cidertf::util::benchkit::{fmt_bytes, Table};

/// Counts drop/offline events as they stream past — the kind of live
/// telemetry that used to require patching the engine. Only the `sim`
/// driver streams per-fault events; the delegated `async` driver emits
/// the coarse RunStart/EvalPoint/RunEnd sequence, so this observer
/// stays silent on those rows.
#[derive(Default)]
struct FaultCounter {
    dropped: u64,
    offline: u64,
}

impl Observer for FaultCounter {
    fn on_event(&mut self, event: &SessionEvent) -> anyhow::Result<()> {
        match event {
            SessionEvent::NetFault { kind, .. } => match kind {
                NetFaultKind::Dropped { .. } => self.dropped += 1,
                NetFaultKind::Offline { .. } => self.offline += 1,
            },
            SessionEvent::RunEnd { .. } => {
                if self.dropped + self.offline > 0 {
                    println!(
                        "  [observer] saw {} dropped deltas, {} offline client-rounds",
                        self.dropped, self.offline
                    );
                }
            }
            _ => {}
        }
        Ok(())
    }
}

fn main() -> anyhow::Result<()> {
    let base = ExperimentSpec::builder("tiny", Loss::Logit, AlgoConfig::cidertf(4))
        .rank(4)
        .fiber_samples(16)
        .k(8)
        .gamma(Ctx::gamma_for("tiny", Loss::Logit))
        .eval_batch(64)
        .epochs(4)
        .iters_per_epoch(150)
        .driver(DriverKind::Sim)
        .build()?;

    let data = base.dataset_data()?;
    println!(
        "tensor {:?}, {} nonzeros — 8 hospitals on a ring, CiderTF tau=4\n",
        data.tensor.dims,
        data.tensor.nnz()
    );

    let seed = base.seed;
    let scenarios: Vec<(&str, DriverKind, Option<FaultConfig>)> = vec![
        ("ideal", DriverKind::Sim, None),
        ("20% loss", DriverKind::Sim, Some(FaultConfig::lossy(0.2).with_seed(seed))),
        (
            "1 straggler 4x",
            DriverKind::Async,
            Some(FaultConfig {
                straggler_ids: vec![0],
                straggler_slow: 4.0,
                ..Default::default()
            }),
        ),
        ("hostile", DriverKind::Async, Some(FaultConfig::hostile().with_seed(seed))),
    ];

    let table = Table::new(&[
        "driver", "network", "final_loss", "delivered", "dropped", "stale", "offline", "uplink",
        "sim_s",
    ]);
    for (label, driver, fault) in scenarios {
        let mut spec = base.clone();
        spec.driver = driver;
        spec.fault = fault;
        let mut session = Session::new(spec).observe(Box::<FaultCounter>::default());
        let mut backend = NativeBackend::new();
        let out = session.run_on(&data, &mut backend, None)?;
        table.row(&[
            driver.name().to_string(),
            label.to_string(),
            format!("{:.4e}", out.record.final_loss()),
            out.record.net.delivered.to_string(),
            out.record.net.dropped.to_string(),
            out.record.net.stale.to_string(),
            out.record.net.offline_rounds.to_string(),
            fmt_bytes(out.record.total.bytes as f64),
            format!("{:.0}", out.record.wall_s),
        ]);
    }

    println!(
        "\nReading the table: drops leave peer estimates stale instead of\n\
         corrupting them (CHOCO-style difference encoding), so loss degrades\n\
         gracefully; the async driver hides stragglers in wall-clock terms\n\
         at the price of stale mixing, which the consensus step absorbs.\n\
         Each row is one ExperimentSpec — print it with `cidertf spec` or\n\
         persist it as JSON and rerun with `cidertf train --spec`."
    );
    Ok(())
}
