//! Topology comparison (paper Fig. 4 + extensions): CiderTF over ring,
//! star, complete, chain, and 2-D torus graphs — same K, same data.
//! The paper compares ring vs star; the other graphs probe how the
//! spectral gap of the Metropolis weights affects convergence.
//!
//!     cargo run --release --example topology_comparison

use cidertf::engine::{train, AlgoConfig, TrainConfig};
use cidertf::harness::Ctx;
use cidertf::losses::Loss;
use cidertf::runtime::{default_artifact_dir, PjrtBackend};
use cidertf::tensor::synth::SynthConfig;
use cidertf::topology::{Graph, Topology};
use cidertf::util::benchkit::{fmt_bytes, Table};

fn main() -> anyhow::Result<()> {
    let data = SynthConfig::synthetic().generate();
    let mut backend = PjrtBackend::new(&default_artifact_dir())?;
    let k = 16; // 16 = 4x4 torus is valid
    println!("CiderTF (tau=4) across topologies, K={k}, synthetic/logit\n");
    let table =
        Table::new(&["topology", "links", "spectral_gap", "final_loss", "uplink", "wall_s"]);
    for topo in
        [Topology::Ring, Topology::Star, Topology::Complete, Topology::Chain, Topology::Torus]
    {
        let graph = Graph::build(topo, k)?;
        let mut cfg = TrainConfig::new("synthetic", Loss::Logit, AlgoConfig::cidertf(4));
        cfg.gamma = Ctx::gamma_for("synthetic", Loss::Logit);
        cfg.k = k;
        cfg.topology = topo;
        cfg.epochs = 3;
        cfg.iters_per_epoch = 250;
        let out = train(&cfg, &data, &mut backend, None)?;
        table.row(&[
            topo.name().to_string(),
            graph.total_links().to_string(),
            format!("{:.4}", graph.spectral_gap()),
            format!("{:.3e}", out.record.final_loss()),
            fmt_bytes(out.record.total.bytes as f64),
            format!("{:.1}", out.record.wall_s),
        ]);
    }
    println!("\n(paper Fig. 4: ring vs star converge alike; star ships fewer bytes)");
    Ok(())
}
