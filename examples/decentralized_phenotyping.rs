//! End-to-end validation driver (EXPERIMENTS.md §End-to-end): the paper's
//! headline workload, full pipeline, all layers composing.
//!
//! 1. generate the MIMIC-like EHR tensor (4352 patients x 320 dx x 320 med),
//!    partition across 8 hospitals on a ring;
//! 2. train CiderTF_m (Bernoulli-logit) through the PJRT artifacts
//!    (Pallas-fused gradient), logging loss curve + uplink ledger;
//! 3. case study (least squares, as the paper's BrasCPD-referenced study):
//!    CiderTF vs centralized BrasCPD -> FMS, top-3 phenotypes, planted
//!    support recovery, patient subgroups, tSNE + silhouette.
//!
//!     make artifacts && cargo run --release --example decentralized_phenotyping
//!     (CIDERTF_EPOCHS=12 for a longer run)

use cidertf::analysis::phenotype::{assign_subgroups, extract, support_recovery};
use cidertf::analysis::silhouette;
use cidertf::analysis::tsne::{tsne, TsneConfig};
use cidertf::engine::{train, AlgoConfig, TrainConfig};
use cidertf::factor::fms::fms;
use cidertf::harness::Ctx;
use cidertf::losses::Loss;
use cidertf::runtime::{default_artifact_dir, PjrtBackend};
use cidertf::tensor::synth::{SynthConfig, ValueKind};
use cidertf::util::benchkit::fmt_bytes;
use cidertf::util::csv::CsvWriter;
use cidertf::util::mat::Mat;

fn main() -> anyhow::Result<()> {
    let epochs: usize =
        std::env::var("CIDERTF_EPOCHS").ok().and_then(|s| s.parse().ok()).unwrap_or(8);
    let mut backend = PjrtBackend::new(&default_artifact_dir())?;

    // ---------- part 1: decentralized logit training (headline) ----------
    let synth_cfg = SynthConfig::mimic_like();
    let data = synth_cfg.generate();
    println!(
        "MIMIC-like tensor {:?}: {} nnz, density {:.2e}, {} planted phenotypes",
        data.tensor.dims,
        data.tensor.nnz(),
        data.tensor.density(),
        synth_cfg.rank
    );
    let mut cfg = TrainConfig::new("mimic_like", Loss::Logit, AlgoConfig::cidertf_m(8));
    // Nesterov momentum amplifies the steady-state step by 1/(1-beta).
    cfg.gamma = Ctx::gamma_for("mimic_like", Loss::Logit) * 0.1;
    cfg.epochs = epochs;
    println!("\n[1/3] CiderTF_m (tau=8), K=8 ring, Bernoulli-logit, gamma={} ...", cfg.gamma);
    let cider_m = train(&cfg, &data, &mut backend, None)?;
    for p in &cider_m.record.points {
        println!(
            "  epoch {:>2}  loss {:>12.4e}  uplink {:>10}  {:>6.1}s",
            p.epoch,
            p.loss,
            fmt_bytes(p.bytes as f64),
            p.time_s
        );
    }
    cider_m.record.write_csv(std::path::Path::new("results/e2e/cidertf_m_curve.csv"))?;

    // ---------- part 2: LS case study vs centralized BrasCPD ----------
    println!("\n[2/3] case study (least squares): CiderTF tau=8 vs centralized BrasCPD");
    let data_ls = SynthConfig::mimic_like().with_values(ValueKind::Gaussian).generate();
    let mut run = |algo: AlgoConfig, k: usize, ep: usize, be: &mut PjrtBackend| {
        let mut c = TrainConfig::new("mimic_like", Loss::Ls, algo);
        c.gamma = Ctx::gamma_for("mimic_like", Loss::Ls);
        c.k = k;
        c.epochs = ep;
        train(&c, &data_ls, be, None)
    };
    let cider = run(AlgoConfig::cidertf(8), 8, epochs, &mut backend)?;
    let bras = run(AlgoConfig::bras_cpd(), 1, epochs * 2, &mut backend)?;
    println!(
        "  cidertf loss {:.4e} ({:.1}s, uplink {}) | brascpd loss {:.4e} ({:.1}s)",
        cider.record.final_loss(),
        cider.record.wall_s,
        fmt_bytes(cider.record.total.bytes as f64),
        bras.record.final_loss(),
        bras.record.wall_s,
    );
    println!("  FMS(cidertf, brascpd) = {:.4}", fms(&cider.factors, &bras.factors));

    // ---------- part 3: phenotypes + subgroups ----------
    println!("\n[3/3] phenotype case study");
    let phenos = extract(&cider.factors, 3, 20);
    for (i, ph) in phenos.iter().enumerate() {
        let f0: Vec<String> =
            ph.top_features[0].iter().take(6).map(|&(id, w)| format!("dx{id}({w:.2})")).collect();
        let f1: Vec<String> =
            ph.top_features[1].iter().take(6).map(|&(id, w)| format!("med{id}({w:.2})")).collect();
        println!("  P{} (lambda {:.1}): {} | {}", i + 1, ph.weight, f0.join(" "), f1.join(" "));
    }
    println!(
        "  planted-support recovery (best-Jaccard avg over modes): {:.3}",
        support_recovery(&phenos, &data_ls.truth)
    );

    let top = cider.factors.top_components(3);
    let all: Vec<usize> = (0..cider.factors.rank()).collect();
    let patients = subsample(&cider.factors.mats[0], 800);
    let groups3 = assign_subgroups(&patients, &top);
    let groups_all = assign_subgroups(&patients, &all);
    let emb = tsne(&patients, &TsneConfig::default());
    let mut w =
        CsvWriter::create("results/e2e/tsne_mimic_like.csv", &["x", "y", "group_top3", "group_all"])?;
    for i in 0..emb.rows {
        w.row_f64(&[
            emb.at(i, 0) as f64,
            emb.at(i, 1) as f64,
            groups3[i] as f64,
            groups_all[i] as f64,
        ])?;
    }
    w.flush()?;
    println!("  tSNE embedding of {} patients -> results/e2e/tsne_mimic_like.csv", emb.rows);
    println!(
        "  subgroup silhouette: top-3 rule {:.3}, all-component argmax {:.3}",
        silhouette(&emb, &groups3),
        silhouette(&emb, &groups_all)
    );
    println!("\nloss curve -> results/e2e/cidertf_m_curve.csv");
    Ok(())
}

fn subsample(m: &Mat, max_rows: usize) -> Mat {
    if m.rows <= max_rows {
        return m.clone();
    }
    let stride = m.rows.div_ceil(max_rows);
    let rows: Vec<usize> = (0..m.rows).step_by(stride).collect();
    let mut out = Mat::zeros(rows.len(), m.cols);
    for (o, &i) in rows.iter().enumerate() {
        out.row_mut(o).copy_from_slice(m.row(i));
    }
    out
}
