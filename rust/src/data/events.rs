//! Event-log CSV → (patient × code × time) tensor builder.
//!
//! The shape real EHR extracts arrive in (MIMIC-III / CMS-style): one
//! event per row, `patient,code,time[,...]` with a header line. Each of
//! the three key columns is mapped through a vocabulary (ids assigned in
//! first-appearance order — deterministic for a given file), repeated
//! events accumulate as counts, and the result is a 3-mode
//! [`SparseTensor`] whose dims are the vocabulary sizes. Extra columns
//! are ignored; values beyond counts (e.g. doses) belong in a `.tns`
//! file instead. Parsing is plain comma splitting (offline substrate):
//! quoted fields are rejected with an error rather than silently
//! miskeyed.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use crate::tensor::SparseTensor;

/// One column's value ↔ id mapping.
#[derive(Debug, Clone, Default)]
pub struct Vocab {
    map: HashMap<String, u32>,
    /// names in id order (first appearance in the file)
    pub names: Vec<String>,
}

impl Vocab {
    fn intern(&mut self, s: &str) -> u32 {
        if let Some(&i) = self.map.get(s) {
            return i;
        }
        let i = self.names.len() as u32;
        self.map.insert(s.to_string(), i);
        self.names.push(s.to_string());
        i
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

/// The three vocabularies behind a loaded event tensor, in mode order.
#[derive(Debug, Clone)]
pub struct EventVocabs {
    pub patients: Vocab,
    pub codes: Vocab,
    pub times: Vocab,
}

/// Load an event-log CSV into a count tensor plus its vocabularies.
///
/// Counts accumulate in a `BTreeMap` keyed by the id triple, so entries
/// materialize in key order structurally — re-ingesting the same file
/// always yields a bit-identical tensor (asserted in the tests below).
/// The per-vocabulary `HashMap` is a lookup index only (ids are assigned
/// in first-appearance order and never iterated), so it cannot leak hash
/// order into the output.
pub fn load_events_csv(path: &Path) -> anyhow::Result<(SparseTensor, EventVocabs)> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    let (_, header) = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("{}: empty event log", path.display()))?;
    let n_cols = header.split(',').count();
    anyhow::ensure!(
        n_cols >= 3,
        "{}: event logs need at least 3 columns (patient,code,time), header has {n_cols}",
        path.display()
    );

    let mut vocabs: [Vocab; 3] = Default::default();
    let mut counts: BTreeMap<(u32, u32, u32), f32> = BTreeMap::new();
    for (lineno, line) in lines {
        // naive comma splitting by design (offline substrate, no csv
        // crate) — quoted fields would be silently miskeyed, so reject
        // them loudly instead
        anyhow::ensure!(
            !line.contains('"'),
            "{}:{}: quoted CSV fields are not supported — export plain comma-separated values",
            path.display(),
            lineno + 1
        );
        let mut fields = line.split(',');
        let mut key = [0u32; 3];
        for (vocab, slot) in vocabs.iter_mut().zip(key.iter_mut()) {
            let field = fields
                .next()
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "{}:{}: row has fewer than 3 fields",
                        path.display(),
                        lineno + 1
                    )
                })?
                .trim();
            anyhow::ensure!(
                !field.is_empty(),
                "{}:{}: empty key field",
                path.display(),
                lineno + 1
            );
            *slot = vocab.intern(field);
        }
        *counts.entry((key[0], key[1], key[2])).or_insert(0.0) += 1.0;
    }
    anyhow::ensure!(!counts.is_empty(), "{}: no event rows", path.display());

    let dims = vec![vocabs[0].len(), vocabs[1].len(), vocabs[2].len()];
    let mut t = SparseTensor::new(dims);
    // BTreeMap iteration is already key-ordered — no sort pass needed
    for (&(p, c, tm), &v) in counts.iter() {
        t.push(&[p, c, tm], v);
    }
    let [patients, codes, times] = vocabs;
    Ok((t, EventVocabs { patients, codes, times }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("cidertf_events_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn builds_count_tensor_with_vocab() {
        let path = tmp("ev.csv");
        std::fs::write(
            &path,
            "patient,code,time\n\
             p1,dx_flu,w1\n\
             p1,dx_flu,w1\n\
             p2,dx_flu,w2\n\
             p1,rx_abx,w1\n\
             p3,dx_cold,w3\n",
        )
        .unwrap();
        let (t, vocabs) = load_events_csv(&path).unwrap();
        assert_eq!(t.dims, vec![3, 3, 3]);
        assert_eq!(t.nnz(), 4, "repeat events aggregate");
        assert_eq!(vocabs.patients.names, vec!["p1", "p2", "p3"]);
        assert_eq!(vocabs.codes.names, vec!["dx_flu", "rx_abx", "dx_cold"]);
        assert_eq!(vocabs.times.names, vec!["w1", "w2", "w3"]);
        // (p1, dx_flu, w1) fired twice
        let e = (0..t.nnz()).find(|&e| t.entry(e) == [0, 0, 0]).unwrap();
        assert_eq!(t.vals[e], 2.0);
    }

    #[test]
    fn reingesting_the_same_log_is_bit_identical() {
        // regression for hash-order leakage: enough distinct keys that a
        // hash-ordered accumulator would almost surely permute entries
        let path = tmp("stable.csv");
        let mut body = String::from("patient,code,time\n");
        for i in 0..97u32 {
            // spread keys across all three vocabularies, with repeats
            body.push_str(&format!("p{},c{},t{}\n", i % 29, (i * 7) % 13, (i * 3) % 11));
            body.push_str(&format!("p{},c{},t{}\n", (i * 5) % 29, i % 13, (i * 2) % 11));
        }
        std::fs::write(&path, body).unwrap();
        let (t1, v1) = load_events_csv(&path).unwrap();
        let (t2, v2) = load_events_csv(&path).unwrap();
        assert_eq!(t1.dims, t2.dims);
        assert_eq!(t1.nnz(), t2.nnz());
        for e in 0..t1.nnz() {
            assert_eq!(t1.entry(e), t2.entry(e), "entry {e} index order drifted");
            assert_eq!(
                t1.vals[e].to_bits(),
                t2.vals[e].to_bits(),
                "entry {e} value drifted"
            );
        }
        assert_eq!(v1.patients.names, v2.patients.names);
        assert_eq!(v1.codes.names, v2.codes.names);
        assert_eq!(v1.times.names, v2.times.names);
    }

    #[test]
    fn extra_columns_ignored_and_whitespace_trimmed() {
        let path = tmp("extra.csv");
        std::fs::write(
            &path,
            "patient,code,time,note\n p1 , dx , w1 , something\np2,dx,w1,else\n",
        )
        .unwrap();
        let (t, vocabs) = load_events_csv(&path).unwrap();
        assert_eq!(t.dims, vec![2, 1, 1]);
        assert_eq!(vocabs.patients.names, vec!["p1", "p2"]);
        assert!(!vocabs.codes.is_empty());
    }

    #[test]
    fn error_paths() {
        let path = tmp("empty.csv");
        std::fs::write(&path, "\n\n").unwrap();
        assert!(load_events_csv(&path).is_err());

        let path = tmp("narrow.csv");
        std::fs::write(&path, "a,b\n1,2\n").unwrap();
        let err = format!("{:#}", load_events_csv(&path).unwrap_err());
        assert!(err.contains("3 columns"), "{err}");

        let path = tmp("short_row.csv");
        std::fs::write(&path, "a,b,c\np1,dx\n").unwrap();
        assert!(load_events_csv(&path).is_err());

        let path = tmp("only_header.csv");
        std::fs::write(&path, "a,b,c\n").unwrap();
        assert!(load_events_csv(&path).is_err(), "no data rows");

        // quoted fields would be miskeyed by naive splitting — rejected
        let path = tmp("quoted.csv");
        std::fs::write(&path, "a,b,c\np1,\"401.9, unspecified\",w1\n").unwrap();
        let err = format!("{:#}", load_events_csv(&path).unwrap_err());
        assert!(err.contains("quoted"), "{err}");
    }
}
