//! Dataset ingestion: the run currency ([`Dataset`]), the pluggable
//! [`DatasetSource`] trait, and loaders for real tensors on disk.
//!
//! Every execution path consumes a [`Dataset`] — a sparse tensor plus
//! (for generated data) the planted ground-truth factors. Where the
//! tensor comes from is a registry axis
//! ([`crate::registry::datasets`]), so `--dataset` accepts either a
//! synthetic generator name (`synthetic`, `mimic_like`, ...) or a
//! loader spec:
//!
//! * `file:<path>` — a FROSTT-style `.tns` COO text file ([`tns`]) or
//!   the compact binary format ([`bin`]), selected by extension,
//! * `csv:<path>` — an event-log CSV (`patient,code,time` rows) built
//!   into a (patient × code × time) count tensor with vocabulary
//!   mapping ([`events`]).
//!
//! Loaded datasets ride the whole pipeline: spec JSON, `Session`,
//! checkpoint/resume (the checkpointed spec stores the loader string and
//! re-loads the file on resume), `cidertf info`, and the harness.

pub mod bin;
pub mod events;
pub mod tns;

use std::path::{Path, PathBuf};

use crate::tensor::synth::{SynthConfig, ValueKind};
use crate::tensor::SparseTensor;
use crate::util::mat::Mat;

/// One experiment's data: the sparse tensor plus, for synthetic data,
/// the planted ground-truth factors (used for FMS and the phenotype
/// recovery study). Loaded real datasets have no oracle — `truth` is
/// empty.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub tensor: SparseTensor,
    /// planted factors, one `I_m x R` matrix per mode; empty when the
    /// tensor was loaded from disk
    pub truth: Vec<Mat>,
}

impl Dataset {
    /// Order-sensitive FNV-1a fingerprint over dims, entry indices, and
    /// value bit patterns — the cheap identity check checkpoints use to
    /// fail loudly when a `file:`/`csv:` source changed between
    /// checkpoint and resume.
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &d in &self.tensor.dims {
            h ^= d as u64;
            h = h.wrapping_mul(PRIME);
        }
        for &i in &self.tensor.idx {
            h ^= i as u64;
            h = h.wrapping_mul(PRIME);
        }
        for &v in &self.tensor.vals {
            h ^= v.to_bits() as u64;
            h = h.wrapping_mul(PRIME);
        }
        h
    }
}

/// One way to materialize a [`Dataset`]. Implementations are registered
/// in [`crate::registry::datasets`] and resolved by name from specs,
/// the CLI, and the harness.
pub trait DatasetSource {
    /// Where the data comes from (for logs and error messages).
    fn describe(&self) -> String;

    /// Materialize the dataset. `vk` selects the value model for
    /// *generated* sources (Gaussian for ls, binary for logit, as in the
    /// paper); loaders keep whatever values are stored on disk.
    fn load(&self, vk: ValueKind) -> anyhow::Result<Dataset>;
}

/// Resolve `name` through the dataset registry and load it.
pub fn load_dataset(name: &str, vk: ValueKind) -> anyhow::Result<Dataset> {
    crate::registry::datasets().resolve(name)?.load(vk)
}

/// A synthetic generator as a [`DatasetSource`].
pub struct SynthSource(pub SynthConfig);

impl DatasetSource for SynthSource {
    fn describe(&self) -> String {
        format!("synthetic generator {:?} rank {}", self.0.dims, self.0.rank)
    }

    fn load(&self, vk: ValueKind) -> anyhow::Result<Dataset> {
        Ok(self.0.clone().with_values(vk).generate())
    }
}

/// A sparse tensor file (`.tns` text or `.bin`/`.ctf` binary) as a
/// [`DatasetSource`]. Values are taken as stored; under the Bernoulli
/// value model (logit loss) a file carrying values outside {0, 1} gets
/// a one-line warning — the Bernoulli NLL is only meaningful on binary
/// data, and silent misuse is worse than noise on stderr.
pub struct FileSource(pub PathBuf);

impl DatasetSource for FileSource {
    fn describe(&self) -> String {
        format!("tensor file {}", self.0.display())
    }

    fn load(&self, vk: ValueKind) -> anyhow::Result<Dataset> {
        let tensor = load_tensor_file(&self.0)?;
        if vk == ValueKind::Binary && tensor.vals.iter().any(|&v| v != 0.0 && v != 1.0) {
            eprintln!(
                "warning: {} has non-binary values but the run uses the Bernoulli-logit \
                 loss; binarize the file or pass --loss ls",
                self.0.display()
            );
        }
        Ok(Dataset { tensor, truth: Vec::new() })
    }
}

/// An event-log CSV as a [`DatasetSource`] (vocabularies are rebuilt on
/// every load, deterministically from the file contents). Under the
/// Bernoulli value model (logit loss) repeated events are **binarized**
/// to 1.0 event indicators — the Bernoulli NLL diverges on counts ≥ 2;
/// the Gaussian model (ls loss) keeps the raw counts.
pub struct CsvSource(pub PathBuf);

impl DatasetSource for CsvSource {
    fn describe(&self) -> String {
        format!("event-log csv {}", self.0.display())
    }

    fn load(&self, vk: ValueKind) -> anyhow::Result<Dataset> {
        let (mut tensor, _vocabs) = events::load_events_csv(&self.0)?;
        if vk == ValueKind::Binary {
            for v in tensor.vals.iter_mut() {
                *v = 1.0;
            }
        }
        Ok(Dataset { tensor, truth: Vec::new() })
    }
}

/// Reject dim vectors whose cell space overflows u64 — `linearize`,
/// `fiber_of_entry`, and the fiber-index sizing all multiply dims and
/// would silently wrap in release builds on crafted headers.
pub(crate) fn validate_dims(dims: &[usize], what: &std::path::Path) -> anyhow::Result<()> {
    anyhow::ensure!(
        dims.iter().all(|&d| d > 0 && d < u32::MAX as usize),
        "{}: dims {dims:?} out of per-mode range",
        what.display()
    );
    anyhow::ensure!(
        dims.iter().try_fold(1u64, |acc, &d| acc.checked_mul(d as u64)).is_some(),
        "{}: dims {dims:?} overflow the u64 cell space",
        what.display()
    );
    Ok(())
}

/// Load a tensor file by extension: `.tns` → FROSTT-style text,
/// `.bin`/`.ctf` → the compact binary format.
pub fn load_tensor_file(path: &Path) -> anyhow::Result<SparseTensor> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("tns") => tns::load_tns(path),
        Some("bin") | Some("ctf") => bin::load_bin(path),
        other => anyhow::bail!(
            "{}: unsupported tensor extension {:?} (known: .tns, .bin, .ctf)",
            path.display(),
            other.unwrap_or("<none>")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_source_respects_value_kind() {
        let src = SynthSource(SynthConfig::tiny(5));
        let bin = src.load(ValueKind::Binary).unwrap();
        assert!(bin.tensor.vals.iter().all(|&v| v == 1.0));
        assert!(!bin.truth.is_empty());
        let gauss = src.load(ValueKind::Gaussian).unwrap();
        assert!(gauss.tensor.vals.iter().any(|&v| v != 1.0));
        assert!(!src.describe().is_empty());
    }

    #[test]
    fn unknown_extension_is_an_error() {
        let err = load_tensor_file(Path::new("/tmp/whatever.xyz")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("xyz") && msg.contains(".tns"), "{msg}");
    }

    #[test]
    fn dims_cell_space_overflow_rejected() {
        // each dim passes the per-mode range check; the product wraps u64
        let dims = vec![1usize << 31, 1 << 31, 1 << 31];
        let err = validate_dims(&dims, Path::new("crafted.bin")).unwrap_err();
        assert!(format!("{err:#}").contains("overflow"));
        assert!(validate_dims(&[4096, 256, 256], Path::new("ok")).is_ok());
    }
}
