//! Compact binary tensor format (`.bin`/`.ctf`).
//!
//! Fixed little-endian layout, written/read in one pass:
//!
//! ```text
//! magic    8 bytes   b"CTFBIN01"
//! order    u32       number of modes D (>= 2)
//! dims     D x u64   mode sizes
//! nnz      u64       entry count
//! idx      nnz*D u32 per-entry mode indices (entry-major, 0-based)
//! vals     nnz  u32  IEEE-754 f32 bit patterns
//! ```
//!
//! Values travel as raw bit patterns, so a write → load round trip is
//! bit-exact (including -0.0, subnormals, and NaN payloads).

use std::path::Path;

use crate::tensor::SparseTensor;

const MAGIC: [u8; 8] = *b"CTFBIN01";

fn take<'a>(bytes: &'a [u8], off: &mut usize, n: usize, path: &Path) -> anyhow::Result<&'a [u8]> {
    let end = off.checked_add(n).filter(|&e| e <= bytes.len()).ok_or_else(|| {
        anyhow::anyhow!("{}: truncated binary tensor (need {n} bytes at {off})", path.display())
    })?;
    let s = &bytes[*off..end];
    *off = end;
    Ok(s)
}

fn rd_u32(bytes: &[u8], off: &mut usize, path: &Path) -> anyhow::Result<u32> {
    let s = take(bytes, off, 4, path)?;
    Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
}

fn rd_u64(bytes: &[u8], off: &mut usize, path: &Path) -> anyhow::Result<u64> {
    let s = take(bytes, off, 8, path)?;
    Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
}

/// Load a binary tensor file (entry order preserved, values bit-exact).
pub fn load_bin(path: &Path) -> anyhow::Result<SparseTensor> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    let mut off = 0usize;
    let magic = take(&bytes, &mut off, 8, path)?;
    anyhow::ensure!(
        magic == MAGIC,
        "{}: not a cidertf binary tensor (bad magic)",
        path.display()
    );
    let order = rd_u32(&bytes, &mut off, path)? as usize;
    anyhow::ensure!(
        (2..=64).contains(&order),
        "{}: implausible order {order}",
        path.display()
    );
    let mut dims = Vec::with_capacity(order);
    for _ in 0..order {
        let d = rd_u64(&bytes, &mut off, path)?;
        anyhow::ensure!(
            d > 0 && d < u32::MAX as u64,
            "{}: dim {d} out of range",
            path.display()
        );
        dims.push(d as usize);
    }
    super::validate_dims(&dims, path)?;
    let nnz = rd_u64(&bytes, &mut off, path)? as usize;
    let total = nnz
        .checked_mul(order + 1)
        .and_then(|words| words.checked_mul(4))
        .and_then(|body| off.checked_add(body))
        .ok_or_else(|| anyhow::anyhow!("{}: nnz overflow", path.display()))?;
    anyhow::ensure!(
        bytes.len() == total,
        "{}: body is {} bytes, header promises {}",
        path.display(),
        bytes.len() - off,
        total - off
    );

    let mut t = SparseTensor::new(dims);
    let mut idx = vec![0u32; order];
    // see load_tns: duplicate coordinates are rejected, not merged
    let mut seen = std::collections::HashSet::with_capacity(nnz);
    for e in 0..nnz {
        for slot in idx.iter_mut() {
            *slot = rd_u32(&bytes, &mut off, path)?;
        }
        for (m, &i) in idx.iter().enumerate() {
            anyhow::ensure!(
                (i as usize) < t.dims[m],
                "{}: entry {e} mode-{m} index {i} >= dim {}",
                path.display(),
                t.dims[m]
            );
        }
        anyhow::ensure!(
            seen.insert(t.linearize(&idx)),
            "{}: duplicate entry {e} at coordinate {idx:?}",
            path.display()
        );
        t.idx.extend_from_slice(&idx);
    }
    for _ in 0..nnz {
        t.vals.push(f32::from_bits(rd_u32(&bytes, &mut off, path)?));
    }
    Ok(t)
}

/// Write `t` in the binary format (atomic: temp file + rename).
pub fn write_bin(path: &Path, t: &SparseTensor) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut buf =
        Vec::with_capacity(8 + 4 + t.dims.len() * 8 + 8 + t.idx.len() * 4 + t.vals.len() * 4);
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&(t.order() as u32).to_le_bytes());
    for &d in &t.dims {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    buf.extend_from_slice(&(t.nnz() as u64).to_le_bytes());
    for &i in &t.idx {
        buf.extend_from_slice(&i.to_le_bytes());
    }
    for &v in &t.vals {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    let tmp = path.with_extension("bin.tmp");
    std::fs::write(&tmp, &buf)
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("cannot move {} into place: {e}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("cidertf_bin_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip_bit_exact() {
        let mut t = SparseTensor::new(vec![7, 3, 9, 2]);
        t.push(&[0, 0, 0, 0], -0.0);
        t.push(&[6, 2, 8, 1], f32::MIN_POSITIVE / 2.0); // subnormal
        t.push(&[3, 1, 4, 0], 1.5e-7);
        let path = tmp("rt.bin");
        write_bin(&path, &t).unwrap();
        let back = load_bin(&path).unwrap();
        assert_eq!(back.dims, t.dims);
        assert_eq!(back.idx, t.idx);
        let bits: Vec<u32> = back.vals.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = t.vals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want);
    }

    #[test]
    fn corrupt_files_error_cleanly() {
        let path = tmp("short.bin");
        std::fs::write(&path, b"CTFBIN01\x03").unwrap();
        assert!(load_bin(&path).is_err(), "truncated header");

        let path = tmp("magic.bin");
        std::fs::write(&path, b"NOTATNSR________________").unwrap();
        let err = format!("{:#}", load_bin(&path).unwrap_err());
        assert!(err.contains("magic"), "{err}");

        // body length mismatch
        let mut t = SparseTensor::new(vec![2, 2]);
        t.push(&[0, 0], 1.0);
        let path = tmp("chop.bin");
        write_bin(&path, &t).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.pop();
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_bin(&path).is_err(), "chopped body");

        // out-of-range index
        let path = tmp("oob.bin");
        let mut t2 = SparseTensor::new(vec![2, 2]);
        t2.push(&[1, 1], 1.0);
        write_bin(&path, &t2).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // idx block starts after magic(8) + order(4) + dims(16) + nnz(8)
        bytes[36] = 9;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", load_bin(&path).unwrap_err());
        assert!(err.contains(">= dim"), "{err}");

        // duplicate coordinates rejected
        let path = tmp("dup.bin");
        let mut t3 = SparseTensor::new(vec![3, 3]);
        t3.push(&[1, 2], 1.0);
        t3.push(&[1, 2], 2.0);
        write_bin(&path, &t3).unwrap();
        let err = format!("{:#}", load_bin(&path).unwrap_err());
        assert!(err.contains("duplicate"), "{err}");
    }
}
