//! FROSTT-style `.tns` COO text format.
//!
//! One entry per line: `i_1 i_2 ... i_D value`, indices **1-based**
//! (the FROSTT convention), whitespace-separated. Lines starting with
//! `#` are comments; blank lines are ignored. The writer additionally
//! emits a `# dims: I_1 ... I_D` comment header so trailing-empty slices
//! survive a round trip; the loader honors it when present and falls
//! back to inferring each dim as the max observed index (plain FROSTT
//! files load fine). Duplicate coordinates are rejected — the engine
//! assumes one entry per cell.

use std::io::{BufWriter, Write};
use std::path::Path;

use crate::tensor::SparseTensor;

/// Load a `.tns` file into a [`SparseTensor`] (entry order preserved).
pub fn load_tns(path: &Path) -> anyhow::Result<SparseTensor> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    let mut declared_dims: Option<Vec<usize>> = None;
    let mut order: Option<usize> = None;
    // one flat index buffer (stride = order) — no per-entry allocations,
    // moved into the tensor wholesale once dims are known
    let mut idx_flat: Vec<u32> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut max_idx: Vec<u32> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(comment) = line.strip_prefix('#') {
            if let Some(dims_str) = comment.trim().strip_prefix("dims:") {
                let dims: Vec<usize> = dims_str
                    .split_whitespace()
                    .map(|t| t.parse::<usize>())
                    .collect::<Result<_, _>>()
                    .map_err(|_| {
                        anyhow::anyhow!(
                            "{}:{}: malformed '# dims:' header",
                            path.display(),
                            lineno + 1
                        )
                    })?;
                declared_dims = Some(dims);
            }
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        anyhow::ensure!(
            toks.len() >= 3,
            "{}:{}: entry needs at least 2 indices and a value, got {} token(s)",
            path.display(),
            lineno + 1,
            toks.len()
        );
        let d = toks.len() - 1;
        match order {
            None => {
                order = Some(d);
                max_idx = vec![0u32; d];
            }
            Some(o) => anyhow::ensure!(
                o == d,
                "{}:{}: entry has {d} indices, earlier entries had {o}",
                path.display(),
                lineno + 1
            ),
        }
        for (m, tok) in toks[..d].iter().enumerate() {
            let i: u64 = tok.parse().map_err(|_| {
                anyhow::anyhow!("{}:{}: bad index '{tok}'", path.display(), lineno + 1)
            })?;
            anyhow::ensure!(
                i >= 1 && i <= u32::MAX as u64,
                "{}:{}: index {i} out of range (.tns indices are 1-based)",
                path.display(),
                lineno + 1
            );
            let zero_based = (i - 1) as u32;
            if zero_based > max_idx[m] {
                max_idx[m] = zero_based;
            }
            idx_flat.push(zero_based);
        }
        let val: f32 = toks[d].parse().map_err(|_| {
            anyhow::anyhow!("{}:{}: bad value '{}'", path.display(), lineno + 1, toks[d])
        })?;
        vals.push(val);
    }

    let order = order
        .ok_or_else(|| anyhow::anyhow!("{}: no tensor entries found", path.display()))?;
    anyhow::ensure!(order >= 2, "{}: tensors need at least 2 modes, got {order}", path.display());
    let dims: Vec<usize> = match declared_dims {
        Some(dims) => {
            anyhow::ensure!(
                dims.len() == order,
                "{}: '# dims:' header names {} modes, entries have {order}",
                path.display(),
                dims.len()
            );
            for (m, (&dim, &mx)) in dims.iter().zip(max_idx.iter()).enumerate() {
                anyhow::ensure!(
                    (mx as usize) < dim,
                    "{}: mode-{m} index {} exceeds declared dim {dim}",
                    path.display(),
                    mx as usize + 1
                );
            }
            dims
        }
        None => max_idx.iter().map(|&m| m as usize + 1).collect(),
    };
    super::validate_dims(&dims, path)?;
    let mut t = SparseTensor::new(dims);
    t.idx = idx_flat;
    t.vals = vals;
    // Duplicate coordinates would make the gather (last write wins) and
    // the loss estimator (counts every entry) silently disagree — reject.
    let mut seen = std::collections::HashSet::with_capacity(t.nnz());
    for e in 0..t.nnz() {
        anyhow::ensure!(
            seen.insert(t.linearize(t.entry(e))),
            "{}: duplicate entry at coordinate {:?} (1-based) — merge values first",
            path.display(),
            t.entry(e).iter().map(|&i| i + 1).collect::<Vec<u32>>()
        );
    }
    Ok(t)
}

/// Write `t` as a `.tns` file (with the `# dims:` header; values use
/// Rust's shortest round-trip float formatting, so load-back is exact).
pub fn write_tns(path: &Path, t: &SparseTensor) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", path.display()))?;
    let mut w = BufWriter::new(f);
    let dims: Vec<String> = t.dims.iter().map(|d| d.to_string()).collect();
    writeln!(w, "# dims: {}", dims.join(" "))?;
    for e in 0..t.nnz() {
        for &i in t.entry(e) {
            write!(w, "{} ", i + 1)?;
        }
        writeln!(w, "{}", t.vals[e])?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("cidertf_tns_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn write_load_round_trip_exact() {
        let mut t = SparseTensor::new(vec![5, 4, 3]);
        t.push(&[0, 0, 0], 1.0);
        t.push(&[4, 3, 2], -0.62511176);
        t.push(&[2, 1, 0], 3.25e-8);
        let path = tmp("rt.tns");
        write_tns(&path, &t).unwrap();
        let back = load_tns(&path).unwrap();
        assert_eq!(back.dims, t.dims, "dims header honored");
        assert_eq!(back.idx, t.idx);
        let bits: Vec<u32> = back.vals.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = t.vals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want, "values must round-trip bit-exactly");
    }

    #[test]
    fn plain_frostt_without_header_infers_dims() {
        let path = tmp("plain.tns");
        std::fs::write(&path, "1 1 1 2.5\n3 2 4 1\n").unwrap();
        let t = load_tns(&path).unwrap();
        assert_eq!(t.dims, vec![3, 2, 4]);
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.entry(1), &[2, 1, 3]);
    }

    #[test]
    fn error_paths() {
        let path = tmp("bad0.tns");
        std::fs::write(&path, "0 1 1.0\n").unwrap();
        assert!(load_tns(&path).is_err(), "0 index must error (1-based format)");

        let path = tmp("badmix.tns");
        std::fs::write(&path, "1 1 1 1.0\n1 1 1.0\n").unwrap();
        let err = format!("{:#}", load_tns(&path).unwrap_err());
        assert!(err.contains("indices"), "{err}");

        let path = tmp("badval.tns");
        std::fs::write(&path, "1 1 x\n").unwrap();
        assert!(load_tns(&path).is_err());

        let path = tmp("empty.tns");
        std::fs::write(&path, "# nothing here\n").unwrap();
        assert!(load_tns(&path).is_err());

        let path = tmp("overflow.tns");
        std::fs::write(&path, "# dims: 2 2\n3 1 1.0\n").unwrap();
        let err = format!("{:#}", load_tns(&path).unwrap_err());
        assert!(err.contains("exceeds"), "{err}");

        // duplicate coordinates would make gather and loss disagree
        let path = tmp("dup.tns");
        std::fs::write(&path, "1 1 2.0\n2 2 1.0\n1 1 3.0\n").unwrap();
        let err = format!("{:#}", load_tns(&path).unwrap_err());
        assert!(err.contains("duplicate"), "{err}");
    }
}
