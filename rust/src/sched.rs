//! Schedules and samplers: the block-randomization sequence `d_ξ[t]`, the
//! per-client fiber sampler, the learning-rate schedule, and the
//! event-trigger threshold schedule `λ[t]` (paper §III-B, §IV-A3).

use crate::util::rng::Rng;

/// Shared randomized block (mode) sampling sequence — all clients draw the
/// same mode each round (Alg. 1 input), so the sequence is derived from a
/// shared seed, independent of client id.
#[derive(Debug, Clone)]
pub struct BlockSampler {
    d_order: usize,
    rng: Rng,
    /// when false, cycle deterministically (for baselines that update all
    /// modes this is unused)
    randomized: bool,
    t: usize,
}

impl BlockSampler {
    pub fn new(d_order: usize, seed: u64, randomized: bool) -> Self {
        BlockSampler { d_order, rng: Rng::new(seed ^ 0xB10C), randomized, t: 0 }
    }

    /// Mode for round t (paper eq. 11: uniform over modes).
    pub fn next_mode(&mut self) -> usize {
        let m = if self.randomized {
            self.rng.below(self.d_order)
        } else {
            self.t % self.d_order
        };
        self.t += 1;
        m
    }

    /// Snapshot the sampler stream (RNG state + draw counter) for
    /// checkpointing; `d_order`/`randomized` are rebuilt from the config.
    pub fn state(&self) -> (([u64; 4], Option<f64>), usize) {
        (self.rng.state(), self.t)
    }

    /// Restore a [`BlockSampler::state`] snapshot so the mode sequence
    /// continues bit-identically.
    pub fn restore(&mut self, rng: ([u64; 4], Option<f64>), t: usize) {
        self.rng = Rng::from_state(rng.0, rng.1);
        self.t = t;
    }
}

/// Per-client fiber sampler: `|S|` distinct mode-d fibers per iteration.
///
/// Owns the scratch buffers of [`Rng::sample_indices_into`] so the
/// steady-state [`FiberSampler::sample_into`] path performs no heap
/// allocations once the buffers have reached their working size.
#[derive(Debug, Clone)]
pub struct FiberSampler {
    rng: Rng,
    idx: Vec<usize>,
    scratch: Vec<usize>,
    chosen: std::collections::HashSet<usize>,
}

impl FiberSampler {
    pub fn new(seed: u64, client: u64) -> Self {
        FiberSampler {
            rng: Rng::new(seed ^ 0xF1BE).split(client + 1),
            idx: Vec::new(),
            scratch: Vec::new(),
            chosen: std::collections::HashSet::new(),
        }
    }

    /// Sample `s` distinct fibers out of `n_fibers` (or all if fewer).
    pub fn sample(&mut self, n_fibers: usize, s: usize) -> Vec<u64> {
        let mut out = Vec::new();
        self.sample_into(n_fibers, s, &mut out);
        out
    }

    /// Allocation-free (steady-state) variant of [`FiberSampler::sample`]:
    /// delegates to [`Rng::sample_indices_into`] — the single source of
    /// truth for the sampling algorithm — so the draws are identical to
    /// `Rng::sample_indices` on the same stream.
    pub fn sample_into(&mut self, n_fibers: usize, s: usize, out: &mut Vec<u64>) {
        let take = s.min(n_fibers);
        self.rng.sample_indices_into(
            n_fibers,
            take,
            &mut self.idx,
            &mut self.scratch,
            &mut self.chosen,
        );
        out.clear();
        out.extend(self.idx.iter().map(|&i| i as u64));
    }

    /// Snapshot the sampling stream for checkpointing. The scratch
    /// buffers are cleared on every draw, so the RNG state alone
    /// determines all future samples.
    pub fn rng_state(&self) -> ([u64; 4], Option<f64>) {
        self.rng.state()
    }

    /// Restore a [`FiberSampler::rng_state`] snapshot so the fiber
    /// sequence continues bit-identically.
    pub fn restore_rng(&mut self, state: ([u64; 4], Option<f64>)) {
        self.rng = Rng::from_state(state.0, state.1);
    }
}

/// Learning-rate schedule. The paper uses a constant rate found by grid
/// search over powers of two; a decay variant is provided for extensions.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    Constant(f64),
    /// γ[t] = γ0 / (1 + decay · epoch)
    InverseEpoch { gamma0: f64, decay: f64, iters_per_epoch: usize },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f64 {
        match *self {
            LrSchedule::Constant(g) => g,
            LrSchedule::InverseEpoch { gamma0, decay, iters_per_epoch } => {
                gamma0 / (1.0 + decay * (t / iters_per_epoch.max(1)) as f64)
            }
        }
    }
}

/// Event-trigger threshold schedule (follows SPARQ-SGD [41], §IV-A3):
/// `λ[0] = 1/γ`, multiplied by `alpha` every `every_epochs` epochs so that
/// late in training the trigger fires less and less often.
#[derive(Debug, Clone, Copy)]
pub struct TriggerSchedule {
    pub lambda0: f64,
    pub alpha: f64,
    pub every_epochs: usize,
    pub iters_per_epoch: usize,
}

impl TriggerSchedule {
    /// Paper's setting: λ[0] = 1/γ.
    pub fn paper_default(gamma: f64, iters_per_epoch: usize) -> Self {
        TriggerSchedule {
            lambda0: 1.0 / gamma,
            alpha: 1.3,
            every_epochs: 2,
            iters_per_epoch,
        }
    }

    pub fn at(&self, t: usize) -> f64 {
        let epoch = t / self.iters_per_epoch.max(1);
        let bumps = (epoch / self.every_epochs.max(1)) as i32;
        self.lambda0 * self.alpha.powi(bumps)
    }

    /// The Alg. 1 line-10 condition:
    /// `‖A[t+½] - Â[t]‖_F² >= λ[t] · γ[t]²`.
    pub fn fires(&self, dist_sq: f64, t: usize, gamma: f64) -> bool {
        dist_sq >= self.at(t) * gamma * gamma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_sampler_uniform_over_modes() {
        let mut s = BlockSampler::new(3, 1, true);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[s.next_mode()] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn block_sampler_shared_seed_agrees() {
        let mut a = BlockSampler::new(4, 77, true);
        let mut b = BlockSampler::new(4, 77, true);
        for _ in 0..100 {
            assert_eq!(a.next_mode(), b.next_mode());
        }
    }

    #[test]
    fn cyclic_mode_when_not_randomized() {
        let mut s = BlockSampler::new(3, 5, false);
        assert_eq!(
            (0..6).map(|_| s.next_mode()).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2]
        );
    }

    #[test]
    fn fiber_sampler_distinct_in_range() {
        let mut f = FiberSampler::new(9, 3);
        let s = f.sample(1000, 64);
        assert_eq!(s.len(), 64);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 64);
        assert!(s.iter().all(|&x| x < 1000));
        // fewer fibers than requested -> all of them
        let all = f.sample(10, 64);
        assert_eq!(all.len(), 10);
    }

    #[test]
    fn fiber_sampler_client_streams_independent() {
        let mut a = FiberSampler::new(9, 0);
        let mut b = FiberSampler::new(9, 1);
        assert_ne!(a.sample(10_000, 32), b.sample(10_000, 32));
    }

    #[test]
    fn lr_schedules() {
        let c = LrSchedule::Constant(0.25);
        assert_eq!(c.at(0), 0.25);
        assert_eq!(c.at(10_000), 0.25);
        let d = LrSchedule::InverseEpoch { gamma0: 1.0, decay: 1.0, iters_per_epoch: 100 };
        assert_eq!(d.at(0), 1.0);
        assert_eq!(d.at(100), 0.5);
        assert_eq!(d.at(350), 0.25);
    }

    #[test]
    fn trigger_schedule_grows_and_fires() {
        let ts = TriggerSchedule::paper_default(0.5, 500);
        assert!((ts.lambda0 - 2.0).abs() < 1e-12);
        assert_eq!(ts.at(0), ts.at(499));
        assert!(ts.at(500 * 2) > ts.at(0)); // bumped after every_epochs
        // fires iff dist_sq >= λ γ²
        let thr = ts.at(0) * 0.5 * 0.5;
        assert!(ts.fires(thr + 1e-9, 0, 0.5));
        assert!(!ts.fires(thr - 1e-9, 0, 0.5));
    }
}
