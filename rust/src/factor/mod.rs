//! CP/GCP factor machinery: factor sets, initialization, λ importance
//! weights, Khatri-Rao row products, and dense reconstruction for small
//! oracles.

pub mod fms;

use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// One factor matrix per mode, `A_(m)` of shape `I_m x R`.
#[derive(Debug, Clone)]
pub struct FactorSet {
    pub mats: Vec<Mat>,
}

impl FactorSet {
    /// Uniform `[0, scale)` init (the standard non-negative EHR TF init);
    /// every client must start from the *same* init (paper Alg. 1 input
    /// `A^k[0] = A[0]`), which callers achieve by passing the same seed.
    pub fn init_uniform(dims: &[usize], rank: usize, scale: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        FactorSet {
            mats: dims.iter().map(|&d| Mat::rand_uniform(d, rank, scale, &mut rng)).collect(),
        }
    }

    pub fn rank(&self) -> usize {
        self.mats[0].cols
    }

    pub fn order(&self) -> usize {
        self.mats.len()
    }

    pub fn dims(&self) -> Vec<usize> {
        self.mats.iter().map(|m| m.rows).collect()
    }

    /// Phenotype importance λ_r = Π_m ‖A_(m)(:,r)‖ (paper §IV-C).
    pub fn lambda_weights(&self) -> Vec<f64> {
        let per_mode: Vec<Vec<f64>> = self.mats.iter().map(|m| m.col_norms()).collect();
        (0..self.rank())
            .map(|r| per_mode.iter().map(|n| n[r]).product())
            .collect()
    }

    /// Indices of the top-`k` components by λ weight (descending). A
    /// degenerate factor (e.g. NaN from an exploded logit run) must not
    /// panic phenotype extraction at the end of an otherwise-finished
    /// sweep: NaN weights sort *last*, never first, never abort.
    pub fn top_components(&self, k: usize) -> Vec<usize> {
        let lw = self.lambda_weights();
        let mut order: Vec<usize> = (0..lw.len()).collect();
        order.sort_by(|&a, &b| crate::util::order::nan_last_desc_f64(&lw[a], &lw[b]));
        order.truncate(k);
        order
    }

    /// Model value at one multi-index: `sum_r prod_m A_(m)(i_m, r)`.
    pub fn value_at(&self, index: &[u32]) -> f32 {
        let r_dim = self.rank();
        let mut acc = 0.0f32;
        for r in 0..r_dim {
            let mut p = 1.0f32;
            for (m, mat) in self.mats.iter().enumerate() {
                p *= mat.at(index[m] as usize, r);
            }
            acc += p;
        }
        acc
    }

    /// Gather Khatri-Rao rows: for each sampled fiber of mode `mode`,
    /// the Hadamard product over the *other* modes' factor rows.
    /// Returns `[S, R]` row-major — the `H(S_d, :)` of paper §III-B2.
    pub fn khatri_rao_rows(&self, mode: usize, dims: &[usize], fibers: &[u64]) -> Mat {
        let r_dim = self.rank();
        let mut h = Mat::zeros(fibers.len(), r_dim);
        let mut idx_buf = vec![0u32; dims.len()];
        for (s, &fid) in fibers.iter().enumerate() {
            decode_into(dims, mode, fid, &mut idx_buf);
            let row = h.row_mut(s);
            row.fill(1.0);
            for (m, mat) in self.mats.iter().enumerate() {
                if m == mode {
                    continue;
                }
                let a_row = mat.row(idx_buf[m] as usize);
                for (o, &v) in row.iter_mut().zip(a_row.iter()) {
                    *o *= v;
                }
            }
        }
        h
    }
}

/// `decode_fiber` into a reusable buffer (hot path, avoids allocation).
/// Thin alias over the canonical
/// [`crate::tensor::decode_fiber_into`], kept for callers that think in
/// factor terms.
#[inline]
pub fn decode_into(dims: &[usize], mode: usize, fid: u64, out: &mut [u32]) {
    crate::tensor::decode_fiber_into(dims, mode, fid, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{decode_fiber, SparseTensor};

    fn small_factors() -> FactorSet {
        FactorSet::init_uniform(&[4, 3, 2], 3, 0.5, 99)
    }

    #[test]
    fn same_seed_same_init() {
        let a = small_factors();
        let b = small_factors();
        for (x, y) in a.mats.iter().zip(b.mats.iter()) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn lambda_weights_match_manual() {
        let f = small_factors();
        let lw = f.lambda_weights();
        for r in 0..3 {
            let manual: f64 = f
                .mats
                .iter()
                .map(|m| {
                    (0..m.rows).map(|i| (m.at(i, r) as f64).powi(2)).sum::<f64>().sqrt()
                })
                .product();
            assert!((lw[r] - manual).abs() < 1e-9);
        }
    }

    #[test]
    fn top_components_sorted_desc() {
        let mut f = small_factors();
        // boost column 1 of mode 0 to make it the clear winner
        for i in 0..f.mats[0].rows {
            *f.mats[0].at_mut(i, 1) = 10.0;
        }
        let top = f.top_components(2);
        assert_eq!(top[0], 1);
        let lw = f.lambda_weights();
        assert!(lw[top[0]] >= lw[top[1]]);
    }

    #[test]
    fn top_components_nan_lambda_sorts_last_not_panics() {
        // regression: a NaN λ weight used to panic partial_cmp().unwrap()
        let mut f = small_factors();
        for i in 0..f.mats[0].rows {
            *f.mats[0].at_mut(i, 0) = f32::NAN; // poison component 0
        }
        let lw = f.lambda_weights();
        assert!(lw[0].is_nan());
        let top = f.top_components(3);
        assert_eq!(top.len(), 3);
        // the poisoned component ranks last, after every finite weight
        assert_eq!(top[2], 0, "NaN component must sort last: {top:?}");
        assert!(top[0] != 0 && top[1] != 0);
    }

    #[test]
    fn khatri_rao_rows_match_value_at() {
        // H(s,:) . A_(d)(i,:) summed over r must equal the model value at
        // the cell (i at mode d, fiber s elsewhere).
        let dims = vec![4usize, 3, 2];
        let f = small_factors();
        let t = SparseTensor::new(dims.clone());
        for mode in 0..3 {
            let n_f = t.n_fibers(mode);
            let fibers: Vec<u64> = (0..n_f as u64).collect();
            let h = f.khatri_rao_rows(mode, &dims, &fibers);
            for (s, &fid) in fibers.iter().enumerate() {
                let mut idx = decode_fiber(&dims, mode, fid);
                for i in 0..dims[mode] {
                    idx[mode] = i as u32;
                    let dot: f32 = h
                        .row(s)
                        .iter()
                        .zip(f.mats[mode].row(i).iter())
                        .map(|(a, b)| a * b)
                        .sum();
                    let want = f.value_at(&idx);
                    assert!((dot - want).abs() < 1e-5, "mode {mode} fid {fid} i {i}");
                }
            }
        }
    }

    #[test]
    fn decode_into_matches_decode_fiber() {
        let dims = vec![5usize, 4, 3, 2];
        let mut buf = vec![0u32; 4];
        for mode in 0..4 {
            let n: usize = dims.iter().enumerate().filter(|(m, _)| *m != mode).map(|(_, &d)| d).product();
            for fid in [0u64, 1, (n - 1) as u64] {
                decode_into(&dims, mode, fid, &mut buf);
                assert_eq!(buf, decode_fiber(&dims, mode, fid));
            }
        }
    }
}
