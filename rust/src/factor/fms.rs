//! Factor Match Score (FMS) — Acar, Dunlavy, Kolda, Mørup (2011),
//! used by the paper's Fig. 7 / case study to compare decentralized
//! factors against the centralized baseline's.
//!
//! For two factor sets {A_(m)}, {B_(m)} of equal rank R, the per-pair
//! component similarity is
//!
//!   sim(r, s) = (1 - |λ_r - μ_s| / max(λ_r, μ_s))
//!               * Π_m |cos(A_(m)(:,r), B_(m)(:,s))|
//!
//! and FMS is the average of sim over a one-to-one matching of components.
//! We use greedy matching on the similarity matrix (exact Hungarian is
//! unnecessary at R <= 64; greedy matches the reference implementations'
//! behaviour for well-separated factors and is what we validate against).

use super::FactorSet;

/// Column-wise cosine similarity magnitudes between two `I x R` factors.
fn column_cosines(a: &crate::util::mat::Mat, b: &crate::util::mat::Mat) -> Vec<Vec<f64>> {
    assert_eq!(a.rows, b.rows, "factor row mismatch");
    let (ra, rb) = (a.cols, b.cols);
    let mut dots = vec![vec![0.0f64; rb]; ra];
    let mut na = vec![0.0f64; ra];
    let mut nb = vec![0.0f64; rb];
    for i in 0..a.rows {
        let ar = a.row(i);
        let br = b.row(i);
        for r in 0..ra {
            let av = ar[r] as f64;
            na[r] += av * av;
            for s in 0..rb {
                dots[r][s] += av * br[s] as f64;
            }
        }
        for s in 0..rb {
            let bv = br[s] as f64;
            nb[s] += bv * bv;
        }
    }
    for r in 0..ra {
        for s in 0..rb {
            let denom = (na[r].sqrt() * nb[s].sqrt()).max(1e-30);
            dots[r][s] = (dots[r][s] / denom).abs();
        }
    }
    dots
}

/// Component-pair similarity matrix (cosine product x λ penalty).
pub fn similarity_matrix(a: &FactorSet, b: &FactorSet) -> Vec<Vec<f64>> {
    assert_eq!(a.order(), b.order());
    let r_a = a.rank();
    let r_b = b.rank();
    let mut sim = vec![vec![1.0f64; r_b]; r_a];
    for m in 0..a.order() {
        let cos = column_cosines(&a.mats[m], &b.mats[m]);
        for r in 0..r_a {
            for s in 0..r_b {
                sim[r][s] *= cos[r][s];
            }
        }
    }
    let la = a.lambda_weights();
    let lb = b.lambda_weights();
    for r in 0..r_a {
        for s in 0..r_b {
            let (x, y) = (la[r], lb[s]);
            let penalty = 1.0 - (x - y).abs() / x.max(y).max(1e-30);
            sim[r][s] *= penalty.max(0.0);
        }
    }
    sim
}

/// Greedy one-to-one matching maximizing total similarity; returns
/// `(fms, matching)` where `matching[r] = s`.
pub fn fms_with_matching(a: &FactorSet, b: &FactorSet) -> (f64, Vec<usize>) {
    let sim = similarity_matrix(a, b);
    let r_dim = sim.len();
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for r in 0..r_dim {
        for s in 0..sim[r].len() {
            pairs.push((r, s));
        }
    }
    // descending by similarity; NaN entries (a degenerate factor poisons
    // whole rows/columns of `sim`) sort last instead of panicking, so a
    // diverged run still gets matched on its finite components first
    pairs.sort_by(|&(r1, s1), &(r2, s2)| {
        crate::util::order::nan_last_desc_f64(&sim[r1][s1], &sim[r2][s2])
    });
    let mut used_r = vec![false; r_dim];
    let mut used_s = vec![false; sim[0].len()];
    let mut matching = vec![usize::MAX; r_dim];
    let mut total = 0.0;
    let mut matched = 0;
    for (r, s) in pairs {
        if !used_r[r] && !used_s[s] {
            used_r[r] = true;
            used_s[s] = true;
            matching[r] = s;
            total += sim[r][s];
            matched += 1;
            if matched == r_dim.min(sim[0].len()) {
                break;
            }
        }
    }
    (total / r_dim as f64, matching)
}

/// Factor Match Score in `[0, 1]`; 1 = identical up to permutation/sign.
pub fn fms(a: &FactorSet, b: &FactorSet) -> f64 {
    fms_with_matching(a, b).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mat::Mat;
    use crate::util::rng::Rng;

    fn random_factors(dims: &[usize], rank: usize, seed: u64) -> FactorSet {
        let mut rng = Rng::new(seed);
        FactorSet {
            mats: dims.iter().map(|&d| Mat::rand_normal(d, rank, 1.0, &mut rng)).collect(),
        }
    }

    #[test]
    fn identical_factors_score_one() {
        let a = random_factors(&[20, 15, 10], 4, 1);
        let s = fms(&a, &a.clone());
        assert!((s - 1.0).abs() < 1e-9, "fms {s}");
    }

    #[test]
    fn permuted_columns_score_one() {
        let a = random_factors(&[20, 15, 10], 4, 2);
        // permute columns by rotation in every mode consistently
        let perm = [2usize, 3, 0, 1];
        let b = FactorSet {
            mats: a
                .mats
                .iter()
                .map(|m| Mat::from_fn(m.rows, m.cols, |i, j| m.at(i, perm[j])))
                .collect(),
        };
        let (s, matching) = fms_with_matching(&a, &b);
        assert!((s - 1.0).abs() < 1e-6, "fms {s}");
        // matching must invert the permutation
        for r in 0..4 {
            assert_eq!(perm[matching[r]], r);
        }
    }

    #[test]
    fn sign_flips_are_forgiven() {
        let a = random_factors(&[12, 12, 12], 3, 3);
        let b = FactorSet {
            mats: a
                .mats
                .iter()
                .map(|m| Mat::from_fn(m.rows, m.cols, |i, j| -m.at(i, j)))
                .collect(),
        };
        assert!((fms(&a, &b) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn unrelated_factors_score_low() {
        let a = random_factors(&[60, 50, 40], 5, 4);
        let b = random_factors(&[60, 50, 40], 5, 5);
        let s = fms(&a, &b);
        assert!(s < 0.35, "fms of unrelated factors {s}");
    }

    #[test]
    fn scaled_component_penalized_by_lambda_term() {
        let a = random_factors(&[15, 15, 15], 2, 6);
        let mut b = a.clone();
        // scale one component's columns by 4 in one mode -> λ mismatch
        for i in 0..b.mats[0].rows {
            *b.mats[0].at_mut(i, 0) *= 4.0;
        }
        let s = fms(&a, &b);
        assert!(s < 0.95 && s > 0.3, "fms {s}");
    }

    #[test]
    fn nan_poisoned_factors_do_not_panic() {
        // regression: a NaN similarity entry used to panic the greedy
        // pair sort via partial_cmp().unwrap()
        let a = random_factors(&[10, 8, 6], 3, 11);
        let mut b = a.clone();
        for i in 0..b.mats[1].rows {
            *b.mats[1].at_mut(i, 2) = f32::NAN; // poison one component
        }
        let sim = similarity_matrix(&a, &b);
        assert!(sim.iter().any(|row| row.iter().any(|v| v.is_nan())));
        let (_, matching) = fms_with_matching(&a, &b);
        // every component still gets a one-to-one match, and the two
        // clean components are matched to themselves (finite pairs win
        // before any NaN pair is considered)
        assert_eq!(matching.len(), 3);
        let mut seen = matching.clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
        assert_eq!(matching[0], 0);
        assert_eq!(matching[1], 1);
    }

    #[test]
    fn noisy_copy_scores_between() {
        let a = random_factors(&[40, 30, 20], 4, 7);
        let mut rng = Rng::new(8);
        let b = FactorSet {
            mats: a
                .mats
                .iter()
                .map(|m| Mat::from_fn(m.rows, m.cols, |i, j| m.at(i, j) + 0.1 * rng.normal_f32()))
                .collect(),
        };
        let s = fms(&a, &b);
        assert!(s > 0.9 && s < 1.0, "fms {s}");
    }
}
