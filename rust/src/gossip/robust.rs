//! Robust consensus aggregators — Byzantine-tolerant alternatives to the
//! weighted gossip mean of [`EstimateState::consensus_into`].
//!
//! The plain consensus step trusts every neighbor estimate linearly, so a
//! single Byzantine peer can drag `A[t+1]` arbitrarily far. The robust
//! aggregators replace the weighted average of neighbor estimates with a
//! per-coordinate robust center:
//!
//! * `trimmed_mean(β)` — drop the `⌊β·n⌋` smallest and largest values of
//!   each coordinate, mean the rest. `β = 0` trims nothing and is defined
//!   to dispatch to the *existing* weighted-mean code path, bit-identically.
//! * `coordinate_median` — the per-coordinate median (even counts average
//!   the two middles).
//!
//! For `β > 0` (and the median) the per-peer gossip weights no longer
//! scale individual values — a Byzantine peer's weight is exactly what it
//! would game — so the robust center is computed over the *unweighted*
//! value set `{Â^j : j ∈ N_k} ∪ {Â^k}` and the consensus step becomes
//! `a += ϱ (Σ_j w_kj) (center − Â^k)`: the same total step size as the
//! mean path, aimed at the robust center instead of the weighted average.
//!
//! Determinism: values are collected in fixed order (self, then the
//! graph's neighbor order) and sorted with a NaN-last `total_cmp`
//! comparator ([`crate::util::order::nan_last_f32`]), so the result is a
//! pure function of the value multiset — bit-identical across drivers,
//! worker counts, and input permutations. NaN and ±inf payloads sort to
//! the extremes, which is precisely where trimming removes them.

use crate::util::mat::Mat;
use crate::util::order::nan_last_f32;

use super::EstimateState;

/// Which consensus aggregator a run uses (spec axis `aggregator`).
#[derive(Debug, Clone, PartialEq)]
pub enum Aggregator {
    /// The paper's weighted gossip mean (Alg. 1 line 18) — the default.
    Mean,
    /// Per-coordinate β-trimmed mean over neighbor+self estimates.
    /// `TrimmedMean(0.0)` is bit-identical to [`Aggregator::Mean`].
    TrimmedMean(f64),
    /// Per-coordinate median over neighbor+self estimates.
    CoordinateMedian,
}

impl Aggregator {
    /// Short axis name (registry key).
    pub fn name(&self) -> &'static str {
        match self {
            Aggregator::Mean => "mean",
            Aggregator::TrimmedMean(_) => "trimmed_mean",
            Aggregator::CoordinateMedian => "coordinate_median",
        }
    }

    /// Registry-parseable string form (`mean`, `trimmed_mean:<beta>`,
    /// `coordinate_median`) — what `ExperimentSpec` JSON carries.
    pub fn spec_string(&self) -> String {
        match self {
            Aggregator::Mean => "mean".to_string(),
            Aggregator::TrimmedMean(b) => format!("trimmed_mean:{b}"),
            Aggregator::CoordinateMedian => "coordinate_median".to_string(),
        }
    }

    /// Filesystem-safe label fragment for run stems (no `:`).
    pub fn label_component(&self) -> String {
        match self {
            Aggregator::Mean => "mean".to_string(),
            Aggregator::TrimmedMean(b) => format!("trim{b}"),
            Aggregator::CoordinateMedian => "median".to_string(),
        }
    }

    /// One consensus step on `a = A[t+½]`, dispatching between the
    /// weighted-mean path and the robust per-coordinate path.
    pub fn consensus_into(
        &self,
        est: &EstimateState,
        a: &mut Mat,
        mode: usize,
        neighbors: &[usize],
        weights_row: &[f64],
        rho: f64,
    ) {
        match self {
            // β = 0 trims nothing: defined as the literal mean code path
            // so `trimmed_mean:0` is bit-identical to `mean`.
            Aggregator::Mean => est.consensus_into(a, mode, neighbors, weights_row, rho),
            Aggregator::TrimmedMean(beta) if *beta == 0.0 => {
                est.consensus_into(a, mode, neighbors, weights_row, rho);
            }
            Aggregator::TrimmedMean(beta) => {
                robust_step(est, a, mode, neighbors, weights_row, rho, |vals| {
                    trimmed_mean_of(vals, *beta)
                });
            }
            Aggregator::CoordinateMedian => {
                robust_step(est, a, mode, neighbors, weights_row, rho, |vals| {
                    coordinate_median_of(vals)
                });
            }
        }
    }
}

std::thread_local! {
    /// Reused per-thread scratch for [`robust_step`]: the per-coordinate
    /// value buffer and the neighbor→estimate-slot map. Both keep their
    /// capacity across calls, so a robust consensus round allocates
    /// nothing in steady state (gated by `tests/alloc_free.rs`).
    static ROBUST_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<usize>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

/// `a += ϱ (Σ_j w_kj) (center(values) − Â^k)` per coordinate, with
/// `values = [Â^k, Â^j...]` collected in fixed (self, neighbor) order.
fn robust_step(
    est: &EstimateState,
    a: &mut Mat,
    mode: usize,
    neighbors: &[usize],
    weights_row: &[f64],
    rho: f64,
    center: impl Fn(&mut [f32]) -> f32,
) {
    crate::util::invariant::neighbors_sorted(neighbors);
    let self_hat = est.self_estimate(mode);
    let sum_w: f64 = neighbors.iter().map(|&j| weights_row[j]).sum();
    let c = (rho * sum_w) as f32;
    if c == 0.0 || neighbors.is_empty() {
        return;
    }
    ROBUST_SCRATCH.with(|cell| {
        let (vals, slots) = &mut *cell.borrow_mut();
        // neighbor → estimate slot, resolved once per call (a `Vec<&Mat>`
        // here would allocate every round — this fold sits on the per-mode
        // per-round hot path now)
        slots.clear();
        slots.extend(neighbors.iter().map(|&j| est.slot_of(j)));
        debug_assert!(slots.iter().all(|&s| {
            est.mats[s][mode].as_ref().is_some_and(|h| h.data.len() == a.data.len())
        }));
        for (i, av) in a.data.iter_mut().enumerate() {
            vals.clear();
            let vk = self_hat.data[i];
            vals.push(vk);
            for &s in slots.iter() {
                vals.push(est.mats[s][mode].as_ref().expect("untracked mode").data[i]);
            }
            *av += c * (center(vals) - vk);
        }
    });
}

/// β-trimmed mean: sort (NaN last), drop `⌊β·n⌋` from each end, mean the
/// rest in sorted order. `β` is clamped so at least one value survives.
/// Pure and permutation-invariant — the test-facing core of
/// [`Aggregator::TrimmedMean`].
pub fn trimmed_mean_of(values: &mut [f32], beta: f64) -> f32 {
    assert!(!values.is_empty(), "trimmed mean of no values");
    values.sort_by(nan_last_f32);
    let n = values.len();
    let g = ((beta.max(0.0) * n as f64).floor() as usize).min((n - 1) / 2);
    let kept = &values[g..n - g];
    let sum: f64 = kept.iter().map(|&v| v as f64).sum();
    (sum / kept.len() as f64) as f32
}

/// Per-coordinate median: sort (NaN last), take the middle (even counts
/// average the two middles). Pure and permutation-invariant.
pub fn coordinate_median_of(values: &mut [f32]) -> f32 {
    assert!(!values.is_empty(), "median of no values");
    values.sort_by(nan_last_f32);
    let n = values.len();
    if n % 2 == 1 {
        values[n / 2]
    } else {
        0.5 * (values[n / 2 - 1] + values[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trimmed_mean_drops_extremes() {
        let mut v = vec![100.0f32, 1.0, 2.0, 3.0, -100.0];
        assert_eq!(trimmed_mean_of(&mut v, 0.25), 2.0);
    }

    #[test]
    fn trim_zero_is_the_plain_mean() {
        let mut v = vec![1.0f32, 2.0, 6.0];
        assert_eq!(trimmed_mean_of(&mut v, 0.0), 3.0);
    }

    #[test]
    fn median_odd_and_even() {
        let mut odd = vec![5.0f32, 1.0, 3.0];
        assert_eq!(coordinate_median_of(&mut odd), 3.0);
        let mut even = vec![4.0f32, 1.0, 3.0, 2.0];
        assert_eq!(coordinate_median_of(&mut even), 2.5);
    }

    #[test]
    fn beta_clamps_to_keep_one_value() {
        let mut v = vec![7.0f32, 9.0];
        // β=0.5 would trim 1 from each end of 2 values; clamp keeps ≥1
        assert_eq!(trimmed_mean_of(&mut v, 0.5), 8.0);
    }

    #[test]
    fn spec_strings_are_stable() {
        assert_eq!(Aggregator::Mean.spec_string(), "mean");
        assert_eq!(Aggregator::TrimmedMean(0.25).spec_string(), "trimmed_mean:0.25");
        assert_eq!(Aggregator::CoordinateMedian.spec_string(), "coordinate_median");
        assert_eq!(Aggregator::TrimmedMean(0.25).label_component(), "trim0.25");
    }
}
