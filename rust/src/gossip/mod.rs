//! Gossip protocol state: compressed-difference peer estimates, the
//! event-trigger check, the consensus step, and the communication ledger
//! (paper Alg. 1 lines 9-18).
//!
//! Every client `k` maintains `Â_(d)^j` — its estimate of each neighbor's
//! (and its own) factor — updated only by the compressed deltas that
//! actually travel (CHOCO-style). The consensus step then mixes
//!
//!   `A_(d)^k[t+1] = A_(d)^k[t+½] + ϱ Σ_j w_kj (Â_(d)^j - Â_(d)^k)`.
//!
//! Only *feature* modes (d >= 1, zero-based) ever travel: the patient mode
//! is kept local for privacy (paper §III-B2) and is dimensionally local
//! anyway (each client owns different patients).

#![warn(missing_docs)]

pub mod robust;

pub use robust::Aggregator;

use crate::compress::Payload;
use crate::util::mat::Mat;

/// One gossip message (what the wire carries + accounting metadata).
#[derive(Debug, Clone)]
pub struct Message {
    /// sending client id
    pub from: usize,
    /// which factor mode the delta applies to (never 0 — the patient mode
    /// stays local, paper §III-B2)
    pub mode: usize,
    /// the sender's iteration `t` when the delta was published (receivers
    /// under asynchrony use this to detect staleness)
    pub round: usize,
    /// the compressed delta `C(A_(d)[t+½] − Â_(d)[t])` (Alg. 1 line 12)
    pub payload: Payload,
}

impl Message {
    /// Fixed header: from/mode/round/len (u32 each) — charged per message.
    pub const HEADER_BYTES: u64 = 16;

    /// Total bytes this message occupies on the wire (header + payload).
    pub fn wire_bytes(&self) -> u64 {
        Self::HEADER_BYTES + self.payload.wire_bytes()
    }

    /// Serialize to a length-prefixed wire frame: a u32 LE frame length
    /// followed by magic `"CT"`, version, the payload tag, the four
    /// accounted header words (from/mode/round/logical-len, u32 LE each),
    /// and the canonical payload body from
    /// [`Payload::encode_into`](crate::compress::Payload::encode_into).
    ///
    /// The 8 bytes of length prefix + magic + version + tag are transport
    /// envelope, deliberately *not* charged by
    /// [`Message::wire_bytes`]/[`CommLedger`]: the accounted cost stays
    /// `HEADER_BYTES + body`, so the ledger and the wire agree on the
    /// modeled protocol regardless of how frames are delimited.
    pub fn encode_frame(&self) -> Vec<u8> {
        let mut body = Vec::new();
        self.payload.encode_into(&mut body);
        encode_frame_parts(
            self.payload.tag(),
            self.from as u32,
            self.mode as u32,
            self.round as u32,
            self.payload.logical_len() as u32,
            &body,
        )
    }

    /// Decode one frame (the bytes *after* the u32 length prefix). The
    /// magic, version, tag, and all body-length relations are validated.
    pub fn decode_frame(frame: &[u8]) -> anyhow::Result<Message> {
        let (tag, from, mode, round, logical_len, body) = decode_frame_parts(frame)?;
        let payload = Payload::decode_body(tag, logical_len as usize, body)?;
        Ok(Message {
            from: from as usize,
            mode: mode as usize,
            round: round as usize,
            payload,
        })
    }
}

/// Frame magic: every frame after its length prefix starts `b"CT"`.
pub const FRAME_MAGIC: [u8; 2] = *b"CT";
/// Wire protocol version carried in every frame header.
pub const FRAME_VERSION: u8 = 1;
/// Frame bytes that precede the body: magic (2) + version + tag +
/// from/mode/round/logical-len (u32 LE each).
pub const FRAME_HEADER_BYTES: usize = 20;

/// Assemble a length-prefixed frame from raw header parts. Shared by
/// [`Message::encode_frame`] and the node control channel (which reuses
/// the envelope with its own tag space).
pub(crate) fn encode_frame_parts(
    tag: u8,
    from: u32,
    mode: u32,
    round: u32,
    logical_len: u32,
    body: &[u8],
) -> Vec<u8> {
    let frame_len = FRAME_HEADER_BYTES + body.len();
    let mut out = Vec::with_capacity(4 + frame_len);
    out.extend_from_slice(&(frame_len as u32).to_le_bytes());
    out.extend_from_slice(&FRAME_MAGIC);
    out.push(FRAME_VERSION);
    out.push(tag);
    out.extend_from_slice(&from.to_le_bytes());
    out.extend_from_slice(&mode.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&logical_len.to_le_bytes());
    out.extend_from_slice(body);
    out
}

/// Split a frame (without its length prefix) into
/// `(tag, from, mode, round, logical_len, body)`, validating magic and
/// version.
pub(crate) fn decode_frame_parts(
    frame: &[u8],
) -> anyhow::Result<(u8, u32, u32, u32, u32, &[u8])> {
    anyhow::ensure!(
        frame.len() >= FRAME_HEADER_BYTES,
        "frame is {} bytes, shorter than the {FRAME_HEADER_BYTES}-byte header",
        frame.len()
    );
    anyhow::ensure!(
        frame[..2] == FRAME_MAGIC,
        "bad frame magic {:02x}{:02x} (expected \"CT\")",
        frame[0],
        frame[1]
    );
    anyhow::ensure!(
        frame[2] == FRAME_VERSION,
        "unsupported wire version {} (this build speaks {FRAME_VERSION})",
        frame[2]
    );
    let u32_at =
        |o: usize| u32::from_le_bytes([frame[o], frame[o + 1], frame[o + 2], frame[o + 3]]);
    Ok((
        frame[3],
        u32_at(4),
        u32_at(8),
        u32_at(12),
        u32_at(16),
        &frame[FRAME_HEADER_BYTES..],
    ))
}

/// Uplink communication ledger for one client (the paper's reported
/// communication cost is uplink bytes summed over clients).
#[derive(Debug, Clone, Default)]
pub struct CommLedger {
    /// payload + header bytes actually sent
    pub bytes: u64,
    /// messages sent (including zero-payload suppressed notifications)
    pub messages: u64,
    /// rounds where the event trigger fired
    pub triggered: u64,
    /// rounds where the trigger suppressed the payload
    pub suppressed: u64,
}

impl CommLedger {
    /// Charge one uplink message (Alg. 1 line 14); `fired` records whether
    /// the event trigger passed (vs a suppressed zero-payload round).
    pub fn record(&mut self, msg: &Message, fired: bool) {
        self.bytes += msg.wire_bytes();
        self.messages += 1;
        if fired {
            self.triggered += 1;
        } else {
            self.suppressed += 1;
        }
    }

    /// Accumulate another client's ledger (for run-level totals).
    pub fn merge(&mut self, other: &CommLedger) {
        self.bytes += other.bytes;
        self.messages += other.messages;
        self.triggered += other.triggered;
        self.suppressed += other.suppressed;
    }
}

/// Per-client peer-estimate state `Â_(d)^j` for `j ∈ N_k ∪ {k}`.
#[derive(Debug, Clone)]
pub struct EstimateState {
    /// estimates indexed by [peer slot][mode]; slot order = `peers`
    pub peers: Vec<usize>,
    mats: Vec<Vec<Option<Mat>>>,
    /// this client's slot in `peers`
    self_slot: usize,
}

impl EstimateState {
    /// Initialize from the shared init `A[0]` (paper: `Â^j[0] = A[0]` —
    /// consistent because every client starts from the same factors).
    /// `init[mode]` is `None` for modes that never travel (patient mode).
    ///
    /// `neighbors` may already list `client` (a self-loop topology, or a
    /// caller that includes the client in its own neighborhood); peers
    /// are deduplicated so `slot_of` can never misalign with the
    /// estimate `mats` slots.
    pub fn new(client: usize, neighbors: &[usize], init: &[Option<Mat>]) -> Self {
        let mut peers = neighbors.to_vec();
        peers.push(client);
        peers.sort_unstable();
        // sort + dedup leaves the slot ids strictly increasing and
        // unique, so every id maps to exactly one estimate slot
        peers.dedup();
        let self_slot = peers.iter().position(|&p| p == client).unwrap();
        crate::util::invariant::estimate_slots_aligned(client, &peers, neighbors);
        let mats = peers.iter().map(|_| init.to_vec()).collect();
        EstimateState { peers, mats, self_slot }
    }

    fn slot_of(&self, peer: usize) -> usize {
        self.peers.iter().position(|&p| p == peer).expect("unknown peer")
    }

    /// `Â_(mode)^peer += decode(payload)` — Alg. 1 line 16.
    pub fn apply_delta(&mut self, peer: usize, mode: usize, payload: &Payload) {
        let slot = self.slot_of(peer);
        let m = self.mats[slot][mode]
            .as_mut()
            .expect("delta for a mode that never travels");
        payload.add_into(m);
    }

    /// `Â_(mode)^peer` — this client's current estimate of a peer's factor.
    pub fn estimate(&self, peer: usize, mode: usize) -> &Mat {
        self.mats[self.slot_of(peer)][mode].as_ref().expect("untracked mode")
    }

    /// `Â_(mode)^k` — the estimate every neighbor holds of *this* client
    /// (consistent because all peers apply the same broadcast deltas).
    pub fn self_estimate(&self, mode: usize) -> &Mat {
        self.mats[self.self_slot][mode].as_ref().expect("untracked mode")
    }

    /// Checkpoint view of the estimate matrices, indexed
    /// `[peer slot][mode]` in [`EstimateState::peers`] order (`None` for
    /// modes that never travel).
    pub fn snapshot_mats(&self) -> &[Vec<Option<Mat>>] {
        &self.mats
    }

    /// Restore a [`EstimateState::snapshot_mats`] checkpoint. The slot
    /// layout (peers + self) is rebuilt deterministically from the graph,
    /// so only the matrices travel through the checkpoint; shapes are
    /// validated against the current layout.
    pub fn restore_mats(&mut self, mats: Vec<Vec<Option<Mat>>>) -> anyhow::Result<()> {
        anyhow::ensure!(
            mats.len() == self.mats.len(),
            "estimate checkpoint has {} peer slots, expected {}",
            mats.len(),
            self.mats.len()
        );
        for (slot, (new, old)) in mats.iter().zip(self.mats.iter()).enumerate() {
            anyhow::ensure!(
                new.len() == old.len(),
                "estimate checkpoint slot {slot} has {} modes, expected {}",
                new.len(),
                old.len()
            );
            for (m, (n, o)) in new.iter().zip(old.iter()).enumerate() {
                match (n, o) {
                    (None, None) => {}
                    (Some(a), Some(b)) if a.rows == b.rows && a.cols == b.cols => {}
                    _ => anyhow::bail!("estimate checkpoint shape mismatch at slot {slot} mode {m}"),
                }
            }
        }
        self.mats = mats;
        Ok(())
    }

    /// Consensus step (Alg. 1 line 18):
    /// `a += ϱ Σ_{j∈N_k} w_kj (Â^j - Â^k)`, in place on `a = A[t+½]`.
    pub fn consensus_into(
        &self,
        a: &mut Mat,
        mode: usize,
        neighbors: &[usize],
        weights_row: &[f64],
        rho: f64,
    ) {
        let lv = crate::util::simd::level();
        let self_hat = self.self_estimate(mode);
        for &j in neighbors {
            let w = (rho * weights_row[j]) as f32;
            if w == 0.0 {
                continue;
            }
            let hat_j = self.estimate(j, mode);
            debug_assert_eq!(hat_j.rows, a.rows);
            // elementwise a += w * (hj - hk); bit-identical at every SIMD
            // level (see util::simd)
            crate::util::simd::scaled_diff_acc(lv, w, &hat_j.data, &self_hat.data, &mut a.data);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Compressor;

    fn mat(rows: usize, cols: usize, v: f32) -> Mat {
        Mat::from_vec(rows, cols, vec![v; rows * cols])
    }

    fn init3() -> Vec<Option<Mat>> {
        vec![None, Some(mat(3, 2, 1.0)), Some(mat(4, 2, 1.0))]
    }

    #[test]
    fn estimates_start_at_shared_init() {
        let st = EstimateState::new(1, &[0, 2], &init3());
        assert_eq!(st.peers, vec![0, 1, 2]);
        assert_eq!(st.estimate(0, 1).data, mat(3, 2, 1.0).data);
        assert_eq!(st.self_estimate(2).data, mat(4, 2, 1.0).data);
    }

    #[test]
    fn self_loop_topology_deduplicates_peer_slots() {
        // regression: a neighbor list that already contains the client
        // (self-loop topology) used to leave a duplicate id in `peers`,
        // misaligning slot_of with the estimate mats slots
        let mut st = EstimateState::new(1, &[0, 1, 2], &init3());
        assert_eq!(st.peers, vec![0, 1, 2]);
        // one slot per peer, and a delta addressed to a peer *after* the
        // client lands in the right slot
        let delta = Compressor::None.compress(&mat(3, 2, 0.5));
        st.apply_delta(2, 1, &delta);
        assert!(st.estimate(2, 1).data.iter().all(|&v| (v - 1.5).abs() < 1e-6));
        // the client's own estimate is untouched and consistent
        assert!(st.self_estimate(1).data.iter().all(|&v| v == 1.0));
        st.apply_delta(1, 1, &delta);
        assert!(st.self_estimate(1).data.iter().all(|&v| (v - 1.5).abs() < 1e-6));
    }

    #[test]
    fn apply_delta_accumulates() {
        let mut st = EstimateState::new(0, &[1], &init3());
        let delta = Compressor::None.compress(&mat(3, 2, 0.5));
        st.apply_delta(1, 1, &delta);
        st.apply_delta(1, 1, &delta);
        assert!(st.estimate(1, 1).data.iter().all(|&v| (v - 2.0).abs() < 1e-6));
        // self untouched
        assert!(st.self_estimate(1).data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn consensus_moves_toward_neighbors() {
        let mut st = EstimateState::new(0, &[1, 2], &init3());
        // neighbor 1's estimate goes up by 2, neighbor 2 stays
        st.apply_delta(1, 1, &Compressor::None.compress(&mat(3, 2, 2.0)));
        let mut a = mat(3, 2, 1.0);
        // uniform weights 1/3 each, rho = 1
        let w = vec![1.0 / 3.0; 3];
        st.consensus_into(&mut a, 1, &[1, 2], &w, 1.0);
        // a += 1/3*(3-1) + 1/3*(1-1) = 2/3
        assert!(a.data.iter().all(|&v| (v - (1.0 + 2.0 / 3.0)).abs() < 1e-6));
    }

    #[test]
    fn consensus_fixed_point_when_all_equal() {
        let st = EstimateState::new(0, &[1, 2], &init3());
        let mut a = mat(3, 2, 1.0);
        let before = a.clone();
        st.consensus_into(&mut a, 1, &[1, 2], &[0.3, 0.3, 0.4], 0.7);
        assert_eq!(a.data, before.data);
    }

    #[test]
    fn rho_scales_the_step() {
        let mut st = EstimateState::new(0, &[1], &init3());
        st.apply_delta(1, 1, &Compressor::None.compress(&mat(3, 2, 4.0)));
        let w = vec![0.5, 0.5];
        let mut a_full = mat(3, 2, 0.0);
        st.consensus_into(&mut a_full, 1, &[1], &w, 1.0);
        let mut a_half = mat(3, 2, 0.0);
        st.consensus_into(&mut a_half, 1, &[1], &w, 0.5);
        for (f, h) in a_full.data.iter().zip(a_half.data.iter()) {
            assert!((f - 2.0 * h).abs() < 1e-6);
        }
    }

    #[test]
    fn ledger_accounting() {
        let mut ledger = CommLedger::default();
        let fired = Message {
            from: 0,
            mode: 1,
            round: 7,
            payload: Compressor::Sign.compress(&mat(8, 4, 1.0)),
        };
        let zero = Message { from: 0, mode: 1, round: 8, payload: Payload::Zero { len: 32 } };
        ledger.record(&fired, true);
        ledger.record(&zero, false);
        assert_eq!(ledger.messages, 2);
        assert_eq!(ledger.triggered, 1);
        assert_eq!(ledger.suppressed, 1);
        assert_eq!(ledger.bytes, fired.wire_bytes() + Message::HEADER_BYTES);
        let mut total = CommLedger::default();
        total.merge(&ledger);
        total.merge(&ledger);
        assert_eq!(total.bytes, 2 * ledger.bytes);
    }

    #[test]
    #[should_panic(expected = "never travels")]
    fn patient_mode_delta_rejected() {
        let mut st = EstimateState::new(0, &[1], &init3());
        let delta = Compressor::None.compress(&mat(3, 2, 0.5));
        st.apply_delta(1, 0, &delta); // mode 0 = patient, untracked
    }

    #[test]
    fn message_frame_roundtrips_every_payload_variant() {
        crate::util::propcheck::forall(
            "message frame round-trip",
            256,
            |rng| Message {
                from: rng.below(1024),
                mode: rng.below(8),
                round: rng.below(1 << 20),
                payload: crate::compress::tests::arbitrary_payload(rng),
            },
            |msg, _| {
                let frame = msg.encode_frame();
                // u32 length prefix + envelope (magic/version/tag) +
                // accounted header + exactly wire_bytes() of body
                let expect =
                    4 + FRAME_HEADER_BYTES as u64 + msg.payload.wire_bytes();
                if frame.len() as u64 != expect {
                    return Err(format!("frame is {} bytes, expected {expect}", frame.len()));
                }
                let declared = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]);
                if declared as usize != frame.len() - 4 {
                    return Err(format!("length prefix {declared} != {}", frame.len() - 4));
                }
                let back = Message::decode_frame(&frame[4..])
                    .map_err(|e| format!("decode failed: {e:#}"))?;
                if (back.from, back.mode, back.round) != (msg.from, msg.mode, msg.round) {
                    return Err(format!(
                        "header mismatch: ({}, {}, {})",
                        back.from, back.mode, back.round
                    ));
                }
                if !crate::compress::tests::payload_bits_eq(&msg.payload, &back.payload) {
                    return Err(format!("payload mismatch: {:?}", back.payload));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn frame_decode_rejects_bad_envelope() {
        let msg = Message {
            from: 1,
            mode: 1,
            round: 3,
            payload: Compressor::Sign.compress(&mat(3, 2, 1.0)),
        };
        let frame = msg.encode_frame()[4..].to_vec();
        // truncated header
        assert!(Message::decode_frame(&frame[..10]).is_err());
        // bad magic
        let mut bad = frame.clone();
        bad[0] = b'X';
        let err = format!("{:#}", Message::decode_frame(&bad).unwrap_err());
        assert!(err.contains("magic"), "{err}");
        // wrong version
        let mut bad = frame.clone();
        bad[2] = 9;
        let err = format!("{:#}", Message::decode_frame(&bad).unwrap_err());
        assert!(err.contains("version"), "{err}");
        // body truncated relative to the declared logical length
        let bad = frame[..frame.len() - 1].to_vec();
        assert!(Message::decode_frame(&bad).is_err());
    }
}
