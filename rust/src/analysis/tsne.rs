//! Exact t-SNE (van der Maaten & Hinton 2008) — the substrate behind the
//! paper's Table III patient-subgroup visualization.
//!
//! O(N²) exact implementation (no Barnes-Hut): the harness embeds a few
//! thousand patient representation vectors, well within range. Gradient
//! descent with momentum and early exaggeration, per the reference
//! implementation's schedule.

use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// t-SNE hyperparameters.
#[derive(Debug, Clone)]
pub struct TsneConfig {
    pub perplexity: f64,
    pub iters: usize,
    pub learning_rate: f64,
    pub early_exaggeration: f64,
    pub exaggeration_iters: usize,
    pub seed: u64,
}

impl Default for TsneConfig {
    fn default() -> Self {
        // NOTE: with adaptive gains the classic lr=100/exaggeration=4
        // combination diverges on small point sets; lr≈10-20 with mild (or
        // no) exaggeration is stable and separates clusters cleanly.
        TsneConfig {
            perplexity: 30.0,
            iters: 300,
            learning_rate: 15.0,
            early_exaggeration: 1.0,
            exaggeration_iters: 50,
            seed: 0x7515,
        }
    }
}

/// Embed `x` (`N x d`) into 2-D. Returns an `N x 2` matrix.
pub fn tsne(x: &Mat, cfg: &TsneConfig) -> Mat {
    let n = x.rows;
    if n <= 2 {
        let mut y = Mat::zeros(n, 2);
        for i in 0..n {
            *y.at_mut(i, 0) = i as f32;
        }
        return y;
    }
    let p = joint_probabilities(x, cfg.perplexity);

    let mut rng = Rng::new(cfg.seed);
    let mut y = Mat::rand_normal(n, 2, 1e-2, &mut rng);
    let mut vel = Mat::zeros(n, 2);
    let mut gains = vec![1.0f64; n * 2];

    let mut q = vec![0.0f64; n * n];
    let mut num = vec![0.0f64; n * n];
    for it in 0..cfg.iters {
        let exaggeration = if it < cfg.exaggeration_iters { cfg.early_exaggeration } else { 1.0 };
        // student-t affinities
        let mut z = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                let dy0 = (y.at(i, 0) - y.at(j, 0)) as f64;
                let dy1 = (y.at(i, 1) - y.at(j, 1)) as f64;
                let t = 1.0 / (1.0 + dy0 * dy0 + dy1 * dy1);
                num[i * n + j] = t;
                num[j * n + i] = t;
                z += 2.0 * t;
            }
        }
        let z = z.max(1e-12);
        for v in q.iter_mut().zip(num.iter()) {
            *v.0 = (v.1 / z).max(1e-12);
        }
        // gradient: 4 Σ_j (p_ij·ex − q_ij) num_ij (y_i − y_j)
        let momentum = if it < 20 { 0.5 } else { 0.8 };
        for i in 0..n {
            let mut g0 = 0.0f64;
            let mut g1 = 0.0f64;
            for j in 0..n {
                if i == j {
                    continue;
                }
                let k = i * n + j;
                let coeff = (exaggeration * p[k] - q[k]) * num[k];
                g0 += coeff * (y.at(i, 0) - y.at(j, 0)) as f64;
                g1 += coeff * (y.at(i, 1) - y.at(j, 1)) as f64;
            }
            for (dim, g) in [(0usize, 4.0 * g0), (1usize, 4.0 * g1)] {
                let gi = i * 2 + dim;
                // adaptive gains (reference implementation)
                let same_sign = g.signum() == (vel.at(i, dim) as f64).signum();
                gains[gi] = if same_sign { (gains[gi] * 0.8).max(0.01) } else { gains[gi] + 0.2 };
                let v = momentum * vel.at(i, dim) as f64 - cfg.learning_rate * gains[gi] * g;
                *vel.at_mut(i, dim) = v as f32;
                *y.at_mut(i, dim) += v as f32;
            }
        }
        // recentre
        for dim in 0..2 {
            let mean: f32 = (0..n).map(|i| y.at(i, dim)).sum::<f32>() / n as f32;
            for i in 0..n {
                *y.at_mut(i, dim) -= mean;
            }
        }
    }
    y
}

/// Symmetrized high-dimensional affinities with per-point perplexity
/// calibration (binary search over Gaussian bandwidths).
fn joint_probabilities(x: &Mat, perplexity: f64) -> Vec<f64> {
    let n = x.rows;
    // pairwise squared distances
    let mut d2 = vec![0.0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let mut s = 0.0f64;
            for (a, b) in x.row(i).iter().zip(x.row(j).iter()) {
                let d = (a - b) as f64;
                s += d * d;
            }
            d2[i * n + j] = s;
            d2[j * n + i] = s;
        }
    }
    let target_entropy = perplexity.ln();
    let mut p = vec![0.0f64; n * n];
    let mut row = vec![0.0f64; n];
    for i in 0..n {
        // binary search beta = 1/(2σ²)
        let (mut lo, mut hi, mut beta) = (0.0f64, f64::INFINITY, 1.0f64);
        for _ in 0..50 {
            let mut sum = 0.0f64;
            let mut dot = 0.0f64;
            for j in 0..n {
                if j == i {
                    row[j] = 0.0;
                    continue;
                }
                let v = (-beta * d2[i * n + j]).exp();
                row[j] = v;
                sum += v;
                dot += v * d2[i * n + j];
            }
            let sum = sum.max(1e-300);
            let entropy = sum.ln() + beta * dot / sum;
            let diff = entropy - target_entropy;
            if diff.abs() < 1e-5 {
                break;
            }
            if diff > 0.0 {
                lo = beta;
                beta = if hi.is_finite() { (beta + hi) / 2.0 } else { beta * 2.0 };
            } else {
                hi = beta;
                beta = (beta + lo) / 2.0;
            }
        }
        let sum: f64 = row.iter().sum::<f64>().max(1e-300);
        for j in 0..n {
            p[i * n + j] = row[j] / sum;
        }
    }
    // symmetrize + normalize
    let mut joint = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            joint[i * n + j] = ((p[i * n + j] + p[j * n + i]) / (2.0 * n as f64)).max(1e-12);
        }
    }
    joint
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated Gaussian blobs in 5-D.
    fn blobs(n_per: usize, seed: u64) -> (Mat, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let centers = [[8.0, 0.0, 0.0, 0.0, 0.0], [0.0, 8.0, 0.0, 0.0, 0.0], [0.0, 0.0, 8.0, 0.0, 0.0]];
        let mut x = Mat::zeros(3 * n_per, 5);
        let mut labels = Vec::new();
        for (c, center) in centers.iter().enumerate() {
            for i in 0..n_per {
                let row = x.row_mut(c * n_per + i);
                for (d, v) in row.iter_mut().enumerate() {
                    *v = center[d] as f32 + 0.5 * rng.normal_f32();
                }
                labels.push(c);
            }
        }
        (x, labels)
    }

    #[test]
    fn separates_blobs() {
        let (x, labels) = blobs(30, 3);
        let cfg = TsneConfig { perplexity: 10.0, iters: 250, ..Default::default() };
        let y = tsne(&x, &cfg);
        let sil = crate::analysis::silhouette(&y, &labels);
        assert!(sil > 0.5, "silhouette {sil} too low — blobs not separated");
    }

    #[test]
    fn embedding_is_finite_and_centred() {
        let (x, _) = blobs(20, 4);
        let y = tsne(&x, &TsneConfig { iters: 100, ..Default::default() });
        assert!(y.data.iter().all(|v| v.is_finite()));
        for dim in 0..2 {
            let mean: f32 = (0..y.rows).map(|i| y.at(i, dim)).sum::<f32>() / y.rows as f32;
            assert!(mean.abs() < 1e-3);
        }
    }

    #[test]
    fn degenerate_inputs() {
        let y = tsne(&Mat::zeros(1, 3), &TsneConfig::default());
        assert_eq!(y.rows, 1);
        let y = tsne(&Mat::zeros(2, 3), &TsneConfig::default());
        assert_eq!(y.rows, 2);
    }

    #[test]
    fn perplexity_calibration_rows_sum_to_one() {
        let (x, _) = blobs(10, 5);
        let p = joint_probabilities(&x, 5.0);
        let n = x.rows;
        let total: f64 = p.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "joint P sums to {total}");
        for i in 0..n {
            assert!(p[i * n + i] <= 1e-11);
        }
    }
}
