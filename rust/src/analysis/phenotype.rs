//! Phenotype extraction (the paper's case study, Tables III-IV).
//!
//! * top-3 phenotypes by importance λ_r = Π_m ‖A_(m)(:,r)‖,
//! * per-mode top-weight features per phenotype (Table IV analogue; on
//!   synthetic data feature ids play the role of dx/px/med codes and are
//!   checked against the planted supports),
//! * patient subgroup assignment by the largest coordinate among the top
//!   phenotypes (Table III), feeding t-SNE + silhouette.

use crate::factor::FactorSet;
use crate::util::mat::Mat;

/// One extracted phenotype.
#[derive(Debug, Clone)]
pub struct Phenotype {
    /// component index r
    pub component: usize,
    /// importance weight λ_r
    pub weight: f64,
    /// per feature mode (1..D): the top feature indices with their factor
    /// weights, descending
    pub top_features: Vec<Vec<(usize, f32)>>,
}

/// Extract the top-`n` phenotypes with `per_mode` features each.
pub fn extract(factors: &FactorSet, n: usize, per_mode: usize) -> Vec<Phenotype> {
    let lambda = factors.lambda_weights();
    factors
        .top_components(n)
        .into_iter()
        .map(|r| {
            let top_features = factors.mats[1..]
                .iter()
                .map(|m| top_rows_of_column(m, r, per_mode))
                .collect();
            Phenotype { component: r, weight: lambda[r], top_features }
        })
        .collect()
}

fn top_rows_of_column(m: &Mat, col: usize, k: usize) -> Vec<(usize, f32)> {
    let mut rows: Vec<(usize, f32)> = (0..m.rows).map(|i| (i, m.at(i, col))).collect();
    rows.sort_by(|a, b| crate::util::order::nan_last_desc_abs_f32(&a.1, &b.1));
    rows.truncate(k);
    rows
}

/// Assign each patient to the top phenotype with the largest coordinate in
/// its representation vector (paper Table III grouping rule).
pub fn assign_subgroups(patient_factor: &Mat, top: &[usize]) -> Vec<usize> {
    (0..patient_factor.rows)
        .map(|i| {
            let row = patient_factor.row(i);
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (slot, &r) in top.iter().enumerate() {
                if row[r] > best_v {
                    best_v = row[r];
                    best = slot;
                }
            }
            best
        })
        .collect()
}

/// Support-recovery score vs planted truth: for each extracted phenotype,
/// the best Jaccard overlap between its top features and any planted
/// component's support, averaged over feature modes. 1.0 = exact recovery.
/// Returns 0.0 when there is no oracle — datasets loaded from disk carry
/// an empty `truth` ([`crate::data::Dataset`]).
pub fn support_recovery(phenos: &[Phenotype], truth: &[Mat]) -> f64 {
    if phenos.is_empty() || truth.is_empty() {
        return 0.0;
    }
    let mut total = 0.0f64;
    let mut count = 0usize;
    for ph in phenos {
        for (fm, feats) in ph.top_features.iter().enumerate() {
            let mode = fm + 1;
            if mode >= truth.len() {
                continue;
            }
            let got: std::collections::HashSet<usize> = feats.iter().map(|&(i, _)| i).collect();
            let mut best = 0.0f64;
            for r in 0..truth[mode].cols {
                let planted: std::collections::HashSet<usize> = (0..truth[mode].rows)
                    .filter(|&i| truth[mode].at(i, r) != 0.0)
                    .collect();
                if planted.is_empty() {
                    continue;
                }
                let inter = got.intersection(&planted).count() as f64;
                let union = got.union(&planted).count() as f64;
                best = best.max(inter / union);
            }
            total += best;
            count += 1;
        }
    }
    if count == 0 {
        return 0.0;
    }
    total / count as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::SynthConfig;

    fn planted_factorset() -> (FactorSet, Vec<Mat>) {
        let data = SynthConfig::tiny(31).generate();
        let truth = data.truth.clone();
        (FactorSet { mats: data.truth }, truth)
    }

    #[test]
    fn extract_orders_by_weight() {
        let (f, _) = planted_factorset();
        let ph = extract(&f, 3, 5);
        assert_eq!(ph.len(), 3);
        assert!(ph[0].weight >= ph[1].weight && ph[1].weight >= ph[2].weight);
        for p in &ph {
            assert_eq!(p.top_features.len(), 2); // two feature modes
            assert_eq!(p.top_features[0].len(), 5);
            // descending magnitude
            for w in p.top_features[0].windows(2) {
                assert!(w[0].1.abs() >= w[1].1.abs());
            }
        }
    }

    #[test]
    fn planted_factors_recover_their_own_supports() {
        let (f, truth) = planted_factorset();
        // take per_mode equal to the planted support size
        let supp = (0..truth[1].rows).filter(|&i| truth[1].at(i, 0) != 0.0).count();
        let ph = extract(&f, 3, supp);
        let score = support_recovery(&ph, &truth);
        assert!(score > 0.99, "self-recovery {score}");
    }

    #[test]
    fn random_factors_recover_poorly() {
        let (_, truth) = planted_factorset();
        let mut rng = crate::util::rng::Rng::new(5);
        let rand = FactorSet {
            mats: truth.iter().map(|m| Mat::rand_normal(m.rows, m.cols, 1.0, &mut rng)).collect(),
        };
        let supp = (0..truth[1].rows).filter(|&i| truth[1].at(i, 0) != 0.0).count();
        let ph = extract(&rand, 3, supp);
        let score = support_recovery(&ph, &truth);
        assert!(score < 0.6, "random factors scored {score}");
    }

    #[test]
    fn nan_poisoned_factor_column_does_not_panic_top_rows() {
        // regression: the magnitude sort used partial_cmp().unwrap(),
        // which panics on NaN; NaN weights must now sort last so a
        // diverged factor still yields the finite top features
        let mut m = Mat::zeros(5, 1);
        *m.at_mut(0, 0) = 0.5;
        *m.at_mut(1, 0) = f32::NAN;
        *m.at_mut(2, 0) = -3.0;
        *m.at_mut(3, 0) = 1.0;
        *m.at_mut(4, 0) = -f32::NAN;
        let top = top_rows_of_column(&m, 0, 3);
        let ids: Vec<usize> = top.iter().map(|&(i, _)| i).collect();
        assert_eq!(ids, vec![2, 3, 0], "finite rows ordered by |weight|, NaNs excluded");
    }

    #[test]
    fn subgroup_assignment_follows_argmax() {
        let mut a = Mat::zeros(4, 3);
        *a.at_mut(0, 0) = 1.0;
        *a.at_mut(1, 2) = 1.0;
        *a.at_mut(2, 1) = 1.0;
        *a.at_mut(3, 2) = 0.5;
        *a.at_mut(3, 0) = 0.4;
        // top components: [2, 0] -> slots {0: comp2, 1: comp0}
        let groups = assign_subgroups(&a, &[2, 0]);
        // row2 is zero on both tracked comps -> first slot wins (strict >)
        assert_eq!(groups, vec![1, 0, 0, 0]);
    }
}
