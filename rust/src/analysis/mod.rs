//! Post-hoc analysis: t-SNE embedding, silhouette scores, and phenotype
//! extraction — the machinery behind the paper's case study (Fig. 7,
//! Table III, Table IV).

pub mod phenotype;
pub mod tsne;

use crate::util::mat::Mat;

/// Mean silhouette coefficient of a labelled point set (O(N²)).
///
/// The numeric stand-in for Table III's visual "well-clustered subgroups":
/// higher = tighter, better-separated clusters.
pub fn silhouette(x: &Mat, labels: &[usize]) -> f64 {
    let n = x.rows;
    assert_eq!(labels.len(), n);
    let k = labels.iter().copied().max().map(|m| m + 1).unwrap_or(0);
    if k < 2 || n < 3 {
        return 0.0;
    }
    let counts = {
        let mut c = vec![0usize; k];
        for &l in labels {
            c[l] += 1;
        }
        c
    };
    let mut total = 0.0f64;
    let mut scored = 0usize;
    let mut mean_dist = vec![0.0f64; k];
    for i in 0..n {
        if counts[labels[i]] < 2 {
            continue;
        }
        mean_dist.iter_mut().for_each(|d| *d = 0.0);
        for j in 0..n {
            if i == j {
                continue;
            }
            let mut s = 0.0f64;
            for (a, b) in x.row(i).iter().zip(x.row(j).iter()) {
                let d = (a - b) as f64;
                s += d * d;
            }
            mean_dist[labels[j]] += s.sqrt();
        }
        let own = labels[i];
        let a = mean_dist[own] / (counts[own] - 1) as f64;
        let b = (0..k)
            .filter(|&c| c != own && counts[c] > 0)
            .map(|c| mean_dist[c] / counts[c] as f64)
            .fold(f64::INFINITY, f64::min);
        if b.is_finite() {
            total += (b - a) / a.max(b).max(1e-12);
            scored += 1;
        }
    }
    if scored == 0 {
        0.0
    } else {
        total / scored as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silhouette_separated_vs_mixed() {
        // two tight, distant clusters -> near 1
        let mut x = Mat::zeros(8, 2);
        let mut labels = Vec::new();
        for i in 0..4 {
            *x.at_mut(i, 0) = 0.0 + 0.01 * i as f32;
            labels.push(0);
        }
        for i in 4..8 {
            *x.at_mut(i, 0) = 10.0 + 0.01 * i as f32;
            labels.push(1);
        }
        assert!(silhouette(&x, &labels) > 0.95);
        // random labels on the same points -> poor
        let bad = vec![0, 1, 0, 1, 0, 1, 0, 1];
        assert!(silhouette(&x, &bad) < 0.1);
    }

    #[test]
    fn silhouette_degenerate() {
        assert_eq!(silhouette(&Mat::zeros(5, 2), &[0, 0, 0, 0, 0]), 0.0);
        assert_eq!(silhouette(&Mat::zeros(2, 2), &[0, 1]), 0.0);
    }
}
