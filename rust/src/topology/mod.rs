//! Decentralized communication topologies (paper §III-A, Fig. 2).
//!
//! An undirected graph over K clients plus the symmetric doubly-stochastic
//! connectivity matrix `W` built with Metropolis–Hastings weights:
//! `w_kj = 1/(1 + max(deg_k, deg_j))` on edges, `w_kk = 1 - Σ_j w_kj`.

use crate::util::rng::Rng;

/// Supported topologies (ring and star are the paper's; the rest support
/// extension experiments).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    Ring,
    Star,
    Complete,
    Chain,
    /// 2-D torus (K must be a perfect square)
    Torus,
}

impl Topology {
    pub fn name(self) -> &'static str {
        match self {
            Topology::Ring => "ring",
            Topology::Star => "star",
            Topology::Complete => "complete",
            Topology::Chain => "chain",
            Topology::Torus => "torus",
        }
    }

    /// Look up a topology by CLI name (thin wrapper over
    /// [`crate::registry::topologies`]).
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        crate::registry::topologies().resolve(s)
    }
}

/// Undirected communication graph with consensus weights.
#[derive(Debug, Clone)]
pub struct Graph {
    pub n: usize,
    pub topology: Topology,
    /// adjacency lists (sorted, no self-loops)
    pub neighbors: Vec<Vec<usize>>,
    /// dense K x K Metropolis weight matrix
    pub weights: Vec<Vec<f64>>,
}

impl Graph {
    pub fn build(topology: Topology, n: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(n >= 1, "need at least one client");
        let mut neighbors: Vec<Vec<usize>> = vec![Vec::new(); n];
        let connect = |a: usize, b: usize, nb: &mut Vec<Vec<usize>>| {
            if a != b && !nb[a].contains(&b) {
                nb[a].push(b);
                nb[b].push(a);
            }
        };
        match topology {
            Topology::Ring => {
                for k in 0..n {
                    connect(k, (k + 1) % n, &mut neighbors);
                }
            }
            Topology::Star => {
                for k in 1..n {
                    connect(0, k, &mut neighbors);
                }
            }
            Topology::Complete => {
                for a in 0..n {
                    for b in (a + 1)..n {
                        connect(a, b, &mut neighbors);
                    }
                }
            }
            Topology::Chain => {
                for k in 0..n.saturating_sub(1) {
                    connect(k, k + 1, &mut neighbors);
                }
            }
            Topology::Torus => {
                let side = (n as f64).sqrt().round() as usize;
                anyhow::ensure!(side * side == n, "torus needs a square client count, got {n}");
                for r in 0..side {
                    for c in 0..side {
                        let id = r * side + c;
                        connect(id, r * side + (c + 1) % side, &mut neighbors);
                        connect(id, ((r + 1) % side) * side + c, &mut neighbors);
                    }
                }
            }
        }
        for adj in &mut neighbors {
            adj.sort_unstable();
        }
        let weights = metropolis_weights(&neighbors);
        Ok(Graph { n, topology, neighbors, weights })
    }

    pub fn degree(&self, k: usize) -> usize {
        self.neighbors[k].len()
    }

    /// Total directed communication links (each undirected edge counts
    /// twice — every client uplinks to each neighbor). This is the factor
    /// behind the paper's ring-vs-star byte comparison (Fig. 4).
    pub fn total_links(&self) -> usize {
        self.neighbors.iter().map(Vec::len).sum()
    }

    pub fn w(&self, k: usize, j: usize) -> f64 {
        self.weights[k][j]
    }

    /// Spectral gap `1 - λ₂(W)` estimated by power iteration on the
    /// deflated operator (connectivity/mixing speed diagnostic).
    pub fn spectral_gap(&self) -> f64 {
        let n = self.n;
        if n == 1 {
            return 1.0;
        }
        let mut rng = Rng::new(0xBEEF);
        // start orthogonal to the all-ones top eigenvector
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut tmp = vec![0.0f64; n];
        let mut lambda2 = 0.0;
        for _ in 0..300 {
            let mean = v.iter().sum::<f64>() / n as f64;
            v.iter_mut().for_each(|x| *x -= mean);
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-30);
            v.iter_mut().for_each(|x| *x /= norm);
            for k in 0..n {
                tmp[k] = (0..n).map(|j| self.weights[k][j] * v[j]).sum();
            }
            lambda2 = v.iter().zip(tmp.iter()).map(|(a, b)| a * b).sum::<f64>();
            std::mem::swap(&mut v, &mut tmp);
        }
        1.0 - lambda2.abs()
    }
}

/// Metropolis–Hastings symmetric doubly-stochastic weights.
pub fn metropolis_weights(neighbors: &[Vec<usize>]) -> Vec<Vec<f64>> {
    let n = neighbors.len();
    let deg: Vec<usize> = neighbors.iter().map(Vec::len).collect();
    let mut w = vec![vec![0.0f64; n]; n];
    for k in 0..n {
        for &j in &neighbors[k] {
            w[k][j] = 1.0 / (1.0 + deg[k].max(deg[j]) as f64);
        }
        w[k][k] = 1.0 - w[k].iter().sum::<f64>();
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_doubly_stochastic(g: &Graph) {
        for k in 0..g.n {
            let row: f64 = g.weights[k].iter().sum();
            assert!((row - 1.0).abs() < 1e-12, "row {k} sums to {row}");
            let col: f64 = (0..g.n).map(|j| g.weights[j][k]).sum();
            assert!((col - 1.0).abs() < 1e-12, "col {k} sums to {col}");
            for j in 0..g.n {
                assert!((g.weights[k][j] - g.weights[j][k]).abs() < 1e-15, "not symmetric");
                assert!(g.weights[k][j] >= 0.0);
            }
        }
    }

    #[test]
    fn ring_structure_and_weights() {
        let g = Graph::build(Topology::Ring, 8).unwrap();
        for k in 0..8 {
            assert_eq!(g.degree(k), 2);
            assert!(g.neighbors[k].contains(&((k + 1) % 8)));
            assert!(g.neighbors[k].contains(&((k + 7) % 8)));
        }
        assert_eq!(g.total_links(), 16);
        check_doubly_stochastic(&g);
    }

    #[test]
    fn star_structure() {
        let g = Graph::build(Topology::Star, 8).unwrap();
        assert_eq!(g.degree(0), 7);
        for k in 1..8 {
            assert_eq!(g.degree(k), 1);
            assert_eq!(g.neighbors[k], vec![0]);
        }
        // star has fewer total links than ring at same K (paper Fig. 4)
        let ring = Graph::build(Topology::Ring, 8).unwrap();
        assert!(g.total_links() < ring.total_links());
        check_doubly_stochastic(&g);
    }

    #[test]
    fn complete_chain_torus() {
        let g = Graph::build(Topology::Complete, 6).unwrap();
        assert!(g.neighbors.iter().all(|a| a.len() == 5));
        check_doubly_stochastic(&g);

        let c = Graph::build(Topology::Chain, 5).unwrap();
        assert_eq!(c.degree(0), 1);
        assert_eq!(c.degree(2), 2);
        check_doubly_stochastic(&c);

        let t = Graph::build(Topology::Torus, 16).unwrap();
        assert!(t.neighbors.iter().all(|a| a.len() == 4));
        check_doubly_stochastic(&t);
        assert!(Graph::build(Topology::Torus, 12).is_err());
    }

    #[test]
    fn single_client_degenerates() {
        let g = Graph::build(Topology::Ring, 1).unwrap();
        assert_eq!(g.degree(0), 0);
        assert_eq!(g.weights[0][0], 1.0);
        assert_eq!(g.total_links(), 0);
    }

    #[test]
    fn spectral_gap_ordering() {
        // complete mixes faster than ring, ring faster than chain
        let complete = Graph::build(Topology::Complete, 16).unwrap().spectral_gap();
        let ring = Graph::build(Topology::Ring, 16).unwrap().spectral_gap();
        let chain = Graph::build(Topology::Chain, 16).unwrap().spectral_gap();
        assert!(complete > ring, "complete {complete} vs ring {ring}");
        assert!(ring > chain, "ring {ring} vs chain {chain}");
        assert!(chain > 0.0);
    }

    #[test]
    fn names_roundtrip() {
        for t in [Topology::Ring, Topology::Star, Topology::Complete, Topology::Chain, Topology::Torus] {
            assert_eq!(Topology::from_name(t.name()).unwrap(), t);
        }
        assert!(Topology::from_name("hypercube").is_err());
    }
}
