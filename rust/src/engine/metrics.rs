//! Run records: the per-epoch metric curves every figure is drawn from.

use crate::gossip::CommLedger;
use crate::net::sim::NetStats;
use crate::util::csv::CsvWriter;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One point on a training curve (paper figures plot `loss` against
/// `time_s` and against `bytes`).
#[derive(Debug, Clone)]
pub struct MetricPoint {
    pub epoch: usize,
    pub iter: usize,
    /// wall-clock seconds since training start
    pub time_s: f64,
    /// estimated global GCP loss (stratified estimator, fixed sample)
    pub loss: f64,
    /// cumulative uplink bytes across all clients
    pub bytes: u64,
    /// FMS vs the reference factors, when tracked (Fig. 7)
    pub fms: Option<f64>,
}

/// Complete record of one training run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub algo: String,
    pub dataset: String,
    pub loss: String,
    pub topology: String,
    pub k: usize,
    pub tau: usize,
    pub points: Vec<MetricPoint>,
    pub total: CommLedger,
    /// delivery/staleness counters. Every decentralized path counts
    /// `delivered` (the lock-step in-process engines deliver everything),
    /// but `dropped`/`stale`/`offline_rounds` can only become nonzero when
    /// a run is routed through a faulty `NetworkModel`.
    pub net: NetStats,
    pub wall_s: f64,
}

impl RunRecord {
    /// Final loss (last recorded point).
    pub fn final_loss(&self) -> f64 {
        self.points.last().map(|p| p.loss).unwrap_or(f64::NAN)
    }

    /// First point at which the loss dips below `target`, if any.
    pub fn first_reaching(&self, target: f64) -> Option<&MetricPoint> {
        self.points.iter().find(|p| p.loss <= target)
    }

    /// Minimum loss over the run.
    pub fn best_loss(&self) -> f64 {
        self.points.iter().map(|p| p.loss).fold(f64::INFINITY, f64::min)
    }

    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["algo", "dataset", "loss_kind", "topology", "k", "tau", "epoch", "iter", "time_s", "loss", "bytes", "fms"],
        )?;
        for p in &self.points {
            w.row(&[
                self.algo.clone(),
                self.dataset.clone(),
                self.loss.clone(),
                self.topology.clone(),
                self.k.to_string(),
                self.tau.to_string(),
                p.epoch.to_string(),
                p.iter.to_string(),
                format!("{:.4}", p.time_s),
                format!("{:.6e}", p.loss),
                p.bytes.to_string(),
                p.fms.map(|f| format!("{f:.4}")).unwrap_or_default(),
            ])?;
        }
        w.flush()
    }

    /// Parse the [`RunRecord::to_json`] layout back. Used by the sweep
    /// engine's resumability: a finished run's record file is reloaded
    /// instead of re-running the experiment, so the parse must be exact
    /// for every field `to_json` writes (loss values ride f64 shortest
    /// round-trip decimals; byte counts stay below 2^53). JSON has no
    /// NaN, so a diverged run's loss serializes as `null` — parse it
    /// back to NaN rather than rejecting the record (a diverged cell is
    /// *finished*; resume must not re-run it forever).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let nan_or_f64 = |pj: &Json, key: &str| -> anyhow::Result<f64> {
            match pj.get(key) {
                Some(Json::Null) => Ok(f64::NAN),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("invalid point '{key}'")),
                None => anyhow::bail!("missing point '{key}'"),
            }
        };
        let mut points = Vec::new();
        for pj in j.req_array("points")? {
            points.push(MetricPoint {
                epoch: pj.req_usize("epoch")?,
                iter: pj.req_usize("iter")?,
                time_s: pj.req_f64("time_s")?,
                loss: nan_or_f64(pj, "loss")?,
                bytes: pj.req_f64("bytes")? as u64,
                // `fms: None` omits the key; `Some(NaN)` writes null —
                // keep the distinction so re-serialization is identical
                fms: match pj.get("fms") {
                    None => None,
                    Some(Json::Null) => Some(f64::NAN),
                    Some(v) => Some(
                        v.as_f64()
                            .ok_or_else(|| anyhow::anyhow!("invalid point 'fms'"))?,
                    ),
                },
            });
        }
        let total = CommLedger {
            bytes: j.req_f64("total_bytes")? as u64,
            messages: j.req_f64("messages")? as u64,
            triggered: j.req_f64("triggered")? as u64,
            suppressed: j.req_f64("suppressed")? as u64,
        };
        let net = NetStats {
            delivered: j.req_f64("delivered")? as u64,
            dropped: j.req_f64("dropped")? as u64,
            stale: j.req_f64("stale")? as u64,
            offline_rounds: j.req_f64("offline_rounds")? as u64,
            // absent in records written before the adversary plane existed
            adversarial: j.get("adversarial").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        };
        Ok(RunRecord {
            algo: j.req_str("algo")?.to_string(),
            dataset: j.req_str("dataset")?.to_string(),
            loss: j.req_str("loss")?.to_string(),
            topology: j.req_str("topology")?.to_string(),
            k: j.req_usize("k")?,
            tau: j.req_usize("tau")?,
            points,
            total,
            net,
            wall_s: j.req_f64("wall_s")?,
        })
    }

    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("epoch".into(), Json::Num(p.epoch as f64));
                m.insert("iter".into(), Json::Num(p.iter as f64));
                m.insert("time_s".into(), Json::Num(p.time_s));
                m.insert("loss".into(), Json::Num(p.loss));
                m.insert("bytes".into(), Json::Num(p.bytes as f64));
                if let Some(f) = p.fms {
                    m.insert("fms".into(), Json::Num(f));
                }
                Json::Obj(m)
            })
            .collect();
        Json::obj(vec![
            ("algo", Json::Str(self.algo.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("loss", Json::Str(self.loss.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("k", Json::Num(self.k as f64)),
            ("tau", Json::Num(self.tau as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("total_bytes", Json::Num(self.total.bytes as f64)),
            ("messages", Json::Num(self.total.messages as f64)),
            ("triggered", Json::Num(self.total.triggered as f64)),
            ("suppressed", Json::Num(self.total.suppressed as f64)),
            ("delivered", Json::Num(self.net.delivered as f64)),
            ("dropped", Json::Num(self.net.dropped as f64)),
            ("stale", Json::Num(self.net.stale as f64)),
            ("offline_rounds", Json::Num(self.net.offline_rounds as f64)),
            ("adversarial", Json::Num(self.net.adversarial as f64)),
            ("points", Json::Arr(points)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> RunRecord {
        RunRecord {
            algo: "cidertf".into(),
            dataset: "tiny".into(),
            loss: "logit".into(),
            topology: "ring".into(),
            k: 4,
            tau: 4,
            points: vec![
                MetricPoint { epoch: 0, iter: 99, time_s: 0.5, loss: 10.0, bytes: 100, fms: None },
                MetricPoint { epoch: 1, iter: 199, time_s: 1.0, loss: 4.0, bytes: 200, fms: Some(0.7) },
                MetricPoint { epoch: 2, iter: 299, time_s: 1.5, loss: 5.0, bytes: 300, fms: Some(0.8) },
            ],
            total: Default::default(),
            net: Default::default(),
            wall_s: 1.5,
        }
    }

    #[test]
    fn summaries() {
        let r = rec();
        assert_eq!(r.final_loss(), 5.0);
        assert_eq!(r.best_loss(), 4.0);
        assert_eq!(r.first_reaching(4.5).unwrap().epoch, 1);
        assert!(r.first_reaching(1.0).is_none());
    }

    #[test]
    fn csv_and_json_roundtrip() {
        let r = rec();
        let dir = std::env::temp_dir().join("cidertf_metrics_test");
        let p = dir.join("run.csv");
        r.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 4);
        assert!(text.lines().nth(2).unwrap().contains("0.7"));
        let j = r.to_json();
        assert_eq!(j.req_str("algo").unwrap(), "cidertf");
        assert_eq!(j.req_array("points").unwrap().len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_json_parse_back_is_exact() {
        let mut r = rec();
        r.total.bytes = 123456;
        r.total.messages = 78;
        r.total.triggered = 60;
        r.total.suppressed = 18;
        r.net.delivered = 99;
        r.net.dropped = 3;
        r.points[1].loss = 0.1234567891234567; // exercise shortest-round-trip
        // a diverged run: NaN serializes as null and must parse back
        // (resume depends on it), re-serializing identically
        r.points[2].loss = f64::NAN;
        let text = r.to_json().to_string();
        let back = RunRecord::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.algo, r.algo);
        assert_eq!(back.k, r.k);
        assert_eq!(back.tau, r.tau);
        assert_eq!(back.total.bytes, r.total.bytes);
        assert_eq!(back.total.suppressed, r.total.suppressed);
        assert_eq!(back.net.delivered, r.net.delivered);
        assert_eq!(back.points.len(), r.points.len());
        assert!(back.points[2].loss.is_nan(), "null loss must parse to NaN");
        for (a, b) in back.points.iter().zip(r.points.iter()).take(2) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.fms, b.fms);
        }
        // serializing the parsed record again is byte-identical
        assert_eq!(back.to_json().to_string(), text);
    }
}
