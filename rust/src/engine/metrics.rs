//! Run records: the per-epoch metric curves every figure is drawn from.

use crate::gossip::CommLedger;
use crate::net::sim::NetStats;
use crate::util::csv::CsvWriter;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One point on a training curve (paper figures plot `loss` against
/// `time_s` and against `bytes`).
#[derive(Debug, Clone)]
pub struct MetricPoint {
    pub epoch: usize,
    pub iter: usize,
    /// wall-clock seconds since training start
    pub time_s: f64,
    /// estimated global GCP loss (stratified estimator, fixed sample)
    pub loss: f64,
    /// cumulative uplink bytes across all clients
    pub bytes: u64,
    /// FMS vs the reference factors, when tracked (Fig. 7)
    pub fms: Option<f64>,
}

/// Complete record of one training run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub algo: String,
    pub dataset: String,
    pub loss: String,
    pub topology: String,
    pub k: usize,
    pub tau: usize,
    pub points: Vec<MetricPoint>,
    pub total: CommLedger,
    /// delivery/staleness counters. Every decentralized path counts
    /// `delivered` (the lock-step in-process engines deliver everything),
    /// but `dropped`/`stale`/`offline_rounds` can only become nonzero when
    /// a run is routed through a faulty `NetworkModel`.
    pub net: NetStats,
    pub wall_s: f64,
}

impl RunRecord {
    /// Final loss (last recorded point).
    pub fn final_loss(&self) -> f64 {
        self.points.last().map(|p| p.loss).unwrap_or(f64::NAN)
    }

    /// First point at which the loss dips below `target`, if any.
    pub fn first_reaching(&self, target: f64) -> Option<&MetricPoint> {
        self.points.iter().find(|p| p.loss <= target)
    }

    /// Minimum loss over the run.
    pub fn best_loss(&self) -> f64 {
        self.points.iter().map(|p| p.loss).fold(f64::INFINITY, f64::min)
    }

    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["algo", "dataset", "loss_kind", "topology", "k", "tau", "epoch", "iter", "time_s", "loss", "bytes", "fms"],
        )?;
        for p in &self.points {
            w.row(&[
                self.algo.clone(),
                self.dataset.clone(),
                self.loss.clone(),
                self.topology.clone(),
                self.k.to_string(),
                self.tau.to_string(),
                p.epoch.to_string(),
                p.iter.to_string(),
                format!("{:.4}", p.time_s),
                format!("{:.6e}", p.loss),
                p.bytes.to_string(),
                p.fms.map(|f| format!("{f:.4}")).unwrap_or_default(),
            ])?;
        }
        w.flush()
    }

    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("epoch".into(), Json::Num(p.epoch as f64));
                m.insert("iter".into(), Json::Num(p.iter as f64));
                m.insert("time_s".into(), Json::Num(p.time_s));
                m.insert("loss".into(), Json::Num(p.loss));
                m.insert("bytes".into(), Json::Num(p.bytes as f64));
                if let Some(f) = p.fms {
                    m.insert("fms".into(), Json::Num(f));
                }
                Json::Obj(m)
            })
            .collect();
        Json::obj(vec![
            ("algo", Json::Str(self.algo.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("loss", Json::Str(self.loss.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("k", Json::Num(self.k as f64)),
            ("tau", Json::Num(self.tau as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("total_bytes", Json::Num(self.total.bytes as f64)),
            ("messages", Json::Num(self.total.messages as f64)),
            ("triggered", Json::Num(self.total.triggered as f64)),
            ("suppressed", Json::Num(self.total.suppressed as f64)),
            ("delivered", Json::Num(self.net.delivered as f64)),
            ("dropped", Json::Num(self.net.dropped as f64)),
            ("stale", Json::Num(self.net.stale as f64)),
            ("offline_rounds", Json::Num(self.net.offline_rounds as f64)),
            ("points", Json::Arr(points)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> RunRecord {
        RunRecord {
            algo: "cidertf".into(),
            dataset: "tiny".into(),
            loss: "logit".into(),
            topology: "ring".into(),
            k: 4,
            tau: 4,
            points: vec![
                MetricPoint { epoch: 0, iter: 99, time_s: 0.5, loss: 10.0, bytes: 100, fms: None },
                MetricPoint { epoch: 1, iter: 199, time_s: 1.0, loss: 4.0, bytes: 200, fms: Some(0.7) },
                MetricPoint { epoch: 2, iter: 299, time_s: 1.5, loss: 5.0, bytes: 300, fms: Some(0.8) },
            ],
            total: Default::default(),
            net: Default::default(),
            wall_s: 1.5,
        }
    }

    #[test]
    fn summaries() {
        let r = rec();
        assert_eq!(r.final_loss(), 5.0);
        assert_eq!(r.best_loss(), 4.0);
        assert_eq!(r.first_reaching(4.5).unwrap().epoch, 1);
        assert!(r.first_reaching(1.0).is_none());
    }

    #[test]
    fn csv_and_json_roundtrip() {
        let r = rec();
        let dir = std::env::temp_dir().join("cidertf_metrics_test");
        let p = dir.join("run.csv");
        r.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 4);
        assert!(text.lines().nth(2).unwrap().contains("0.7"));
        let j = r.to_json();
        assert_eq!(j.req_str("algo").unwrap(), "cidertf");
        assert_eq!(j.req_array("points").unwrap().len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
