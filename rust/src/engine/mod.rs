//! The decentralized training engine — paper Algorithm 1 in full, plus
//! every baseline as a configuration (see `presets.rs`).
//!
//! The engine runs synchronous gossip rounds over a simulated in-process
//! network. One `ClientState` per institution holds the local shard,
//! factors, momentum, and peer estimates; the trainer drives the
//! four-level communication-reduction stack:
//!
//! 1. **element** — the compressor applied to factor deltas,
//! 2. **block** — the shared randomized mode sequence `d_ξ[t]`,
//! 3. **round** — communication only when `t mod τ == 0`,
//! 4. **event** — the `‖A[t+½] − Â‖² ≥ λ[t]γ²` trigger.
//!
//! Gradient and loss evaluation execute through a [`ComputeBackend`] —
//! the PJRT artifacts in production, the native mirror in tests.

pub mod checkpoint;
pub mod client;
pub mod metrics;
pub mod presets;
pub mod session;
pub mod spec;

use crate::adversary::AdversarySchedule;
use crate::compress::{Compressor, Payload};
use crate::data::Dataset;
use crate::factor::{fms::fms, FactorSet};
use crate::gossip::{Aggregator, Message};
use crate::losses::Loss;
use crate::net::sim::NetStats;
use crate::runtime::ComputeBackend;
use crate::sched::TriggerSchedule;
use crate::tensor::partition::{partition_shared_with, Partitioner};
use crate::topology::{Graph, Topology};
use crate::util::mat::Mat;
use client::ClientState;
use metrics::{MetricPoint, RunRecord};

/// Algorithm configuration (the Table II feature matrix).
#[derive(Debug, Clone, PartialEq)]
pub struct AlgoConfig {
    pub name: String,
    pub compressor: Compressor,
    /// sample one mode per round (vs updating all modes)
    pub block_random: bool,
    /// local rounds between communications (τ)
    pub tau: usize,
    pub event_triggered: bool,
    /// Nesterov momentum β (CiderTF_m)
    pub momentum: Option<f64>,
    /// error-feedback compressed updates (Centralized CiderTF)
    pub error_feedback: bool,
    /// consensus step size ϱ
    pub rho: f64,
}

/// Full training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub dataset: String,
    pub loss: Loss,
    pub rank: usize,
    /// fiber sample size |S|
    pub fiber_samples: usize,
    /// number of clients K
    pub k: usize,
    pub topology: Topology,
    /// learning rate γ (constant; paper grid-searches powers of two)
    pub gamma: f64,
    /// iterations per epoch (paper: 500)
    pub iters_per_epoch: usize,
    pub epochs: usize,
    pub seed: u64,
    /// stratified loss-estimator batch size (must match an eval artifact)
    pub eval_batch: usize,
    pub init_scale: f32,
    /// scale on the event-trigger threshold λ₀ = scale/γ (paper: 1.0)
    pub trigger_lambda0_scale: f64,
    /// λ[t] growth factor α (paper grid-searches in [1, 2])
    pub trigger_alpha: f64,
    /// nominal per-iteration compute cost in *simulated* seconds, scaled
    /// by `NetworkModel::compute_multiplier` in the net drivers (the
    /// sequential engine keeps wall-clock time and ignores this)
    pub sim_iter_s: f64,
    /// compute threads the backend may use per gradient call
    /// (`ComputeBackend::set_threads`). Default 1 — fully deterministic.
    /// >1 tiles the native row-panel kernel across a scoped thread pool;
    /// gradients stay bit-identical (lane-deterministic kernels), and all
    /// execution paths (`train` / `train_parallel` / `train_sim`) receive
    /// the same value so they remain bit-identical to each other.
    pub compute_threads: usize,
    /// how patient rows are split across institutions (even / skewed /
    /// site-vocabulary; non-even modes draw from `seed`)
    pub partitioner: Partitioner,
    /// consensus combiner for peer estimates (mean / trimmed mean /
    /// coordinate-wise median)
    pub aggregator: Aggregator,
    /// Byzantine-client schedule; `None` = every client honest
    pub adversary: Option<AdversarySchedule>,
    pub algo: AlgoConfig,
}

impl TrainConfig {
    /// The event-trigger threshold schedule for this config.
    pub fn trigger_schedule(&self) -> TriggerSchedule {
        let mut t = TriggerSchedule::paper_default(self.gamma, self.iters_per_epoch);
        t.lambda0 *= self.trigger_lambda0_scale;
        t.alpha = self.trigger_alpha;
        t
    }

    /// Sensible defaults for the scaled datasets (overridden per figure).
    pub fn new(dataset: &str, loss: Loss, algo: AlgoConfig) -> Self {
        TrainConfig {
            dataset: dataset.to_string(),
            loss,
            rank: 16,
            fiber_samples: 256,
            k: 8,
            topology: Topology::Ring,
            gamma: 0.25,
            iters_per_epoch: 500,
            epochs: 10,
            seed: 0xC1DE,
            eval_batch: 8192,
            init_scale: 0.3,
            trigger_lambda0_scale: 1.0,
            trigger_alpha: 1.3,
            sim_iter_s: 1.0,
            compute_threads: 1,
            partitioner: Partitioner::Even,
            aggregator: Aggregator::Mean,
            adversary: None,
            algo,
        }
    }
}

/// Outcome of a run: the metric record plus the assembled global factors.
pub struct TrainOutcome {
    pub record: RunRecord,
    pub factors: FactorSet,
}

/// Run one training configuration to completion.
///
/// **Deprecated shim.** This is the legacy entry point, kept so existing
/// callers and tests compile unchanged; it now delegates to the unified
/// session loop in [`session`] with the ideal network and a wall clock,
/// performing exactly the float operations of the original engine loop
/// (bit-identical factors, asserted in `tests/network_sim.rs`). New code
/// should build an [`spec::ExperimentSpec`] and run a
/// [`session::Session`] — that path adds observers, eval cadence,
/// stopping rules, and checkpoint/resume.
pub fn train(
    cfg: &TrainConfig,
    data: &Dataset,
    backend: &mut dyn ComputeBackend,
    fms_reference: Option<&FactorSet>,
) -> anyhow::Result<TrainOutcome> {
    let mut net = crate::net::sim::IdealNetwork;
    session::run_loop(
        cfg,
        data,
        backend,
        &mut net,
        true,
        fms_reference,
        &mut session::Hooks::none(),
    )
}

/// Shard the tensor into `Arc<ShardData>` data planes (tensor + fiber
/// indices built once, immutably shared) and build one [`ClientState`]
/// view per institution, wiring gossip estimates when the run is
/// decentralized. Shared by every execution path so they all start from
/// bit-identical state without ever copying tensor data.
pub(crate) fn build_clients(
    cfg: &TrainConfig,
    data: &Dataset,
    graph: &Graph,
) -> Vec<ClientState> {
    let shards = partition_shared_with(&data.tensor, cfg.k, &cfg.partitioner, cfg.seed);
    let mut clients: Vec<ClientState> = shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            ClientState::new(
                id,
                shard,
                cfg.rank,
                cfg.init_scale,
                cfg.seed,
                cfg.fiber_samples,
                cfg.eval_batch,
                cfg.algo.momentum.is_some(),
                cfg.algo.error_feedback,
            )
        })
        .collect();
    if cfg.k > 1 {
        for c in clients.iter_mut() {
            let nbrs = graph.neighbors[c.id].clone();
            c.init_estimates(&nbrs);
        }
    }
    clients
}

/// Merge per-client ledgers/stats into the final [`RunRecord`]. Shared by
/// every execution path so the comm accounting stays comparable.
pub(crate) fn finalize_record(
    cfg: &TrainConfig,
    graph: &Graph,
    clients: &[ClientState],
    points: Vec<MetricPoint>,
    wall_s: f64,
) -> RunRecord {
    let mut total = crate::gossip::CommLedger::default();
    let mut net = NetStats::default();
    for c in clients {
        total.merge(&c.ledger);
        net.merge(&c.net);
    }
    RunRecord {
        algo: cfg.algo.name.clone(),
        dataset: cfg.dataset.clone(),
        loss: cfg.loss.name().to_string(),
        topology: graph.topology.name().to_string(),
        k: cfg.k,
        tau: cfg.algo.tau,
        points,
        total,
        net,
        wall_s,
    }
}

/// Publish phase (Alg. 1 lines 10-14): event-trigger check, delta
/// compression, and uplink ledger accounting for every client. Returns
/// each client's broadcast payload (`None` = trigger suppressed, or the
/// client is offline under `online`). Shared by the sequential engine and
/// the network-simulator drivers; passing `online: None` reproduces the
/// ideal lock-step behaviour exactly.
pub(crate) fn publish_phase(
    clients: &mut [ClientState],
    graph: &Graph,
    cfg: &TrainConfig,
    trigger: &TriggerSchedule,
    t: usize,
    m: usize,
    online: Option<&[bool]>,
) -> Vec<Option<Payload>> {
    clients
        .iter_mut()
        .map(|c| {
            if let Some(mask) = online {
                if !mask[c.id] {
                    return None;
                }
            }
            publish_one(c, graph, cfg, trigger, t, m)
        })
        .collect()
}

/// One client's publish decision (Alg. 1 lines 10-14): event-trigger
/// check, delta compression, and per-neighbor uplink ledger accounting.
/// The single source of truth for publish semantics — every execution
/// path (sequential, thread-parallel, sync simulator, async gossip)
/// calls this.
pub(crate) fn publish_one(
    c: &mut ClientState,
    graph: &Graph,
    cfg: &TrainConfig,
    trigger: &TriggerSchedule,
    t: usize,
    m: usize,
) -> Option<Payload> {
    let est = c.estimates.as_ref().expect("estimates");
    let a = &c.factors.mats[m];
    let dist_sq = a.dist_sq(est.self_estimate(m));
    let fired = !cfg.algo.event_triggered || trigger.fires(dist_sq, t, cfg.gamma);
    if fired {
        let mut delta = a.clone();
        delta.sub_assign(est.self_estimate(m));
        let payload = cfg.algo.compressor.compress(&delta);
        let msg = Message { from: c.id, mode: m, round: t, payload };
        for _ in &graph.neighbors[c.id] {
            c.ledger.record(&msg, true);
        }
        let Message { payload, .. } = msg;
        Some(payload)
    } else {
        // nothing on the wire; receivers treat it as a zero delta
        c.ledger.suppressed += 1;
        None
    }
}

/// Consensus phase (Alg. 1 line 18) for every (online) client, combining
/// peer estimates through `aggregator` — the plain mean reproduces
/// `A^k += ϱ Σ_j w_kj (Â^j − Â^k)` on mode `m` bit-exactly.
pub(crate) fn consensus_phase(
    clients: &mut [ClientState],
    graph: &Graph,
    aggregator: &Aggregator,
    rho: f64,
    m: usize,
    online: Option<&[bool]>,
) {
    for (k, c) in clients.iter_mut().enumerate() {
        if let Some(mask) = online {
            if !mask[k] {
                continue;
            }
        }
        let ClientState { estimates, factors, .. } = c;
        let est = estimates.as_ref().expect("estimates");
        // the finiteness scan is debug-only: consensus may legitimately
        // propagate a NaN a diverged local step produced, but must never
        // manufacture one from all-finite inputs
        let inputs_finite = crate::util::invariant::enabled()
            && factors.mats[m].data.iter().all(|v| v.is_finite())
            && est
                .peers
                .iter()
                .all(|&p| est.estimate(p, m).data.iter().all(|v| v.is_finite()));
        aggregator.consensus_into(
            est,
            &mut factors.mats[m],
            m,
            &graph.neighbors[k],
            &graph.weights[k],
            rho,
        );
        crate::util::invariant::consensus_kept_finite(
            k,
            m,
            inputs_finite,
            &factors.mats[m].data,
        );
    }
}

/// Centralized CiderTF's error-feedback step: undo the raw update on mode
/// `m` and re-apply its EF-compressed version.
pub(crate) fn apply_error_feedback(c: &mut ClientState, m: usize, compressor: Compressor) {
    // local_step already applied `A -= update`; recover the raw update from
    // the EF residual trick: compress(update + residual) and fix A by the
    // difference between raw and decoded updates.
    // We reconstruct `update` as the delta since the last EF snapshot held
    // in the residual state; simpler and equivalent: track via shadow.
    let shadow = c
        .ef_shadow
        .get_or_insert_with(|| c.factors.mats.iter().map(|x| x.clone()).collect::<Vec<_>>());
    let mut update = shadow[m].clone();
    update.sub_assign(&c.factors.mats[m]); // update = A_old - A_new = γ·step
    let ef = c.ef[m].as_mut().expect("error feedback state");
    let payload = ef.compress(compressor, &update);
    let decoded = payload.decode(update.rows, update.cols);
    // A_new' = A_old - decoded
    let mut a_new = shadow[m].clone();
    a_new.sub_assign(&decoded);
    c.factors.mats[m] = a_new.clone();
    shadow[m] = a_new;
}

/// Scatter patient factors back to their global rows and average feature
/// factors. Works for any partitioner: each shard carries its own
/// `global_rows` map, so non-contiguous (skewed / site-vocab) shards land
/// in the right global slots.
pub fn assemble_global(clients: &[ClientState]) -> FactorSet {
    let d = clients[0].factors.order();
    let r = clients[0].factors.rank();
    let mut mats = Vec::with_capacity(d);
    // patient mode: every partition covers each global row exactly once
    let total_rows: usize = clients.iter().map(|c| c.factors.mats[0].rows).sum();
    let mut a0 = Mat::zeros(total_rows, r);
    for c in clients {
        let m = &c.factors.mats[0];
        for i in 0..m.rows {
            a0.row_mut(c.shard.global_rows[i] as usize).copy_from_slice(m.row(i));
        }
    }
    mats.push(a0);
    // feature modes: average across clients
    for m in 1..d {
        let mut avg = clients[0].factors.mats[m].clone();
        for c in &clients[1..] {
            avg.add_assign(&c.factors.mats[m]);
        }
        avg.scale(1.0 / clients.len() as f32);
        mats.push(avg);
    }
    FactorSet { mats }
}

/// Evaluate the global loss estimator across clients and append a metric
/// point stamped with `time_s` (wall seconds for the sequential engine,
/// virtual seconds for the simulators).
#[allow(clippy::too_many_arguments)]
pub(crate) fn record_point(
    clients: &mut [ClientState],
    cfg: &TrainConfig,
    backend: &mut dyn ComputeBackend,
    fms_reference: Option<&FactorSet>,
    epoch: usize,
    iter: usize,
    time_s: f64,
    points: &mut Vec<MetricPoint>,
) -> anyhow::Result<()> {
    let mut loss = 0.0;
    for c in clients.iter_mut() {
        loss += c.eval_loss(cfg.loss, backend)?;
    }
    let bytes: u64 = clients.iter().map(|c| c.ledger.bytes).sum();
    let fms_val = fms_reference.map(|r| fms(&assemble_global(clients), r));
    points.push(MetricPoint { epoch, iter, time_s, loss, bytes, fms: fms_val });
    Ok(())
}
