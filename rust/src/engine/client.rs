//! Per-client (institution) state and the local update step — the inner
//! loop of Alg. 1 as seen by one node.
//!
//! A client's shard (tensor + fiber indices) is an immutable
//! `Arc<ShardData>` built once by the partitioner and shared across
//! every execution path — constructing a client never copies tensor
//! data, and the thread-per-client driver's clients all read the same
//! allocations.

use std::sync::Arc;

use crate::compress::ErrorFeedback;
use crate::factor::FactorSet;
use crate::gossip::{CommLedger, EstimateState};
use crate::losses::Loss;
use crate::net::sim::NetStats;
use crate::runtime::ComputeBackend;
use crate::sched::FiberSampler;
use crate::tensor::partition::ShardData;
use crate::tensor::SparseTensor;
use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// Fixed stratified loss-estimation sample for one client: `B` nonzero
/// draws and `B` uniform zero cells, fixed at init so the loss curve is a
/// consistent estimator across epochs and algorithms.
#[derive(Debug, Clone)]
pub struct EvalSample {
    /// per-mode row indices of the nonzero batch, each `Vec<u32>` len B
    pub nnz_rows: Vec<Vec<u32>>,
    pub nnz_vals: Vec<f32>,
    /// per-mode row indices of the zero batch
    pub zero_rows: Vec<Vec<u32>>,
    /// weights turning batch sums into an unbiased total-loss estimate
    pub w_nnz: f64,
    pub w_zero: f64,
}

impl EvalSample {
    pub fn build(t: &SparseTensor, batch: usize, rng: &mut Rng) -> Self {
        let d = t.order();
        let nnz = t.nnz();
        let cells = t.n_cells();
        let cell_set = t.cell_set();

        let mut nnz_rows = vec![Vec::with_capacity(batch); d];
        let mut nnz_vals = Vec::with_capacity(batch);
        for _ in 0..batch {
            let e = rng.below(nnz.max(1));
            if nnz == 0 {
                for rows in nnz_rows.iter_mut() {
                    rows.push(0);
                }
                nnz_vals.push(0.0);
                continue;
            }
            let idx = t.entry(e);
            for (m, rows) in nnz_rows.iter_mut().enumerate() {
                rows.push(idx[m]);
            }
            nnz_vals.push(t.vals[e]);
        }

        // Zero-cell rejection sampling, bounded: a fully (or nearly) dense
        // shard has few or no true zero cells, and the unbounded loop
        // would spin forever. After 64 x batch failed draws, keep whatever
        // zero cells *were* found and reweight the stratum by the actual
        // sample size — the estimator stays unbiased (accepted rejection
        // draws are uniform over the zero cells); a fully dense shard
        // ends with an empty stratum and `w_zero = 0`.
        let mut zero_rows = vec![Vec::with_capacity(batch); d];
        let mut found = 0usize;
        let max_attempts = 64 * batch.max(1);
        let mut attempts = 0usize;
        while found < batch && attempts < max_attempts {
            attempts += 1;
            let idx: Vec<u32> = t.dims.iter().map(|&dim| rng.below(dim) as u32).collect();
            if cell_set.contains(&t.linearize(&idx)) {
                continue; // rejection: must be a true zero cell
            }
            for (m, rows) in zero_rows.iter_mut().enumerate() {
                rows.push(idx[m]);
            }
            found += 1;
        }
        let w_zero = if found == 0 { 0.0 } else { (cells - nnz as f64) / found as f64 };

        EvalSample {
            nnz_rows,
            nnz_vals,
            zero_rows,
            w_nnz: nnz as f64 / batch as f64,
            w_zero,
        }
    }
}

/// One decentralized client: local shard view, factors, momentum,
/// estimates.
pub struct ClientState {
    pub id: usize,
    /// shared immutable data plane (tensor + per-mode fiber indices) —
    /// a view, never a copy
    pub shard: Arc<ShardData>,
    /// local factors: `mats[0]` holds only this client's patient rows
    pub factors: FactorSet,
    /// Nesterov momentum velocity per mode (allocated when enabled)
    momentum: Vec<Option<Mat>>,
    /// peer estimates for feature modes (None until decentralized init)
    pub estimates: Option<EstimateState>,
    /// error feedback per mode (centralized CiderTF)
    pub ef: Vec<Option<ErrorFeedback>>,
    /// pre-step factor snapshot used by the error-feedback path
    pub ef_shadow: Option<Vec<Mat>>,
    pub fiber_sampler: FiberSampler,
    pub ledger: CommLedger,
    /// receive-side delivery accounting (populated by the net drivers)
    pub net: NetStats,
    pub eval: EvalSample,
    /// reused dense-slice gather buffer (grown on demand when a caller
    /// passes a larger `fiber_samples` than the construction-time default)
    xs_buf: Vec<f32>,
    /// reused per-mode row-gather buffers for the gradient call
    u_bufs: Vec<Mat>,
    /// reused row-gather buffers for eval batches
    eval_u_bufs: Vec<Mat>,
    /// reused per-mode gradient output buffers (`grad_into` target) —
    /// per mode so cycling modes never reallocates
    grad_bufs: Vec<Mat>,
    /// reused fiber-id sample buffer
    fiber_buf: Vec<u64>,
}

impl ClientState {
    pub fn new(
        id: usize,
        shard: Arc<ShardData>,
        rank: usize,
        init_scale: f32,
        seed: u64,
        fiber_samples: usize,
        eval_batch: usize,
        momentum_enabled: bool,
        error_feedback: bool,
    ) -> Self {
        let dims = shard.tensor.dims.clone();
        // Feature-mode factors use the *shared* seed so all clients start
        // identical (Alg. 1: A^k[0] = A[0]); the patient mode is seeded per
        // client slice — we draw the full global matrix and take our rows
        // so that K=1 and K=8 runs start from the same global init.
        let factors = init_factors_for_shard(&shard, &dims, rank, init_scale, seed);
        let d = dims.len();
        let momentum = (0..d)
            .map(|m| momentum_enabled.then(|| Mat::zeros(dims[m], rank)))
            .collect();
        let ef = (0..d)
            .map(|m| error_feedback.then(|| ErrorFeedback::new(dims[m], rank)))
            .collect();
        let mut eval_rng = Rng::new(seed ^ 0xE7A1).split(id as u64);
        let eval = EvalSample::build(&shard.tensor, eval_batch, &mut eval_rng);
        let max_i = *dims.iter().max().unwrap();
        let u_bufs = (0..d.saturating_sub(1)).map(|_| Mat::zeros(fiber_samples, rank)).collect();
        let eval_u_bufs = (0..d).map(|_| Mat::zeros(eval_batch, rank)).collect();
        let grad_bufs = dims.iter().map(|&dm| Mat::zeros(dm, rank)).collect();
        ClientState {
            id,
            shard,
            factors,
            momentum,
            estimates: None,
            ef,
            ef_shadow: None,
            fiber_sampler: FiberSampler::new(seed, id as u64),
            ledger: CommLedger::default(),
            net: NetStats::default(),
            eval,
            xs_buf: vec![0.0; max_i * fiber_samples],
            u_bufs,
            eval_u_bufs,
            grad_bufs,
            fiber_buf: Vec::with_capacity(fiber_samples),
        }
    }

    /// Checkpoint view of the per-mode momentum velocities (`None` when
    /// momentum is disabled).
    pub(crate) fn momentum_mats(&self) -> &[Option<Mat>] {
        &self.momentum
    }

    /// Mutable counterpart of [`ClientState::momentum_mats`] for
    /// checkpoint restore.
    pub(crate) fn momentum_mats_mut(&mut self) -> &mut [Option<Mat>] {
        &mut self.momentum
    }

    /// Wire up gossip estimates (decentralized runs only): feature modes
    /// start from the shared init.
    pub fn init_estimates(&mut self, neighbors: &[usize]) {
        let d = self.factors.order();
        let init: Vec<Option<Mat>> = (0..d)
            .map(|m| (m > 0).then(|| self.factors.mats[m].clone()))
            .collect();
        self.estimates = Some(EstimateState::new(self.id, neighbors, &init));
    }

    /// One local SGD (or momentum) step on `mode` (Alg. 1 lines 4-5,
    /// eq. 12-13). Returns the slice loss (monitoring only).
    ///
    /// Steady state this is **allocation-free** end to end: the fiber
    /// sample, the dense slice, the row gathers, and the gradient all land
    /// in buffers owned by `self` (asserted by `tests/alloc_free.rs`).
    pub fn local_step(
        &mut self,
        mode: usize,
        loss: Loss,
        fiber_samples: usize,
        gamma: f64,
        beta: Option<f64>,
        backend: &mut dyn ComputeBackend,
    ) -> anyhow::Result<f64> {
        let d = self.shard.tensor.dims.len();
        let i_dim = self.shard.tensor.dims[mode];
        let n_fibers = self.shard.tensor.n_fibers(mode);
        self.fiber_sampler.sample_into(n_fibers, fiber_samples, &mut self.fiber_buf);
        let s_dim = self.fiber_buf.len();

        // dense slice gather (L3 hot path #1); the buffer is sized for the
        // construction-time fiber_samples but callers may legitimately
        // pass more — grow on demand instead of slicing out of bounds
        if self.xs_buf.len() < i_dim * s_dim {
            self.xs_buf.resize(i_dim * s_dim, 0.0);
        }
        self.shard.indices.mode(mode).gather_slice_threads(
            &self.fiber_buf,
            i_dim,
            &mut self.xs_buf[..i_dim * s_dim],
            backend.threads(),
        );

        // row gathers of the other modes (L3 hot path #2)
        gather_rows(
            &self.factors,
            mode,
            &self.shard.tensor.dims,
            &self.fiber_buf,
            &mut self.u_bufs,
        );

        // Mean over the sampled fibers (BrasCPD convention): keeps the
        // step size interpretable independent of tensor size. (The fully
        // unbiased sum-gradient is `n_fibers/|S| ·` this; the constant is
        // absorbed by the grid-searched γ, exactly as in the paper.)
        let scale = 1.0 / s_dim as f32;
        let slice_loss = backend.grad_into(
            loss,
            &self.xs_buf[..i_dim * s_dim],
            i_dim,
            s_dim,
            &self.factors.mats[mode],
            &self.u_bufs[..d - 1],
            scale,
            &mut self.grad_bufs[mode],
        )?;

        // momentum velocity M = G + β M_prev (eq. 12, constant lr),
        // applied fully in place on the reused buffers
        let g = &self.grad_bufs[mode];
        let a = &mut self.factors.mats[mode];
        match (&mut self.momentum[mode], beta) {
            (Some(m), Some(b)) => {
                m.scale(b as f32);
                m.add_assign(g);
                // A -= γ (G + β M)   (eq. 13)
                a.axpy(-(gamma as f32), g);
                a.axpy(-(gamma * b) as f32, m);
            }
            _ => {
                a.axpy(-(gamma as f32), g);
            }
        }
        Ok(slice_loss)
    }

    /// Estimate this client's contribution to the global loss on the fixed
    /// stratified sample (two backend eval calls).
    pub fn eval_loss(&mut self, loss: Loss, backend: &mut dyn ComputeBackend) -> anyhow::Result<f64> {
        let d = self.factors.order();
        // nonzero batch
        for m in 0..d {
            gather_rows_by_index(&self.factors.mats[m], &self.eval.nnz_rows[m], &mut self.eval_u_bufs[m]);
        }
        let refs: Vec<&Mat> = self.eval_u_bufs.iter().collect();
        let sum_nnz = backend.eval(loss, &self.eval.nnz_vals, &refs)?;
        // zero batch
        for m in 0..d {
            gather_rows_by_index(&self.factors.mats[m], &self.eval.zero_rows[m], &mut self.eval_u_bufs[m]);
        }
        let refs: Vec<&Mat> = self.eval_u_bufs.iter().collect();
        let zeros = vec![0.0f32; self.eval.zero_rows[0].len()];
        let sum_zero = backend.eval(loss, &zeros, &refs)?;
        Ok(self.eval.w_nnz * sum_nnz + self.eval.w_zero * sum_zero)
    }
}

/// Draw the shared global init and slice out this shard's patient rows.
fn init_factors_for_shard(
    shard: &ShardData,
    dims: &[usize],
    rank: usize,
    init_scale: f32,
    seed: u64,
) -> FactorSet {
    // Row i of the global patient factor depends only on (seed, global row
    // index), so any K — and any partitioner, contiguous or not —
    // produces the same global init; K=1 and K=8 runs are directly
    // comparable and shards never need the global row count.
    let mut mats = Vec::with_capacity(dims.len());
    // patient mode: per-global-row deterministic rows
    let mut a0 = Mat::zeros(dims[0], rank);
    for local in 0..dims[0] {
        let global_row = shard.global_rows[local] as usize;
        let mut row_rng = Rng::new(seed ^ 0xA0).split(global_row as u64);
        for r in 0..rank {
            *a0.at_mut(local, r) = row_rng.uniform_f32() * init_scale;
        }
    }
    mats.push(a0);
    // feature modes: shared across clients
    for (m, &dim) in dims.iter().enumerate().skip(1) {
        let mut mode_rng = Rng::new(seed ^ 0xA0).split(0x1_0000 + m as u64);
        mats.push(Mat::rand_uniform(dim, rank, init_scale, &mut mode_rng));
    }
    FactorSet { mats }
}

/// Tensor orders the gather scratch covers on the stack (so the hot path
/// never touches the heap; EHR tensors are order 3-4). Higher orders fall
/// back to a heap buffer — slower, never wrong.
const MAX_ORDER: usize = 8;

/// Gather the Khatri-Rao row matrices `U_m[S, R]` for every mode except
/// `mode`, into reusable buffers (order: ascending mode, skipping `mode`).
pub fn gather_rows(
    factors: &FactorSet,
    mode: usize,
    dims: &[usize],
    fibers: &[u64],
    out: &mut [Mat],
) {
    let d = dims.len();
    let r_dim = factors.rank();
    let s = fibers.len();
    let mut idx_arr = [0u32; MAX_ORDER];
    let mut idx_vec;
    let idx_buf: &mut [u32] = if d <= MAX_ORDER {
        &mut idx_arr[..d]
    } else {
        idx_vec = vec![0u32; d];
        &mut idx_vec
    };
    // resize buffers if the fiber count shrank (tiny tensors)
    for buf in out.iter_mut().take(d - 1) {
        if buf.rows != s || buf.cols != r_dim {
            *buf = Mat::zeros(s, r_dim);
        }
    }
    for (row, &fid) in fibers.iter().enumerate() {
        crate::tensor::decode_fiber_into(dims, mode, fid, idx_buf);
        let mut slot = 0;
        for m in 0..d {
            if m == mode {
                continue;
            }
            let src = factors.mats[m].row(idx_buf[m] as usize);
            out[slot].row_mut(row).copy_from_slice(src);
            slot += 1;
        }
    }
}

/// Gather rows of `a` at `rows` into `out` (`[B, R]`).
pub fn gather_rows_by_index(a: &Mat, rows: &[u32], out: &mut Mat) {
    debug_assert_eq!(out.cols, a.cols);
    if out.rows != rows.len() {
        *out = Mat::zeros(rows.len(), a.cols);
    }
    for (b, &i) in rows.iter().enumerate() {
        out.row_mut(b).copy_from_slice(a.row(i as usize));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeBackend;
    use crate::tensor::partition::partition_shared;
    use crate::tensor::synth::SynthConfig;

    fn mk_client(id: usize, k: usize, momentum: bool) -> ClientState {
        let data = SynthConfig::tiny(11).generate();
        let shards = partition_shared(&data.tensor, k);
        ClientState::new(id, shards[id].clone(), 4, 0.2, 123, 16, 32, momentum, false)
    }

    #[test]
    fn shared_init_feature_modes_identical_across_clients() {
        let c0 = mk_client(0, 2, false);
        let c1 = mk_client(1, 2, false);
        for m in 1..3 {
            assert_eq!(c0.factors.mats[m].data, c1.factors.mats[m].data);
        }
        // patient rows differ (different global rows)
        assert_ne!(c0.factors.mats[0].data, c1.factors.mats[0].data);
    }

    #[test]
    fn patient_init_matches_k1_global_slice() {
        // rows of a K=2 shard must equal the same global rows at K=1
        let k1 = mk_client(0, 1, false);
        let c1 = mk_client(1, 2, false);
        for local in 0..c1.factors.mats[0].rows {
            assert_eq!(
                c1.factors.mats[0].row(local),
                k1.factors.mats[0].row(c1.shard.global_rows[local] as usize),
                "row {local}"
            );
        }
    }

    #[test]
    fn non_contiguous_shard_init_matches_global_slice() {
        // a site_vocab shard owns scattered global rows; each local row
        // must still equal the K=1 global init at its global index
        let data = SynthConfig::tiny(11).generate();
        let k1 = mk_client(0, 1, false);
        let shards = crate::tensor::partition::partition_shared_with(
            &data.tensor,
            3,
            &crate::tensor::partition::Partitioner::SiteVocab(0.2),
            9,
        );
        for (id, sh) in shards.into_iter().enumerate() {
            let c = ClientState::new(id, sh, 4, 0.2, 123, 16, 32, false, false);
            for local in 0..c.factors.mats[0].rows {
                assert_eq!(
                    c.factors.mats[0].row(local),
                    k1.factors.mats[0].row(c.shard.global_rows[local] as usize),
                    "row {local}"
                );
            }
        }
    }

    #[test]
    fn local_step_descends_slice_loss() {
        let mut c = mk_client(0, 1, false);
        let mut backend = NativeBackend::new();
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for t in 0..300 {
            let mode = t % 3;
            let l = c.local_step(mode, Loss::Ls, 16, 0.05, None, &mut backend).unwrap();
            if t == 0 {
                first = l;
            }
            last = l;
        }
        assert!(last < first, "slice loss did not descend: {first} -> {last}");
        assert!(c.factors.mats[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn momentum_step_differs_from_plain() {
        let mut plain = mk_client(0, 1, false);
        let mut mom = mk_client(0, 1, true);
        let mut b1 = NativeBackend::new();
        let mut b2 = NativeBackend::new();
        for t in 0..10 {
            plain.local_step(t % 3, Loss::Ls, 16, 0.05, None, &mut b1).unwrap();
            mom.local_step(t % 3, Loss::Ls, 16, 0.05, Some(0.9), &mut b2).unwrap();
        }
        assert_ne!(plain.factors.mats[0].data, mom.factors.mats[0].data);
    }

    #[test]
    fn eval_sample_weights_unbiased_for_ls() {
        // For the all-zero factor set, ls loss estimate must equal ‖X‖_F²
        // exactly: nnz batch contributes w_nnz * Σ x², zero batch 0.
        let data = SynthConfig::tiny(12).generate();
        let shards = partition_shared(&data.tensor, 1);
        let mut c = ClientState::new(0, shards[0].clone(), 4, 0.2, 5, 16, 64, false, false);
        for m in c.factors.mats.iter_mut() {
            m.fill(0.0);
        }
        let mut backend = NativeBackend::new();
        let est = c.eval_loss(Loss::Ls, &mut backend).unwrap();
        // estimator over the nnz batch: mean(x²)*nnz — with-replacement
        // draws of uniform entries; for the binary tensor every x=1 so the
        // estimate is exact
        let exact = data.tensor.frob_sq();
        assert!((est - exact).abs() / exact < 1e-6, "{est} vs {exact}");
    }

    #[test]
    fn eval_sample_terminates_on_fully_dense_shard() {
        // every cell nonzero: the zero-cell rejection sampler has nothing
        // to find and must fall back (previously: infinite loop)
        let dims = vec![3usize, 3, 3];
        let mut t = crate::tensor::SparseTensor::new(dims.clone());
        for i in 0..3u32 {
            for j in 0..3u32 {
                for k in 0..3u32 {
                    t.push(&[i, j, k], 1.0);
                }
            }
        }
        let shard = Arc::new(ShardData::new(t, 0));
        let mut rng = Rng::new(77);
        let es = EvalSample::build(&shard.tensor, 16, &mut rng);
        assert_eq!(es.w_zero, 0.0, "dense shard has an empty zero stratum");
        assert_eq!(es.zero_rows[0].len(), 0, "no fake zero cells");
        // the loss estimate is still exact for the all-zero factor set
        let mut c = ClientState::new(0, shard, 4, 0.2, 5, 8, 16, false, false);
        for m in c.factors.mats.iter_mut() {
            m.fill(0.0);
        }
        let mut backend = NativeBackend::new();
        let est = c.eval_loss(Loss::Ls, &mut backend).unwrap();
        let exact = 27.0; // ‖X‖_F² of the all-ones 3x3x3 tensor
        assert!((est - exact).abs() / exact < 1e-6, "{est} vs {exact}");
    }

    #[test]
    fn local_step_accepts_larger_fiber_samples_than_construction() {
        // construction-time fiber_samples = 4; stepping with 64 must grow
        // xs_buf instead of slicing out of bounds (previous panic)
        let data = SynthConfig::tiny(15).generate();
        let shards = partition_shared(&data.tensor, 1);
        let mut c = ClientState::new(0, shards[0].clone(), 4, 0.2, 123, 4, 32, false, false);
        let mut backend = NativeBackend::new();
        for t in 0..6 {
            let l = c.local_step(t % 3, Loss::Ls, 64, 0.05, None, &mut backend).unwrap();
            assert!(l.is_finite());
        }
        assert!(c.factors.mats[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn client_holds_a_view_of_the_shared_shard() {
        // constructing a client must not copy the data plane: the client's
        // shard is the same allocation the partitioner produced
        let data = SynthConfig::tiny(11).generate();
        let shards = partition_shared(&data.tensor, 2);
        let c0 = ClientState::new(0, shards[0].clone(), 4, 0.2, 123, 16, 32, false, false);
        let c1 = ClientState::new(1, shards[1].clone(), 4, 0.2, 123, 16, 32, false, false);
        assert!(Arc::ptr_eq(&c0.shard, &shards[0]));
        assert!(Arc::ptr_eq(&c1.shard, &shards[1]));
        assert!(!Arc::ptr_eq(&c0.shard, &c1.shard));
    }

    #[test]
    fn gather_rows_by_index_basic() {
        let a = Mat::from_fn(5, 2, |i, j| (i * 10 + j) as f32);
        let mut out = Mat::zeros(3, 2);
        gather_rows_by_index(&a, &[4, 0, 2], &mut out);
        assert_eq!(out.data, vec![40.0, 41.0, 0.0, 1.0, 20.0, 21.0]);
    }

    #[test]
    fn gather_rows_skips_target_mode_and_matches_krp() {
        let data = SynthConfig::tiny(13).generate();
        let shards = partition_shared(&data.tensor, 1);
        let c = ClientState::new(0, shards[0].clone(), 4, 0.2, 9, 8, 16, false, false);
        let dims = c.shard.tensor.dims.clone();
        let fibers: Vec<u64> = vec![0, 5, 17];
        let mut bufs = vec![Mat::zeros(3, 4), Mat::zeros(3, 4)];
        gather_rows(&c.factors, 1, &dims, &fibers, &mut bufs);
        // hadamard of gathered rows must equal FactorSet::khatri_rao_rows
        let h_ref = c.factors.khatri_rao_rows(1, &dims, &fibers);
        let mut h = bufs[0].clone();
        h.hadamard_assign(&bufs[1]);
        for (x, y) in h.data.iter().zip(h_ref.data.iter()) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
