//! Algorithm presets — the feature matrix of paper Table II plus the
//! centralized baselines of §IV-A2. Every algorithm is one configuration
//! of the same engine; the table maps directly onto `AlgoConfig` fields.

use super::AlgoConfig;
use crate::compress::Compressor;
use crate::net::driver::DriverKind;
use crate::net::sim::FaultConfig;

impl AlgoConfig {
    /// CiderTF (paper Alg. 1): sign + block randomization + periodic (τ) +
    /// event-triggered communication.
    pub fn cidertf(tau: usize) -> Self {
        AlgoConfig {
            name: format!("cidertf_t{tau}"),
            compressor: Compressor::Sign,
            block_random: true,
            tau,
            event_triggered: true,
            momentum: None,
            error_feedback: false,
            rho: 0.7,
        }
    }

    /// CiderTF_m: CiderTF + Nesterov momentum (paper §III-C, β = 0.9).
    pub fn cidertf_m(tau: usize) -> Self {
        AlgoConfig {
            name: format!("cidertf_m_t{tau}"),
            momentum: Some(0.9),
            ..Self::cidertf(tau)
        }
    }

    /// D-PSGD (Lian et al.): full-precision, all modes, every round.
    pub fn dpsgd() -> Self {
        AlgoConfig {
            name: "dpsgd".into(),
            compressor: Compressor::None,
            block_random: false,
            tau: 1,
            event_triggered: false,
            momentum: None,
            error_feedback: false,
            rho: 0.7,
        }
    }

    /// D-PSGDbras: D-PSGD + block randomization (ablation Table II).
    pub fn dpsgd_bras() -> Self {
        AlgoConfig { name: "dpsgd_bras".into(), block_random: true, ..Self::dpsgd() }
    }

    /// D-PSGD + signSGD: gradient compression only (ablation Table II).
    pub fn dpsgd_sign() -> Self {
        AlgoConfig { name: "dpsgd_sign".into(), compressor: Compressor::Sign, ..Self::dpsgd() }
    }

    /// D-PSGDbras + signSGD (ablation Table II).
    pub fn dpsgd_bras_sign() -> Self {
        AlgoConfig {
            name: "dpsgd_bras_sign".into(),
            compressor: Compressor::Sign,
            block_random: true,
            ..Self::dpsgd()
        }
    }

    /// SPARQ-SGD (Singh et al.): compression + periodic + event-triggered,
    /// but no block randomization — all modes updated and shipped.
    pub fn sparq_sgd(tau: usize) -> Self {
        AlgoConfig {
            name: format!("sparq_sgd_t{tau}"),
            compressor: Compressor::Sign,
            block_random: false,
            tau,
            event_triggered: true,
            momentum: None,
            error_feedback: false,
            rho: 0.7,
        }
    }

    /// GCP (Kolda-Hong stochastic generalized CP): centralized (run with
    /// K = 1), all modes per iteration, no communication machinery.
    pub fn gcp() -> Self {
        AlgoConfig {
            name: "gcp".into(),
            compressor: Compressor::None,
            block_random: false,
            tau: 1,
            event_triggered: false,
            momentum: None,
            error_feedback: false,
            rho: 0.0,
        }
    }

    /// BrasCPD (Fu et al.): centralized block-randomized stochastic CPD.
    pub fn bras_cpd() -> Self {
        AlgoConfig { name: "bras_cpd".into(), block_random: true, ..Self::gcp() }
    }

    /// Centralized CiderTF: K = 1, sign-compressed updates with error
    /// feedback (paper baseline iii).
    pub fn centralized_cidertf() -> Self {
        AlgoConfig {
            name: "centralized_cidertf".into(),
            compressor: Compressor::Sign,
            block_random: true,
            tau: 1,
            event_triggered: false,
            momentum: None,
            error_feedback: true,
            rho: 0.0,
        }
    }

    /// Look up a preset by CLI name (`cidertf:4` selects τ = 4). Thin
    /// wrapper over [`crate::registry::algos`].
    pub fn by_name(spec: &str) -> anyhow::Result<Self> {
        crate::registry::algos().resolve(spec)
    }

    /// Table II "Compression Ratio" column (analytical, per communicating
    /// round, vs full-precision all-mode D-PSGD).
    pub fn table2_ratio(&self, d_order: usize) -> f64 {
        let element = match self.compressor {
            Compressor::None => 1.0,
            Compressor::Sign => 1.0 / 32.0,
            Compressor::TopK { ratio } => (2.0 / ratio.max(1) as f64).min(1.0),
        };
        let block = if self.block_random { 1.0 / d_order as f64 } else { 1.0 };
        let round = 1.0 / self.tau as f64;
        1.0 - element * block * round
    }
}

/// A fully-specified execution scenario: algorithm preset + network fault
/// envelope + round driver, resolvable from a single CLI spec
/// `<algo>[@<network>[@<driver>]]` — e.g. `cidertf:4@lossy:0.2@async`.
///
/// This is the entry point the `train` subcommand and the
/// `harness::faults` sweep share: the algorithm table (Table II) stays
/// orthogonal to the network conditions it runs under.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// algorithm configuration (Table II row)
    pub algo: AlgoConfig,
    /// network fault envelope (`None` = ideal network)
    pub fault: Option<FaultConfig>,
    /// execution path
    pub driver: DriverKind,
}

impl Scenario {
    /// Parse `<algo>[@<network>[@<driver>]]`.
    ///
    /// The driver defaults to `sim` whenever a non-ideal network is named
    /// (faults need the simulator) and to the sequential engine otherwise.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut parts = spec.split('@');
        let algo = AlgoConfig::by_name(parts.next().unwrap_or_default())?;
        let fault = match parts.next() {
            Some(name) => FaultConfig::by_name(name)?,
            None => None,
        };
        let driver = match parts.next() {
            Some(d) => DriverKind::from_name(d)?,
            None => {
                if fault.is_some() {
                    DriverKind::Sim
                } else {
                    DriverKind::Sequential
                }
            }
        };
        anyhow::ensure!(
            parts.next().is_none(),
            "too many '@' segments in scenario '{spec}' (algo[@network[@driver]])"
        );
        anyhow::ensure!(
            !(fault.is_some() && matches!(driver, DriverKind::Sequential | DriverKind::Parallel)),
            "driver '{}' cannot inject network faults — use sim or async",
            driver.name()
        );
        Ok(Scenario { algo, fault, driver })
    }

    /// Display name, e.g. `cidertf_t4@lossy@async`.
    pub fn label(&self) -> String {
        let net = match &self.fault {
            None => "ideal".to_string(),
            Some(f) if f.drop_rate > 0.0 => format!("lossy{:.0}%", 100.0 * f.drop_rate),
            Some(_) => "faulty".to_string(),
        };
        format!("{}@{}@{}", self.algo.name, net, self.driver.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_feature_matrix() {
        let d = 3;
        assert_eq!(AlgoConfig::dpsgd().table2_ratio(d), 0.0);
        assert!((AlgoConfig::dpsgd_bras().table2_ratio(d) - (1.0 - 1.0 / 3.0)).abs() < 1e-12);
        assert!((AlgoConfig::dpsgd_sign().table2_ratio(d) - (1.0 - 1.0 / 32.0)).abs() < 1e-12);
        assert!(
            (AlgoConfig::dpsgd_bras_sign().table2_ratio(d) - (1.0 - 1.0 / (32.0 * 3.0))).abs() < 1e-12
        );
        assert!(
            (AlgoConfig::sparq_sgd(4).table2_ratio(d) - (1.0 - 1.0 / (32.0 * 4.0))).abs() < 1e-12
        );
        assert!(
            (AlgoConfig::cidertf(4).table2_ratio(d) - (1.0 - 1.0 / (32.0 * 3.0 * 4.0))).abs() < 1e-12
        );
    }

    #[test]
    fn by_name_with_tau() {
        let a = AlgoConfig::by_name("cidertf:8").unwrap();
        assert_eq!(a.tau, 8);
        assert!(a.event_triggered && a.block_random);
        let m = AlgoConfig::by_name("cidertf_m").unwrap();
        assert_eq!(m.momentum, Some(0.9));
        assert!(AlgoConfig::by_name("magic").is_err());
        assert!(AlgoConfig::by_name("cidertf:x").is_err());
    }

    #[test]
    fn preset_flags_match_table2_rows() {
        // (element, block, round, event) per Table II
        let rows: Vec<(AlgoConfig, [bool; 4])> = vec![
            (AlgoConfig::dpsgd(), [false, false, false, false]),
            (AlgoConfig::dpsgd_bras(), [false, true, false, false]),
            (AlgoConfig::dpsgd_sign(), [true, false, false, false]),
            (AlgoConfig::dpsgd_bras_sign(), [true, true, false, false]),
            (AlgoConfig::sparq_sgd(4), [true, false, true, true]),
            (AlgoConfig::cidertf(4), [true, true, true, true]),
        ];
        for (a, [el, bl, rd, ev]) in rows {
            assert_eq!(a.compressor == Compressor::Sign, el, "{}", a.name);
            assert_eq!(a.block_random, bl, "{}", a.name);
            assert_eq!(a.tau > 1, rd, "{}", a.name);
            assert_eq!(a.event_triggered, ev, "{}", a.name);
        }
    }

    #[test]
    fn centralized_presets() {
        assert!(!AlgoConfig::gcp().block_random);
        assert!(AlgoConfig::bras_cpd().block_random);
        assert!(AlgoConfig::centralized_cidertf().error_feedback);
    }

    #[test]
    fn scenario_specs_parse() {
        let s = Scenario::parse("cidertf:8").unwrap();
        assert_eq!(s.algo.tau, 8);
        assert!(s.fault.is_none());
        assert_eq!(s.driver, DriverKind::Sequential);

        let s = Scenario::parse("cidertf:4@lossy:0.2").unwrap();
        assert!((s.fault.as_ref().unwrap().drop_rate - 0.2).abs() < 1e-12);
        assert_eq!(s.driver, DriverKind::Sim);

        let s = Scenario::parse("dpsgd@hostile@async").unwrap();
        assert_eq!(s.driver, DriverKind::Async);
        assert!(s.label().contains("async"));

        let s = Scenario::parse("cidertf:4@ideal@par").unwrap();
        assert_eq!(s.driver, DriverKind::Parallel);
        assert!(s.fault.is_none());

        assert!(Scenario::parse("cidertf:4@lossy:0.2@seq").is_err());
        assert!(Scenario::parse("nope@ideal").is_err());
        assert!(Scenario::parse("cidertf@ideal@seq@extra").is_err());
    }
}
