//! The declarative experiment surface: [`ExperimentSpec`].
//!
//! One serializable value names **every** axis of a CiderTF run —
//! dataset, loss, algorithm (Table II row), compressor, topology, fault
//! envelope, round driver, seeds, budget/stopping rule, and eval cadence
//! — and is buildable three ways:
//!
//! 1. **typed builder** — `ExperimentSpec::builder("tiny", Loss::Logit,
//!    AlgoConfig::cidertf(4)).k(8).driver(DriverKind::Sim).build()?`,
//! 2. **scenario string** — `ExperimentSpec::from_scenario_str(
//!    "cidertf:4@lossy:0.2@async", "synthetic", Loss::Logit)?`
//!    ([`crate::engine::presets::Scenario`] is the thin front-end),
//! 3. **JSON file** — `ExperimentSpec::load(path)?` / `--spec file.json`
//!    (schema [`SPEC_SCHEMA`]); `cidertf spec` prints the fully-resolved
//!    default JSON for any scenario string.
//!
//! A spec is *consumed* by [`crate::engine::session::Session`], which
//! resolves each named axis through the [`crate::registry`] tables and
//! drives the run while streaming typed events to observers.

use std::path::Path;

use crate::adversary::AdversarySchedule;
use crate::data::Dataset;
use crate::engine::{AlgoConfig, TrainConfig};
use crate::gossip::Aggregator;
use crate::losses::Loss;
use crate::net::driver::DriverKind;
use crate::net::sim::{self, FaultConfig, NetworkModel};
use crate::runtime::NativeOrPjrt;
use crate::tensor::partition::Partitioner;
use crate::tensor::synth::ValueKind;
use crate::topology::Topology;
use crate::util::json::Json;

/// Schema tag written into every serialized spec.
pub const SPEC_SCHEMA: &str = "cidertf-spec-v1";

/// Budget/stopping rule: the run ends at `epochs` regardless, but may
/// stop earlier once a loss target is reached or a communication budget
/// is exhausted (both checked at eval points).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StopRule {
    /// stop once the estimated global loss is ≤ this value
    pub target_loss: Option<f64>,
    /// stop once cumulative uplink bytes reach this budget
    pub max_bytes: Option<u64>,
}

impl StopRule {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "target_loss",
                self.target_loss.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "max_bytes",
                self.max_bytes.map(Json::u64).unwrap_or(Json::Null),
            ),
        ])
    }

    fn from_json(j: &Json) -> anyhow::Result<Self> {
        j.ensure_known_keys("stop rule", &["target_loss", "max_bytes"])?;
        let target_loss = match j.get("target_loss") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("invalid 'target_loss' (number expected)"))?,
            ),
        };
        let max_bytes = match j.get("max_bytes") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_u64()
                    .ok_or_else(|| anyhow::anyhow!("invalid 'max_bytes' (integer expected)"))?,
            ),
        };
        Ok(StopRule { target_loss, max_bytes })
    }
}

/// A fully-specified, serializable experiment: every pluggable axis by
/// name plus every numeric knob. See the module docs for the three ways
/// to build one.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentSpec {
    /// dataset name (see `cidertf info` → datasets)
    pub dataset: String,
    /// GCP elementwise loss
    pub loss: Loss,
    /// algorithm configuration (Table II row), including the compressor
    /// and error-feedback flags
    pub algo: AlgoConfig,
    /// communication graph
    pub topology: Topology,
    /// number of clients (institutions) K
    pub k: usize,
    /// CP rank R
    pub rank: usize,
    /// fiber sample size |S| per local step
    pub fiber_samples: usize,
    /// learning rate γ
    pub gamma: f64,
    /// epochs to run
    pub epochs: usize,
    /// iterations per epoch
    pub iters_per_epoch: usize,
    /// master seed for every derived stream (init, sampling, faults)
    pub seed: u64,
    /// stratified loss-estimator batch size
    pub eval_batch: usize,
    /// factor init scale
    pub init_scale: f32,
    /// scale on the event-trigger threshold λ₀ = scale/γ
    pub trigger_lambda0_scale: f64,
    /// event-trigger growth factor α
    pub trigger_alpha: f64,
    /// nominal per-iteration compute cost in simulated seconds
    pub sim_iter_s: f64,
    /// compute threads per gradient call (1 = fully deterministic)
    pub compute_threads: usize,
    /// network fault envelope (`None` = ideal network)
    pub fault: Option<FaultConfig>,
    /// mode-0 patient partitioner (heterogeneity axis)
    pub partitioner: Partitioner,
    /// consensus combiner for peer estimates (robustness axis)
    pub aggregator: Aggregator,
    /// Byzantine-client schedule (`None` = every client honest)
    pub adversary: Option<AdversarySchedule>,
    /// execution path
    pub driver: DriverKind,
    /// socket transport for the `node` driver (`tcp` or `uds`; see
    /// `cidertf info` → transports). Ignored by in-process drivers.
    pub transport: String,
    /// compute backend flag (`native` or `pjrt`)
    pub backend: String,
    /// epochs between eval points (1 = every epoch)
    pub eval_every: usize,
    /// early-stopping rule
    pub stop: StopRule,
}

impl ExperimentSpec {
    /// Spec with the engine's stock defaults (mirrors
    /// [`TrainConfig::new`]): sequential driver, ideal network, default
    /// backend, eval every epoch, no early stopping.
    pub fn new(dataset: &str, loss: Loss, algo: AlgoConfig) -> Self {
        let cfg = TrainConfig::new(dataset, loss, algo);
        Self::from_train_config(&cfg, DriverKind::Sequential, None, NativeOrPjrt::default_flag())
    }

    /// Start a fluent builder from the stock defaults.
    pub fn builder(dataset: &str, loss: Loss, algo: AlgoConfig) -> ExperimentSpecBuilder {
        ExperimentSpecBuilder { spec: Self::new(dataset, loss, algo) }
    }

    /// Lift an imperative [`TrainConfig`] (the legacy surface) into a
    /// spec, naming the execution path and fault envelope explicitly.
    pub fn from_train_config(
        cfg: &TrainConfig,
        driver: DriverKind,
        fault: Option<FaultConfig>,
        backend: &str,
    ) -> Self {
        ExperimentSpec {
            dataset: cfg.dataset.clone(),
            loss: cfg.loss,
            algo: cfg.algo.clone(),
            topology: cfg.topology,
            k: cfg.k,
            rank: cfg.rank,
            fiber_samples: cfg.fiber_samples,
            gamma: cfg.gamma,
            epochs: cfg.epochs,
            iters_per_epoch: cfg.iters_per_epoch,
            seed: cfg.seed,
            eval_batch: cfg.eval_batch,
            init_scale: cfg.init_scale,
            trigger_lambda0_scale: cfg.trigger_lambda0_scale,
            trigger_alpha: cfg.trigger_alpha,
            sim_iter_s: cfg.sim_iter_s,
            compute_threads: cfg.compute_threads,
            fault,
            partitioner: cfg.partitioner.clone(),
            aggregator: cfg.aggregator.clone(),
            adversary: cfg.adversary.clone(),
            driver,
            transport: "tcp".to_string(),
            backend: backend.to_string(),
            eval_every: 1,
            stop: StopRule::default(),
        }
    }

    /// Resolve a scenario string `<algo>[@<network>[@<driver>]]` (the
    /// [`crate::engine::presets::Scenario`] front-end) into a spec. The
    /// fault envelope inherits the spec's master seed at run time unless
    /// its own seed was set explicitly.
    pub fn from_scenario_str(scenario: &str, dataset: &str, loss: Loss) -> anyhow::Result<Self> {
        let s = crate::engine::presets::Scenario::parse(scenario)?;
        let mut spec = Self::new(dataset, loss, s.algo);
        spec.fault = s.fault;
        spec.driver = s.driver;
        spec.validate()?;
        Ok(spec)
    }

    /// The imperative config this spec resolves to (the engine's input).
    pub fn to_train_config(&self) -> TrainConfig {
        TrainConfig {
            dataset: self.dataset.clone(),
            loss: self.loss,
            rank: self.rank,
            fiber_samples: self.fiber_samples,
            k: self.k,
            topology: self.topology,
            gamma: self.gamma,
            iters_per_epoch: self.iters_per_epoch,
            epochs: self.epochs,
            seed: self.seed,
            eval_batch: self.eval_batch,
            init_scale: self.init_scale,
            trigger_lambda0_scale: self.trigger_lambda0_scale,
            trigger_alpha: self.trigger_alpha,
            sim_iter_s: self.sim_iter_s,
            compute_threads: self.compute_threads,
            partitioner: self.partitioner.clone(),
            aggregator: self.aggregator.clone(),
            // materialized: sentinel seeds inherit the master seed here,
            // so the engine always sees the effective Byzantine subset
            adversary: self.adversary_schedule(),
            algo: self.algo.clone(),
        }
    }

    /// The effective adversary schedule: a schedule still carrying the
    /// sentinel seed inherits the spec's master seed (same rule as
    /// [`ExperimentSpec::network_model`] fault seeds), so one `--seed`
    /// reseeds the Byzantine subset along with everything else.
    pub fn adversary_schedule(&self) -> Option<AdversarySchedule> {
        self.adversary.clone().map(|mut s| {
            s.inherit_seed(self.seed);
            s
        })
    }

    /// Cross-axis invariants (cheap, pure): fault envelopes need a
    /// network-mediated driver, and every count must be positive.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.k >= 1, "k must be >= 1");
        anyhow::ensure!(self.rank >= 1, "rank must be >= 1");
        anyhow::ensure!(self.algo.tau >= 1, "tau must be >= 1");
        anyhow::ensure!(self.epochs >= 1, "epochs must be >= 1");
        anyhow::ensure!(self.iters_per_epoch >= 1, "iters_per_epoch must be >= 1");
        anyhow::ensure!(self.eval_every >= 1, "eval_every must be >= 1");
        anyhow::ensure!(self.fiber_samples >= 1, "fiber_samples must be >= 1");
        anyhow::ensure!(self.eval_batch >= 1, "eval_batch must be >= 1");
        anyhow::ensure!(
            !(self.fault.is_some()
                && matches!(
                    self.driver,
                    DriverKind::Sequential | DriverKind::Parallel | DriverKind::Node
                )),
            "driver '{}' cannot inject network faults — use sim or async",
            self.driver.name()
        );
        anyhow::ensure!(
            !(self.adversary.is_some()
                && matches!(
                    self.driver,
                    DriverKind::Parallel | DriverKind::Async | DriverKind::Node
                )),
            "driver '{}' does not support Byzantine clients yet — use seq or sim",
            self.driver.name()
        );
        // the transport name must resolve even for in-process drivers (a
        // typo'd spec should fail loudly, not only once handed to a fleet)
        crate::registry::transports().resolve(&self.transport)?;
        if self.driver == DriverKind::Node {
            anyhow::ensure!(
                self.stop == StopRule::default(),
                "the node driver cannot evaluate early-stopping rules — they need the \
                 global loss, which no single node computes; drop 'stop' or use sim"
            );
        }
        if let Some(a) = &self.adversary {
            anyhow::ensure!(
                (0.0..=1.0).contains(&a.fraction),
                "adversary fraction {} outside [0, 1]",
                a.fraction
            );
        }
        if let Aggregator::TrimmedMean(b) = &self.aggregator {
            anyhow::ensure!(
                (0.0..0.5).contains(b),
                "trimmed_mean fraction {b} outside [0, 0.5)"
            );
        }
        Ok(())
    }

    /// Materialize the dataset this spec names through the
    /// [`crate::registry::datasets`] sources — a synthetic generator
    /// (value kind follows the loss, as in the paper: Gaussian for ls,
    /// binary for logit) or an on-disk loader (`file:<path>`,
    /// `csv:<path>`, values taken as stored).
    pub fn dataset_data(&self) -> anyhow::Result<Dataset> {
        let vk = if self.loss == Loss::Ls { ValueKind::Gaussian } else { ValueKind::Binary };
        crate::data::load_dataset(&self.dataset, vk)
    }

    /// Materialize the network model. A fault envelope still carrying the
    /// stock [`FaultConfig::default`] seed inherits the spec's master
    /// seed, so one `--seed` reseeds the whole run; an explicit fault
    /// seed is respected.
    pub fn network_model(&self) -> Box<dyn NetworkModel> {
        match &self.fault {
            None => sim::ideal(),
            Some(f) => {
                let mut f = f.clone();
                if f.seed == FaultConfig::default().seed {
                    f.seed = self.seed;
                }
                f.boxed()
            }
        }
    }

    /// Filename-friendly label:
    /// `dataset_loss_algo_driver_topology_kK`, with suffixes for any
    /// non-default robustness/heterogeneity axis (adversary, aggregator,
    /// partitioner) so grid cells never collide on disk. Loader dataset
    /// specs (`file:dir/t.tns`) are sanitized so the label never
    /// introduces path separators.
    pub fn label(&self) -> String {
        let mut label = format!(
            "{}_{}_{}_{}_{}_k{}",
            fs_component(&self.dataset),
            self.loss.name(),
            self.algo.name,
            self.driver.name(),
            self.topology.name(),
            self.k
        );
        if let Some(a) = &self.adversary {
            label.push('_');
            label.push_str(&a.label_component());
        }
        if self.aggregator != Aggregator::Mean {
            label.push('_');
            label.push_str(&self.aggregator.label_component());
        }
        if self.partitioner != Partitioner::Even {
            label.push('_');
            label.push_str(&self.partitioner.label_component());
        }
        label
    }

    // ---- JSON layer ----

    /// Serialize (schema [`SPEC_SCHEMA`]). Exact round-trip: floats use
    /// shortest-round-trip decimal, u64 seeds ride as strings.
    pub fn to_json(&self) -> Json {
        let algo = algo_to_json(&self.algo);
        Json::obj(vec![
            ("schema", Json::Str(SPEC_SCHEMA.to_string())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("loss", Json::Str(self.loss.name().to_string())),
            ("algo", algo),
            ("topology", Json::Str(self.topology.name().to_string())),
            ("k", Json::Num(self.k as f64)),
            ("rank", Json::Num(self.rank as f64)),
            ("fiber_samples", Json::Num(self.fiber_samples as f64)),
            ("gamma", Json::Num(self.gamma)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("iters_per_epoch", Json::Num(self.iters_per_epoch as f64)),
            ("seed", Json::u64(self.seed)),
            ("eval_batch", Json::Num(self.eval_batch as f64)),
            ("init_scale", Json::Num(self.init_scale as f64)),
            ("trigger_lambda0_scale", Json::Num(self.trigger_lambda0_scale)),
            ("trigger_alpha", Json::Num(self.trigger_alpha)),
            ("sim_iter_s", Json::Num(self.sim_iter_s)),
            ("compute_threads", Json::Num(self.compute_threads as f64)),
            (
                "network",
                self.fault.as_ref().map(FaultConfig::to_json).unwrap_or(Json::Null),
            ),
            ("partitioner", Json::Str(self.partitioner.spec_string())),
            ("aggregator", Json::Str(self.aggregator.spec_string())),
            (
                "adversary",
                self.adversary.as_ref().map(AdversarySchedule::to_json).unwrap_or(Json::Null),
            ),
            ("driver", Json::Str(self.driver.name().to_string())),
            ("transport", Json::Str(self.transport.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("stop", self.stop.to_json()),
        ])
    }

    /// Deserialize the [`ExperimentSpec::to_json`] layout. Strict:
    /// unknown/typo'd keys are errors (with a did-you-mean hint), so a
    /// hand-written `--spec` file can never silently run a different
    /// experiment than written; the result is validated.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        j.ensure_known_keys(
            "spec",
            &[
                "schema",
                "dataset",
                "loss",
                "algo",
                "topology",
                "k",
                "rank",
                "fiber_samples",
                "gamma",
                "epochs",
                "iters_per_epoch",
                "seed",
                "eval_batch",
                "init_scale",
                "trigger_lambda0_scale",
                "trigger_alpha",
                "sim_iter_s",
                "compute_threads",
                "network",
                "partitioner",
                "aggregator",
                "adversary",
                "driver",
                "transport",
                "backend",
                "eval_every",
                "stop",
            ],
        )?;
        if let Some(s) = j.get("schema").and_then(Json::as_str) {
            anyhow::ensure!(s == SPEC_SCHEMA, "unsupported spec schema '{s}' (want {SPEC_SCHEMA})");
        }
        let aj = j
            .get("algo")
            .ok_or_else(|| anyhow::anyhow!("missing 'algo' object"))?;
        let algo = algo_from_json(aj)?;
        let fault = match j.get("network") {
            None | Some(Json::Null) => None,
            Some(fj) => Some(FaultConfig::from_json(fj)?),
        };
        let partitioner = match j.get("partitioner") {
            None | Some(Json::Null) => Partitioner::Even,
            Some(v) => crate::registry::partitioners().resolve(
                v.as_str()
                    .ok_or_else(|| anyhow::anyhow!("invalid 'partitioner' (string expected)"))?,
            )?,
        };
        let aggregator = match j.get("aggregator") {
            None | Some(Json::Null) => Aggregator::Mean,
            Some(v) => crate::registry::aggregators().resolve(
                v.as_str()
                    .ok_or_else(|| anyhow::anyhow!("invalid 'aggregator' (string expected)"))?,
            )?,
        };
        let adversary = match j.get("adversary") {
            None | Some(Json::Null) => None,
            // accept the registry string form in hand-written specs
            Some(Json::Str(s)) => crate::registry::adversaries().resolve(s)?,
            Some(aj) => Some(AdversarySchedule::from_json(aj)?),
        };
        let spec = ExperimentSpec {
            dataset: j.req_str("dataset")?.to_string(),
            loss: Loss::from_name(j.req_str("loss")?)?,
            algo,
            topology: Topology::from_name(j.req_str("topology")?)?,
            k: j.req_usize("k")?,
            rank: j.req_usize("rank")?,
            fiber_samples: j.req_usize("fiber_samples")?,
            gamma: j.req_f64("gamma")?,
            epochs: j.req_usize("epochs")?,
            iters_per_epoch: j.req_usize("iters_per_epoch")?,
            seed: j.req_u64("seed")?,
            eval_batch: j.req_usize("eval_batch")?,
            init_scale: j.req_f64("init_scale")? as f32,
            trigger_lambda0_scale: j.req_f64("trigger_lambda0_scale")?,
            trigger_alpha: j.req_f64("trigger_alpha")?,
            sim_iter_s: j.req_f64("sim_iter_s")?,
            compute_threads: j.req_usize("compute_threads")?,
            fault,
            partitioner,
            aggregator,
            adversary,
            driver: DriverKind::from_name(j.req_str("driver")?)?,
            // pre-deployment-plane specs carry no transport: default tcp
            transport: match j.get("transport") {
                None => "tcp".to_string(),
                Some(v) => v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("invalid 'transport' (string expected)"))?
                    .to_string(),
            },
            backend: j.req_str("backend")?.to_string(),
            eval_every: match j.get("eval_every") {
                None => 1,
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("invalid 'eval_every' (integer expected)"))?,
            },
            stop: match j.get("stop") {
                None | Some(Json::Null) => StopRule::default(),
                Some(sj) => StopRule::from_json(sj)?,
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from JSON text.
    pub fn from_json_str(s: &str) -> anyhow::Result<Self> {
        let j = Json::parse(s).map_err(|e| anyhow::anyhow!("spec: {e}"))?;
        Self::from_json(&j)
    }

    /// Load a spec from a `--spec file.json`.
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read spec {}: {e}", path.display()))?;
        Self::from_json_str(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Write the spec as pretty JSON.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_pretty_string())
            .map_err(|e| anyhow::anyhow!("cannot write spec {}: {e}", path.display()))
    }
}

/// Make one filename component out of an arbitrary axis value (loader
/// dataset specs like `file:dir/t.tns` carry separators) — used by
/// [`ExperimentSpec::label`] and the harness CSV paths.
pub(crate) fn fs_component(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == '-' { c } else { '_' })
        .collect()
}

/// Serialize an [`AlgoConfig`] in the spec-JSON layout (shared between
/// [`ExperimentSpec::to_json`] and the sweep-spec algo axis).
pub(crate) fn algo_to_json(algo: &crate::engine::AlgoConfig) -> Json {
    Json::obj(vec![
        ("name", Json::Str(algo.name.clone())),
        ("compressor", Json::Str(algo.compressor.spec_string())),
        ("block_random", Json::Bool(algo.block_random)),
        ("tau", Json::Num(algo.tau as f64)),
        ("event_triggered", Json::Bool(algo.event_triggered)),
        ("momentum", algo.momentum.map(Json::Num).unwrap_or(Json::Null)),
        ("error_feedback", Json::Bool(algo.error_feedback)),
        ("rho", Json::Num(algo.rho)),
    ])
}

/// Parse the [`algo_to_json`] layout back into an [`AlgoConfig`].
/// Strict: unknown keys error with a did-you-mean hint.
pub(crate) fn algo_from_json(aj: &Json) -> anyhow::Result<crate::engine::AlgoConfig> {
    aj.ensure_known_keys(
        "algo",
        &[
            "name",
            "compressor",
            "block_random",
            "tau",
            "event_triggered",
            "momentum",
            "error_feedback",
            "rho",
        ],
    )?;
    Ok(crate::engine::AlgoConfig {
        name: aj.req_str("name")?.to_string(),
        compressor: crate::compress::Compressor::by_name(aj.req_str("compressor")?)?,
        block_random: aj
            .get("block_random")
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid 'algo.block_random'"))?,
        tau: aj.req_usize("tau")?,
        event_triggered: aj
            .get("event_triggered")
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid 'algo.event_triggered'"))?,
        momentum: match aj.get("momentum") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("invalid 'algo.momentum' (number or null expected)")
            })?),
        },
        error_feedback: aj
            .get("error_feedback")
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid 'algo.error_feedback'"))?,
        rho: aj.req_f64("rho")?,
    })
}

/// Fluent builder over [`ExperimentSpec`] (start with
/// [`ExperimentSpec::builder`]).
#[derive(Debug, Clone)]
pub struct ExperimentSpecBuilder {
    spec: ExperimentSpec,
}

macro_rules! setter {
    ($(#[$doc:meta])* $name:ident: $ty:ty) => {
        $(#[$doc])*
        pub fn $name(mut self, v: $ty) -> Self {
            self.spec.$name = v;
            self
        }
    };
}

impl ExperimentSpecBuilder {
    setter!(/// number of clients K
        k: usize);
    setter!(/// CP rank R
        rank: usize);
    setter!(/// fiber sample size |S|
        fiber_samples: usize);
    setter!(/// communication graph
        topology: Topology);
    setter!(/// learning rate γ
        gamma: f64);
    setter!(/// epochs to run
        epochs: usize);
    setter!(/// iterations per epoch
        iters_per_epoch: usize);
    setter!(/// master seed
        seed: u64);
    setter!(/// loss-estimator batch size
        eval_batch: usize);
    setter!(/// factor init scale
        init_scale: f32);
    setter!(/// simulated seconds per iteration
        sim_iter_s: f64);
    setter!(/// compute threads per gradient call
        compute_threads: usize);
    setter!(/// execution path
        driver: DriverKind);
    setter!(/// network fault envelope (`None` = ideal)
        fault: Option<FaultConfig>);
    setter!(/// mode-0 patient partitioner
        partitioner: Partitioner);
    setter!(/// consensus combiner for peer estimates
        aggregator: Aggregator);
    setter!(/// Byzantine-client schedule (`None` = all honest)
        adversary: Option<AdversarySchedule>);
    setter!(/// epochs between eval points
        eval_every: usize);

    /// Compute backend flag (`native`/`pjrt`).
    pub fn backend(mut self, b: &str) -> Self {
        self.spec.backend = b.to_string();
        self
    }

    /// Socket transport for the `node` driver (`tcp`/`uds`).
    pub fn transport(mut self, t: &str) -> Self {
        self.spec.transport = t.to_string();
        self
    }

    /// Stop early once the loss reaches this target.
    pub fn target_loss(mut self, l: f64) -> Self {
        self.spec.stop.target_loss = Some(l);
        self
    }

    /// Stop early once cumulative uplink bytes reach this budget.
    pub fn max_bytes(mut self, b: u64) -> Self {
        self.spec.stop.max_bytes = Some(b);
        self
    }

    /// Validate and produce the spec.
    pub fn build(self) -> anyhow::Result<ExperimentSpec> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Compressor;

    #[test]
    fn builder_round_trips_through_json() {
        let spec = ExperimentSpec::builder("tiny", Loss::Logit, AlgoConfig::cidertf(4))
            .k(8)
            .rank(4)
            .gamma(0.125)
            .seed(0xDEAD_BEEF_1234_5678)
            .driver(DriverKind::Sim)
            .fault(Some(FaultConfig::lossy(0.2)))
            .eval_every(2)
            .target_loss(1e-3)
            .max_bytes(1 << 30)
            .build()
            .unwrap();
        let text = spec.to_json().to_pretty_string();
        let back = ExperimentSpec::from_json_str(&text).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn scenario_string_front_end() {
        let spec =
            ExperimentSpec::from_scenario_str("cidertf:8@lossy:0.3@async", "synthetic", Loss::Ls)
                .unwrap();
        assert_eq!(spec.algo.tau, 8);
        assert_eq!(spec.driver, DriverKind::Async);
        assert!((spec.fault.as_ref().unwrap().drop_rate - 0.3).abs() < 1e-12);
        assert!(ExperimentSpec::from_scenario_str("nope", "synthetic", Loss::Ls).is_err());
    }

    #[test]
    fn validate_rejects_fault_on_lockstep_drivers() {
        let mut spec = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        spec.fault = Some(FaultConfig::lossy(0.1));
        assert!(spec.validate().is_err());
        spec.driver = DriverKind::Sim;
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn wrongly_typed_optional_fields_error() {
        // optional fields must not silently fall back to defaults when
        // present with the wrong type (e.g. quoted numbers)
        let base = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("eval_every".into(), Json::Str("5".into()));
        }
        assert!(ExperimentSpec::from_json(&j).is_err(), "quoted eval_every must error");

        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("stop".into(), Json::obj(vec![("target_loss", Json::Str("1e-3".into()))]));
        }
        assert!(ExperimentSpec::from_json(&j).is_err(), "quoted target_loss must error");

        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(a)) = m.get_mut("algo") {
                a.insert("momentum".into(), Json::Str("0.9".into()));
            }
        }
        assert!(ExperimentSpec::from_json(&j).is_err(), "quoted momentum must error");
    }

    #[test]
    fn every_registered_robustness_axis_round_trips() {
        let base = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        for name in crate::registry::adversaries().names() {
            let mut spec = base.clone();
            spec.adversary = crate::registry::adversaries().resolve(name).unwrap();
            let back = ExperimentSpec::from_json_str(&spec.to_json().to_string()).unwrap();
            assert_eq!(back, spec, "adversary '{name}'");
        }
        for name in crate::registry::aggregators().names() {
            let mut spec = base.clone();
            spec.aggregator = crate::registry::aggregators().resolve(name).unwrap();
            let back = ExperimentSpec::from_json_str(&spec.to_json().to_string()).unwrap();
            assert_eq!(back, spec, "aggregator '{name}'");
        }
        for name in crate::registry::partitioners().names() {
            let mut spec = base.clone();
            spec.partitioner = crate::registry::partitioners().resolve(name).unwrap();
            let back = ExperimentSpec::from_json_str(&spec.to_json().to_string()).unwrap();
            assert_eq!(back, spec, "partitioner '{name}'");
        }
    }

    #[test]
    fn every_driver_and_transport_round_trips() {
        // satellite for the deployment plane: the driver x transport grid
        // survives the JSON round trip exactly, for every registered name
        let base = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        for d in crate::registry::drivers().names() {
            for t in crate::registry::transports().names() {
                let mut spec = base.clone();
                spec.driver = DriverKind::from_name(d).unwrap();
                spec.transport = t.to_string();
                let back = ExperimentSpec::from_json_str(&spec.to_json().to_string()).unwrap();
                assert_eq!(back, spec, "driver '{d}' transport '{t}'");
            }
        }
        // pre-deployment-plane specs (no transport key) still load, as tcp
        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("transport");
        }
        assert_eq!(ExperimentSpec::from_json(&j).unwrap().transport, "tcp");
        // unknown transports fail at validate with a did-you-mean
        let mut spec = base.clone();
        spec.transport = "tpc".to_string();
        let err = format!("{:#}", spec.validate().unwrap_err());
        assert!(err.contains("did you mean 'tcp'"), "{err}");
    }

    #[test]
    fn node_driver_gates() {
        let mut spec = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        spec.driver = DriverKind::Node;
        assert!(spec.validate().is_ok());
        // real sockets cannot inject simulated faults
        spec.fault = Some(FaultConfig::lossy(0.1));
        assert!(spec.validate().is_err());
        spec.fault = None;
        // no node sees the global loss, so stopping rules are rejected
        spec.stop.target_loss = Some(1e-3);
        let err = format!("{:#}", spec.validate().unwrap_err());
        assert!(err.contains("early-stopping"), "{err}");
        spec.stop = StopRule::default();
        spec.adversary = Some(AdversarySchedule::sign_flip(0.2));
        assert!(spec.validate().is_err());
    }

    #[test]
    fn adversary_string_form_and_bad_axes_error() {
        // hand-written specs may name the adversary as a registry string
        let base = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("adversary".into(), Json::Str("sign_flip:0.4".into()));
        }
        let spec = ExperimentSpec::from_json(&j).unwrap();
        assert!((spec.adversary.unwrap().fraction - 0.4).abs() < 1e-12);
        // unknown axis names error through the registry (did-you-mean)
        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("aggregator".into(), Json::Str("trimed_mean:0.2".into()));
        }
        let err = format!("{:#}", ExperimentSpec::from_json(&j).unwrap_err());
        assert!(err.contains("trimmed_mean"), "{err}");
        let mut j = base.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("partitioner".into(), Json::Num(3.0));
        }
        assert!(ExperimentSpec::from_json(&j).is_err(), "non-string partitioner must error");
    }

    #[test]
    fn robustness_axes_extend_the_label_and_gate_drivers() {
        let mut spec = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        let plain = spec.label();
        spec.adversary = Some(AdversarySchedule::sign_flip(0.2));
        spec.aggregator = Aggregator::TrimmedMean(0.25);
        spec.partitioner = Partitioner::Skewed(1.5);
        let l = spec.label();
        assert!(l.starts_with(&plain), "{l}");
        assert!(l.contains("signflip0.2") && l.contains("trim0.25") && l.contains("skew1.5"), "{l}");
        assert!(!l.contains(':') && !l.contains('/'), "label must stay fs-safe: {l}");
        // Byzantine clients need a publish-intercepting driver
        spec.driver = DriverKind::Async;
        assert!(spec.validate().is_err());
        spec.driver = DriverKind::Sim;
        spec.fault = Some(FaultConfig::lossy(0.1));
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn adversary_seed_inherits_master_seed() {
        let mut spec = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        spec.seed = 99;
        spec.adversary = Some(AdversarySchedule::sign_flip(0.2));
        assert_eq!(spec.adversary_schedule().unwrap().seed, 99);
        assert_eq!(spec.to_train_config().adversary.unwrap().seed, 99);
        // an explicitly pinned seed is respected
        let mut pinned = AdversarySchedule::sign_flip(0.2);
        pinned.seed = 5;
        spec.adversary = Some(pinned);
        assert_eq!(spec.adversary_schedule().unwrap().seed, 5);
    }

    #[test]
    fn topk_compressor_round_trips() {
        let mut spec = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        spec.algo.compressor = Compressor::TopK { ratio: 16 };
        let back = ExperimentSpec::from_json_str(&spec.to_json().to_string()).unwrap();
        assert_eq!(back.algo.compressor, Compressor::TopK { ratio: 16 });
    }

    #[test]
    fn fault_seed_inheritance() {
        let mut spec = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        spec.driver = DriverKind::Sim;
        spec.seed = 99;
        spec.fault = Some(FaultConfig::lossy(0.5)); // default fault seed
        let net = spec.network_model();
        assert_eq!(net.name(), "faulty");
        spec.fault = Some(FaultConfig::lossy(0.5).with_seed(7));
        let _ = spec.network_model(); // explicit seed path also builds
    }

    #[test]
    fn train_config_lift_is_lossless() {
        let mut cfg = TrainConfig::new("synthetic", Loss::Ls, AlgoConfig::dpsgd());
        cfg.k = 5;
        cfg.gamma = 0.75;
        let spec = ExperimentSpec::from_train_config(&cfg, DriverKind::Sequential, None, "native");
        let back = spec.to_train_config();
        assert_eq!(back.k, 5);
        assert_eq!(back.gamma, 0.75);
        assert_eq!(back.algo, cfg.algo);
        assert_eq!(back.dataset, cfg.dataset);
    }
}
