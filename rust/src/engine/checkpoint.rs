//! Bit-exact checkpoint/resume for [`crate::engine::session::Session`].
//!
//! A checkpoint is one JSON file (schema [`CHECKPOINT_SCHEMA`]) holding
//! the full [`crate::engine::spec::ExperimentSpec`] plus every piece of
//! mutable run state:
//!
//! * per-client factors, momentum velocities, peer estimates `Â`,
//!   error-feedback residuals/shadows, the fiber-sampler RNG stream, and
//!   the comm/delivery ledgers,
//! * the shared block-sampler RNG stream and draw counter,
//! * the network model's per-link fault machines
//!   ([`crate::net::sim::NetworkModel::state_json`]),
//! * the virtual/wall clock and the metric points recorded so far.
//!
//! Everything derived deterministically from the spec (shards, graph,
//! eval samples, trigger schedule, static link traits) is rebuilt on
//! resume rather than stored. Matrices are serialized as IEEE-754 bit
//! patterns ([`crate::util::mat::Mat::encode_bits`]) and RNG words as
//! decimal strings, so a resumed run continues **bit-identically** —
//! asserted by `tests/session_api.rs` under both ideal and faulty
//! networks.

use std::path::Path;

use crate::engine::client::ClientState;
use crate::engine::metrics::MetricPoint;
use crate::engine::spec::ExperimentSpec;
use crate::util::json::Json;
use crate::util::mat::Mat;

/// Schema tag written into every checkpoint file.
pub const CHECKPOINT_SCHEMA: &str = "cidertf-checkpoint-v1";

/// Mid-run mutable state, as restored by a resume. Produced/consumed by
/// the session loop; opaque JSON blobs keep the client and network
/// layouts private to their owners.
#[derive(Debug, Clone)]
pub struct SessionState {
    /// next iteration index to execute
    pub t: usize,
    /// clock at the checkpoint (virtual seconds, or elapsed wall seconds)
    pub time_s: f64,
    /// shared block-sampler RNG stream
    pub sampler_rng: ([u64; 4], Option<f64>),
    /// shared block-sampler draw counter
    pub sampler_t: usize,
    /// network-model internal state (`Json::Null` for stateless models)
    pub net_model: Json,
    /// adversary internal state ([`crate::adversary::Adversary::state_json`];
    /// `Json::Null` for honest runs and pre-adversary checkpoints)
    pub adversary: Json,
    /// nnz of the dataset the run was training on — re-checked on
    /// resume so a changed/regenerated `file:`/`csv:` source fails
    /// loudly instead of silently voiding the bit-exact-resume
    /// guarantee (`None` in pre-v1.1 checkpoints)
    pub data_nnz: Option<u64>,
    /// content fingerprint of the dataset
    /// ([`crate::data::Dataset::fingerprint`]) — catches same-nnz edits
    /// the count alone would miss (`None` in pre-v1.1 checkpoints)
    pub data_fp: Option<u64>,
    /// metric points recorded so far
    pub points: Vec<MetricPoint>,
    /// per-client state blobs, in client-id order
    pub clients: Vec<Json>,
}

// ---- primitive encoders ----

use crate::util::rng::{state_from_json as rng_from_json, state_to_json as rng_json};

fn mat_json(m: &Mat) -> Json {
    Json::obj(vec![
        ("r", Json::Num(m.rows as f64)),
        ("c", Json::Num(m.cols as f64)),
        ("b", Json::Str(m.encode_bits())),
    ])
}

fn mat_from_json(j: &Json) -> anyhow::Result<Mat> {
    Mat::decode_bits(j.req_usize("r")?, j.req_usize("c")?, j.req_str("b")?)
}

fn opt_mat_json(m: Option<&Mat>) -> Json {
    m.map(mat_json).unwrap_or(Json::Null)
}

fn opt_mat_from_json(j: &Json) -> anyhow::Result<Option<Mat>> {
    match j {
        Json::Null => Ok(None),
        other => Ok(Some(mat_from_json(other)?)),
    }
}

fn assign_mat(slot: &mut Mat, new: Mat, what: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        new.rows == slot.rows && new.cols == slot.cols,
        "{what}: checkpoint shape {}x{} != expected {}x{}",
        new.rows,
        new.cols,
        slot.rows,
        slot.cols
    );
    *slot = new;
    Ok(())
}

fn point_json(p: &MetricPoint) -> Json {
    Json::obj(vec![
        ("epoch", Json::Num(p.epoch as f64)),
        ("iter", Json::Num(p.iter as f64)),
        ("time_s", Json::Num(p.time_s)),
        ("loss", Json::Num(p.loss)),
        ("bytes", Json::u64(p.bytes)),
        ("fms", p.fms.map(Json::Num).unwrap_or(Json::Null)),
    ])
}

fn point_from_json(j: &Json) -> anyhow::Result<MetricPoint> {
    Ok(MetricPoint {
        epoch: j.req_usize("epoch")?,
        iter: j.req_usize("iter")?,
        time_s: j.req_f64("time_s")?,
        loss: j.req_f64("loss")?,
        bytes: j.req_u64("bytes")?,
        fms: j.get("fms").and_then(Json::as_f64),
    })
}

// ---- client state ----

/// Serialize one client's mutable state.
pub(crate) fn snapshot_client(c: &ClientState) -> Json {
    let factors: Vec<Json> = c.factors.mats.iter().map(mat_json).collect();
    let momentum: Vec<Json> =
        c.momentum_mats().iter().map(|m| opt_mat_json(m.as_ref())).collect();
    let estimates = match &c.estimates {
        None => Json::Null,
        Some(est) => Json::Arr(
            est.snapshot_mats()
                .iter()
                .map(|slot| Json::Arr(slot.iter().map(|m| opt_mat_json(m.as_ref())).collect()))
                .collect(),
        ),
    };
    let ef: Vec<Json> =
        c.ef.iter().map(|e| opt_mat_json(e.as_ref().map(|e| &e.residual))).collect();
    let ef_shadow = match &c.ef_shadow {
        None => Json::Null,
        Some(mats) => Json::Arr(mats.iter().map(mat_json).collect()),
    };
    Json::obj(vec![
        ("factors", Json::Arr(factors)),
        ("momentum", Json::Arr(momentum)),
        ("estimates", estimates),
        ("ef", Json::Arr(ef)),
        ("ef_shadow", ef_shadow),
        ("fiber_rng", rng_json(c.fiber_sampler.rng_state())),
        (
            "ledger",
            Json::obj(vec![
                ("bytes", Json::u64(c.ledger.bytes)),
                ("messages", Json::u64(c.ledger.messages)),
                ("triggered", Json::u64(c.ledger.triggered)),
                ("suppressed", Json::u64(c.ledger.suppressed)),
            ]),
        ),
        (
            "net",
            Json::obj(vec![
                ("delivered", Json::u64(c.net.delivered)),
                ("dropped", Json::u64(c.net.dropped)),
                ("stale", Json::u64(c.net.stale)),
                ("offline_rounds", Json::u64(c.net.offline_rounds)),
                ("adversarial", Json::u64(c.net.adversarial)),
            ]),
        ),
    ])
}

/// Restore a [`snapshot_client`] blob into a freshly-built client
/// (shapes validated against the deterministic construction).
pub(crate) fn restore_client(c: &mut ClientState, j: &Json) -> anyhow::Result<()> {
    // factors
    let fj = j.req_array("factors")?;
    anyhow::ensure!(
        fj.len() == c.factors.mats.len(),
        "checkpoint has {} factor modes, expected {}",
        fj.len(),
        c.factors.mats.len()
    );
    for (m, (slot, mj)) in c.factors.mats.iter_mut().zip(fj.iter()).enumerate() {
        assign_mat(slot, mat_from_json(mj)?, &format!("factor mode {m}"))?;
    }

    // momentum velocities
    let mj = j.req_array("momentum")?;
    let moms = c.momentum_mats_mut();
    anyhow::ensure!(mj.len() == moms.len(), "momentum mode count mismatch");
    for (m, (slot, v)) in moms.iter_mut().zip(mj.iter()).enumerate() {
        match (slot, opt_mat_from_json(v)?) {
            (None, None) => {}
            (Some(slot), Some(new)) => assign_mat(slot, new, &format!("momentum mode {m}"))?,
            _ => anyhow::bail!("momentum enablement mismatch at mode {m}"),
        }
    }

    // peer estimates
    match (c.estimates.as_mut(), j.get("estimates")) {
        (None, None | Some(Json::Null)) => {}
        (Some(est), Some(Json::Arr(slots))) => {
            let mut mats: Vec<Vec<Option<Mat>>> = Vec::with_capacity(slots.len());
            for slot in slots {
                let modes = slot
                    .as_array()
                    .ok_or_else(|| anyhow::anyhow!("bad estimates slot"))?;
                mats.push(
                    modes.iter().map(opt_mat_from_json).collect::<anyhow::Result<Vec<_>>>()?,
                );
            }
            est.restore_mats(mats)?;
        }
        _ => anyhow::bail!("estimate presence mismatch between checkpoint and spec"),
    }

    // error feedback residuals
    let efj = j.req_array("ef")?;
    anyhow::ensure!(efj.len() == c.ef.len(), "error-feedback mode count mismatch");
    for (m, (slot, v)) in c.ef.iter_mut().zip(efj.iter()).enumerate() {
        match (slot, opt_mat_from_json(v)?) {
            (None, None) => {}
            (Some(ef), Some(new)) => {
                assign_mat(&mut ef.residual, new, &format!("ef residual mode {m}"))?
            }
            _ => anyhow::bail!("error-feedback enablement mismatch at mode {m}"),
        }
    }

    // error feedback shadow factors
    match j.get("ef_shadow") {
        None | Some(Json::Null) => c.ef_shadow = None,
        Some(Json::Arr(mats)) => {
            c.ef_shadow =
                Some(mats.iter().map(mat_from_json).collect::<anyhow::Result<Vec<_>>>()?);
        }
        Some(_) => anyhow::bail!("bad 'ef_shadow'"),
    }

    // fiber sampler stream
    c.fiber_sampler.restore_rng(rng_from_json(
        j.get("fiber_rng").ok_or_else(|| anyhow::anyhow!("missing 'fiber_rng'"))?,
    )?);

    // ledgers
    let lj = j.get("ledger").ok_or_else(|| anyhow::anyhow!("missing 'ledger'"))?;
    c.ledger.bytes = lj.req_u64("bytes")?;
    c.ledger.messages = lj.req_u64("messages")?;
    c.ledger.triggered = lj.req_u64("triggered")?;
    c.ledger.suppressed = lj.req_u64("suppressed")?;
    let nj = j.get("net").ok_or_else(|| anyhow::anyhow!("missing 'net'"))?;
    c.net.delivered = nj.req_u64("delivered")?;
    c.net.dropped = nj.req_u64("dropped")?;
    c.net.stale = nj.req_u64("stale")?;
    c.net.offline_rounds = nj.req_u64("offline_rounds")?;
    // absent in checkpoints written before the adversary plane existed
    c.net.adversarial = nj.get("adversarial").and_then(Json::as_u64).unwrap_or(0);
    Ok(())
}

// ---- whole-file layer ----

fn state_to_json(st: &SessionState) -> Json {
    Json::obj(vec![
        ("t", Json::Num(st.t as f64)),
        ("time_s", Json::Num(st.time_s)),
        ("sampler_rng", rng_json(st.sampler_rng)),
        ("sampler_t", Json::Num(st.sampler_t as f64)),
        ("net_model", st.net_model.clone()),
        ("adversary", st.adversary.clone()),
        ("data_nnz", st.data_nnz.map(Json::u64).unwrap_or(Json::Null)),
        ("data_fp", st.data_fp.map(Json::u64).unwrap_or(Json::Null)),
        ("points", Json::Arr(st.points.iter().map(point_json).collect())),
        ("clients", Json::Arr(st.clients.clone())),
    ])
}

fn state_from_json(j: &Json) -> anyhow::Result<SessionState> {
    Ok(SessionState {
        t: j.req_usize("t")?,
        time_s: j.req_f64("time_s")?,
        sampler_rng: rng_from_json(
            j.get("sampler_rng").ok_or_else(|| anyhow::anyhow!("missing 'sampler_rng'"))?,
        )?,
        sampler_t: j.req_usize("sampler_t")?,
        net_model: j.get("net_model").cloned().unwrap_or(Json::Null),
        adversary: j.get("adversary").cloned().unwrap_or(Json::Null),
        data_nnz: j.get("data_nnz").and_then(Json::as_u64),
        data_fp: j.get("data_fp").and_then(Json::as_u64),
        points: j
            .req_array("points")?
            .iter()
            .map(point_from_json)
            .collect::<anyhow::Result<Vec<_>>>()?,
        clients: j.req_array("clients")?.to_vec(),
    })
}

/// Atomically write a checkpoint (temp file + rename, like BENCH.json):
/// an interrupted writer can never leave a truncated checkpoint behind.
pub fn write_checkpoint(
    path: &Path,
    spec: &ExperimentSpec,
    state: &SessionState,
) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let top = Json::obj(vec![
        ("schema", Json::Str(CHECKPOINT_SCHEMA.to_string())),
        ("spec", spec.to_json()),
        ("state", state_to_json(state)),
    ]);
    let tmp = path.with_extension("ckpt.tmp");
    std::fs::write(&tmp, top.to_string())
        .map_err(|e| anyhow::anyhow!("cannot write checkpoint {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("cannot move checkpoint into place at {}: {e}", path.display()))?;
    Ok(())
}

/// Read a checkpoint back into its spec + mutable state.
pub fn read_checkpoint(path: &Path) -> anyhow::Result<(ExperimentSpec, SessionState)> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read checkpoint {}: {e}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))?;
    let schema = j.req_str("schema")?;
    anyhow::ensure!(
        schema == CHECKPOINT_SCHEMA,
        "unsupported checkpoint schema '{schema}' (want {CHECKPOINT_SCHEMA})"
    );
    let spec = ExperimentSpec::from_json(
        j.get("spec").ok_or_else(|| anyhow::anyhow!("missing 'spec'"))?,
    )?;
    let state = state_from_json(
        j.get("state").ok_or_else(|| anyhow::anyhow!("missing 'state'"))?,
    )?;
    Ok((spec, state))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mat_bits_round_trip_exactly() {
        let mut rng = Rng::new(3);
        let mut m = Mat::rand_normal(7, 5, 3.0, &mut rng);
        m.data[0] = -0.0;
        m.data[1] = f32::MIN_POSITIVE / 2.0; // subnormal
        let j = mat_json(&m);
        let back = mat_from_json(&j).unwrap();
        assert_eq!(back.rows, 7);
        for (a, b) in m.data.iter().zip(back.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rng_state_round_trips_and_continues() {
        let mut r = Rng::new(42);
        for _ in 0..17 {
            r.next_u64();
        }
        let _ = r.normal(); // populate the Box-Muller spare
        let snap = r.state();
        let j = rng_json(snap);
        let (words, spare) = rng_from_json(&j).unwrap();
        let mut restored = Rng::from_state(words, spare);
        for _ in 0..32 {
            assert_eq!(r.next_u64(), restored.next_u64());
        }
        assert_eq!(r.normal(), restored.normal());
    }

    #[test]
    fn point_round_trip() {
        let p = MetricPoint {
            epoch: 3,
            iter: 450,
            time_s: 12.125,
            loss: 1.0625e-3,
            bytes: 123_456_789,
            fms: Some(0.875),
        };
        let q = point_from_json(&point_json(&p)).unwrap();
        assert_eq!(q.epoch, p.epoch);
        assert_eq!(q.time_s, p.time_s);
        assert_eq!(q.loss, p.loss);
        assert_eq!(q.bytes, p.bytes);
        assert_eq!(q.fms, p.fms);
    }
}
