//! The one experiment pipeline: spec → [`Session`] → observers.
//!
//! A [`Session`] consumes a declarative
//! [`ExperimentSpec`](crate::engine::spec::ExperimentSpec), resolves
//! every named axis through the [`crate::registry`] tables, runs the
//! selected [`DriverKind`] execution path, and streams typed
//! [`SessionEvent`]s to any number of [`Observer`]s. What used to be
//! engine-internal bookkeeping — CSV dumps, console progress, BENCH.json
//! appending, a JSONL progress stream — are now independent observers
//! ([`CsvObserver`], [`ConsoleObserver`], [`BenchJsonObserver`],
//! [`JsonlObserver`]).
//!
//! Long runs survive restarts: [`Session::checkpoint_every`] writes a
//! bit-exact state file at epoch boundaries
//! (see [`crate::engine::checkpoint`]) and [`Session::resume_from`]
//! continues a run with bit-identical results, under both ideal and
//! faulty networks (test-asserted).
//!
//! The unified round loop here subsumes the old `engine::train` and
//! `net::driver::train_sim` bodies — with the ideal network and a wall
//! clock it performs exactly the float operations of the former, with a
//! `NetworkModel` and the virtual clock exactly those of the latter —
//! and both remain as thin deprecated shims over this module.

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

use crate::engine::checkpoint::{self, SessionState};
use crate::engine::metrics::{MetricPoint, RunRecord};
use crate::engine::spec::ExperimentSpec;
use crate::engine::{
    apply_error_feedback, assemble_global, build_clients, consensus_phase, finalize_record,
    publish_phase, record_point, TrainConfig, TrainOutcome,
};
use crate::factor::FactorSet;
use crate::gossip::Message;
use crate::net::driver::DriverKind;
use crate::net::sim::{self, NetworkModel, VirtualClock};
use crate::runtime::{ComputeBackend, NativeOrPjrt};
use crate::sched::BlockSampler;
use crate::data::Dataset;
use crate::topology::Graph;
use crate::util::benchkit::{append_bench_json, fmt_bytes, BenchRun, Stats};
use crate::util::json::Json;

/// What kind of network misbehaviour a [`SessionEvent::NetFault`]
/// reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetFaultKind {
    /// a published delta was lost on the directed link `from -> to`
    Dropped {
        /// sending client id
        from: usize,
        /// receiving client id
        to: usize,
    },
    /// `client` was churned out for this round (no compute, no traffic)
    Offline {
        /// the offline client id
        client: usize,
    },
}

/// Typed events a running [`Session`] emits to its [`Observer`]s, in
/// order: one `RunStart`, then per-iteration `RoundEnd` (with
/// `CommBytes`/`NetFault` interleaved on communicating rounds),
/// `EvalPoint` at each eval cadence, `Checkpoint` after each state file
/// is written, and exactly one `RunEnd`. An iteration's `RoundEnd`
/// precedes any `EvalPoint`/`Checkpoint` it triggers, so interval
/// counters keyed off `RoundEnd` include the evaluating iteration
/// itself.
#[derive(Debug, Clone)]
pub enum SessionEvent {
    /// the run is configured and about to start
    RunStart {
        /// the resolved spec as JSON (a config summary for legacy-shim
        /// runs that have no full spec)
        spec: Json,
    },
    /// one training iteration finished
    RoundEnd {
        /// iteration index
        t: usize,
        /// clock at the end of the iteration (wall or virtual seconds)
        time_s: f64,
    },
    /// uplink traffic happened on a communicating iteration
    CommBytes {
        /// iteration index
        t: usize,
        /// bytes put on the wire this iteration (all clients)
        round_bytes: u64,
        /// cumulative uplink bytes so far
        total_bytes: u64,
    },
    /// the network model dropped a delta or took a client offline
    NetFault {
        /// iteration index
        t: usize,
        /// what happened
        kind: NetFaultKind,
    },
    /// a Byzantine client corrupted its published delta before broadcast
    AdversarialAct {
        /// iteration index
        t: usize,
        /// the Byzantine client id
        client: usize,
        /// the mode whose delta was corrupted
        mode: usize,
        /// the attack's registry name (`sign_flip`, `scaled_noise`, ...)
        kind: &'static str,
    },
    /// a metric point was recorded
    EvalPoint {
        /// the point (epoch, iter, time, loss, bytes, fms)
        point: MetricPoint,
    },
    /// a checkpoint file was written
    Checkpoint {
        /// next iteration index stored in the checkpoint
        t: usize,
        /// where it was written
        path: PathBuf,
    },
    /// the run finished (completed, stopped early, or diverged)
    RunEnd {
        /// the final run record (points, ledgers, delivery stats)
        record: RunRecord,
    },
}

/// Receives [`SessionEvent`]s from a running [`Session`]. Observers
/// cannot perturb the *results* — any combination of them leaves the
/// factors bit-identical — but a failing observer (e.g. an unwritable
/// CSV destination) aborts the run with its error rather than silently
/// losing output.
pub trait Observer {
    /// Handle one event. Called synchronously from the training loop —
    /// keep it cheap on `RoundEnd`. Returning an error aborts the run.
    fn on_event(&mut self, event: &SessionEvent) -> anyhow::Result<()>;
}

/// When and where [`Session`] writes checkpoints.
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// checkpoint file (atomically replaced on each write)
    pub path: PathBuf,
    /// write every this-many epochs (also at early stops and run end)
    pub every_epochs: usize,
}

// ---------------------------------------------------------------------
// Observers
// ---------------------------------------------------------------------

/// Prints eval points and a final summary to stdout (the `cidertf train`
/// progress output).
#[derive(Debug, Clone, Default)]
pub struct ConsoleObserver;

impl Observer for ConsoleObserver {
    fn on_event(&mut self, event: &SessionEvent) -> anyhow::Result<()> {
        match event {
            SessionEvent::EvalPoint { point: p } => {
                println!(
                    "epoch {:>3}  t={:>7.1}s  loss={:.6e}  uplink={}",
                    p.epoch,
                    p.time_s,
                    p.loss,
                    fmt_bytes(p.bytes as f64)
                );
            }
            SessionEvent::Checkpoint { t, path } => {
                println!("checkpoint @ iter {t} -> {}", path.display());
            }
            SessionEvent::RunEnd { record } => {
                println!(
                    "done: final loss {:.6e}, wall {:.1}s, uplink {}, msgs {} (triggered {}, suppressed {})",
                    record.final_loss(),
                    record.wall_s,
                    fmt_bytes(record.total.bytes as f64),
                    record.total.messages,
                    record.total.triggered,
                    record.total.suppressed
                );
                let n = &record.net;
                if n.dropped + n.stale + n.offline_rounds > 0 || n.delivered > 0 {
                    println!(
                        "network: delivered {}, dropped {} ({:.1}% loss), stale {}, offline rounds {}",
                        n.delivered,
                        n.dropped,
                        100.0 * n.drop_fraction(),
                        n.stale,
                        n.offline_rounds
                    );
                }
                if n.adversarial > 0 {
                    println!("adversary: {} corrupted payloads", n.adversarial);
                }
            }
            _ => {}
        }
        Ok(())
    }
}

/// Writes the final [`RunRecord`] as a CSV curve on `RunEnd` (what the
/// harness used to do inline). A write failure aborts the run with an
/// error — figure regeneration must not "succeed" with no artifacts.
#[derive(Debug, Clone)]
pub struct CsvObserver {
    path: PathBuf,
}

impl CsvObserver {
    /// CSV destination (parent directories are created).
    pub fn new(path: impl Into<PathBuf>) -> Self {
        CsvObserver { path: path.into() }
    }
}

impl Observer for CsvObserver {
    fn on_event(&mut self, event: &SessionEvent) -> anyhow::Result<()> {
        if let SessionEvent::RunEnd { record } = event {
            record
                .write_csv(&self.path)
                .map_err(|e| anyhow::anyhow!("cannot write {}: {e:#}", self.path.display()))?;
        }
        Ok(())
    }
}

/// Streams run progress as JSON lines: one `run_start` line with the
/// full spec, one `eval` line per metric point (carrying round/fault
/// counters for the interval since the previous point), `checkpoint`
/// lines, and a final `run_end` line. Each line is flushed, so the file
/// tails cleanly while a long faulty-network run is in flight. The file
/// is opened in **append** mode — a resumed run continues the same
/// stream after its own `run_start` marker instead of erasing the
/// pre-crash history. I/O failures abort the run.
#[derive(Debug)]
pub struct JsonlObserver {
    path: PathBuf,
    out: Option<std::io::BufWriter<std::fs::File>>,
    /// per-interval counters, reset after every `eval` line
    rounds: u64,
    dropped: u64,
    offline: u64,
    adversarial: u64,
}

impl JsonlObserver {
    /// JSONL destination (parent directories are created, lines appended
    /// starting at `RunStart`).
    pub fn new(path: impl Into<PathBuf>) -> Self {
        JsonlObserver {
            path: path.into(),
            out: None,
            rounds: 0,
            dropped: 0,
            offline: 0,
            adversarial: 0,
        }
    }

    fn write_line(&mut self, line: Json) -> anyhow::Result<()> {
        if self.out.is_none() {
            if let Some(dir) = self.path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            let f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&self.path)
                .map_err(|e| {
                    anyhow::anyhow!("jsonl observer: cannot open {}: {e}", self.path.display())
                })?;
            self.out = Some(std::io::BufWriter::new(f));
        }
        let w = self.out.as_mut().expect("jsonl writer just opened");
        writeln!(w, "{line}")
            .and_then(|_| w.flush())
            .map_err(|e| anyhow::anyhow!("jsonl observer: write to {} failed: {e}", self.path.display()))
    }
}

impl Observer for JsonlObserver {
    fn on_event(&mut self, event: &SessionEvent) -> anyhow::Result<()> {
        match event {
            SessionEvent::RunStart { spec } => {
                self.write_line(Json::obj(vec![
                    ("event", Json::Str("run_start".into())),
                    ("spec", spec.clone()),
                ]))?;
            }
            SessionEvent::RoundEnd { .. } => self.rounds += 1,
            SessionEvent::NetFault { kind, .. } => match kind {
                NetFaultKind::Dropped { .. } => self.dropped += 1,
                NetFaultKind::Offline { .. } => self.offline += 1,
            },
            SessionEvent::AdversarialAct { .. } => self.adversarial += 1,
            SessionEvent::CommBytes { .. } => {}
            SessionEvent::EvalPoint { point: p } => {
                let line = Json::obj(vec![
                    ("event", Json::Str("eval".into())),
                    ("epoch", Json::Num(p.epoch as f64)),
                    ("iter", Json::Num(p.iter as f64)),
                    ("time_s", Json::Num(p.time_s)),
                    ("loss", Json::Num(p.loss)),
                    ("bytes", Json::u64(p.bytes)),
                    ("fms", p.fms.map(Json::Num).unwrap_or(Json::Null)),
                    ("rounds", Json::u64(self.rounds)),
                    ("dropped", Json::u64(self.dropped)),
                    ("offline", Json::u64(self.offline)),
                    ("adversarial", Json::u64(self.adversarial)),
                ]);
                self.rounds = 0;
                self.dropped = 0;
                self.offline = 0;
                self.adversarial = 0;
                self.write_line(line)?;
            }
            SessionEvent::Checkpoint { t, path } => {
                self.write_line(Json::obj(vec![
                    ("event", Json::Str("checkpoint".into())),
                    ("t", Json::Num(*t as f64)),
                    ("path", Json::Str(path.display().to_string())),
                ]))?;
            }
            SessionEvent::RunEnd { record } => {
                self.write_line(Json::obj(vec![
                    ("event", Json::Str("run_end".into())),
                    ("final_loss", Json::Num(record.final_loss())),
                    ("wall_s", Json::Num(record.wall_s)),
                    ("bytes", Json::u64(record.total.bytes)),
                    ("delivered", Json::u64(record.net.delivered)),
                    ("dropped", Json::u64(record.net.dropped)),
                ]))?;
            }
        }
        Ok(())
    }
}

/// Appends the finished run to BENCH.json (schema
/// [`crate::util::benchkit::BENCH_SCHEMA`]), so experiment runs land in
/// the same perf ledger as the micro benchmarks. Wall-clock drivers
/// (seq/par) record a real end-to-end timing entry; the simulated
/// drivers (sim/async) report *virtual* seconds, which must not pose as
/// machine timings — those runs record a `virtual_s` derived scalar and
/// no timing entry.
#[derive(Debug, Clone)]
pub struct BenchJsonObserver {
    path: PathBuf,
    name: String,
    /// driver name captured from `RunStart` (decides wall vs virtual)
    driver: Option<String>,
}

impl BenchJsonObserver {
    /// Append to `path` under benchmark name `name` (typically the
    /// spec's [`ExperimentSpec::label`]).
    pub fn new(path: impl Into<PathBuf>, name: impl Into<String>) -> Self {
        BenchJsonObserver { path: path.into(), name: name.into(), driver: None }
    }
}

impl Observer for BenchJsonObserver {
    fn on_event(&mut self, event: &SessionEvent) -> anyhow::Result<()> {
        match event {
            SessionEvent::RunStart { spec } => {
                self.driver = spec.get("driver").and_then(Json::as_str).map(str::to_string);
            }
            SessionEvent::RunEnd { record } => {
                let virtual_time =
                    matches!(self.driver.as_deref(), Some("sim") | Some("async"));
                let mut derived = vec![
                    ("final_loss".to_string(), record.final_loss()),
                    ("uplink_bytes".to_string(), record.total.bytes as f64),
                ];
                let benches = if virtual_time {
                    derived.push(("virtual_s".to_string(), record.wall_s));
                    Vec::new()
                } else {
                    let ns = record.wall_s * 1e9;
                    vec![Stats {
                        name: format!("session_e2e_{}", self.name),
                        iters: 1,
                        mean_ns: ns,
                        p50_ns: ns,
                        p95_ns: ns,
                        min_ns: ns,
                    }]
                };
                let run = BenchRun { mode: "session".to_string(), benches, derived };
                append_bench_json(&self.path, &run)?;
            }
            _ => {}
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// The session
// ---------------------------------------------------------------------

/// Runs one [`ExperimentSpec`] end to end. Build with [`Session::new`]
/// (or [`Session::resume_from`] a checkpoint), attach observers and a
/// checkpoint policy builder-style, then call [`Session::run`] — or
/// [`Session::run_on`] to supply the dataset/backend yourself (what the
/// harness does to share datasets across a sweep).
pub struct Session {
    spec: ExperimentSpec,
    observers: Vec<Box<dyn Observer>>,
    checkpoint: Option<CheckpointPolicy>,
    resume_state: Option<SessionState>,
}

impl Session {
    /// A session for `spec` with no observers attached.
    pub fn new(spec: ExperimentSpec) -> Self {
        Session { spec, observers: Vec::new(), checkpoint: None, resume_state: None }
    }

    /// Load the spec from a `--spec` JSON file.
    pub fn from_spec_file(path: &std::path::Path) -> anyhow::Result<Self> {
        Ok(Self::new(ExperimentSpec::load(path)?))
    }

    /// Continue a checkpointed run: restores the spec and the full
    /// mutable state, producing results bit-identical to the
    /// uninterrupted run (seq/sim drivers only).
    pub fn resume_from(path: &std::path::Path) -> anyhow::Result<Self> {
        let (spec, state) = checkpoint::read_checkpoint(path)?;
        Ok(Session { spec, observers: Vec::new(), checkpoint: None, resume_state: Some(state) })
    }

    /// Attach an observer (builder-style; any number may be attached).
    pub fn observe(mut self, observer: Box<dyn Observer>) -> Self {
        self.observers.push(observer);
        self
    }

    /// Write a checkpoint to `path` every `every_epochs` epochs (and at
    /// early stops / run end). Requires the seq or sim driver.
    pub fn checkpoint_every(mut self, path: impl Into<PathBuf>, every_epochs: usize) -> Self {
        self.checkpoint = Some(CheckpointPolicy { path: path.into(), every_epochs });
        self
    }

    /// The spec this session will run.
    pub fn spec(&self) -> &ExperimentSpec {
        &self.spec
    }

    /// Mutable spec access — the supported way to extend a resumed run
    /// (e.g. raise `epochs` after loading a finished checkpoint).
    pub fn spec_mut(&mut self) -> &mut ExperimentSpec {
        &mut self.spec
    }

    /// Generate the spec's dataset, construct its backend, and run.
    pub fn run(&mut self) -> anyhow::Result<TrainOutcome> {
        let data = self.spec.dataset_data()?;
        let mut backend = NativeOrPjrt::from_flag(&self.spec.backend)?;
        self.run_on(&data, backend.as_mut(), None)
    }

    /// Run on a caller-provided dataset and backend (the backend is
    /// ignored by the `par` driver, which builds one per thread from the
    /// spec's backend flag).
    pub fn run_on(
        &mut self,
        data: &Dataset,
        backend: &mut dyn ComputeBackend,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        self.spec.validate()?;
        match self.spec.driver {
            DriverKind::Sequential | DriverKind::Sim => {
                let wall = self.spec.driver == DriverKind::Sequential;
                let cfg = self.spec.to_train_config();
                let mut net =
                    if wall { sim::ideal() } else { self.spec.network_model() };
                let mut hooks = Hooks {
                    observers: &mut self.observers,
                    eval_every: self.spec.eval_every,
                    target_loss: self.spec.stop.target_loss,
                    max_bytes: self.spec.stop.max_bytes,
                    checkpoint: self.checkpoint.as_ref(),
                    spec: Some(&self.spec),
                    resume: self.resume_state.as_ref(),
                };
                run_loop(&cfg, data, backend, net.as_mut(), wall, fms_reference, &mut hooks)
            }
            DriverKind::Parallel => {
                self.reject_unsupported_on_delegated()?;
                let cfg = self.spec.to_train_config();
                let flag = self.spec.backend.clone();
                let out = crate::net::parallel::train_parallel(
                    &cfg,
                    data,
                    |_| NativeOrPjrt::from_flag(&flag),
                    fms_reference,
                )?;
                self.emit_outcome(&out)?;
                Ok(out)
            }
            DriverKind::Async => {
                self.reject_unsupported_on_delegated()?;
                let cfg = self.spec.to_train_config();
                let mut net = self.spec.network_model();
                let out = crate::net::async_gossip::train_async(
                    &cfg,
                    data,
                    backend,
                    net.as_mut(),
                    fms_reference,
                )?;
                self.emit_outcome(&out)?;
                Ok(out)
            }
            DriverKind::Node => anyhow::bail!(
                "spec driver is 'node': each client runs as its own OS process over \
                 real sockets — launch with 'cidertf fleet spawn --config <fleet.json>' \
                 (or one 'cidertf node --config <fleet.json> --id <k>' per process), \
                 not through an in-process Session"
            ),
        }
    }

    /// Coarse event stream for the delegated drivers (par/async), which
    /// run to completion internally: start, one `EvalPoint` per recorded
    /// point, end.
    fn emit_outcome(&mut self, out: &TrainOutcome) -> anyhow::Result<()> {
        let spec_json = self.spec.to_json();
        let obs = &mut self.observers;
        let mut send = |ev: SessionEvent| -> anyhow::Result<()> {
            for o in obs.iter_mut() {
                o.on_event(&ev)?;
            }
            Ok(())
        };
        send(SessionEvent::RunStart { spec: spec_json })?;
        for p in &out.record.points {
            send(SessionEvent::EvalPoint { point: p.clone() })?;
        }
        send(SessionEvent::RunEnd { record: out.record.clone() })
    }

    /// The delegated drivers (par/async) run their loops internally and
    /// cannot honor mid-run session features — reject rather than
    /// silently ignore them.
    fn reject_unsupported_on_delegated(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.checkpoint.is_none() && self.resume_state.is_none(),
            "checkpoint/resume requires the seq or sim driver"
        );
        anyhow::ensure!(
            self.spec.stop == crate::engine::spec::StopRule::default(),
            "stopping rules (target_loss/max_bytes) require the seq or sim driver"
        );
        anyhow::ensure!(
            self.spec.eval_every == 1,
            "eval_every > 1 requires the seq or sim driver"
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------
// The unified round loop
// ---------------------------------------------------------------------

/// Loop wiring beyond the bare `TrainConfig`: observers, eval cadence,
/// stop rules, checkpoint policy, resume state.
pub(crate) struct Hooks<'a> {
    pub observers: &'a mut [Box<dyn Observer>],
    pub eval_every: usize,
    pub target_loss: Option<f64>,
    pub max_bytes: Option<u64>,
    pub checkpoint: Option<&'a CheckpointPolicy>,
    pub spec: Option<&'a ExperimentSpec>,
    pub resume: Option<&'a SessionState>,
}

impl Hooks<'_> {
    /// No observers, default cadence, no stop rules — the legacy-shim
    /// configuration.
    pub(crate) fn none() -> Hooks<'static> {
        Hooks {
            observers: &mut [],
            eval_every: 1,
            target_loss: None,
            max_bytes: None,
            checkpoint: None,
            spec: None,
            resume: None,
        }
    }

    fn emit(&mut self, ev: SessionEvent) -> anyhow::Result<()> {
        for o in self.observers.iter_mut() {
            o.on_event(&ev)?;
        }
        Ok(())
    }
}

/// One lock-step training loop for both in-process execution (ideal
/// network + wall clock — the old `engine::train`) and the synchronous
/// network simulator (arbitrary `NetworkModel` + virtual clock — the
/// old `train_sim`). Per iteration `t`:
///
/// 1. an online mask is drawn — churned-out clients skip the round,
/// 2. online clients take their local SGD/momentum step(s),
/// 3. on communication rounds, payloads from online clients go through
///    the shared publish phase (same trigger, compressor, and uplink
///    ledger on every path), then each neighbor message is subjected to
///    `net.delivers`; survivors update `Â` and their latency is charged
///    to the barrier,
/// 4. online clients run the consensus step,
/// 5. the clock advances (virtual mode) by the slowest online client's
///    compute time plus the slowest surviving message.
///
/// With [`crate::net::sim::IdealNetwork`] every mask is all-true and
/// every message survives instantly, so the float operations reduce
/// exactly to the classic engine loop — bit-identical factors (asserted
/// in `tests/network_sim.rs`).
pub(crate) fn run_loop(
    cfg: &TrainConfig,
    data: &Dataset,
    backend: &mut dyn ComputeBackend,
    net: &mut dyn NetworkModel,
    wall_time: bool,
    fms_reference: Option<&FactorSet>,
    hooks: &mut Hooks<'_>,
) -> anyhow::Result<TrainOutcome> {
    let d_order = data.tensor.dims.len();
    anyhow::ensure!(cfg.rank >= 1 && cfg.k >= 1 && cfg.algo.tau >= 1);
    backend.set_threads(cfg.compute_threads);
    let graph = Graph::build(cfg.topology, cfg.k)?;
    let decentralized = cfg.k > 1;
    let mut clients = build_clients(cfg, data, &graph);
    for c in clients.iter() {
        if let Some(est) = c.estimates.as_ref() {
            crate::util::invariant::estimate_slots_aligned(c.id, &est.peers, &graph.neighbors[c.id]);
        }
    }

    // Byzantine plane: the schedule picks the static corrupt subset, the
    // built adversary mutates payloads at publish time. A sentinel seed
    // inherits the run seed (specs materialize this in to_train_config;
    // direct TrainConfig users get the same rule here).
    let mut adversary = cfg.adversary.clone().map(|mut sched| {
        sched.inherit_seed(cfg.seed);
        (sched.adversarial_clients(cfg.k), sched.build())
    });
    let adv_kind = adversary.as_ref().map(|(_, a)| a.kind_name());

    let mut block_sampler = BlockSampler::new(d_order, cfg.seed, true);
    let trigger = cfg.trigger_schedule();
    let all_modes: Vec<usize> = (0..d_order).collect();
    let mut clock = VirtualClock::default();
    // lint: allow(wall-clock) — seq-driver wall timing only; it feeds the
    // time_s/wall_s reporting fields, never a deterministic aggregate
    #[allow(clippy::disallowed_methods)]
    let start = Instant::now();
    let mut wall_offset = 0.0f64;

    let mut points: Vec<MetricPoint> = Vec::with_capacity(cfg.epochs + 1);
    let mut start_t = 0usize;

    if let Some(st) = hooks.resume {
        anyhow::ensure!(
            st.clients.len() == clients.len(),
            "checkpoint has {} clients, this spec builds {}",
            st.clients.len(),
            clients.len()
        );
        // a regenerated/edited file: or csv: source would silently void
        // the bit-exact-resume guarantee — fail loudly instead
        if let Some(nnz) = st.data_nnz {
            anyhow::ensure!(
                nnz == data.tensor.nnz() as u64,
                "checkpoint was taken on a dataset with {nnz} nonzeros, \
                 the current one has {} — the data source changed since \
                 the checkpoint was written",
                data.tensor.nnz()
            );
        }
        if let Some(fp) = st.data_fp {
            anyhow::ensure!(
                fp == data.fingerprint(),
                "dataset content fingerprint mismatch — the data source \
                 changed since the checkpoint was written"
            );
        }
        for (c, cj) in clients.iter_mut().zip(st.clients.iter()) {
            checkpoint::restore_client(c, cj)?;
        }
        block_sampler.restore(st.sampler_rng, st.sampler_t);
        net.restore_state(&st.net_model)?;
        if let Some((_, adv)) = adversary.as_mut() {
            adv.restore_state(&st.adversary)?;
        }
        clock.advance_to(st.time_s);
        wall_offset = st.time_s;
        points = st.points.clone();
        start_t = st.t;
    } else if hooks.checkpoint.is_some() && hooks.spec.is_none() {
        anyhow::bail!("checkpointing requires a full ExperimentSpec (use Session)");
    }

    let spec_json = match hooks.spec {
        Some(s) => s.to_json(),
        None => Json::obj(vec![
            ("algo", Json::Str(cfg.algo.name.clone())),
            ("dataset", Json::Str(cfg.dataset.clone())),
            ("k", Json::Num(cfg.k as f64)),
        ]),
    };
    hooks.emit(SessionEvent::RunStart { spec: spec_json })?;

    if start_t == 0 {
        let now = if wall_time { start.elapsed().as_secs_f64() } else { clock.now() };
        record_point(&mut clients, cfg, backend, fms_reference, 0, 0, now, &mut points)?;
        if let Some(p) = points.last() {
            let point = p.clone();
            hooks.emit(SessionEvent::EvalPoint { point })?;
        }
    }

    let total_iters = cfg.epochs * cfg.iters_per_epoch;
    let eval_period = cfg.iters_per_epoch * hooks.eval_every.max(1);
    // dataset identity stamped into every checkpoint — the data is
    // immutable for the run, so hash it once, not per epoch
    let data_fp = hooks.checkpoint.is_some().then(|| data.fingerprint());
    // with no observers attached (the legacy shims), skip all event
    // bookkeeping so the reference loop stays as lean as it always was
    let has_observers = !hooks.observers.is_empty();
    let mut online: Vec<bool> = vec![false; cfg.k];
    let mut drops: Vec<(usize, usize)> = Vec::new();
    let mut adv_acts: Vec<usize> = Vec::new();

    for t in start_t..total_iters {
        for (k, slot) in online.iter_mut().enumerate() {
            *slot = net.online(k, t);
        }
        // block level: the shared mode sequence d_ξ[t], drawn every round
        // so baselines consume the same randomness
        let sampled_mode = block_sampler.next_mode();
        let modes: &[usize] =
            if cfg.algo.block_random { std::slice::from_ref(&sampled_mode) } else { &all_modes };

        // ---- local gradient steps (Alg. 1 lines 4-5) ----
        let mut round_compute = 0.0f64;
        for c in clients.iter_mut() {
            if !online[c.id] {
                c.net.offline_rounds += 1;
                continue;
            }
            for &m in modes {
                c.local_step(m, cfg.loss, cfg.fiber_samples, cfg.gamma, cfg.algo.momentum, backend)?;
                if cfg.algo.error_feedback {
                    apply_error_feedback(c, m, cfg.algo.compressor);
                }
            }
            let cost = cfg.sim_iter_s * net.compute_multiplier(c.id);
            if cost > round_compute {
                round_compute = cost;
            }
        }
        clock.advance(round_compute);
        if has_observers {
            for (k, &up) in online.iter().enumerate() {
                if !up {
                    hooks.emit(SessionEvent::NetFault {
                        t,
                        kind: NetFaultKind::Offline { client: k },
                    })?;
                }
            }
        }

        // ---- round level: gossip through the network model ----
        if decentralized && t % cfg.algo.tau == 0 {
            let track_bytes = has_observers || crate::util::invariant::enabled();
            let bytes_before: u64 =
                if track_bytes { clients.iter().map(|c| c.ledger.bytes).sum() } else { 0 };
            let mut expected_round_bytes = 0u64;
            for &m in modes {
                if m == 0 {
                    continue; // patient mode never travels (privacy)
                }
                let mut payloads =
                    publish_phase(&mut clients, &graph, cfg, &trigger, t, m, Some(&online[..]));

                // wire-byte conservation: snapshot what publish charged
                // (pre-corruption — the ledger was charged on the honest
                // payload) so the invariant can reconcile the ledgers
                // after the round
                if crate::util::invariant::enabled() {
                    for (k, p) in payloads.iter().enumerate() {
                        if let Some(p) = p {
                            expected_round_bytes += (p.wire_bytes() + Message::HEADER_BYTES)
                                * graph.neighbors[k].len() as u64;
                        }
                    }
                }

                // own delta applies locally before any tampering — it
                // never touches the wire. A Byzantine client lies to its
                // *peers*, not to itself: its private Â^k keeps tracking
                // A^k, so its published deltas stay bounded instead of
                // compounding its own corruption round over round.
                for k in 0..clients.len() {
                    if let Some(p) = &payloads[k] {
                        clients[k].estimates.as_mut().expect("estimates").apply_delta(k, m, p);
                    }
                }

                // Byzantine corruption happens between publish and
                // delivery, so every *receiver* of the broadcast gets the
                // same corrupted delta and receiver-side copies of Â^k
                // stay consistent with each other — the invariant honest
                // consensus relies on.
                adv_acts.clear();
                if let Some((byzantine, adv)) = adversary.as_mut() {
                    for &j in byzantine.iter() {
                        if let Some(p) = payloads[j].as_mut() {
                            let shape = &clients[j].factors.mats[m];
                            let (rows, cols) = (shape.rows, shape.cols);
                            adv.corrupt(j, m, t, rows, cols, p);
                            clients[j].net.adversarial += 1;
                            if has_observers {
                                adv_acts.push(j);
                            }
                        }
                    }
                }
                if let Some(kind) = adv_kind {
                    for client in adv_acts.drain(..) {
                        hooks.emit(SessionEvent::AdversarialAct { t, client, mode: m, kind })?;
                    }
                }

                drops.clear();
                for k in 0..clients.len() {
                    if !online[k] {
                        // receiver is down: everything addressed to it is lost
                        for &j in &graph.neighbors[k] {
                            if payloads[j].is_some() {
                                clients[k].net.dropped += 1;
                                if has_observers {
                                    drops.push((j, k));
                                }
                            }
                        }
                        continue;
                    }
                    for &j in &graph.neighbors[k] {
                        let Some(p) = &payloads[j] else { continue };
                        if net.delivers(j, k, t) {
                            clients[k].estimates.as_mut().expect("estimates").apply_delta(j, m, p);
                            clients[k].net.delivered += 1;
                            let wire = p.wire_bytes() + Message::HEADER_BYTES;
                            clock.note_latency(net.latency_s(j, k, wire));
                        } else {
                            clients[k].net.dropped += 1;
                            if has_observers {
                                drops.push((j, k));
                            }
                        }
                    }
                }
                clock.flush_latency();

                consensus_phase(
                    &mut clients,
                    &graph,
                    &cfg.aggregator,
                    cfg.algo.rho,
                    m,
                    Some(&online[..]),
                );

                for (from, to) in drops.drain(..) {
                    hooks.emit(SessionEvent::NetFault {
                        t,
                        kind: NetFaultKind::Dropped { from, to },
                    })?;
                }
            }
            if track_bytes {
                let bytes_after: u64 = clients.iter().map(|c| c.ledger.bytes).sum();
                crate::util::invariant::wire_bytes_conserved(
                    t,
                    bytes_before,
                    bytes_after,
                    expected_round_bytes,
                );
                if has_observers && bytes_after > bytes_before {
                    hooks.emit(SessionEvent::CommBytes {
                        t,
                        round_bytes: bytes_after - bytes_before,
                        total_bytes: bytes_after,
                    })?;
                }
            }
        }

        if has_observers {
            let time_s = if wall_time {
                wall_offset + start.elapsed().as_secs_f64()
            } else {
                clock.now()
            };
            hooks.emit(SessionEvent::RoundEnd { t, time_s })?;
        }

        // ---- eval cadence: metrics and stop rules ----
        let mut stopping = false;
        let mut diverged = false;
        if (t + 1) % eval_period == 0 || t + 1 == total_iters {
            let epoch = (t + 1) / cfg.iters_per_epoch;
            let now = if wall_time {
                wall_offset + start.elapsed().as_secs_f64()
            } else {
                clock.now()
            };
            record_point(&mut clients, cfg, backend, fms_reference, epoch, t + 1, now, &mut points)?;
            let last = points.last().expect("point just recorded").clone();
            hooks.emit(SessionEvent::EvalPoint { point: last.clone() })?;
            if !last.loss.is_finite() {
                eprintln!(
                    "[{}] diverged at epoch {epoch} (gamma {} too large) — stopping early",
                    cfg.algo.name, cfg.gamma
                );
                diverged = true;
            } else {
                let target_hit =
                    hooks.target_loss.map(|target| last.loss <= target).unwrap_or(false);
                let budget_hit = hooks.max_bytes.map(|b| last.bytes >= b).unwrap_or(false);
                stopping = target_hit || budget_hit;
            }
        }

        // ---- checkpoint cadence: every epoch boundary, independent of
        // the eval cadence (a diverged state is never persisted) ----
        if !diverged && (t + 1) % cfg.iters_per_epoch == 0 {
            if let (Some(ck), Some(spec)) = (hooks.checkpoint, hooks.spec) {
                let epoch = (t + 1) / cfg.iters_per_epoch;
                if epoch % ck.every_epochs.max(1) == 0 || stopping || t + 1 == total_iters {
                    let now = if wall_time {
                        wall_offset + start.elapsed().as_secs_f64()
                    } else {
                        clock.now()
                    };
                    let state = SessionState {
                        t: t + 1,
                        time_s: now,
                        sampler_rng: block_sampler.state().0,
                        sampler_t: block_sampler.state().1,
                        net_model: net.state_json(),
                        adversary: adversary
                            .as_ref()
                            .map(|(_, a)| a.state_json())
                            .unwrap_or(Json::Null),
                        data_nnz: Some(data.tensor.nnz() as u64),
                        data_fp,
                        points: points.clone(),
                        clients: clients.iter().map(checkpoint::snapshot_client).collect(),
                    };
                    checkpoint::write_checkpoint(&ck.path, spec, &state)?;
                    let path = ck.path.clone();
                    hooks.emit(SessionEvent::Checkpoint { t: t + 1, path })?;
                }
            }
        }
        if diverged || stopping {
            break;
        }
    }

    let factors = assemble_global(&clients);
    let wall_s =
        if wall_time { wall_offset + start.elapsed().as_secs_f64() } else { clock.now() };
    let record = finalize_record(cfg, &graph, &clients, points, wall_s);
    hooks.emit(SessionEvent::RunEnd { record: record.clone() })?;
    Ok(TrainOutcome { record, factors })
}
