//! The unified round-driver abstraction plus the synchronous network
//! simulator path.
//!
//! [`RoundDriver`] erases *how* a configuration executes (sequential,
//! threaded, simulated-faulty, async) behind one `run` call, so harnesses
//! and the CLI can sweep execution paths exactly like they sweep
//! algorithms. [`train_sim`] is the tentpole path: the engine's lock-step
//! protocol (Alg. 1) with every message routed through a
//! [`NetworkModel`] — per-link latency/bandwidth, i.i.d. and bursty drops,
//! straggler compute, and churn — on a [`crate::net::sim::VirtualClock`].
//!
//! Invariant (asserted in `tests/network_sim.rs`): with
//! [`crate::net::sim::IdealNetwork`] the simulator performs exactly the
//! float operations of `engine::train`, so the factors are bit-identical.

use crate::engine::{TrainConfig, TrainOutcome};
use crate::factor::FactorSet;
use crate::net::sim::NetworkModel;
use crate::runtime::ComputeBackend;
use crate::data::Dataset;

/// Which execution path drives the rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriverKind {
    /// in-process lock-step (`engine::train`) — the reference path
    Sequential,
    /// one OS thread per client with barrier-synchronized rounds
    Parallel,
    /// lock-step rounds through a `NetworkModel` on a virtual clock
    Sim,
    /// event-driven asynchronous gossip (no barriers)
    Async,
    /// one OS process per client over real sockets (`cidertf node` /
    /// `cidertf fleet` — see [`crate::node`])
    Node,
}

impl DriverKind {
    /// CLI name of this driver.
    pub fn name(self) -> &'static str {
        match self {
            DriverKind::Sequential => "seq",
            DriverKind::Parallel => "par",
            DriverKind::Sim => "sim",
            DriverKind::Async => "async",
            DriverKind::Node => "node",
        }
    }

    /// Parse a CLI `--driver` flag (thin wrapper over
    /// [`crate::registry::drivers`]).
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        crate::registry::drivers().resolve(s)
    }
}

/// One way to execute a training configuration end-to-end. Every
/// implementation consumes the same [`TrainConfig`] and produces the same
/// [`TrainOutcome`] shape (metrics, ledger, delivery stats), so callers
/// can swap drivers without touching anything else.
pub trait RoundDriver {
    /// Short name for tables and filenames.
    fn name(&self) -> &'static str;

    /// Run `cfg` on `data` to completion.
    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &Dataset,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome>;
}

/// [`RoundDriver`] over the sequential reference engine.
pub struct SequentialDriver {
    /// compute backend shared by all simulated clients
    pub backend: Box<dyn ComputeBackend>,
}

impl RoundDriver for SequentialDriver {
    fn name(&self) -> &'static str {
        "seq"
    }

    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &Dataset,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        crate::engine::train(cfg, data, self.backend.as_mut(), fms_reference)
    }
}

/// [`RoundDriver`] over the thread-per-client runtime.
pub struct ParallelDriver {
    /// per-thread backend factory (PJRT clients are per-thread)
    pub make_backend: Box<dyn Fn(usize) -> anyhow::Result<Box<dyn ComputeBackend>> + Sync>,
}

impl RoundDriver for ParallelDriver {
    fn name(&self) -> &'static str {
        "par"
    }

    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &Dataset,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        crate::net::parallel::train_parallel(cfg, data, |k| (self.make_backend)(k), fms_reference)
    }
}

/// [`RoundDriver`] over the synchronous network simulator.
pub struct SimDriver {
    /// compute backend shared by all simulated clients
    pub backend: Box<dyn ComputeBackend>,
    /// the fault envelope messages travel through
    pub net: Box<dyn NetworkModel>,
}

impl RoundDriver for SimDriver {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &Dataset,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        train_sim(cfg, data, self.backend.as_mut(), self.net.as_mut(), fms_reference)
    }
}

/// [`RoundDriver`] over the event-driven async gossip engine.
pub struct AsyncGossipDriver {
    /// compute backend shared by all simulated clients
    pub backend: Box<dyn ComputeBackend>,
    /// the fault envelope messages travel through
    pub net: Box<dyn NetworkModel>,
}

impl RoundDriver for AsyncGossipDriver {
    fn name(&self) -> &'static str {
        "async"
    }

    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &Dataset,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        crate::net::async_gossip::train_async(
            cfg,
            data,
            self.backend.as_mut(),
            self.net.as_mut(),
            fms_reference,
        )
    }
}

/// Build a boxed driver from CLI-ish inputs. `backend_flag` is resolved
/// per [`crate::runtime::NativeOrPjrt`]; `net` is consumed by the
/// simulator paths and ignored by the lock-step in-process paths (their
/// network is ideal by construction).
///
/// **Deprecated.** Kept for API compatibility; the CLI and harness now
/// resolve drivers through [`crate::engine::session::Session`], which
/// consumes a declarative [`crate::engine::spec::ExperimentSpec`]
/// instead of loose flags.
pub fn driver_from_flags(
    kind: DriverKind,
    backend_flag: &str,
    net: Box<dyn NetworkModel>,
) -> anyhow::Result<Box<dyn RoundDriver>> {
    use crate::runtime::NativeOrPjrt;
    Ok(match kind {
        DriverKind::Sequential => {
            Box::new(SequentialDriver { backend: NativeOrPjrt::from_flag(backend_flag)? })
        }
        DriverKind::Parallel => {
            let flag = backend_flag.to_string();
            Box::new(ParallelDriver {
                make_backend: Box::new(move |_| NativeOrPjrt::from_flag(&flag)),
            })
        }
        DriverKind::Sim => {
            Box::new(SimDriver { backend: NativeOrPjrt::from_flag(backend_flag)?, net })
        }
        DriverKind::Async => {
            Box::new(AsyncGossipDriver { backend: NativeOrPjrt::from_flag(backend_flag)?, net })
        }
        DriverKind::Node => anyhow::bail!(
            "the node driver runs clients as separate OS processes over real sockets — \
             launch it with 'cidertf fleet spawn --config fleet.json' (or 'cidertf node' \
             per process), not through an in-process RoundDriver"
        ),
    })
}

/// Lock-step training over a [`NetworkModel`] (the sync simulator).
///
/// **Deprecated shim.** The loop body now lives in the unified session
/// loop (`engine::session`), which this delegates to with the caller's
/// network model and the virtual clock — exactly the float operations of
/// the original simulator, so with `IdealNetwork` the factors stay
/// bit-identical to [`crate::engine::train`] (asserted in
/// `tests/network_sim.rs`). New code should build an
/// [`crate::engine::spec::ExperimentSpec`] with the `sim` driver and run
/// a [`crate::engine::session::Session`] — that path adds observers,
/// eval cadence, stopping rules, and checkpoint/resume.
pub fn train_sim(
    cfg: &TrainConfig,
    data: &Dataset,
    backend: &mut dyn ComputeBackend,
    net: &mut dyn NetworkModel,
    fms_reference: Option<&FactorSet>,
) -> anyhow::Result<TrainOutcome> {
    crate::engine::session::run_loop(
        cfg,
        data,
        backend,
        net,
        false,
        fms_reference,
        &mut crate::engine::session::Hooks::none(),
    )
}
