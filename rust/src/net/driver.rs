//! The unified round-driver abstraction plus the synchronous network
//! simulator path.
//!
//! [`RoundDriver`] erases *how* a configuration executes (sequential,
//! threaded, simulated-faulty, async) behind one `run` call, so harnesses
//! and the CLI can sweep execution paths exactly like they sweep
//! algorithms. [`train_sim`] is the tentpole path: the engine's lock-step
//! protocol (Alg. 1) with every message routed through a
//! [`NetworkModel`] — per-link latency/bandwidth, i.i.d. and bursty drops,
//! straggler compute, and churn — on a [`VirtualClock`].
//!
//! Invariant (asserted in `tests/network_sim.rs`): with
//! [`crate::net::sim::IdealNetwork`] the simulator performs exactly the
//! float operations of `engine::train`, so the factors are bit-identical.

use crate::engine::{
    apply_error_feedback, assemble_global, build_clients, consensus_phase, finalize_record,
    publish_phase, record_point, TrainConfig, TrainOutcome,
};
use crate::factor::FactorSet;
use crate::gossip::Message;
use crate::net::sim::{NetworkModel, VirtualClock};
use crate::runtime::ComputeBackend;
use crate::sched::BlockSampler;
use crate::tensor::synth::SynthData;
use crate::topology::Graph;

/// Which execution path drives the rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriverKind {
    /// in-process lock-step (`engine::train`) — the reference path
    Sequential,
    /// one OS thread per client with barrier-synchronized rounds
    Parallel,
    /// lock-step rounds through a `NetworkModel` on a virtual clock
    Sim,
    /// event-driven asynchronous gossip (no barriers)
    Async,
}

impl DriverKind {
    /// CLI name of this driver.
    pub fn name(self) -> &'static str {
        match self {
            DriverKind::Sequential => "seq",
            DriverKind::Parallel => "par",
            DriverKind::Sim => "sim",
            DriverKind::Async => "async",
        }
    }

    /// Parse a CLI `--driver` flag.
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "seq" | "sequential" => DriverKind::Sequential,
            "par" | "parallel" => DriverKind::Parallel,
            "sim" => DriverKind::Sim,
            "async" => DriverKind::Async,
            other => anyhow::bail!("unknown driver '{other}' (seq|par|sim|async)"),
        })
    }
}

/// One way to execute a training configuration end-to-end. Every
/// implementation consumes the same [`TrainConfig`] and produces the same
/// [`TrainOutcome`] shape (metrics, ledger, delivery stats), so callers
/// can swap drivers without touching anything else.
pub trait RoundDriver {
    /// Short name for tables and filenames.
    fn name(&self) -> &'static str;

    /// Run `cfg` on `data` to completion.
    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &SynthData,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome>;
}

/// [`RoundDriver`] over the sequential reference engine.
pub struct SequentialDriver {
    /// compute backend shared by all simulated clients
    pub backend: Box<dyn ComputeBackend>,
}

impl RoundDriver for SequentialDriver {
    fn name(&self) -> &'static str {
        "seq"
    }

    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &SynthData,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        crate::engine::train(cfg, data, self.backend.as_mut(), fms_reference)
    }
}

/// [`RoundDriver`] over the thread-per-client runtime.
pub struct ParallelDriver {
    /// per-thread backend factory (PJRT clients are per-thread)
    pub make_backend: Box<dyn Fn(usize) -> anyhow::Result<Box<dyn ComputeBackend>> + Sync>,
}

impl RoundDriver for ParallelDriver {
    fn name(&self) -> &'static str {
        "par"
    }

    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &SynthData,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        crate::net::parallel::train_parallel(cfg, data, |k| (self.make_backend)(k), fms_reference)
    }
}

/// [`RoundDriver`] over the synchronous network simulator.
pub struct SimDriver {
    /// compute backend shared by all simulated clients
    pub backend: Box<dyn ComputeBackend>,
    /// the fault envelope messages travel through
    pub net: Box<dyn NetworkModel>,
}

impl RoundDriver for SimDriver {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &SynthData,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        train_sim(cfg, data, self.backend.as_mut(), self.net.as_mut(), fms_reference)
    }
}

/// [`RoundDriver`] over the event-driven async gossip engine.
pub struct AsyncGossipDriver {
    /// compute backend shared by all simulated clients
    pub backend: Box<dyn ComputeBackend>,
    /// the fault envelope messages travel through
    pub net: Box<dyn NetworkModel>,
}

impl RoundDriver for AsyncGossipDriver {
    fn name(&self) -> &'static str {
        "async"
    }

    fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &SynthData,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        crate::net::async_gossip::train_async(
            cfg,
            data,
            self.backend.as_mut(),
            self.net.as_mut(),
            fms_reference,
        )
    }
}

/// Build a boxed driver from CLI-ish inputs. `backend_flag` is resolved
/// per [`crate::runtime::NativeOrPjrt`]; `net` is consumed by the
/// simulator paths and ignored by the lock-step in-process paths (their
/// network is ideal by construction).
pub fn driver_from_flags(
    kind: DriverKind,
    backend_flag: &str,
    net: Box<dyn NetworkModel>,
) -> anyhow::Result<Box<dyn RoundDriver>> {
    use crate::runtime::NativeOrPjrt;
    Ok(match kind {
        DriverKind::Sequential => {
            Box::new(SequentialDriver { backend: NativeOrPjrt::from_flag(backend_flag)? })
        }
        DriverKind::Parallel => {
            let flag = backend_flag.to_string();
            Box::new(ParallelDriver {
                make_backend: Box::new(move |_| NativeOrPjrt::from_flag(&flag)),
            })
        }
        DriverKind::Sim => {
            Box::new(SimDriver { backend: NativeOrPjrt::from_flag(backend_flag)?, net })
        }
        DriverKind::Async => {
            Box::new(AsyncGossipDriver { backend: NativeOrPjrt::from_flag(backend_flag)?, net })
        }
    })
}

/// Lock-step training over a [`NetworkModel`] (the sync simulator).
///
/// Per iteration `t` (mirroring `engine::train` exactly):
/// 1. an online mask is drawn — churned-out clients skip the round,
/// 2. online clients take their local SGD/momentum step(s),
/// 3. on communication rounds, payloads from online clients go through
///    [`crate::engine::publish_phase`] (same trigger, compressor, and
///    uplink ledger as the engine), then each neighbor message is
///    subjected to `net.delivers`; survivors update `Â` and their latency
///    is charged to the barrier,
/// 4. online clients run the consensus step,
/// 5. the [`VirtualClock`] advances by the slowest online client's
///    compute time (stragglers stretch the round) plus the slowest
///    surviving message.
///
/// With `IdealNetwork` every mask is all-true, every message survives with
/// zero latency, and steps 1–4 reduce to the engine's loop — bit-identical
/// factors.
pub fn train_sim(
    cfg: &TrainConfig,
    data: &SynthData,
    backend: &mut dyn ComputeBackend,
    net: &mut dyn NetworkModel,
    fms_reference: Option<&FactorSet>,
) -> anyhow::Result<TrainOutcome> {
    let d_order = data.tensor.dims.len();
    anyhow::ensure!(cfg.rank >= 1 && cfg.k >= 1 && cfg.algo.tau >= 1);
    backend.set_threads(cfg.compute_threads);
    let graph = Graph::build(cfg.topology, cfg.k)?;
    let decentralized = cfg.k > 1;
    let mut clients = build_clients(cfg, data, &graph);

    let mut block_sampler = BlockSampler::new(d_order, cfg.seed, true);
    let trigger = cfg.trigger_schedule();
    let all_modes: Vec<usize> = (0..d_order).collect();
    let mut clock = VirtualClock::default();

    let mut points = Vec::with_capacity(cfg.epochs + 1);
    record_point(&mut clients, cfg, backend, fms_reference, 0, 0, clock.now(), &mut points)?;

    let total_iters = cfg.epochs * cfg.iters_per_epoch;
    for t in 0..total_iters {
        let online: Vec<bool> = (0..cfg.k).map(|k| net.online(k, t)).collect();
        let sampled_mode = block_sampler.next_mode();
        let modes: &[usize] =
            if cfg.algo.block_random { std::slice::from_ref(&sampled_mode) } else { &all_modes };

        // ---- local steps (skipped while churned out) ----
        let mut round_compute = 0.0f64;
        for c in clients.iter_mut() {
            if !online[c.id] {
                c.net.offline_rounds += 1;
                continue;
            }
            for &m in modes {
                let beta = cfg.algo.momentum;
                c.local_step(m, cfg.loss, cfg.fiber_samples, cfg.gamma, beta, backend)?;
                if cfg.algo.error_feedback {
                    apply_error_feedback(c, m, cfg.algo.compressor);
                }
            }
            let cost = cfg.sim_iter_s * net.compute_multiplier(c.id);
            if cost > round_compute {
                round_compute = cost;
            }
        }
        clock.advance(round_compute);

        // ---- gossip through the network model ----
        if decentralized && t % cfg.algo.tau == 0 {
            for &m in modes {
                if m == 0 {
                    continue; // patient mode never travels
                }
                let payloads =
                    publish_phase(&mut clients, &graph, cfg, &trigger, t, m, Some(&online[..]));

                for k in 0..clients.len() {
                    if !online[k] {
                        // receiver is down: everything addressed to it is lost
                        for &j in &graph.neighbors[k] {
                            if payloads[j].is_some() {
                                clients[k].net.dropped += 1;
                            }
                        }
                        continue;
                    }
                    // own delta applies locally, never on the wire
                    if let Some(p) = &payloads[k] {
                        clients[k].estimates.as_mut().expect("estimates").apply_delta(k, m, p);
                    }
                    for &j in &graph.neighbors[k] {
                        let Some(p) = &payloads[j] else { continue };
                        if net.delivers(j, k, t) {
                            clients[k].estimates.as_mut().expect("estimates").apply_delta(j, m, p);
                            clients[k].net.delivered += 1;
                            let wire = p.wire_bytes() + Message::HEADER_BYTES;
                            clock.note_latency(net.latency_s(j, k, wire));
                        } else {
                            clients[k].net.dropped += 1;
                        }
                    }
                }
                clock.flush_latency();

                consensus_phase(&mut clients, &graph, cfg.algo.rho, m, Some(&online[..]));
            }
        }

        // ---- metrics per epoch ----
        if (t + 1) % cfg.iters_per_epoch == 0 {
            let epoch = (t + 1) / cfg.iters_per_epoch;
            let now = clock.now();
            let iter = t + 1;
            record_point(&mut clients, cfg, backend, fms_reference, epoch, iter, now, &mut points)?;
            if !points.last().map(|p| p.loss.is_finite()).unwrap_or(true) {
                eprintln!(
                    "[{}] diverged at epoch {epoch} (gamma {} too large) — stopping early",
                    cfg.algo.name, cfg.gamma
                );
                break;
            }
        }
    }

    let factors = assemble_global(&clients);
    let record = finalize_record(cfg, &graph, &clients, points, clock.now());
    Ok(TrainOutcome { record, factors })
}
