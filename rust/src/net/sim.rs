//! Event-driven network simulator: link models, faults, and virtual time.
//!
//! The engine's gossip exchange (Alg. 1 lines 9-18) assumes an *ideal*
//! network — every message arrives, instantly, every round. Real hospital
//! deployments see none of that: WAN links drop packets (i.i.d. and in
//! bursts), clients compute at different speeds (stragglers), and nodes
//! leave and rejoin (churn). This module models those behaviours behind
//! the [`NetworkModel`] trait so every execution path in
//! [`crate::net::driver`] can run against the same fault envelope.
//!
//! Design notes:
//!
//! * **Determinism.** Static traits (per-link latency spread, straggler
//!   assignment, churn windows) come from stable hashes of
//!   `(seed, link/client[, period])`; drop decisions come from an
//!   independent seeded [`Rng`] stream *per directed link*, advanced once
//!   per message on that link. Either way a run is a pure function of its
//!   config, and one link's loss pattern does not depend on traffic
//!   elsewhere. No wall clock is consulted anywhere; time is
//!   [`VirtualClock`] time.
//! * **Ideal == no-op.** [`IdealNetwork`] returns "deliver, instantly,
//!   everyone online" unconditionally, which is what makes the sync
//!   simulator bit-identical to `engine::train` (asserted in tests).
//! * **CHOCO-style tolerance.** Dropped or late deltas leave the peer
//!   estimate `Â` stale rather than corrupt — exactly the error the
//!   compressed-gossip analysis (paper Thm. III.2) already absorbs, which
//!   is why convergence degrades gracefully under loss.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::gossip::Message;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Per-run network delivery statistics (reported in
/// [`crate::engine::metrics::RunRecord`]).
#[derive(Debug, Clone, Default)]
pub struct NetStats {
    /// neighbor deltas that arrived and were applied to `Â`
    pub delivered: u64,
    /// neighbor deltas lost to link faults or offline receivers
    pub dropped: u64,
    /// deltas applied after the receiver had already passed the sender's
    /// round (async path only — sync rounds are never stale)
    pub stale: u64,
    /// (client, round) pairs skipped because the client was churned out
    pub offline_rounds: u64,
    /// payloads this client corrupted before broadcast (Byzantine runs)
    pub adversarial: u64,
}

impl NetStats {
    /// Accumulate another client's counters.
    pub fn merge(&mut self, other: &NetStats) {
        self.delivered += other.delivered;
        self.dropped += other.dropped;
        self.stale += other.stale;
        self.offline_rounds += other.offline_rounds;
        self.adversarial += other.adversarial;
    }

    /// Fraction of attempted deliveries that were lost (`0.0` when no
    /// traffic was attempted).
    pub fn drop_fraction(&self) -> f64 {
        let attempted = self.delivered + self.dropped;
        if attempted == 0 {
            0.0
        } else {
            self.dropped as f64 / attempted as f64
        }
    }
}

/// Behavioural model of the communication fabric between clients.
///
/// Methods take `&mut self` because fault models keep per-link state
/// (burst machines) and internal RNG streams. Calls happen in a
/// deterministic order from the single-threaded simulators, so equal
/// seeds yield equal runs.
pub trait NetworkModel {
    /// Human-readable model name (for tables and run records).
    fn name(&self) -> &'static str;

    /// One-way delay in (virtual) seconds for `bytes` on the directed
    /// link `from -> to`.
    fn latency_s(&mut self, from: usize, to: usize, bytes: u64) -> f64;

    /// Does a message on `from -> to` at `round` survive the link?
    fn delivers(&mut self, from: usize, to: usize, round: usize) -> bool;

    /// Relative compute cost of one local iteration on `client`
    /// (`1.0` = nominal, `> 1.0` = straggler).
    fn compute_multiplier(&mut self, client: usize) -> f64;

    /// Is `client` participating at `round`? Offline clients neither
    /// compute nor send, and anything addressed to them is lost.
    fn online(&mut self, client: usize, round: usize) -> bool;

    /// Internal mutable state for checkpointing (per-link RNG streams,
    /// burst flags). Stateless models return `Json::Null` — the default.
    fn state_json(&self) -> Json {
        Json::Null
    }

    /// Restore a [`NetworkModel::state_json`] snapshot so fault streams
    /// continue bit-identically across a checkpoint/resume boundary.
    /// Stateless models accept anything — the default is a no-op.
    fn restore_state(&mut self, _state: &Json) -> anyhow::Result<()> {
        Ok(())
    }
}

/// The lossless, zero-latency, homogeneous network (the engine's implicit
/// assumption). Running any driver against it reproduces ideal-network
/// semantics exactly.
#[derive(Debug, Clone, Copy, Default)]
pub struct IdealNetwork;

impl IdealNetwork {
    /// Boxed trait object, for driver constructors.
    pub fn boxed() -> Box<dyn NetworkModel> {
        Box::new(IdealNetwork)
    }
}

impl NetworkModel for IdealNetwork {
    fn name(&self) -> &'static str {
        "ideal"
    }

    fn latency_s(&mut self, _from: usize, _to: usize, _bytes: u64) -> f64 {
        0.0
    }

    fn delivers(&mut self, _from: usize, _to: usize, _round: usize) -> bool {
        true
    }

    fn compute_multiplier(&mut self, _client: usize) -> f64 {
        1.0
    }

    fn online(&mut self, _client: usize, _round: usize) -> bool {
        true
    }
}

/// Convenience constructor for the ideal network model.
pub fn ideal() -> Box<dyn NetworkModel> {
    IdealNetwork::boxed()
}

/// Declarative fault envelope for [`FaultyNetwork`].
///
/// Every knob defaults to "off", so `FaultConfig::default()` behaves like
/// [`IdealNetwork`] up to latency bookkeeping.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// seed for every stochastic decision in the model
    pub seed: u64,
    /// i.i.d. per-message drop probability in the link's *good* state
    pub drop_rate: f64,
    /// probability per message of a link entering a loss burst
    pub burst_rate: f64,
    /// expected number of messages a burst lasts (geometric exit)
    pub burst_len: f64,
    /// drop probability while a link is inside a burst
    pub burst_drop: f64,
    /// base one-way propagation delay per link, seconds
    pub latency_base_s: f64,
    /// relative static per-link latency spread in `[0, jitter]`
    /// (heterogeneous links: hospital A-B is consistently slower than B-C)
    pub latency_jitter: f64,
    /// link bandwidth in bytes/second (`0.0` = infinite)
    pub bandwidth_bps: f64,
    /// fraction of clients that are compute stragglers (sampled by a
    /// stable per-client hash)
    pub straggler_frac: f64,
    /// explicit straggler client ids (deterministic, in addition to the
    /// sampled fraction — useful for tests and targeted scenarios)
    pub straggler_ids: Vec<usize>,
    /// compute multiplier applied to stragglers (`>= 1.0`)
    pub straggler_slow: f64,
    /// per-period probability that a client is churned out
    pub churn_rate: f64,
    /// rounds per churn decision period (availability granularity)
    pub churn_period: usize,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0xFA17,
            drop_rate: 0.0,
            burst_rate: 0.0,
            burst_len: 8.0,
            burst_drop: 0.9,
            latency_base_s: 0.0,
            latency_jitter: 0.0,
            bandwidth_bps: 0.0,
            straggler_frac: 0.0,
            straggler_ids: Vec::new(),
            straggler_slow: 4.0,
            churn_rate: 0.0,
            churn_period: 50,
        }
    }
}

impl FaultConfig {
    /// i.i.d. message loss at probability `p`.
    pub fn lossy(p: f64) -> Self {
        FaultConfig { drop_rate: p, ..Default::default() }
    }

    /// Bursty Gilbert–Elliott-style loss: mostly clean links that
    /// occasionally collapse for `burst_len` messages at a time.
    pub fn bursty() -> Self {
        FaultConfig { drop_rate: 0.01, burst_rate: 0.02, ..Default::default() }
    }

    /// Heterogeneous WAN latency/bandwidth, no loss.
    pub fn wan() -> Self {
        FaultConfig {
            latency_base_s: 0.05,
            latency_jitter: 1.0,
            bandwidth_bps: 1e6,
            ..Default::default()
        }
    }

    /// A quarter of the clients compute 4x slower.
    pub fn stragglers() -> Self {
        FaultConfig { straggler_frac: 0.25, straggler_slow: 4.0, ..Default::default() }
    }

    /// Clients leave and rejoin (10% downtime in 50-round blocks).
    pub fn churning() -> Self {
        FaultConfig { churn_rate: 0.1, ..Default::default() }
    }

    /// Everything at once — the stress scenario.
    pub fn hostile() -> Self {
        FaultConfig {
            drop_rate: 0.1,
            burst_rate: 0.01,
            latency_base_s: 0.05,
            latency_jitter: 1.0,
            bandwidth_bps: 1e6,
            straggler_frac: 0.25,
            churn_rate: 0.05,
            ..Default::default()
        }
    }

    /// Look up a scenario by CLI name; `lossy:<p>` selects the drop rate.
    /// Thin wrapper over [`crate::registry::networks`] (`None` = ideal).
    pub fn by_name(spec: &str) -> anyhow::Result<Option<Self>> {
        crate::registry::networks().resolve(spec)
    }

    /// Override the scenario seed (builder style).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Serialize for the experiment-spec JSON layer.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::u64(self.seed)),
            ("drop_rate", Json::Num(self.drop_rate)),
            ("burst_rate", Json::Num(self.burst_rate)),
            ("burst_len", Json::Num(self.burst_len)),
            ("burst_drop", Json::Num(self.burst_drop)),
            ("latency_base_s", Json::Num(self.latency_base_s)),
            ("latency_jitter", Json::Num(self.latency_jitter)),
            ("bandwidth_bps", Json::Num(self.bandwidth_bps)),
            ("straggler_frac", Json::Num(self.straggler_frac)),
            (
                "straggler_ids",
                Json::arr_usize(&self.straggler_ids),
            ),
            ("straggler_slow", Json::Num(self.straggler_slow)),
            ("churn_rate", Json::Num(self.churn_rate)),
            ("churn_period", Json::Num(self.churn_period as f64)),
        ])
    }

    /// Deserialize the [`FaultConfig::to_json`] layout. Missing keys keep
    /// their defaults, so hand-written spec files only need the knobs
    /// they turn — but unknown/typo'd keys are errors (with a
    /// did-you-mean hint), so `"drop_rte"` can never silently mean an
    /// ideal link.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        j.ensure_known_keys(
            "network",
            &[
                "seed",
                "drop_rate",
                "burst_rate",
                "burst_len",
                "burst_drop",
                "latency_base_s",
                "latency_jitter",
                "bandwidth_bps",
                "straggler_frac",
                "straggler_ids",
                "straggler_slow",
                "churn_rate",
                "churn_period",
            ],
        )?;
        let mut f = FaultConfig::default();
        if let Some(v) = j.get("seed") {
            f.seed = v.as_u64().ok_or_else(|| anyhow::anyhow!("bad fault 'seed'"))?;
        }
        let num = |key: &str, slot: &mut f64| -> anyhow::Result<()> {
            if let Some(v) = j.get(key) {
                *slot = v.as_f64().ok_or_else(|| anyhow::anyhow!("bad fault '{key}'"))?;
            }
            Ok(())
        };
        num("drop_rate", &mut f.drop_rate)?;
        num("burst_rate", &mut f.burst_rate)?;
        num("burst_len", &mut f.burst_len)?;
        num("burst_drop", &mut f.burst_drop)?;
        num("latency_base_s", &mut f.latency_base_s)?;
        num("latency_jitter", &mut f.latency_jitter)?;
        num("bandwidth_bps", &mut f.bandwidth_bps)?;
        num("straggler_frac", &mut f.straggler_frac)?;
        num("straggler_slow", &mut f.straggler_slow)?;
        num("churn_rate", &mut f.churn_rate)?;
        if let Some(v) = j.get("straggler_ids") {
            let arr = v.as_array().ok_or_else(|| anyhow::anyhow!("bad fault 'straggler_ids'"))?;
            f.straggler_ids = arr
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow::anyhow!("bad straggler id")))
                .collect::<anyhow::Result<Vec<_>>>()?;
        }
        if let Some(v) = j.get("churn_period") {
            f.churn_period =
                v.as_usize().ok_or_else(|| anyhow::anyhow!("bad fault 'churn_period'"))?;
        }
        Ok(f)
    }

    /// Materialize the model.
    pub fn build(self) -> FaultyNetwork {
        FaultyNetwork::new(self)
    }

    /// Materialize as a boxed trait object.
    pub fn boxed(self) -> Box<dyn NetworkModel> {
        Box::new(self.build())
    }
}

/// Deterministic hash of a small tuple into `[0, 1)` — used for *static*
/// per-link / per-client traits (latency spread, straggler assignment,
/// churn windows) so they do not depend on call order.
pub(crate) fn unit_hash(seed: u64, a: u64, b: u64, c: u64) -> f64 {
    let mut x = seed ^ 0x9E37_79B9_7F4A_7C15;
    for v in [a.wrapping_add(1), b.wrapping_add(0x1000), c.wrapping_add(0x2000)] {
        x ^= v.wrapping_mul(0xA24B_AED4_963E_E407);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
    }
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Per-directed-link fault state: an independent RNG stream plus the
/// Gilbert–Elliott burst flag, so a link's loss pattern is a pure
/// function of `(seed, link, message sequence)` — independent of the
/// traffic on every other link.
#[derive(Debug, Clone)]
struct LinkState {
    in_burst: bool,
    rng: Rng,
}

impl LinkState {
    fn new(seed: u64, from: usize, to: usize) -> Self {
        let stream = ((from as u64) << 32) | to as u64;
        LinkState { in_burst: false, rng: Rng::new(seed ^ 0x5EED_0F_FA_u64).split(stream) }
    }
}

/// Seeded realization of a [`FaultConfig`].
pub struct FaultyNetwork {
    cfg: FaultConfig,
    /// directed-link fault machines, keyed `(from, to)`. A `BTreeMap` so
    /// every iteration (checkpoint serialization in particular) is
    /// key-ordered structurally — no hash order anywhere near the state
    /// that feeds bit-exact resume.
    links: std::collections::BTreeMap<(usize, usize), LinkState>,
}

impl FaultyNetwork {
    /// Build the model; all decision streams derive from `cfg.seed`.
    pub fn new(cfg: FaultConfig) -> Self {
        FaultyNetwork { cfg, links: std::collections::BTreeMap::new() }
    }

    /// The fault envelope this model realizes.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }
}

impl NetworkModel for FaultyNetwork {
    fn name(&self) -> &'static str {
        "faulty"
    }

    fn latency_s(&mut self, from: usize, to: usize, bytes: u64) -> f64 {
        // static heterogeneity: each undirected link gets a fixed spread
        let (a, b) = if from < to { (from, to) } else { (to, from) };
        let link_hash = unit_hash(self.cfg.seed, a as u64, b as u64, 7);
        let spread = 1.0 + self.cfg.latency_jitter * link_hash;
        let transfer = if self.cfg.bandwidth_bps > 0.0 {
            bytes as f64 / self.cfg.bandwidth_bps
        } else {
            0.0
        };
        self.cfg.latency_base_s * spread + transfer
    }

    fn delivers(&mut self, from: usize, to: usize, _round: usize) -> bool {
        let seed = self.cfg.seed;
        let state =
            self.links.entry((from, to)).or_insert_with(|| LinkState::new(seed, from, to));
        // burst transitions (Gilbert–Elliott): geometric entry and exit
        if state.in_burst {
            if state.rng.bernoulli(1.0 / self.cfg.burst_len.max(1.0)) {
                state.in_burst = false;
            }
        } else if self.cfg.burst_rate > 0.0 && state.rng.bernoulli(self.cfg.burst_rate) {
            state.in_burst = true;
        }
        let p_drop = if state.in_burst { self.cfg.burst_drop } else { self.cfg.drop_rate };
        !(p_drop > 0.0 && state.rng.bernoulli(p_drop))
    }

    fn compute_multiplier(&mut self, client: usize) -> f64 {
        if self.cfg.straggler_ids.contains(&client)
            || unit_hash(self.cfg.seed, client as u64, 0, 13) < self.cfg.straggler_frac
        {
            self.cfg.straggler_slow.max(1.0)
        } else {
            1.0
        }
    }

    fn online(&mut self, client: usize, round: usize) -> bool {
        if self.cfg.churn_rate <= 0.0 {
            return true;
        }
        let period = (round / self.cfg.churn_period.max(1)) as u64;
        unit_hash(self.cfg.seed, client as u64, period, 29) >= self.cfg.churn_rate
    }

    fn state_json(&self) -> Json {
        // static traits (latency spread, stragglers, churn windows) are
        // pure hashes of the config — only the per-link fault machines
        // carry mutable state. BTreeMap iteration is key-ordered, so the
        // file is deterministic without a sort pass.
        let links: Vec<Json> = self
            .links
            .iter()
            .map(|(k, st)| {
                Json::obj(vec![
                    ("from", Json::Num(k.0 as f64)),
                    ("to", Json::Num(k.1 as f64)),
                    ("in_burst", Json::Bool(st.in_burst)),
                    ("rng", crate::util::rng::state_to_json(st.rng.state())),
                ])
            })
            .collect();
        Json::obj(vec![("links", Json::Arr(links))])
    }

    fn restore_state(&mut self, state: &Json) -> anyhow::Result<()> {
        if matches!(state, Json::Null) {
            return Ok(());
        }
        let links = state.req_array("links")?;
        self.links.clear();
        for l in links {
            let from = l.req_usize("from")?;
            let to = l.req_usize("to")?;
            let in_burst = l
                .get("in_burst")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow::anyhow!("bad link 'in_burst'"))?;
            let (words, spare) = crate::util::rng::state_from_json(
                l.get("rng").ok_or_else(|| anyhow::anyhow!("missing link 'rng'"))?,
            )?;
            self.links.insert(
                (from, to),
                LinkState { in_burst, rng: Rng::from_state(words, spare) },
            );
        }
        Ok(())
    }
}

/// Monotone simulated clock shared by the network-mediated drivers.
///
/// Compute and propagation costs are *accounted*, not slept: the sync
/// driver advances by the slowest client per round (barrier semantics),
/// the async driver advances to each event's timestamp.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: f64,
    pending_latency: f64,
}

impl VirtualClock {
    /// Current simulated time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `dt` seconds (`dt < 0` is clamped to zero).
    pub fn advance(&mut self, dt: f64) {
        self.now += dt.max(0.0);
    }

    /// Record an in-flight message latency; a synchronous barrier waits
    /// for the slowest one (applied by [`Self::flush_latency`]).
    pub fn note_latency(&mut self, latency_s: f64) {
        if latency_s > self.pending_latency {
            self.pending_latency = latency_s;
        }
    }

    /// Apply the slowest recorded latency and reset it.
    pub fn flush_latency(&mut self) {
        self.now += self.pending_latency;
        self.pending_latency = 0.0;
    }

    /// Jump to an absolute timestamp (events never run backwards).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

/// Discrete event kinds for the async gossip loop.
#[derive(Debug, Clone)]
pub enum EventKind {
    /// client `client` is ready to start its next local iteration
    Resume {
        /// client id
        client: usize,
    },
    /// a gossip message reaches its receiver
    Deliver {
        /// receiving client id
        to: usize,
        /// the message (payload + provenance), shared across the
        /// sender's per-neighbor deliveries instead of deep-cloned
        msg: Arc<Message>,
    },
}

/// A timestamped simulator event; ordering is `(time, seq)` so ties break
/// deterministically in insertion order.
#[derive(Debug, Clone)]
pub struct Event {
    /// virtual-time firing point, seconds
    pub time: f64,
    /// global insertion sequence (tie-breaker)
    pub seq: u64,
    /// what happens
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time) == Ordering::Equal && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap event queue with deterministic FIFO tie-breaking.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<Event>>,
    seq: u64,
}

impl EventQueue {
    /// Empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at absolute virtual time `time`.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(std::cmp::Reverse(Event { time, seq, kind }));
    }

    /// Pop the earliest event (FIFO among equal timestamps).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|r| r.0)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Payload;

    #[test]
    fn ideal_network_is_transparent() {
        let mut net = IdealNetwork;
        for r in 0..100 {
            assert!(net.delivers(0, 1, r));
            assert!(net.online(r % 4, r));
        }
        assert_eq!(net.latency_s(0, 1, 1 << 20), 0.0);
        assert_eq!(net.compute_multiplier(3), 1.0);
    }

    #[test]
    fn lossy_drop_fraction_matches_rate() {
        let mut net = FaultConfig::lossy(0.3).build();
        let mut dropped = 0usize;
        let n = 50_000;
        for r in 0..n {
            if !net.delivers(0, 1, r) {
                dropped += 1;
            }
        }
        let frac = dropped as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.02, "observed drop fraction {frac}");
    }

    #[test]
    fn faulty_network_is_deterministic() {
        let decisions = |seed: u64| {
            let mut net = FaultConfig::hostile().with_seed(seed).build();
            (0..500)
                .map(|r| {
                    (
                        net.delivers(r % 3, (r + 1) % 3, r),
                        net.online(r % 5, r),
                        net.latency_s(0, 1, 100).to_bits(),
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(decisions(7), decisions(7));
        assert_ne!(decisions(7), decisions(8));
    }

    #[test]
    fn bursts_drop_in_runs() {
        // rare bursts (mean good run ~100 msgs) of total loss (~10 msgs):
        // overall drop fraction ~9%, but heavily clustered
        let cfg = FaultConfig {
            drop_rate: 0.0,
            burst_rate: 0.01,
            burst_len: 10.0,
            burst_drop: 1.0,
            ..Default::default()
        };
        let mut net = cfg.build();
        let outcomes: Vec<bool> = (0..20_000).map(|r| net.delivers(0, 1, r)).collect();
        let total_drops = outcomes.iter().filter(|d| !**d).count();
        assert!(total_drops > 500, "bursts never engaged ({total_drops} drops)");
        // drops must cluster: count drop->drop adjacencies vs what i.i.d.
        // loss at the same rate would produce
        let pairs = outcomes.windows(2).filter(|w| !w[0] && !w[1]).count();
        let p = total_drops as f64 / outcomes.len() as f64;
        let iid_pairs = (outcomes.len() as f64 * p * p) as usize;
        assert!(pairs > 4 * iid_pairs, "no clustering: {pairs} pairs vs iid {iid_pairs}");
    }

    #[test]
    fn stragglers_are_a_stable_subset() {
        let cfg = FaultConfig {
            straggler_frac: 0.25,
            straggler_ids: vec![3],
            ..Default::default()
        };
        let mut net = cfg.build();
        let mults: Vec<f64> = (0..16).map(|k| net.compute_multiplier(k)).collect();
        let again: Vec<f64> = (0..16).map(|k| net.compute_multiplier(k)).collect();
        assert_eq!(mults, again, "straggler assignment must be static");
        assert!(mults[3] > 1.0, "explicit straggler id ignored");
        let slow = mults.iter().filter(|&&m| m > 1.0).count();
        assert!((1..=12).contains(&slow), "straggler count {slow} out of band");
    }

    #[test]
    fn latency_is_static_per_link_and_charges_bandwidth() {
        let mut net = FaultConfig::wan().build();
        let l1 = net.latency_s(2, 5, 1000);
        let l2 = net.latency_s(2, 5, 1000);
        assert_eq!(l1, l2, "per-link latency must be static");
        assert_eq!(net.latency_s(5, 2, 1000), l1, "latency must be symmetric");
        let bigger = net.latency_s(2, 5, 1_000_000);
        assert!(bigger > l1, "bandwidth term missing");
    }

    #[test]
    fn churn_takes_clients_offline_sometimes() {
        let mut net = FaultConfig::churning().build();
        let mut offline = 0;
        let mut total = 0;
        for k in 0..8 {
            for r in (0..5000).step_by(50) {
                total += 1;
                if !net.online(k, r) {
                    offline += 1;
                }
            }
        }
        let frac = offline as f64 / total as f64;
        assert!(frac > 0.02 && frac < 0.3, "churn fraction {frac}");
        // stable within a period
        assert_eq!(net.online(0, 0), net.online(0, 49));
    }

    #[test]
    fn event_queue_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::Resume { client: 0 });
        q.push(1.0, EventKind::Resume { client: 1 });
        q.push(1.0, EventKind::Resume { client: 2 });
        q.push(0.5, EventKind::Deliver { to: 3, msg: Arc::new(dummy_msg()) });
        let mut order = Vec::new();
        while let Some(ev) = q.pop() {
            order.push(match ev.kind {
                EventKind::Resume { client } => client,
                EventKind::Deliver { to, .. } => to,
            });
        }
        assert_eq!(order, vec![3, 1, 2, 0]);
    }

    #[test]
    fn virtual_clock_barriers() {
        let mut c = VirtualClock::default();
        c.advance(1.0);
        c.note_latency(0.25);
        c.note_latency(0.75);
        c.note_latency(0.5);
        c.flush_latency();
        assert!((c.now() - 1.75).abs() < 1e-12);
        c.advance_to(1.0); // never backwards
        assert!((c.now() - 1.75).abs() < 1e-12);
        c.advance_to(3.0);
        assert!((c.now() - 3.0).abs() < 1e-12);
    }

    fn dummy_msg() -> Message {
        Message { from: 0, mode: 1, round: 0, payload: Payload::Zero { len: 4 } }
    }

    #[test]
    fn scenario_names_resolve() {
        assert!(FaultConfig::by_name("ideal").unwrap().is_none());
        let lossy = FaultConfig::by_name("lossy:0.35").unwrap().unwrap();
        assert!((lossy.drop_rate - 0.35).abs() < 1e-12);
        for name in ["bursty", "wan", "stragglers", "churning", "hostile"] {
            assert!(FaultConfig::by_name(name).unwrap().is_some(), "{name}");
        }
        assert!(FaultConfig::by_name("carrier-pigeon").is_err());
        assert!(FaultConfig::by_name("lossy:x").is_err());
    }
}
