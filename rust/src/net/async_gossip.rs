//! Event-driven asynchronous gossip: no barriers, no lock-step rounds.
//!
//! Each client is a state machine advanced by a deterministic discrete-
//! event loop ([`EventQueue`]): a `Resume` event runs one local iteration
//! (compute time scaled by the client's straggler multiplier), publishes
//! compressed deltas whose `Deliver` events fire after the link's latency,
//! and immediately runs consensus with whatever peer estimates it
//! currently holds. Deltas that arrive late are simply *stale* — the
//! CHOCO-style difference encoding in [`crate::gossip`] accumulates them
//! into `Â` whenever they land, which is exactly the staleness the
//! compressed-consensus analysis tolerates (paper Thm. III.2; see also
//! the asynchronous-gossip lineage of Lian et al. AD-PSGD).
//!
//! Differences from the lock-step paths, by design:
//! * clients at different virtual times mix estimates of different ages
//!   (`RunRecord.net.stale` counts how often),
//! * a straggler no longer stalls the fleet — fast clients keep
//!   iterating, which is the wall-clock argument for going async,
//! * per-epoch losses are evaluated when *each client* crosses its own
//!   epoch boundary, so curves are comparable but not barrier-aligned.
//!
//! Determinism: all stochasticity comes from seeded streams; the event
//! queue breaks timestamp ties FIFO. Two runs with the same config are
//! bit-identical (asserted in `tests/network_sim.rs`).

use std::sync::Arc;

use crate::engine::client::ClientState;
use crate::engine::metrics::MetricPoint;
use crate::engine::{
    apply_error_feedback, assemble_global, build_clients, finalize_record, publish_one,
    TrainConfig, TrainOutcome,
};
use crate::factor::{fms::fms, FactorSet};
use crate::gossip::Message;
use crate::net::sim::{EventKind, EventQueue, NetworkModel};
use crate::runtime::ComputeBackend;
use crate::sched::BlockSampler;
use crate::data::Dataset;
use crate::topology::Graph;

/// One client's simulation wrapper.
struct Node {
    c: ClientState,
    sampler: BlockSampler,
    /// local iteration counter (the client's own clock)
    iter: usize,
    /// messages that have arrived but not yet been consumed
    inbox: Vec<Arc<Message>>,
    done: bool,
}

/// Run `cfg` under event-driven asynchronous gossip over `net`.
///
/// See the module docs for semantics. The returned record's `points`
/// carry virtual-time stamps (the slowest client's crossing time per
/// epoch slot), and `net` counts delivered/dropped/stale messages.
pub fn train_async(
    cfg: &TrainConfig,
    data: &Dataset,
    backend: &mut dyn ComputeBackend,
    net: &mut dyn NetworkModel,
    fms_reference: Option<&FactorSet>,
) -> anyhow::Result<TrainOutcome> {
    let d_order = data.tensor.dims.len();
    anyhow::ensure!(cfg.rank >= 1 && cfg.k >= 1 && cfg.algo.tau >= 1);
    anyhow::ensure!(
        cfg.adversary.is_none(),
        "the async driver does not support Byzantine clients yet — use seq or sim"
    );
    backend.set_threads(cfg.compute_threads);
    let graph = Graph::build(cfg.topology, cfg.k)?;
    let decentralized = cfg.k > 1;
    let trigger = cfg.trigger_schedule();
    let all_modes: Vec<usize> = (0..d_order).collect();
    let total_iters = cfg.epochs * cfg.iters_per_epoch;
    let n_points = cfg.epochs + 1;

    let mut nodes: Vec<Node> = build_clients(cfg, data, &graph)
        .into_iter()
        .map(|c| Node {
            c,
            // every client samples the same per-iteration mode sequence —
            // the lock-step protocol's shared d_ξ[t], indexed by *local*
            // iteration under asynchrony
            sampler: BlockSampler::new(d_order, cfg.seed, true),
            iter: 0,
            inbox: Vec::new(),
            done: total_iters == 0,
        })
        .collect();

    // per-epoch-slot accumulators (same layout as train_parallel)
    let mut losses = vec![0.0f64; n_points];
    let mut bytes_per_point = vec![0u64; n_points];
    let mut times = vec![0.0f64; n_points];
    for node in nodes.iter_mut() {
        losses[0] += node.c.eval_loss(cfg.loss, backend)?;
    }

    let mut q = EventQueue::new();
    for k in 0..cfg.k {
        q.push(0.0, EventKind::Resume { client: k });
    }

    let mut final_time = 0.0f64;
    while let Some(ev) = q.pop() {
        let now = ev.time;
        final_time = final_time.max(now);
        match ev.kind {
            EventKind::Deliver { to, msg } => {
                // arrivals after the receiver's last iteration are moot —
                // the run is over for it, so they count as neither
                // delivered nor dropped (no link fault occurred)
                if !nodes[to].done {
                    nodes[to].inbox.push(msg);
                }
            }
            EventKind::Resume { client: k } => {
                if nodes[k].done {
                    continue;
                }
                let t = nodes[k].iter;
                // the iteration starting now completes at `end` — compute
                // cost is charged whether the client works or sits out
                let end = now + cfg.sim_iter_s * net.compute_multiplier(k);
                final_time = final_time.max(end);
                if net.online(k, t) {
                    // 1) consume everything that has arrived (Alg. 1 line
                    //    16, applied lazily at the receiver's pace)
                    let msgs = std::mem::take(&mut nodes[k].inbox);
                    for msg in msgs {
                        let node = &mut nodes[k];
                        node.c
                            .estimates
                            .as_mut()
                            .expect("estimates")
                            .apply_delta(msg.from, msg.mode, &msg.payload);
                        node.c.net.delivered += 1;
                        // lock-step freshness is "consumed before the round
                        // after the sender's": anything older is stale
                        if msg.round + 1 < t {
                            node.c.net.stale += 1;
                        }
                    }

                    // 2) local step(s)
                    let sampled_mode = nodes[k].sampler.next_mode();
                    let modes: &[usize] = if cfg.algo.block_random {
                        std::slice::from_ref(&sampled_mode)
                    } else {
                        &all_modes
                    };
                    for &m in modes {
                        nodes[k].c.local_step(
                            m,
                            cfg.loss,
                            cfg.fiber_samples,
                            cfg.gamma,
                            cfg.algo.momentum,
                            backend,
                        )?;
                        if cfg.algo.error_feedback {
                            apply_error_feedback(&mut nodes[k].c, m, cfg.algo.compressor);
                        }
                    }

                    // 3) publish + consensus on communication rounds;
                    //    messages depart when the iteration *finishes*
                    if decentralized && t % cfg.algo.tau == 0 {
                        for &m in modes {
                            if m == 0 {
                                continue; // patient mode never travels
                            }
                            async_gossip_step(
                                &mut nodes[k], &graph, cfg, &trigger, net, &mut q, end, t, m,
                            );
                        }
                    }
                } else {
                    let node = &mut nodes[k];
                    node.c.net.offline_rounds += 1;
                    // anything queued for a down node is lost
                    let lost = node.inbox.len() as u64;
                    node.inbox.clear();
                    node.c.net.dropped += lost;
                }

                // 4) bookkeeping + next wake-up
                nodes[k].iter += 1;
                let done_iters = nodes[k].iter;
                if done_iters % cfg.iters_per_epoch == 0 {
                    let slot = done_iters / cfg.iters_per_epoch;
                    losses[slot] += nodes[k].c.eval_loss(cfg.loss, backend)?;
                    bytes_per_point[slot] += nodes[k].c.ledger.bytes;
                    times[slot] = times[slot].max(end);
                }
                if done_iters >= total_iters {
                    nodes[k].done = true;
                } else {
                    q.push(end, EventKind::Resume { client: k });
                }
            }
        }
    }

    let clients: Vec<ClientState> = nodes.into_iter().map(|n| n.c).collect();
    let factors = assemble_global(&clients);
    let fms_final = fms_reference.map(|r| fms(&factors, r));
    let points: Vec<MetricPoint> = (0..n_points)
        .map(|slot| MetricPoint {
            epoch: slot,
            iter: slot * cfg.iters_per_epoch,
            time_s: times[slot],
            loss: losses[slot],
            bytes: bytes_per_point[slot],
            fms: if slot + 1 == n_points { fms_final } else { None },
        })
        .collect();
    let record = finalize_record(cfg, &graph, &clients, points, final_time);
    Ok(TrainOutcome { record, factors })
}

/// One client's publish-then-consense step on mode `m` at local round `t`
/// (the async counterpart of the engine's gossip phases).
#[allow(clippy::too_many_arguments)]
fn async_gossip_step(
    node: &mut Node,
    graph: &Graph,
    cfg: &TrainConfig,
    trigger: &crate::sched::TriggerSchedule,
    net: &mut dyn NetworkModel,
    q: &mut EventQueue,
    depart: f64,
    t: usize,
    m: usize,
) {
    let k = node.c.id;
    if let Some(payload) = publish_one(&mut node.c, graph, cfg, trigger, t, m) {
        let msg = Arc::new(Message { from: k, mode: m, round: t, payload });
        // own estimate updates immediately (no wire involved)
        node.c.estimates.as_mut().expect("estimates").apply_delta(k, m, &msg.payload);
        let wire = msg.wire_bytes();
        for &j in &graph.neighbors[k] {
            if net.delivers(k, j, t) {
                let latency = net.latency_s(k, j, wire);
                q.push(depart + latency, EventKind::Deliver { to: j, msg: Arc::clone(&msg) });
            } else {
                node.c.net.dropped += 1;
            }
        }
    }

    // consensus with whatever estimates are on hand (stale included)
    let ClientState { estimates, factors, .. } = &mut node.c;
    cfg.aggregator.consensus_into(
        estimates.as_ref().expect("estimates"),
        &mut factors.mats[m],
        m,
        &graph.neighbors[k],
        &graph.weights[k],
        cfg.algo.rho,
    );
}
