//! Network execution layer: every way a CiderTF run can be *driven*.
//!
//! The paper's premise is that decentralized gossip removes the central
//! server's single point of failure — which only means something if the
//! system still behaves when the network misbehaves. This module provides
//! four execution paths over the same engine state, unified behind
//! [`driver::RoundDriver`]:
//!
//! | path | module | semantics |
//! |------|--------|-----------|
//! | sequential | [`crate::engine::train`] | lock-step rounds, in-process, wall-clock time |
//! | thread-parallel | [`parallel::train_parallel`] | lock-step rounds, one OS thread/client |
//! | sync simulator | [`driver::train_sim`] | lock-step rounds routed through a [`sim::NetworkModel`] (drops, latency, stragglers, churn) on a virtual clock |
//! | async gossip | [`async_gossip::train_async`] | event-driven: clients consume whatever peer deltas have *arrived*, no barriers |
//!
//! All four share `TrainConfig`, the comm ledger, and `RunRecord` (which
//! carries [`sim::NetStats`] delivery counters), so results are directly
//! comparable. With [`sim::IdealNetwork`] the sync simulator is
//! bit-identical to the sequential engine — asserted in tests — so the
//! fault envelope is the *only* difference a scenario introduces.
#![warn(missing_docs)]

pub mod async_gossip;
pub mod driver;
pub mod parallel;
pub mod sim;

pub use driver::{train_sim, DriverKind, RoundDriver};
pub use parallel::train_parallel;
pub use sim::{FaultConfig, IdealNetwork, NetStats, NetworkModel};
