//! Thread-parallel gossip runtime.
//!
//! The default engine (`engine::train`) executes clients sequentially —
//! deterministic and ideal for experiments. This module runs the *same*
//! protocol with one OS thread per client, synchronous rounds enforced by
//! barriers, and payload exchange through shared mailboxes: the deployment
//! shape of the coordinator (one process per hospital, lock-step gossip).
//!
//! Clients are built once on the main thread by the shared
//! `engine::build_clients` helper and **step over the shared data
//! plane**: each holds an `Arc<ShardData>` view (tensor + fiber indices
//! built once), so moving a client into its thread moves a pointer, not
//! a tensor copy, and all threads gather from the same read-only
//! allocations. Results are merged back in deterministic client-id
//! order.
//!
//! Determinism is preserved: every client draws from its own seeded
//! stream and the shared block sequence, so `train_parallel` produces
//! **bit-identical factors** to `engine::train` (asserted in tests) —
//! threads only change wall-clock, not results.
//!
//! For runs over *imperfect* networks (latency, loss, stragglers, churn)
//! see [`crate::net::driver`] and [`crate::net::sim`].

use std::sync::{Arc, Barrier, Mutex, RwLock};
use std::time::Instant;

use crate::compress::Payload;
use crate::engine::client::ClientState;
use crate::engine::metrics::MetricPoint;
use crate::engine::{
    apply_error_feedback, assemble_global, build_clients, finalize_record, publish_one,
    TrainConfig, TrainOutcome,
};
use crate::factor::{fms::fms, FactorSet};
use crate::runtime::ComputeBackend;
use crate::sched::BlockSampler;
use crate::data::Dataset;
use crate::topology::Graph;

/// Per-round mailbox: slot `k` holds client k's broadcast payload for the
/// current (mode, round), or `None` when its event trigger suppressed.
type Mailbox = Arc<Vec<RwLock<Option<Payload>>>>;

/// Run one configuration with one thread per client.
///
/// `make_backend(k)` builds client k's compute backend *inside its
/// thread* (PJRT clients are per-thread; the native mirror is cheap).
pub fn train_parallel<F>(
    cfg: &TrainConfig,
    data: &Dataset,
    make_backend: F,
    fms_reference: Option<&FactorSet>,
) -> anyhow::Result<TrainOutcome>
where
    F: Fn(usize) -> anyhow::Result<Box<dyn ComputeBackend>> + Sync,
{
    let k_clients = cfg.k;
    anyhow::ensure!(k_clients >= 1);
    anyhow::ensure!(
        cfg.adversary.is_none(),
        "the parallel driver does not support Byzantine clients yet — use seq or sim"
    );
    let graph = Arc::new(Graph::build(cfg.topology, k_clients)?);
    let decentralized = k_clients > 1;
    let d_order = data.tensor.dims.len();

    // clients built on the main thread by the shared helper (bit-identical
    // starting state across all execution paths), then moved into threads
    let initial_clients = build_clients(cfg, data, &graph);
    let barrier = Arc::new(Barrier::new(k_clients));
    let mailbox: Mailbox = Arc::new((0..k_clients).map(|_| RwLock::new(None)).collect());
    // per-epoch loss accumulator: (epoch slot) -> summed loss
    let total_iters = cfg.epochs * cfg.iters_per_epoch;
    let n_points = cfg.epochs + 1;
    let losses = Arc::new(Mutex::new(vec![0.0f64; n_points]));
    let bytes_per_point = Arc::new(Mutex::new(vec![0u64; n_points]));
    let trigger = cfg.trigger_schedule();
    // lint: allow(wall-clock) — per-thread wall timing only; feeds the
    // time_s curve column, never a deterministic aggregate
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();

    // lint: allow(raw-thread-spawn) — K barrier-synchronized client threads
    // that must all run concurrently; scheduling them as pool jobs would
    // deadlock the shared pool at the first barrier wait
    let results: Vec<anyhow::Result<(ClientState, Vec<f64>)>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(k_clients);
        for (id, mut client) in initial_clients.into_iter().enumerate() {
            let graph = Arc::clone(&graph);
            let barrier = Arc::clone(&barrier);
            let mailbox = Arc::clone(&mailbox);
            let losses = Arc::clone(&losses);
            let bytes_per_point = Arc::clone(&bytes_per_point);
            let cfg = cfg.clone();
            let make_backend = &make_backend;
            handles.push(scope.spawn(move || -> anyhow::Result<(ClientState, Vec<f64>)> {
                let mut backend = make_backend(id)?;
                backend.set_threads(cfg.compute_threads);
                // shared block sequence: same seed on every thread
                let mut block_sampler = BlockSampler::new(d_order, cfg.seed, true);
                let all_modes: Vec<usize> = (0..d_order).collect();
                let mut times = Vec::with_capacity(n_points);

                // epoch-0 metric point
                let l0 = client.eval_loss(cfg.loss, backend.as_mut())?;
                losses.lock().unwrap()[0] += l0;
                times.push(t0.elapsed().as_secs_f64());
                barrier.wait();

                for t in 0..total_iters {
                    let sampled_mode = block_sampler.next_mode();
                    let modes: &[usize] = if cfg.algo.block_random {
                        std::slice::from_ref(&sampled_mode)
                    } else {
                        &all_modes
                    };
                    for &m in modes {
                        client.local_step(
                            m,
                            cfg.loss,
                            cfg.fiber_samples,
                            cfg.gamma,
                            cfg.algo.momentum,
                            backend.as_mut(),
                        )?;
                        if cfg.algo.error_feedback {
                            apply_error_feedback(&mut client, m, cfg.algo.compressor);
                        }
                    }

                    if decentralized && t % cfg.algo.tau == 0 {
                        for &m in modes {
                            if m == 0 {
                                continue; // patient mode never travels
                            }
                            // 1) publish (Alg. 1 lines 10-14), via the
                            // shared single-client publish core
                            let payload = publish_one(&mut client, &graph, &cfg, &trigger, t, m);
                            *mailbox[id].write().unwrap() = payload;
                            barrier.wait(); // all published

                            // 2) deliver (line 16)
                            let mut delivered = 0;
                            {
                                let est = client.estimates.as_mut().expect("estimates");
                                if let Some(p) = mailbox[id].read().unwrap().as_ref() {
                                    est.apply_delta(id, m, p);
                                }
                                for &j in &graph.neighbors[id] {
                                    if let Some(p) = mailbox[j].read().unwrap().as_ref() {
                                        est.apply_delta(j, m, p);
                                        delivered += 1;
                                    }
                                }
                            }
                            client.net.delivered += delivered;
                            barrier.wait(); // all delivered before slots are reused

                            // 3) consensus (line 18)
                            let ClientState { estimates, factors, .. } = &mut client;
                            cfg.aggregator.consensus_into(
                                estimates.as_ref().expect("estimates"),
                                &mut factors.mats[m],
                                m,
                                &graph.neighbors[id],
                                &graph.weights[id],
                                cfg.algo.rho,
                            );
                        }
                    }

                    if (t + 1) % cfg.iters_per_epoch == 0 {
                        let slot = (t + 1) / cfg.iters_per_epoch;
                        let l = client.eval_loss(cfg.loss, backend.as_mut())?;
                        losses.lock().unwrap()[slot] += l;
                        bytes_per_point.lock().unwrap()[slot] += client.ledger.bytes;
                        times.push(t0.elapsed().as_secs_f64());
                        barrier.wait(); // consistent epoch boundaries
                    }
                }
                Ok((client, times))
            }));
        }
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
    });

    let mut clients = Vec::with_capacity(k_clients);
    let mut times: Vec<f64> = vec![0.0; n_points];
    for r in results {
        let (c, t) = r?;
        for (slot, v) in t.iter().enumerate() {
            times[slot] = times[slot].max(*v);
        }
        clients.push(c);
    }
    clients.sort_by_key(|c| c.id);

    let losses = Arc::try_unwrap(losses).unwrap().into_inner().unwrap();
    let bytes = Arc::try_unwrap(bytes_per_point).unwrap().into_inner().unwrap();
    let factors = assemble_global(&clients);
    let fms_final = fms_reference.map(|r| fms(&factors, r));
    let points: Vec<MetricPoint> = (0..n_points)
        .map(|slot| MetricPoint {
            epoch: slot,
            iter: slot * cfg.iters_per_epoch,
            time_s: times[slot],
            loss: losses[slot],
            bytes: bytes[slot],
            fms: if slot + 1 == n_points { fms_final } else { None },
        })
        .collect();
    let record = finalize_record(cfg, &graph, &clients, points, t0.elapsed().as_secs_f64());
    Ok(TrainOutcome { record, factors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{train, AlgoConfig};
    use crate::losses::Loss;
    use crate::runtime::native::NativeBackend;
    use crate::tensor::synth::SynthConfig;

    fn tiny_cfg(algo: AlgoConfig, k: usize) -> TrainConfig {
        let mut cfg = TrainConfig::new("tiny", Loss::Logit, algo);
        cfg.rank = 4;
        cfg.fiber_samples = 16;
        cfg.k = k;
        cfg.gamma = 0.5;
        cfg.iters_per_epoch = 60;
        cfg.epochs = 3;
        cfg.eval_batch = 64;
        cfg
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let data = SynthConfig::tiny(42).generate();
        let cfg = tiny_cfg(AlgoConfig::cidertf(2), 4);
        let mut backend = NativeBackend::new();
        let seq = train(&cfg, &data, &mut backend, None).unwrap();
        let par = train_parallel(
            &cfg,
            &data,
            |_| Ok(Box::new(NativeBackend::new()) as Box<dyn ComputeBackend>),
            None,
        )
        .unwrap();
        for (a, b) in seq.factors.mats.iter().zip(par.factors.mats.iter()) {
            assert_eq!(a.data, b.data, "parallel and sequential factors diverge");
        }
        assert_eq!(seq.record.total.bytes, par.record.total.bytes);
        assert_eq!(seq.record.total.triggered, par.record.total.triggered);
        assert_eq!(seq.record.net.delivered, par.record.net.delivered);
        // per-epoch loss sums agree
        for (p, q) in seq.record.points.iter().zip(par.record.points.iter()) {
            assert!((p.loss - q.loss).abs() < 1e-6 * p.loss.abs().max(1.0));
        }
    }

    #[test]
    fn parallel_all_mode_algorithms_match_too() {
        let data = SynthConfig::tiny(7).generate();
        let cfg = tiny_cfg(AlgoConfig::dpsgd_sign(), 3);
        let mut backend = NativeBackend::new();
        let seq = train(&cfg, &data, &mut backend, None).unwrap();
        let par = train_parallel(
            &cfg,
            &data,
            |_| Ok(Box::new(NativeBackend::new()) as Box<dyn ComputeBackend>),
            None,
        )
        .unwrap();
        for (a, b) in seq.factors.mats.iter().zip(par.factors.mats.iter()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn parallel_k1_centralized() {
        let data = SynthConfig::tiny(9).generate();
        let cfg = tiny_cfg(AlgoConfig::bras_cpd(), 1);
        let par = train_parallel(
            &cfg,
            &data,
            |_| Ok(Box::new(NativeBackend::new()) as Box<dyn ComputeBackend>),
            None,
        )
        .unwrap();
        assert_eq!(par.record.total.bytes, 0);
        assert!(par.record.final_loss().is_finite());
    }
}
