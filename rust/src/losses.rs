//! GCP elementwise losses — the Rust mirror of `python/compile/kernels/
//! losses.py`. The Rust side needs them for the native differential-test
//! gradient path and for exact small-oracle loss evaluation; the PJRT
//! artifacts carry the authoritative implementations at train time.

/// Which elementwise GCP loss models the data (paper eq. 3-4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Loss {
    /// least squares — Gaussian data, classic CP
    Ls,
    /// Bernoulli-logit — binary data (implemented per the cited GCP papers:
    /// `f = log(1+e^m) - x m`; the paper's eq. (4) as printed is a typo,
    /// see DESIGN.md substitutions)
    Logit,
}

impl Loss {
    pub fn name(self) -> &'static str {
        match self {
            Loss::Ls => "ls",
            Loss::Logit => "logit",
        }
    }

    /// Look up a loss by CLI name (thin wrapper over
    /// [`crate::registry::losses`]).
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        crate::registry::losses().resolve(s)
    }

    /// f(m, x)
    #[inline]
    pub fn value(self, m: f32, x: f32) -> f32 {
        match self {
            Loss::Ls => {
                let d = m - x;
                d * d
            }
            // log(1 + e^m) - x m, stable for large |m|
            Loss::Logit => {
                let softplus = if m > 0.0 { m + (-m).exp().ln_1p() } else { m.exp().ln_1p() };
                softplus - x * m
            }
        }
    }

    /// df/dm
    #[inline]
    pub fn grad(self, m: f32, x: f32) -> f32 {
        match self {
            Loss::Ls => 2.0 * (m - x),
            Loss::Logit => sigmoid(m) - x,
        }
    }
}

#[inline]
pub fn sigmoid(m: f32) -> f32 {
    if m >= 0.0 {
        1.0 / (1.0 + (-m).exp())
    } else {
        let e = m.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ls_value_and_grad() {
        assert_eq!(Loss::Ls.value(3.0, 1.0), 4.0);
        assert_eq!(Loss::Ls.grad(3.0, 1.0), 4.0);
        assert_eq!(Loss::Ls.grad(1.0, 1.0), 0.0);
    }

    #[test]
    fn logit_matches_bernoulli_nll() {
        for &m in &[-5.0f32, -0.5, 0.0, 0.5, 5.0] {
            for &x in &[0.0f32, 1.0] {
                let p = sigmoid(m);
                let nll = -(x * p.ln() + (1.0 - x) * (1.0 - p).ln());
                let f = Loss::Logit.value(m, x);
                assert!((f - nll).abs() < 1e-5, "m={m} x={x}: {f} vs {nll}");
            }
        }
    }

    #[test]
    fn logit_grad_is_derivative() {
        let eps = 1e-3f32;
        for &m in &[-2.0f32, -0.1, 0.0, 0.7, 3.0] {
            for &x in &[0.0f32, 1.0] {
                let fd = (Loss::Logit.value(m + eps, x) - Loss::Logit.value(m - eps, x)) / (2.0 * eps);
                assert!((fd - Loss::Logit.grad(m, x)).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn logit_stable_for_large_m() {
        assert!(Loss::Logit.value(80.0, 1.0).is_finite());
        assert!(Loss::Logit.value(-80.0, 0.0).is_finite());
        assert!((Loss::Logit.value(80.0, 1.0) - 0.0).abs() < 1e-3);
        assert!((Loss::Logit.grad(80.0, 0.0) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn names_roundtrip() {
        for l in [Loss::Ls, Loss::Logit] {
            assert_eq!(Loss::from_name(l.name()).unwrap(), l);
        }
        assert!(Loss::from_name("poisson").is_err());
    }
}
