//! Byzantine client simulation — the adversary axis of the experiment
//! plane.
//!
//! CiderTF's decentralized setting exists because a central server is an
//! attack target, yet honest-only simulation says nothing about what a
//! *compromised site* does to convergence. This module corrupts gossip
//! payloads at publish time, after compression and ledger accounting:
//! the wire carries whatever the adversary emits, every neighbor of a
//! Byzantine client receives the same corrupted delta (matching the
//! broadcast model of the honest path), and the comm ledger keeps the
//! honest byte count the client *claims* to have sent.
//!
//! # Determinism
//!
//! Which clients are Byzantine is a static trait of
//! ([`AdversarySchedule::seed`], client id) via the same unit-hash used
//! for straggler assignment — independent of call order. The
//! `scaled_noise` attack derives a fresh RNG from
//! `(seed, client, round, mode)` per corruption, so adversarial noise is
//! a pure function of its coordinates: bit-identical across drivers,
//! worker counts, and checkpoint/resume. `stale_replay` carries a replay
//! buffer that is serialized into checkpoints
//! ([`Adversary::state_json`]), preserving bit-exact resume.
//!
//! The default seed [`AdversarySchedule::DEFAULT_SEED`] is a sentinel:
//! specs replace it with the run seed at materialization (same
//! inheritance rule as [`crate::net::sim::FaultConfig`]), so two runs
//! differing only in `seed` get different Byzantine subsets.
//!
//! # Allocation discipline
//!
//! The per-iteration compute loop is allocation-free in steady state
//! (gated by `tests/alloc_free.rs`); corruption runs on the per-round
//! *publish* path, which already materializes wire payloads. Within
//! that budget: `sign_flip` corrupts strictly in place (it negates a
//! dense/top-k buffer or the sign payload's scale — zero allocations);
//! `scaled_noise` and `stale_replay` decode one dense matrix per
//! corrupted payload, the same order of traffic the publish encoding
//! itself performs. None of the attacks allocate on iterations where
//! no gossip round fires.

use std::collections::{BTreeMap, VecDeque};

use crate::compress::Payload;
use crate::net::sim::unit_hash;
use crate::util::json::Json;
use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// Which attack a Byzantine client mounts.
#[derive(Debug, Clone, PartialEq)]
pub enum AdversaryKind {
    /// Negate every published delta (gradient-reversal attack).
    SignFlip,
    /// Add `N(0, σ²)` noise to every published delta (σ = the payload's
    /// scale is *not* consulted — large σ swamps the honest signal).
    ScaledNoise(f64),
    /// Replay the delta published `age` rounds ago for the same mode
    /// (model-poisoning via stale updates; honest until the buffer
    /// fills).
    StaleReplay(usize),
}

impl AdversaryKind {
    /// Registry key for this attack.
    pub fn name(&self) -> &'static str {
        match self {
            AdversaryKind::SignFlip => "sign_flip",
            AdversaryKind::ScaledNoise(_) => "scaled_noise",
            AdversaryKind::StaleReplay(_) => "stale_replay",
        }
    }
}

/// Spec-carried adversary axis: which attack, what fraction of clients
/// mount it, and the seed that picks the Byzantine subset.
#[derive(Debug, Clone, PartialEq)]
pub struct AdversarySchedule {
    /// the attack every Byzantine client mounts
    pub kind: AdversaryKind,
    /// fraction of clients that are Byzantine (deterministic subset)
    pub fraction: f64,
    /// subset-selection + noise seed; [`Self::DEFAULT_SEED`] is a
    /// sentinel replaced by the run seed at materialization
    pub seed: u64,
}

impl AdversarySchedule {
    /// Sentinel seed meaning "inherit the experiment seed".
    pub const DEFAULT_SEED: u64 = 0xAD5E;
    /// Default Byzantine fraction for registry string forms.
    pub const DEFAULT_FRACTION: f64 = 0.2;
    /// Default `scaled_noise` σ.
    pub const DEFAULT_SIGMA: f64 = 8.0;
    /// Default `stale_replay` age (rounds).
    pub const DEFAULT_AGE: usize = 5;

    /// `sign_flip` schedule at `fraction` (registry constructor).
    pub fn sign_flip(fraction: f64) -> Self {
        AdversarySchedule { kind: AdversaryKind::SignFlip, fraction, seed: Self::DEFAULT_SEED }
    }

    /// `scaled_noise` schedule at `fraction` with the default σ.
    pub fn scaled_noise(fraction: f64) -> Self {
        AdversarySchedule {
            kind: AdversaryKind::ScaledNoise(Self::DEFAULT_SIGMA),
            fraction,
            seed: Self::DEFAULT_SEED,
        }
    }

    /// `stale_replay` schedule at `fraction` with the default age.
    pub fn stale_replay(fraction: f64) -> Self {
        AdversarySchedule {
            kind: AdversaryKind::StaleReplay(Self::DEFAULT_AGE),
            fraction,
            seed: Self::DEFAULT_SEED,
        }
    }

    /// Replace the sentinel seed with the run seed (no-op for an
    /// explicitly pinned seed) — call at materialization, like
    /// `FaultConfig` seed inheritance.
    pub fn inherit_seed(&mut self, run_seed: u64) {
        if self.seed == Self::DEFAULT_SEED {
            self.seed = run_seed;
        }
    }

    /// Is `client` Byzantine under this schedule? A static trait of
    /// `(seed, client)` — stable across rounds and call order.
    pub fn is_adversarial(&self, client: usize) -> bool {
        unit_hash(self.seed, client as u64, 0, 17) < self.fraction
    }

    /// The Byzantine subset of `0..k` (ascending, deterministic).
    pub fn adversarial_clients(&self, k: usize) -> Vec<usize> {
        (0..k).filter(|&c| self.is_adversarial(c)).collect()
    }

    /// Filesystem-safe label fragment for run stems (no `:`).
    pub fn label_component(&self) -> String {
        match &self.kind {
            AdversaryKind::SignFlip => format!("signflip{}", self.fraction),
            AdversaryKind::ScaledNoise(s) => format!("noise{}s{s}", self.fraction),
            AdversaryKind::StaleReplay(a) => format!("stale{}a{a}", self.fraction),
        }
    }

    /// Materialize the payload corruptor for one run.
    pub fn build(&self) -> Box<dyn Adversary> {
        match &self.kind {
            AdversaryKind::SignFlip => Box::new(SignFlip),
            AdversaryKind::ScaledNoise(sigma) => {
                Box::new(ScaledNoise { sigma: *sigma, seed: self.seed })
            }
            AdversaryKind::StaleReplay(age) => {
                Box::new(StaleReplay { age: *age, history: BTreeMap::new() })
            }
        }
    }

    /// Spec JSON object: `{"kind", "fraction", "seed"}` plus the
    /// kind-specific parameter (`"sigma"` or `"age"`).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("kind", Json::Str(self.kind.name().to_string())),
            ("fraction", Json::Num(self.fraction)),
        ];
        match &self.kind {
            AdversaryKind::SignFlip => {}
            AdversaryKind::ScaledNoise(s) => fields.push(("sigma", Json::Num(*s))),
            AdversaryKind::StaleReplay(a) => fields.push(("age", Json::Num(*a as f64))),
        }
        fields.push(("seed", Json::u64(self.seed)));
        Json::obj(fields)
    }

    /// Parse [`AdversarySchedule::to_json`] back (strict keys; `seed`
    /// optional → sentinel, parameters optional → kind defaults).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        j.ensure_known_keys("adversary", &["kind", "fraction", "sigma", "age", "seed"])?;
        let kind = match j.req_str("kind")? {
            "sign_flip" => AdversaryKind::SignFlip,
            "scaled_noise" => {
                let sigma = match j.get("sigma") {
                    None => Self::DEFAULT_SIGMA,
                    Some(v) => {
                        v.as_f64().ok_or_else(|| anyhow::anyhow!("bad adversary 'sigma'"))?
                    }
                };
                AdversaryKind::ScaledNoise(sigma)
            }
            "stale_replay" => {
                let age = match j.get("age") {
                    None => Self::DEFAULT_AGE,
                    Some(v) => {
                        v.as_usize().ok_or_else(|| anyhow::anyhow!("bad adversary 'age'"))?
                    }
                };
                AdversaryKind::StaleReplay(age)
            }
            other => anyhow::bail!("unknown adversary kind '{other}'"),
        };
        let fraction = j.req_f64("fraction")?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&fraction),
            "adversary fraction {fraction} outside [0, 1]"
        );
        let seed = match j.get("seed") {
            None => Self::DEFAULT_SEED,
            Some(v) => v.as_u64().ok_or_else(|| anyhow::anyhow!("bad adversary 'seed'"))?,
        };
        Ok(AdversarySchedule { kind, fraction, seed })
    }
}

/// A payload corruptor, applied after compression at publish time.
pub trait Adversary {
    /// The attack's registry name (for events/observers).
    fn kind_name(&self) -> &'static str;

    /// Corrupt `payload` in place. `rows x cols` is the decoded shape of
    /// the mode-`mode` delta; `client`/`round` feed deterministic
    /// per-corruption randomness.
    fn corrupt(
        &mut self,
        client: usize,
        mode: usize,
        round: usize,
        rows: usize,
        cols: usize,
        payload: &mut Payload,
    );

    /// Checkpointable internal state (`Json::Null` for stateless
    /// attacks).
    fn state_json(&self) -> Json {
        Json::Null
    }

    /// Restore a [`Adversary::state_json`] snapshot.
    fn restore_state(&mut self, j: &Json) -> anyhow::Result<()> {
        anyhow::ensure!(matches!(j, Json::Null), "unexpected adversary state for stateless attack");
        Ok(())
    }
}

/// Negates every published delta without touching its wire encoding.
struct SignFlip;

impl Adversary for SignFlip {
    fn kind_name(&self) -> &'static str {
        "sign_flip"
    }

    fn corrupt(
        &mut self,
        _client: usize,
        _mode: usize,
        _round: usize,
        _rows: usize,
        _cols: usize,
        payload: &mut Payload,
    ) {
        match payload {
            Payload::Dense(v) => v.iter_mut().for_each(|x| *x = -*x),
            // decode emits ±scale by bit: negating the scale flips every
            // sign while keeping the exact wire size
            Payload::Sign { scale, .. } => *scale = -*scale,
            Payload::TopK { values, .. } => values.iter_mut().for_each(|x| *x = -*x),
            Payload::Zero { .. } => {}
        }
    }
}

/// Adds `N(0, σ²)` noise to the decoded delta and republishes it dense.
struct ScaledNoise {
    sigma: f64,
    seed: u64,
}

impl Adversary for ScaledNoise {
    fn kind_name(&self) -> &'static str {
        "scaled_noise"
    }

    fn corrupt(
        &mut self,
        client: usize,
        mode: usize,
        round: usize,
        rows: usize,
        cols: usize,
        payload: &mut Payload,
    ) {
        // fresh stream per (client, round, mode): the noise is a pure
        // function of its coordinates, so resume replays it bit-exactly
        let mut rng = Rng::new(self.seed ^ 0x5CA1_ED00)
            .split(client as u64)
            .split(round as u64)
            .split(mode as u64);
        let mut m = payload.decode(rows, cols);
        for x in m.data.iter_mut() {
            *x += (self.sigma * rng.normal()) as f32;
        }
        *payload = Payload::Dense(m.data);
    }
}

/// Replays the delta published `age` rounds ago for the same mode.
struct StaleReplay {
    age: usize,
    /// per-(client, mode) ring of decoded published deltas, oldest first
    history: BTreeMap<(usize, usize), VecDeque<Mat>>,
}

impl Adversary for StaleReplay {
    fn kind_name(&self) -> &'static str {
        "stale_replay"
    }

    fn corrupt(
        &mut self,
        client: usize,
        mode: usize,
        round: usize,
        rows: usize,
        cols: usize,
        payload: &mut Payload,
    ) {
        let _ = round;
        let q = self.history.entry((client, mode)).or_default();
        q.push_back(payload.decode(rows, cols));
        if q.len() > self.age {
            let stale = q.pop_front().expect("non-empty replay buffer");
            *payload = Payload::Dense(stale.data);
        }
    }

    fn state_json(&self) -> Json {
        let entries: Vec<Json> = self
            .history
            .iter()
            .map(|(&(client, mode), q)| {
                let deltas: Vec<Json> = q
                    .iter()
                    .map(|m| {
                        Json::obj(vec![
                            ("r", Json::Num(m.rows as f64)),
                            ("c", Json::Num(m.cols as f64)),
                            ("b", Json::Str(m.encode_bits())),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("client", Json::Num(client as f64)),
                    ("mode", Json::Num(mode as f64)),
                    ("deltas", Json::Arr(deltas)),
                ])
            })
            .collect();
        Json::obj(vec![("history", Json::Arr(entries))])
    }

    fn restore_state(&mut self, j: &Json) -> anyhow::Result<()> {
        self.history.clear();
        if matches!(j, Json::Null) {
            return Ok(());
        }
        for entry in j.req_array("history")? {
            let client = entry.req_usize("client")?;
            let mode = entry.req_usize("mode")?;
            let mut q = VecDeque::new();
            for d in entry.req_array("deltas")? {
                q.push_back(Mat::decode_bits(d.req_usize("r")?, d.req_usize("c")?, d.req_str("b")?)?);
            }
            self.history.insert((client, mode), q);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Compressor;

    fn delta(seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::rand_normal(4, 3, 1.0, &mut rng)
    }

    #[test]
    fn subset_is_stable_and_fraction_sized() {
        let sched = AdversarySchedule::sign_flip(0.2);
        let a = sched.adversarial_clients(200);
        let b = sched.adversarial_clients(200);
        assert_eq!(a, b, "static per-client trait");
        // ~20% of 200 with unit-hash scatter
        assert!((20..=60).contains(&a.len()), "got {} adversaries", a.len());
        // a different seed picks a different subset
        let mut other = sched.clone();
        other.seed = 99;
        assert_ne!(a, other.adversarial_clients(200));
    }

    #[test]
    fn sentinel_seed_inherits_run_seed_but_pinned_stays() {
        let mut s = AdversarySchedule::sign_flip(0.3);
        s.inherit_seed(7);
        assert_eq!(s.seed, 7);
        let mut pinned = AdversarySchedule::sign_flip(0.3);
        pinned.seed = 42;
        pinned.inherit_seed(7);
        assert_eq!(pinned.seed, 42);
    }

    #[test]
    fn sign_flip_negates_every_encoding() {
        let m = delta(1);
        let mut adv = AdversarySchedule::sign_flip(1.0).build();
        for comp in [Compressor::None, Compressor::Sign, Compressor::TopK { ratio: 4 }] {
            let mut p = comp.compress(&m);
            let honest = p.decode(4, 3);
            adv.corrupt(0, 1, 0, 4, 3, &mut p);
            let corrupted = p.decode(4, 3);
            for (h, c) in honest.data.iter().zip(corrupted.data.iter()) {
                assert_eq!((-h).to_bits(), c.to_bits(), "{comp:?}");
            }
        }
    }

    #[test]
    fn scaled_noise_is_deterministic_per_coordinates() {
        let m = delta(2);
        let sched = AdversarySchedule::scaled_noise(1.0);
        let mut a = sched.build();
        let mut b = sched.build();
        let mut pa = Compressor::None.compress(&m);
        let mut pb = Compressor::None.compress(&m);
        a.corrupt(3, 1, 10, 4, 3, &mut pa);
        b.corrupt(3, 1, 10, 4, 3, &mut pb);
        assert_eq!(pa.decode(4, 3).data, pb.decode(4, 3).data);
        // different round -> different noise
        let mut pc = Compressor::None.compress(&m);
        b.corrupt(3, 1, 11, 4, 3, &mut pc);
        assert_ne!(pa.decode(4, 3).data, pc.decode(4, 3).data);
        // and the corruption actually moved the payload
        assert_ne!(pa.decode(4, 3).data, m.data);
    }

    #[test]
    fn stale_replay_is_honest_until_the_buffer_fills() {
        let mut adv = AdversarySchedule::stale_replay(1.0).build();
        let deltas: Vec<Mat> = (0..4).map(|i| delta(10 + i)).collect();
        let mut published = Vec::new();
        for (round, d) in deltas.iter().enumerate() {
            let mut p = Compressor::None.compress(d);
            adv.corrupt(0, 1, round, 4, 3, &mut p);
            published.push(p.decode(4, 3));
        }
        // DEFAULT_AGE = 5 > 4 rounds: everything still honest
        for (d, p) in deltas.iter().zip(published.iter()) {
            assert_eq!(d.data, p.data);
        }
        // age = 2: round t >= 2 republishes round t-2
        let sched = AdversarySchedule {
            kind: AdversaryKind::StaleReplay(2),
            fraction: 1.0,
            seed: 1,
        };
        let mut adv = sched.build();
        let mut published = Vec::new();
        for (round, d) in deltas.iter().enumerate() {
            let mut p = Compressor::None.compress(d);
            adv.corrupt(0, 1, round, 4, 3, &mut p);
            published.push(p.decode(4, 3));
        }
        assert_eq!(published[0].data, deltas[0].data);
        assert_eq!(published[1].data, deltas[1].data);
        assert_eq!(published[2].data, deltas[0].data);
        assert_eq!(published[3].data, deltas[1].data);
    }

    #[test]
    fn stale_replay_state_round_trips_bit_exactly() {
        let sched = AdversarySchedule {
            kind: AdversaryKind::StaleReplay(3),
            fraction: 1.0,
            seed: 1,
        };
        let mut adv = sched.build();
        for round in 0..2 {
            let mut p = Compressor::None.compress(&delta(20 + round as u64));
            adv.corrupt(1, 2, round, 4, 3, &mut p);
        }
        let snap = adv.state_json();
        let mut restored = sched.build();
        restored.restore_state(&snap).unwrap();
        // both continue identically
        for round in 2..6 {
            let d = delta(20 + round as u64);
            let mut pa = Compressor::None.compress(&d);
            let mut pb = Compressor::None.compress(&d);
            adv.corrupt(1, 2, round, 4, 3, &mut pa);
            restored.corrupt(1, 2, round, 4, 3, &mut pb);
            assert_eq!(pa.decode(4, 3).data, pb.decode(4, 3).data, "round {round}");
        }
    }

    #[test]
    fn schedule_json_round_trips() {
        let scheds = [
            AdversarySchedule::sign_flip(0.2),
            AdversarySchedule::scaled_noise(0.35),
            AdversarySchedule::stale_replay(0.1),
            AdversarySchedule { kind: AdversaryKind::ScaledNoise(2.5), fraction: 0.4, seed: 77 },
        ];
        for s in &scheds {
            let back = AdversarySchedule::from_json(&s.to_json()).unwrap();
            assert_eq!(&back, s);
        }
        assert!(AdversarySchedule::from_json(&Json::obj(vec![
            ("kind", Json::Str("sign_flip".into())),
            ("fraction", Json::Num(1.5)),
        ]))
        .is_err());
        assert!(AdversarySchedule::from_json(&Json::obj(vec![
            ("kind", Json::Str("gradient_ascent".into())),
            ("fraction", Json::Num(0.2)),
        ]))
        .is_err());
    }
}
