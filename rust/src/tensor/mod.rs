//! Sparse tensor substrate: COO storage, mode-d fiber addressing, and the
//! horizontal (patient-mode) partitioner used by the decentralized setting.
//!
//! Conventions follow the paper / Kolda: a D-order tensor `X` with dims
//! `I_1..I_D`; its mode-d matricization `X_<d>` is `I_d x (I_Pi / I_d)`.
//! A *mode-d fiber* is one column of `X_<d>`, addressed by a fiber id that
//! mixed-radix-encodes the indices of all modes except `d` (modes in
//! increasing order, first mode fastest — Kolda's unfolding order).

pub mod fiber;
pub mod partition;
pub mod synth;

// lint: allow(hash-structure) — membership probes only (see cell_set)
use std::collections::HashSet;

/// COO sparse tensor, f32 values, u32 per-mode indices.
#[derive(Debug, Clone)]
pub struct SparseTensor {
    /// mode sizes `I_1..I_D`
    pub dims: Vec<usize>,
    /// entry indices, row-major per entry: `idx[e*D + m]` is mode-m index
    pub idx: Vec<u32>,
    /// entry values, `vals[e]`
    pub vals: Vec<f32>,
}

impl SparseTensor {
    pub fn new(dims: Vec<usize>) -> Self {
        assert!(dims.len() >= 2);
        assert!(dims.iter().all(|&d| d > 0 && d < u32::MAX as usize));
        SparseTensor { dims, idx: Vec::new(), vals: Vec::new() }
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Total number of cells `I_Pi`.
    pub fn n_cells(&self) -> f64 {
        self.dims.iter().map(|&d| d as f64).product()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / self.n_cells()
    }

    /// Number of mode-d fibers, `I_Pi / I_d`.
    pub fn n_fibers(&self, mode: usize) -> usize {
        self.dims
            .iter()
            .enumerate()
            .filter(|(m, _)| *m != mode)
            .map(|(_, &d)| d)
            .product()
    }

    pub fn push(&mut self, index: &[u32], val: f32) {
        debug_assert_eq!(index.len(), self.order());
        debug_assert!(index.iter().zip(&self.dims).all(|(&i, &d)| (i as usize) < d));
        self.idx.extend_from_slice(index);
        self.vals.push(val);
    }

    /// Mode-m index of entry e.
    #[inline]
    pub fn entry_index(&self, e: usize, mode: usize) -> u32 {
        self.idx[e * self.order() + mode]
    }

    /// Full multi-index of entry e.
    #[inline]
    pub fn entry(&self, e: usize) -> &[u32] {
        let d = self.order();
        &self.idx[e * d..(e + 1) * d]
    }

    /// Linearize a full multi-index (first mode fastest) to a global cell id.
    pub fn linearize(&self, index: &[u32]) -> u64 {
        let mut id = 0u64;
        for m in (0..self.order()).rev() {
            id = id * self.dims[m] as u64 + index[m] as u64;
        }
        id
    }

    /// Set of linearized nonzero cell ids (for stratified zero sampling).
    // lint: allow(hash-structure) — callers only probe membership
    // (rejection sampling); the set is never iterated, so hash order
    // cannot reach any output
    pub fn cell_set(&self) -> HashSet<u64> {
        (0..self.nnz()).map(|e| self.linearize(self.entry(e))).collect()
    }

    /// Encode the mode-d fiber id of entry `e` (mixed radix over all modes
    /// except `d`, increasing mode order, first remaining mode fastest).
    pub fn fiber_of_entry(&self, e: usize, mode: usize) -> u64 {
        let entry = self.entry(e);
        let mut id = 0u64;
        for m in (0..self.order()).rev() {
            if m == mode {
                continue;
            }
            id = id * self.dims[m] as u64 + entry[m] as u64;
        }
        id
    }

    /// Decode a mode-d fiber id into per-mode row indices (the entry for
    /// mode `d` itself is left as 0 and must be ignored by the caller).
    pub fn decode_fiber(&self, mode: usize, fid: u64) -> Vec<u32> {
        decode_fiber(&self.dims, mode, fid)
    }

    /// Sum of squared values (used by ls loss bookkeeping / tests).
    pub fn frob_sq(&self) -> f64 {
        self.vals.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }
}

/// Decode a mode-`mode` fiber id into a full multi-index with 0 at `mode`.
///
/// Allocates the output; hot paths use [`decode_fiber_into`] instead.
pub fn decode_fiber(dims: &[usize], mode: usize, fid: u64) -> Vec<u32> {
    let mut out = vec![0u32; dims.len()];
    decode_fiber_into(dims, mode, fid, &mut out);
    out
}

/// Allocation-free form of [`decode_fiber`]: decode into a caller-owned
/// buffer of length `dims.len()` (the entry at `mode` is set to 0). This
/// is the canonical implementation — the client step path and the
/// Khatri-Rao row gather both route through it, so fiber decoding never
/// allocates inside the training loop.
#[inline]
pub fn decode_fiber_into(dims: &[usize], mode: usize, fid: u64, out: &mut [u32]) {
    debug_assert_eq!(out.len(), dims.len());
    let mut rest = fid;
    for (m, &dim) in dims.iter().enumerate() {
        if m == mode {
            out[m] = 0;
            continue;
        }
        out[m] = (rest % dim as u64) as u32;
        rest /= dim as u64;
    }
    debug_assert_eq!(rest, 0, "fiber id out of range");
}

/// Encode the mode-`mode` fiber id of a full multi-index.
pub fn encode_fiber(dims: &[usize], mode: usize, index: &[u32]) -> u64 {
    let mut id = 0u64;
    for m in (0..dims.len()).rev() {
        if m == mode {
            continue;
        }
        id = id * dims[m] as u64 + index[m] as u64;
    }
    id
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t3() -> SparseTensor {
        let mut t = SparseTensor::new(vec![4, 3, 2]);
        t.push(&[0, 0, 0], 1.0);
        t.push(&[1, 2, 0], 2.0);
        t.push(&[3, 1, 1], 3.0);
        t
    }

    #[test]
    fn basic_accessors() {
        let t = t3();
        assert_eq!(t.order(), 3);
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.n_cells(), 24.0);
        assert!((t.density() - 3.0 / 24.0).abs() < 1e-12);
        assert_eq!(t.n_fibers(0), 6);
        assert_eq!(t.n_fibers(1), 8);
        assert_eq!(t.n_fibers(2), 12);
        assert_eq!(t.entry(1), &[1, 2, 0]);
        assert_eq!(t.entry_index(2, 2), 1);
    }

    #[test]
    fn fiber_encode_decode_roundtrip() {
        let t = t3();
        for mode in 0..3 {
            for fid in 0..t.n_fibers(mode) as u64 {
                let idx = t.decode_fiber(mode, fid);
                assert_eq!(encode_fiber(&t.dims, mode, &idx), fid, "mode {mode} fid {fid}");
            }
        }
    }

    #[test]
    fn fiber_of_entry_consistent_with_encode() {
        let t = t3();
        for e in 0..t.nnz() {
            for mode in 0..3 {
                assert_eq!(
                    t.fiber_of_entry(e, mode),
                    encode_fiber(&t.dims, mode, t.entry(e))
                );
            }
        }
    }

    #[test]
    fn linearize_is_injective() {
        let t = t3();
        let mut seen = std::collections::HashSet::new();
        for i0 in 0..4u32 {
            for i1 in 0..3u32 {
                for i2 in 0..2u32 {
                    assert!(seen.insert(t.linearize(&[i0, i1, i2])));
                }
            }
        }
        assert_eq!(seen.len(), 24);
        assert!(seen.iter().all(|&x| x < 24));
    }

    #[test]
    fn cell_set_contains_exactly_nnz() {
        let t = t3();
        let s = t.cell_set();
        assert_eq!(s.len(), 3);
        assert!(s.contains(&t.linearize(&[3, 1, 1])));
        assert!(!s.contains(&t.linearize(&[0, 0, 1])));
    }

    #[test]
    fn order4_fibers() {
        let mut t = SparseTensor::new(vec![3, 4, 5, 6]);
        t.push(&[2, 3, 4, 5], 1.0);
        assert_eq!(t.n_fibers(0), 120);
        let fid = t.fiber_of_entry(0, 2);
        let idx = t.decode_fiber(2, fid);
        assert_eq!(&idx[..2], &[2, 3]);
        assert_eq!(idx[3], 5);
    }
}
