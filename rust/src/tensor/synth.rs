//! Synthetic EHR tensor generator (the data substitute — DESIGN.md table).
//!
//! MIMIC-III and CMS DE-SynPUF are access-gated, so experiments run on
//! generated tensors with the same *structure* the paper's phenotyping
//! setting exhibits: a planted low-rank CP model where each of R latent
//! phenotypes has a small support set per mode (a patient subgroup, a set
//! of diagnoses, a set of medications), plus background noise entries.
//! Values are binary (Bernoulli-logit experiments) or positive counts
//! turned Gaussian-ish (least-squares experiments).
//!
//! The planted factors are returned as ground truth — used for FMS and for
//! the phenotype-recovery analogue of the paper's Table IV case study.

use super::SparseTensor;
use crate::data::Dataset;
use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// What values entries carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueKind {
    /// 1.0 at sampled cells — for Bernoulli-logit experiments.
    Binary,
    /// positive noisy magnitudes — for least-squares experiments.
    Gaussian,
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// mode sizes, patient mode first
    pub dims: Vec<usize>,
    /// number of planted phenotypes
    pub rank: usize,
    /// per-component support size as a fraction of each mode
    pub support_frac: f64,
    /// within-support fire probability (controls density)
    pub fire_prob: f64,
    /// number of uniform background (noise) entries as a fraction of the
    /// structured nnz
    pub noise_frac: f64,
    pub value_kind: ValueKind,
    pub seed: u64,
}

/// Legacy name for the run currency, which now lives in
/// [`crate::data`]: generated datasets carry the planted ground-truth
/// factors in `truth`, loaded datasets leave it empty.
pub type SynthData = Dataset;

impl SynthConfig {
    /// Paper's "Synthetic" dataset analogue (scaled: 4096 x 256 x 256).
    /// Densities target ~1e-3-1e-4 — the regime of the paper's top-500
    /// feature tensors ("select the top 500 ... to reduce the sparsity"),
    /// where the planted structure carries a meaningful share of the loss.
    pub fn synthetic() -> Self {
        SynthConfig {
            dims: vec![4096, 256, 256],
            rank: 8,
            support_frac: 0.08,
            fire_prob: 0.35,
            noise_frac: 0.3,
            value_kind: ValueKind::Binary,
            seed: 0x5EED_0001,
        }
    }

    /// MIMIC-III analogue (scaled 4352 x 320 x 320; `--full-scale` in the
    /// CLI swaps in 34272 x 500 x 500).
    pub fn mimic_like() -> Self {
        SynthConfig {
            dims: vec![4352, 320, 320],
            rank: 10,
            support_frac: 0.06,
            fire_prob: 0.35,
            noise_frac: 0.3,
            value_kind: ValueKind::Binary,
            seed: 0x5EED_0002,
        }
    }

    /// CMS DE-SynPUF analogue (scaled 8192 x 384 x 384).
    pub fn cms_like() -> Self {
        SynthConfig {
            dims: vec![8192, 384, 384],
            rank: 12,
            support_frac: 0.05,
            fire_prob: 0.3,
            noise_frac: 0.3,
            value_kind: ValueKind::Binary,
            seed: 0x5EED_0003,
        }
    }

    /// Paper full-scale MIMIC-III dims (34,272 x 500 x 500).
    pub fn mimic_full() -> Self {
        SynthConfig { dims: vec![34_272, 500, 500], ..Self::mimic_like() }
    }

    /// Tiny config for tests.
    pub fn tiny(seed: u64) -> Self {
        SynthConfig {
            dims: vec![64, 32, 32],
            rank: 4,
            support_frac: 0.3,
            fire_prob: 0.5,
            noise_frac: 0.2,
            value_kind: ValueKind::Binary,
            seed,
        }
    }

    pub fn with_values(mut self, v: ValueKind) -> Self {
        self.value_kind = v;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Generate the dataset.
    pub fn generate(&self) -> Dataset {
        let d_order = self.dims.len();
        let rng = Rng::new(self.seed);

        // 1. Sample per-component supports for every mode.
        //
        // Patient mode (0): a *disjoint partition* of all patients — each
        // patient belongs to exactly one phenotype subgroup, mirroring the
        // distinct patient populations behind the paper's Table III tSNE
        // clusters. Feature modes: independent (possibly overlapping)
        // subsets, as real phenotypes share diagnoses/medications.
        let mut supports: Vec<Vec<Vec<u32>>> = Vec::with_capacity(d_order); // [mode][r] -> rows
        for (m, &dim) in self.dims.iter().enumerate() {
            let mut per_r = Vec::with_capacity(self.rank);
            let mut mode_rng = rng.split(1000 + m as u64);
            if m == 0 && dim >= self.rank {
                let mut all: Vec<u32> = (0..dim as u32).collect();
                mode_rng.shuffle(&mut all);
                let chunk = dim / self.rank;
                for r in 0..self.rank {
                    let start = r * chunk;
                    let end = if r + 1 == self.rank { dim } else { start + chunk };
                    let mut rows = all[start..end].to_vec();
                    rows.sort_unstable();
                    per_r.push(rows);
                }
            } else {
                let supp_size = ((dim as f64 * self.support_frac).ceil() as usize).clamp(2, dim);
                for _ in 0..self.rank {
                    let mut rows: Vec<u32> = mode_rng
                        .sample_indices(dim, supp_size)
                        .into_iter()
                        .map(|i| i as u32)
                        .collect();
                    rows.sort_unstable();
                    per_r.push(rows);
                }
            }
            supports.push(per_r);
        }

        // 2. Structured entries: for each component, each patient in its
        //    support fires a Bernoulli(fire_prob) coin per cross-support
        //    feature combination, sampled sparsely.
        // lint: allow(hash-structure) — dedup accumulator only; entries
        // materialize through the sort_unstable_by_key pass below, so
        // hash order never reaches the tensor
        let mut cells = std::collections::HashMap::<u64, f32>::new();
        let mut gen_rng = rng.split(2);
        let mut t = SparseTensor::new(self.dims.clone());
        for r in 0..self.rank {
            // expected structured entries for this component
            let cross: f64 = (0..d_order).map(|m| supports[m][r].len() as f64).product();
            let expect = (cross * self.fire_prob).ceil() as usize;
            for _ in 0..expect {
                let idx: Vec<u32> = (0..d_order)
                    .map(|m| {
                        let supp = &supports[m][r];
                        supp[gen_rng.below(supp.len())]
                    })
                    .collect();
                let lin = t.linearize(&idx);
                let val = match self.value_kind {
                    ValueKind::Binary => 1.0,
                    ValueKind::Gaussian => (1.5 + 0.5 * gen_rng.normal()).abs() as f32 + 0.1,
                };
                cells.entry(lin).or_insert(val);
            }
        }

        // 3. Background noise entries (uniform random cells).
        let n_noise = (cells.len() as f64 * self.noise_frac) as usize;
        let mut noise_rng = rng.split(3);
        for _ in 0..n_noise {
            let idx: Vec<u32> =
                self.dims.iter().map(|&d| noise_rng.below(d) as u32).collect();
            let lin = t.linearize(&idx);
            let val = match self.value_kind {
                ValueKind::Binary => 1.0,
                ValueKind::Gaussian => (0.3 * noise_rng.normal()).abs() as f32 + 0.05,
            };
            cells.entry(lin).or_insert(val);
        }

        // 4. Materialize entries in deterministic order.
        let mut lins: Vec<(&u64, &f32)> = cells.iter().collect();
        lins.sort_unstable_by_key(|(l, _)| **l);
        for (&lin, &val) in lins {
            let idx = delinearize(&self.dims, lin);
            t.push(&idx, val);
        }

        // 5. Ground-truth factors: column-normalized support indicators.
        let truth = (0..d_order)
            .map(|m| {
                let mut a = Mat::zeros(self.dims[m], self.rank);
                for r in 0..self.rank {
                    let supp = &supports[m][r];
                    let w = 1.0 / (supp.len() as f32).sqrt();
                    for &row in supp {
                        *a.at_mut(row as usize, r) = w;
                    }
                }
                a
            })
            .collect();

        Dataset { tensor: t, truth }
    }
}

/// Inverse of `SparseTensor::linearize` (first mode fastest).
pub fn delinearize(dims: &[usize], mut lin: u64) -> Vec<u32> {
    let mut idx = vec![0u32; dims.len()];
    for m in 0..dims.len() {
        idx[m] = (lin % dims[m] as u64) as u32;
        lin /= dims[m] as u64;
    }
    debug_assert_eq!(lin, 0);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = SynthConfig::tiny(42).generate();
        let b = SynthConfig::tiny(42).generate();
        assert_eq!(a.tensor.idx, b.tensor.idx);
        assert_eq!(a.tensor.vals, b.tensor.vals);
        let c = SynthConfig::tiny(43).generate();
        assert_ne!(a.tensor.idx, c.tensor.idx);
    }

    #[test]
    fn entries_in_range_and_unique() {
        let d = SynthConfig::tiny(1).generate();
        let t = &d.tensor;
        let mut seen = std::collections::HashSet::new();
        for e in 0..t.nnz() {
            let idx = t.entry(e);
            for (m, &i) in idx.iter().enumerate() {
                assert!((i as usize) < t.dims[m]);
            }
            assert!(seen.insert(t.linearize(idx)), "duplicate cell");
        }
        assert!(t.nnz() > 50, "too few entries: {}", t.nnz());
    }

    #[test]
    fn binary_values_are_one() {
        let d = SynthConfig::tiny(2).generate();
        assert!(d.tensor.vals.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn gaussian_values_positive() {
        let d = SynthConfig { value_kind: ValueKind::Gaussian, ..SynthConfig::tiny(3) }.generate();
        assert!(d.tensor.vals.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn truth_factors_are_column_normalized_supports() {
        let cfg = SynthConfig::tiny(4);
        let d = cfg.generate();
        for (m, a) in d.truth.iter().enumerate() {
            assert_eq!(a.rows, cfg.dims[m]);
            assert_eq!(a.cols, cfg.rank);
            for r in 0..a.cols {
                let n: f32 = (0..a.rows).map(|i| a.at(i, r) * a.at(i, r)).sum();
                assert!((n - 1.0).abs() < 1e-4, "col {r} norm {n}");
            }
        }
    }

    #[test]
    fn delinearize_roundtrip() {
        let dims = vec![7, 5, 3, 2];
        let t = SparseTensor::new(dims.clone());
        for lin in [0u64, 1, 13, 209] {
            let idx = delinearize(&dims, lin);
            assert_eq!(t.linearize(&idx), lin);
        }
    }

    #[test]
    fn presets_have_expected_shape() {
        assert_eq!(SynthConfig::synthetic().dims, vec![4096, 256, 256]);
        assert_eq!(SynthConfig::mimic_like().dims, vec![4352, 320, 320]);
        assert_eq!(SynthConfig::cms_like().dims, vec![8192, 384, 384]);
        assert_eq!(SynthConfig::mimic_full().dims[0], 34_272);
        assert!(crate::registry::datasets().resolve("nope").is_err());
    }

    #[test]
    fn density_is_ehr_sparse() {
        let d = SynthConfig::synthetic().generate();
        let dens = d.tensor.density();
        assert!(dens < 1e-2 && dens > 1e-7, "density {dens}");
    }
}
