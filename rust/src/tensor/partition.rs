//! Horizontal (patient-mode) partitioning — paper eq. (5).
//!
//! The global tensor is split along mode 0 into K contiguous row blocks,
//! one per client/institution. Mode-0 indices are re-based so each local
//! tensor is self-contained; `row_offset` maps back to global patient ids.
//!
//! [`partition_shared`] wraps each shard in an `Arc<ShardData>` — the
//! tensor plus all per-mode fiber indices, built **once** and immutably
//! shared. Clients hold a view of this data plane instead of a deep copy,
//! so the thread-per-client driver shares one read-only shard per site
//! across threads.

use std::sync::Arc;

use super::fiber::ModeIndices;
use super::SparseTensor;

/// One client's shard (raw partition output: tensor + global offset).
#[derive(Debug, Clone)]
pub struct Shard {
    pub tensor: SparseTensor,
    /// global patient-row offset of local row 0
    pub row_offset: usize,
}

/// The immutable per-site data plane: one shard's tensor with every
/// per-mode [`FiberIndex`](super::fiber::FiberIndex) pre-built. Shared
/// across execution paths via `Arc` — `ClientState` holds a reference,
/// never a copy, and the parallel driver's threads all read the same
/// allocation.
#[derive(Debug)]
pub struct ShardData {
    pub tensor: SparseTensor,
    /// per-mode fiber indices, built once at load
    pub indices: ModeIndices,
    /// global patient-row offset of local row 0
    pub row_offset: usize,
}

impl ShardData {
    /// Build the data plane for one shard (tensor + all fiber indices).
    pub fn new(tensor: SparseTensor, row_offset: usize) -> Self {
        let indices = ModeIndices::build(&tensor);
        ShardData { tensor, indices, row_offset }
    }

    /// Lift a raw [`Shard`] into the shared data plane.
    pub fn from_shard(shard: Shard) -> Self {
        Self::new(shard.tensor, shard.row_offset)
    }
}

/// [`partition_mode0`] + fiber-index construction, each shard wrapped in
/// an `Arc` for zero-copy sharing across clients and threads.
pub fn partition_shared(t: &SparseTensor, k: usize) -> Vec<Arc<ShardData>> {
    partition_mode0(t, k).into_iter().map(|s| Arc::new(ShardData::from_shard(s))).collect()
}

/// Split `t` into `k` shards of (near-)equal patient rows.
///
/// Row counts differ by at most 1; every global row lands in exactly one
/// shard and local indices are re-based.
pub fn partition_mode0(t: &SparseTensor, k: usize) -> Vec<Shard> {
    assert!(k >= 1);
    let i0 = t.dims[0];
    assert!(k <= i0, "more clients ({k}) than patient rows ({i0})");
    let base = i0 / k;
    let extra = i0 % k;
    // shard s covers rows [starts[s], starts[s+1])
    let mut starts = Vec::with_capacity(k + 1);
    let mut acc = 0usize;
    for s in 0..k {
        starts.push(acc);
        acc += base + usize::from(s < extra);
    }
    starts.push(i0);

    let mut shards: Vec<Shard> = (0..k)
        .map(|s| {
            let mut dims = t.dims.clone();
            dims[0] = starts[s + 1] - starts[s];
            Shard { tensor: SparseTensor::new(dims), row_offset: starts[s] }
        })
        .collect();

    let d = t.order();
    let mut local_idx = vec![0u32; d];
    for e in 0..t.nnz() {
        let idx = t.entry(e);
        let row = idx[0] as usize;
        // find shard by binary search over starts
        let s = match starts.binary_search(&row) {
            Ok(pos) => pos.min(k - 1),
            Err(pos) => pos - 1,
        };
        local_idx.copy_from_slice(idx);
        local_idx[0] = (row - starts[s]) as u32;
        shards[s].tensor.push(&local_idx, t.vals[e]);
    }
    shards
}

/// Even split sizes for dimension `i0` across `k` clients (used by configs
/// to pick artifact shapes; equals the shard row counts of
/// [`partition_mode0`] when `k` divides `i0`).
pub fn shard_rows(i0: usize, k: usize) -> Vec<usize> {
    let base = i0 / k;
    let extra = i0 % k;
    (0..k).map(|s| base + usize::from(s < extra)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::SynthConfig;

    #[test]
    fn partition_covers_all_entries_exactly_once() {
        let data = SynthConfig::tiny(5).generate();
        let t = &data.tensor;
        for k in [1, 3, 8] {
            let shards = partition_mode0(t, k);
            assert_eq!(shards.len(), k);
            let total: usize = shards.iter().map(|s| s.tensor.nnz()).sum();
            assert_eq!(total, t.nnz(), "k={k}");
            let rows: usize = shards.iter().map(|s| s.tensor.dims[0]).sum();
            assert_eq!(rows, t.dims[0]);
            // every local entry maps back to a global entry
            let global: std::collections::HashSet<u64> = t.cell_set();
            for sh in &shards {
                for e in 0..sh.tensor.nnz() {
                    let mut idx = sh.tensor.entry(e).to_vec();
                    idx[0] += sh.row_offset as u32;
                    assert!(global.contains(&t.linearize(&idx)));
                    assert!((sh.tensor.entry(e)[0] as usize) < sh.tensor.dims[0]);
                }
            }
        }
    }

    #[test]
    fn row_offsets_are_contiguous() {
        let data = SynthConfig::tiny(6).generate();
        let shards = partition_mode0(&data.tensor, 5);
        let mut expect = 0;
        for sh in &shards {
            assert_eq!(sh.row_offset, expect);
            expect += sh.tensor.dims[0];
        }
        assert_eq!(expect, data.tensor.dims[0]);
    }

    #[test]
    fn uneven_division_spreads_remainder() {
        // 64 rows, 6 clients -> 11,11,11,11,10,10
        let rows = shard_rows(64, 6);
        assert_eq!(rows, vec![11, 11, 11, 11, 10, 10]);
        assert_eq!(rows.iter().sum::<usize>(), 64);
    }

    #[test]
    fn k1_is_identity() {
        let data = SynthConfig::tiny(7).generate();
        let shards = partition_mode0(&data.tensor, 1);
        assert_eq!(shards[0].tensor.nnz(), data.tensor.nnz());
        assert_eq!(shards[0].tensor.idx, data.tensor.idx);
        assert_eq!(shards[0].row_offset, 0);
    }

    #[test]
    fn partition_shared_builds_indices_once_per_shard() {
        let data = SynthConfig::tiny(9).generate();
        let shards = partition_shared(&data.tensor, 3);
        assert_eq!(shards.len(), 3);
        for sh in &shards {
            assert_eq!(sh.indices.per_mode.len(), sh.tensor.order());
            assert_eq!(sh.indices.mode(0).len(), sh.tensor.nnz());
            // Arc clones share the same allocation — the whole point
            let view = sh.clone();
            assert!(std::sync::Arc::ptr_eq(sh, &view));
        }
        let total: usize = shards.iter().map(|s| s.tensor.nnz()).sum();
        assert_eq!(total, data.tensor.nnz());
    }

    #[test]
    fn feature_modes_untouched() {
        let data = SynthConfig::tiny(8).generate();
        let shards = partition_mode0(&data.tensor, 4);
        for sh in &shards {
            assert_eq!(&sh.tensor.dims[1..], &data.tensor.dims[1..]);
        }
    }
}
