//! Horizontal (patient-mode) partitioning — paper eq. (5) — plus the
//! non-IID partitioners of the heterogeneity axis.
//!
//! The global tensor is split along mode 0 into K row sets, one per
//! client/institution. Mode-0 indices are re-based so each local tensor
//! is self-contained; `global_rows` maps every local row back to its
//! global patient id (`row_offset` is kept as the first global row for
//! the contiguous partitioners' callers).
//!
//! Three [`Partitioner`]s:
//!
//! * `even` — contiguous blocks of (near-)equal size, the IID default
//!   ([`partition_mode0`]).
//! * `skewed:<alpha>` — contiguous blocks with power-law sizes
//!   `(s+1)^-alpha`, shuffled across clients by the seed: a few giant
//!   hospitals, many small clinics.
//! * `site_vocab:<overlap>` — per-site code vocabularies: a seeded
//!   fraction `overlap` of mode-1 codes is shared by all sites, the rest
//!   are split into per-site private vocabularies, and each patient row
//!   is assigned to the site whose private codes dominate its events
//!   (non-contiguous row sets — the realistic "each hospital sees its
//!   own specialty mix" regime).
//!
//! Every partitioner is a pure function of `(tensor, k, seed)`; shard
//! membership never depends on call order.
//!
//! [`partition_shared`] wraps each shard in an `Arc<ShardData>` — the
//! tensor plus all per-mode fiber indices, built **once** and immutably
//! shared. Clients hold a view of this data plane instead of a deep copy,
//! so the thread-per-client driver shares one read-only shard per site
//! across threads.

use std::sync::Arc;

use super::fiber::ModeIndices;
use super::SparseTensor;
use crate::util::order::nan_last_f64;
use crate::util::rng::Rng;

/// One client's shard (raw partition output: tensor + global row map).
#[derive(Debug, Clone)]
pub struct Shard {
    pub tensor: SparseTensor,
    /// global patient row of local row 0 (== `global_rows[0]`)
    pub row_offset: usize,
    /// local row -> global patient row (ascending)
    pub global_rows: Vec<u32>,
}

/// The immutable per-site data plane: one shard's tensor with every
/// per-mode [`FiberIndex`](super::fiber::FiberIndex) pre-built. Shared
/// across execution paths via `Arc` — `ClientState` holds a reference,
/// never a copy, and the parallel driver's threads all read the same
/// allocation.
#[derive(Debug)]
pub struct ShardData {
    pub tensor: SparseTensor,
    /// per-mode fiber indices, built once at load
    pub indices: ModeIndices,
    /// global patient row of local row 0 (== `global_rows[0]`)
    pub row_offset: usize,
    /// local row -> global patient row (ascending)
    pub global_rows: Vec<u32>,
}

impl ShardData {
    /// Build the data plane for a *contiguous* shard starting at
    /// `row_offset` (the pre-heterogeneity contract, kept for callers
    /// that construct shards directly).
    pub fn new(tensor: SparseTensor, row_offset: usize) -> Self {
        let global_rows = (0..tensor.dims[0]).map(|r| (row_offset + r) as u32).collect();
        Self::with_rows(tensor, global_rows)
    }

    /// Build the data plane from an explicit local→global row map.
    pub fn with_rows(tensor: SparseTensor, global_rows: Vec<u32>) -> Self {
        assert_eq!(global_rows.len(), tensor.dims[0], "one global row per local row");
        let indices = ModeIndices::build(&tensor);
        let row_offset = global_rows.first().copied().unwrap_or(0) as usize;
        ShardData { tensor, indices, row_offset, global_rows }
    }

    /// Lift a raw [`Shard`] into the shared data plane.
    pub fn from_shard(shard: Shard) -> Self {
        Self::with_rows(shard.tensor, shard.global_rows)
    }
}

/// How patient rows are distributed across clients (spec axis
/// `partitioner`).
#[derive(Debug, Clone, PartialEq)]
pub enum Partitioner {
    /// Contiguous (near-)equal blocks — the IID default.
    Even,
    /// Contiguous power-law blocks: client sizes ∝ `(s+1)^-alpha`,
    /// shuffled across clients by the seed.
    Skewed(f64),
    /// Per-site code vocabularies with the given shared-overlap fraction;
    /// patients follow their dominant private vocabulary.
    SiteVocab(f64),
}

impl Partitioner {
    /// Short axis name (registry key).
    pub fn name(&self) -> &'static str {
        match self {
            Partitioner::Even => "even",
            Partitioner::Skewed(_) => "skewed",
            Partitioner::SiteVocab(_) => "site_vocab",
        }
    }

    /// Registry-parseable string form (`even`, `skewed:<alpha>`,
    /// `site_vocab:<overlap>`) — what `ExperimentSpec` JSON carries.
    pub fn spec_string(&self) -> String {
        match self {
            Partitioner::Even => "even".to_string(),
            Partitioner::Skewed(a) => format!("skewed:{a}"),
            Partitioner::SiteVocab(o) => format!("site_vocab:{o}"),
        }
    }

    /// Filesystem-safe label fragment for run stems (no `:`).
    pub fn label_component(&self) -> String {
        match self {
            Partitioner::Even => "even".to_string(),
            Partitioner::Skewed(a) => format!("skew{a}"),
            Partitioner::SiteVocab(o) => format!("vocab{o}"),
        }
    }
}

/// [`partition_with`] + fiber-index construction, each shard wrapped in
/// an `Arc` for zero-copy sharing across clients and threads.
pub fn partition_shared_with(
    t: &SparseTensor,
    k: usize,
    p: &Partitioner,
    seed: u64,
) -> Vec<Arc<ShardData>> {
    partition_with(t, k, p, seed).into_iter().map(|s| Arc::new(ShardData::from_shard(s))).collect()
}

/// The even (IID) partition behind an `Arc` — back-compat shorthand for
/// [`partition_shared_with`] with [`Partitioner::Even`].
pub fn partition_shared(t: &SparseTensor, k: usize) -> Vec<Arc<ShardData>> {
    partition_shared_with(t, k, &Partitioner::Even, 0)
}

/// Split `t` into `k` shards under `p`. Every global row lands in exactly
/// one shard, every shard is non-empty, and local indices are re-based;
/// `seed` drives the non-IID partitioners (ignored by `even`).
pub fn partition_with(t: &SparseTensor, k: usize, p: &Partitioner, seed: u64) -> Vec<Shard> {
    assert!(k >= 1);
    let i0 = t.dims[0];
    assert!(k <= i0, "more clients ({k}) than patient rows ({i0})");
    let rows_per_shard = match p {
        Partitioner::Even => contiguous_rows(&shard_rows(i0, k)),
        Partitioner::Skewed(alpha) => contiguous_rows(&skewed_sizes(i0, k, *alpha, seed)),
        Partitioner::SiteVocab(overlap) => site_vocab_rows(t, k, *overlap, seed),
    };
    shards_from_rows(t, rows_per_shard)
}

/// Split `t` into `k` shards of (near-)equal contiguous patient blocks
/// (the IID default; row counts differ by at most 1).
pub fn partition_mode0(t: &SparseTensor, k: usize) -> Vec<Shard> {
    partition_with(t, k, &Partitioner::Even, 0)
}

/// Turn per-shard sizes into contiguous ascending global-row lists.
fn contiguous_rows(sizes: &[usize]) -> Vec<Vec<u32>> {
    let mut start = 0u32;
    sizes
        .iter()
        .map(|&n| {
            let rows = (start..start + n as u32).collect();
            start += n as u32;
            rows
        })
        .collect()
}

/// Materialize shards from explicit row ownership (each global row in
/// exactly one list; lists ascending). The single assembly path every
/// partitioner funnels through.
fn shards_from_rows(t: &SparseTensor, rows_per_shard: Vec<Vec<u32>>) -> Vec<Shard> {
    let i0 = t.dims[0];
    let mut owner = vec![usize::MAX; i0];
    let mut local_of = vec![0u32; i0];
    for (s, rows) in rows_per_shard.iter().enumerate() {
        assert!(!rows.is_empty(), "partitioner produced an empty shard {s}");
        for (l, &r) in rows.iter().enumerate() {
            assert_eq!(owner[r as usize], usize::MAX, "row {r} assigned twice");
            owner[r as usize] = s;
            local_of[r as usize] = l as u32;
        }
    }
    assert!(owner.iter().all(|&o| o != usize::MAX), "partitioner left a row unassigned");

    let mut shards: Vec<Shard> = rows_per_shard
        .into_iter()
        .map(|rows| {
            let mut dims = t.dims.clone();
            dims[0] = rows.len();
            Shard {
                tensor: SparseTensor::new(dims),
                row_offset: rows[0] as usize,
                global_rows: rows,
            }
        })
        .collect();

    let d = t.order();
    let mut local_idx = vec![0u32; d];
    for e in 0..t.nnz() {
        let idx = t.entry(e);
        let row = idx[0] as usize;
        let s = owner[row];
        local_idx.copy_from_slice(idx);
        local_idx[0] = local_of[row];
        shards[s].tensor.push(&local_idx, t.vals[e]);
    }
    shards
}

/// Even split sizes for dimension `i0` across `k` clients (used by configs
/// to pick artifact shapes; equals the shard row counts of
/// [`partition_mode0`] when `k` divides `i0`).
pub fn shard_rows(i0: usize, k: usize) -> Vec<usize> {
    let base = i0 / k;
    let extra = i0 % k;
    (0..k).map(|s| base + usize::from(s < extra)).collect()
}

/// Power-law shard sizes: every shard gets 1 row, the remaining
/// `i0 - k` are distributed by largest remainder over weights
/// `(s+1)^-alpha` (ties broken by index), then the size list is
/// seed-shuffled across clients. Deterministic per `(i0, k, alpha,
/// seed)`.
pub fn skewed_sizes(i0: usize, k: usize, alpha: f64, seed: u64) -> Vec<usize> {
    assert!(k >= 1 && k <= i0);
    let weights: Vec<f64> = (0..k).map(|s| ((s + 1) as f64).powf(-alpha)).collect();
    let total: f64 = weights.iter().sum();
    let spare = i0 - k;
    let ideal: Vec<f64> = weights.iter().map(|w| spare as f64 * w / total).collect();
    let mut sizes: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
    let assigned: usize = sizes.iter().sum();
    // largest-remainder rounding, ties by lower index
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| {
        nan_last_f64(&(ideal[b] - ideal[b].floor()), &(ideal[a] - ideal[a].floor()))
            .then(a.cmp(&b))
    });
    for &s in order.iter().take(spare - assigned) {
        sizes[s] += 1;
    }
    for s in sizes.iter_mut() {
        *s += 1; // the guaranteed row
    }
    Rng::new(seed ^ 0x9A27_1710).shuffle(&mut sizes);
    sizes
}

/// Per-site mode-1 code vocabularies: a seeded permutation of all codes,
/// the first `round(overlap * J)` shared by every site, the rest split
/// into per-site private chunks. Each vocabulary is ascending; their
/// union always covers `0..j_dim`.
pub fn site_vocabularies(j_dim: usize, k: usize, overlap: f64, seed: u64) -> Vec<Vec<usize>> {
    assert!(k >= 1);
    let mut perm: Vec<usize> = (0..j_dim).collect();
    let mut rng = Rng::new(seed ^ 0x50CA_B017);
    rng.shuffle(&mut perm);
    let n_shared = ((overlap.clamp(0.0, 1.0) * j_dim as f64).round() as usize).min(j_dim);
    let (shared, rest) = perm.split_at(n_shared);
    let base = rest.len() / k;
    let extra = rest.len() % k;
    let mut vocabs = Vec::with_capacity(k);
    let mut start = 0usize;
    for s in 0..k {
        let n = base + usize::from(s < extra);
        let mut v: Vec<usize> = shared.iter().chain(rest[start..start + n].iter()).copied().collect();
        v.sort_unstable();
        vocabs.push(v);
        start += n;
    }
    vocabs
}

/// Assign each patient row to the site whose *private* vocabulary
/// dominates its events (ties → lowest site; rows touching only shared
/// codes → round-robin). Empty shards are repaired by moving rows from
/// the largest shard, deterministically.
fn site_vocab_rows(t: &SparseTensor, k: usize, overlap: f64, seed: u64) -> Vec<Vec<u32>> {
    assert!(t.order() >= 2, "site_vocab partitioner needs a code mode (mode 1)");
    let i0 = t.dims[0];
    let j_dim = t.dims[1];
    let vocabs = site_vocabularies(j_dim, k, overlap, seed);

    // codes listed by exactly one site are private to it
    let mut appearances = vec![0u32; j_dim];
    let mut owner_of_code = vec![usize::MAX; j_dim];
    for (s, v) in vocabs.iter().enumerate() {
        for &c in v {
            appearances[c] += 1;
            owner_of_code[c] = s;
        }
    }
    for c in 0..j_dim {
        if appearances[c] != 1 {
            owner_of_code[c] = usize::MAX; // shared (or unused) — no vote
        }
    }

    // per-row private-code votes
    let mut votes = vec![0u32; i0 * k];
    for e in 0..t.nnz() {
        let idx = t.entry(e);
        let site = owner_of_code[idx[1] as usize];
        if site != usize::MAX {
            votes[idx[0] as usize * k + site] += 1;
        }
    }

    let mut rows_per_shard: Vec<Vec<u32>> = vec![Vec::new(); k];
    for r in 0..i0 {
        let row_votes = &votes[r * k..(r + 1) * k];
        let best = row_votes.iter().enumerate().max_by_key(|&(s, &v)| (v, std::cmp::Reverse(s)));
        let site = match best {
            Some((s, &v)) if v > 0 => s,
            _ => r % k, // no private-code signal: round-robin
        };
        rows_per_shard[site].push(r as u32);
    }

    // repair empty shards: move the largest shard's last row over
    loop {
        let Some(empty) = rows_per_shard.iter().position(Vec::is_empty) else { break };
        let donor = (0..k)
            .max_by_key(|&s| (rows_per_shard[s].len(), std::cmp::Reverse(s)))
            .expect("k >= 1");
        let moved = rows_per_shard[donor].pop().expect("donor has rows (k <= i0)");
        rows_per_shard[empty].push(moved);
    }
    for rows in rows_per_shard.iter_mut() {
        rows.sort_unstable();
    }
    rows_per_shard
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::SynthConfig;

    #[test]
    fn partition_covers_all_entries_exactly_once() {
        let data = SynthConfig::tiny(5).generate();
        let t = &data.tensor;
        for k in [1, 3, 8] {
            let shards = partition_mode0(t, k);
            assert_eq!(shards.len(), k);
            let total: usize = shards.iter().map(|s| s.tensor.nnz()).sum();
            assert_eq!(total, t.nnz(), "k={k}");
            let rows: usize = shards.iter().map(|s| s.tensor.dims[0]).sum();
            assert_eq!(rows, t.dims[0]);
            // every local entry maps back to a global entry
            let global: std::collections::HashSet<u64> = t.cell_set();
            for sh in &shards {
                for e in 0..sh.tensor.nnz() {
                    let mut idx = sh.tensor.entry(e).to_vec();
                    idx[0] = sh.global_rows[idx[0] as usize];
                    assert!(global.contains(&t.linearize(&idx)));
                    assert!((sh.tensor.entry(e)[0] as usize) < sh.tensor.dims[0]);
                }
            }
        }
    }

    /// Shared property harness for every partitioner: entries covered
    /// exactly once, rows covered exactly once, local indices re-based,
    /// no empty shard.
    fn assert_valid_partition(t: &SparseTensor, shards: &[Shard]) {
        let total: usize = shards.iter().map(|s| s.tensor.nnz()).sum();
        assert_eq!(total, t.nnz());
        let mut seen_rows = vec![false; t.dims[0]];
        for sh in shards {
            assert!(sh.tensor.dims[0] > 0, "empty shard");
            assert_eq!(sh.global_rows.len(), sh.tensor.dims[0]);
            assert_eq!(sh.row_offset, sh.global_rows[0] as usize);
            assert!(sh.global_rows.windows(2).all(|w| w[0] < w[1]), "global rows ascending");
            for &g in &sh.global_rows {
                assert!(!seen_rows[g as usize], "row {g} in two shards");
                seen_rows[g as usize] = true;
            }
            assert_eq!(&sh.tensor.dims[1..], &t.dims[1..]);
        }
        assert!(seen_rows.iter().all(|&s| s), "row missing from every shard");
        let global: std::collections::HashSet<u64> = t.cell_set();
        for sh in shards {
            for e in 0..sh.tensor.nnz() {
                let mut idx = sh.tensor.entry(e).to_vec();
                assert!((idx[0] as usize) < sh.tensor.dims[0]);
                idx[0] = sh.global_rows[idx[0] as usize];
                assert!(global.contains(&t.linearize(&idx)));
            }
        }
    }

    #[test]
    fn skewed_partition_covers_everything_and_skews() {
        let data = SynthConfig::tiny(11).generate();
        let t = &data.tensor;
        let shards = partition_with(t, 4, &Partitioner::Skewed(1.2), 7);
        assert_valid_partition(t, &shards);
        let mut sizes: Vec<usize> = shards.iter().map(|s| s.tensor.dims[0]).collect();
        sizes.sort_unstable();
        assert!(sizes[3] > sizes[0], "alpha=1.2 must produce unequal shard sizes");
    }

    #[test]
    fn skewed_sizes_are_deterministic_per_seed_and_sum() {
        for (i0, k, alpha) in [(64, 6, 0.5), (100, 10, 1.0), (33, 33, 2.0), (40, 1, 1.5)] {
            let a = skewed_sizes(i0, k, alpha, 3);
            let b = skewed_sizes(i0, k, alpha, 3);
            assert_eq!(a, b, "deterministic per seed");
            assert_eq!(a.iter().sum::<usize>(), i0);
            assert!(a.iter().all(|&s| s >= 1), "every client keeps at least one row");
            let c = skewed_sizes(i0, k, alpha, 4);
            assert_eq!(c.iter().sum::<usize>(), i0, "other seeds still cover");
        }
        // alpha = 0 degenerates to the even split (sorted: shuffle only
        // permutes client order)
        let mut even = skewed_sizes(64, 6, 0.0, 9);
        even.sort_unstable();
        let mut expect = shard_rows(64, 6);
        expect.sort_unstable();
        assert_eq!(even, expect);
    }

    #[test]
    fn site_vocabularies_union_covers_and_shares() {
        for (j, k, overlap) in [(40, 4, 0.3), (17, 3, 0.0), (12, 5, 1.0), (9, 1, 0.5)] {
            let vocabs = site_vocabularies(j, k, overlap, 11);
            let mut union: Vec<usize> = vocabs.iter().flatten().copied().collect();
            union.sort_unstable();
            union.dedup();
            assert_eq!(union, (0..j).collect::<Vec<_>>(), "j={j} k={k} overlap={overlap}");
            let n_shared = ((overlap * j as f64).round() as usize).min(j);
            for v in &vocabs {
                assert!(v.len() >= n_shared, "each site holds at least the shared codes");
                assert!(v.windows(2).all(|w| w[0] < w[1]), "vocabulary sorted + deduped");
            }
            assert_eq!(vocabs, site_vocabularies(j, k, overlap, 11), "deterministic");
        }
    }

    #[test]
    fn site_vocab_partition_covers_everything() {
        let data = SynthConfig::tiny(13).generate();
        let t = &data.tensor;
        for overlap in [0.0, 0.3, 1.0] {
            let shards = partition_with(t, 3, &Partitioner::SiteVocab(overlap), 5);
            assert_valid_partition(t, &shards);
        }
        // determinism across calls
        let a = partition_with(t, 3, &Partitioner::SiteVocab(0.3), 5);
        let b = partition_with(t, 3, &Partitioner::SiteVocab(0.3), 5);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.global_rows, y.global_rows);
            assert_eq!(x.tensor.idx, y.tensor.idx);
        }
    }

    #[test]
    fn row_offsets_are_contiguous() {
        let data = SynthConfig::tiny(6).generate();
        let shards = partition_mode0(&data.tensor, 5);
        let mut expect = 0;
        for sh in &shards {
            assert_eq!(sh.row_offset, expect);
            assert_eq!(
                sh.global_rows,
                (expect as u32..(expect + sh.tensor.dims[0]) as u32).collect::<Vec<_>>()
            );
            expect += sh.tensor.dims[0];
        }
        assert_eq!(expect, data.tensor.dims[0]);
    }

    #[test]
    fn uneven_division_spreads_remainder() {
        // 64 rows, 6 clients -> 11,11,11,11,10,10
        let rows = shard_rows(64, 6);
        assert_eq!(rows, vec![11, 11, 11, 11, 10, 10]);
        assert_eq!(rows.iter().sum::<usize>(), 64);
    }

    #[test]
    fn k1_is_identity() {
        let data = SynthConfig::tiny(7).generate();
        let shards = partition_mode0(&data.tensor, 1);
        assert_eq!(shards[0].tensor.nnz(), data.tensor.nnz());
        assert_eq!(shards[0].tensor.idx, data.tensor.idx);
        assert_eq!(shards[0].row_offset, 0);
    }

    #[test]
    fn partition_shared_builds_indices_once_per_shard() {
        let data = SynthConfig::tiny(9).generate();
        let shards = partition_shared(&data.tensor, 3);
        assert_eq!(shards.len(), 3);
        for sh in &shards {
            assert_eq!(sh.indices.per_mode.len(), sh.tensor.order());
            assert_eq!(sh.indices.mode(0).len(), sh.tensor.nnz());
            // Arc clones share the same allocation — the whole point
            let view = sh.clone();
            assert!(std::sync::Arc::ptr_eq(sh, &view));
        }
        let total: usize = shards.iter().map(|s| s.tensor.nnz()).sum();
        assert_eq!(total, data.tensor.nnz());
    }

    #[test]
    fn feature_modes_untouched() {
        let data = SynthConfig::tiny(8).generate();
        let shards = partition_mode0(&data.tensor, 4);
        for sh in &shards {
            assert_eq!(&sh.tensor.dims[1..], &data.tensor.dims[1..]);
        }
    }

    #[test]
    fn partitioner_spec_strings_are_stable() {
        assert_eq!(Partitioner::Even.spec_string(), "even");
        assert_eq!(Partitioner::Skewed(1.5).spec_string(), "skewed:1.5");
        assert_eq!(Partitioner::SiteVocab(0.3).spec_string(), "site_vocab:0.3");
        assert_eq!(Partitioner::Skewed(1.5).label_component(), "skew1.5");
    }
}
