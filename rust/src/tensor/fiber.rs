//! Per-mode fiber index: the sparse -> dense gather behind fiber-sampled
//! MTTKRP (paper §III-B2, eq. 10).
//!
//! For a sampled fiber set `S_d` the engine needs the dense slice
//! `Y_<d>(:, S_d)` as an `I_d x |S|` row-major buffer for the gradient
//! call. Building it per iteration from raw COO would be O(nnz); the
//! `FiberIndex` groups entries of each mode by fiber id once (O(nnz log
//! nnz) at load), making each gather O(sum of nnz in the sampled fibers).
//! This is an L3 hot path — see EXPERIMENTS.md §Perf.
//!
//! # Storage layout (CSF-style)
//!
//! Entries are stored sorted by `(fiber id, entry id)` in two parallel
//! arrays (`rows`, `vals`) — one contiguous segment per non-empty fiber,
//! so a fiber's entries are a cache-friendly linear scan. Fiber-id →
//! segment resolution is one of two compact offset tables, chosen at
//! build time:
//!
//! * **dense** — when the fiber-id space is small, a CSR-style `starts`
//!   array of length `n_fibers + 1`: fiber `f` owns
//!   `rows[starts[f]..starts[f+1]]`. O(1) lookup, no hashing, no search.
//! * **sorted** — otherwise, the sorted non-empty fiber ids plus their
//!   segment offsets, resolved by binary search. O(log n_nonempty)
//!   lookup with O(n_nonempty) memory, independent of the id space.
//!
//! Both layouts scatter exactly the same `(row, value)` pairs, so the
//! gather is bit-identical to the historical HashMap-COO index (asserted
//! by the `prop_fiber_gather_matches_bruteforce` property test and the
//! dense-vs-sorted test below); only the lookup cost changes.

use super::SparseTensor;

/// Above this many fiber ids the dense `starts` table is never built
/// (`(1 << 22) + 1` u32 ≈ 16 MB per mode at the cap).
const DENSE_MAX_FIBERS: usize = 1 << 22;

/// Fiber-id → entry-segment resolution (see the module docs).
#[derive(Debug, Clone)]
enum FiberLookup {
    /// CSR-style cumulative starts, length `n_fibers + 1`.
    Dense(Vec<u32>),
    /// Sorted non-empty fiber ids + segment offsets
    /// (`offsets.len() == fids.len() + 1`).
    Sorted { fids: Vec<u64>, offsets: Vec<u32> },
}

/// Entries of one mode grouped by fiber id.
#[derive(Debug, Clone)]
pub struct FiberIndex {
    pub mode: usize,
    /// row index within the mode (i_d) per grouped entry
    rows: Vec<u32>,
    /// value per grouped entry (parallel to `rows`)
    vals: Vec<f32>,
    /// fiber id -> segment into rows/vals
    lookup: FiberLookup,
    /// number of fibers with at least one nonzero
    pub n_nonempty: usize,
}

impl FiberIndex {
    /// Group all entries of `t` by their mode-`mode` fiber.
    pub fn build(t: &SparseTensor, mode: usize) -> Self {
        let nnz = t.nnz();
        // (fiber id, entry id) pairs in total (fid, e) order: segments are
        // contiguous and within-fiber entry order is deterministic.
        let mut keyed: Vec<(u64, u32)> =
            (0..nnz).map(|e| (t.fiber_of_entry(e, mode), e as u32)).collect();
        keyed.sort_unstable();

        let mut rows = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        let mut fids: Vec<u64> = Vec::new();
        let mut offsets: Vec<u32> = vec![0];
        let mut i = 0usize;
        while i < keyed.len() {
            let fid = keyed[i].0;
            fids.push(fid);
            while i < keyed.len() && keyed[i].0 == fid {
                let e = keyed[i].1 as usize;
                rows.push(t.entry_index(e, mode));
                vals.push(t.vals[e]);
                i += 1;
            }
            offsets.push(rows.len() as u32);
        }
        let n_nonempty = fids.len();

        // Dense starts pay O(n_fibers) memory for O(1) lookup — worth it
        // only when the id space is within a constant factor of the data.
        let n_fibers = t.n_fibers(mode);
        let lookup = if n_fibers <= DENSE_MAX_FIBERS && n_fibers <= 4 * nnz.max(1024) {
            let mut starts = vec![0u32; n_fibers + 1];
            let mut slot = 0usize; // index of the first fid >= f
            for (f, start) in starts.iter_mut().enumerate() {
                while slot < fids.len() && fids[slot] < f as u64 {
                    slot += 1;
                }
                *start = offsets[slot];
            }
            FiberLookup::Dense(starts)
        } else {
            FiberLookup::Sorted { fids, offsets }
        };
        FiberIndex { mode, rows, vals, lookup, n_nonempty }
    }

    /// Entry segment of fiber `fid` (empty range for empty/out-of-range
    /// ids).
    #[inline]
    fn range(&self, fid: u64) -> (usize, usize) {
        match &self.lookup {
            FiberLookup::Dense(starts) => {
                let f = fid as usize;
                if fid < (starts.len() - 1) as u64 {
                    (starts[f] as usize, starts[f + 1] as usize)
                } else {
                    (0, 0)
                }
            }
            FiberLookup::Sorted { fids, offsets } => match fids.binary_search(&fid) {
                Ok(s) => (offsets[s] as usize, offsets[s + 1] as usize),
                Err(_) => (0, 0),
            },
        }
    }

    /// Whether this index resolved to the dense (CSR-starts) layout.
    pub fn is_dense(&self) -> bool {
        matches!(self.lookup, FiberLookup::Dense(_))
    }

    /// Number of nonzeros in fiber `fid`.
    pub fn fiber_nnz(&self, fid: u64) -> usize {
        let (s, e) = self.range(fid);
        e - s
    }

    /// Iterate `(row, value)` pairs of fiber `fid`, in deterministic
    /// (original entry) order.
    pub fn fiber_entries(&self, fid: u64) -> impl Iterator<Item = (u32, f32)> + '_ {
        let (s, e) = self.range(fid);
        (s..e).map(move |k| (self.rows[k], self.vals[k]))
    }

    /// Scatter the sampled fibers into a dense row-major `I x |S|` buffer.
    ///
    /// `out` must hold `i_dim * fibers.len()` f32 and is fully overwritten
    /// (zero fill + scatter) — callers reuse the buffer across iterations.
    ///
    /// Serial path of [`FiberIndex::gather_slice_threads`]; always
    /// bit-identical to it at any thread count.
    pub fn gather_slice(&self, fibers: &[u64], i_dim: usize, out: &mut [f32]) {
        self.gather_slice_threads(fibers, i_dim, out, 1);
    }

    /// [`FiberIndex::gather_slice`] on the shared worker pool
    /// ([`crate::runtime::pool`]).
    ///
    /// Engages only when `threads > 1` and the output is at least
    /// [`crate::runtime::pool::thresholds::GATHER_PAR_MIN_CELLS`] cells
    /// (below that, pool hand-off costs more than the memory-bound scatter
    /// saves — see ARCHITECTURE.md for the crossover table). Two phases,
    /// both with disjoint writes and no reductions, so the result is
    /// **bit-identical** to the serial path at every thread count:
    ///
    /// 1. zero-fill, chunked by row panels
    ///    ([`crate::runtime::pool::thresholds::GATHER_ROWS_PER_JOB`] rows
    ///    per job — rows partition the buffer);
    /// 2. scatter, chunked by *columns* (each column is written only by
    ///    the job owning its fiber, so every `out` cell has exactly one
    ///    writer even when `fibers` contains duplicates of one fiber id —
    ///    duplicate columns are distinct cells).
    pub fn gather_slice_threads(
        &self,
        fibers: &[u64],
        i_dim: usize,
        out: &mut [f32],
        threads: usize,
    ) {
        use crate::runtime::pool::{self, thresholds};
        let s = fibers.len();
        assert_eq!(out.len(), i_dim * s);
        if threads <= 1 || s < 2 || i_dim * s < thresholds::GATHER_PAR_MIN_CELLS {
            out.fill(0.0);
            for (col, &fid) in fibers.iter().enumerate() {
                let (a, b) = self.range(fid);
                for k in a..b {
                    let row = self.rows[k] as usize;
                    debug_assert!(row < i_dim);
                    out[row * s + col] = self.vals[k];
                }
            }
            return;
        }

        let out_ptr = pool::SendPtr::new(out.as_mut_ptr());

        // Phase 1: zero fill. Row panels partition `out` exactly.
        let rows_per_job = thresholds::GATHER_ROWS_PER_JOB;
        let n_fill_jobs = i_dim.div_ceil(rows_per_job);
        pool::parallel_for(threads, n_fill_jobs, &|job| {
            let r0 = job * rows_per_job;
            let r1 = (r0 + rows_per_job).min(i_dim);
            // lint: allow(unsafe-containment) — audited SendPtr write
            // SAFETY: disjoint in-bounds panels [r0, r1); `out` outlives the call.
            let panel =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(r0 * s), (r1 - r0) * s) };
            panel.fill(0.0);
        });

        // Phase 2: scatter. Column ranges partition the fiber list; a job
        // only writes cells `row * s + col` with `col` in its own range.
        let n_scatter_jobs = (4 * threads).min(s);
        let cols_per_job = s.div_ceil(n_scatter_jobs);
        let n_jobs = s.div_ceil(cols_per_job);
        pool::parallel_for(threads, n_jobs, &|job| {
            let c0 = job * cols_per_job;
            let c1 = (c0 + cols_per_job).min(s);
            for (col, &fid) in fibers.iter().enumerate().take(c1).skip(c0) {
                let (a, b) = self.range(fid);
                for k in a..b {
                    let row = self.rows[k] as usize;
                    debug_assert!(row < i_dim);
                    // lint: allow(unsafe-containment) — audited SendPtr write
                    // SAFETY: `col` has exactly one owning job and
                    // `row < i_dim`: a single writer, always in bounds.
                    unsafe { *out_ptr.get().add(row * s + col) = self.vals[k] };
                }
            }
        });
    }

    /// Total stored entries (== tensor nnz).
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// All per-mode fiber indices of a local tensor (built once at load,
/// immutably shared across clients via
/// [`crate::tensor::partition::ShardData`]).
#[derive(Debug, Clone)]
pub struct ModeIndices {
    pub per_mode: Vec<FiberIndex>,
}

impl ModeIndices {
    pub fn build(t: &SparseTensor) -> Self {
        ModeIndices { per_mode: (0..t.order()).map(|m| FiberIndex::build(t, m)).collect() }
    }

    pub fn mode(&self, m: usize) -> &FiberIndex {
        &self.per_mode[m]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::encode_fiber;
    use crate::util::rng::Rng;

    fn random_tensor(dims: &[usize], nnz: usize, seed: u64) -> SparseTensor {
        let mut t = SparseTensor::new(dims.to_vec());
        let mut rng = Rng::new(seed);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < nnz {
            let idx: Vec<u32> = dims.iter().map(|&d| rng.below(d) as u32).collect();
            if seen.insert(t.linearize(&idx)) {
                let v = rng.normal_f32();
                t.push(&idx, if v == 0.0 { 1.0 } else { v });
            }
        }
        t
    }

    /// Dense oracle: materialize the full mode-d matricization.
    fn dense_unfold(t: &SparseTensor, mode: usize) -> Vec<f32> {
        let i_dim = t.dims[mode];
        let nf = t.n_fibers(mode);
        let mut m = vec![0.0f32; i_dim * nf];
        for e in 0..t.nnz() {
            let row = t.entry_index(e, mode) as usize;
            let col = t.fiber_of_entry(e, mode) as usize;
            m[row * nf + col] = t.vals[e];
        }
        m
    }

    #[test]
    fn gather_matches_dense_unfold_all_modes() {
        let t = random_tensor(&[6, 5, 4], 40, 9);
        for mode in 0..3 {
            let fi = FiberIndex::build(&t, mode);
            let i_dim = t.dims[mode];
            let nf = t.n_fibers(mode);
            let dense = dense_unfold(&t, mode);
            // gather every fiber in one call and compare column-by-column
            let fibers: Vec<u64> = (0..nf as u64).collect();
            let mut out = vec![0.0f32; i_dim * nf];
            fi.gather_slice(&fibers, i_dim, &mut out);
            assert_eq!(out, dense, "mode {mode}");
        }
    }

    #[test]
    fn gather_subset_and_duplicates() {
        let t = random_tensor(&[8, 3, 3], 30, 5);
        let fi = FiberIndex::build(&t, 0);
        let fibers = vec![2u64, 2, 7, 0];
        let mut out = vec![1.0f32; 8 * 4];
        fi.gather_slice(&fibers, 8, &mut out);
        // duplicated fiber columns must be identical
        for row in 0..8 {
            assert_eq!(out[row * 4], out[row * 4 + 1]);
        }
        // zero-fill happened (buffer had garbage 1.0s)
        let dense = dense_unfold(&t, 0);
        let nf = t.n_fibers(0);
        for row in 0..8 {
            assert_eq!(out[row * 4 + 3], dense[row * nf]);
        }
    }

    #[test]
    fn fiber_entries_and_nnz() {
        let mut t = SparseTensor::new(vec![4, 3, 2]);
        t.push(&[0, 1, 1], 5.0);
        t.push(&[2, 1, 1], 6.0);
        t.push(&[1, 0, 0], 7.0);
        let fi = FiberIndex::build(&t, 0);
        let fid = encode_fiber(&t.dims, 0, &[0, 1, 1]);
        assert_eq!(fi.fiber_nnz(fid), 2);
        let got: Vec<(u32, f32)> = fi.fiber_entries(fid).collect();
        assert!(got.contains(&(0, 5.0)) && got.contains(&(2, 6.0)));
        assert_eq!(fi.fiber_nnz(999), 0);
        assert_eq!(fi.n_nonempty, 2);
        assert_eq!(fi.len(), 3);
        assert!(fi.is_dense(), "tiny fiber space must take the dense path");
    }

    #[test]
    fn sorted_path_engages_on_huge_fiber_spaces() {
        // mode-0 fiber space is 3000*3000 = 9M ids > DENSE_MAX_FIBERS, so
        // the index must fall back to the binary-searched layout and still
        // resolve every fiber exactly.
        let mut t = SparseTensor::new(vec![4, 3000, 3000]);
        t.push(&[1, 7, 2999], 1.5);
        t.push(&[3, 7, 2999], 2.5);
        t.push(&[0, 0, 0], 3.5);
        let fi = FiberIndex::build(&t, 0);
        assert!(!fi.is_dense(), "9M-id space must take the sorted path");
        let fid = encode_fiber(&t.dims, 0, &[0, 7, 2999]);
        assert_eq!(fi.fiber_nnz(fid), 2);
        let got: Vec<(u32, f32)> = fi.fiber_entries(fid).collect();
        assert_eq!(got, vec![(1, 1.5), (3, 2.5)]);
        assert_eq!(fi.fiber_nnz(fid + 1), 0);
        let mut out = vec![9.0f32; 4 * 2];
        fi.gather_slice(&[fid, 0], 4, &mut out);
        assert_eq!(out, vec![0.0, 3.5, 1.5, 0.0, 0.0, 0.0, 2.5, 0.0]);
    }

    #[test]
    fn dense_and_sorted_layouts_agree() {
        // Same tensor, different modes hit different layouts: mode 0's
        // 6400-id space exceeds 4x nnz (sorted), the feature modes stay
        // dense — both must agree with the brute-force oracle (and hence
        // with each other).
        let t = random_tensor(&[6, 80, 80], 120, 17);
        for mode in 0..3 {
            let fi = FiberIndex::build(&t, mode);
            assert_eq!(fi.is_dense(), mode != 0, "mode {mode} layout");
            let dense = dense_unfold(&t, mode);
            let nf = t.n_fibers(mode);
            let fibers: Vec<u64> = (0..nf as u64).collect();
            let mut out = vec![f32::NAN; t.dims[mode] * nf];
            fi.gather_slice(&fibers, t.dims[mode], &mut out);
            assert_eq!(out, dense, "mode {mode}");
        }
    }

    #[test]
    fn threaded_gather_bit_identical_to_serial() {
        // 600 x (32*32) cells = 614,400 > GATHER_PAR_MIN_CELLS, so the
        // pooled two-phase path engages; its output must match the serial
        // scatter bitwise at every thread count (disjoint writes, no
        // reductions). Duplicate fiber ids exercise the one-writer-per-
        // *column* argument.
        let t = random_tensor(&[600, 32, 32], 2000, 21);
        let fi = FiberIndex::build(&t, 0);
        let nf = t.n_fibers(0);
        let mut fibers: Vec<u64> = (0..nf as u64).collect();
        fibers[7] = fibers[3]; // duplicate column
        let mut serial = vec![f32::NAN; 600 * fibers.len()];
        fi.gather_slice(&fibers, 600, &mut serial);
        for threads in [2usize, 4, 8] {
            let mut par = vec![f32::NAN; 600 * fibers.len()];
            fi.gather_slice_threads(&fibers, 600, &mut par, threads);
            assert!(
                par.iter().zip(serial.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads = {threads}"
            );
        }
    }

    #[test]
    fn mode_indices_builds_all() {
        let t = random_tensor(&[5, 4, 3, 2], 25, 3);
        let mi = ModeIndices::build(&t);
        assert_eq!(mi.per_mode.len(), 4);
        for m in 0..4 {
            assert_eq!(mi.mode(m).len(), 25);
            assert_eq!(mi.mode(m).mode, m);
        }
    }
}
