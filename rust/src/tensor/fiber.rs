//! Per-mode fiber index: the sparse -> dense gather behind fiber-sampled
//! MTTKRP (paper §III-B2, eq. 10).
//!
//! For a sampled fiber set `S_d` the engine needs the dense slice
//! `Y_<d>(:, S_d)` as an `I_d x |S|` row-major buffer for the PJRT gradient
//! artifact. Building it per iteration from raw COO would be O(nnz); the
//! `FiberIndex` groups entries of each mode by fiber id once (O(nnz log
//! nnz) at load), making each gather O(sum of nnz in the sampled fibers).
//! This is an L3 hot path — see EXPERIMENTS.md §Perf.

use std::collections::HashMap;

use super::SparseTensor;

/// Entries of one mode grouped by fiber id.
#[derive(Debug, Clone)]
pub struct FiberIndex {
    pub mode: usize,
    /// row index within the mode (i_d) per grouped entry
    rows: Vec<u32>,
    /// value per grouped entry (parallel to `rows`)
    vals: Vec<f32>,
    /// fiber id -> (start, end) range into rows/vals
    ranges: HashMap<u64, (u32, u32)>,
    /// number of fibers with at least one nonzero
    pub n_nonempty: usize,
}

impl FiberIndex {
    /// Group all entries of `t` by their mode-`mode` fiber.
    pub fn build(t: &SparseTensor, mode: usize) -> Self {
        let nnz = t.nnz();
        // (fiber id, entry id) pairs sorted by fiber id.
        let mut keyed: Vec<(u64, u32)> =
            (0..nnz).map(|e| (t.fiber_of_entry(e, mode), e as u32)).collect();
        keyed.sort_unstable_by_key(|&(f, _)| f);

        let mut rows = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        let mut ranges = HashMap::new();
        let mut i = 0usize;
        while i < keyed.len() {
            let fid = keyed[i].0;
            let start = i;
            while i < keyed.len() && keyed[i].0 == fid {
                let e = keyed[i].1 as usize;
                rows.push(t.entry_index(e, mode));
                vals.push(t.vals[e]);
                i += 1;
            }
            ranges.insert(fid, (start as u32, i as u32));
        }
        let n_nonempty = ranges.len();
        FiberIndex { mode, rows, vals, ranges, n_nonempty }
    }

    /// Number of nonzeros in fiber `fid`.
    pub fn fiber_nnz(&self, fid: u64) -> usize {
        self.ranges.get(&fid).map(|&(s, e)| (e - s) as usize).unwrap_or(0)
    }

    /// Iterate `(row, value)` pairs of fiber `fid`.
    pub fn fiber_entries(&self, fid: u64) -> impl Iterator<Item = (u32, f32)> + '_ {
        let (s, e) = self.ranges.get(&fid).copied().unwrap_or((0, 0));
        (s as usize..e as usize).map(move |k| (self.rows[k], self.vals[k]))
    }

    /// Scatter the sampled fibers into a dense row-major `I x |S|` buffer.
    ///
    /// `out` must hold `i_dim * fibers.len()` f32 and is fully overwritten
    /// (zero fill + scatter) — callers reuse the buffer across iterations.
    pub fn gather_slice(&self, fibers: &[u64], i_dim: usize, out: &mut [f32]) {
        let s = fibers.len();
        assert_eq!(out.len(), i_dim * s);
        out.fill(0.0);
        for (col, &fid) in fibers.iter().enumerate() {
            if let Some(&(a, b)) = self.ranges.get(&fid) {
                for k in a as usize..b as usize {
                    let row = self.rows[k] as usize;
                    debug_assert!(row < i_dim);
                    out[row * s + col] = self.vals[k];
                }
            }
        }
    }

    /// Total stored entries (== tensor nnz).
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// All per-mode fiber indices of a local tensor (built once at load).
#[derive(Debug, Clone)]
pub struct ModeIndices {
    pub per_mode: Vec<FiberIndex>,
}

impl ModeIndices {
    pub fn build(t: &SparseTensor) -> Self {
        ModeIndices { per_mode: (0..t.order()).map(|m| FiberIndex::build(t, m)).collect() }
    }

    pub fn mode(&self, m: usize) -> &FiberIndex {
        &self.per_mode[m]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::encode_fiber;
    use crate::util::rng::Rng;

    fn random_tensor(dims: &[usize], nnz: usize, seed: u64) -> SparseTensor {
        let mut t = SparseTensor::new(dims.to_vec());
        let mut rng = Rng::new(seed);
        let mut seen = std::collections::HashSet::new();
        while t.nnz() < nnz {
            let idx: Vec<u32> = dims.iter().map(|&d| rng.below(d) as u32).collect();
            if seen.insert(t.linearize(&idx)) {
                let v = rng.normal_f32();
                t.push(&idx, if v == 0.0 { 1.0 } else { v });
            }
        }
        t
    }

    /// Dense oracle: materialize the full mode-d matricization.
    fn dense_unfold(t: &SparseTensor, mode: usize) -> Vec<f32> {
        let i_dim = t.dims[mode];
        let nf = t.n_fibers(mode);
        let mut m = vec![0.0f32; i_dim * nf];
        for e in 0..t.nnz() {
            let row = t.entry_index(e, mode) as usize;
            let col = t.fiber_of_entry(e, mode) as usize;
            m[row * nf + col] = t.vals[e];
        }
        m
    }

    #[test]
    fn gather_matches_dense_unfold_all_modes() {
        let t = random_tensor(&[6, 5, 4], 40, 9);
        for mode in 0..3 {
            let fi = FiberIndex::build(&t, mode);
            let i_dim = t.dims[mode];
            let nf = t.n_fibers(mode);
            let dense = dense_unfold(&t, mode);
            // gather every fiber in one call and compare column-by-column
            let fibers: Vec<u64> = (0..nf as u64).collect();
            let mut out = vec![0.0f32; i_dim * nf];
            fi.gather_slice(&fibers, i_dim, &mut out);
            assert_eq!(out, dense, "mode {mode}");
        }
    }

    #[test]
    fn gather_subset_and_duplicates() {
        let t = random_tensor(&[8, 3, 3], 30, 5);
        let fi = FiberIndex::build(&t, 0);
        let fibers = vec![2u64, 2, 7, 0];
        let mut out = vec![1.0f32; 8 * 4];
        fi.gather_slice(&fibers, 8, &mut out);
        // duplicated fiber columns must be identical
        for row in 0..8 {
            assert_eq!(out[row * 4], out[row * 4 + 1]);
        }
        // zero-fill happened (buffer had garbage 1.0s)
        let dense = dense_unfold(&t, 0);
        let nf = t.n_fibers(0);
        for row in 0..8 {
            assert_eq!(out[row * 4 + 3], dense[row * nf]);
        }
    }

    #[test]
    fn fiber_entries_and_nnz() {
        let mut t = SparseTensor::new(vec![4, 3, 2]);
        t.push(&[0, 1, 1], 5.0);
        t.push(&[2, 1, 1], 6.0);
        t.push(&[1, 0, 0], 7.0);
        let fi = FiberIndex::build(&t, 0);
        let fid = encode_fiber(&t.dims, 0, &[0, 1, 1]);
        assert_eq!(fi.fiber_nnz(fid), 2);
        let got: Vec<(u32, f32)> = fi.fiber_entries(fid).collect();
        assert!(got.contains(&(0, 5.0)) && got.contains(&(2, 6.0)));
        assert_eq!(fi.fiber_nnz(999), 0);
        assert_eq!(fi.n_nonempty, 2);
        assert_eq!(fi.len(), 3);
    }

    #[test]
    fn mode_indices_builds_all() {
        let t = random_tensor(&[5, 4, 3, 2], 25, 3);
        let mi = ModeIndices::build(&t);
        assert_eq!(mi.per_mode.len(), 4);
        for m in 0..4 {
            assert_eq!(mi.mode(m).len(), 25);
            assert_eq!(mi.mode(m).mode, m);
        }
    }
}
