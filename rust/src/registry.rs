//! Name → constructor registries for every pluggable axis.
//!
//! Historically each axis of the experiment space grew its own ad-hoc
//! lookup (`AlgoConfig::by_name`, `Loss::from_name`,
//! `Topology::from_name`, `FaultConfig::by_name`,
//! `DriverKind::from_name`) with its own error wording and no common way
//! to enumerate the choices. This module collapses them onto one
//! [`Registry`] type:
//!
//! * every entry has a canonical name, aliases, a one-line help string,
//!   and a constructor taking the optional `:arg` suffix
//!   (`cidertf:8`, `lossy:0.2`, `topk:16`),
//! * unknown names fail with the full known-name list *and* a
//!   did-you-mean suggestion,
//! * `cidertf info` prints every registry, so the scenario vocabulary is
//!   discoverable from the CLI instead of from source code.
//!
//! The legacy `by_name`/`from_name` constructors remain as thin wrappers
//! over [`algos`], [`losses`], [`topologies`], [`compressors`],
//! [`networks`], and [`drivers`]; datasets resolve through
//! [`crate::data::load_dataset`].

use std::path::PathBuf;

use crate::adversary::AdversarySchedule;
use crate::compress::Compressor;
use crate::data::{CsvSource, DatasetSource, FileSource, SynthSource};
use crate::engine::AlgoConfig;
use crate::gossip::Aggregator;
use crate::losses::Loss;
use crate::net::driver::DriverKind;
use crate::net::sim::FaultConfig;
use crate::node::transport::TransportKind;
use crate::tensor::partition::Partitioner;
use crate::tensor::synth::SynthConfig;
use crate::topology::Topology;

/// One named constructor in a [`Registry`].
pub struct RegEntry<T: 'static> {
    /// canonical CLI name
    pub name: &'static str,
    /// accepted alternative spellings
    pub aliases: &'static [&'static str],
    /// one-line description (shown by `cidertf info`); include the `:arg`
    /// syntax here when the entry takes one
    pub help: &'static str,
    /// constructor; receives the text after `:` in the spec, if any
    pub make: fn(Option<&str>) -> anyhow::Result<T>,
}

/// A name → constructor table for one pluggable axis.
pub struct Registry<T: 'static> {
    kind: &'static str,
    entries: &'static [RegEntry<T>],
}

impl<T: 'static> Registry<T> {
    /// Build a registry over a static entry table.
    pub const fn new(kind: &'static str, entries: &'static [RegEntry<T>]) -> Self {
        Registry { kind, entries }
    }

    /// What this registry constructs (for error messages), e.g.
    /// `"algorithm"`.
    pub fn kind(&self) -> &'static str {
        self.kind
    }

    /// The entry table (for `cidertf info`).
    pub fn entries(&self) -> &'static [RegEntry<T>] {
        self.entries
    }

    /// Canonical names, in table order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// Formatted `name  help (aliases: ...)` lines — the type-erased
    /// view `cidertf info` prints, so adding a registry automatically
    /// surfaces it in the CLI.
    pub fn help_lines(&self) -> Vec<String> {
        self.entries
            .iter()
            .map(|e| {
                let aliases = if e.aliases.is_empty() {
                    String::new()
                } else {
                    format!(" (aliases: {})", e.aliases.join(", "))
                };
                format!("  {:<22} {}{}", e.name, e.help, aliases)
            })
            .collect()
    }

    /// Resolve `name[:arg]` to a constructed value.
    pub fn resolve(&self, spec: &str) -> anyhow::Result<T> {
        let (name, arg) = match spec.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (spec, None),
        };
        for e in self.entries {
            if e.name == name || e.aliases.contains(&name) {
                return (e.make)(arg)
                    .map_err(|err| anyhow::anyhow!("{} '{spec}': {err}", self.kind));
            }
        }
        let known = self.names().join("|");
        match did_you_mean(name, self.entries.iter().map(|e| e.name)) {
            Some(s) => anyhow::bail!(
                "unknown {} '{name}' — did you mean '{s}'? (known: {known})",
                self.kind
            ),
            None => anyhow::bail!("unknown {} '{name}' (known: {known})", self.kind),
        }
    }

    /// Resolve a whole axis list (a sweep-spec grid), tagging errors with
    /// the failing element's position so `"network scenario axis [2]:
    /// unknown network scenario 'lozzy' — did you mean 'lossy'?"` points
    /// at the exact grid cell.
    pub fn resolve_list(&self, specs: &[String]) -> anyhow::Result<Vec<T>> {
        specs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                self.resolve(s).map_err(|e| anyhow::anyhow!("{} axis [{i}]: {e}", self.kind))
            })
            .collect()
    }
}

/// Levenshtein edit distance (iterative two-row DP) — small inputs only.
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() {
        return b.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The closest known name, if it is close enough to be a plausible typo
/// (edit distance ≤ 2, or ≤ a third of the name's length for long names,
/// or a unique prefix/superstring match).
pub fn did_you_mean<'a>(
    unknown: &str,
    known: impl Iterator<Item = &'a str>,
) -> Option<&'a str> {
    let mut best: Option<(&str, usize)> = None;
    for k in known {
        if k.starts_with(unknown) || unknown.starts_with(k) {
            return Some(k);
        }
        let d = edit_distance(unknown, k);
        if best.map(|(_, bd)| d < bd).unwrap_or(true) {
            best = Some((k, d));
        }
    }
    let (k, d) = best?;
    let budget = 2.max(k.len() / 3);
    (d <= budget).then_some(k)
}

// ---- shared argument parsers ----

fn no_arg(kind: &'static str, arg: Option<&str>) -> anyhow::Result<()> {
    match arg {
        None => Ok(()),
        Some(a) => anyhow::bail!("{kind} takes no ':' argument (got ':{a}')"),
    }
}

fn usize_arg(arg: Option<&str>, what: &str, default: usize) -> anyhow::Result<usize> {
    match arg {
        None => Ok(default),
        Some(a) => a.parse().map_err(|_| anyhow::anyhow!("bad {what} '{a}' (expected an integer)")),
    }
}

fn f64_arg(arg: Option<&str>, what: &str, default: f64) -> anyhow::Result<f64> {
    match arg {
        None => Ok(default),
        Some(a) => a.parse().map_err(|_| anyhow::anyhow!("bad {what} '{a}' (expected a number)")),
    }
}

// ---- algorithms (paper Table II + centralized baselines) ----

/// Algorithm presets: the Table II feature matrix plus the centralized
/// baselines, each one configuration of the same engine.
pub fn algos() -> &'static Registry<AlgoConfig> {
    static ENTRIES: &[RegEntry<AlgoConfig>] = &[
        RegEntry {
            name: "cidertf",
            aliases: &[],
            help: "cidertf[:tau] — sign + block random + periodic(τ) + event-triggered",
            make: |a| Ok(AlgoConfig::cidertf(usize_arg(a, "tau", 4)?)),
        },
        RegEntry {
            name: "cidertf_m",
            aliases: &[],
            help: "cidertf_m[:tau] — CiderTF + Nesterov momentum (β = 0.9)",
            make: |a| Ok(AlgoConfig::cidertf_m(usize_arg(a, "tau", 4)?)),
        },
        RegEntry {
            name: "dpsgd",
            aliases: &[],
            help: "D-PSGD: full precision, all modes, every round",
            make: |a| {
                no_arg("dpsgd", a)?;
                Ok(AlgoConfig::dpsgd())
            },
        },
        RegEntry {
            name: "dpsgd_bras",
            aliases: &[],
            help: "D-PSGD + block randomization",
            make: |a| {
                no_arg("dpsgd_bras", a)?;
                Ok(AlgoConfig::dpsgd_bras())
            },
        },
        RegEntry {
            name: "dpsgd_sign",
            aliases: &[],
            help: "D-PSGD + sign compression",
            make: |a| {
                no_arg("dpsgd_sign", a)?;
                Ok(AlgoConfig::dpsgd_sign())
            },
        },
        RegEntry {
            name: "dpsgd_bras_sign",
            aliases: &[],
            help: "D-PSGD + block randomization + sign compression",
            make: |a| {
                no_arg("dpsgd_bras_sign", a)?;
                Ok(AlgoConfig::dpsgd_bras_sign())
            },
        },
        RegEntry {
            name: "sparq_sgd",
            aliases: &[],
            help: "sparq_sgd[:tau] — compression + periodic + event-triggered, all modes",
            make: |a| Ok(AlgoConfig::sparq_sgd(usize_arg(a, "tau", 4)?)),
        },
        RegEntry {
            name: "gcp",
            aliases: &[],
            help: "centralized stochastic generalized CP (run with K = 1)",
            make: |a| {
                no_arg("gcp", a)?;
                Ok(AlgoConfig::gcp())
            },
        },
        RegEntry {
            name: "bras_cpd",
            aliases: &[],
            help: "centralized block-randomized stochastic CPD (K = 1)",
            make: |a| {
                no_arg("bras_cpd", a)?;
                Ok(AlgoConfig::bras_cpd())
            },
        },
        RegEntry {
            name: "centralized_cidertf",
            aliases: &[],
            help: "K = 1, sign-compressed updates with error feedback",
            make: |a| {
                no_arg("centralized_cidertf", a)?;
                Ok(AlgoConfig::centralized_cidertf())
            },
        },
    ];
    static REG: Registry<AlgoConfig> = Registry::new("algorithm", ENTRIES);
    &REG
}

// ---- losses ----

/// GCP elementwise losses.
pub fn losses() -> &'static Registry<Loss> {
    static ENTRIES: &[RegEntry<Loss>] = &[
        RegEntry {
            name: "logit",
            aliases: &["bernoulli", "bernoulli_logit"],
            help: "Bernoulli-logit loss — binary data",
            make: |a| {
                no_arg("logit", a)?;
                Ok(Loss::Logit)
            },
        },
        RegEntry {
            name: "ls",
            aliases: &["least_squares", "gaussian"],
            help: "least squares — Gaussian data, classic CP",
            make: |a| {
                no_arg("ls", a)?;
                Ok(Loss::Ls)
            },
        },
    ];
    static REG: Registry<Loss> = Registry::new("loss", ENTRIES);
    &REG
}

// ---- topologies ----

/// Communication graph topologies.
pub fn topologies() -> &'static Registry<Topology> {
    static ENTRIES: &[RegEntry<Topology>] = &[
        RegEntry {
            name: "ring",
            aliases: &[],
            help: "cycle over K clients (paper default)",
            make: |a| {
                no_arg("ring", a)?;
                Ok(Topology::Ring)
            },
        },
        RegEntry {
            name: "star",
            aliases: &[],
            help: "hub-and-spoke around client 0",
            make: |a| {
                no_arg("star", a)?;
                Ok(Topology::Star)
            },
        },
        RegEntry {
            name: "complete",
            aliases: &["full"],
            help: "all-to-all",
            make: |a| {
                no_arg("complete", a)?;
                Ok(Topology::Complete)
            },
        },
        RegEntry {
            name: "chain",
            aliases: &["line"],
            help: "open path",
            make: |a| {
                no_arg("chain", a)?;
                Ok(Topology::Chain)
            },
        },
        RegEntry {
            name: "torus",
            aliases: &["grid"],
            help: "2-D torus (K must be a perfect square)",
            make: |a| {
                no_arg("torus", a)?;
                Ok(Topology::Torus)
            },
        },
    ];
    static REG: Registry<Topology> = Registry::new("topology", ENTRIES);
    &REG
}

// ---- compressors ----

/// Element-level compressors (Table II "Element-level" column).
pub fn compressors() -> &'static Registry<Compressor> {
    static ENTRIES: &[RegEntry<Compressor>] = &[
        RegEntry {
            name: "sign",
            aliases: &[],
            help: "Def. III.1 sign compressor — 1 bit/entry + scale",
            make: |a| {
                no_arg("sign", a)?;
                Ok(Compressor::Sign)
            },
        },
        RegEntry {
            name: "none",
            aliases: &["dense"],
            help: "identity — full-precision f32",
            make: |a| {
                no_arg("none", a)?;
                Ok(Compressor::None)
            },
        },
        RegEntry {
            name: "topk",
            aliases: &[],
            help: "topk[:ratio] — keep the n/ratio largest-magnitude entries (default 4)",
            make: |a| {
                let ratio = usize_arg(a, "topk ratio", 4)?;
                anyhow::ensure!(ratio >= 1 && ratio <= u32::MAX as usize, "ratio {ratio} out of range");
                Ok(Compressor::TopK { ratio: ratio as u32 })
            },
        },
    ];
    static REG: Registry<Compressor> = Registry::new("compressor", ENTRIES);
    &REG
}

// ---- network fault envelopes ----

/// Network scenarios; `None` is the ideal (fault-free) network.
pub fn networks() -> &'static Registry<Option<FaultConfig>> {
    static ENTRIES: &[RegEntry<Option<FaultConfig>>] = &[
        RegEntry {
            name: "ideal",
            aliases: &[],
            help: "lossless, zero latency, everyone online",
            make: |a| {
                no_arg("ideal", a)?;
                Ok(None)
            },
        },
        RegEntry {
            name: "lossy",
            aliases: &[],
            help: "lossy[:p] — i.i.d. message drops at probability p (default 0.2)",
            make: |a| {
                let p = f64_arg(a, "drop probability", 0.2)?;
                anyhow::ensure!((0.0..=1.0).contains(&p), "drop probability {p} out of range [0, 1]");
                Ok(Some(FaultConfig::lossy(p)))
            },
        },
        RegEntry {
            name: "bursty",
            aliases: &[],
            help: "Gilbert–Elliott loss bursts on mostly-clean links",
            make: |a| {
                no_arg("bursty", a)?;
                Ok(Some(FaultConfig::bursty()))
            },
        },
        RegEntry {
            name: "wan",
            aliases: &[],
            help: "heterogeneous WAN latency/bandwidth, no loss",
            make: |a| {
                no_arg("wan", a)?;
                Ok(Some(FaultConfig::wan()))
            },
        },
        RegEntry {
            name: "stragglers",
            aliases: &[],
            help: "a quarter of the clients compute 4x slower",
            make: |a| {
                no_arg("stragglers", a)?;
                Ok(Some(FaultConfig::stragglers()))
            },
        },
        RegEntry {
            name: "churning",
            aliases: &[],
            help: "clients leave and rejoin (10% downtime, 50-round blocks)",
            make: |a| {
                no_arg("churning", a)?;
                Ok(Some(FaultConfig::churning()))
            },
        },
        RegEntry {
            name: "hostile",
            aliases: &[],
            help: "drops + bursts + WAN + stragglers + churn at once",
            make: |a| {
                no_arg("hostile", a)?;
                Ok(Some(FaultConfig::hostile()))
            },
        },
    ];
    static REG: Registry<Option<FaultConfig>> = Registry::new("network scenario", ENTRIES);
    &REG
}

// ---- adversary schedules ----

fn fraction_arg(arg: Option<&str>) -> anyhow::Result<f64> {
    let f = f64_arg(arg, "adversarial fraction", AdversarySchedule::DEFAULT_FRACTION)?;
    anyhow::ensure!((0.0..=1.0).contains(&f), "adversarial fraction {f} out of range [0, 1]");
    Ok(f)
}

/// Byzantine-client schedules; `None` is the all-honest network.
pub fn adversaries() -> &'static Registry<Option<AdversarySchedule>> {
    static ENTRIES: &[RegEntry<Option<AdversarySchedule>>] = &[
        RegEntry {
            name: "honest",
            aliases: &["none"],
            help: "every client publishes its true delta",
            make: |a| {
                no_arg("honest", a)?;
                Ok(None)
            },
        },
        RegEntry {
            name: "sign_flip",
            aliases: &["signflip"],
            help: "sign_flip[:frac] — frac of clients negate every published delta (default 0.2)",
            make: |a| Ok(Some(AdversarySchedule::sign_flip(fraction_arg(a)?))),
        },
        RegEntry {
            name: "scaled_noise",
            aliases: &["noise"],
            help: "scaled_noise[:frac] — frac of clients add large Gaussian noise (default 0.2)",
            make: |a| Ok(Some(AdversarySchedule::scaled_noise(fraction_arg(a)?))),
        },
        RegEntry {
            name: "stale_replay",
            aliases: &["stale", "replay"],
            help: "stale_replay[:frac] — frac of clients rebroadcast old deltas (default 0.2)",
            make: |a| Ok(Some(AdversarySchedule::stale_replay(fraction_arg(a)?))),
        },
    ];
    static REG: Registry<Option<AdversarySchedule>> = Registry::new("adversary", ENTRIES);
    &REG
}

// ---- consensus aggregators ----

/// Consensus combiners for peer estimates (gossip robustness axis).
pub fn aggregators() -> &'static Registry<Aggregator> {
    static ENTRIES: &[RegEntry<Aggregator>] = &[
        RegEntry {
            name: "mean",
            aliases: &[],
            help: "weighted mean — the paper's consensus step",
            make: |a| {
                no_arg("mean", a)?;
                Ok(Aggregator::Mean)
            },
        },
        RegEntry {
            name: "trimmed_mean",
            aliases: &["trim"],
            help: "trimmed_mean[:beta] — drop the beta-fraction extremes per coordinate (default 0.2)",
            make: |a| {
                let b = f64_arg(a, "trim fraction", 0.2)?;
                anyhow::ensure!((0.0..0.5).contains(&b), "trim fraction {b} out of range [0, 0.5)");
                Ok(Aggregator::TrimmedMean(b))
            },
        },
        RegEntry {
            name: "coordinate_median",
            aliases: &["median"],
            help: "coordinate-wise median of self + neighbor estimates",
            make: |a| {
                no_arg("coordinate_median", a)?;
                Ok(Aggregator::CoordinateMedian)
            },
        },
    ];
    static REG: Registry<Aggregator> = Registry::new("aggregator", ENTRIES);
    &REG
}

// ---- patient partitioners ----

/// Mode-0 (patient) partitioners — how rows are split across sites.
pub fn partitioners() -> &'static Registry<Partitioner> {
    static ENTRIES: &[RegEntry<Partitioner>] = &[
        RegEntry {
            name: "even",
            aliases: &["uniform"],
            help: "contiguous near-equal shards (the i.i.d. baseline)",
            make: |a| {
                no_arg("even", a)?;
                Ok(Partitioner::Even)
            },
        },
        RegEntry {
            name: "skewed",
            aliases: &[],
            help: "skewed[:alpha] — power-law patient counts per site (default 1.0)",
            make: |a| {
                let alpha = f64_arg(a, "skew exponent", 1.0)?;
                anyhow::ensure!(
                    alpha.is_finite() && alpha >= 0.0,
                    "skew exponent {alpha} must be finite and >= 0"
                );
                Ok(Partitioner::Skewed(alpha))
            },
        },
        RegEntry {
            name: "site_vocab",
            aliases: &["vocab"],
            help: "site_vocab[:overlap] — per-site code vocabularies sharing an overlap fraction (default 0.3)",
            make: |a| {
                let ov = f64_arg(a, "vocabulary overlap", 0.3)?;
                anyhow::ensure!((0.0..=1.0).contains(&ov), "vocabulary overlap {ov} out of range [0, 1]");
                Ok(Partitioner::SiteVocab(ov))
            },
        },
    ];
    static REG: Registry<Partitioner> = Registry::new("partitioner", ENTRIES);
    &REG
}

// ---- round drivers ----

/// Execution paths (how rounds are driven).
pub fn drivers() -> &'static Registry<DriverKind> {
    static ENTRIES: &[RegEntry<DriverKind>] = &[
        RegEntry {
            name: "seq",
            aliases: &["sequential"],
            help: "in-process lock-step (the reference path)",
            make: |a| {
                no_arg("seq", a)?;
                Ok(DriverKind::Sequential)
            },
        },
        RegEntry {
            name: "par",
            aliases: &["parallel"],
            help: "one OS thread per client, barrier-synchronized",
            make: |a| {
                no_arg("par", a)?;
                Ok(DriverKind::Parallel)
            },
        },
        RegEntry {
            name: "sim",
            aliases: &[],
            help: "lock-step rounds through a NetworkModel on a virtual clock",
            make: |a| {
                no_arg("sim", a)?;
                Ok(DriverKind::Sim)
            },
        },
        RegEntry {
            name: "async",
            aliases: &[],
            help: "event-driven asynchronous gossip (no barriers)",
            make: |a| {
                no_arg("async", a)?;
                Ok(DriverKind::Async)
            },
        },
        RegEntry {
            name: "node",
            aliases: &["fleet"],
            help: "one OS process per client over real sockets (cidertf node / fleet)",
            make: |a| {
                no_arg("node", a)?;
                Ok(DriverKind::Node)
            },
        },
    ];
    static REG: Registry<DriverKind> = Registry::new("driver", ENTRIES);
    &REG
}

// ---- node transports ----

/// Socket transports for the `node` driver (`spec.transport`).
pub fn transports() -> &'static Registry<TransportKind> {
    static ENTRIES: &[RegEntry<TransportKind>] = &[
        RegEntry {
            name: "tcp",
            aliases: &[],
            help: "TCP over loopback or LAN — addr is host:port",
            make: |a| {
                no_arg("tcp", a)?;
                Ok(TransportKind::Tcp)
            },
        },
        RegEntry {
            name: "uds",
            aliases: &["unix"],
            help: "Unix-domain sockets — addr is a filesystem path",
            make: |a| {
                no_arg("uds", a)?;
                Ok(TransportKind::Uds)
            },
        },
    ];
    static REG: Registry<TransportKind> = Registry::new("transport", ENTRIES);
    &REG
}

// ---- datasets ----

/// Dataset sources: synthetic generators plus the on-disk loaders
/// (`file:<path>`, `csv:<path>`) from [`crate::data`].
pub fn datasets() -> &'static Registry<Box<dyn DatasetSource>> {
    static ENTRIES: &[RegEntry<Box<dyn DatasetSource>>] = &[
        RegEntry {
            name: "synthetic",
            aliases: &[],
            help: "mid-size synthetic EHR tensor (quick-profile default)",
            make: |a| {
                no_arg("synthetic", a)?;
                Ok(Box::new(SynthSource(SynthConfig::synthetic())) as Box<dyn DatasetSource>)
            },
        },
        RegEntry {
            name: "mimic_like",
            aliases: &["mimic"],
            help: "MIMIC-III-shaped tensor",
            make: |a| {
                no_arg("mimic_like", a)?;
                Ok(Box::new(SynthSource(SynthConfig::mimic_like())) as Box<dyn DatasetSource>)
            },
        },
        RegEntry {
            name: "cms_like",
            aliases: &["cms"],
            help: "CMS-shaped tensor",
            make: |a| {
                no_arg("cms_like", a)?;
                Ok(Box::new(SynthSource(SynthConfig::cms_like())) as Box<dyn DatasetSource>)
            },
        },
        RegEntry {
            name: "mimic_full",
            aliases: &[],
            help: "full-scale MIMIC-III-shaped tensor",
            make: |a| {
                no_arg("mimic_full", a)?;
                Ok(Box::new(SynthSource(SynthConfig::mimic_full())) as Box<dyn DatasetSource>)
            },
        },
        RegEntry {
            name: "tiny",
            aliases: &[],
            help: "tiny[:seed] — 64x32x32 test tensor (default seed 7)",
            make: |a| {
                Ok(Box::new(SynthSource(SynthConfig::tiny(usize_arg(a, "seed", 7)? as u64)))
                    as Box<dyn DatasetSource>)
            },
        },
        RegEntry {
            name: "file",
            aliases: &[],
            help: "file:<path> — load a FROSTT-style .tns or binary .bin/.ctf tensor",
            make: |a| {
                let p = a.ok_or_else(|| anyhow::anyhow!("file:<path> requires a path"))?;
                Ok(Box::new(FileSource(PathBuf::from(p))) as Box<dyn DatasetSource>)
            },
        },
        RegEntry {
            name: "csv",
            aliases: &[],
            help: "csv:<path> — event-log CSV (patient,code,time) -> count tensor",
            make: |a| {
                let p = a.ok_or_else(|| anyhow::anyhow!("csv:<path> requires a path"))?;
                Ok(Box::new(CsvSource(PathBuf::from(p))) as Box<dyn DatasetSource>)
            },
        },
    ];
    static REG: Registry<Box<dyn DatasetSource>> = Registry::new("dataset", ENTRIES);
    &REG
}

/// Every registry's `(kind-plural, names)` pair.
pub fn axis_names() -> Vec<(&'static str, Vec<&'static str>)> {
    vec![
        ("algorithms", algos().names()),
        ("losses", losses().names()),
        ("compressors", compressors().names()),
        ("topologies", topologies().names()),
        ("networks", networks().names()),
        ("adversaries", adversaries().names()),
        ("aggregators", aggregators().names()),
        ("partitioners", partitioners().names()),
        ("drivers", drivers().names()),
        ("transports", transports().names()),
        ("datasets", datasets().names()),
    ]
}

/// Every registry's `(kind-plural, formatted help lines)` pair — the
/// single `cidertf info` vocabulary dump. New registries added here show
/// up in the CLI with no further wiring.
pub fn axis_help() -> Vec<(&'static str, Vec<String>)> {
    vec![
        ("algorithms", algos().help_lines()),
        ("losses", losses().help_lines()),
        ("compressors", compressors().help_lines()),
        ("topologies", topologies().help_lines()),
        ("networks", networks().help_lines()),
        ("adversaries", adversaries().help_lines()),
        ("aggregators", aggregators().help_lines()),
        ("partitioners", partitioners().help_lines()),
        ("drivers", drivers().help_lines()),
        ("transports", transports().help_lines()),
        ("datasets", datasets().help_lines()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_with_and_without_args() {
        assert_eq!(algos().resolve("cidertf:8").unwrap().tau, 8);
        assert_eq!(algos().resolve("cidertf").unwrap().tau, 4);
        assert_eq!(losses().resolve("gaussian").unwrap(), Loss::Ls);
        assert_eq!(topologies().resolve("full").unwrap(), Topology::Complete);
        assert_eq!(drivers().resolve("sequential").unwrap(), DriverKind::Sequential);
        assert_eq!(compressors().resolve("topk:16").unwrap(), Compressor::TopK { ratio: 16 });
        assert!(networks().resolve("ideal").unwrap().is_none());
        let lossy = networks().resolve("lossy:0.3").unwrap().unwrap();
        assert!((lossy.drop_rate - 0.3).abs() < 1e-12);
    }

    #[test]
    fn unknown_names_suggest_and_enumerate() {
        let err = format!("{:#}", algos().resolve("cidrtf").unwrap_err());
        assert!(err.contains("did you mean 'cidertf'"), "{err}");
        assert!(err.contains("dpsgd"), "known list missing: {err}");
        let err = format!("{:#}", networks().resolve("lozzy:0.2").unwrap_err());
        assert!(err.contains("lossy"), "{err}");
        // nothing close: no suggestion, but still the known list
        let err = format!("{:#}", losses().resolve("zzz").unwrap_err());
        assert!(!err.contains("did you mean"), "{err}");
        assert!(err.contains("logit"), "{err}");
    }

    #[test]
    fn resolve_list_tags_the_failing_index() {
        let specs: Vec<String> = vec!["ring".into(), "lozenge".into()];
        let err = format!("{:#}", topologies().resolve_list(&specs).unwrap_err());
        assert!(err.contains("axis [1]"), "{err}");
        let ok = topologies().resolve_list(&["ring".to_string(), "star".to_string()]).unwrap();
        assert_eq!(ok, vec![Topology::Ring, Topology::Star]);
    }

    #[test]
    fn bad_args_are_errors() {
        assert!(algos().resolve("cidertf:x").is_err());
        assert!(algos().resolve("dpsgd:3").is_err(), "no-arg entry must reject ':3'");
        assert!(networks().resolve("lossy:1.5").is_err());
        assert!(networks().resolve("lossy:abc").is_err());
        assert!(compressors().resolve("topk:0").is_err());
    }

    #[test]
    fn dataset_sources_resolve() {
        assert!(datasets().resolve("tiny:9").is_ok());
        assert!(datasets().resolve("mimic").is_ok(), "alias");
        let src = datasets().resolve("file:examples/data/tiny.tns").unwrap();
        assert!(src.describe().contains("tiny.tns"));
        let err = format!("{:#}", datasets().resolve("file").unwrap_err());
        assert!(err.contains("requires a path"), "{err}");
        assert!(datasets().resolve("csv").is_err());
        assert!(datasets().resolve("tiny:x").is_err());
    }

    #[test]
    fn robustness_axes_resolve() {
        assert!(adversaries().resolve("honest").unwrap().is_none());
        let s = adversaries().resolve("sign_flip:0.4").unwrap().unwrap();
        assert!((s.fraction - 0.4).abs() < 1e-12);
        let s = adversaries().resolve("stale").unwrap().unwrap();
        assert!((s.fraction - AdversarySchedule::DEFAULT_FRACTION).abs() < 1e-12);
        assert_eq!(aggregators().resolve("mean").unwrap(), Aggregator::Mean);
        assert_eq!(aggregators().resolve("trim:0.25").unwrap(), Aggregator::TrimmedMean(0.25));
        assert_eq!(aggregators().resolve("median").unwrap(), Aggregator::CoordinateMedian);
        assert_eq!(partitioners().resolve("even").unwrap(), Partitioner::Even);
        assert_eq!(partitioners().resolve("skewed:1.5").unwrap(), Partitioner::Skewed(1.5));
        assert_eq!(partitioners().resolve("vocab").unwrap(), Partitioner::SiteVocab(0.3));
    }

    #[test]
    fn robustness_axes_reject_bad_specs() {
        // typos get a did-you-mean pointing at the new names
        let err = format!("{:#}", adversaries().resolve("sing_flip").unwrap_err());
        assert!(err.contains("did you mean 'sign_flip'"), "{err}");
        let err = format!("{:#}", aggregators().resolve("trimed_mean").unwrap_err());
        assert!(err.contains("trimmed_mean"), "{err}");
        let err = format!("{:#}", partitioners().resolve("skewd").unwrap_err());
        assert!(err.contains("skewed"), "{err}");
        // out-of-range arguments are rejected with the range in the message
        assert!(adversaries().resolve("sign_flip:1.5").is_err());
        assert!(aggregators().resolve("trimmed_mean:0.5").is_err(), "beta 0.5 trims everything");
        assert!(aggregators().resolve("mean:0.1").is_err(), "mean takes no argument");
        assert!(partitioners().resolve("site_vocab:-0.1").is_err());
        assert!(partitioners().resolve("skewed:nan").is_err());
    }

    #[test]
    fn node_axes_resolve_with_did_you_mean() {
        assert_eq!(drivers().resolve("node").unwrap(), DriverKind::Node);
        assert_eq!(drivers().resolve("fleet").unwrap(), DriverKind::Node, "alias");
        assert_eq!(transports().resolve("tcp").unwrap(), TransportKind::Tcp);
        assert_eq!(transports().resolve("unix").unwrap(), TransportKind::Uds, "alias");
        let err = format!("{:#}", transports().resolve("tpc").unwrap_err());
        assert!(err.contains("did you mean 'tcp'"), "{err}");
        assert!(err.contains("uds"), "known list missing: {err}");
        assert!(transports().resolve("tcp:9").is_err(), "no-arg entry must reject ':9'");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("cidrtf", "cidertf"), 1);
        assert_eq!(edit_distance("ring", "star"), 4);
    }

    #[test]
    fn did_you_mean_thresholds() {
        let names = ["ring", "star", "complete", "chain", "torus"];
        assert_eq!(did_you_mean("rign", names.iter().copied()), Some("ring"));
        assert_eq!(did_you_mean("comp", names.iter().copied()), Some("complete"));
        assert_eq!(did_you_mean("xyzzy", names.iter().copied()), None);
    }
}
