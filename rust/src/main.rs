//! `cidertf` — CLI entry point for the CiderTF reproduction.
//!
//! Every run flows through one pipeline: an
//! [`ExperimentSpec`](cidertf::engine::spec::ExperimentSpec) (built from
//! flags, a scenario string, or `--spec file.json`) consumed by a
//! [`Session`](cidertf::engine::session::Session) that emits typed
//! events to observers (console progress, CSV curves, JSONL streams,
//! BENCH.json appends).
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md index):
//!
//! ```text
//! cidertf train  --algo cidertf:4 --dataset mimic_like --loss logit ...
//! cidertf train  --spec experiment.json                # declarative run
//! cidertf spec   --algo cidertf:4@lossy:0.2@async      # print resolved spec
//! cidertf sweep  --spec sweep.json --workers 8         # run a whole grid
//! cidertf fig3 | fig4 | fig5 | fig6 | fig7             # regenerate figures
//! cidertf table2 | table3 | table4 | theorems          # regenerate tables
//! cidertf tune   --dataset synthetic --loss logit      # γ grid search
//! cidertf info                                         # axes + artifacts
//! ```
//!
//! The figure/ablation/fault commands all expand to
//! [`SweepSpec`](cidertf::sweep::SweepSpec) grids executed concurrently
//! on `--workers` threads — results are bit-identical for any worker
//! count, and finished runs are skipped on re-invocation.
//!
//! Common flags: `--profile quick|paper`, `--k N`, `--tau T`,
//! `--epochs E`, `--backend pjrt|native`, `--out results/`,
//! `--workers N`.

use std::path::{Path, PathBuf};

use cidertf::engine::presets::Scenario;
use cidertf::engine::session::{
    BenchJsonObserver, ConsoleObserver, CsvObserver, JsonlObserver, Session,
};
use cidertf::engine::spec::ExperimentSpec;
use cidertf::engine::{AlgoConfig, TrainConfig};
use cidertf::harness::{self, Ctx, Profile};
use cidertf::losses::Loss;
use cidertf::net::driver::DriverKind;
use cidertf::net::sim::FaultConfig;
use cidertf::registry;
use cidertf::runtime::{default_artifact_dir, ComputeBackend, Manifest, NativeOrPjrt};
use cidertf::sweep::SweepSpec;
use cidertf::topology::Topology;
use cidertf::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn make_backend(args: &Args) -> anyhow::Result<Box<dyn ComputeBackend>> {
    NativeOrPjrt::from_flag(&args.get_str("backend", NativeOrPjrt::default_flag())?)
}

fn ctx_from(args: &Args) -> anyhow::Result<Ctx> {
    let profile = Profile::from_name(&args.get_str("profile", "quick")?)?;
    let mut ctx = Ctx::with_backend(make_backend(args)?, profile);
    ctx.out_dir = args.get_str("out", "results")?.into();
    ctx.workers = args.get_usize("workers", cidertf::sweep::default_workers())?;
    anyhow::ensure!(ctx.workers >= 1, "--workers must be >= 1");
    Ok(ctx)
}

/// Every subcommand, for the did-you-mean hint on typos.
const COMMANDS: &[&str] = &[
    "train", "spec", "sweep", "node", "fleet", "fig3", "fig4", "fig5", "fig6", "fig7",
    "table2", "table3", "table4", "faults", "ablate", "theorems", "bench", "tune", "info",
    "help",
];

fn run() -> anyhow::Result<()> {
    let args = Args::from_env();
    let command = args.command.clone().unwrap_or_else(|| "help".to_string());
    match command.as_str() {
        "train" => cmd_train(&args)?,
        "spec" => cmd_spec(&args)?,
        "sweep" => cmd_sweep(&args)?,
        "node" => cmd_node(&args)?,
        "fleet" => cmd_fleet(&args)?,
        "fig3" => {
            let mut ctx = ctx_from(&args)?;
            let k = args.get_usize("k", 8)?;
            let taus = args.get_usize_list("taus", &[2, 4, 6, 8])?;
            harness::fig3::run(&mut ctx, k, &taus)?;
        }
        "fig4" => {
            let mut ctx = ctx_from(&args)?;
            harness::fig4::run(&mut ctx, args.get_usize("k", 8)?, args.get_usize("tau", 4)?)?;
        }
        "fig5" => {
            let mut ctx = ctx_from(&args)?;
            let ks = args.get_usize_list("ks", &[8, 16, 32])?;
            let taus = args.get_usize_list("taus", &[4, 8])?;
            harness::fig5::run(&mut ctx, &ks, &taus)?;
        }
        "fig6" => {
            let mut ctx = ctx_from(&args)?;
            harness::fig6::run(&mut ctx, args.get_usize("k", 8)?, args.get_usize("tau", 4)?)?;
        }
        "fig7" => {
            let mut ctx = ctx_from(&args)?;
            harness::fig7::run(&mut ctx, args.get_usize("k", 8)?, args.get_usize("tau", 4)?)?;
        }
        "table2" => {
            harness::tables::table2(args.get_usize("d", 3)?, args.get_usize("tau", 4)?);
            args.finish()?;
            return Ok(());
        }
        "table3" => {
            let mut ctx = ctx_from(&args)?;
            harness::tables::table3(
                &mut ctx,
                args.get_usize("k", 8)?,
                args.get_usize("tau", 8)?,
                args.get_usize("max-patients", 1000)?,
            )?;
        }
        "table4" => {
            let mut ctx = ctx_from(&args)?;
            harness::tables::table4(
                &mut ctx,
                args.get_usize("k", 8)?,
                args.get_usize("tau", 8)?,
                args.get_usize("features", 8)?,
            )?;
        }
        "faults" => {
            let mut ctx = ctx_from(&args)?;
            harness::faults::run(&mut ctx, args.get_usize("k", 8)?, args.get_usize("tau", 4)?)?;
        }
        "ablate" => {
            let mut ctx = ctx_from(&args)?;
            let k = args.get_usize("k", 8)?;
            let tau = args.get_usize("tau", 4)?;
            match args.get_str("sweep", "all")?.as_str() {
                "rho" => harness::ablate::rho_sweep(&mut ctx, k, tau)?,
                "tau" => harness::ablate::tau_sweep(&mut ctx, k)?,
                "trigger" => harness::ablate::trigger_sweep(&mut ctx, k, tau)?,
                _ => {
                    harness::ablate::rho_sweep(&mut ctx, k, tau)?;
                    harness::ablate::tau_sweep(&mut ctx, k)?;
                    harness::ablate::trigger_sweep(&mut ctx, k, tau)?;
                }
            }
        }
        "theorems" => {
            let mut ctx = ctx_from(&args)?;
            harness::tables::theorems(&mut ctx, args.get_usize("k", 8)?, args.get_usize("tau", 4)?)?;
        }
        "bench" => harness::bench::run(&args)?,
        "tune" => cmd_tune(&args)?,
        "info" => cmd_info(&args)?,
        "help" => {
            print_help();
            return Ok(());
        }
        other => {
            let hint = registry::did_you_mean(other, COMMANDS.iter().copied())
                .map(|s| format!(" — did you mean '{s}'?"))
                .unwrap_or_default();
            anyhow::bail!("unknown command '{other}'{hint} (run 'cidertf help')");
        }
    }
    args.finish()
}

/// Resolve the experiment spec from `--spec file.json` (authoritative —
/// no other axis flags allowed) or from the scenario flags, applying the
/// profile-scaled defaults and explicit overrides exactly like the
/// harness does.
fn spec_from_args(args: &Args) -> anyhow::Result<ExperimentSpec> {
    if let Some(path) = args.opt_str("spec")? {
        return ExperimentSpec::load(Path::new(&path));
    }
    // scenario: `--algo cidertf:4@lossy:0.2@async`, with `--network` and
    // `--driver` as explicit overrides for the last two segments
    let mut scenario = Scenario::parse(&args.get_str("algo", "cidertf:4")?)?;
    if let Some(net) = args.opt_str("network")? {
        scenario.fault = FaultConfig::by_name(&net)?;
        if scenario.fault.is_some()
            && matches!(scenario.driver, DriverKind::Sequential | DriverKind::Parallel)
        {
            scenario.driver = DriverKind::Sim;
        }
    }
    if let Some(d) = args.opt_str("driver")? {
        scenario.driver = DriverKind::from_name(&d)?;
    }
    let dataset = args.get_str("dataset", "synthetic")?;
    let loss = Loss::from_name(&args.get_str("loss", "logit")?)?;
    let profile = Profile::from_name(&args.get_str("profile", "quick")?)?;

    // profile-scaled defaults come from the same Ctx::base_config the
    // fig/table harness uses (grid-searched γ, momentum rescale, profile
    // iteration counts) — `train` and the harness can never diverge.
    // This Ctx only supplies defaults; its backend is never exercised.
    let ctx = Ctx::with_backend(
        Box::new(cidertf::runtime::native::NativeBackend::new()),
        profile,
    );
    let cfg = ctx.base_config(&dataset, loss, scenario.algo);
    let mut spec = ExperimentSpec::from_train_config(
        &cfg,
        scenario.driver,
        scenario.fault,
        NativeOrPjrt::default_flag(),
    );
    // explicit flag overrides
    spec.k = args.get_usize("k", spec.k)?;
    spec.topology = Topology::from_name(&args.get_str("topology", spec.topology.name())?)?;
    spec.epochs = args.get_usize("epochs", spec.epochs)?;
    spec.iters_per_epoch = args.get_usize("iters-per-epoch", spec.iters_per_epoch)?;
    spec.gamma = args.get_f64("gamma", spec.gamma)?;
    spec.rank = args.get_usize("rank", spec.rank)?;
    spec.seed = args.get_u64("seed", spec.seed)?;
    spec.compute_threads = args.get_usize("threads", spec.compute_threads)?;
    spec.eval_every = args.get_usize("eval-every", spec.eval_every)?;
    if let Some(t) = args.opt_str("target-loss")? {
        spec.stop.target_loss = Some(
            t.parse()
                .map_err(|_| anyhow::anyhow!("--target-loss expects a number, got '{t}'"))?,
        );
    }
    if let Some(b) = args.opt_str("max-bytes")? {
        spec.stop.max_bytes = Some(
            b.parse()
                .map_err(|_| anyhow::anyhow!("--max-bytes expects an integer, got '{b}'"))?,
        );
    }
    // robustness axes (registry-resolved, did-you-mean on typos)
    if let Some(p) = args.opt_str("partitioner")? {
        spec.partitioner = registry::partitioners().resolve(&p)?;
    }
    if let Some(a) = args.opt_str("aggregator")? {
        spec.aggregator = registry::aggregators().resolve(&a)?;
    }
    if let Some(a) = args.opt_str("adversary")? {
        spec.adversary = registry::adversaries().resolve(&a)?;
    }
    spec.backend = args.get_str("backend", NativeOrPjrt::default_flag())?;
    if let Some(t) = args.opt_str("transport")? {
        // resolve canonicalizes aliases ("unix" -> "uds") and gives a
        // did-you-mean on typos before validate sees the spec
        spec.transport = registry::transports().resolve(&t)?.name().to_string();
    }
    spec.validate()?;
    Ok(spec)
}

/// `cidertf node --config fleet.json --id K [--control addr]`: run ONE
/// client of the fleet's spec as this OS process, gossiping with its
/// peers over real sockets. Normally launched by `fleet spawn`, but can
/// be started by hand (one invocation per node id) across machines.
fn cmd_node(args: &Args) -> anyhow::Result<()> {
    let config = args
        .opt_str("config")?
        .ok_or_else(|| anyhow::anyhow!("node needs --config fleet.json"))?;
    let id: usize = match args.opt_str("id")? {
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("--id expects an integer, got '{v}'"))?,
        None => anyhow::bail!("node needs --id <k> (index into the fleet's node list)"),
    };
    let control = args.opt_str("control")?;
    let cfg = cidertf::node::fleet::FleetConfig::load(Path::new(&config))?;
    let outcome = cidertf::node::daemon::run_node(&cfg, id, control.as_deref())?;
    println!(
        "node {id} done: {} iterations, virtual {:.1}s, final client state captured",
        outcome.t, outcome.time_s
    );
    Ok(())
}

/// `cidertf fleet spawn|status|stop`: launch a local fleet of node
/// daemons as child processes, inspect a running fleet's progress, or
/// signal it to stop.
fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    let verb = args.positional(0).unwrap_or("").to_string();
    let out_dir: PathBuf = args.get_str("out", "results/fleet")?.into();
    match verb.as_str() {
        "spawn" => {
            let config = args
                .opt_str("config")?
                .ok_or_else(|| anyhow::anyhow!("fleet spawn needs --config fleet.json"))?;
            cidertf::node::controller::spawn(Path::new(&config), &out_dir)
        }
        "status" => cidertf::node::controller::status(&out_dir),
        "stop" => cidertf::node::controller::stop(&out_dir),
        "" => anyhow::bail!("fleet needs a subcommand: spawn | status | stop"),
        other => {
            let verbs = ["spawn", "status", "stop"];
            let hint = registry::did_you_mean(other, verbs.iter().copied())
                .map(|s| format!(" — did you mean 'fleet {s}'?"))
                .unwrap_or_default();
            anyhow::bail!("unknown fleet subcommand '{other}'{hint} (spawn | status | stop)")
        }
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let out_dir: PathBuf = args.get_str("out", "results")?.into();
    let resume_path = args.opt_str("resume")?;
    let mut session = if let Some(ckpt) = &resume_path {
        println!("resuming from {ckpt}");
        Session::resume_from(Path::new(ckpt))?
    } else {
        Session::new(spec_from_args(args)?)
    };

    {
        let spec = session.spec();
        println!(
            "training {} on {}/{} K={} topology={} gamma={} driver={} ({} epochs x {} iters)",
            spec.algo.name,
            spec.dataset,
            spec.loss.name(),
            spec.k,
            spec.topology.name(),
            spec.gamma,
            spec.driver.name(),
            spec.epochs,
            spec.iters_per_epoch
        );
    }

    let csv_path = out_dir.join(format!("train/{}.csv", session.spec().label()));
    session = session
        .observe(Box::new(ConsoleObserver))
        .observe(Box::new(CsvObserver::new(csv_path)));
    if let Some(jsonl) = args.opt_str("jsonl")? {
        session = session.observe(Box::new(JsonlObserver::new(jsonl)));
    }
    if let Some(bench_json) = args.opt_str("bench-json")? {
        let label = session.spec().label();
        session = session.observe(Box::new(BenchJsonObserver::new(bench_json, label)));
    }
    // a resumed run keeps writing to its own checkpoint file unless an
    // explicit --checkpoint overrides it — crash protection survives
    // the restart
    let ckpt_path = args.opt_str("checkpoint")?.or(resume_path);
    let ckpt_every = args.get_usize("checkpoint-every", 1)?;
    if let Some(p) = ckpt_path {
        session = session.checkpoint_every(p, ckpt_every);
    }

    session.run()?;
    Ok(())
}

fn cmd_spec(args: &Args) -> anyhow::Result<()> {
    let spec = spec_from_args(args)?;
    println!("{}", spec.to_json().to_pretty_string());
    Ok(())
}

/// `cidertf sweep --spec sweep.json --workers N`: expand a declarative
/// grid and execute it on the worker pool. `--smoke` runs the tiny
/// built-in 4-run grid (the CI path); `--print` shows the expanded specs
/// without running; `--fresh` ignores existing run records.
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let sweep_spec = if args.flag("smoke") {
        SweepSpec::smoke()
    } else if args.flag("smoke-robust") {
        SweepSpec::robust_smoke()
    } else {
        let path = args.opt_str("spec")?.ok_or_else(|| {
            anyhow::anyhow!("sweep needs --spec sweep.json (or --smoke for the built-in grid)")
        })?;
        SweepSpec::load(Path::new(&path))?
    };
    let mut opts = cidertf::sweep::SweepOptions::new(
        PathBuf::from(args.get_str("out", "results/sweep")?),
        args.get_usize("workers", cidertf::sweep::default_workers())?,
    );
    anyhow::ensure!(opts.workers >= 1, "--workers must be >= 1");
    opts.resume = !args.flag("fresh");
    opts.per_run_jsonl = args.flag("per-run-jsonl");
    if args.flag("print") {
        for (i, run) in sweep_spec.expand()?.iter().enumerate() {
            println!("[{i:>3}] {}", run.label());
        }
        return Ok(());
    }
    cidertf::sweep::execute(&sweep_spec, &opts, None)?;
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let dataset = args.get_str("dataset", "synthetic")?;
    let loss = Loss::from_name(&args.get_str("loss", "logit")?)?;
    let mut backend = make_backend(args)?;
    let data = {
        let ctx = Ctx::with_backend(NativeOrPjrt::from_flag("native")?, Profile::Quick);
        ctx.dataset(&dataset, loss)?
    };
    let mut best = (f64::INFINITY, 0.0);
    for exp in -3i32..=3 {
        let gamma = 2f64.powi(exp);
        let mut cfg = TrainConfig::new(&dataset, loss, AlgoConfig::cidertf(4));
        cfg.gamma = gamma;
        cfg.epochs = args.get_usize("epochs", 2)?;
        cfg.iters_per_epoch = args.get_usize("iters-per-epoch", 150)?;
        let spec =
            ExperimentSpec::from_train_config(&cfg, DriverKind::Sequential, None, "native");
        let out = Session::new(spec).run_on(&data, backend.as_mut(), None)?;
        let l = out.record.final_loss();
        println!("gamma = {gamma:>8}: final loss {l:.6e}");
        if l.is_finite() && l < best.0 {
            best = (l, gamma);
        }
    }
    println!("best gamma for {dataset}/{}: {}", loss.name(), best.1);
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    println!("experiment axes (scenario strings, --spec files, and flags):\n");
    for (kind, lines) in registry::axis_help() {
        println!("{kind}:");
        for line in lines {
            println!("{line}");
        }
        println!();
    }

    let dir = default_artifact_dir();
    println!("artifact dir: {}", dir.display());
    match Manifest::load(&dir) {
        Ok(m) => {
            let mut names: Vec<&String> = m.artifacts.keys().collect();
            names.sort();
            println!("{} artifacts:", names.len());
            for n in names {
                let a = &m.artifacts[n];
                println!("  {:<28} op={:<5} loss={:<5} inputs={:?}", a.name, a.op, a.loss, a.inputs);
            }
        }
        Err(_) => println!("no AOT artifacts found (native backend needs none)"),
    }
    args.finish()
}

fn print_help() {
    println!(
        "cidertf — decentralized generalized tensor factorization (CiderTF reproduction)

USAGE: cidertf <command> [flags]

COMMANDS
  train      run one experiment spec
             --algo <algo>[@<network>[@<driver>]]   scenario string, e.g.
                                                    cidertf:4@lossy:0.2@async
             --spec file.json     load a full ExperimentSpec (authoritative)
             --dataset synthetic|mimic_like|cms_like|mimic_full|tiny
                       |file:<path.tns|.bin|.ctf>|csv:<events.csv>  (real data)
             --loss logit|ls  --k 8  --topology ring|star|complete|chain|torus
             --epochs N --iters-per-epoch N --gamma G --rank R --seed S
             --driver seq|par|sim|async|node   execution path (default seq)
             --transport tcp|uds  socket family for the node driver
             --network ideal|lossy[:p]|bursty|wan|stragglers|churning|hostile
             --partitioner even|skewed[:alpha]|site_vocab[:overlap]
             --aggregator mean|trimmed_mean[:beta]|coordinate_median
             --adversary honest|sign_flip[:f]|scaled_noise[:f]|stale_replay[:f]
             --threads N          native-backend compute threads (default 1)
             --eval-every N       epochs between eval points
             --target-loss L --max-bytes B          early-stopping rules
             --checkpoint ckpt.json [--checkpoint-every N]
             --resume ckpt.json   continue bit-identically from a checkpoint
             --jsonl run.jsonl    stream progress as JSON lines
             --bench-json BENCH.json                append e2e timing
  spec       print the fully-resolved ExperimentSpec JSON for any scenario
             string / flag set (same flags as train)
  sweep      run a whole experiment grid on a worker pool
             --spec sweep.json    base ExperimentSpec + axis lists (datasets/
                                  losses/algos/taus/ks/topologies/compressors/
                                  networks/drivers/partitioners/aggregators/
                                  adversaries/triggers/gammas/seeds)
             --workers N          concurrent runs (results identical for any N)
             --out results/sweep  sweep dir: per-run CSV + record JSON +
                                  deterministic aggregate sweep.jsonl
             --smoke              built-in tiny 4-run grid (CI exercise)
             --smoke-robust       built-in adversary x aggregator grid (CI)
             --print              list the expanded runs without executing
             --fresh              re-run everything (default: skip runs whose
                                  record file already matches their spec)
             --per-run-jsonl      stream each run's progress as <label>.jsonl
  node       run ONE client of a fleet as this OS process (real sockets)
             --config fleet.json  fleet file: spec + node id -> address map
             --id K               which fleet entry this process is
             --control host:port  stream NDJSON events to a fleet controller
  fleet      launch / inspect / stop a local fleet of node daemons
             spawn  --config fleet.json [--out results/fleet]
                    start one child process per node, collect their event
                    streams, merge the final states into a checkpoint that
                    is byte-identical to the sim driver's
             status [--out results/fleet]   print the live status.json
             stop   [--out results/fleet]   signal every fleet process
  fig3       convergence vs baselines (paper Fig. 3)   [--k --taus 2,4,6,8]
  fig4       ring vs star topology    (paper Fig. 4)   [--k --tau]
  fig5       scalability K=8,16,32    (paper Fig. 5)   [--ks --taus]
  fig6       ablation + measured compression (Fig. 6)  [--k --tau]
  fig7       FMS vs centralized BrasCPD (Fig. 7)       [--k --tau]
  table2     feature/ratio matrix     (Table II)       [--d --tau]
  table3     tSNE subgroup study      (Table III)      [--k --tau --max-patients]
  table4     phenotype extraction     (Table IV)       [--k --tau --features]
  theorems   Thm III.1-III.3 checks                    [--k --tau]
  faults     drop-rate x topology x compressor sweep   [--k --tau]
  ablate     design-knob sweeps (rho/tau/trigger)      [--sweep rho|tau|trigger|all]
  bench      hot-path micro + e2e benchmarks; appends to BENCH.json
             [--smoke] [--out-json BENCH.json] [--threads N]
  tune       learning-rate grid search                 [--dataset --loss]
  info       list every pluggable axis + AOT artifacts

COMMON FLAGS
  --profile quick|paper   effort level (default quick)
  --backend pjrt|native   compute backend (default: pjrt when built with the
                          `pjrt` feature, else native — the pure-Rust mirror)
  --out results/          output directory for CSVs
  --workers N             sweep worker threads for fig*/ablate/faults/sweep
                          (default: machine parallelism, capped at 8;
                          results are bit-identical for any N)

Unknown commands and flags error with a did-you-mean hint; malformed
numeric flags are errors, never silent defaults."
    );
}
