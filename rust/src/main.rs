//! `cidertf` — CLI entry point for the CiderTF reproduction.
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md index):
//!
//! ```text
//! cidertf train  --algo cidertf:4 --dataset mimic_like --loss logit ...
//! cidertf fig3 | fig4 | fig5 | fig6 | fig7         # regenerate figures
//! cidertf table2 | table3 | table4 | theorems      # regenerate tables
//! cidertf tune   --dataset synthetic --loss logit  # γ grid search
//! cidertf info                                      # artifact/manifest info
//! ```
//!
//! Common flags: `--profile quick|paper`, `--k N`, `--tau T`,
//! `--epochs E`, `--backend pjrt|native`, `--out results/`.

use cidertf::engine::presets::Scenario;
use cidertf::engine::{train, AlgoConfig, TrainConfig};
use cidertf::harness::{self, Ctx, Profile};
use cidertf::losses::Loss;
use cidertf::net::driver::{driver_from_flags, DriverKind};
use cidertf::net::sim::{self, FaultConfig, NetworkModel};
use cidertf::runtime::{default_artifact_dir, ComputeBackend, Manifest, NativeOrPjrt};
use cidertf::topology::Topology;
use cidertf::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Default `--backend`: PJRT when this binary was built with the `pjrt`
/// feature, otherwise the artifact-free native mirror (so the
/// out-of-the-box commands in README.md work on a plain build).
fn default_backend() -> &'static str {
    if cfg!(feature = "pjrt") {
        "pjrt"
    } else {
        "native"
    }
}

fn make_backend(args: &Args) -> anyhow::Result<Box<dyn ComputeBackend>> {
    NativeOrPjrt::from_flag(&args.get_str("backend", default_backend()))
}

fn ctx_from(args: &Args) -> anyhow::Result<Ctx> {
    let profile = Profile::from_name(&args.get_str("profile", "quick"))?;
    let mut ctx = Ctx::with_backend(make_backend(args)?, profile);
    ctx.out_dir = args.get_str("out", "results").into();
    Ok(ctx)
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env();
    let command = args.command.clone().unwrap_or_else(|| "help".to_string());
    match command.as_str() {
        "train" => cmd_train(&args)?,
        "fig3" => {
            let mut ctx = ctx_from(&args)?;
            let k = args.get_usize("k", 8);
            let taus = args.get_usize_list("taus", &[2, 4, 6, 8]);
            harness::fig3::run(&mut ctx, k, &taus)?;
        }
        "fig4" => {
            let mut ctx = ctx_from(&args)?;
            harness::fig4::run(&mut ctx, args.get_usize("k", 8), args.get_usize("tau", 4))?;
        }
        "fig5" => {
            let mut ctx = ctx_from(&args)?;
            let ks = args.get_usize_list("ks", &[8, 16, 32]);
            let taus = args.get_usize_list("taus", &[4, 8]);
            harness::fig5::run(&mut ctx, &ks, &taus)?;
        }
        "fig6" => {
            let mut ctx = ctx_from(&args)?;
            harness::fig6::run(&mut ctx, args.get_usize("k", 8), args.get_usize("tau", 4))?;
        }
        "fig7" => {
            let mut ctx = ctx_from(&args)?;
            harness::fig7::run(&mut ctx, args.get_usize("k", 8), args.get_usize("tau", 4))?;
        }
        "table2" => {
            harness::tables::table2(args.get_usize("d", 3), args.get_usize("tau", 4));
            args.finish()?;
            return Ok(());
        }
        "table3" => {
            let mut ctx = ctx_from(&args)?;
            harness::tables::table3(
                &mut ctx,
                args.get_usize("k", 8),
                args.get_usize("tau", 8),
                args.get_usize("max-patients", 1000),
            )?;
        }
        "table4" => {
            let mut ctx = ctx_from(&args)?;
            harness::tables::table4(
                &mut ctx,
                args.get_usize("k", 8),
                args.get_usize("tau", 8),
                args.get_usize("features", 8),
            )?;
        }
        "faults" => {
            let mut ctx = ctx_from(&args)?;
            harness::faults::run(&mut ctx, args.get_usize("k", 8), args.get_usize("tau", 4))?;
        }
        "ablate" => {
            let mut ctx = ctx_from(&args)?;
            let k = args.get_usize("k", 8);
            let tau = args.get_usize("tau", 4);
            match args.get_str("sweep", "all").as_str() {
                "rho" => harness::ablate::rho_sweep(&mut ctx, k, tau)?,
                "tau" => harness::ablate::tau_sweep(&mut ctx, k)?,
                "trigger" => harness::ablate::trigger_sweep(&mut ctx, k, tau)?,
                _ => {
                    harness::ablate::rho_sweep(&mut ctx, k, tau)?;
                    harness::ablate::tau_sweep(&mut ctx, k)?;
                    harness::ablate::trigger_sweep(&mut ctx, k, tau)?;
                }
            }
        }
        "theorems" => {
            let mut ctx = ctx_from(&args)?;
            harness::tables::theorems(&mut ctx, args.get_usize("k", 8), args.get_usize("tau", 4))?;
        }
        "bench" => harness::bench::run(&args)?,
        "tune" => cmd_tune(&args)?,
        "info" => cmd_info(&args)?,
        "help" | _ => {
            print_help();
            return Ok(());
        }
    }
    args.finish()
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    // scenario: `--algo cidertf:4@lossy:0.2@async`, with `--network` and
    // `--driver` as explicit overrides for the last two segments
    let mut scenario = Scenario::parse(&args.get_str("algo", "cidertf:4"))?;
    if let Some(net) = args.opt_str("network") {
        scenario.fault = FaultConfig::by_name(&net)?;
        if scenario.fault.is_some()
            && matches!(scenario.driver, DriverKind::Sequential | DriverKind::Parallel)
        {
            scenario.driver = DriverKind::Sim;
        }
    }
    if let Some(d) = args.opt_str("driver") {
        scenario.driver = DriverKind::from_name(&d)?;
    }
    // same invariant Scenario::parse enforces, re-checked because the
    // --driver override above can undo the auto-upgrade to sim
    anyhow::ensure!(
        !(scenario.fault.is_some()
            && matches!(scenario.driver, DriverKind::Sequential | DriverKind::Parallel)),
        "driver '{}' cannot inject network faults — use --driver sim or --driver async",
        scenario.driver.name()
    );
    let dataset = args.get_str("dataset", "synthetic");
    let loss = Loss::from_name(&args.get_str("loss", "logit"))?;
    let profile = Profile::from_name(&args.get_str("profile", "quick"))?;
    let out_dir: std::path::PathBuf = args.get_str("out", "results").into();
    // This Ctx only generates the dataset and profile-scaled defaults —
    // its backend is never exercised. The run's actual compute backend is
    // resolved from --backend by driver_from_flags below.
    let ctx = Ctx::with_backend(Box::new(cidertf::runtime::native::NativeBackend::new()), profile);
    let data = ctx.dataset(&dataset, loss)?;
    let mut cfg = ctx.base_config(&dataset, loss, scenario.algo.clone());
    cfg.k = args.get_usize("k", 8);
    cfg.topology = Topology::from_name(&args.get_str("topology", "ring"))?;
    cfg.epochs = args.get_usize("epochs", cfg.epochs);
    cfg.iters_per_epoch = args.get_usize("iters-per-epoch", cfg.iters_per_epoch);
    cfg.gamma = args.get_f64("gamma", cfg.gamma);
    cfg.rank = args.get_usize("rank", cfg.rank);
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.compute_threads = args.get_usize("threads", cfg.compute_threads);
    println!(
        "training {} on {dataset}/{} K={} topology={} gamma={} driver={} ({} epochs x {} iters)",
        cfg.algo.name,
        cfg.loss.name(),
        cfg.k,
        cfg.topology.name(),
        cfg.gamma,
        scenario.driver.name(),
        cfg.epochs,
        cfg.iters_per_epoch
    );
    let net: Box<dyn NetworkModel> = match scenario.fault.clone() {
        None => sim::ideal(),
        Some(f) => f.with_seed(cfg.seed).boxed(),
    };
    let mut driver =
        driver_from_flags(scenario.driver, &args.get_str("backend", default_backend()), net)?;
    let out = driver.run(&cfg, &data, None)?;
    let fname = format!(
        "train/{}_{}_{}_{}_{}_k{}.csv",
        cfg.dataset,
        cfg.loss.name(),
        cfg.algo.name,
        driver.name(),
        cfg.topology.name(),
        cfg.k
    );
    out.record.write_csv(&out_dir.join(fname))?;
    for p in &out.record.points {
        println!(
            "epoch {:>3}  t={:>7.1}s  loss={:.6e}  uplink={}",
            p.epoch,
            p.time_s,
            p.loss,
            cidertf::util::benchkit::fmt_bytes(p.bytes as f64)
        );
    }
    println!(
        "done: final loss {:.6e}, wall {:.1}s, uplink {}, msgs {} (triggered {}, suppressed {})",
        out.record.final_loss(),
        out.record.wall_s,
        cidertf::util::benchkit::fmt_bytes(out.record.total.bytes as f64),
        out.record.total.messages,
        out.record.total.triggered,
        out.record.total.suppressed
    );
    let net_stats = &out.record.net;
    if matches!(scenario.driver, DriverKind::Sim | DriverKind::Async) {
        println!(
            "network: delivered {}, dropped {} ({:.1}% loss), stale {}, offline rounds {}",
            net_stats.delivered,
            net_stats.dropped,
            100.0 * net_stats.drop_fraction(),
            net_stats.stale,
            net_stats.offline_rounds
        );
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let dataset = args.get_str("dataset", "synthetic");
    let loss = Loss::from_name(&args.get_str("loss", "logit"))?;
    let mut backend = make_backend(args)?;
    let data = {
        let ctx = Ctx::with_backend(NativeOrPjrt::from_flag("native")?, Profile::Quick);
        ctx.dataset(&dataset, loss)?
    };
    let mut best = (f64::INFINITY, 0.0);
    for exp in -3i32..=3 {
        let gamma = 2f64.powi(exp);
        let mut cfg = TrainConfig::new(&dataset, loss, AlgoConfig::cidertf(4));
        cfg.gamma = gamma;
        cfg.epochs = args.get_usize("epochs", 2);
        cfg.iters_per_epoch = args.get_usize("iters-per-epoch", 150);
        let out = train(&cfg, &data, backend.as_mut(), None)?;
        let l = out.record.final_loss();
        println!("gamma = {gamma:>8}: final loss {l:.6e}");
        if l.is_finite() && l < best.0 {
            best = (l, gamma);
        }
    }
    println!("best gamma for {dataset}/{}: {}", loss.name(), best.1);
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    println!("artifact dir: {}", dir.display());
    let m = Manifest::load(&dir)?;
    let mut names: Vec<&String> = m.artifacts.keys().collect();
    names.sort();
    println!("{} artifacts:", names.len());
    for n in names {
        let a = &m.artifacts[n];
        println!("  {:<28} op={:<5} loss={:<5} inputs={:?}", a.name, a.op, a.loss, a.inputs);
    }
    args.finish()
}

fn print_help() {
    println!(
        "cidertf — decentralized generalized tensor factorization (CiderTF reproduction)

USAGE: cidertf <command> [flags]

COMMANDS
  train      run one algorithm        --algo cidertf:4|cidertf_m:4|dpsgd|dpsgd_bras|
                                       dpsgd_sign|dpsgd_bras_sign|sparq_sgd:4|gcp|
                                       bras_cpd|centralized_cidertf
             --dataset synthetic|mimic_like|cms_like|mimic_full|tiny --loss logit|ls
             --k 8 --topology ring|star|complete|chain|torus --epochs N --gamma G
             --driver seq|par|sim|async   execution path (default seq)
             --threads N   native-backend compute threads (default 1 = deterministic)
             --network ideal|lossy[:p]|bursty|wan|stragglers|churning|hostile
             (or one spec: --algo cidertf:4@lossy:0.2@async)
  fig3       convergence vs baselines (paper Fig. 3)   [--k --taus 2,4,6,8]
  fig4       ring vs star topology    (paper Fig. 4)   [--k --tau]
  fig5       scalability K=8,16,32    (paper Fig. 5)   [--ks --taus]
  fig6       ablation + measured compression (Fig. 6)  [--k --tau]
  fig7       FMS vs centralized BrasCPD (Fig. 7)       [--k --tau]
  table2     feature/ratio matrix     (Table II)       [--d --tau]
  table3     tSNE subgroup study      (Table III)      [--k --tau --max-patients]
  table4     phenotype extraction     (Table IV)       [--k --tau --features]
  theorems   Thm III.1-III.3 checks                    [--k --tau]
  faults     drop-rate x topology x compressor sweep   [--k --tau]
  ablate     design-knob sweeps (rho/tau/trigger)      [--sweep rho|tau|trigger|all]
  bench      hot-path micro + e2e benchmarks; appends to BENCH.json
             [--smoke] [--out-json BENCH.json] [--threads N]
  tune       learning-rate grid search                 [--dataset --loss]
  info       list AOT artifacts

COMMON FLAGS
  --profile quick|paper   effort level (default quick)
  --backend pjrt|native   compute backend (default: pjrt when built with the
                          `pjrt` feature, else native — the pure-Rust mirror)
  --out results/          output directory for CSVs"
    );
}
