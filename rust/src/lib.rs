//! # CiderTF — Communication-Efficient Decentralized Generalized Tensor Factorization
//!
//! Production-grade reproduction of *"Communication Efficient Generalized
//! Tensor Factorization for Decentralized Healthcare Networks"* (Ma et al.,
//! 2021) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized gossip coordinator with the
//!   paper's four-level communication-reduction stack (sign compression,
//!   block randomization, periodic communication, event triggering),
//!   Nesterov momentum, every baseline, and the experiment harness.
//! * **L2/L1 (python/, build-time only)** — the generalized-CP gradient
//!   graph and its fused Pallas kernel, AOT-lowered to HLO text under
//!   `artifacts/` and executed here through the PJRT CPU client.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

pub mod adversary;
pub mod analysis;
pub mod compress;
pub mod data;
pub mod engine;
pub mod factor;
pub mod gossip;
pub mod harness;
pub mod losses;
pub mod net;
pub mod node;
pub mod registry;
pub mod runtime;
pub mod sched;
pub mod sweep;
pub mod tensor;
pub mod topology;
pub mod util;
