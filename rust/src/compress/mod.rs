//! Element-level communication reduction: compressors + error feedback
//! (paper §III-B1, Def. III.1, Table II).
//!
//! Payloads model *real* wire encodings — the comm ledger charges the
//! actual serialized byte count (bit-packed signs, u32 indices, f32
//! values), not an analytical estimate, so the measured compression ratios
//! in Fig. 6 / Table II come from genuine payload sizes.
//!
//! # Wire accounting convention
//!
//! Every message carries one fixed 16-byte header charged by the engine
//! (`gossip::Message::HEADER_BYTES`: sender, mode, round, and the payload
//! body length — u32 each). [`Payload::wire_bytes`] therefore counts
//! **only the serialized body**, uniformly across variants, with no
//! redundant per-variant length or count words (the header's body length
//! determines them):
//!
//! | variant | body | bytes |
//! |---------|------|-------|
//! | `Dense` | `n` f32 values | `4n` |
//! | `Sign`  | f32 scale + bit-packed signs | `4 + ⌈n/8⌉` |
//! | `TopK`  | `k` u32 indices + `k` f32 values (`k` = body len / 8) | `8k` |
//! | `Zero`  | nothing — a header-only message | `0` |

use crate::util::mat::Mat;
use crate::util::simd;

/// A compressed factor-update message payload.
#[derive(Debug, Clone)]
pub enum Payload {
    /// full-precision matrix (D-PSGD family)
    Dense(Vec<f32>),
    /// sign compressor: `‖x‖₁/n · sign(x)` — one scale + 1 bit/entry
    Sign { scale: f32, bits: Vec<u8>, len: usize },
    /// top-k by magnitude (ablation/extension compressor)
    TopK { indices: Vec<u32>, values: Vec<f32>, len: usize },
    /// event trigger not fired: the "matrix of zeros" of Alg. 1 line 13 —
    /// nothing but a header goes on the wire
    Zero { len: usize },
}

impl Payload {
    /// Serialized body bytes (uniform convention: the engine separately
    /// charges the fixed 16-byte per-message header, which carries the
    /// body length — see the module docs).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Dense(v) => 4 * v.len() as u64,
            Payload::Sign { bits, .. } => 4 + bits.len() as u64,
            Payload::TopK { indices, values, .. } => 4 * (indices.len() + values.len()) as u64,
            Payload::Zero { .. } => 0,
        }
    }

    /// Decode into a dense `rows x cols` matrix.
    pub fn decode(&self, rows: usize, cols: usize) -> Mat {
        let n = rows * cols;
        match self {
            Payload::Dense(v) => {
                assert_eq!(v.len(), n);
                Mat::from_vec(rows, cols, v.clone())
            }
            Payload::Sign { scale, bits, len } => {
                assert_eq!(*len, n);
                let mut data = vec![0.0f32; n];
                for (i, x) in data.iter_mut().enumerate() {
                    let bit = (bits[i >> 3] >> (i & 7)) & 1;
                    *x = if bit == 1 { *scale } else { -*scale };
                }
                Mat::from_vec(rows, cols, data)
            }
            Payload::TopK { indices, values, len } => {
                assert_eq!(*len, n);
                let mut data = vec![0.0f32; n];
                for (&i, &v) in indices.iter().zip(values.iter()) {
                    data[i as usize] = v;
                }
                Mat::from_vec(rows, cols, data)
            }
            Payload::Zero { len } => {
                assert_eq!(*len, n);
                Mat::zeros(rows, cols)
            }
        }
    }

    /// Decode-and-add into an existing matrix without allocating
    /// (`target += decode(payload)`), the receive-side hot path.
    pub fn add_into(&self, target: &mut Mat) {
        let n = target.rows * target.cols;
        match self {
            Payload::Dense(v) => {
                assert_eq!(v.len(), n);
                simd::add_assign(simd::level(), v, &mut target.data);
            }
            Payload::Sign { scale, bits, len } => {
                assert_eq!(*len, n);
                simd::sign_decode_add(simd::level(), *scale, bits, &mut target.data);
            }
            Payload::TopK { indices, values, len } => {
                assert_eq!(*len, n);
                for (&i, &v) in indices.iter().zip(values.iter()) {
                    target.data[i as usize] += v;
                }
            }
            Payload::Zero { len } => assert_eq!(*len, n),
        }
    }

    // ---- binary wire codec (deployment plane) ----

    /// Frame tag byte for [`Payload::Dense`].
    pub const TAG_DENSE: u8 = 0;
    /// Frame tag byte for [`Payload::Sign`].
    pub const TAG_SIGN: u8 = 1;
    /// Frame tag byte for [`Payload::TopK`].
    pub const TAG_TOPK: u8 = 2;
    /// Frame tag byte for [`Payload::Zero`].
    pub const TAG_ZERO: u8 = 3;

    /// The variant tag that rides in a frame header (see
    /// `gossip::Message::encode_frame`).
    pub fn tag(&self) -> u8 {
        match self {
            Payload::Dense(_) => Self::TAG_DENSE,
            Payload::Sign { .. } => Self::TAG_SIGN,
            Payload::TopK { .. } => Self::TAG_TOPK,
            Payload::Zero { .. } => Self::TAG_ZERO,
        }
    }

    /// Logical element count `n` of the (uncompressed) delta this payload
    /// describes. Carried in the frame header — together with the body
    /// length it makes every variant self-describing on the wire.
    pub fn logical_len(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Sign { len, .. } | Payload::TopK { len, .. } | Payload::Zero { len } => *len,
        }
    }

    /// Append the canonical body encoding to `out`: exactly
    /// [`Payload::wire_bytes`] bytes, little-endian throughout, f32 as raw
    /// IEEE-754 bit patterns — NaN payloads, infinities, and signed zeros
    /// survive the round trip bit-for-bit.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Payload::Dense(v) => {
                out.reserve(4 * v.len());
                for &x in v {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            Payload::Sign { scale, bits, .. } => {
                out.reserve(4 + bits.len());
                out.extend_from_slice(&scale.to_bits().to_le_bytes());
                out.extend_from_slice(bits);
            }
            Payload::TopK { indices, values, .. } => {
                out.reserve(4 * (indices.len() + values.len()));
                for &i in indices {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                for &v in values {
                    out.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            Payload::Zero { .. } => {}
        }
    }

    /// Decode a body produced by [`Payload::encode_into`]. `tag` and
    /// `logical_len` come from the frame header; every length relation and
    /// every `TopK` index is validated so a corrupt frame is an error, not
    /// a panic in the receive hot path.
    pub fn decode_body(tag: u8, logical_len: usize, body: &[u8]) -> anyhow::Result<Payload> {
        let f32_at = |c: &[u8]| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        match tag {
            Self::TAG_DENSE => {
                anyhow::ensure!(
                    body.len() == 4 * logical_len,
                    "dense body is {} bytes, expected {} for n = {logical_len}",
                    body.len(),
                    4 * logical_len
                );
                Ok(Payload::Dense(body.chunks_exact(4).map(f32_at).collect()))
            }
            Self::TAG_SIGN => {
                let want = 4 + logical_len.div_ceil(8);
                anyhow::ensure!(
                    body.len() == want,
                    "sign body is {} bytes, expected {want} for n = {logical_len}",
                    body.len()
                );
                Ok(Payload::Sign {
                    scale: f32_at(body),
                    bits: body[4..].to_vec(),
                    len: logical_len,
                })
            }
            Self::TAG_TOPK => {
                anyhow::ensure!(
                    body.len() % 8 == 0,
                    "topk body length {} is not a multiple of 8",
                    body.len()
                );
                let k = body.len() / 8;
                anyhow::ensure!(k <= logical_len, "topk keeps {k} of n = {logical_len} entries");
                let indices: Vec<u32> = body[..4 * k]
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                for &i in &indices {
                    anyhow::ensure!(
                        (i as usize) < logical_len,
                        "topk index {i} out of range for n = {logical_len}"
                    );
                }
                let values = body[4 * k..].chunks_exact(4).map(f32_at).collect();
                Ok(Payload::TopK { indices, values, len: logical_len })
            }
            Self::TAG_ZERO => {
                anyhow::ensure!(
                    body.is_empty(),
                    "zero payload carries {} body bytes",
                    body.len()
                );
                Ok(Payload::Zero { len: logical_len })
            }
            other => anyhow::bail!("unknown payload tag {other:#04x}"),
        }
    }
}

/// Which compressor a configuration uses (Table II "Element-level").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compressor {
    /// identity — full precision f32
    None,
    /// Def. III.1 sign compressor
    Sign,
    /// top-k with `k = max(1, n/ratio)` entries kept
    TopK { ratio: u32 },
}

impl Compressor {
    pub fn name(self) -> &'static str {
        match self {
            Compressor::None => "none",
            Compressor::Sign => "sign",
            Compressor::TopK { .. } => "topk",
        }
    }

    /// Look up a compressor by CLI/spec name (`topk:16` selects the keep
    /// ratio). Thin wrapper over [`crate::registry::compressors`].
    pub fn by_name(spec: &str) -> anyhow::Result<Self> {
        crate::registry::compressors().resolve(spec)
    }

    /// The spec string this compressor round-trips through
    /// [`Compressor::by_name`] — `"none"`, `"sign"`, or `"topk:<ratio>"`.
    pub fn spec_string(self) -> String {
        match self {
            Compressor::None => "none".to_string(),
            Compressor::Sign => "sign".to_string(),
            Compressor::TopK { ratio } => format!("topk:{ratio}"),
        }
    }

    /// Compress a delta matrix.
    pub fn compress(self, m: &Mat) -> Payload {
        let n = m.data.len();
        match self {
            Compressor::None => Payload::Dense(m.data.clone()),
            Compressor::Sign => {
                // scale = ‖x‖₁ / n  (Def. III.1); guard the 0/0 of an
                // empty matrix so the scale stays finite
                let scale = if n == 0 { 0.0 } else { (m.l1() / n as f64) as f32 };
                let mut bits = vec![0u8; n.div_ceil(8)];
                simd::sign_pack(simd::level(), &m.data, &mut bits);
                Payload::Sign { scale, bits, len: n }
            }
            Compressor::TopK { ratio } => {
                if n == 0 {
                    // nothing to select from — a header-only message
                    // (select_nth_unstable_by(k-1) would panic on n == 0)
                    return Payload::Zero { len: 0 };
                }
                let k = (n / (ratio.max(1) as usize)).max(1);
                let mut order: Vec<u32> = (0..n as u32).collect();
                // total_cmp on the |value| keys: a total order that never
                // panics. NaN sorts above +inf under total_cmp, so NaN
                // entries are deterministically *kept* (and surfaced to
                // the receiver) rather than crashing the selection.
                order.select_nth_unstable_by(k - 1, |&a, &b| {
                    m.data[b as usize].abs().total_cmp(&m.data[a as usize].abs())
                });
                let mut indices: Vec<u32> = order[..k].to_vec();
                indices.sort_unstable();
                let values = indices.iter().map(|&i| m.data[i as usize]).collect();
                Payload::TopK { indices, values, len: n }
            }
        }
    }

    /// Theoretical compression ratio vs 32-bit dense (Table II row entry),
    /// ignoring the O(1) scale header. Clamped to `[0, 1)`: degenerate
    /// `TopK` ratios (< 2) keep every entry as an (index, value) pair,
    /// which saves nothing — the ratio is 0, never negative.
    pub fn element_ratio(self) -> f64 {
        match self {
            Compressor::None => 0.0,
            Compressor::Sign => 1.0 - 1.0 / 32.0,
            Compressor::TopK { ratio } => (1.0 - 2.0 / ratio.max(1) as f64).max(0.0),
        }
    }
}

/// Error feedback (Karimireddy et al.; used by Centralized CiderTF):
/// compress `target + residual`, keep what the compressor lost.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    pub residual: Mat,
}

impl ErrorFeedback {
    pub fn new(rows: usize, cols: usize) -> Self {
        ErrorFeedback { residual: Mat::zeros(rows, cols) }
    }

    /// Compress `delta + residual`; update the residual to the compression
    /// error; return the payload.
    pub fn compress(&mut self, compressor: Compressor, delta: &Mat) -> Payload {
        let mut corrected = delta.clone();
        corrected.add_assign(&self.residual);
        let payload = compressor.compress(&corrected);
        // residual = corrected - decode(payload)
        let decoded = payload.decode(delta.rows, delta.cols);
        self.residual = corrected;
        self.residual.sub_assign(&decoded);
        payload
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::rand_normal(rows, cols, 1.0, &mut rng)
    }

    #[test]
    fn sign_matches_definition() {
        let m = Mat::from_vec(2, 3, vec![1.0, -2.0, 0.5, -0.5, 3.0, -1.0]);
        let p = Compressor::Sign.compress(&m);
        let d = p.decode(2, 3);
        let scale = m.l1() as f32 / 6.0;
        for (orig, dec) in m.data.iter().zip(d.data.iter()) {
            assert!((dec.abs() - scale).abs() < 1e-6);
            assert_eq!(dec.signum(), if *orig >= 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn sign_wire_bytes_are_one_bit_per_entry() {
        let m = randmat(37, 11, 1); // 407 entries -> 51 bytes + 4 scale
        let p = Compressor::Sign.compress(&m);
        assert_eq!(p.wire_bytes(), 4 + 51);
        // ~32x smaller than dense
        let dense = Compressor::None.compress(&m);
        assert_eq!(dense.wire_bytes(), 4 * 407);
        assert!((dense.wire_bytes() as f64 / p.wire_bytes() as f64) > 29.0);
    }

    #[test]
    fn dense_roundtrip_exact() {
        let m = randmat(8, 5, 2);
        let p = Compressor::None.compress(&m);
        assert_eq!(p.decode(8, 5).data, m.data);
    }

    #[test]
    fn topk_keeps_largest() {
        let m = Mat::from_vec(1, 8, vec![0.1, -5.0, 0.2, 4.0, -0.3, 0.0, 3.0, -0.1]);
        let p = Compressor::TopK { ratio: 4 }.compress(&m); // k = 2
        let d = p.decode(1, 8);
        assert_eq!(d.data[1], -5.0);
        assert_eq!(d.data[3], 4.0);
        assert_eq!(d.data.iter().filter(|&&v| v != 0.0).count(), 2);
    }

    #[test]
    fn zero_payload_is_free_and_decodes_to_zero() {
        let p = Payload::Zero { len: 12 };
        assert_eq!(p.wire_bytes(), 0);
        assert!(p.decode(3, 4).data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn add_into_agrees_with_decode() {
        let m = randmat(6, 7, 3);
        for c in [Compressor::None, Compressor::Sign, Compressor::TopK { ratio: 8 }] {
            let p = c.compress(&m);
            let mut t1 = randmat(6, 7, 4);
            let t2base = t1.clone();
            p.add_into(&mut t1);
            let mut t2 = t2base;
            t2.add_assign(&p.decode(6, 7));
            for (a, b) in t1.data.iter().zip(t2.data.iter()) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn error_feedback_residual_tracks_loss() {
        let m = randmat(10, 4, 5);
        let mut ef = ErrorFeedback::new(10, 4);
        let p = ef.compress(Compressor::Sign, &m);
        let decoded = p.decode(10, 4);
        // residual == (m) - decoded on the first step
        for i in 0..m.data.len() {
            assert!((ef.residual.data[i] - (m.data[i] - decoded.data[i])).abs() < 1e-6);
        }
        // over many steps the accumulated decoded sum tracks the true sum
        let mut ef = ErrorFeedback::new(10, 4);
        let mut sum_true = Mat::zeros(10, 4);
        let mut sum_dec = Mat::zeros(10, 4);
        for s in 0..200 {
            let g = randmat(10, 4, 100 + s);
            sum_true.add_assign(&g);
            let p = ef.compress(Compressor::Sign, &g);
            sum_dec.add_assign(&p.decode(10, 4));
        }
        let rel = sum_true.dist_sq(&sum_dec).sqrt() / sum_true.frob();
        assert!(rel < 0.5, "error-feedback drift {rel}");
    }

    #[test]
    fn element_ratios_match_table2() {
        assert_eq!(Compressor::None.element_ratio(), 0.0);
        assert!((Compressor::Sign.element_ratio() - (1.0 - 1.0 / 32.0)).abs() < 1e-12);
        assert!((Compressor::TopK { ratio: 8 }.element_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn degenerate_topk_ratios_clamp_to_zero() {
        // ratio < 2 keeps every entry as an 8-byte pair: no savings, and
        // the ratio must clamp to 0 instead of going negative (or
        // dividing by zero for ratio == 0)
        assert_eq!(Compressor::TopK { ratio: 1 }.element_ratio(), 0.0);
        assert_eq!(Compressor::TopK { ratio: 0 }.element_ratio(), 0.0);
        let m = Mat::from_vec(1, 4, vec![1.0, -2.0, 3.0, -4.0]);
        let p = Compressor::TopK { ratio: 0 }.compress(&m); // treated as 1
        assert_eq!(p.decode(1, 4).data, m.data);
    }

    #[test]
    fn topk_handles_nan_and_inf_without_panicking() {
        // partial_cmp().unwrap() used to panic on any NaN; total_cmp
        // orders NaN above +inf, so NaN entries are kept deterministically
        let m = Mat::from_vec(2, 4, vec![0.1, f32::NAN, 0.2, f32::INFINITY, -0.3, 0.0, -5.0, -0.1]);
        let p = Compressor::TopK { ratio: 4 }.compress(&m); // k = 2
        let Payload::TopK { indices, values, len } = &p else { panic!("not TopK") };
        assert_eq!(*len, 8);
        assert_eq!(indices.as_slice(), &[1, 3], "NaN then +inf are the largest |keys|");
        assert!(values[0].is_nan());
        assert_eq!(values[1], f32::INFINITY);
        let d = p.decode(2, 4);
        assert!(d.data[1].is_nan());
        // all-NaN input still selects k entries
        let m = Mat::from_vec(1, 4, vec![f32::NAN; 4]);
        let p = Compressor::TopK { ratio: 2 }.compress(&m);
        let Payload::TopK { indices, .. } = &p else { panic!("not TopK") };
        assert_eq!(indices.len(), 2);
    }

    #[test]
    fn empty_matrix_compresses_to_header_only() {
        let m = Mat::zeros(0, 5);
        let p = Compressor::TopK { ratio: 4 }.compress(&m);
        assert!(matches!(p, Payload::Zero { len: 0 }));
        assert_eq!(p.wire_bytes(), 0);
        let mut t = Mat::zeros(0, 5);
        p.add_into(&mut t); // len assertion: 0 == 0
        assert_eq!(p.decode(0, 5).data.len(), 0);
        // sign/dense also stay finite and well-formed on empty input
        let s = Compressor::Sign.compress(&m);
        let Payload::Sign { scale, bits, len } = &s else { panic!("not Sign") };
        assert_eq!((*len, bits.len()), (0, 0));
        assert!(scale.is_finite(), "empty-matrix sign scale must not be 0/0 NaN");
        assert_eq!(Compressor::None.compress(&m).wire_bytes(), 0);
    }

    #[test]
    fn topk_wire_bytes_body_only() {
        // uniform convention: the body is exactly 8k bytes — the count
        // lives in the engine's fixed per-message header
        let m = Mat::from_vec(2, 8, (0..16).map(|i| i as f32 - 8.0).collect());
        let p = Compressor::TopK { ratio: 4 }.compress(&m); // k = 4
        assert_eq!(p.wire_bytes(), 8 * 4);
    }

    // ---- wire codec ----

    /// An adversarial f32: special values and raw bit patterns (including
    /// NaNs with arbitrary payload bits) are all fair game on the wire.
    fn hostile_f32(rng: &mut Rng) -> f32 {
        match rng.below(6) {
            0 => f32::NAN,
            1 => f32::INFINITY,
            2 => f32::NEG_INFINITY,
            3 => -0.0,
            4 => 0.0,
            _ => f32::from_bits(rng.next_u32()),
        }
    }

    /// A random payload covering every variant, including empty (`n = 0`)
    /// shapes and `Zero`.
    pub(crate) fn arbitrary_payload(rng: &mut Rng) -> Payload {
        let n = rng.below(65); // 0..=64 logical elements
        match rng.below(4) {
            0 => Payload::Dense((0..n).map(|_| hostile_f32(rng)).collect()),
            1 => Payload::Sign {
                scale: hostile_f32(rng),
                bits: (0..n.div_ceil(8)).map(|_| rng.next_u32() as u8).collect(),
                len: n,
            },
            2 => {
                let k = if n == 0 { 0 } else { rng.below(n + 1) };
                let mut indices: Vec<u32> =
                    rng.sample_indices(n, k).into_iter().map(|i| i as u32).collect();
                indices.sort_unstable();
                Payload::TopK {
                    indices,
                    values: (0..k).map(|_| hostile_f32(rng)).collect(),
                    len: n,
                }
            }
            _ => Payload::Zero { len: n },
        }
    }

    /// Structural + bit-pattern equality (NaN == NaN when the bits agree).
    pub(crate) fn payload_bits_eq(a: &Payload, b: &Payload) -> bool {
        let beq = |x: &[f32], y: &[f32]| {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        };
        match (a, b) {
            (Payload::Dense(x), Payload::Dense(y)) => beq(x, y),
            (
                Payload::Sign { scale: s1, bits: b1, len: l1 },
                Payload::Sign { scale: s2, bits: b2, len: l2 },
            ) => s1.to_bits() == s2.to_bits() && b1 == b2 && l1 == l2,
            (
                Payload::TopK { indices: i1, values: v1, len: l1 },
                Payload::TopK { indices: i2, values: v2, len: l2 },
            ) => i1 == i2 && beq(v1, v2) && l1 == l2,
            (Payload::Zero { len: l1 }, Payload::Zero { len: l2 }) => l1 == l2,
            _ => false,
        }
    }

    #[test]
    fn codec_roundtrips_every_variant_bit_exactly() {
        crate::util::propcheck::forall(
            "payload encode/decode round-trip",
            256,
            arbitrary_payload,
            |p, _| {
                let mut body = Vec::new();
                p.encode_into(&mut body);
                if body.len() as u64 != p.wire_bytes() {
                    return Err(format!(
                        "encoded {} bytes but wire_bytes() charges {}",
                        body.len(),
                        p.wire_bytes()
                    ));
                }
                let back = Payload::decode_body(p.tag(), p.logical_len(), &body)
                    .map_err(|e| format!("decode failed: {e:#}"))?;
                if !payload_bits_eq(p, &back) {
                    return Err(format!("round-trip mismatch: {back:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn codec_rejects_malformed_bodies() {
        // wrong body length for the declared logical length
        assert!(Payload::decode_body(Payload::TAG_DENSE, 3, &[0u8; 8]).is_err());
        assert!(Payload::decode_body(Payload::TAG_SIGN, 9, &[0u8; 4]).is_err());
        // truncated topk pair
        assert!(Payload::decode_body(Payload::TAG_TOPK, 8, &[0u8; 12]).is_err());
        // more kept entries than logical elements
        assert!(Payload::decode_body(Payload::TAG_TOPK, 1, &[0u8; 16]).is_err());
        // out-of-range topk index: k = 1, index = 7, n = 4
        let mut body = 7u32.to_le_bytes().to_vec();
        body.extend_from_slice(&1.0f32.to_bits().to_le_bytes());
        assert!(Payload::decode_body(Payload::TAG_TOPK, 4, &body).is_err());
        // zero must be body-free
        assert!(Payload::decode_body(Payload::TAG_ZERO, 4, &[1]).is_err());
        // unknown tag
        let err = format!("{:#}", Payload::decode_body(9, 0, &[]).unwrap_err());
        assert!(err.contains("unknown payload tag"), "{err}");
    }

    #[test]
    fn compressed_outputs_roundtrip_through_the_codec() {
        // not just arbitrary payloads: the compressors' real outputs too
        let m = randmat(9, 5, 11);
        for c in [Compressor::None, Compressor::Sign, Compressor::TopK { ratio: 8 }] {
            let p = c.compress(&m);
            let mut body = Vec::new();
            p.encode_into(&mut body);
            assert_eq!(body.len() as u64, p.wire_bytes(), "{c:?}");
            let back = Payload::decode_body(p.tag(), p.logical_len(), &body).unwrap();
            assert!(payload_bits_eq(&p, &back), "{c:?}");
        }
    }
}
