//! Element-level communication reduction: compressors + error feedback
//! (paper §III-B1, Def. III.1, Table II).
//!
//! Payloads model *real* wire encodings — the comm ledger charges the
//! actual serialized byte count (bit-packed signs, u32 indices, f32
//! values), not an analytical estimate, so the measured compression ratios
//! in Fig. 6 / Table II come from genuine payload sizes.

use crate::util::mat::Mat;

/// A compressed factor-update message payload.
#[derive(Debug, Clone)]
pub enum Payload {
    /// full-precision matrix (D-PSGD family)
    Dense(Vec<f32>),
    /// sign compressor: `‖x‖₁/n · sign(x)` — one scale + 1 bit/entry
    Sign { scale: f32, bits: Vec<u8>, len: usize },
    /// top-k by magnitude (ablation/extension compressor)
    TopK { indices: Vec<u32>, values: Vec<f32>, len: usize },
    /// event trigger not fired: the "matrix of zeros" of Alg. 1 line 13 —
    /// nothing but a header goes on the wire
    Zero { len: usize },
}

impl Payload {
    /// Bytes on the wire (payload only; the engine adds a fixed
    /// per-message header).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Dense(v) => 4 * v.len() as u64,
            Payload::Sign { bits, .. } => 4 + bits.len() as u64,
            Payload::TopK { indices, values, .. } => 4 + 4 * (indices.len() + values.len()) as u64,
            Payload::Zero { .. } => 0,
        }
    }

    /// Decode into a dense `rows x cols` matrix.
    pub fn decode(&self, rows: usize, cols: usize) -> Mat {
        let n = rows * cols;
        match self {
            Payload::Dense(v) => {
                assert_eq!(v.len(), n);
                Mat::from_vec(rows, cols, v.clone())
            }
            Payload::Sign { scale, bits, len } => {
                assert_eq!(*len, n);
                let mut data = vec![0.0f32; n];
                for (i, x) in data.iter_mut().enumerate() {
                    let bit = (bits[i >> 3] >> (i & 7)) & 1;
                    *x = if bit == 1 { *scale } else { -*scale };
                }
                Mat::from_vec(rows, cols, data)
            }
            Payload::TopK { indices, values, len } => {
                assert_eq!(*len, n);
                let mut data = vec![0.0f32; n];
                for (&i, &v) in indices.iter().zip(values.iter()) {
                    data[i as usize] = v;
                }
                Mat::from_vec(rows, cols, data)
            }
            Payload::Zero { len } => {
                assert_eq!(*len, n);
                Mat::zeros(rows, cols)
            }
        }
    }

    /// Decode-and-add into an existing matrix without allocating
    /// (`target += decode(payload)`), the receive-side hot path.
    pub fn add_into(&self, target: &mut Mat) {
        let n = target.rows * target.cols;
        match self {
            Payload::Dense(v) => {
                assert_eq!(v.len(), n);
                for (t, &x) in target.data.iter_mut().zip(v.iter()) {
                    *t += x;
                }
            }
            Payload::Sign { scale, bits, len } => {
                assert_eq!(*len, n);
                for (i, t) in target.data.iter_mut().enumerate() {
                    let bit = (bits[i >> 3] >> (i & 7)) & 1;
                    *t += if bit == 1 { *scale } else { -*scale };
                }
            }
            Payload::TopK { indices, values, len } => {
                assert_eq!(*len, n);
                for (&i, &v) in indices.iter().zip(values.iter()) {
                    target.data[i as usize] += v;
                }
            }
            Payload::Zero { len } => assert_eq!(*len, n),
        }
    }
}

/// Which compressor a configuration uses (Table II "Element-level").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compressor {
    /// identity — full precision f32
    None,
    /// Def. III.1 sign compressor
    Sign,
    /// top-k with `k = max(1, n/ratio)` entries kept
    TopK { ratio: u32 },
}

impl Compressor {
    pub fn name(self) -> &'static str {
        match self {
            Compressor::None => "none",
            Compressor::Sign => "sign",
            Compressor::TopK { .. } => "topk",
        }
    }

    /// Compress a delta matrix.
    pub fn compress(self, m: &Mat) -> Payload {
        let n = m.data.len();
        match self {
            Compressor::None => Payload::Dense(m.data.clone()),
            Compressor::Sign => {
                // scale = ‖x‖₁ / n  (Def. III.1)
                let scale = (m.l1() / n as f64) as f32;
                let mut bits = vec![0u8; n.div_ceil(8)];
                for (i, &v) in m.data.iter().enumerate() {
                    if v >= 0.0 {
                        bits[i >> 3] |= 1 << (i & 7);
                    }
                }
                Payload::Sign { scale, bits, len: n }
            }
            Compressor::TopK { ratio } => {
                let k = (n as u32 / ratio).max(1) as usize;
                let mut order: Vec<u32> = (0..n as u32).collect();
                order.select_nth_unstable_by(k - 1, |&a, &b| {
                    m.data[b as usize]
                        .abs()
                        .partial_cmp(&m.data[a as usize].abs())
                        .unwrap()
                });
                let mut indices: Vec<u32> = order[..k].to_vec();
                indices.sort_unstable();
                let values = indices.iter().map(|&i| m.data[i as usize]).collect();
                Payload::TopK { indices, values, len: n }
            }
        }
    }

    /// Theoretical compression ratio vs 32-bit dense (Table II row entry),
    /// ignoring the O(1) scale header.
    pub fn element_ratio(self) -> f64 {
        match self {
            Compressor::None => 0.0,
            Compressor::Sign => 1.0 - 1.0 / 32.0,
            Compressor::TopK { ratio } => 1.0 - 2.0 / ratio as f64,
        }
    }
}

/// Error feedback (Karimireddy et al.; used by Centralized CiderTF):
/// compress `target + residual`, keep what the compressor lost.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    pub residual: Mat,
}

impl ErrorFeedback {
    pub fn new(rows: usize, cols: usize) -> Self {
        ErrorFeedback { residual: Mat::zeros(rows, cols) }
    }

    /// Compress `delta + residual`; update the residual to the compression
    /// error; return the payload.
    pub fn compress(&mut self, compressor: Compressor, delta: &Mat) -> Payload {
        let mut corrected = delta.clone();
        corrected.add_assign(&self.residual);
        let payload = compressor.compress(&corrected);
        // residual = corrected - decode(payload)
        let decoded = payload.decode(delta.rows, delta.cols);
        self.residual = corrected;
        self.residual.sub_assign(&decoded);
        payload
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::rand_normal(rows, cols, 1.0, &mut rng)
    }

    #[test]
    fn sign_matches_definition() {
        let m = Mat::from_vec(2, 3, vec![1.0, -2.0, 0.5, -0.5, 3.0, -1.0]);
        let p = Compressor::Sign.compress(&m);
        let d = p.decode(2, 3);
        let scale = m.l1() as f32 / 6.0;
        for (orig, dec) in m.data.iter().zip(d.data.iter()) {
            assert!((dec.abs() - scale).abs() < 1e-6);
            assert_eq!(dec.signum(), if *orig >= 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn sign_wire_bytes_are_one_bit_per_entry() {
        let m = randmat(37, 11, 1); // 407 entries -> 51 bytes + 4 scale
        let p = Compressor::Sign.compress(&m);
        assert_eq!(p.wire_bytes(), 4 + 51);
        // ~32x smaller than dense
        let dense = Compressor::None.compress(&m);
        assert_eq!(dense.wire_bytes(), 4 * 407);
        assert!((dense.wire_bytes() as f64 / p.wire_bytes() as f64) > 29.0);
    }

    #[test]
    fn dense_roundtrip_exact() {
        let m = randmat(8, 5, 2);
        let p = Compressor::None.compress(&m);
        assert_eq!(p.decode(8, 5).data, m.data);
    }

    #[test]
    fn topk_keeps_largest() {
        let m = Mat::from_vec(1, 8, vec![0.1, -5.0, 0.2, 4.0, -0.3, 0.0, 3.0, -0.1]);
        let p = Compressor::TopK { ratio: 4 }.compress(&m); // k = 2
        let d = p.decode(1, 8);
        assert_eq!(d.data[1], -5.0);
        assert_eq!(d.data[3], 4.0);
        assert_eq!(d.data.iter().filter(|&&v| v != 0.0).count(), 2);
    }

    #[test]
    fn zero_payload_is_free_and_decodes_to_zero() {
        let p = Payload::Zero { len: 12 };
        assert_eq!(p.wire_bytes(), 0);
        assert!(p.decode(3, 4).data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn add_into_agrees_with_decode() {
        let m = randmat(6, 7, 3);
        for c in [Compressor::None, Compressor::Sign, Compressor::TopK { ratio: 8 }] {
            let p = c.compress(&m);
            let mut t1 = randmat(6, 7, 4);
            let t2base = t1.clone();
            p.add_into(&mut t1);
            let mut t2 = t2base;
            t2.add_assign(&p.decode(6, 7));
            for (a, b) in t1.data.iter().zip(t2.data.iter()) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn error_feedback_residual_tracks_loss() {
        let m = randmat(10, 4, 5);
        let mut ef = ErrorFeedback::new(10, 4);
        let p = ef.compress(Compressor::Sign, &m);
        let decoded = p.decode(10, 4);
        // residual == (m) - decoded on the first step
        for i in 0..m.data.len() {
            assert!((ef.residual.data[i] - (m.data[i] - decoded.data[i])).abs() < 1e-6);
        }
        // over many steps the accumulated decoded sum tracks the true sum
        let mut ef = ErrorFeedback::new(10, 4);
        let mut sum_true = Mat::zeros(10, 4);
        let mut sum_dec = Mat::zeros(10, 4);
        for s in 0..200 {
            let g = randmat(10, 4, 100 + s);
            sum_true.add_assign(&g);
            let p = ef.compress(Compressor::Sign, &g);
            sum_dec.add_assign(&p.decode(10, 4));
        }
        let rel = sum_true.dist_sq(&sum_dec).sqrt() / sum_true.frob();
        assert!(rel < 0.5, "error-feedback drift {rel}");
    }

    #[test]
    fn element_ratios_match_table2() {
        assert_eq!(Compressor::None.element_ratio(), 0.0);
        assert!((Compressor::Sign.element_ratio() - (1.0 - 1.0 / 32.0)).abs() < 1e-12);
    }
}
