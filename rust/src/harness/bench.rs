//! `cidertf bench` — the persistent performance gate.
//!
//! Runs the L3 hot-path micro-benchmarks (slice gather, Khatri-Rao row
//! gather, sign codec, consensus AXPY), the gradient kernel in **both**
//! its pre-blocked naive form and the blocked allocation-free form (so
//! each run measures the speedup on the same machine in the same
//! process), plus one end-to-end training-round benchmark, then appends
//! the results to `BENCH.json` at the repo root
//! (schema [`crate::util::benchkit::BENCH_SCHEMA`]).
//!
//! `--smoke` shrinks sizes and durations to CI scale; `--out-json PATH`
//! redirects the report. The gradient comparison defaults to the
//! acceptance shape `(i=512, s=128, r=32)`.

use std::path::PathBuf;

use crate::compress::Compressor;
use crate::engine::client::gather_rows;
use crate::engine::session::Session;
use crate::engine::spec::ExperimentSpec;
use crate::engine::{AlgoConfig, TrainConfig};
use crate::factor::FactorSet;
use crate::net::driver::DriverKind;
use crate::losses::Loss;
use crate::runtime::native::NativeBackend;
use crate::runtime::ComputeBackend;
use crate::sched::FiberSampler;
use crate::tensor::fiber::FiberIndex;
use crate::tensor::synth::SynthConfig;
use crate::util::benchkit::{append_bench_json, bench, BenchRun};
use crate::util::cli::Args;
use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// Entry point for the `bench` subcommand.
pub fn run(args: &Args) -> anyhow::Result<()> {
    let smoke = args.flag("smoke");
    let out_path = PathBuf::from(args.get_str("out-json", "BENCH.json")?);
    let threads = args.get_usize("threads", 1)?;
    // acceptance shape for the grad comparison; smoke shrinks everything
    let (i_dim, s_dim, r_dim, ms) =
        if smoke { (64, 32, 8, 25u64) } else { (512, 128, 32, 400u64) };
    let mode = if smoke { "smoke" } else { "full" };
    println!("bench mode={mode}  grad shape i={i_dim} s={s_dim} r={r_dim}  threads={threads}\n");

    let mut rng = Rng::new(0xBE7C);
    let a = Mat::rand_uniform(i_dim, r_dim, 0.3, &mut rng);
    let us: Vec<Mat> = (0..2).map(|_| Mat::rand_uniform(s_dim, r_dim, 0.3, &mut rng)).collect();
    let u_refs: Vec<&Mat> = us.iter().collect();
    let xs: Vec<f32> =
        (0..i_dim * s_dim).map(|_| if rng.bernoulli(0.25) { 1.0 } else { 0.0 }).collect();
    let scale = 1.0 / s_dim as f32;

    let mut benches = Vec::new();

    // --- the perf-gate pair: naive vs blocked gradient, same process.
    // Gated on the ls loss (pure FLOPs — measures the kernel, where the
    // logit loss spends most of its time in scalar exp/log either way;
    // a logit pair is recorded below as supplementary data). ---
    let mut backend = NativeBackend::with_threads(threads);
    let naive = bench(&format!("grad_naive_ls_i{i_dim}_s{s_dim}_r{r_dim}"), ms, || {
        backend.grad_naive(Loss::Ls, &xs, i_dim, s_dim, &a, &u_refs, scale).unwrap()
    });
    let mut g_out = Mat::zeros(i_dim, r_dim);
    let blocked = bench(&format!("grad_blocked_ls_i{i_dim}_s{s_dim}_r{r_dim}"), ms, || {
        backend.grad_into(Loss::Ls, &xs, i_dim, s_dim, &a, &us, scale, &mut g_out).unwrap()
    });
    let speedup = naive.mean_ns / blocked.mean_ns.max(1.0);
    benches.push(bench(&format!("grad_naive_logit_i{i_dim}_s{s_dim}_r{r_dim}"), ms / 2, || {
        backend.grad_naive(Loss::Logit, &xs, i_dim, s_dim, &a, &u_refs, scale).unwrap()
    }));
    benches.push(bench(&format!("grad_blocked_logit_i{i_dim}_s{s_dim}_r{r_dim}"), ms / 2, || {
        backend
            .grad_into(Loss::Logit, &xs, i_dim, s_dim, &a, &us, scale, &mut g_out)
            .unwrap()
    }));

    // --- kernel micro-benches ---
    let mut h = us[0].clone();
    h.hadamard_assign(&us[1]);
    let mut m_buf = Mat::zeros(i_dim, s_dim);
    benches.push(bench(&format!("gemm_transb_{i_dim}x{s_dim}x{r_dim}"), ms / 2, || {
        a.matmul_transb_into(&h, &mut m_buf)
    }));
    let mut g_buf = Mat::zeros(i_dim, r_dim);
    benches.push(bench(&format!("gemm_acc_{i_dim}x{r_dim}x{s_dim}"), ms / 2, || {
        m_buf.matmul_acc_into(&h, &mut g_buf)
    }));

    // --- comms micro-benches (the other L3 hot paths) ---
    let delta = Mat::rand_normal(s_dim, r_dim, 0.1, &mut rng);
    benches.push(bench(&format!("sign_compress_{s_dim}x{r_dim}"), ms / 2, || {
        Compressor::Sign.compress(&delta)
    }));
    let payload = Compressor::Sign.compress(&delta);
    let mut hat = Mat::zeros(s_dim, r_dim);
    benches.push(bench(&format!("sign_decode_add_{s_dim}x{r_dim}"), ms / 2, || {
        payload.add_into(&mut hat)
    }));
    let mut target = Mat::zeros(s_dim, r_dim);
    benches.push(bench(&format!("consensus_axpy_{s_dim}x{r_dim}"), ms / 2, || {
        target.axpy(0.33, &delta)
    }));

    // --- threading: the standard shapes sit below the row-panel pool's
    // engagement threshold (i >= 2048), so with --threads > 1 also bench
    // a tall shape where the scoped pool actually runs ---
    if threads > 1 {
        let (ti, ts) = (4096usize, 64usize);
        let ta = Mat::rand_uniform(ti, r_dim, 0.3, &mut rng);
        let tus: Vec<Mat> =
            (0..2).map(|_| Mat::rand_uniform(ts, r_dim, 0.3, &mut rng)).collect();
        let txs: Vec<f32> =
            (0..ti * ts).map(|_| if rng.bernoulli(0.25) { 1.0 } else { 0.0 }).collect();
        let tscale = 1.0 / ts as f32;
        let mut tout = Mat::zeros(ti, r_dim);
        let mut one = NativeBackend::new();
        benches.push(bench(&format!("grad_tall_1thread_i{ti}_s{ts}_r{r_dim}"), ms / 2, || {
            one.grad_into(Loss::Ls, &txs, ti, ts, &ta, &tus, tscale, &mut tout).unwrap()
        }));
        benches.push(bench(
            &format!("grad_tall_{threads}threads_i{ti}_s{ts}_r{r_dim}"),
            ms / 2,
            || backend.grad_into(Loss::Ls, &txs, ti, ts, &ta, &tus, tscale, &mut tout).unwrap(),
        ));
    }

    // --- L3 gather hot paths: sparse slice gather + Khatri-Rao rows ---
    let data = SynthConfig::tiny(5).generate();
    let gdims = data.tensor.dims.clone();
    let fi = FiberIndex::build(&data.tensor, 0);
    let mut fib_sampler = FiberSampler::new(7, 0);
    let fibers = fib_sampler.sample(data.tensor.n_fibers(0), s_dim);
    let gs = fibers.len();
    let mut xs_gather = vec![0.0f32; gdims[0] * gs];
    benches.push(bench(&format!("gather_slice_{}x{gs}", gdims[0]), ms / 2, || {
        fi.gather_slice(&fibers, gdims[0], &mut xs_gather)
    }));
    let gfactors = FactorSet::init_uniform(&gdims, r_dim, 0.3, 3);
    let mut gather_bufs = vec![Mat::zeros(gs, r_dim), Mat::zeros(gs, r_dim)];
    benches.push(bench(&format!("gather_krp_rows_{gs}x{r_dim}"), ms / 2, || {
        gather_rows(&gfactors, 0, &gdims, &fibers, &mut gather_bufs)
    }));

    // --- end-to-end: one full (tiny) decentralized training run,
    // driven through the Session pipeline like every experiment ---
    let mut cfg = TrainConfig::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
    cfg.k = 4;
    cfg.rank = 4;
    cfg.fiber_samples = 16;
    cfg.eval_batch = 64;
    cfg.gamma = 0.5;
    cfg.epochs = 1;
    cfg.iters_per_epoch = if smoke { 10 } else { 60 };
    cfg.compute_threads = threads;
    let spec = ExperimentSpec::from_train_config(&cfg, DriverKind::Sequential, None, "native");
    let mut session = Session::new(spec);
    let e2e = bench(&format!("train_e2e_tiny_k4_iters{}", cfg.iters_per_epoch), ms, || {
        let mut b = NativeBackend::new();
        session.run_on(&data, &mut b, None).unwrap()
    });

    let mut all = vec![naive.clone(), blocked.clone()];
    all.append(&mut benches);
    all.push(e2e);
    let run = BenchRun {
        mode: mode.to_string(),
        benches: all,
        derived: vec![("grad_speedup_blocked_vs_naive".to_string(), speedup)],
    };
    append_bench_json(&out_path, &run)?;
    println!("\ngrad blocked vs naive: {speedup:.2}x ({} -> {})",
        crate::util::benchkit::fmt_ns(naive.mean_ns),
        crate::util::benchkit::fmt_ns(blocked.mean_ns));
    println!("appended run to {}", out_path.display());
    Ok(())
}
