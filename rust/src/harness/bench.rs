//! `cidertf bench` — the persistent performance gate.
//!
//! Runs the L3 hot-path micro-benchmarks (slice gather, Khatri-Rao row
//! gather, sign codec, consensus AXPY), the gradient kernel in **both**
//! its pre-blocked naive form and the blocked allocation-free form, the
//! sparse slice gather in **both** its CSF form and the historical
//! HashMap-COO form, the SIMD-dispatched kernels against their scalar
//! pins, and the persistent-pool gradient against the frozen scoped-spawn
//! baseline (so each run measures every speedup on the same machine in
//! the same process), plus one end-to-end training-round benchmark, then
//! appends the results to `BENCH.json` at the repo root (schema
//! [`crate::util::benchkit::BENCH_SCHEMA`]). Full mode adds paper-scale
//! patient modes (`i = 1e5, 1e6`) comparing the single-thread and
//! 4-thread pooled gradient.
//!
//! `--smoke` shrinks sizes and durations to CI scale (tiny tensor); the
//! full mode gathers over the `synthetic` and `mimic_like` tensors.
//! `--out-json PATH` redirects the report. The gradient comparison
//! defaults to the acceptance shape `(i=512, s=128, r=32)`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::compress::Compressor;
use crate::engine::client::gather_rows;
use crate::engine::session::Session;
use crate::engine::spec::ExperimentSpec;
use crate::engine::{AlgoConfig, TrainConfig};
use crate::factor::FactorSet;
use crate::losses::Loss;
use crate::net::driver::DriverKind;
use crate::runtime::native::NativeBackend;
use crate::runtime::ComputeBackend;
use crate::sched::FiberSampler;
use crate::tensor::fiber::FiberIndex;
use crate::tensor::synth::SynthConfig;
use crate::tensor::SparseTensor;
use crate::util::benchkit::{append_bench_json, bench, BenchRun, BENCH_SCHEMA};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::mat::{gemm_transb_into_l, Mat};
use crate::util::rng::Rng;
use crate::util::simd::{self, Level};

/// The pre-CSF fiber lookup (HashMap over COO groups), preserved here as
/// the gather reference so every bench run records the CSF speedup
/// same-machine, same-process — exactly like `grad_naive` does for the
/// blocked gradient.
struct HashGatherRef {
    rows: Vec<u32>,
    vals: Vec<f32>,
    ranges: HashMap<u64, (u32, u32)>,
}

impl HashGatherRef {
    fn build(t: &SparseTensor, mode: usize) -> Self {
        let nnz = t.nnz();
        let mut keyed: Vec<(u64, u32)> =
            (0..nnz).map(|e| (t.fiber_of_entry(e, mode), e as u32)).collect();
        keyed.sort_unstable();
        let mut rows = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        let mut ranges = HashMap::new();
        let mut i = 0usize;
        while i < keyed.len() {
            let fid = keyed[i].0;
            let start = i;
            while i < keyed.len() && keyed[i].0 == fid {
                let e = keyed[i].1 as usize;
                rows.push(t.entry_index(e, mode));
                vals.push(t.vals[e]);
                i += 1;
            }
            ranges.insert(fid, (start as u32, i as u32));
        }
        HashGatherRef { rows, vals, ranges }
    }

    fn gather_slice(&self, fibers: &[u64], i_dim: usize, out: &mut [f32]) {
        let s = fibers.len();
        assert_eq!(out.len(), i_dim * s);
        out.fill(0.0);
        for (col, &fid) in fibers.iter().enumerate() {
            if let Some(&(a, b)) = self.ranges.get(&fid) {
                for k in a as usize..b as usize {
                    out[self.rows[k] as usize * s + col] = self.vals[k];
                }
            }
        }
    }
}

/// Mean ns of the most recent bench with **exactly** this name in an
/// existing BENCH.json (for cross-run derived speedups). Exact matching
/// matters: the e2e bench name encodes its workload size
/// (`train_e2e_tiny_k4_iters10` vs `...iters60`), so smoke and full runs
/// never get compared to each other.
fn prev_bench_mean(path: &Path, name: &str) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    if j.get("schema").and_then(Json::as_str) != Some(BENCH_SCHEMA) {
        return None;
    }
    let Some(Json::Arr(runs)) = j.get("runs") else { return None };
    for run in runs.iter().rev() {
        let Some(Json::Arr(bs)) = run.get("benches") else { continue };
        for b in bs {
            if b.get("name").and_then(Json::as_str) == Some(name) {
                return b.get("mean_ns").and_then(Json::as_f64);
            }
        }
    }
    None
}

/// Entry point for the `bench` subcommand.
pub fn run(args: &Args) -> anyhow::Result<()> {
    let smoke = args.flag("smoke");
    let out_path = PathBuf::from(args.get_str("out-json", "BENCH.json")?);
    let threads = args.get_usize("threads", 1)?;
    // acceptance shape for the grad comparison; smoke shrinks everything
    let (i_dim, s_dim, r_dim, ms) =
        if smoke { (64, 32, 8, 25u64) } else { (512, 128, 32, 400u64) };
    let mode = if smoke { "smoke" } else { "full" };
    println!(
        "bench mode={mode}  grad shape i={i_dim} s={s_dim} r={r_dim}  threads={threads}  \
         simd={}\n",
        simd::level().name()
    );

    let mut rng = Rng::new(0xBE7C);
    let a = Mat::rand_uniform(i_dim, r_dim, 0.3, &mut rng);
    let us: Vec<Mat> = (0..2).map(|_| Mat::rand_uniform(s_dim, r_dim, 0.3, &mut rng)).collect();
    let u_refs: Vec<&Mat> = us.iter().collect();
    let xs: Vec<f32> =
        (0..i_dim * s_dim).map(|_| if rng.bernoulli(0.25) { 1.0 } else { 0.0 }).collect();
    let scale = 1.0 / s_dim as f32;

    let mut benches = Vec::new();

    // --- the perf-gate pair: naive vs blocked gradient, same process.
    // Gated on the ls loss (pure FLOPs — measures the kernel, where the
    // logit loss spends most of its time in scalar exp/log either way;
    // a logit pair is recorded below as supplementary data). ---
    let mut backend = NativeBackend::with_threads(threads);
    let naive = bench(&format!("grad_naive_ls_i{i_dim}_s{s_dim}_r{r_dim}"), ms, || {
        backend.grad_naive(Loss::Ls, &xs, i_dim, s_dim, &a, &u_refs, scale).unwrap()
    });
    let mut g_out = Mat::zeros(i_dim, r_dim);
    let blocked = bench(&format!("grad_blocked_ls_i{i_dim}_s{s_dim}_r{r_dim}"), ms, || {
        backend.grad_into(Loss::Ls, &xs, i_dim, s_dim, &a, &us, scale, &mut g_out).unwrap()
    });
    let speedup = naive.mean_ns / blocked.mean_ns.max(1.0);
    benches.push(bench(&format!("grad_naive_logit_i{i_dim}_s{s_dim}_r{r_dim}"), ms / 2, || {
        backend.grad_naive(Loss::Logit, &xs, i_dim, s_dim, &a, &u_refs, scale).unwrap()
    }));
    benches.push(bench(&format!("grad_blocked_logit_i{i_dim}_s{s_dim}_r{r_dim}"), ms / 2, || {
        backend
            .grad_into(Loss::Logit, &xs, i_dim, s_dim, &a, &us, scale, &mut g_out)
            .unwrap()
    }));

    // --- kernel micro-benches ---
    let mut h = us[0].clone();
    h.hadamard_assign(&us[1]);
    let mut m_buf = Mat::zeros(i_dim, s_dim);
    benches.push(bench(&format!("gemm_transb_{i_dim}x{s_dim}x{r_dim}"), ms / 2, || {
        a.matmul_transb_into(&h, &mut m_buf)
    }));
    let mut g_buf = Mat::zeros(i_dim, r_dim);
    benches.push(bench(&format!("gemm_acc_{i_dim}x{r_dim}x{s_dim}"), ms / 2, || {
        m_buf.matmul_acc_into(&h, &mut g_buf)
    }));

    // --- comms micro-benches (the other L3 hot paths) ---
    let delta = Mat::rand_normal(s_dim, r_dim, 0.1, &mut rng);
    benches.push(bench(&format!("sign_compress_{s_dim}x{r_dim}"), ms / 2, || {
        Compressor::Sign.compress(&delta)
    }));
    let payload = Compressor::Sign.compress(&delta);
    let mut hat = Mat::zeros(s_dim, r_dim);
    benches.push(bench(&format!("sign_decode_add_{s_dim}x{r_dim}"), ms / 2, || {
        payload.add_into(&mut hat)
    }));
    let mut target = Mat::zeros(s_dim, r_dim);
    benches.push(bench(&format!("consensus_axpy_{s_dim}x{r_dim}"), ms / 2, || {
        target.axpy(0.33, &delta)
    }));

    // --- SIMD vs scalar: the dispatched kernel level against the same
    // kernel pinned to the scalar lanes, same buffers, same process (the
    // third perf-gate pair). gemm_transb is the dot-product-bound kernel
    // where the lanes pay off; the sign pack pair covers the byte-output
    // compress kernel. ---
    let lv = simd::level();
    let gemm_simd = bench(&format!("gemm_transb_simd_{i_dim}x{s_dim}x{r_dim}"), ms / 2, || {
        gemm_transb_into_l(lv, &a.data, &h.data, &mut m_buf.data, i_dim, s_dim, r_dim)
    });
    let gemm_scalar =
        bench(&format!("gemm_transb_scalar_{i_dim}x{s_dim}x{r_dim}"), ms / 2, || {
            gemm_transb_into_l(
                Level::Scalar,
                &a.data,
                &h.data,
                &mut m_buf.data,
                i_dim,
                s_dim,
                r_dim,
            )
        });
    let simd_speedup = gemm_scalar.mean_ns / gemm_simd.mean_ns.max(1.0);
    benches.push(gemm_simd);
    benches.push(gemm_scalar);
    let mut pack_bits = vec![0u8; (s_dim * r_dim).div_ceil(8)];
    benches.push(bench(&format!("sign_pack_simd_{s_dim}x{r_dim}"), ms / 4, || {
        pack_bits.fill(0);
        simd::sign_pack(lv, &delta.data, &mut pack_bits)
    }));
    benches.push(bench(&format!("sign_pack_scalar_{s_dim}x{r_dim}"), ms / 4, || {
        pack_bits.fill(0);
        simd::sign_pack(Level::Scalar, &delta.data, &mut pack_bits)
    }));

    // --- persistent pool vs the frozen PR 2 scoped-spawn gradient, both
    // at 4 threads on a pool-engaging tall shape (the fourth perf-gate
    // pair: what the persistent workers buy over per-call spawns) ---
    let pool_speedup = {
        let (pi, ps, pr) = (4096usize, 64usize, 16usize);
        let pa = Mat::rand_uniform(pi, pr, 0.3, &mut rng);
        let pus: Vec<Mat> = (0..2).map(|_| Mat::rand_uniform(ps, pr, 0.3, &mut rng)).collect();
        let pu_refs: Vec<&Mat> = pus.iter().collect();
        let pxs: Vec<f32> =
            (0..pi * ps).map(|_| if rng.bernoulli(0.25) { 1.0 } else { 0.0 }).collect();
        let pscale = 1.0 / ps as f32;
        let mut pout = Mat::zeros(pi, pr);
        let mut pbe = NativeBackend::with_threads(4);
        let pooled = bench(&format!("grad_pool_4threads_i{pi}_s{ps}_r{pr}"), ms / 2, || {
            pbe.grad_into(Loss::Ls, &pxs, pi, ps, &pa, &pus, pscale, &mut pout).unwrap()
        });
        let spawned = bench(&format!("grad_spawn_4threads_i{pi}_s{ps}_r{pr}"), ms / 2, || {
            pbe.grad_spawn_reference(Loss::Ls, &pxs, pi, ps, &pa, &pu_refs, pscale, 4)
        });
        let s = spawned.mean_ns / pooled.mean_ns.max(1.0);
        benches.push(pooled);
        benches.push(spawned);
        s
    };

    // --- large patient modes (paper-scale I), smoke-skipped: the
    // single-thread blocked kernel vs the 4-thread pooled kernel on the
    // same buffers. These are the shapes where the pool's row panels and
    // the SIMD lanes both engage. ---
    let mut derived_large: Vec<(String, f64)> = Vec::new();
    if !smoke {
        for (li, ls, lr) in [(100_000usize, 128usize, 32usize), (1_000_000, 16, 8)] {
            let la = Mat::rand_uniform(li, lr, 0.3, &mut rng);
            let lus: Vec<Mat> =
                (0..2).map(|_| Mat::rand_uniform(ls, lr, 0.3, &mut rng)).collect();
            let lxs: Vec<f32> =
                (0..li * ls).map(|_| if rng.bernoulli(0.05) { 1.0 } else { 0.0 }).collect();
            let lscale = 1.0 / ls as f32;
            let mut lout = Mat::zeros(li, lr);
            let mut one = NativeBackend::new();
            let single = bench(&format!("grad_blocked_ls_i{li}_s{ls}_r{lr}"), ms, || {
                one.grad_into(Loss::Ls, &lxs, li, ls, &la, &lus, lscale, &mut lout).unwrap()
            });
            let mut four = NativeBackend::with_threads(4);
            let pooled = bench(&format!("grad_pool_4threads_i{li}_s{ls}_r{lr}"), ms, || {
                four.grad_into(Loss::Ls, &lxs, li, ls, &la, &lus, lscale, &mut lout).unwrap()
            });
            derived_large.push((
                format!("grad_speedup_pool4_vs_1thread_i{li}"),
                single.mean_ns / pooled.mean_ns.max(1.0),
            ));
            benches.push(single);
            benches.push(pooled);
        }
    }

    // --- threading: with --threads > 1 also bench a tall shape where
    // the persistent pool is far past its engagement threshold
    // (`pool::thresholds::GRAD_PAR_MIN_ROWS` rows) ---
    if threads > 1 {
        let (ti, ts) = (4096usize, 64usize);
        let ta = Mat::rand_uniform(ti, r_dim, 0.3, &mut rng);
        let tus: Vec<Mat> =
            (0..2).map(|_| Mat::rand_uniform(ts, r_dim, 0.3, &mut rng)).collect();
        let txs: Vec<f32> =
            (0..ti * ts).map(|_| if rng.bernoulli(0.25) { 1.0 } else { 0.0 }).collect();
        let tscale = 1.0 / ts as f32;
        let mut tout = Mat::zeros(ti, r_dim);
        let mut one = NativeBackend::new();
        benches.push(bench(&format!("grad_tall_1thread_i{ti}_s{ts}_r{r_dim}"), ms / 2, || {
            one.grad_into(Loss::Ls, &txs, ti, ts, &ta, &tus, tscale, &mut tout).unwrap()
        }));
        benches.push(bench(
            &format!("grad_tall_{threads}threads_i{ti}_s{ts}_r{r_dim}"),
            ms / 2,
            || backend.grad_into(Loss::Ls, &txs, ti, ts, &ta, &tus, tscale, &mut tout).unwrap(),
        ));
    }

    // --- L3 gather hot paths: the CSF slice gather vs the historical
    // HashMap-COO lookup (the second perf-gate pair), + Khatri-Rao rows.
    // Smoke gathers over the tiny tensor (shared with the e2e run below);
    // full mode over `synthetic`. ---
    let data = SynthConfig::tiny(5).generate();
    let gather_data = if smoke { data.clone() } else { SynthConfig::synthetic().generate() };
    let gdims = gather_data.tensor.dims.clone();
    let fi = FiberIndex::build(&gather_data.tensor, 0);
    let hg = HashGatherRef::build(&gather_data.tensor, 0);
    let mut fib_sampler = FiberSampler::new(7, 0);
    let fibers = fib_sampler.sample(gather_data.tensor.n_fibers(0), s_dim);
    let gs = fibers.len();
    let mut xs_gather = vec![0.0f32; gdims[0] * gs];
    let gather_csf = bench(&format!("gather_csf_{}x{gs}", gdims[0]), ms / 2, || {
        fi.gather_slice(&fibers, gdims[0], &mut xs_gather)
    });
    let gather_hash = bench(&format!("gather_hashmap_{}x{gs}", gdims[0]), ms / 2, || {
        hg.gather_slice(&fibers, gdims[0], &mut xs_gather)
    });
    let gather_speedup = gather_hash.mean_ns / gather_csf.mean_ns.max(1.0);
    benches.push(gather_csf.clone());
    benches.push(gather_hash);
    let gfactors = FactorSet::init_uniform(&gdims, r_dim, 0.3, 3);
    let mut gather_bufs = vec![Mat::zeros(gs, r_dim), Mat::zeros(gs, r_dim)];
    benches.push(bench(&format!("gather_krp_rows_{gs}x{r_dim}"), ms / 2, || {
        gather_rows(&gfactors, 0, &gdims, &fibers, &mut gather_bufs)
    }));
    if !smoke {
        // second dataset shape for the committed baseline trajectory
        let md = SynthConfig::mimic_like().generate();
        let mi = md.tensor.dims[0];
        let mfi = FiberIndex::build(&md.tensor, 0);
        let mhg = HashGatherRef::build(&md.tensor, 0);
        let mfibers = fib_sampler.sample(md.tensor.n_fibers(0), s_dim);
        let mgs = mfibers.len();
        let mut mxs = vec![0.0f32; mi * mgs];
        benches.push(bench(&format!("gather_csf_mimic_like_{mi}x{mgs}"), ms / 2, || {
            mfi.gather_slice(&mfibers, mi, &mut mxs)
        }));
        benches.push(bench(&format!("gather_hashmap_mimic_like_{mi}x{mgs}"), ms / 2, || {
            mhg.gather_slice(&mfibers, mi, &mut mxs)
        }));
    }

    // --- end-to-end: one full (tiny) decentralized training run,
    // driven through the Session pipeline like every experiment ---
    let mut cfg = TrainConfig::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
    cfg.k = 4;
    cfg.rank = 4;
    cfg.fiber_samples = 16;
    cfg.eval_batch = 64;
    cfg.gamma = 0.5;
    cfg.epochs = 1;
    cfg.iters_per_epoch = if smoke { 10 } else { 60 };
    cfg.compute_threads = threads;
    let spec = ExperimentSpec::from_train_config(&cfg, DriverKind::Sequential, None, "native");
    let mut session = Session::new(spec);
    let e2e_name = format!("train_e2e_tiny_k4_iters{}", cfg.iters_per_epoch);
    let e2e = bench(&e2e_name, ms, || {
        let mut b = NativeBackend::new();
        session.run_on(&data, &mut b, None).unwrap()
    });

    // end-to-end speedup vs the most recent recorded run of the *same*
    // bench (committed BENCH.json history), when one exists
    let prev_e2e = prev_bench_mean(&out_path, &e2e_name);

    let mut all = vec![naive.clone(), blocked.clone()];
    all.append(&mut benches);
    let mut derived = vec![
        ("grad_speedup_blocked_vs_naive".to_string(), speedup),
        ("gather_speedup_csf_vs_hashmap".to_string(), gather_speedup),
        ("simd_speedup_vs_scalar".to_string(), simd_speedup),
        ("pool_speedup_vs_spawn".to_string(), pool_speedup),
    ];
    derived.append(&mut derived_large);
    if let Some(prev) = prev_e2e {
        derived.push(("e2e_speedup_vs_prev_run".to_string(), prev / e2e.mean_ns.max(1.0)));
    }
    all.push(e2e.clone());
    let run = BenchRun { mode: mode.to_string(), benches: all, derived };
    append_bench_json(&out_path, &run)?;
    println!("\ngrad blocked vs naive: {speedup:.2}x ({} -> {})",
        crate::util::benchkit::fmt_ns(naive.mean_ns),
        crate::util::benchkit::fmt_ns(blocked.mean_ns));
    println!("gather CSF vs hashmap: {gather_speedup:.2}x (dense layout: {})", fi.is_dense());
    println!("gemm SIMD ({}) vs scalar: {simd_speedup:.2}x", lv.name());
    println!("grad pool vs scoped spawn (4 threads): {pool_speedup:.2}x");
    if let Some(prev) = prev_e2e {
        println!(
            "e2e round vs previous recorded run: {:.2}x ({} -> {})",
            prev / e2e.mean_ns.max(1.0),
            crate::util::benchkit::fmt_ns(prev),
            crate::util::benchkit::fmt_ns(e2e.mean_ns)
        );
    }
    println!("appended run to {}", out_path.display());
    Ok(())
}
