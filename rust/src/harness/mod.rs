//! Experiment harness: one driver per paper figure/table (DESIGN.md
//! per-experiment index). Each figure/sweep driver is a
//! [`crate::sweep::SweepSpec`] constructor fed to the parallel sweep
//! executor — runs execute concurrently on `Ctx::workers` threads with
//! `Arc`-shared datasets, and regenerate the corresponding rows/series
//! as printed tables + per-run CSV curves + a deterministic
//! `sweep.jsonl` under `results/<figure>/`. (The phenotype tables keep
//! single [`Ctx::run`] calls — they consume the run's *factors*, not
//! just its record.)
//!
//! Two effort profiles:
//! * `quick` — reduced iterations/datasets; minutes, shape-checking runs
//!   (the default for `cargo bench`),
//! * `paper` — the paper's settings (500 iters/epoch, all datasets, both
//!   losses); tens of minutes.

pub mod ablate;
pub mod bench;
pub mod faults;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod tables;

use std::path::PathBuf;

use crate::data::Dataset;
use crate::engine::session::{CsvObserver, Session};
use crate::engine::spec::ExperimentSpec;
use crate::engine::{AlgoConfig, TrainConfig, TrainOutcome};
use crate::factor::FactorSet;
use crate::losses::Loss;
use crate::net::driver::DriverKind;
use crate::runtime::{default_artifact_dir, ComputeBackend, PjrtBackend};
use crate::sweep::{SweepOptions, SweepOutcome, SweepSpec};
use crate::tensor::synth::ValueKind;

/// Effort profile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    Quick,
    Paper,
}

impl Profile {
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        match s {
            "quick" => Ok(Profile::Quick),
            "paper" | "full" => Ok(Profile::Paper),
            other => anyhow::bail!("unknown profile '{other}' (quick|paper)"),
        }
    }

    pub fn iters_per_epoch(self) -> usize {
        match self {
            Profile::Quick => 150,
            Profile::Paper => 500, // paper §IV-A3
        }
    }

    pub fn epochs(self) -> usize {
        match self {
            Profile::Quick => 4,
            Profile::Paper => 10,
        }
    }

    pub fn datasets(self) -> Vec<&'static str> {
        match self {
            Profile::Quick => vec!["synthetic"],
            Profile::Paper => vec!["cms_like", "mimic_like", "synthetic"],
        }
    }

    pub fn losses(self) -> Vec<Loss> {
        match self {
            Profile::Quick => vec![Loss::Logit],
            Profile::Paper => vec![Loss::Logit, Loss::Ls],
        }
    }
}

/// Shared harness context: backend, output dir, profile, sweep width.
pub struct Ctx {
    pub backend: Box<dyn ComputeBackend>,
    pub out_dir: PathBuf,
    pub profile: Profile,
    /// worker threads for the sweep executor (`--workers`; results are
    /// bit-identical for any value)
    pub workers: usize,
}

impl Ctx {
    pub fn new(profile: Profile) -> anyhow::Result<Self> {
        let backend = Box::new(PjrtBackend::new(&default_artifact_dir())?);
        Ok(Ctx {
            backend,
            out_dir: PathBuf::from("results"),
            profile,
            workers: crate::sweep::default_workers(),
        })
    }

    pub fn with_backend(backend: Box<dyn ComputeBackend>, profile: Profile) -> Self {
        Ctx {
            backend,
            out_dir: PathBuf::from("results"),
            profile,
            workers: crate::sweep::default_workers(),
        }
    }

    /// Materialize (deterministically) the dataset for a source name +
    /// loss — synthetic generators and the `file:`/`csv:` loaders alike
    /// resolve through [`crate::registry::datasets`].
    pub fn dataset(&self, name: &str, loss: Loss) -> anyhow::Result<Dataset> {
        let vk = if loss == Loss::Ls { ValueKind::Gaussian } else { ValueKind::Binary };
        crate::data::load_dataset(name, vk)
    }

    /// Grid-searched learning rate per (dataset, loss) — powers of two, as
    /// the paper prescribes (§IV-A3). Values found by `cidertf tune`;
    /// the canonical table lives in [`crate::sweep::tuned_gamma`] (sweep
    /// expansion applies it under `auto_gamma`).
    pub fn gamma_for(dataset: &str, loss: Loss) -> f64 {
        crate::sweep::tuned_gamma(dataset, loss)
    }

    /// Base train config for a figure run.
    pub fn base_config(&self, dataset: &str, loss: Loss, algo: AlgoConfig) -> TrainConfig {
        let mut cfg = TrainConfig::new(dataset, loss, algo);
        cfg.gamma = Self::gamma_for(dataset, loss);
        // Nesterov momentum amplifies the steady-state step by ~1/(1-β);
        // rescale γ so momentum runs sit at the same effective rate the
        // grid search found (the paper grid-searches each algorithm).
        if let Some(beta) = cfg.algo.momentum {
            cfg.gamma *= 1.0 - beta;
        }
        cfg.iters_per_epoch = self.profile.iters_per_epoch();
        cfg.epochs = self.profile.epochs();
        cfg
    }

    /// Base [`ExperimentSpec`] for a figure sweep: the same stock
    /// defaults + profile iteration counts as [`Ctx::base_config`]
    /// (γ included, so a sweep without `auto_gamma` still runs the
    /// grid-searched rate of its base cell).
    pub fn sweep_base(&self, dataset: &str, loss: Loss, algo: AlgoConfig) -> ExperimentSpec {
        let cfg = self.base_config(dataset, loss, algo);
        ExperimentSpec::from_train_config(&cfg, DriverKind::Sequential, None, self.backend.name())
    }

    /// Executor options for one figure/sweep: `results/<exp>/` with this
    /// context's worker count, resume on, per-run curves on.
    pub fn sweep_opts(&self, exp: &str) -> SweepOptions {
        SweepOptions::new(self.out_dir.join(exp), self.workers)
    }

    /// Expand + execute a figure's [`SweepSpec`] under `results/<exp>/`.
    pub fn run_sweep(&self, spec: &SweepSpec, exp: &str) -> anyhow::Result<SweepOutcome> {
        crate::sweep::execute(spec, &self.sweep_opts(exp), None)
    }

    /// Run + persist one config; returns the outcome (with factors —
    /// what the phenotype tables need). Grid-shaped experiments should
    /// go through the sweep executor instead ([`Ctx::run_sweep`]); this
    /// stays for the single runs whose *factors* feed further analysis.
    pub fn run(
        &mut self,
        exp: &str,
        cfg: &TrainConfig,
        data: &Dataset,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        let fname = format!(
            "{exp}/{}_{}_{}_{}_k{}.csv",
            crate::engine::spec::fs_component(&cfg.dataset),
            cfg.loss.name(),
            cfg.algo.name,
            cfg.topology.name(),
            cfg.k
        );
        let spec =
            ExperimentSpec::from_train_config(cfg, DriverKind::Sequential, None, self.backend.name());
        let mut session = Session::new(spec)
            .observe(Box::new(CsvObserver::new(self.out_dir.join(fname))));
        session.run_on(data, self.backend.as_mut(), fms_reference)
    }
}
