//! Experiment harness: one driver per paper figure/table (DESIGN.md
//! per-experiment index). Each driver regenerates the corresponding
//! rows/series as printed tables + CSV files under `results/`.
//!
//! Two effort profiles:
//! * `quick` — reduced iterations/datasets; minutes, shape-checking runs
//!   (the default for `cargo bench`),
//! * `paper` — the paper's settings (500 iters/epoch, all datasets, both
//!   losses); tens of minutes.

pub mod ablate;
pub mod bench;
pub mod faults;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod tables;

use std::path::PathBuf;

use crate::data::Dataset;
use crate::engine::session::{CsvObserver, Session};
use crate::engine::spec::ExperimentSpec;
use crate::engine::{metrics::RunRecord, AlgoConfig, TrainConfig, TrainOutcome};
use crate::factor::FactorSet;
use crate::losses::Loss;
use crate::net::driver::DriverKind;
use crate::runtime::{default_artifact_dir, ComputeBackend, PjrtBackend};
use crate::tensor::synth::ValueKind;

/// Effort profile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    Quick,
    Paper,
}

impl Profile {
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        match s {
            "quick" => Ok(Profile::Quick),
            "paper" | "full" => Ok(Profile::Paper),
            other => anyhow::bail!("unknown profile '{other}' (quick|paper)"),
        }
    }

    pub fn iters_per_epoch(self) -> usize {
        match self {
            Profile::Quick => 150,
            Profile::Paper => 500, // paper §IV-A3
        }
    }

    pub fn epochs(self) -> usize {
        match self {
            Profile::Quick => 4,
            Profile::Paper => 10,
        }
    }

    pub fn datasets(self) -> Vec<&'static str> {
        match self {
            Profile::Quick => vec!["synthetic"],
            Profile::Paper => vec!["cms_like", "mimic_like", "synthetic"],
        }
    }

    pub fn losses(self) -> Vec<Loss> {
        match self {
            Profile::Quick => vec![Loss::Logit],
            Profile::Paper => vec![Loss::Logit, Loss::Ls],
        }
    }
}

/// Shared harness context: backend, output dir, profile.
pub struct Ctx {
    pub backend: Box<dyn ComputeBackend>,
    pub out_dir: PathBuf,
    pub profile: Profile,
}

impl Ctx {
    pub fn new(profile: Profile) -> anyhow::Result<Self> {
        let backend = Box::new(PjrtBackend::new(&default_artifact_dir())?);
        Ok(Ctx { backend, out_dir: PathBuf::from("results"), profile })
    }

    pub fn with_backend(backend: Box<dyn ComputeBackend>, profile: Profile) -> Self {
        Ctx { backend, out_dir: PathBuf::from("results"), profile }
    }

    /// Materialize (deterministically) the dataset for a source name +
    /// loss — synthetic generators and the `file:`/`csv:` loaders alike
    /// resolve through [`crate::registry::datasets`].
    pub fn dataset(&self, name: &str, loss: Loss) -> anyhow::Result<Dataset> {
        let vk = if loss == Loss::Ls { ValueKind::Gaussian } else { ValueKind::Binary };
        crate::data::load_dataset(name, vk)
    }

    /// Grid-searched learning rate per (dataset, loss) — powers of two, as
    /// the paper prescribes (§IV-A3). Values found by `cidertf tune`.
    pub fn gamma_for(dataset: &str, loss: Loss) -> f64 {
        // grid over powers of two, 2-epoch probes (logit diverges at 32;
        // 8 is comfortably inside the stable region for both losses)
        match (dataset, loss) {
            ("tiny", Loss::Logit) => 0.5,
            ("tiny", Loss::Ls) => 2.0,
            (_, Loss::Logit) => 8.0,
            (_, Loss::Ls) => 8.0,
        }
    }

    /// Base train config for a figure run.
    pub fn base_config(&self, dataset: &str, loss: Loss, algo: AlgoConfig) -> TrainConfig {
        let mut cfg = TrainConfig::new(dataset, loss, algo);
        cfg.gamma = Self::gamma_for(dataset, loss);
        // Nesterov momentum amplifies the steady-state step by ~1/(1-β);
        // rescale γ so momentum runs sit at the same effective rate the
        // grid search found (the paper grid-searches each algorithm).
        if let Some(beta) = cfg.algo.momentum {
            cfg.gamma *= 1.0 - beta;
        }
        cfg.iters_per_epoch = self.profile.iters_per_epoch();
        cfg.epochs = self.profile.epochs();
        cfg
    }

    /// Run + persist one config; returns the outcome. Every harness
    /// figure/table goes through here, so they all ride the
    /// [`Session`] pipeline: the CSV curve is written by a
    /// [`CsvObserver`] instead of inline engine bookkeeping.
    pub fn run(
        &mut self,
        exp: &str,
        cfg: &TrainConfig,
        data: &Dataset,
        fms_reference: Option<&FactorSet>,
    ) -> anyhow::Result<TrainOutcome> {
        let fname = format!(
            "{exp}/{}_{}_{}_{}_k{}.csv",
            crate::engine::spec::fs_component(&cfg.dataset),
            cfg.loss.name(),
            cfg.algo.name,
            cfg.topology.name(),
            cfg.k
        );
        let spec =
            ExperimentSpec::from_train_config(cfg, DriverKind::Sequential, None, self.backend.name());
        let mut session = Session::new(spec)
            .observe(Box::new(CsvObserver::new(self.out_dir.join(fname))));
        session.run_on(data, self.backend.as_mut(), fms_reference)
    }
}

/// Centralized-vs-decentralized K selection: centralized presets run K=1.
pub fn k_for(algo: &AlgoConfig, default_k: usize) -> usize {
    match algo.name.as_str() {
        "gcp" | "bras_cpd" | "centralized_cidertf" => 1,
        _ => default_k,
    }
}

/// Print a one-line summary for a finished run.
pub fn summarize(rec: &RunRecord) -> Vec<String> {
    vec![
        rec.algo.clone(),
        rec.k.to_string(),
        format!("{:.3e}", rec.final_loss()),
        format!("{:.1}", rec.wall_s),
        crate::util::benchkit::fmt_bytes(rec.total.bytes as f64),
        rec.total.messages.to_string(),
    ]
}

pub const SUMMARY_HEADER: [&str; 6] = ["algo", "K", "final_loss", "wall_s", "uplink", "msgs"];
