//! Fault-tolerance sweep: CiderTF under message loss, across topologies,
//! compressors, and execution drivers.
//!
//! This is the experiment the paper's decentralization argument implies
//! but never runs: if gossip removes the single point of failure, how
//! much network failure does the *protocol* absorb? Two
//! [`crate::sweep::SweepSpec`]s feed the parallel sweep executor: the
//! synchronous-simulator grid
//! (dataset × loss × compressor-variant × topology × drop rate,
//! `results/faults_sim/`) and the async rows for the headline
//! configuration (ideal / lossy / stragglers, `results/faults_async/`).
//! Every run is reported relative to its ideal-network twin, grouped
//! from the deterministic record stream — no per-cell run loop.
//!
//! Expected shape of the results (and what the tests assert in
//! miniature): moderate i.i.d. loss behaves like a smaller effective
//! consensus step — convergence degrades gracefully rather than
//! collapsing, because dropped compressed deltas leave peer estimates
//! stale, an error mode Thm. III.2's analysis already covers.

use std::collections::BTreeMap;

use super::Ctx;
use crate::compress::Compressor;
use crate::engine::metrics::RunRecord;
use crate::engine::spec::ExperimentSpec;
use crate::engine::AlgoConfig;
use crate::net::driver::DriverKind;
use crate::net::sim::FaultConfig;
use crate::topology::Topology;
use crate::util::benchkit::{fmt_bytes, Table};
use crate::util::csv::CsvWriter;

/// Drop rates the sweep grids over (0 = ideal-network baseline).
pub const DROP_RATES: [f64; 4] = [0.0, 0.1, 0.2, 0.4];

/// The synchronous-simulator grid as a sweep: compressor variants ride
/// the algo axis (keeping their `cidertf_<tag>_t<τ>` names), drop rates
/// ride the network axis (`None` = the ideal baseline).
pub fn sim_sweep(ctx: &Ctx, k: usize, tau: usize) -> crate::sweep::SweepSpec {
    let datasets = ctx.profile.datasets();
    let losses = ctx.profile.losses();
    let mut sweep = crate::sweep::SweepSpec::new(ctx.sweep_base(
        datasets[0],
        losses[0],
        AlgoConfig::cidertf(tau),
    ));
    sweep.datasets = datasets.iter().map(|s| s.to_string()).collect();
    sweep.losses = losses;
    sweep.algos = vec![
        algo_for(tau, Compressor::Sign, "sign"),
        algo_for(tau, Compressor::None, "dense"),
    ];
    sweep.topologies = vec![Topology::Ring, Topology::Star];
    sweep.networks = DROP_RATES
        .iter()
        .map(|&drop| (drop > 0.0).then(|| FaultConfig::lossy(drop)))
        .collect();
    sweep.drivers = vec![DriverKind::Sim];
    sweep.ks = vec![k];
    sweep.auto_gamma = true;
    sweep
}

/// The async rows as a sweep: the headline configuration under ideal,
/// lossy, and straggler networks (fault seeds inherit the master seed at
/// session time, exactly as the hand-rolled loop seeded them).
pub fn async_sweep(ctx: &Ctx, k: usize, tau: usize) -> crate::sweep::SweepSpec {
    let datasets = ctx.profile.datasets();
    let losses = ctx.profile.losses();
    let mut sweep = crate::sweep::SweepSpec::new(ctx.sweep_base(
        datasets[0],
        losses[0],
        AlgoConfig::cidertf(tau),
    ));
    sweep.datasets = datasets.iter().map(|s| s.to_string()).collect();
    sweep.losses = losses;
    sweep.networks =
        vec![None, Some(FaultConfig::lossy(0.2)), Some(FaultConfig::stragglers())];
    sweep.drivers = vec![DriverKind::Async];
    sweep.ks = vec![k];
    sweep.auto_gamma = true;
    sweep
}

/// Run both sweeps. `k` clients, τ = `tau` local rounds.
pub fn run(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<Vec<RunRecord>> {
    let sim = sim_sweep(ctx, k, tau);
    println!(
        "\n=== Faults: sim grid, K={k} tau={tau} — {} runs on {} workers ===",
        sim.len(),
        ctx.workers
    );
    let sim_out = ctx.run_sweep(&sim, "faults_sim")?;

    let asy = async_sweep(ctx, k, tau);
    println!(
        "\n=== Faults: async rows — {} runs on {} workers ===",
        asy.len(),
        ctx.workers
    );
    let asy_out = ctx.run_sweep(&asy, "faults_async")?;

    report(ctx, k, tau, &sim_out, &asy_out)?;

    let mut records: Vec<RunRecord> = sim_out.into_records();
    records.extend(asy_out.into_records());
    Ok(records)
}

/// Per (dataset, loss): print the comparison table and write the summary
/// CSV, every run against its ideal-network twin — pure post-processing
/// over the deterministic record stream.
fn report(
    ctx: &Ctx,
    k: usize,
    tau: usize,
    sim_out: &crate::sweep::SweepOutcome,
    asy_out: &crate::sweep::SweepOutcome,
) -> anyhow::Result<()> {
    let mut cells: Vec<(&ExperimentSpec, &RunRecord)> = Vec::new();
    for (spec, res) in sim_out.runs.iter().zip(sim_out.results.iter()) {
        cells.push((spec, &res.record));
    }
    for (spec, res) in asy_out.runs.iter().zip(asy_out.results.iter()) {
        cells.push((spec, &res.record));
    }
    // ideal twin per (dataset, loss, driver, algo, topology)
    let mut ideal: BTreeMap<TwinKey, f64> = BTreeMap::new();
    for (spec, rec) in &cells {
        if spec.fault.is_none() {
            ideal.insert(twin_key(spec, rec), rec.final_loss());
        }
    }

    for dataset in ctx.profile.datasets() {
        for loss in ctx.profile.losses() {
            let group: Vec<&(&ExperimentSpec, &RunRecord)> = cells
                .iter()
                .filter(|(_, r)| r.dataset == dataset && r.loss == loss.name())
                .collect();
            if group.is_empty() {
                continue;
            }
            println!("\n=== Faults: {dataset} / {} / K={k} tau={tau} ===", loss.name());
            let table = Table::new(&[
                "driver", "topology", "compressor", "drop", "final_loss", "vs_ideal",
                "delivered", "dropped", "uplink",
            ]);
            let csv_name = format!("faults/{dataset}_{}_summary.csv", loss.name());
            let csv_path = ctx.out_dir.join(&csv_name);
            let mut csv = CsvWriter::create(
                &csv_path,
                &[
                    "driver", "topology", "compressor", "drop_rate", "final_loss",
                    "ideal_loss", "delivered", "dropped", "stale", "offline_rounds",
                    "uplink_bytes", "virtual_s",
                ],
            )?;
            for (spec, rec) in group {
                let ideal_loss =
                    ideal.get(&twin_key(spec, rec)).copied().unwrap_or(f64::NAN);
                emit(&table, &mut csv, spec, rec, ideal_loss)?;
            }
            csv.flush()?;
            println!("  wrote {}", csv_path.display());
        }
    }
    Ok(())
}

/// The grouping key linking a faulty run to its ideal-network twin:
/// (dataset, loss, driver, algo, topology).
type TwinKey = (String, String, &'static str, String, String);

fn twin_key(spec: &ExperimentSpec, rec: &RunRecord) -> TwinKey {
    (
        rec.dataset.clone(),
        rec.loss.clone(),
        spec.driver.name(),
        rec.algo.clone(),
        rec.topology.clone(),
    )
}

/// CiderTF with the compressor swapped (the sweep's compressor axis).
fn algo_for(tau: usize, compressor: Compressor, cname: &str) -> AlgoConfig {
    let mut algo = AlgoConfig::cidertf(tau);
    algo.compressor = compressor;
    algo.name = format!("cidertf_{cname}_t{tau}");
    algo
}

/// Human label for the network column: `ideal`, `lossy`, `stragglers`.
fn fault_label(spec: &ExperimentSpec) -> &'static str {
    match &spec.fault {
        None => "ideal",
        Some(f) if f.drop_rate > 0.0 => "lossy",
        Some(f) if f.straggler_frac > 0.0 || !f.straggler_ids.is_empty() => "stragglers",
        Some(_) => "faulty",
    }
}

/// One table row + CSV row for a finished run.
fn emit(
    table: &Table,
    csv: &mut CsvWriter,
    spec: &ExperimentSpec,
    rec: &RunRecord,
    ideal_loss: f64,
) -> anyhow::Result<()> {
    let drop = spec.fault.as_ref().map(|f| f.drop_rate).unwrap_or(0.0);
    // the sim grid names the compressor in the algo; the async rows name
    // the scenario instead (what the hand-rolled loop printed)
    let compressor = if spec.driver == DriverKind::Async {
        fault_label(spec).to_string()
    } else if rec.algo.contains("_dense_") {
        "dense".to_string()
    } else {
        "sign".to_string()
    };
    let fl = rec.final_loss();
    let vs = if ideal_loss.is_finite() && ideal_loss != 0.0 { fl / ideal_loss } else { f64::NAN };
    table.row(&[
        spec.driver.name().to_string(),
        rec.topology.clone(),
        compressor.clone(),
        format!("{drop:.0e}"),
        format!("{fl:.3e}"),
        format!("{vs:.2}x"),
        rec.net.delivered.to_string(),
        rec.net.dropped.to_string(),
        fmt_bytes(rec.total.bytes as f64),
    ]);
    csv.row(&[
        spec.driver.name().to_string(),
        rec.topology.clone(),
        compressor,
        format!("{drop}"),
        format!("{fl:.6e}"),
        format!("{ideal_loss:.6e}"),
        rec.net.delivered.to_string(),
        rec.net.dropped.to_string(),
        rec.net.stale.to_string(),
        rec.net.offline_rounds.to_string(),
        rec.total.bytes.to_string(),
        format!("{:.2}", rec.wall_s),
    ])?;
    Ok(())
}
