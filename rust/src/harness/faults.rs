//! Fault-tolerance sweep: CiderTF under message loss, across topologies,
//! compressors, and execution drivers.
//!
//! This is the experiment the paper's decentralization argument implies
//! but never runs: if gossip removes the single point of failure, how
//! much network failure does the *protocol* absorb? The sweep grids
//! drop rate × topology × compressor through the synchronous network
//! simulator, adds async rows for the headline configuration, and reports
//! every run relative to its ideal-network twin.
//!
//! Expected shape of the results (and what the tests assert in
//! miniature): moderate i.i.d. loss behaves like a smaller effective
//! consensus step — convergence degrades gracefully rather than
//! collapsing, because dropped compressed deltas leave peer estimates
//! stale, an error mode Thm. III.2's analysis already covers.

use super::Ctx;
use crate::compress::Compressor;
use crate::engine::metrics::RunRecord;
use crate::engine::session::Session;
use crate::engine::spec::ExperimentSpec;
use crate::engine::{AlgoConfig, TrainConfig};
use crate::net::driver::DriverKind;
use crate::net::sim::FaultConfig;
use crate::topology::Topology;
use crate::util::benchkit::{fmt_bytes, Table};
use crate::util::csv::CsvWriter;

/// Drop rates the sweep grids over (0 = ideal-network baseline).
pub const DROP_RATES: [f64; 4] = [0.0, 0.1, 0.2, 0.4];

/// Run the sweep. `k` clients, τ = `tau` local rounds.
pub fn run(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<Vec<RunRecord>> {
    let mut records = Vec::new();
    let topologies = [Topology::Ring, Topology::Star];
    let compressors = [(Compressor::Sign, "sign"), (Compressor::None, "dense")];

    for dataset in ctx.profile.datasets() {
        for loss in ctx.profile.losses() {
            println!("\n=== Faults: {dataset} / {} / K={k} tau={tau} ===", loss.name());
            let data = ctx.dataset(dataset, loss)?;
            let table = Table::new(&[
                "driver", "topology", "compressor", "drop", "final_loss", "vs_ideal",
                "delivered", "dropped", "uplink",
            ]);
            let csv_name = format!("faults/{dataset}_{}_summary.csv", loss.name());
            let csv_path = ctx.out_dir.join(csv_name);
            let mut csv = CsvWriter::create(
                &csv_path,
                &[
                    "driver", "topology", "compressor", "drop_rate", "final_loss",
                    "ideal_loss", "delivered", "dropped", "stale", "offline_rounds",
                    "uplink_bytes", "virtual_s",
                ],
            )?;

            for topo in topologies {
                for (compressor, cname) in compressors {
                    let mut ideal_loss = f64::NAN;
                    for drop in DROP_RATES {
                        let algo = algo_for(tau, compressor, cname);
                        let mut cfg = ctx.base_config(dataset, loss, algo);
                        cfg.k = k;
                        cfg.topology = topo;
                        let fault = (drop > 0.0)
                            .then(|| FaultConfig::lossy(drop).with_seed(cfg.seed));
                        let out = run_session(ctx, &cfg, DriverKind::Sim, fault, &data)?;
                        if drop == 0.0 {
                            ideal_loss = out.record.final_loss();
                        }
                        emit(&table, &mut csv, "sim", topo, cname, drop, ideal_loss, &out.record)?;
                        records.push(out.record);
                    }
                }
            }

            // async rows: the headline config, ideal + lossy + stragglers
            let mut ideal_loss = f64::NAN;
            for (label, fault) in [
                ("ideal", None),
                ("lossy", Some(FaultConfig::lossy(0.2))),
                ("stragglers", Some(FaultConfig::stragglers())),
            ] {
                let mut cfg = ctx.base_config(dataset, loss, AlgoConfig::cidertf(tau));
                cfg.k = k;
                let drop = fault.as_ref().map(|f| f.drop_rate).unwrap_or(0.0);
                let fault = fault.map(|f| f.with_seed(cfg.seed));
                let out = run_session(ctx, &cfg, DriverKind::Async, fault, &data)?;
                if label == "ideal" {
                    ideal_loss = out.record.final_loss();
                }
                let rec = &out.record;
                emit(&table, &mut csv, "async", Topology::Ring, label, drop, ideal_loss, rec)?;
                records.push(out.record);
            }
            csv.flush()?;
            println!("  wrote {}", csv_path.display());
        }
    }
    Ok(records)
}

/// One sweep cell through the [`Session`] pipeline (the sweep names the
/// driver and fault envelope explicitly; the spec carries both).
fn run_session(
    ctx: &mut Ctx,
    cfg: &TrainConfig,
    driver: DriverKind,
    fault: Option<FaultConfig>,
    data: &crate::data::Dataset,
) -> anyhow::Result<crate::engine::TrainOutcome> {
    let spec = ExperimentSpec::from_train_config(cfg, driver, fault, ctx.backend.name());
    Session::new(spec).run_on(data, ctx.backend.as_mut(), None)
}

/// CiderTF with the compressor swapped (the sweep's compressor axis).
fn algo_for(tau: usize, compressor: Compressor, cname: &str) -> AlgoConfig {
    let mut algo = AlgoConfig::cidertf(tau);
    algo.compressor = compressor;
    algo.name = format!("cidertf_{cname}_t{tau}");
    algo
}

/// One table row + CSV row for a finished run.
#[allow(clippy::too_many_arguments)]
fn emit(
    table: &Table,
    csv: &mut CsvWriter,
    driver: &str,
    topo: Topology,
    compressor: &str,
    drop: f64,
    ideal_loss: f64,
    rec: &RunRecord,
) -> anyhow::Result<()> {
    let fl = rec.final_loss();
    let vs = if ideal_loss.is_finite() && ideal_loss != 0.0 { fl / ideal_loss } else { f64::NAN };
    table.row(&[
        driver.to_string(),
        topo.name().to_string(),
        compressor.to_string(),
        format!("{drop:.0e}"),
        format!("{fl:.3e}"),
        format!("{vs:.2}x"),
        rec.net.delivered.to_string(),
        rec.net.dropped.to_string(),
        fmt_bytes(rec.total.bytes as f64),
    ]);
    csv.row(&[
        driver.to_string(),
        topo.name().to_string(),
        compressor.to_string(),
        format!("{drop}"),
        format!("{fl:.6e}"),
        format!("{ideal_loss:.6e}"),
        rec.net.delivered.to_string(),
        rec.net.dropped.to_string(),
        rec.net.stale.to_string(),
        rec.net.offline_rounds.to_string(),
        rec.total.bytes.to_string(),
        format!("{:.2}", rec.wall_s),
    ])?;
    Ok(())
}
