//! Fig. 4 — topology study: CiderTF on ring vs star, loss vs time and vs
//! communication, per dataset and loss. The paper's finding: convergence
//! is topology-insensitive, but star costs fewer total uplink bytes.
//!
//! One [`SweepSpec`]: dataset × loss × topology, executed concurrently
//! by the sweep engine (`results/fig4/`).

use super::Ctx;
use crate::engine::metrics::RunRecord;
use crate::engine::AlgoConfig;
use crate::sweep::SweepSpec;
use crate::topology::Topology;

/// The figure as a sweep.
pub fn sweep(ctx: &Ctx, k: usize, tau: usize) -> SweepSpec {
    let datasets = ctx.profile.datasets();
    let losses = ctx.profile.losses();
    let mut sweep =
        SweepSpec::new(ctx.sweep_base(datasets[0], losses[0], AlgoConfig::cidertf(tau)));
    sweep.datasets = datasets.iter().map(|s| s.to_string()).collect();
    sweep.losses = losses;
    sweep.ks = vec![k];
    sweep.topologies = vec![Topology::Ring, Topology::Star];
    sweep.auto_gamma = true;
    sweep
}

pub fn run(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<Vec<RunRecord>> {
    let sweep = sweep(ctx, k, tau);
    println!(
        "\n=== Fig.4: ring vs star, K={k} tau={tau} — {} runs on {} workers ===",
        sweep.len(),
        ctx.workers
    );
    let records = ctx.run_sweep(&sweep, "fig4")?.into_records();
    // topology is the innermost axis: records arrive as (ring, star)
    // pairs per (dataset, loss)
    for pair in records.chunks(2) {
        let (ring, star) = (&pair[0], &pair[1]);
        let loss_gap = (ring.final_loss() - star.final_loss()).abs()
            / ring.final_loss().max(star.final_loss());
        println!(
            "  {}/{}: star/ring uplink ratio = {:.3} (paper: star < ring); loss gap = {:.1}%",
            ring.dataset,
            ring.loss,
            star.total.bytes as f64 / ring.total.bytes.max(1) as f64,
            100.0 * loss_gap
        );
    }
    Ok(records)
}
