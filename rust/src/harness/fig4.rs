//! Fig. 4 — topology study: CiderTF on ring vs star, loss vs time and vs
//! communication, per dataset and loss. The paper's finding: convergence
//! is topology-insensitive, but star costs fewer total uplink bytes.

use super::{summarize, Ctx};
use crate::engine::metrics::RunRecord;
use crate::engine::AlgoConfig;
use crate::topology::Topology;
use crate::util::benchkit::Table;

pub fn run(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<Vec<RunRecord>> {
    let mut records = Vec::new();
    for dataset in ctx.profile.datasets() {
        for loss in ctx.profile.losses() {
            println!("\n=== Fig.4: {dataset} / {} / K={k} ring vs star ===", loss.name());
            let data = ctx.dataset(dataset, loss)?;
            let table = Table::new(&["topology", "K", "final_loss", "wall_s", "uplink", "msgs"]);
            let mut pair = Vec::new();
            for topo in [Topology::Ring, Topology::Star] {
                let mut cfg = ctx.base_config(dataset, loss, AlgoConfig::cidertf(tau));
                cfg.k = k;
                cfg.topology = topo;
                let out = ctx.run("fig4", &cfg, &data, None)?;
                let mut row = summarize(&out.record);
                row[0] = topo.name().to_string();
                table.row(&row);
                pair.push(out.record);
            }
            let (ring, star) = (&pair[0], &pair[1]);
            let loss_gap = (ring.final_loss() - star.final_loss()).abs()
                / ring.final_loss().max(star.final_loss());
            println!(
                "  star/ring uplink ratio = {:.3} (paper: star < ring); loss gap = {:.1}%",
                star.total.bytes as f64 / ring.total.bytes.max(1) as f64,
                100.0 * loss_gap
            );
            records.extend(pair);
        }
    }
    Ok(records)
}
