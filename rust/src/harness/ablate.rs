//! Extension ablations over CiderTF's own design knobs (DESIGN.md §Perf /
//! "ablation benches for the design choices"): consensus step size ϱ, the
//! local-round period τ, and the event-trigger schedule (λ₀ multiplier,
//! growth factor α) — none of which the paper sweeps explicitly.

use super::{summarize, Ctx, SUMMARY_HEADER};
use crate::engine::AlgoConfig;
use crate::losses::Loss;
use crate::util::benchkit::Table;

/// ϱ sweep: too small mixes slowly, too large overshoots the compressed
/// consensus (CHOCO-style estimates tolerate ϱ <= 1).
pub fn rho_sweep(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<()> {
    let dataset = ctx.profile.datasets()[0];
    let loss = Loss::Logit;
    let data = ctx.dataset(dataset, loss)?;
    println!("\n=== Ablation: consensus step size rho (K={k}, tau={tau}, {dataset}) ===");
    let table = Table::new(&SUMMARY_HEADER);
    for rho in [0.1, 0.3, 0.5, 0.7, 0.9, 1.0] {
        let mut algo = AlgoConfig::cidertf(tau);
        algo.rho = rho;
        algo.name = format!("cidertf_rho{rho}");
        let mut cfg = ctx.base_config(dataset, loss, algo);
        cfg.k = k;
        let out = ctx.run("ablate", &cfg, &data, None)?;
        table.row(&summarize(&out.record));
    }
    Ok(())
}

/// τ sweep beyond the paper's {2,4,6,8}: the comm/convergence frontier.
pub fn tau_sweep(ctx: &mut Ctx, k: usize) -> anyhow::Result<()> {
    let dataset = ctx.profile.datasets()[0];
    let loss = Loss::Logit;
    let data = ctx.dataset(dataset, loss)?;
    println!("\n=== Ablation: local-round period tau (K={k}, {dataset}) ===");
    let table = Table::new(&SUMMARY_HEADER);
    for tau in [1usize, 2, 4, 8, 16, 32] {
        let mut cfg = ctx.base_config(dataset, loss, AlgoConfig::cidertf(tau));
        cfg.k = k;
        let out = ctx.run("ablate", &cfg, &data, None)?;
        table.row(&summarize(&out.record));
    }
    println!("  (expect: bytes fall ~1/tau; convergence degrades gracefully at large tau)");
    Ok(())
}

/// Event-trigger schedule sweep: λ₀ scale and growth α (paper fixes
/// λ₀ = 1/γ and grid-searches α in [1,2]).
pub fn trigger_sweep(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<()> {
    let dataset = ctx.profile.datasets()[0];
    let loss = Loss::Logit;
    let data = ctx.dataset(dataset, loss)?;
    println!("\n=== Ablation: event-trigger schedule (K={k}, tau={tau}, {dataset}) ===");
    let table = Table::new(&["lambda0_scale", "alpha", "final_loss", "uplink", "suppressed%"]);
    for (scale, alpha) in
        [(0.0f64, 1.0f64), (0.5, 1.3), (1.0, 1.0), (1.0, 1.3), (1.0, 2.0), (4.0, 1.3)]
    {
        let mut algo = AlgoConfig::cidertf(tau);
        algo.name = format!("cidertf_trig_s{scale}_a{alpha}");
        if scale == 0.0 {
            algo.event_triggered = false; // trigger disabled baseline
        }
        let mut cfg = ctx.base_config(dataset, loss, algo);
        cfg.k = k;
        cfg.trigger_lambda0_scale = scale.max(f64::MIN_POSITIVE);
        cfg.trigger_alpha = alpha;
        let out = ctx.run("ablate", &cfg, &data, None)?;
        let sup = out.record.total.suppressed as f64
            / (out.record.total.suppressed + out.record.total.triggered).max(1) as f64;
        table.row(&[
            format!("{scale}"),
            format!("{alpha}"),
            format!("{:.3e}", out.record.final_loss()),
            crate::util::benchkit::fmt_bytes(out.record.total.bytes as f64),
            format!("{:.1}%", 100.0 * sup),
        ]);
    }
    Ok(())
}
