//! Extension ablations over CiderTF's own design knobs (DESIGN.md §Perf /
//! "ablation benches for the design choices"): consensus step size ϱ, the
//! local-round period τ, and the event-trigger schedule (λ₀ multiplier,
//! growth factor α) — none of which the paper sweeps explicitly.
//!
//! Each ablation is one [`SweepSpec`] fed to the parallel sweep executor
//! (`results/ablate_rho/`, `ablate_tau/`, `ablate_trigger/`).

use super::Ctx;
use crate::engine::AlgoConfig;
use crate::losses::Loss;
use crate::sweep::{SweepSpec, TriggerPoint};
use crate::util::benchkit::Table;

/// The ϱ grid (CHOCO-style estimates tolerate ϱ <= 1).
pub const RHOS: [f64; 6] = [0.1, 0.3, 0.5, 0.7, 0.9, 1.0];

/// The τ grid beyond the paper's {2,4,6,8}.
pub const TAUS: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// The (λ₀ scale, α) grid; scale 0 = trigger-disabled baseline.
pub const TRIGGERS: [(f64, f64); 6] =
    [(0.0, 1.0), (0.5, 1.3), (1.0, 1.0), (1.0, 1.3), (1.0, 2.0), (4.0, 1.3)];

/// ϱ sweep: too small mixes slowly, too large overshoots the compressed
/// consensus — ϱ rides the algo axis (it is an `AlgoConfig` field).
pub fn rho_sweep_spec(ctx: &Ctx, k: usize, tau: usize) -> SweepSpec {
    let dataset = ctx.profile.datasets()[0];
    let mut sweep = SweepSpec::new(ctx.sweep_base(dataset, Loss::Logit, AlgoConfig::cidertf(tau)));
    sweep.algos = RHOS
        .iter()
        .map(|&rho| {
            let mut algo = AlgoConfig::cidertf(tau);
            algo.rho = rho;
            algo.name = format!("cidertf_rho{rho}");
            algo
        })
        .collect();
    sweep.ks = vec![k];
    sweep.auto_gamma = true;
    sweep
}

/// ϱ sweep: run and print.
pub fn rho_sweep(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<()> {
    let sweep = rho_sweep_spec(ctx, k, tau);
    println!(
        "\n=== Ablation: consensus step size rho (K={k}, tau={tau}, {}) — {} runs on {} workers ===",
        sweep.base.dataset,
        sweep.len(),
        ctx.workers
    );
    ctx.run_sweep(&sweep, "ablate_rho")?;
    Ok(())
}

/// τ sweep: the comm/convergence frontier, τ as a sweep axis.
pub fn tau_sweep_spec(ctx: &Ctx, k: usize) -> SweepSpec {
    let dataset = ctx.profile.datasets()[0];
    let mut sweep = SweepSpec::new(ctx.sweep_base(dataset, Loss::Logit, AlgoConfig::cidertf(4)));
    sweep.algos = vec![AlgoConfig::cidertf(4)];
    sweep.taus = TAUS.to_vec();
    sweep.ks = vec![k];
    sweep.auto_gamma = true;
    sweep
}

/// τ sweep: run and print.
pub fn tau_sweep(ctx: &mut Ctx, k: usize) -> anyhow::Result<()> {
    let sweep = tau_sweep_spec(ctx, k);
    println!(
        "\n=== Ablation: local-round period tau (K={k}, {}) — {} runs on {} workers ===",
        sweep.base.dataset,
        sweep.len(),
        ctx.workers
    );
    ctx.run_sweep(&sweep, "ablate_tau")?;
    println!("  (expect: bytes fall ~1/tau; convergence degrades gracefully at large tau)");
    Ok(())
}

/// Event-trigger schedule sweep: λ₀ scale and growth α on the trigger
/// axis (paper fixes λ₀ = 1/γ and grid-searches α in [1,2]).
pub fn trigger_sweep_spec(ctx: &Ctx, k: usize, tau: usize) -> SweepSpec {
    let dataset = ctx.profile.datasets()[0];
    let mut sweep = SweepSpec::new(ctx.sweep_base(dataset, Loss::Logit, AlgoConfig::cidertf(tau)));
    sweep.ks = vec![k];
    sweep.auto_gamma = true;
    sweep.triggers = TRIGGERS
        .iter()
        .map(|&(lambda0_scale, alpha)| TriggerPoint { lambda0_scale, alpha })
        .collect();
    sweep
}

/// Trigger sweep: run and print the suppression table.
pub fn trigger_sweep(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<()> {
    let sweep = trigger_sweep_spec(ctx, k, tau);
    println!(
        "\n=== Ablation: event-trigger schedule (K={k}, tau={tau}, {}) — {} runs on {} workers ===",
        sweep.base.dataset,
        sweep.len(),
        ctx.workers
    );
    let outcome = ctx.run_sweep(&sweep, "ablate_trigger")?;
    let table = Table::new(&["lambda0_scale", "alpha", "final_loss", "uplink", "suppressed%"]);
    for ((scale, alpha), res) in TRIGGERS.iter().zip(outcome.results.iter()) {
        let rec = &res.record;
        let sup = rec.total.suppressed as f64
            / (rec.total.suppressed + rec.total.triggered).max(1) as f64;
        table.row(&[
            format!("{scale}"),
            format!("{alpha}"),
            format!("{:.3e}", rec.final_loss()),
            crate::util::benchkit::fmt_bytes(rec.total.bytes as f64),
            format!("{:.1}%", 100.0 * sup),
        ]);
    }
    Ok(())
}
