//! Fig. 5 — scalability: CiderTF with K = 8, 16, 32 workers at τ = 4, 8 on
//! the MIMIC-like dataset (Bernoulli-logit), loss vs time and vs bytes.
//! Paper finding: computation time scales down with K (each worker holds
//! 1/K of the patients) while total communication grows with K.

use super::{summarize, Ctx, SUMMARY_HEADER};
use crate::engine::metrics::RunRecord;
use crate::engine::AlgoConfig;
use crate::losses::Loss;
use crate::util::benchkit::Table;

pub fn run(ctx: &mut Ctx, ks: &[usize], taus: &[usize]) -> anyhow::Result<Vec<RunRecord>> {
    let dataset = if ctx.profile.datasets().contains(&"mimic_like") { "mimic_like" } else { ctx.profile.datasets()[0] };
    let loss = Loss::Logit;
    let data = ctx.dataset(dataset, loss)?;
    println!("\n=== Fig.5: scalability on {dataset} / logit ===");
    let table = Table::new(&SUMMARY_HEADER);
    let mut records = Vec::new();
    for &tau in taus {
        for &k in ks {
            let mut cfg = ctx.base_config(dataset, loss, AlgoConfig::cidertf(tau));
            cfg.k = k;
            let out = ctx.run("fig5", &cfg, &data, None)?;
            table.row(&summarize(&out.record));
            records.push(out.record);
        }
    }
    // The in-process network executes clients sequentially; the paper's
    // Fig. 5 time axis is parallel wall-clock, i.e. ~wall/K here.
    for r in &records {
        println!(
            "  K={:<3} tau={}: simulated-parallel time ~{:.1}s (wall {:.1}s / K)",
            r.k,
            r.tau,
            r.wall_s / r.k as f64,
            r.wall_s
        );
    }
    // paper's trade-off: larger K -> more uplink bytes
    for &tau in taus {
        let by_k: Vec<&RunRecord> =
            records.iter().filter(|r| r.tau == tau).collect();
        if by_k.len() >= 2 {
            let first = by_k.first().unwrap();
            let last = by_k.last().unwrap();
            println!(
                "  tau={tau}: bytes K={} -> K={} grew {:.2}x (paper: grows with K)",
                first.k,
                last.k,
                last.total.bytes as f64 / first.total.bytes.max(1) as f64
            );
        }
    }
    Ok(records)
}
