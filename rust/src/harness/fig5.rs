//! Fig. 5 — scalability: CiderTF with K = 8, 16, 32 workers at τ = 4, 8 on
//! the MIMIC-like dataset (Bernoulli-logit), loss vs time and vs bytes.
//! Paper finding: computation time scales down with K (each worker holds
//! 1/K of the patients) while total communication grows with K.
//!
//! One [`SweepSpec`]: τ × K on one dataset, executed concurrently by the
//! sweep engine (`results/fig5/`).

use super::Ctx;
use crate::engine::metrics::RunRecord;
use crate::engine::AlgoConfig;
use crate::losses::Loss;
use crate::sweep::SweepSpec;

/// The figure as a sweep (τ rides the algo axis so each cell keeps the
/// paper's `cidertf_t<τ>` name; K is the inner axis).
pub fn sweep(ctx: &Ctx, ks: &[usize], taus: &[usize]) -> SweepSpec {
    let dataset = if ctx.profile.datasets().contains(&"mimic_like") {
        "mimic_like"
    } else {
        ctx.profile.datasets()[0]
    };
    let mut sweep =
        SweepSpec::new(ctx.sweep_base(dataset, Loss::Logit, AlgoConfig::cidertf(4)));
    sweep.algos = taus.iter().map(|&t| AlgoConfig::cidertf(t)).collect();
    sweep.ks = ks.to_vec();
    sweep.auto_gamma = true;
    sweep
}

pub fn run(ctx: &mut Ctx, ks: &[usize], taus: &[usize]) -> anyhow::Result<Vec<RunRecord>> {
    anyhow::ensure!(!ks.is_empty() && !taus.is_empty(), "fig5 needs --ks and --taus");
    let sweep = sweep(ctx, ks, taus);
    println!(
        "\n=== Fig.5: scalability (logit) — {} runs on {} workers ===",
        sweep.len(),
        ctx.workers
    );
    let records = ctx.run_sweep(&sweep, "fig5")?.into_records();
    // The in-process network executes clients sequentially; the paper's
    // Fig. 5 time axis is parallel wall-clock, i.e. ~wall/K here.
    for r in &records {
        println!(
            "  K={:<3} tau={}: simulated-parallel time ~{:.1}s (wall {:.1}s / K)",
            r.k,
            r.tau,
            r.wall_s / r.k as f64,
            r.wall_s
        );
    }
    // paper's trade-off: larger K -> more uplink bytes
    for &tau in taus {
        let by_k: Vec<&RunRecord> = records.iter().filter(|r| r.tau == tau).collect();
        if by_k.len() >= 2 {
            let first = by_k.first().unwrap();
            let last = by_k.last().unwrap();
            println!(
                "  tau={tau}: bytes K={} -> K={} grew {:.2}x (paper: grows with K)",
                first.k,
                last.k,
                last.total.bytes as f64 / first.total.bytes.max(1) as f64
            );
        }
    }
    Ok(records)
}
