//! Fig. 6 + Table II — ablation of the four communication-reduction
//! levels: measured uplink bytes per epoch for D-PSGD, D-PSGDbras,
//! D-PSGD+signSGD, D-PSGDbras+signSGD, SPARQ-SGD, CiderTF, plus each
//! configuration's analytical compression ratio.

use super::{k_for, Ctx};
use crate::engine::metrics::RunRecord;
use crate::engine::AlgoConfig;
use crate::losses::Loss;
use crate::util::benchkit::{fmt_bytes, Table};

pub fn roster(tau: usize) -> Vec<AlgoConfig> {
    vec![
        AlgoConfig::dpsgd(),
        AlgoConfig::dpsgd_bras(),
        AlgoConfig::dpsgd_sign(),
        AlgoConfig::dpsgd_bras_sign(),
        AlgoConfig::sparq_sgd(tau),
        AlgoConfig::cidertf(tau),
    ]
}

pub fn run(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<Vec<RunRecord>> {
    let dataset = if ctx.profile.datasets().contains(&"mimic_like") { "mimic_like" } else { ctx.profile.datasets()[0] };
    let loss = Loss::Logit;
    let data = ctx.dataset(dataset, loss)?;
    let d_order = data.tensor.dims.len();
    println!("\n=== Fig.6 / Table II: ablation on {dataset} / logit / K={k} ===");
    let table = Table::new(&[
        "algo",
        "bytes/epoch",
        "measured_red.",
        "analytic_ratio",
        "final_loss",
    ]);
    let mut records = Vec::new();
    let mut dpsgd_bpe = 0.0f64;
    for algo in roster(tau) {
        let analytic = algo.table2_ratio(d_order);
        let mut cfg = ctx.base_config(dataset, loss, algo);
        cfg.k = k_for(&cfg.algo, k);
        let out = ctx.run("fig6", &cfg, &data, None)?;
        let bpe = out.record.total.bytes as f64 / cfg.epochs as f64;
        if out.record.algo == "dpsgd" {
            dpsgd_bpe = bpe;
        }
        let measured = if dpsgd_bpe > 0.0 { 1.0 - bpe / dpsgd_bpe } else { 0.0 };
        table.row(&[
            out.record.algo.clone(),
            fmt_bytes(bpe),
            format!("{:.4}%", 100.0 * measured),
            format!("{:.4}%", 100.0 * analytic),
            format!("{:.3e}", out.record.final_loss()),
        ]);
        records.push(out.record);
    }
    println!(
        "  (paper Fig.6: compression is the largest lever ~96.9%, block randomization -> ~{:.1}%, \
         periodic+event -> up to ~97-99.99% combined)",
        100.0 * (1.0 - 1.0 / d_order as f64)
    );
    Ok(records)
}
