//! Fig. 6 + Table II — ablation of the four communication-reduction
//! levels: measured uplink bytes per epoch for D-PSGD, D-PSGDbras,
//! D-PSGD+signSGD, D-PSGDbras+signSGD, SPARQ-SGD, CiderTF, plus each
//! configuration's analytical compression ratio.
//!
//! One [`SweepSpec`] over the ablation roster, executed concurrently by
//! the sweep engine (`results/fig6/`); the measured-vs-analytic table is
//! computed from the returned records.

use super::Ctx;
use crate::engine::metrics::RunRecord;
use crate::engine::AlgoConfig;
use crate::losses::Loss;
use crate::sweep::SweepSpec;
use crate::util::benchkit::{fmt_bytes, Table};

pub fn roster(tau: usize) -> Vec<AlgoConfig> {
    vec![
        AlgoConfig::dpsgd(),
        AlgoConfig::dpsgd_bras(),
        AlgoConfig::dpsgd_sign(),
        AlgoConfig::dpsgd_bras_sign(),
        AlgoConfig::sparq_sgd(tau),
        AlgoConfig::cidertf(tau),
    ]
}

/// The ablation grid as a sweep.
pub fn sweep(ctx: &Ctx, k: usize, tau: usize) -> SweepSpec {
    let dataset = if ctx.profile.datasets().contains(&"mimic_like") {
        "mimic_like"
    } else {
        ctx.profile.datasets()[0]
    };
    let mut sweep =
        SweepSpec::new(ctx.sweep_base(dataset, Loss::Logit, AlgoConfig::cidertf(tau)));
    sweep.algos = roster(tau);
    sweep.ks = vec![k];
    sweep.auto_gamma = true;
    sweep
}

pub fn run(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<Vec<RunRecord>> {
    let sweep = sweep(ctx, k, tau);
    println!(
        "\n=== Fig.6 / Table II: ablation on {}, K={k} tau={tau} — {} runs on {} workers ===",
        sweep.base.dataset,
        sweep.len(),
        ctx.workers
    );
    let epochs = sweep.base.epochs;
    let outcome = ctx.run_sweep(&sweep, "fig6")?;
    // the analytic Table II column needs the tensor order; reuse the
    // executor's Arc-loaded dataset instead of synthesizing it again
    let d_order = outcome.dataset(&sweep.base.dataset, Loss::Logit)?.tensor.dims.len();
    let records = outcome.into_records();

    let table = Table::new(&[
        "algo",
        "bytes/epoch",
        "measured_red.",
        "analytic_ratio",
        "final_loss",
    ]);
    let mut dpsgd_bpe = 0.0f64;
    for (algo, rec) in roster(tau).iter().zip(records.iter()) {
        let analytic = algo.table2_ratio(d_order);
        let bpe = rec.total.bytes as f64 / epochs as f64;
        if rec.algo == "dpsgd" {
            dpsgd_bpe = bpe;
        }
        let measured = if dpsgd_bpe > 0.0 { 1.0 - bpe / dpsgd_bpe } else { 0.0 };
        table.row(&[
            rec.algo.clone(),
            fmt_bytes(bpe),
            format!("{:.4}%", 100.0 * measured),
            format!("{:.4}%", 100.0 * analytic),
            format!("{:.3e}", rec.final_loss()),
        ]);
    }
    println!(
        "  (paper Fig.6: compression is the largest lever ~96.9%, block randomization -> ~{:.1}%, \
         periodic+event -> up to ~97-99.99% combined)",
        100.0 * (1.0 - 1.0 / d_order as f64)
    );
    Ok(records)
}
