//! Fig. 7 — Factor Match Score vs time and vs communication: how fast the
//! decentralized methods' factors approach the centralized BrasCPD
//! reference factors. Paper finding: CiderTF reaches the highest FMS with
//! the least time and bytes among the decentralized methods.

use super::{k_for, Ctx};
use crate::engine::metrics::RunRecord;
use crate::engine::AlgoConfig;
use crate::losses::Loss;
use crate::util::benchkit::{fmt_bytes, Table};

pub fn run(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<Vec<RunRecord>> {
    let dataset = if ctx.profile.datasets().contains(&"mimic_like") { "mimic_like" } else { ctx.profile.datasets()[0] };
    let loss = Loss::Ls; // BrasCPD, the FMS comparator, is a least-squares method
    let data = ctx.dataset(dataset, loss)?;
    println!("\n=== Fig.7: FMS vs centralized BrasCPD on {dataset} / ls ===");

    // reference factors: centralized BrasCPD run (paper's comparator)
    let mut ref_cfg = ctx.base_config(dataset, loss, AlgoConfig::bras_cpd());
    ref_cfg.k = 1;
    ref_cfg.epochs = ctx.profile.epochs() * 2; // converge the reference further
    let reference = ctx.run("fig7", &ref_cfg, &data, None)?;

    let table = Table::new(&["algo", "final_FMS", "wall_s", "uplink"]);
    let mut records = Vec::new();
    let d_order = data.tensor.dims.len();
    for algo in [AlgoConfig::cidertf(tau), AlgoConfig::dpsgd(), AlgoConfig::dpsgd_bras()] {
        let mut cfg = ctx.base_config(dataset, loss, algo);
        cfg.k = k_for(&cfg.algo, k);
        // Block-randomized methods evaluate 1/D of the gradients per
        // iteration; the paper's FMS curves are at convergence, so match
        // total gradient work (FMS tracks convergence level).
        if cfg.algo.block_random {
            cfg.epochs *= d_order;
        }
        let out = ctx.run("fig7", &cfg, &data, Some(&reference.factors))?;
        let final_fms = out.record.points.last().and_then(|p| p.fms).unwrap_or(0.0);
        table.row(&[
            out.record.algo.clone(),
            format!("{final_fms:.4}"),
            format!("{:.1}", out.record.wall_s),
            fmt_bytes(out.record.total.bytes as f64),
        ]);
        records.push(out.record);
    }
    // paper check: CiderTF reaches its final FMS with far fewer bytes
    if let (Some(cider), Some(dpsgd)) = (
        records.iter().find(|r| r.algo.starts_with("cidertf")),
        records.iter().find(|r| r.algo == "dpsgd"),
    ) {
        println!(
            "  bytes to final FMS: cidertf {} vs dpsgd {} ({}x reduction)",
            fmt_bytes(cider.total.bytes as f64),
            fmt_bytes(dpsgd.total.bytes as f64),
            dpsgd.total.bytes.max(1) / cider.total.bytes.max(1)
        );
    }
    Ok(records)
}
