//! Fig. 7 — Factor Match Score vs time and vs communication: how fast the
//! decentralized methods' factors approach the centralized BrasCPD
//! reference factors. Paper finding: CiderTF reaches the highest FMS with
//! the least time and bytes among the decentralized methods.
//!
//! The centralized reference runs once (its *factors* seed the FMS
//! comparison); the decentralized roster is then one [`SweepSpec`]
//! executed concurrently with the reference factors shared read-only
//! across workers (`results/fig7/`).

use std::sync::Arc;

use super::Ctx;
use crate::engine::metrics::RunRecord;
use crate::engine::AlgoConfig;
use crate::losses::Loss;
use crate::sweep::SweepSpec;
use crate::util::benchkit::{fmt_bytes, Table};

/// The decentralized FMS roster as a sweep. Block-randomized methods
/// evaluate 1/D of the gradients per iteration; the paper's FMS curves
/// are at convergence, so `block_random_epochs_scale = d_order` matches
/// total gradient work (FMS tracks convergence level).
pub fn sweep(ctx: &Ctx, k: usize, tau: usize, d_order: usize) -> SweepSpec {
    let dataset = if ctx.profile.datasets().contains(&"mimic_like") {
        "mimic_like"
    } else {
        ctx.profile.datasets()[0]
    };
    // BrasCPD, the FMS comparator, is a least-squares method
    let mut sweep = SweepSpec::new(ctx.sweep_base(dataset, Loss::Ls, AlgoConfig::cidertf(tau)));
    sweep.algos = vec![AlgoConfig::cidertf(tau), AlgoConfig::dpsgd(), AlgoConfig::dpsgd_bras()];
    sweep.ks = vec![k];
    sweep.centralized_k1 = true;
    sweep.auto_gamma = true;
    sweep.block_random_epochs_scale = d_order;
    sweep
}

pub fn run(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<Vec<RunRecord>> {
    let dataset = if ctx.profile.datasets().contains(&"mimic_like") {
        "mimic_like"
    } else {
        ctx.profile.datasets()[0]
    };
    let loss = Loss::Ls;
    let data = Arc::new(ctx.dataset(dataset, loss)?);
    let d_order = data.tensor.dims.len();
    println!("\n=== Fig.7: FMS vs centralized BrasCPD on {dataset} / ls ===");

    // reference factors: centralized BrasCPD run (paper's comparator) —
    // a single Session, because its *factors* feed the sweep
    let mut ref_cfg = ctx.base_config(dataset, loss, AlgoConfig::bras_cpd());
    ref_cfg.k = 1;
    ref_cfg.epochs = ctx.profile.epochs() * 2; // converge the reference further
    let reference = ctx.run("fig7", &ref_cfg, &data, None)?;

    let sweep = sweep(ctx, k, tau, d_order);
    println!(
        "  decentralized roster: {} runs on {} workers",
        sweep.len(),
        ctx.workers
    );
    // hand the already-loaded dataset to the executor — one tensor in
    // memory, shared by the reference factors and every worker
    let mut opts = ctx.sweep_opts("fig7");
    opts.preload.insert(crate::sweep::dataset_cache_key(dataset, loss), Arc::clone(&data));
    let outcome = crate::sweep::execute(&sweep, &opts, Some(&reference.factors))?;
    let records = outcome.into_records();

    let table = Table::new(&["algo", "final_FMS", "wall_s", "uplink"]);
    for rec in &records {
        let final_fms = rec.points.last().and_then(|p| p.fms).unwrap_or(0.0);
        table.row(&[
            rec.algo.clone(),
            format!("{final_fms:.4}"),
            format!("{:.1}", rec.wall_s),
            fmt_bytes(rec.total.bytes as f64),
        ]);
    }
    // paper check: CiderTF reaches its final FMS with far fewer bytes
    if let (Some(cider), Some(dpsgd)) = (
        records.iter().find(|r| r.algo.starts_with("cidertf")),
        records.iter().find(|r| r.algo == "dpsgd"),
    ) {
        println!(
            "  bytes to final FMS: cidertf {} vs dpsgd {} ({}x reduction)",
            fmt_bytes(cider.total.bytes as f64),
            fmt_bytes(dpsgd.total.bytes as f64),
            dpsgd.total.bytes.max(1) / cider.total.bytes.max(1)
        );
    }
    Ok(records)
}
