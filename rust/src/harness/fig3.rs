//! Fig. 3 — convergence of CiderTF (τ = 2,4,6,8) and CiderTF_m against the
//! centralized (GCP, BrasCPD, Centralized CiderTF) and decentralized
//! (D-PSGD, SPARQ-SGD, D-PSGDbras) baselines, loss vs wall-clock and vs
//! uplink bytes, ring topology, K = 8 — per dataset and per loss.

use super::{k_for, summarize, Ctx, SUMMARY_HEADER};
use crate::engine::AlgoConfig;
use crate::engine::metrics::RunRecord;
use crate::util::benchkit::Table;

/// The figure's algorithm roster.
pub fn roster(taus: &[usize]) -> Vec<AlgoConfig> {
    let mut algos = vec![
        AlgoConfig::gcp(),
        AlgoConfig::bras_cpd(),
        AlgoConfig::centralized_cidertf(),
        AlgoConfig::dpsgd(),
        AlgoConfig::dpsgd_bras(),
        AlgoConfig::sparq_sgd(4),
    ];
    for &t in taus {
        algos.push(AlgoConfig::cidertf(t));
    }
    algos.push(AlgoConfig::cidertf_m(4));
    algos
}

pub fn run(ctx: &mut Ctx, k: usize, taus: &[usize]) -> anyhow::Result<Vec<RunRecord>> {
    let mut records = Vec::new();
    for dataset in ctx.profile.datasets() {
        for loss in ctx.profile.losses() {
            println!("\n=== Fig.3: {dataset} / {} / ring K={k} ===", loss.name());
            let data = ctx.dataset(dataset, loss)?;
            let table = Table::new(&SUMMARY_HEADER);
            for algo in roster(taus) {
                let mut cfg = ctx.base_config(dataset, loss, algo);
                cfg.k = k_for(&cfg.algo, k);
                let out = ctx.run("fig3", &cfg, &data, None)?;
                table.row(&summarize(&out.record));
                records.push(out.record);
            }
        }
    }
    println!("\nFig.3 reproduction notes:");
    if let Some(dpsgd) = records.iter().find(|r| r.algo == "dpsgd") {
        for r in records.iter().filter(|r| r.algo.starts_with("cidertf")) {
            if r.dataset == dpsgd.dataset && r.loss == dpsgd.loss {
                let red = 1.0 - r.total.bytes as f64 / dpsgd.total.bytes.max(1) as f64;
                println!("  {}: comm reduction vs D-PSGD = {:.4}%", r.algo, 100.0 * red);
            }
        }
    }
    Ok(records)
}
