//! Fig. 3 — convergence of CiderTF (τ = 2,4,6,8) and CiderTF_m against the
//! centralized (GCP, BrasCPD, Centralized CiderTF) and decentralized
//! (D-PSGD, SPARQ-SGD, D-PSGDbras) baselines, loss vs wall-clock and vs
//! uplink bytes, ring topology, K = 8 — per dataset and per loss.
//!
//! One [`SweepSpec`]: dataset × loss × algorithm roster, executed
//! concurrently by the sweep engine (`results/fig3/`).

use super::Ctx;
use crate::engine::metrics::RunRecord;
use crate::engine::AlgoConfig;
use crate::sweep::SweepSpec;

/// The figure's algorithm roster.
pub fn roster(taus: &[usize]) -> Vec<AlgoConfig> {
    let mut algos = vec![
        AlgoConfig::gcp(),
        AlgoConfig::bras_cpd(),
        AlgoConfig::centralized_cidertf(),
        AlgoConfig::dpsgd(),
        AlgoConfig::dpsgd_bras(),
        AlgoConfig::sparq_sgd(4),
    ];
    for &t in taus {
        algos.push(AlgoConfig::cidertf(t));
    }
    algos.push(AlgoConfig::cidertf_m(4));
    algos
}

/// The figure as a sweep: the full grid in one declarative spec.
pub fn sweep(ctx: &Ctx, k: usize, taus: &[usize]) -> SweepSpec {
    let datasets = ctx.profile.datasets();
    let losses = ctx.profile.losses();
    let mut sweep =
        SweepSpec::new(ctx.sweep_base(datasets[0], losses[0], AlgoConfig::cidertf(4)));
    sweep.datasets = datasets.iter().map(|s| s.to_string()).collect();
    sweep.losses = losses;
    sweep.algos = roster(taus);
    sweep.ks = vec![k];
    sweep.centralized_k1 = true;
    sweep.auto_gamma = true;
    sweep
}

pub fn run(ctx: &mut Ctx, k: usize, taus: &[usize]) -> anyhow::Result<Vec<RunRecord>> {
    let sweep = sweep(ctx, k, taus);
    println!(
        "\n=== Fig.3: convergence vs baselines, ring K={k} — {} runs on {} workers ===",
        sweep.len(),
        ctx.workers
    );
    let records = ctx.run_sweep(&sweep, "fig3")?.into_records();
    println!("\nFig.3 reproduction notes:");
    if let Some(dpsgd) = records.iter().find(|r| r.algo == "dpsgd") {
        for r in records.iter().filter(|r| r.algo.starts_with("cidertf")) {
            if r.dataset == dpsgd.dataset && r.loss == dpsgd.loss {
                let red = 1.0 - r.total.bytes as f64 / dpsgd.total.bytes.max(1) as f64;
                println!("  {}: comm reduction vs D-PSGD = {:.4}%", r.algo, 100.0 * red);
            }
        }
    }
    Ok(records)
}
