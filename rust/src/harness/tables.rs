//! Tables II, III, IV and the §III-D theorem checks.

use super::{Ctx};
use crate::analysis::phenotype::{assign_subgroups, extract, support_recovery};
use crate::analysis::tsne::{tsne, TsneConfig};
use crate::analysis::silhouette;
use crate::engine::AlgoConfig;
use crate::gossip::Message;
use crate::losses::Loss;
use crate::util::benchkit::Table;
use crate::util::csv::CsvWriter;
use crate::util::mat::Mat;

/// Table II: the algorithm feature/compression-ratio matrix (analytical).
pub fn table2(d_order: usize, tau: usize) {
    println!("\n=== Table II: communication reduction feature matrix (D={d_order}, tau={tau}) ===");
    let table = Table::new(&["algo", "element", "block", "round", "event", "ratio"]);
    for algo in [
        AlgoConfig::dpsgd(),
        AlgoConfig::dpsgd_bras(),
        AlgoConfig::dpsgd_sign(),
        AlgoConfig::dpsgd_bras_sign(),
        AlgoConfig::sparq_sgd(tau),
        AlgoConfig::cidertf(tau),
    ] {
        let check = |b: bool| if b { "yes" } else { "-" }.to_string();
        table.row(&[
            algo.name.clone(),
            check(algo.compressor != crate::compress::Compressor::None),
            check(algo.block_random),
            check(algo.tau > 1),
            check(algo.event_triggered),
            format!("1 - {:.5}", 1.0 - algo.table2_ratio(d_order)),
        ]);
    }
}

/// Table III: patient subgroup identification — tSNE embedding CSVs plus
/// silhouette scores for CiderTF vs centralized BrasCPD vs D-PSGD(+bras)
/// at matched communication budgets.
pub fn table3(ctx: &mut Ctx, k: usize, tau: usize, max_patients: usize) -> anyhow::Result<()> {
    let dataset = if ctx.profile.datasets().contains(&"mimic_like") { "mimic_like" } else { ctx.profile.datasets()[0] };
    let loss = Loss::Ls; // case study compares against BrasCPD (least squares)
    let data = ctx.dataset(dataset, loss)?;
    println!("\n=== Table III: subgroup identification on {dataset} ===");
    // two silhouettes: "top3" labels by the paper's top-3 rule; "all"
    // labels by argmax over every component (planted rank > 3, so top-3
    // labelling is inherently lossy — see EXPERIMENTS.md Table III notes)
    let table = Table::new(&["algo", "epochs", "sil_top3", "sil_all", "embedding_csv"]);

    // (algo, epochs): decentralized full-precision baselines get 1 epoch —
    // the paper matches *communication* budgets, and one D-PSGD epoch
    // already out-spends a full CiderTF run.
    // converging runs need >= ~10 epochs for the factors to settle into
    // interpretable phenotypes even on the quick profile
    let conv_epochs = ctx.profile.epochs().max(10);
    let runs: Vec<(AlgoConfig, usize, usize)> = vec![
        (AlgoConfig::bras_cpd(), conv_epochs * 2, 1),
        (AlgoConfig::cidertf(tau), conv_epochs, k),
        (AlgoConfig::dpsgd(), 1, k),
        (AlgoConfig::dpsgd_bras(), 1, k),
    ];
    for (algo, epochs, run_k) in runs {
        let mut cfg = ctx.base_config(dataset, loss, algo);
        cfg.k = run_k;
        cfg.epochs = epochs;
        let out = ctx.run("table3", &cfg, &data, None)?;
        let factors = out.factors;
        let top = factors.top_components(3);
        let all: Vec<usize> = (0..factors.rank()).collect();
        let patients = subsample_rows(&factors.mats[0], max_patients);
        let groups3 = assign_subgroups(&patients, &top);
        let groups_all = assign_subgroups(&patients, &all);
        let embedding = tsne(&patients, &TsneConfig::default());
        let sil3 = silhouette(&embedding, &groups3);
        let sil_all = silhouette(&embedding, &groups_all);
        let csv = format!(
            "table3/tsne_{}_{}.csv",
            crate::engine::spec::fs_component(&cfg.dataset),
            cfg.algo.name
        );
        let mut w =
            CsvWriter::create(ctx.out_dir.join(&csv), &["x", "y", "group_top3", "group_all"])?;
        for i in 0..embedding.rows {
            w.row_f64(&[
                embedding.at(i, 0) as f64,
                embedding.at(i, 1) as f64,
                groups3[i] as f64,
                groups_all[i] as f64,
            ])?;
        }
        w.flush()?;
        table.row(&[
            cfg.algo.name.clone(),
            epochs.to_string(),
            format!("{sil3:.3}"),
            format!("{sil_all:.3}"),
            csv,
        ]);
    }
    println!("  (paper Table III: CiderTF clusters comparably to BrasCPD, better than 1-epoch D-PSGD*)");
    Ok(())
}

/// Table IV: top-3 phenotypes with their top features per mode, plus the
/// support-recovery score vs the planted ground truth (our checkable
/// analogue of the clinician annotation).
pub fn table4(ctx: &mut Ctx, k: usize, tau: usize, feats_per_mode: usize) -> anyhow::Result<()> {
    let dataset = if ctx.profile.datasets().contains(&"mimic_like") { "mimic_like" } else { ctx.profile.datasets()[0] };
    let loss = Loss::Ls; // interpretable nonneg-ish factors come from the ls fit
    let data = ctx.dataset(dataset, loss)?;
    println!("\n=== Table IV: phenotypes extracted by CiderTF (tau={tau}) on {dataset} ===");
    let mut cfg = ctx.base_config(dataset, loss, AlgoConfig::cidertf(tau));
    cfg.k = k;
    cfg.epochs = ctx.profile.epochs().max(10); // converge into the planted basin
    let out = ctx.run("table4", &cfg, &data, None)?;
    let phenos = extract(&out.factors, 3, feats_per_mode);
    let mode_names = ["Dx", "Px/Med"]; // feature-mode labels for D=3
    for (i, ph) in phenos.iter().enumerate() {
        println!("  P{}: component {} (lambda = {:.3})", i + 1, ph.component, ph.weight);
        for (fm, feats) in ph.top_features.iter().enumerate() {
            let items: Vec<String> =
                feats.iter().map(|&(id, w)| format!("f{id}({w:.2})")).collect();
            println!("    {}: {}", mode_names.get(fm).unwrap_or(&"mode"), items.join(", "));
        }
    }
    let truth: Vec<Mat> = data.truth.clone();
    let recovery = support_recovery(&phenos, &truth);
    println!("  planted-support recovery (best-Jaccard avg): {recovery:.3}");
    Ok(())
}

/// §III-D theorem checks: measured communication against the analytical
/// `1 - 1/(32 D tau)` lower bound, and memory/computation scalings.
pub fn theorems(ctx: &mut Ctx, k: usize, tau: usize) -> anyhow::Result<()> {
    let dataset = ctx.profile.datasets()[0];
    let loss = Loss::Logit;
    let data = ctx.dataset(dataset, loss)?;
    let d_order = data.tensor.dims.len();
    println!("\n=== Theorems III.1-III.3 checks ({dataset}, K={k}, tau={tau}) ===");

    // Thm III.2 — communication reduction vs full-precision D-PSGD.
    // The bound is an *expectation* over the block-randomized mode
    // sequence; use enough iterations to shrink sampling noise.
    let mut cfg_d = ctx.base_config(dataset, loss, AlgoConfig::dpsgd());
    cfg_d.k = k;
    cfg_d.epochs = 1;
    cfg_d.iters_per_epoch = 1000;
    let dpsgd = ctx.run("theorems", &cfg_d, &data, None)?;
    let mut cfg_c = ctx.base_config(dataset, loss, AlgoConfig::cidertf(tau));
    cfg_c.k = k;
    cfg_c.epochs = 1;
    cfg_c.iters_per_epoch = 1000;
    let cider = ctx.run("theorems", &cfg_c, &data, None)?;
    let bound = 1.0 - 1.0 / (32.0 * d_order as f64 * tau as f64);
    // wire-level includes per-message headers (which dominate CiderTF's
    // tiny sign payloads); the theorem's bound is payload-level math.
    let wire = 1.0 - cider.record.total.bytes as f64 / dpsgd.record.total.bytes.max(1) as f64;
    let payload = |r: &crate::engine::metrics::RunRecord| {
        (r.total.bytes - r.total.messages * Message::HEADER_BYTES) as f64
    };
    let payload_red = 1.0 - payload(&cider.record) / payload(&dpsgd.record).max(1.0);
    // retained-fraction ratio vs the bound's expectation; <= 1 means the
    // bound holds, small excess is block-sampling noise (~1/sqrt(events))
    let retained_ratio = (1.0 - payload_red) / (1.0 - bound);
    let verdict = if payload_red >= bound {
        "YES"
    } else if retained_ratio < 1.15 {
        "YES (within block-sampling noise)"
    } else {
        "NO"
    };
    println!(
        "  Thm III.2: payload-level reduction {:.5} vs bound {:.5} -> {}  (wire incl. headers: {:.5})",
        payload_red, bound, verdict, wire,
    );
    println!(
        "  uplink: dpsgd {} vs cidertf {} per epoch",
        crate::util::benchkit::fmt_bytes(dpsgd.record.total.bytes as f64),
        crate::util::benchkit::fmt_bytes(cider.record.total.bytes as f64),
    );

    // Thm III.3 — memory: fiber-sampled slice vs full matricization
    let s = cfg_c.fiber_samples;
    let full: f64 = data.tensor.n_cells();
    let sketch: f64 = data.tensor.dims.iter().map(|&i| (i * s) as f64).sum::<f64>() / d_order as f64;
    println!(
        "  Thm III.3: slice memory {:.2e} floats vs full matricization {:.2e} ({}x smaller)",
        sketch,
        full,
        (full / sketch) as u64
    );

    // Thm III.1 — per-iteration computational complexity O((1/D) sum I_d R |S|)
    let r = cfg_c.rank;
    let flops_per_iter: f64 =
        data.tensor.dims.iter().map(|&i| (i * r * s) as f64).sum::<f64>() / d_order as f64;
    println!(
        "  Thm III.1: per-iteration work ~{:.2e} MACs per client (R={r}, |S|={s})",
        flops_per_iter
    );
    Ok(())
}

fn subsample_rows(m: &Mat, max_rows: usize) -> Mat {
    if m.rows <= max_rows {
        return m.clone();
    }
    let stride = m.rows.div_ceil(max_rows);
    let rows: Vec<usize> = (0..m.rows).step_by(stride).collect();
    let mut out = Mat::zeros(rows.len(), m.cols);
    for (o, &i) in rows.iter().enumerate() {
        out.row_mut(o).copy_from_slice(m.row(i));
    }
    out
}
