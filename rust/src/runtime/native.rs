//! Pure-Rust mirror of the L1/L2 compute graph.
//!
//! Bit-faithful to the math of `python/compile/kernels/` (same formulas,
//! same f32 accumulation structure): `M = A Hᵀ`, `Y = ∂f(M, Xs)`,
//! `G = scale · Y H`, `L = Σ f(M, Xs)` with `H` the Hadamard of the row
//! gathers. Used for
//! * differential testing against the PJRT artifacts (runtime_integration),
//! * artifact-free unit tests and debugging,
//! * the perf baseline the PJRT path is compared to in EXPERIMENTS.md §Perf.

use super::ComputeBackend;
use crate::losses::Loss;
use crate::util::mat::Mat;

/// Native (no-PJRT) compute backend.
#[derive(Debug)]
pub struct NativeBackend {
    /// scratch for H = hadamard(us), reused across calls
    h_scratch: Mat,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend { h_scratch: Mat::zeros(0, 0) }
    }

    /// H = elementwise product of the D-1 row-gather matrices.
    fn hadamard_into(&mut self, us: &[&Mat]) {
        let (s, r) = (us[0].rows, us[0].cols);
        if self.h_scratch.rows != s || self.h_scratch.cols != r {
            self.h_scratch = Mat::zeros(s, r);
        }
        self.h_scratch.data.copy_from_slice(&us[0].data);
        for u in &us[1..] {
            debug_assert_eq!((u.rows, u.cols), (s, r));
            self.h_scratch.hadamard_assign(u);
        }
    }
}

impl ComputeBackend for NativeBackend {
    fn grad(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[&Mat],
        scale: f32,
    ) -> anyhow::Result<(Mat, f64)> {
        anyhow::ensure!(xs.len() == i_dim * s_dim, "xs shape mismatch");
        anyhow::ensure!(a.rows == i_dim, "A shape mismatch");
        let r_dim = a.cols;
        self.hadamard_into(us);
        let h = &self.h_scratch;

        let mut g = Mat::zeros(i_dim, r_dim);
        let mut loss_sum = 0.0f64;
        let mut m_row = vec![0.0f32; s_dim];
        for i in 0..i_dim {
            let a_row = a.row(i);
            // M(i,:) = A(i,:) · Hᵀ
            for (s, mv) in m_row.iter_mut().enumerate() {
                let h_row = h.row(s);
                let mut acc = 0.0f32;
                for (av, hv) in a_row.iter().zip(h_row.iter()) {
                    acc += av * hv;
                }
                *mv = acc;
            }
            // Y(i,:) = ∂f, fused with G(i,:) += Y(i,s) · H(s,:)
            let g_row = g.row_mut(i);
            let xs_row = &xs[i * s_dim..(i + 1) * s_dim];
            for s in 0..s_dim {
                let m = m_row[s];
                let x = xs_row[s];
                loss_sum += loss.value(m, x) as f64;
                let y = loss.grad(m, x);
                if y == 0.0 {
                    continue;
                }
                let h_row = h.row(s);
                for (gv, hv) in g_row.iter_mut().zip(h_row.iter()) {
                    *gv += y * hv;
                }
            }
        }
        g.scale(scale);
        Ok((g, loss_sum))
    }

    fn eval(&mut self, loss: Loss, x: &[f32], us: &[&Mat]) -> anyhow::Result<f64> {
        let b = x.len();
        anyhow::ensure!(us.iter().all(|u| u.rows == b), "U shape mismatch");
        let r_dim = us[0].cols;
        let mut sum = 0.0f64;
        let mut prod = vec![0.0f32; r_dim];
        for e in 0..b {
            prod.copy_from_slice(us[0].row(e));
            for u in &us[1..] {
                for (p, v) in prod.iter_mut().zip(u.row(e).iter()) {
                    *p *= v;
                }
            }
            let m: f32 = prod.iter().sum();
            sum += loss.value(m, x[e]) as f64;
        }
        Ok(sum)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat::rand_normal(rows, cols, 0.4, rng)
    }

    /// Straight-line oracle with no fusion/scratch tricks.
    fn oracle_grad(loss: Loss, xs: &[f32], i: usize, s: usize, a: &Mat, h: &Mat, scale: f32) -> (Mat, f64) {
        let m = a.matmul_transb(h); // [i, s]
        let mut y = Mat::zeros(i, s);
        let mut lsum = 0.0f64;
        for r in 0..i {
            for c in 0..s {
                lsum += loss.value(m.at(r, c), xs[r * s + c]) as f64;
                *y.at_mut(r, c) = loss.grad(m.at(r, c), xs[r * s + c]);
            }
        }
        let mut g = y.matmul(h);
        g.scale(scale);
        (g, lsum)
    }

    #[test]
    fn grad_matches_oracle_both_losses() {
        let mut rng = Rng::new(21);
        let (i, s, r) = (13, 9, 5);
        for loss in [Loss::Ls, Loss::Logit] {
            let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
            let a = randmat(i, r, &mut rng);
            let u1 = randmat(s, r, &mut rng);
            let u2 = randmat(s, r, &mut rng);
            let mut h = u1.clone();
            h.hadamard_assign(&u2);
            let mut be = NativeBackend::new();
            let (g, l) = be.grad(loss, &xs, i, s, &a, &[&u1, &u2], 1.7).unwrap();
            let (g2, l2) = oracle_grad(loss, &xs, i, s, &a, &h, 1.7);
            for (x, y) in g.data.iter().zip(g2.data.iter()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
            assert!((l - l2).abs() / l2.abs().max(1.0) < 1e-5);
        }
    }

    #[test]
    fn eval_matches_manual() {
        let mut rng = Rng::new(22);
        let (b, r) = (31, 4);
        let us: Vec<Mat> = (0..3).map(|_| randmat(b, r, &mut rng)).collect();
        let x: Vec<f32> = (0..b).map(|_| rng.normal_f32()).collect();
        let mut be = NativeBackend::new();
        let refs: Vec<&Mat> = us.iter().collect();
        let got = be.eval(Loss::Ls, &x, &refs).unwrap();
        let mut want = 0.0f64;
        for e in 0..b {
            let mut m = 0.0f32;
            for rr in 0..r {
                m += us[0].at(e, rr) * us[1].at(e, rr) * us[2].at(e, rr);
            }
            want += Loss::Ls.value(m, x[e]) as f64;
        }
        assert!((got - want).abs() < 1e-6 * want.abs().max(1.0));
    }

    #[test]
    fn order4_hadamard_chain() {
        let mut rng = Rng::new(23);
        let (i, s, r) = (6, 7, 3);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let us: Vec<Mat> = (0..3).map(|_| randmat(s, r, &mut rng)).collect();
        let refs: Vec<&Mat> = us.iter().collect();
        let mut h = us[0].clone();
        h.hadamard_assign(&us[1]);
        h.hadamard_assign(&us[2]);
        let mut be = NativeBackend::new();
        let (g, _) = be.grad(Loss::Ls, &xs, i, s, &a, &refs, 1.0).unwrap();
        let (g2, _) = oracle_grad(Loss::Ls, &xs, i, s, &a, &h, 1.0);
        for (x, y) in g.data.iter().zip(g2.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn scale_is_applied() {
        let mut rng = Rng::new(24);
        let (i, s, r) = (4, 5, 2);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let u1 = randmat(s, r, &mut rng);
        let u2 = randmat(s, r, &mut rng);
        let mut be = NativeBackend::new();
        let (g1, l1) = be.grad(Loss::Ls, &xs, i, s, &a, &[&u1, &u2], 1.0).unwrap();
        let (g2, l2) = be.grad(Loss::Ls, &xs, i, s, &a, &[&u1, &u2], 3.0).unwrap();
        for (x, y) in g1.data.iter().zip(g2.data.iter()) {
            assert!((3.0 * x - y).abs() < 1e-4);
        }
        assert!((l1 - l2).abs() < 1e-9, "loss is unscaled");
    }
}
