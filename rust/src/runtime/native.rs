//! Pure-Rust mirror of the L1/L2 compute graph.
//!
//! Bit-faithful to the math of `python/compile/kernels/` (same formulas,
//! f32 accumulation): `M = A Hᵀ`, `Y = ∂f(M, Xs)`, `G = scale · Y H`,
//! `L = Σ f(M, Xs)` with `H` the Hadamard of the row gathers. Used for
//! * differential testing against the PJRT artifacts (runtime_integration),
//! * artifact-free unit tests and debugging,
//! * the perf baseline the PJRT path is compared to in EXPERIMENTS.md §Perf.
//!
//! # Blocked panel kernel
//!
//! The gradient runs in **row panels**: the `i` dimension is processed in
//! tiles of [`PANEL`] rows, and for each tile the `M` panel
//! (`[PANEL, s]`) is computed by the 2x2 register-tiled
//! [`mat::gemm_transb_into`] kernel into a scratch buffer owned by the
//! backend, overwritten in place by `Y = ∂f`, then folded into the output
//! with [`mat::gemm_acc_into`]. Steady state performs **zero heap
//! allocations** (the `grad_into` entry point writes into a caller-owned
//! buffer and both scratch panels persist across calls).
//!
//! Because every output cell accumulates in the fixed lane structure of
//! the blocked kernels, the gradient is **bit-identical regardless of
//! panel boundaries or thread count** (see
//! `blocked_transb_cells_are_tiling_invariant` in `util::mat`). The
//! monitoring loss sum is reduced panel-major; with `threads > 1` the
//! per-chunk partials are added in chunk order, which can differ from the
//! single-thread running sum in the last ulp — which is why the
//! deterministic engine default is `threads = 1`
//! (`TrainConfig::compute_threads`).

use super::ComputeBackend;
use crate::losses::Loss;
use crate::util::mat::{self, Mat};

/// Rows per gradient panel: `PANEL x s` f32 scratch (32 x 256 = 32 kB)
/// stays comfortably inside L1/L2 next to the `[s, R]` Hadamard matrix.
const PANEL: usize = 32;

/// Minimum `i` rows per worker before the scoped pool is engaged.
///
/// Workers are `std::thread::scope`-spawned per gradient call (simple and
/// safe without crates-io thread-pool deps), which costs tens of
/// microseconds of spawn + per-worker scratch per call. At 1024 rows a
/// worker's kernel time is hundreds of microseconds, so the overhead is
/// amortized; below the threshold the call silently runs single-thread,
/// which is faster anyway. A persistent pool would lower this threshold
/// and is the natural next step if mid-sized shards need threading.
const MIN_ROWS_PER_THREAD: usize = 1024;

/// Native (no-PJRT) compute backend.
#[derive(Debug)]
pub struct NativeBackend {
    /// scratch for H = hadamard(us), reused across calls
    h_scratch: Mat,
    /// reused `[PANEL, s]` M/Y panel scratch (single-thread path)
    panel: Vec<f32>,
    /// row-panel worker threads (1 = deterministic default)
    threads: usize,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend { h_scratch: Mat::zeros(0, 0), panel: Vec::new(), threads: 1 }
    }

    /// Backend with `threads` row-panel workers (see
    /// [`ComputeBackend::set_threads`]).
    pub fn with_threads(threads: usize) -> Self {
        let mut b = Self::new();
        b.threads = threads.max(1);
        b
    }

    /// H = elementwise product of the D-1 row-gather matrices (fused
    /// two-operand fast path for the common D=3 case).
    fn hadamard_into<'a, I>(&mut self, first: &Mat, rest: I)
    where
        I: Iterator<Item = &'a Mat> + Clone,
    {
        let (s, r) = (first.rows, first.cols);
        if self.h_scratch.rows != s || self.h_scratch.cols != r {
            self.h_scratch = Mat::zeros(s, r);
        }
        let mut peek = rest.clone();
        match (peek.next(), peek.next()) {
            (Some(u), None) => {
                debug_assert_eq!((u.rows, u.cols), (s, r));
                mat::hadamard2_into(&first.data, &u.data, &mut self.h_scratch.data);
            }
            _ => {
                self.h_scratch.data.copy_from_slice(&first.data);
                for u in rest {
                    debug_assert_eq!((u.rows, u.cols), (s, r));
                    self.h_scratch.hadamard_assign(u);
                }
            }
        }
    }

    /// Panel-blocked gradient core. Expects `h_scratch` to already hold
    /// `H`; writes `scale * Y H` into `out` and returns the loss sum.
    #[allow(clippy::too_many_arguments)]
    fn grad_core(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        r_dim: usize,
        a: &Mat,
        scale: f32,
        out: &mut Mat,
    ) -> f64 {
        if out.rows != i_dim || out.cols != r_dim {
            *out = Mat::zeros(i_dim, r_dim);
        }
        out.fill(0.0);
        let NativeBackend { h_scratch, panel, threads } = self;
        let h = &h_scratch.data;
        let a_data = &a.data;

        let n_threads = if i_dim >= 2 * MIN_ROWS_PER_THREAD {
            (*threads).min(i_dim / MIN_ROWS_PER_THREAD).max(1)
        } else {
            1
        };

        let mut loss_sum = 0.0f64;
        if n_threads <= 1 {
            if panel.len() < PANEL * s_dim {
                panel.resize(PANEL * s_dim, 0.0);
            }
            let mut i0 = 0;
            while i0 < i_dim {
                let p = PANEL.min(i_dim - i0);
                loss_sum += panel_step(
                    loss,
                    xs,
                    i0,
                    p,
                    s_dim,
                    r_dim,
                    a_data,
                    h,
                    &mut panel[..p * s_dim],
                    &mut out.data[i0 * r_dim..(i0 + p) * r_dim],
                );
                i0 += p;
            }
        } else {
            // contiguous panel-aligned row chunks, one scoped thread each;
            // each worker owns its panel scratch (threaded mode allocates
            // one scratch per worker per call — the deterministic
            // single-thread default stays allocation-free)
            let panels_total = i_dim.div_ceil(PANEL);
            let rows_per = panels_total.div_ceil(n_threads) * PANEL;
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(n_threads);
                let mut rest: &mut [f32] = &mut out.data;
                let mut i0 = 0usize;
                while i0 < i_dim {
                    let take = rows_per.min(i_dim - i0);
                    let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take * r_dim);
                    rest = tail;
                    let start = i0;
                    handles.push(scope.spawn(move || {
                        let mut scratch = vec![0.0f32; PANEL.min(take) * s_dim];
                        let mut ls = 0.0f64;
                        let mut off = 0;
                        while off < take {
                            let p = PANEL.min(take - off);
                            ls += panel_step(
                                loss,
                                xs,
                                start + off,
                                p,
                                s_dim,
                                r_dim,
                                a_data,
                                h,
                                &mut scratch[..p * s_dim],
                                &mut chunk[off * r_dim..(off + p) * r_dim],
                            );
                            off += p;
                        }
                        ls
                    }));
                    i0 += take;
                }
                for handle in handles {
                    loss_sum += handle.join().expect("panel worker panicked");
                }
            });
        }
        out.scale(scale);
        loss_sum
    }

    /// The pre-blocked scalar reference kernel (rowwise dots, allocates
    /// its output). Kept for the `bench` perf gate and differential tests
    /// against the blocked path.
    pub fn grad_naive(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[&Mat],
        scale: f32,
    ) -> anyhow::Result<(Mat, f64)> {
        anyhow::ensure!(!us.is_empty(), "need at least one row-gather matrix");
        anyhow::ensure!(xs.len() == i_dim * s_dim, "xs shape mismatch");
        anyhow::ensure!(a.rows == i_dim, "A shape mismatch");
        let r_dim = a.cols;
        self.hadamard_into(us[0], us[1..].iter().copied());
        let h = &self.h_scratch;

        let mut g = Mat::zeros(i_dim, r_dim);
        let mut loss_sum = 0.0f64;
        let mut m_row = vec![0.0f32; s_dim];
        for i in 0..i_dim {
            let a_row = a.row(i);
            // M(i,:) = A(i,:) · Hᵀ
            for (s, mv) in m_row.iter_mut().enumerate() {
                let h_row = h.row(s);
                let mut acc = 0.0f32;
                for (av, hv) in a_row.iter().zip(h_row.iter()) {
                    acc += av * hv;
                }
                *mv = acc;
            }
            // Y(i,:) = ∂f, fused with G(i,:) += Y(i,s) · H(s,:)
            let g_row = g.row_mut(i);
            let xs_row = &xs[i * s_dim..(i + 1) * s_dim];
            for s in 0..s_dim {
                let m = m_row[s];
                let x = xs_row[s];
                loss_sum += loss.value(m, x) as f64;
                let y = loss.grad(m, x);
                if y == 0.0 {
                    continue;
                }
                let h_row = h.row(s);
                for (gv, hv) in g_row.iter_mut().zip(h_row.iter()) {
                    *gv += y * hv;
                }
            }
        }
        g.scale(scale);
        Ok((g, loss_sum))
    }
}

/// One `[p, s]` row panel of the gradient: `M = A_panel Hᵀ` (blocked),
/// `Y = ∂f` in place, `G_panel += Y H` (accumulating). Returns the panel
/// loss sum, accumulated in row-major `(i, s)` order.
#[allow(clippy::too_many_arguments)]
fn panel_step(
    loss: Loss,
    xs: &[f32],
    i0: usize,
    p: usize,
    s_dim: usize,
    r_dim: usize,
    a: &[f32],
    h: &[f32],
    panel: &mut [f32],
    g: &mut [f32],
) -> f64 {
    let a_panel = &a[i0 * r_dim..(i0 + p) * r_dim];
    mat::gemm_transb_into(a_panel, h, panel, p, s_dim, r_dim);
    let mut loss_sum = 0.0f64;
    for (row, prow) in panel.chunks_exact_mut(s_dim).enumerate() {
        let xs_row = &xs[(i0 + row) * s_dim..(i0 + row + 1) * s_dim];
        for (mv, &x) in prow.iter_mut().zip(xs_row.iter()) {
            loss_sum += loss.value(*mv, x) as f64;
            *mv = loss.grad(*mv, x);
        }
    }
    mat::gemm_acc_into(panel, h, g, p, r_dim, s_dim);
    loss_sum
}

impl ComputeBackend for NativeBackend {
    fn grad(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[&Mat],
        scale: f32,
    ) -> anyhow::Result<(Mat, f64)> {
        anyhow::ensure!(!us.is_empty(), "need at least one row-gather matrix");
        anyhow::ensure!(xs.len() == i_dim * s_dim, "xs shape mismatch");
        anyhow::ensure!(a.rows == i_dim, "A shape mismatch");
        self.hadamard_into(us[0], us[1..].iter().copied());
        let mut g = Mat::zeros(i_dim, a.cols);
        let l = self.grad_core(loss, xs, i_dim, s_dim, a.cols, a, scale, &mut g);
        Ok((g, l))
    }

    #[allow(clippy::too_many_arguments)]
    fn grad_into(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[Mat],
        scale: f32,
        out: &mut Mat,
    ) -> anyhow::Result<f64> {
        anyhow::ensure!(!us.is_empty(), "need at least one row-gather matrix");
        anyhow::ensure!(xs.len() == i_dim * s_dim, "xs shape mismatch");
        anyhow::ensure!(a.rows == i_dim, "A shape mismatch");
        anyhow::ensure!(
            us.iter().all(|u| u.rows == s_dim && u.cols == a.cols),
            "U shape mismatch"
        );
        self.hadamard_into(&us[0], us[1..].iter());
        Ok(self.grad_core(loss, xs, i_dim, s_dim, a.cols, a, scale, out))
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    fn eval(&mut self, loss: Loss, x: &[f32], us: &[&Mat]) -> anyhow::Result<f64> {
        let b = x.len();
        anyhow::ensure!(us.iter().all(|u| u.rows == b), "U shape mismatch");
        let r_dim = us[0].cols;
        let mut sum = 0.0f64;
        let mut prod = vec![0.0f32; r_dim];
        for e in 0..b {
            prod.copy_from_slice(us[0].row(e));
            for u in &us[1..] {
                for (p, v) in prod.iter_mut().zip(u.row(e).iter()) {
                    *p *= v;
                }
            }
            let m: f32 = prod.iter().sum();
            sum += loss.value(m, x[e]) as f64;
        }
        Ok(sum)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat::rand_normal(rows, cols, 0.4, rng)
    }

    /// Straight-line oracle with no fusion/scratch tricks.
    fn oracle_grad(loss: Loss, xs: &[f32], i: usize, s: usize, a: &Mat, h: &Mat, scale: f32) -> (Mat, f64) {
        let m = a.matmul_transb(h); // [i, s]
        let mut y = Mat::zeros(i, s);
        let mut lsum = 0.0f64;
        for r in 0..i {
            for c in 0..s {
                lsum += loss.value(m.at(r, c), xs[r * s + c]) as f64;
                *y.at_mut(r, c) = loss.grad(m.at(r, c), xs[r * s + c]);
            }
        }
        let mut g = y.matmul(h);
        g.scale(scale);
        (g, lsum)
    }

    #[test]
    fn grad_matches_oracle_both_losses() {
        let mut rng = Rng::new(21);
        let (i, s, r) = (13, 9, 5);
        for loss in [Loss::Ls, Loss::Logit] {
            let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
            let a = randmat(i, r, &mut rng);
            let u1 = randmat(s, r, &mut rng);
            let u2 = randmat(s, r, &mut rng);
            let mut h = u1.clone();
            h.hadamard_assign(&u2);
            let mut be = NativeBackend::new();
            let (g, l) = be.grad(loss, &xs, i, s, &a, &[&u1, &u2], 1.7).unwrap();
            let (g2, l2) = oracle_grad(loss, &xs, i, s, &a, &h, 1.7);
            for (x, y) in g.data.iter().zip(g2.data.iter()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
            assert!((l - l2).abs() / l2.abs().max(1.0) < 1e-5);
        }
    }

    #[test]
    fn blocked_matches_naive_reference() {
        // the blocked panel path must agree with the pre-blocked scalar
        // kernel across panel-edge shapes (i below, at, and above PANEL)
        let mut rng = Rng::new(25);
        for (i, s, r) in [(5, 9, 4), (32, 16, 8), (33, 16, 8), (71, 24, 5)] {
            for loss in [Loss::Ls, Loss::Logit] {
                let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
                let a = randmat(i, r, &mut rng);
                let u1 = randmat(s, r, &mut rng);
                let u2 = randmat(s, r, &mut rng);
                let mut be = NativeBackend::new();
                let (g_b, l_b) = be.grad(loss, &xs, i, s, &a, &[&u1, &u2], 1.3).unwrap();
                let (g_n, l_n) = be.grad_naive(loss, &xs, i, s, &a, &[&u1, &u2], 1.3).unwrap();
                for (x, y) in g_b.data.iter().zip(g_n.data.iter()) {
                    assert!((x - y).abs() < 1e-4, "({i},{s},{r}) {loss:?}: {x} vs {y}");
                }
                assert!((l_b - l_n).abs() / l_n.abs().max(1.0) < 1e-5);
            }
        }
    }

    #[test]
    fn grad_into_is_bit_identical_to_grad() {
        let mut rng = Rng::new(26);
        let (i, s, r) = (40, 12, 6);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let us: Vec<Mat> = (0..2).map(|_| randmat(s, r, &mut rng)).collect();
        let refs: Vec<&Mat> = us.iter().collect();
        let mut be = NativeBackend::new();
        let (g, l) = be.grad(Loss::Logit, &xs, i, s, &a, &refs, 0.5).unwrap();
        let mut out = Mat::zeros(i, r);
        let l2 = be.grad_into(Loss::Logit, &xs, i, s, &a, &us, 0.5, &mut out).unwrap();
        assert_eq!(g.data, out.data);
        assert_eq!(l, l2);
    }

    #[test]
    fn threads_do_not_change_gradient() {
        // the lane-deterministic kernels make G bit-identical across
        // thread counts; the loss sum may differ only in rounding
        let mut rng = Rng::new(27);
        let (i, s, r) = (4 * MIN_ROWS_PER_THREAD, 16, 4);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let us: Vec<Mat> = (0..2).map(|_| randmat(s, r, &mut rng)).collect();
        let mut out1 = Mat::zeros(i, r);
        let mut out4 = Mat::zeros(i, r);
        let mut be1 = NativeBackend::new();
        let l1 = be1.grad_into(Loss::Ls, &xs, i, s, &a, &us, 1.0, &mut out1).unwrap();
        let mut be4 = NativeBackend::with_threads(4);
        let l4 = be4.grad_into(Loss::Ls, &xs, i, s, &a, &us, 1.0, &mut out4).unwrap();
        assert_eq!(out1.data, out4.data, "thread count changed the gradient");
        assert!((l1 - l4).abs() / l1.abs().max(1.0) < 1e-12, "{l1} vs {l4}");
    }

    #[test]
    fn eval_matches_manual() {
        let mut rng = Rng::new(22);
        let (b, r) = (31, 4);
        let us: Vec<Mat> = (0..3).map(|_| randmat(b, r, &mut rng)).collect();
        let x: Vec<f32> = (0..b).map(|_| rng.normal_f32()).collect();
        let mut be = NativeBackend::new();
        let refs: Vec<&Mat> = us.iter().collect();
        let got = be.eval(Loss::Ls, &x, &refs).unwrap();
        let mut want = 0.0f64;
        for e in 0..b {
            let mut m = 0.0f32;
            for rr in 0..r {
                m += us[0].at(e, rr) * us[1].at(e, rr) * us[2].at(e, rr);
            }
            want += Loss::Ls.value(m, x[e]) as f64;
        }
        assert!((got - want).abs() < 1e-6 * want.abs().max(1.0));
    }

    #[test]
    fn order4_hadamard_chain() {
        let mut rng = Rng::new(23);
        let (i, s, r) = (6, 7, 3);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let us: Vec<Mat> = (0..3).map(|_| randmat(s, r, &mut rng)).collect();
        let refs: Vec<&Mat> = us.iter().collect();
        let mut h = us[0].clone();
        h.hadamard_assign(&us[1]);
        h.hadamard_assign(&us[2]);
        let mut be = NativeBackend::new();
        let (g, _) = be.grad(Loss::Ls, &xs, i, s, &a, &refs, 1.0).unwrap();
        let (g2, _) = oracle_grad(Loss::Ls, &xs, i, s, &a, &h, 1.0);
        for (x, y) in g.data.iter().zip(g2.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn scale_is_applied() {
        let mut rng = Rng::new(24);
        let (i, s, r) = (4, 5, 2);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let u1 = randmat(s, r, &mut rng);
        let u2 = randmat(s, r, &mut rng);
        let mut be = NativeBackend::new();
        let (g1, l1) = be.grad(Loss::Ls, &xs, i, s, &a, &[&u1, &u2], 1.0).unwrap();
        let (g2, l2) = be.grad(Loss::Ls, &xs, i, s, &a, &[&u1, &u2], 3.0).unwrap();
        for (x, y) in g1.data.iter().zip(g2.data.iter()) {
            assert!((3.0 * x - y).abs() < 1e-4);
        }
        assert!((l1 - l2).abs() < 1e-9, "loss is unscaled");
    }
}
