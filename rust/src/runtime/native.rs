//! Pure-Rust mirror of the L1/L2 compute graph.
//!
//! Bit-faithful to the math of `python/compile/kernels/` (same formulas,
//! f32 accumulation): `M = A Hᵀ`, `Y = ∂f(M, Xs)`, `G = scale · Y H`,
//! `L = Σ f(M, Xs)` with `H` the Hadamard of the row gathers. Used for
//! * differential testing against the PJRT artifacts (runtime_integration),
//! * artifact-free unit tests and debugging,
//! * the perf baseline the PJRT path is compared to in EXPERIMENTS.md §Perf.
//!
//! # Blocked panel kernel
//!
//! The gradient runs in **row panels**: the `i` dimension is processed in
//! tiles of [`PANEL`] rows, and for each tile the `M` panel
//! (`[PANEL, s]`) is computed by the 2x2 register-tiled
//! [`mat::gemm_transb_into`] kernel into a scratch buffer owned by the
//! backend, overwritten in place by `Y = ∂f`, then folded into the output
//! with [`mat::gemm_acc_into`]. Steady state performs **zero heap
//! allocations** (the `grad_into` entry point writes into a caller-owned
//! buffer and both scratch panels persist across calls).
//!
//! Because every output cell accumulates in the fixed lane structure of
//! the blocked kernels, the gradient is **bit-identical regardless of
//! panel boundaries or thread count** (see
//! `blocked_transb_cells_are_tiling_invariant` in `util::mat`). The
//! monitoring loss sum is bit-identical too: every path — single-thread
//! or pooled — produces one `f64` partial per [`PANEL`]-row panel and the
//! calling thread left-folds the partials in panel order, so the
//! reduction tree never depends on the thread count. Threaded runs are
//! therefore byte-for-byte reproductions of the `threads = 1` default
//! (`TrainConfig::compute_threads`), which is what lets CI run the whole
//! suite under `CIDERTF_THREADS=4`.
//!
//! Threading runs on the persistent worker pool (`runtime::pool`) —
//! parked threads reused across calls and sessions — and engages at the
//! measured-crossover thresholds in `pool::thresholds` instead of PR 2's
//! hard-coded `i >= 2048` scoped-spawn cutoff.

use super::pool;
use super::ComputeBackend;
use crate::losses::Loss;
use crate::util::mat::{self, Mat};
use std::sync::OnceLock;

/// Rows per gradient panel: `PANEL x s` f32 scratch (32 x 256 = 32 kB)
/// stays comfortably inside L1/L2 next to the `[s, R]` Hadamard matrix.
const PANEL: usize = 32;

/// `CIDERTF_THREADS` floor on the backend's thread count (parsed once).
/// CI sets it to force the pool path across the whole test suite; that
/// is safe precisely because threaded outputs are bit-identical to
/// single-thread (see the module docs).
fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("CIDERTF_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(1)
            .max(1)
    })
}

std::thread_local! {
    /// Per-thread `[PANEL, s]` M/Y panel scratch for pooled gradient
    /// jobs: workers are persistent, so after warmup the threaded path
    /// stops allocating scratch too.
    static PANEL_SCRATCH: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Native (no-PJRT) compute backend.
#[derive(Debug)]
pub struct NativeBackend {
    /// scratch for H = hadamard(us), reused across calls
    h_scratch: Mat,
    /// reused `[PANEL, s]` M/Y panel scratch (single-thread path)
    panel: Vec<f32>,
    /// per-panel loss partials (threaded path), folded in panel order
    loss_slots: Vec<f64>,
    /// row-panel worker threads (1 = deterministic default; floored by
    /// `CIDERTF_THREADS`)
    threads: usize,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend {
            h_scratch: Mat::zeros(0, 0),
            panel: Vec::new(),
            loss_slots: Vec::new(),
            threads: env_threads(),
        }
    }

    /// Backend with `threads` row-panel workers (see
    /// [`ComputeBackend::set_threads`]).
    pub fn with_threads(threads: usize) -> Self {
        let mut b = Self::new();
        b.threads = threads.max(1).max(env_threads());
        b
    }

    /// H = elementwise product of the D-1 row-gather matrices (fused
    /// two-operand fast path for the common D=3 case).
    fn hadamard_into<'a, I>(&mut self, first: &Mat, rest: I)
    where
        I: Iterator<Item = &'a Mat> + Clone,
    {
        let (s, r) = (first.rows, first.cols);
        if self.h_scratch.rows != s || self.h_scratch.cols != r {
            self.h_scratch = Mat::zeros(s, r);
        }
        let mut peek = rest.clone();
        match (peek.next(), peek.next()) {
            (Some(u), None) => {
                debug_assert_eq!((u.rows, u.cols), (s, r));
                mat::hadamard2_into(&first.data, &u.data, &mut self.h_scratch.data);
            }
            _ => {
                self.h_scratch.data.copy_from_slice(&first.data);
                for u in rest {
                    debug_assert_eq!((u.rows, u.cols), (s, r));
                    self.h_scratch.hadamard_assign(u);
                }
            }
        }
    }

    /// Panel-blocked gradient core. Expects `h_scratch` to already hold
    /// `H`; writes `scale * Y H` into `out` and returns the loss sum.
    #[allow(clippy::too_many_arguments)]
    fn grad_core(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        r_dim: usize,
        a: &Mat,
        scale: f32,
        out: &mut Mat,
    ) -> f64 {
        if out.rows != i_dim || out.cols != r_dim {
            *out = Mat::zeros(i_dim, r_dim);
        }
        out.fill(0.0);
        let NativeBackend { h_scratch, panel, loss_slots, threads } = self;
        let h = &h_scratch.data;
        let a_data = &a.data;

        let n_threads = if i_dim >= pool::thresholds::GRAD_PAR_MIN_ROWS {
            (*threads).min(i_dim / pool::thresholds::GRAD_MIN_ROWS_PER_THREAD).max(1)
        } else {
            1
        };

        let mut loss_sum = 0.0f64;
        if n_threads <= 1 {
            if panel.len() < PANEL * s_dim {
                panel.resize(PANEL * s_dim, 0.0);
            }
            let mut i0 = 0;
            while i0 < i_dim {
                let p = PANEL.min(i_dim - i0);
                loss_sum += panel_step(
                    loss,
                    xs,
                    i0,
                    p,
                    s_dim,
                    r_dim,
                    a_data,
                    h,
                    &mut panel[..p * s_dim],
                    &mut out.data[i0 * r_dim..(i0 + p) * r_dim],
                );
                i0 += p;
            }
        } else {
            // contiguous panel-aligned row chunks on the persistent pool:
            // each job owns a disjoint slice of `out` and writes one f64
            // loss partial per panel into `loss_slots`, which the calling
            // thread folds in panel order below — the same left fold the
            // single-thread loop performs, so both G and the loss sum are
            // bit-identical at every thread count
            let panels_total = i_dim.div_ceil(PANEL);
            let panels_per_job = panels_total.div_ceil(n_threads);
            let n_jobs = panels_total.div_ceil(panels_per_job);
            loss_slots.clear();
            loss_slots.resize(panels_total, 0.0);
            let out_ptr = pool::SendPtr::new(out.data.as_mut_ptr());
            let slot_ptr = pool::SendPtr::new(loss_slots.as_mut_ptr());
            pool::parallel_for(n_threads, n_jobs, &|job| {
                let p_start = job * panels_per_job;
                let p_end = (p_start + panels_per_job).min(panels_total);
                PANEL_SCRATCH.with(|cell| {
                    let mut scratch = cell.borrow_mut();
                    if scratch.len() < PANEL * s_dim {
                        scratch.resize(PANEL * s_dim, 0.0);
                    }
                    for pi in p_start..p_end {
                        let i0 = pi * PANEL;
                        let p = PANEL.min(i_dim - i0);
                        // lint: allow(unsafe-containment) — audited SendPtr write
                        // SAFETY: panel `pi` has one owning job (single
                        // in-bounds writer); `out` outlives the parallel_for.
                        let g = unsafe {
                            std::slice::from_raw_parts_mut(out_ptr.get().add(i0 * r_dim), p * r_dim)
                        };
                        let ls = panel_step(
                            loss,
                            xs,
                            i0,
                            p,
                            s_dim,
                            r_dim,
                            a_data,
                            h,
                            &mut scratch[..p * s_dim],
                            g,
                        );
                        // lint: allow(unsafe-containment) — audited SendPtr write
                        // SAFETY: loss slot `pi < panels_total` likewise has
                        // this job as its only writer; `loss_slots` outlives.
                        unsafe {
                            *slot_ptr.get().add(pi) = ls;
                        }
                    }
                });
            });
            for &ls in loss_slots.iter() {
                loss_sum += ls;
            }
        }
        out.scale(scale);
        loss_sum
    }

    /// The PR 2 scoped-spawn threaded gradient, kept as the measurement
    /// baseline for the `pool_speedup_vs_spawn` bench metric (spawns
    /// `n_threads` OS threads and allocates per-worker scratch on every
    /// call — exactly the costs the persistent pool removes).
    #[doc(hidden)]
    #[allow(clippy::too_many_arguments)]
    pub fn grad_spawn_reference(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[&Mat],
        scale: f32,
        n_threads: usize,
    ) -> (Mat, f64) {
        assert_eq!(xs.len(), i_dim * s_dim, "xs shape mismatch");
        self.hadamard_into(us[0], us[1..].iter().copied());
        let h = &self.h_scratch.data;
        let a_data = &a.data;
        let r_dim = a.cols;
        let mut out = Mat::zeros(i_dim, r_dim);
        let mut loss_sum = 0.0f64;
        let panels_total = i_dim.div_ceil(PANEL);
        let rows_per = panels_total.div_ceil(n_threads.max(1)) * PANEL;
        // lint: allow(raw-thread-spawn) — frozen PR 2 baseline kept only so
        // the bench can measure the pool's win; production paths use
        // runtime::pool::parallel_for
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n_threads);
            let mut rest: &mut [f32] = &mut out.data;
            let mut i0 = 0usize;
            while i0 < i_dim {
                let take = rows_per.min(i_dim - i0);
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take * r_dim);
                rest = tail;
                let start = i0;
                handles.push(scope.spawn(move || {
                    let mut scratch = vec![0.0f32; PANEL.min(take) * s_dim];
                    let mut ls = 0.0f64;
                    let mut off = 0;
                    while off < take {
                        let p = PANEL.min(take - off);
                        ls += panel_step(
                            loss,
                            xs,
                            start + off,
                            p,
                            s_dim,
                            r_dim,
                            a_data,
                            h,
                            &mut scratch[..p * s_dim],
                            &mut chunk[off * r_dim..(off + p) * r_dim],
                        );
                        off += p;
                    }
                    ls
                }));
                i0 += take;
            }
            for handle in handles {
                loss_sum += handle.join().expect("panel worker panicked");
            }
        });
        out.scale(scale);
        (out, loss_sum)
    }

    /// The pre-blocked scalar reference kernel (rowwise dots, allocates
    /// its output). Kept for the `bench` perf gate and differential tests
    /// against the blocked path.
    pub fn grad_naive(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[&Mat],
        scale: f32,
    ) -> anyhow::Result<(Mat, f64)> {
        anyhow::ensure!(!us.is_empty(), "need at least one row-gather matrix");
        anyhow::ensure!(xs.len() == i_dim * s_dim, "xs shape mismatch");
        anyhow::ensure!(a.rows == i_dim, "A shape mismatch");
        let r_dim = a.cols;
        self.hadamard_into(us[0], us[1..].iter().copied());
        let h = &self.h_scratch;

        let mut g = Mat::zeros(i_dim, r_dim);
        let mut loss_sum = 0.0f64;
        let mut m_row = vec![0.0f32; s_dim];
        for i in 0..i_dim {
            let a_row = a.row(i);
            // M(i,:) = A(i,:) · Hᵀ
            for (s, mv) in m_row.iter_mut().enumerate() {
                let h_row = h.row(s);
                let mut acc = 0.0f32;
                for (av, hv) in a_row.iter().zip(h_row.iter()) {
                    acc += av * hv;
                }
                *mv = acc;
            }
            // Y(i,:) = ∂f, fused with G(i,:) += Y(i,s) · H(s,:)
            let g_row = g.row_mut(i);
            let xs_row = &xs[i * s_dim..(i + 1) * s_dim];
            for s in 0..s_dim {
                let m = m_row[s];
                let x = xs_row[s];
                loss_sum += loss.value(m, x) as f64;
                let y = loss.grad(m, x);
                if y == 0.0 {
                    continue;
                }
                let h_row = h.row(s);
                for (gv, hv) in g_row.iter_mut().zip(h_row.iter()) {
                    *gv += y * hv;
                }
            }
        }
        g.scale(scale);
        Ok((g, loss_sum))
    }
}

/// One `[p, s]` row panel of the gradient: `M = A_panel Hᵀ` (blocked),
/// `Y = ∂f` in place, `G_panel += Y H` (accumulating). Returns the panel
/// loss sum, accumulated in row-major `(i, s)` order.
#[allow(clippy::too_many_arguments)]
fn panel_step(
    loss: Loss,
    xs: &[f32],
    i0: usize,
    p: usize,
    s_dim: usize,
    r_dim: usize,
    a: &[f32],
    h: &[f32],
    panel: &mut [f32],
    g: &mut [f32],
) -> f64 {
    let a_panel = &a[i0 * r_dim..(i0 + p) * r_dim];
    mat::gemm_transb_into(a_panel, h, panel, p, s_dim, r_dim);
    let mut loss_sum = 0.0f64;
    for (row, prow) in panel.chunks_exact_mut(s_dim).enumerate() {
        let xs_row = &xs[(i0 + row) * s_dim..(i0 + row + 1) * s_dim];
        for (mv, &x) in prow.iter_mut().zip(xs_row.iter()) {
            loss_sum += loss.value(*mv, x) as f64;
            *mv = loss.grad(*mv, x);
        }
    }
    mat::gemm_acc_into(panel, h, g, p, r_dim, s_dim);
    loss_sum
}

impl ComputeBackend for NativeBackend {
    fn grad(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[&Mat],
        scale: f32,
    ) -> anyhow::Result<(Mat, f64)> {
        anyhow::ensure!(!us.is_empty(), "need at least one row-gather matrix");
        anyhow::ensure!(xs.len() == i_dim * s_dim, "xs shape mismatch");
        anyhow::ensure!(a.rows == i_dim, "A shape mismatch");
        self.hadamard_into(us[0], us[1..].iter().copied());
        let mut g = Mat::zeros(i_dim, a.cols);
        let l = self.grad_core(loss, xs, i_dim, s_dim, a.cols, a, scale, &mut g);
        Ok((g, l))
    }

    #[allow(clippy::too_many_arguments)]
    fn grad_into(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[Mat],
        scale: f32,
        out: &mut Mat,
    ) -> anyhow::Result<f64> {
        anyhow::ensure!(!us.is_empty(), "need at least one row-gather matrix");
        anyhow::ensure!(xs.len() == i_dim * s_dim, "xs shape mismatch");
        anyhow::ensure!(a.rows == i_dim, "A shape mismatch");
        anyhow::ensure!(
            us.iter().all(|u| u.rows == s_dim && u.cols == a.cols),
            "U shape mismatch"
        );
        self.hadamard_into(&us[0], us[1..].iter());
        Ok(self.grad_core(loss, xs, i_dim, s_dim, a.cols, a, scale, out))
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1).max(env_threads());
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn eval(&mut self, loss: Loss, x: &[f32], us: &[&Mat]) -> anyhow::Result<f64> {
        let b = x.len();
        anyhow::ensure!(us.iter().all(|u| u.rows == b), "U shape mismatch");
        let r_dim = us[0].cols;
        let mut sum = 0.0f64;
        let mut prod = vec![0.0f32; r_dim];
        for e in 0..b {
            prod.copy_from_slice(us[0].row(e));
            for u in &us[1..] {
                for (p, v) in prod.iter_mut().zip(u.row(e).iter()) {
                    *p *= v;
                }
            }
            let m: f32 = prod.iter().sum();
            sum += loss.value(m, x[e]) as f64;
        }
        Ok(sum)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat::rand_normal(rows, cols, 0.4, rng)
    }

    /// Straight-line oracle with no fusion/scratch tricks.
    fn oracle_grad(loss: Loss, xs: &[f32], i: usize, s: usize, a: &Mat, h: &Mat, scale: f32) -> (Mat, f64) {
        let m = a.matmul_transb(h); // [i, s]
        let mut y = Mat::zeros(i, s);
        let mut lsum = 0.0f64;
        for r in 0..i {
            for c in 0..s {
                lsum += loss.value(m.at(r, c), xs[r * s + c]) as f64;
                *y.at_mut(r, c) = loss.grad(m.at(r, c), xs[r * s + c]);
            }
        }
        let mut g = y.matmul(h);
        g.scale(scale);
        (g, lsum)
    }

    #[test]
    fn grad_matches_oracle_both_losses() {
        let mut rng = Rng::new(21);
        let (i, s, r) = (13, 9, 5);
        for loss in [Loss::Ls, Loss::Logit] {
            let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
            let a = randmat(i, r, &mut rng);
            let u1 = randmat(s, r, &mut rng);
            let u2 = randmat(s, r, &mut rng);
            let mut h = u1.clone();
            h.hadamard_assign(&u2);
            let mut be = NativeBackend::new();
            let (g, l) = be.grad(loss, &xs, i, s, &a, &[&u1, &u2], 1.7).unwrap();
            let (g2, l2) = oracle_grad(loss, &xs, i, s, &a, &h, 1.7);
            for (x, y) in g.data.iter().zip(g2.data.iter()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
            assert!((l - l2).abs() / l2.abs().max(1.0) < 1e-5);
        }
    }

    #[test]
    fn blocked_matches_naive_reference() {
        // the blocked panel path must agree with the pre-blocked scalar
        // kernel across panel-edge shapes (i below, at, and above PANEL)
        let mut rng = Rng::new(25);
        for (i, s, r) in [(5, 9, 4), (32, 16, 8), (33, 16, 8), (71, 24, 5)] {
            for loss in [Loss::Ls, Loss::Logit] {
                let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
                let a = randmat(i, r, &mut rng);
                let u1 = randmat(s, r, &mut rng);
                let u2 = randmat(s, r, &mut rng);
                let mut be = NativeBackend::new();
                let (g_b, l_b) = be.grad(loss, &xs, i, s, &a, &[&u1, &u2], 1.3).unwrap();
                let (g_n, l_n) = be.grad_naive(loss, &xs, i, s, &a, &[&u1, &u2], 1.3).unwrap();
                for (x, y) in g_b.data.iter().zip(g_n.data.iter()) {
                    assert!((x - y).abs() < 1e-4, "({i},{s},{r}) {loss:?}: {x} vs {y}");
                }
                assert!((l_b - l_n).abs() / l_n.abs().max(1.0) < 1e-5);
            }
        }
    }

    #[test]
    fn grad_into_is_bit_identical_to_grad() {
        let mut rng = Rng::new(26);
        let (i, s, r) = (40, 12, 6);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let us: Vec<Mat> = (0..2).map(|_| randmat(s, r, &mut rng)).collect();
        let refs: Vec<&Mat> = us.iter().collect();
        let mut be = NativeBackend::new();
        let (g, l) = be.grad(Loss::Logit, &xs, i, s, &a, &refs, 0.5).unwrap();
        let mut out = Mat::zeros(i, r);
        let l2 = be.grad_into(Loss::Logit, &xs, i, s, &a, &us, 0.5, &mut out).unwrap();
        assert_eq!(g.data, out.data);
        assert_eq!(l, l2);
    }

    #[test]
    fn threads_do_not_change_gradient_or_loss() {
        // the lane-deterministic kernels make G bit-identical across
        // thread counts, and the per-panel loss slots folded in panel
        // order make the loss sum bit-identical too — at every width
        let mut rng = Rng::new(27);
        // non-multiple of PANEL so the last panel is ragged
        let (i, s, r) = (4 * pool::thresholds::GRAD_PAR_MIN_ROWS + 37, 16, 4);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let us: Vec<Mat> = (0..2).map(|_| randmat(s, r, &mut rng)).collect();
        let mut out1 = Mat::zeros(i, r);
        let mut be1 = NativeBackend::new();
        be1.threads = 1; // pin below any CIDERTF_THREADS floor: the reference
        let l1 = be1.grad_into(Loss::Ls, &xs, i, s, &a, &us, 1.0, &mut out1).unwrap();
        for threads in [2, 4, 8] {
            let mut out_t = Mat::zeros(i, r);
            let mut be_t = NativeBackend::with_threads(threads);
            let l_t = be_t.grad_into(Loss::Ls, &xs, i, s, &a, &us, 1.0, &mut out_t).unwrap();
            assert_eq!(out1.data, out_t.data, "{threads} threads changed the gradient");
            assert_eq!(l1.to_bits(), l_t.to_bits(), "{threads} threads changed the loss sum");
        }
    }

    #[test]
    fn spawn_reference_matches_pooled_gradient() {
        // the frozen scoped-spawn baseline must stay numerically honest:
        // identical G bitwise (same panel kernels), loss equal up to the
        // chunk-fold association
        let mut rng = Rng::new(28);
        let (i, s, r) = (2 * pool::thresholds::GRAD_PAR_MIN_ROWS, 16, 4);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let us: Vec<Mat> = (0..2).map(|_| randmat(s, r, &mut rng)).collect();
        let refs: Vec<&Mat> = us.iter().collect();
        let mut be = NativeBackend::with_threads(4);
        let (g_pool, l_pool) = be.grad(Loss::Ls, &xs, i, s, &a, &refs, 1.0).unwrap();
        let (g_spawn, l_spawn) = be.grad_spawn_reference(Loss::Ls, &xs, i, s, &a, &refs, 1.0, 4);
        assert_eq!(g_pool.data, g_spawn.data);
        assert!((l_pool - l_spawn).abs() / l_pool.abs().max(1.0) < 1e-12);
    }

    #[test]
    fn eval_matches_manual() {
        let mut rng = Rng::new(22);
        let (b, r) = (31, 4);
        let us: Vec<Mat> = (0..3).map(|_| randmat(b, r, &mut rng)).collect();
        let x: Vec<f32> = (0..b).map(|_| rng.normal_f32()).collect();
        let mut be = NativeBackend::new();
        let refs: Vec<&Mat> = us.iter().collect();
        let got = be.eval(Loss::Ls, &x, &refs).unwrap();
        let mut want = 0.0f64;
        for e in 0..b {
            let mut m = 0.0f32;
            for rr in 0..r {
                m += us[0].at(e, rr) * us[1].at(e, rr) * us[2].at(e, rr);
            }
            want += Loss::Ls.value(m, x[e]) as f64;
        }
        assert!((got - want).abs() < 1e-6 * want.abs().max(1.0));
    }

    #[test]
    fn order4_hadamard_chain() {
        let mut rng = Rng::new(23);
        let (i, s, r) = (6, 7, 3);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let us: Vec<Mat> = (0..3).map(|_| randmat(s, r, &mut rng)).collect();
        let refs: Vec<&Mat> = us.iter().collect();
        let mut h = us[0].clone();
        h.hadamard_assign(&us[1]);
        h.hadamard_assign(&us[2]);
        let mut be = NativeBackend::new();
        let (g, _) = be.grad(Loss::Ls, &xs, i, s, &a, &refs, 1.0).unwrap();
        let (g2, _) = oracle_grad(Loss::Ls, &xs, i, s, &a, &h, 1.0);
        for (x, y) in g.data.iter().zip(g2.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn scale_is_applied() {
        let mut rng = Rng::new(24);
        let (i, s, r) = (4, 5, 2);
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32()).collect();
        let a = randmat(i, r, &mut rng);
        let u1 = randmat(s, r, &mut rng);
        let u2 = randmat(s, r, &mut rng);
        let mut be = NativeBackend::new();
        let (g1, l1) = be.grad(Loss::Ls, &xs, i, s, &a, &[&u1, &u2], 1.0).unwrap();
        let (g2, l2) = be.grad(Loss::Ls, &xs, i, s, &a, &[&u1, &u2], 3.0).unwrap();
        for (x, y) in g1.data.iter().zip(g2.data.iter()) {
            assert!((3.0 * x - y).abs() < 1e-4);
        }
        assert!((l1 - l2).abs() < 1e-9, "loss is unscaled");
    }
}
