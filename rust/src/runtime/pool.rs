//! The persistent compute worker pool.
//!
//! Every threaded hot path in the crate — the native backend's row-panel
//! gradient, the CSF fiber gather, and the sweep executor — funnels
//! through [`parallel_for`] here instead of spawning scoped threads per
//! call. Workers are started lazily on first use, parked on a condvar
//! between calls, and **never exit**: sequential `Session::run`s reuse
//! the same OS threads, so repeated runs neither leak threads nor pay
//! spawn latency (~50µs per thread per call with scoped spawns, versus a
//! single unpark here — that gap is what lets the engagement thresholds
//! in [`thresholds`] drop an order of magnitude below PR 2's
//! `i >= 2048`).
//!
//! # Determinism
//!
//! The pool itself never reduces anything. [`parallel_for`] hands out job
//! indices `0..n_jobs`; callers write each job's result into a
//! caller-owned slot indexed by job id (disjoint writes via [`SendPtr`])
//! and fold the slots **in job order** on the calling thread afterwards.
//! Which worker ran which job — and in what interleaving — is therefore
//! unobservable. `threads <= 1` never touches the pool at all: jobs run
//! inline on the caller, which is the bitwise-identical default path.
//!
//! # Scheduling
//!
//! One global FIFO of active tasks guarded by a mutex. The caller posts
//! its task, wakes the workers, then **participates**: it claims job
//! indices exactly like a worker until the task is drained, then blocks
//! only for stragglers. Caller participation makes nested `parallel_for`
//! calls (a sweep worker stepping a backend whose `compute_threads > 1`)
//! deadlock-free by construction — the inner call always makes progress
//! on its own thread even when every pool worker is busy with outer
//! jobs.
//!
//! # Auditing
//!
//! The lock-free claim/panic-propagation protocol is factored into the
//! [`claim`] state machine: the production claim loop and the bounded
//! exhaustive model checker (`rust/tests/pool_model.rs`) drive the same
//! [`claim::step`] transition function, so the interleavings the checker
//! enumerates are the interleavings this file can exhibit. This module
//! is the only place in the crate allowed to call `std::thread::spawn`
//! (lint rule D007) and one of the two files where `unsafe` may live at
//! all (rule D008) — see `xtask/src/lint.rs`.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Per-kernel threading engagement thresholds, derived from measured
/// crossover on the persistent pool (see the table in ARCHITECTURE.md
/// §"Compute core" — each constant is the smallest size where the
/// threaded path beat single-thread on the bench host, rounded down to a
/// power of two).
pub mod thresholds {
    /// Minimum gradient rows handed to one thread: panels are chunked so
    /// no thread owns fewer rows than this.
    pub const GRAD_MIN_ROWS_PER_THREAD: usize = 256;
    /// Row count below which the gradient runs single-threaded (two
    /// threads need at least a chunk each to win).
    pub const GRAD_PAR_MIN_ROWS: usize = 2 * GRAD_MIN_ROWS_PER_THREAD;
    /// Output cells (`i_dim * s`) below which a fiber gather runs
    /// serially — gathers are pure memory traffic, so the crossover sits
    /// far above the compute kernels'.
    pub const GATHER_PAR_MIN_CELLS: usize = 1 << 19;
    /// Rows per zero-fill job in the gather's clear phase.
    pub const GATHER_ROWS_PER_JOB: usize = 2048;
}

/// The claim/steal/panic-propagation protocol of [`parallel_for`],
/// extracted as an explicit state machine over a small trait of
/// shared-memory operations.
///
/// Production code and the bounded model checker
/// (`rust/tests/pool_model.rs`) execute the *same* [`step`] transition
/// function: the pool's claim loop drives it with [`ClaimOps`]
/// implemented by the real atomics on a live task, while the checker
/// drives it with simulated memory under an exhaustive scheduler. Each trait method
/// performs exactly one shared-memory action (one atomic instruction,
/// or one mutex-serialized section), so interleaving model threads at
/// method-call granularity explores exactly the reorderings real
/// threads can exhibit at this protocol's abstraction level.
pub mod claim {
    /// The shared-memory operations of one claim-loop participant. Every
    /// method is a single atomic action; [`step`] never touches shared
    /// state except through these.
    pub trait ClaimOps {
        /// Atomically claim the next job index (fetch-add on the claim
        /// cursor). Claims `>= n()` mean the task is drained.
        fn claim(&self) -> usize;
        /// Total number of jobs (immutable after task creation — reading
        /// it is not a shared-memory step).
        fn n(&self) -> usize;
        /// Run job `slot` under a panic guard. Returns `true` if the job
        /// panicked (the payload is held locally until `offer_payload`).
        fn run(&self, slot: usize) -> bool;
        /// Raise the task-wide panicked flag.
        fn set_panicked(&self);
        /// Publish this participant's caught payload unless another
        /// panic won the race (first payload wins, under the payload
        /// mutex).
        fn offer_payload(&self, slot: usize);
        /// Decrement the unfinished-job count; `true` iff this was the
        /// final job.
        fn finish(&self) -> bool;
        /// Wake the caller parked on the done condvar.
        fn notify_done(&self);
    }

    /// Program counter of one claim-loop participant. `Exit` is
    /// terminal: the participant has observed the task drained.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
    pub enum Pc {
        /// About to claim the next job index.
        Claim,
        /// Claimed job `slot`, about to run it.
        Run(usize),
        /// Job `slot` panicked; about to raise the panicked flag.
        SetPanicked(usize),
        /// About to offer job `slot`'s panic payload (first wins).
        OfferPayload(usize),
        /// About to decrement the unfinished-job count.
        Finish,
        /// Final job finished; about to wake the caller.
        NotifyDone,
        /// Saw a claim `>= n`: this participant is done with the task.
        Exit,
    }

    /// Advance one participant by exactly one protocol step. The entire
    /// claim loop is `step` iterated from [`Pc::Claim`] to [`Pc::Exit`].
    pub fn step<O: ClaimOps + ?Sized>(pc: Pc, ops: &O) -> Pc {
        match pc {
            Pc::Claim => {
                let slot = ops.claim();
                if slot >= ops.n() {
                    Pc::Exit
                } else {
                    Pc::Run(slot)
                }
            }
            Pc::Run(slot) => {
                if ops.run(slot) {
                    Pc::SetPanicked(slot)
                } else {
                    Pc::Finish
                }
            }
            Pc::SetPanicked(slot) => {
                ops.set_panicked();
                Pc::OfferPayload(slot)
            }
            Pc::OfferPayload(slot) => {
                ops.offer_payload(slot);
                Pc::Finish
            }
            Pc::Finish => {
                if ops.finish() {
                    Pc::NotifyDone
                } else {
                    Pc::Claim
                }
            }
            Pc::NotifyDone => {
                ops.notify_done();
                Pc::Claim
            }
            Pc::Exit => Pc::Exit,
        }
    }
}

/// One posted `parallel_for` call.
struct Task {
    /// The job body. Lifetime-erased to `'static`: sound because
    /// [`parallel_for`] does not return until every claimed job has
    /// finished, and no job is claimed after `next` passes `n`.
    func: &'static (dyn Fn(usize) + Sync),
    /// Next unclaimed job index (may run past `n`; claims `>= n` are
    /// no-ops).
    next: AtomicUsize,
    /// Total jobs.
    n: usize,
    /// Jobs not yet finished; the task is complete at zero.
    remaining: AtomicUsize,
    /// Set when any job panicked.
    panicked: AtomicBool,
    /// First panic payload, re-thrown on the calling thread.
    payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Task {
    fn drained(&self) -> bool {
        // ordering: Relaxed — queue-GC heuristic read under the pool
        // mutex; a stale value only delays popping a drained task
        self.next.load(Ordering::Relaxed) >= self.n
    }
}

struct Inner {
    /// Active tasks, oldest first. A task stays queued until drained
    /// (fully claimed); completion is tracked by `Task::remaining`.
    queue: VecDeque<Arc<Task>>,
    /// Worker threads spawned so far (they never exit).
    workers: usize,
}

struct Shared {
    inner: Mutex<Inner>,
    /// Workers park here between tasks.
    work_cv: Condvar,
    /// Callers park here waiting for straggler jobs.
    done_cv: Condvar,
}

static POOL: OnceLock<Arc<Shared>> = OnceLock::new();

fn shared() -> &'static Arc<Shared> {
    POOL.get_or_init(|| {
        Arc::new(Shared {
            inner: Mutex::new(Inner { queue: VecDeque::new(), workers: 0 }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        })
    })
}

/// [`claim::ClaimOps`] over a live [`Task`]: each method is one
/// shared-memory action of the claim protocol, carrying the concrete
/// atomic orderings the model checker's simulated memory abstracts away.
struct TaskClaim<'a> {
    shared: &'a Shared,
    task: &'a Task,
    /// Panic payload caught by `run`, handed to `offer_payload`. Local
    /// to this participant — not shared state.
    caught: Cell<Option<Box<dyn std::any::Any + Send>>>,
}

impl claim::ClaimOps for TaskClaim<'_> {
    fn claim(&self) -> usize {
        // ordering: Relaxed — slot uniqueness needs only the RMW's
        // atomicity; visibility of each job's effects is published by
        // finish()'s AcqRel on `remaining`, not by this cursor
        self.task.next.fetch_add(1, Ordering::Relaxed)
    }

    fn n(&self) -> usize {
        self.task.n
    }

    fn run(&self, slot: usize) -> bool {
        match catch_unwind(AssertUnwindSafe(|| (self.task.func)(slot))) {
            Ok(()) => false,
            Err(p) => {
                self.caught.set(Some(p));
                true
            }
        }
    }

    fn set_panicked(&self) {
        // ordering: Release — pairs with the caller's Acquire load after
        // its wait loop, making the flag visible once `remaining` is 0
        self.task.panicked.store(true, Ordering::Release);
    }

    fn offer_payload(&self, _slot: usize) {
        let mine = self.caught.take();
        let mut payload = self.task.payload.lock().unwrap();
        if payload.is_none() {
            *payload = mine;
        }
    }

    fn finish(&self) -> bool {
        // ordering: AcqRel — the release half publishes this job's
        // writes; the acquire half on the final decrement orders every
        // job's writes before the caller's wakeup
        self.task.remaining.fetch_sub(1, Ordering::AcqRel) == 1
    }

    fn notify_done(&self) {
        // last job: wake the caller (lock first so the caller cannot
        // miss the notification between its check and its wait)
        let _guard = self.shared.inner.lock().unwrap();
        self.shared.done_cv.notify_all();
    }
}

/// Claim-and-run loop shared by workers and the posting caller: drives
/// the [`claim`] state machine over the live task until it reports
/// [`claim::Pc::Exit`] (every job body runs under `catch_unwind`, so a
/// panicking job cannot wedge the pool).
fn execute(shared: &Shared, task: &Task) {
    let ops = TaskClaim { shared, task, caught: Cell::new(None) };
    let mut pc = claim::Pc::Claim;
    while pc != claim::Pc::Exit {
        pc = claim::step(pc, &ops);
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut guard = shared.inner.lock().unwrap();
    loop {
        while guard.queue.front().is_some_and(|t| t.drained()) {
            guard.queue.pop_front();
        }
        match guard.queue.front().cloned() {
            Some(task) => {
                drop(guard);
                execute(&shared, &task);
                guard = shared.inner.lock().unwrap();
            }
            None => {
                guard = shared.work_cv.wait(guard).unwrap();
            }
        }
    }
}

/// Run `f(0), f(1), …, f(n_jobs - 1)` across at most `threads` threads
/// (the caller counts as one) and return when all jobs have finished.
///
/// * `threads <= 1` or `n_jobs <= 1`: every job runs inline on the
///   caller, in index order, without touching the pool — the bitwise
///   reference path.
/// * Otherwise the pool is lazily grown to `min(threads, n_jobs) - 1`
///   parked workers and jobs are claimed dynamically. Job *indices* are
///   deterministic; job-to-thread assignment is not, so `f` must confine
///   each job's effect to job-indexed state (see [`SendPtr`]) and the
///   caller must do any cross-job reduction itself, in index order.
///
/// A panic in any job is re-thrown on the calling thread after all jobs
/// finish.
pub fn parallel_for(threads: usize, n_jobs: usize, f: &(dyn Fn(usize) + Sync)) {
    if threads <= 1 || n_jobs <= 1 {
        for i in 0..n_jobs {
            f(i);
        }
        return;
    }
    let shared = shared();
    // SAFETY: `f` cannot escape this call — we block below until
    // `remaining == 0`, and only claimed jobs (slot < n) dereference it.
    let func: &'static (dyn Fn(usize) + Sync) =
        unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(f) };
    let task = Arc::new(Task {
        func,
        next: AtomicUsize::new(0),
        n: n_jobs,
        remaining: AtomicUsize::new(n_jobs),
        panicked: AtomicBool::new(false),
        payload: Mutex::new(None),
    });
    {
        let mut guard = shared.inner.lock().unwrap();
        let want = threads.min(n_jobs) - 1;
        while guard.workers < want {
            let pool = Arc::clone(shared);
            let id = guard.workers;
            std::thread::Builder::new()
                .name(format!("cidertf-pool-{id}"))
                .spawn(move || worker_loop(pool))
                .expect("spawn pool worker");
            guard.workers += 1;
        }
        guard.queue.push_back(Arc::clone(&task));
    }
    shared.work_cv.notify_all();
    execute(shared, &task);
    let mut guard = shared.inner.lock().unwrap();
    // ordering: Acquire — pairs with finish()'s AcqRel decrements, so
    // every job's writes are visible once this reads zero
    while task.remaining.load(Ordering::Acquire) > 0 {
        guard = shared.done_cv.wait(guard).unwrap();
    }
    guard.queue.retain(|t| !Arc::ptr_eq(t, &task));
    drop(guard);
    // ordering: Acquire — pairs with set_panicked()'s Release store
    if task.panicked.load(Ordering::Acquire) {
        let payload = task.payload.lock().unwrap().take();
        match payload {
            Some(p) => std::panic::resume_unwind(p),
            None => panic!("pool job panicked"),
        }
    }
}

/// Worker threads currently alive in the pool (0 until the first
/// multi-threaded [`parallel_for`]). Monotone: workers are reused across
/// calls and sessions, never dropped — the thread-leak test pins this.
pub fn worker_count() -> usize {
    match POOL.get() {
        Some(s) => s.inner.lock().unwrap().workers,
        None => 0,
    }
}

/// Shareable raw pointer for disjoint job-indexed writes from pool jobs.
///
/// `parallel_for` job bodies often need `&mut` access into one shared
/// output buffer (each job owning a disjoint range). Rust's closure
/// captures can't express that, so jobs capture a `SendPtr` to the
/// buffer base and offset it by their job index. **Safety contract**
/// (on the caller): distinct jobs must write disjoint ranges, and the
/// pointee must outlive the `parallel_for` call.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

// SAFETY: SendPtr is a plain pointer wrapper; the disjoint-write and
// outlives-the-call contract documented above is discharged by every
// caller at its use site (each carries its own SAFETY comment).
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: sharing &SendPtr across threads only copies the pointer
// value; all writes through it obey the caller's disjointness contract.
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Wrap a base pointer (typically `slice.as_mut_ptr()`).
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    /// The wrapped pointer.
    pub fn get(self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_path_matches_threaded_results() {
        let n = 103;
        for threads in [1, 2, 4, 8] {
            let out: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            parallel_for(threads, n, &|i| {
                out[i].store(i * i + 1, Ordering::Relaxed);
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(v.load(Ordering::Relaxed), i * i + 1, "threads={threads} job {i}");
            }
        }
    }

    #[test]
    fn disjoint_sendptr_writes_land() {
        let n = 64;
        let mut buf = vec![0u64; n * 4];
        let base = SendPtr::new(buf.as_mut_ptr());
        parallel_for(4, n, &|i| {
            // SAFETY: each job writes only its own 4-element range
            let p = unsafe { std::slice::from_raw_parts_mut(base.get().add(i * 4), 4) };
            for (k, v) in p.iter_mut().enumerate() {
                *v = (i * 10 + k) as u64;
            }
        });
        for i in 0..n {
            for k in 0..4 {
                assert_eq!(buf[i * 4 + k], (i * 10 + k) as u64);
            }
        }
    }

    #[test]
    fn nested_calls_complete() {
        // a job body issuing its own parallel_for (sweep worker stepping
        // a threaded backend) must not deadlock: callers participate, so
        // the inner call progresses even with all workers busy
        let total = AtomicUsize::new(0);
        parallel_for(4, 8, &|_| {
            parallel_for(4, 8, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn job_panic_propagates_to_caller() {
        let hit = std::panic::catch_unwind(|| {
            parallel_for(2, 16, &|i| {
                if i == 7 {
                    panic!("job seven");
                }
            });
        });
        let err = hit.expect_err("panic must propagate");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "job seven");
        // the pool must remain usable afterwards
        let n = AtomicUsize::new(0);
        parallel_for(2, 16, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn workers_are_reused_not_leaked() {
        // warm the pool to the widest width any test in this binary uses
        // (8 threads -> 7 workers); from then on the count must be
        // stable, no matter how many calls run or what other tests do
        parallel_for(8, 64, &|_| {});
        let baseline = worker_count();
        assert!(baseline >= 7, "pool grows to threads-1 workers, got {baseline}");
        for _ in 0..20 {
            parallel_for(8, 64, &|_| {});
        }
        assert_eq!(worker_count(), baseline, "repeated calls must not spawn more workers");
    }
}
