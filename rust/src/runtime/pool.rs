//! The persistent compute worker pool.
//!
//! Every threaded hot path in the crate — the native backend's row-panel
//! gradient, the CSF fiber gather, and the sweep executor — funnels
//! through [`parallel_for`] here instead of spawning scoped threads per
//! call. Workers are started lazily on first use, parked on a condvar
//! between calls, and **never exit**: sequential `Session::run`s reuse
//! the same OS threads, so repeated runs neither leak threads nor pay
//! spawn latency (~50µs per thread per call with scoped spawns, versus a
//! single unpark here — that gap is what lets the engagement thresholds
//! in [`thresholds`] drop an order of magnitude below PR 2's
//! `i >= 2048`).
//!
//! # Determinism
//!
//! The pool itself never reduces anything. [`parallel_for`] hands out job
//! indices `0..n_jobs`; callers write each job's result into a
//! caller-owned slot indexed by job id (disjoint writes via [`SendPtr`])
//! and fold the slots **in job order** on the calling thread afterwards.
//! Which worker ran which job — and in what interleaving — is therefore
//! unobservable. `threads <= 1` never touches the pool at all: jobs run
//! inline on the caller, which is the bitwise-identical default path.
//!
//! # Scheduling
//!
//! One global FIFO of active tasks guarded by a mutex. The caller posts
//! its task, wakes the workers, then **participates**: it claims job
//! indices exactly like a worker until the task is drained, then blocks
//! only for stragglers. Caller participation makes nested `parallel_for`
//! calls (a sweep worker stepping a backend whose `compute_threads > 1`)
//! deadlock-free by construction — the inner call always makes progress
//! on its own thread even when every pool worker is busy with outer
//! jobs.
//!
//! This module is the only place in the crate allowed to call
//! `std::thread::spawn` (lint rule D007 — see `xtask/src/lint.rs`).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Per-kernel threading engagement thresholds, derived from measured
/// crossover on the persistent pool (see the table in ARCHITECTURE.md
/// §"Compute core" — each constant is the smallest size where the
/// threaded path beat single-thread on the bench host, rounded down to a
/// power of two).
pub mod thresholds {
    /// Minimum gradient rows handed to one thread: panels are chunked so
    /// no thread owns fewer rows than this.
    pub const GRAD_MIN_ROWS_PER_THREAD: usize = 256;
    /// Row count below which the gradient runs single-threaded (two
    /// threads need at least a chunk each to win).
    pub const GRAD_PAR_MIN_ROWS: usize = 2 * GRAD_MIN_ROWS_PER_THREAD;
    /// Output cells (`i_dim * s`) below which a fiber gather runs
    /// serially — gathers are pure memory traffic, so the crossover sits
    /// far above the compute kernels'.
    pub const GATHER_PAR_MIN_CELLS: usize = 1 << 19;
    /// Rows per zero-fill job in the gather's clear phase.
    pub const GATHER_ROWS_PER_JOB: usize = 2048;
}

/// One posted `parallel_for` call.
struct Task {
    /// The job body. Lifetime-erased to `'static`: sound because
    /// [`parallel_for`] does not return until every claimed job has
    /// finished, and no job is claimed after `next` passes `n`.
    func: &'static (dyn Fn(usize) + Sync),
    /// Next unclaimed job index (may run past `n`; claims `>= n` are
    /// no-ops).
    next: AtomicUsize,
    /// Total jobs.
    n: usize,
    /// Jobs not yet finished; the task is complete at zero.
    remaining: AtomicUsize,
    /// Set when any job panicked.
    panicked: AtomicBool,
    /// First panic payload, re-thrown on the calling thread.
    payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Task {
    fn drained(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.n
    }
}

struct Inner {
    /// Active tasks, oldest first. A task stays queued until drained
    /// (fully claimed); completion is tracked by `Task::remaining`.
    queue: VecDeque<Arc<Task>>,
    /// Worker threads spawned so far (they never exit).
    workers: usize,
}

struct Shared {
    inner: Mutex<Inner>,
    /// Workers park here between tasks.
    work_cv: Condvar,
    /// Callers park here waiting for straggler jobs.
    done_cv: Condvar,
}

static POOL: OnceLock<Arc<Shared>> = OnceLock::new();

fn shared() -> &'static Arc<Shared> {
    POOL.get_or_init(|| {
        Arc::new(Shared {
            inner: Mutex::new(Inner { queue: VecDeque::new(), workers: 0 }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        })
    })
}

/// Claim-and-run loop shared by workers and the posting caller: claims
/// job indices until the task is drained, running each body under
/// `catch_unwind` so a panicking job cannot wedge the pool.
fn execute(shared: &Shared, task: &Task) {
    loop {
        let slot = task.next.fetch_add(1, Ordering::Relaxed);
        if slot >= task.n {
            return;
        }
        let result = catch_unwind(AssertUnwindSafe(|| (task.func)(slot)));
        if let Err(p) = result {
            task.panicked.store(true, Ordering::Release);
            let mut payload = task.payload.lock().unwrap();
            if payload.is_none() {
                *payload = Some(p);
            }
        }
        if task.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last job: wake the caller (lock first so the caller cannot
            // miss the notification between its check and its wait)
            let _guard = shared.inner.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut guard = shared.inner.lock().unwrap();
    loop {
        while guard.queue.front().is_some_and(|t| t.drained()) {
            guard.queue.pop_front();
        }
        match guard.queue.front().cloned() {
            Some(task) => {
                drop(guard);
                execute(&shared, &task);
                guard = shared.inner.lock().unwrap();
            }
            None => {
                guard = shared.work_cv.wait(guard).unwrap();
            }
        }
    }
}

/// Run `f(0), f(1), …, f(n_jobs - 1)` across at most `threads` threads
/// (the caller counts as one) and return when all jobs have finished.
///
/// * `threads <= 1` or `n_jobs <= 1`: every job runs inline on the
///   caller, in index order, without touching the pool — the bitwise
///   reference path.
/// * Otherwise the pool is lazily grown to `min(threads, n_jobs) - 1`
///   parked workers and jobs are claimed dynamically. Job *indices* are
///   deterministic; job-to-thread assignment is not, so `f` must confine
///   each job's effect to job-indexed state (see [`SendPtr`]) and the
///   caller must do any cross-job reduction itself, in index order.
///
/// A panic in any job is re-thrown on the calling thread after all jobs
/// finish.
pub fn parallel_for(threads: usize, n_jobs: usize, f: &(dyn Fn(usize) + Sync)) {
    if threads <= 1 || n_jobs <= 1 {
        for i in 0..n_jobs {
            f(i);
        }
        return;
    }
    let shared = shared();
    // SAFETY: the task never outlives this call — we block below until
    // `remaining == 0`, and workers only dereference `func` for claimed
    // slots `< n`, all of which are counted by `remaining`. After the
    // task drains, every further claim is `>= n` and returns without
    // touching `func`.
    let func: &'static (dyn Fn(usize) + Sync) =
        unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(f) };
    let task = Arc::new(Task {
        func,
        next: AtomicUsize::new(0),
        n: n_jobs,
        remaining: AtomicUsize::new(n_jobs),
        panicked: AtomicBool::new(false),
        payload: Mutex::new(None),
    });
    {
        let mut guard = shared.inner.lock().unwrap();
        let want = threads.min(n_jobs) - 1;
        while guard.workers < want {
            let pool = Arc::clone(shared);
            let id = guard.workers;
            std::thread::Builder::new()
                .name(format!("cidertf-pool-{id}"))
                .spawn(move || worker_loop(pool))
                .expect("spawn pool worker");
            guard.workers += 1;
        }
        guard.queue.push_back(Arc::clone(&task));
    }
    shared.work_cv.notify_all();
    execute(shared, &task);
    let mut guard = shared.inner.lock().unwrap();
    while task.remaining.load(Ordering::Acquire) > 0 {
        guard = shared.done_cv.wait(guard).unwrap();
    }
    guard.queue.retain(|t| !Arc::ptr_eq(t, &task));
    drop(guard);
    if task.panicked.load(Ordering::Acquire) {
        let payload = task.payload.lock().unwrap().take();
        match payload {
            Some(p) => std::panic::resume_unwind(p),
            None => panic!("pool job panicked"),
        }
    }
}

/// Worker threads currently alive in the pool (0 until the first
/// multi-threaded [`parallel_for`]). Monotone: workers are reused across
/// calls and sessions, never dropped — the thread-leak test pins this.
pub fn worker_count() -> usize {
    match POOL.get() {
        Some(s) => s.inner.lock().unwrap().workers,
        None => 0,
    }
}

/// Shareable raw pointer for disjoint job-indexed writes from pool jobs.
///
/// `parallel_for` job bodies often need `&mut` access into one shared
/// output buffer (each job owning a disjoint range). Rust's closure
/// captures can't express that, so jobs capture a `SendPtr` to the
/// buffer base and offset it by their job index. **Safety contract**
/// (on the caller): distinct jobs must write disjoint ranges, and the
/// pointee must outlive the `parallel_for` call.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Wrap a base pointer (typically `slice.as_mut_ptr()`).
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    /// The wrapped pointer.
    pub fn get(self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_path_matches_threaded_results() {
        let n = 103;
        for threads in [1, 2, 4, 8] {
            let out: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            parallel_for(threads, n, &|i| {
                out[i].store(i * i + 1, Ordering::Relaxed);
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(v.load(Ordering::Relaxed), i * i + 1, "threads={threads} job {i}");
            }
        }
    }

    #[test]
    fn disjoint_sendptr_writes_land() {
        let n = 64;
        let mut buf = vec![0u64; n * 4];
        let base = SendPtr::new(buf.as_mut_ptr());
        parallel_for(4, n, &|i| {
            // SAFETY: each job writes only its own 4-element range
            let p = unsafe { std::slice::from_raw_parts_mut(base.get().add(i * 4), 4) };
            for (k, v) in p.iter_mut().enumerate() {
                *v = (i * 10 + k) as u64;
            }
        });
        for i in 0..n {
            for k in 0..4 {
                assert_eq!(buf[i * 4 + k], (i * 10 + k) as u64);
            }
        }
    }

    #[test]
    fn nested_calls_complete() {
        // a job body issuing its own parallel_for (sweep worker stepping
        // a threaded backend) must not deadlock: callers participate, so
        // the inner call progresses even with all workers busy
        let total = AtomicUsize::new(0);
        parallel_for(4, 8, &|_| {
            parallel_for(4, 8, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn job_panic_propagates_to_caller() {
        let hit = std::panic::catch_unwind(|| {
            parallel_for(2, 16, &|i| {
                if i == 7 {
                    panic!("job seven");
                }
            });
        });
        let err = hit.expect_err("panic must propagate");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "job seven");
        // the pool must remain usable afterwards
        let n = AtomicUsize::new(0);
        parallel_for(2, 16, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn workers_are_reused_not_leaked() {
        // warm the pool to the widest width any test in this binary uses
        // (8 threads -> 7 workers); from then on the count must be
        // stable, no matter how many calls run or what other tests do
        parallel_for(8, 64, &|_| {});
        let baseline = worker_count();
        assert!(baseline >= 7, "pool grows to threads-1 workers, got {baseline}");
        for _ in 0..20 {
            parallel_for(8, 64, &|_| {});
        }
        assert_eq!(worker_count(), baseline, "repeated calls must not spawn more workers");
    }
}
