//! AOT runtime: load the HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the PJRT CPU client.
//!
//! Python never runs on this path — `make artifacts` lowers the L2/L1
//! graphs once, the manifest describes every artifact's shapes, and this
//! module compiles each HLO lazily (cached per name) and marshals f32
//! buffers in and out.
//!
//! `ComputeBackend` abstracts the gradient/eval executor so the engine can
//! also run against the bit-faithful pure-Rust mirror (`native.rs`) for
//! differential testing and artifact-free unit tests.

pub mod native;
pub mod pool;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::losses::Loss;
use crate::util::json::Json;
use crate::util::mat::Mat;

/// A single artifact as described by `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub op: String,
    pub loss: String,
    /// input shapes in call order (empty vec = scalar)
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?}: {e} — run `make artifacts`"))?;
        let json = Json::parse(&text)?;
        anyhow::ensure!(
            json.req_str("format")? == "hlo-text-v1",
            "unsupported manifest format"
        );
        let mut artifacts = HashMap::new();
        for a in json.req_array("artifacts")? {
            let shapes = |key: &str| -> anyhow::Result<Vec<Vec<usize>>> {
                a.req_array(key)?
                    .iter()
                    .map(|s| {
                        s.as_array()
                            .ok_or_else(|| anyhow::anyhow!("bad shape entry"))?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
                            .collect()
                    })
                    .collect()
            };
            let info = ArtifactInfo {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                op: a.req_str("op")?.to_string(),
                loss: a.req_str("loss")?.to_string(),
                inputs: shapes("inputs")?,
                outputs: shapes("outputs")?,
            };
            artifacts.insert(info.name.clone(), info);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn grad_name(loss: Loss, i: usize, s: usize, r: usize, d: usize) -> String {
        format!("grad_{}_i{i}_s{s}_r{r}_d{d}", loss.name())
    }

    pub fn eval_name(loss: Loss, b: usize, r: usize, d: usize) -> String {
        format!("eval_{}_b{b}_r{r}_d{d}", loss.name())
    }

    pub fn has(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }
}

/// Backend abstraction: how gradients and loss sums are computed.
pub trait ComputeBackend {
    /// Fiber-sampled GCP gradient (paper eq. 10) for one mode:
    /// `xs` is the dense `[i_dim, s_dim]` slice (row-major), `a` the
    /// `[i_dim, R]` factor, `us` the D-1 row-gathered `[s_dim, R]` factor
    /// matrices of the other modes, `scale` the unbiasedness weight.
    /// Returns `(scale * G, slice_loss_sum)`.
    fn grad(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[&Mat],
        scale: f32,
    ) -> anyhow::Result<(Mat, f64)>;

    /// Allocation-free variant of [`ComputeBackend::grad`]: writes
    /// `scale * G` into the caller-owned `out` buffer (resized only when
    /// its shape is wrong) and returns the slice loss sum. The engine's
    /// steady-state inner loop calls this with per-mode reused buffers so
    /// a local step performs zero heap allocations on the native backend.
    ///
    /// The default implementation delegates to `grad` and copies — correct
    /// for every backend, allocation-free only where overridden.
    #[allow(clippy::too_many_arguments)]
    fn grad_into(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[Mat],
        scale: f32,
        out: &mut Mat,
    ) -> anyhow::Result<f64> {
        let refs: Vec<&Mat> = us.iter().collect();
        let (g, l) = self.grad(loss, xs, i_dim, s_dim, a, &refs, scale)?;
        *out = g;
        Ok(l)
    }

    /// Stratified loss-estimator batch: `x[B]` data values, `us` D
    /// row-gathered `[B, R]` matrices (one per mode). Returns the loss sum.
    fn eval(&mut self, loss: Loss, x: &[f32], us: &[&Mat]) -> anyhow::Result<f64>;

    /// Hint how many compute threads the backend may use for one gradient
    /// call (`TrainConfig::compute_threads`). Backends without a threaded
    /// path ignore it; the native backend tiles row panels across the
    /// persistent worker pool (`runtime::pool`) when `threads > 1`
    /// (gradients stay bit-identical — see `runtime::native`).
    fn set_threads(&mut self, _threads: usize) {}

    /// How many compute threads this backend will use (what
    /// [`ComputeBackend::set_threads`] last established). Consumers that
    /// parallelize work *around* the backend — e.g. the engine's fiber
    /// gathers — size their `parallel_for` calls from this so one
    /// `--threads` knob governs the whole step.
    fn threads(&self) -> usize {
        1
    }

    fn name(&self) -> &'static str;
}

/// PJRT-backed executor: the production backend.
///
/// Only available with the `pjrt` cargo feature (which needs the `xla`
/// crate from the rust_pallas toolchain image — see Cargo.toml). Without
/// it, a stub with the same API surface is compiled instead whose
/// constructor returns a descriptive error, so everything downstream
/// (CLI, examples, harness) builds and runs artifact-free on the native
/// mirror.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(artifact_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtBackend { manifest, client, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&mut self, name: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let info = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest — re-run `make artifacts` after updating artifact_specs.json"))?;
            let path = self.manifest.dir.join(&info.file);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Host -> device buffer (single copy; ~2.5x faster end-to-end than
    /// the Literal marshaling path, see EXPERIMENTS.md §Perf).
    fn buffer(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn run(&mut self, name: &str, inputs: &[xla::PjRtBuffer]) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe.execute_b(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

#[cfg(feature = "pjrt")]
impl ComputeBackend for PjrtBackend {
    fn grad(
        &mut self,
        loss: Loss,
        xs: &[f32],
        i_dim: usize,
        s_dim: usize,
        a: &Mat,
        us: &[&Mat],
        scale: f32,
    ) -> anyhow::Result<(Mat, f64)> {
        let r_dim = a.cols;
        let d_order = us.len() + 1;
        let name = Manifest::grad_name(loss, i_dim, s_dim, r_dim, d_order);
        anyhow::ensure!(xs.len() == i_dim * s_dim, "xs shape mismatch");
        let mut bufs = Vec::with_capacity(d_order + 2);
        bufs.push(self.buffer(xs, &[i_dim, s_dim])?);
        bufs.push(self.buffer(&a.data, &[i_dim, r_dim])?);
        for u in us {
            anyhow::ensure!(u.rows == s_dim && u.cols == r_dim, "U shape mismatch");
            bufs.push(self.buffer(&u.data, &[s_dim, r_dim])?);
        }
        bufs.push(self.buffer(&[scale], &[])?);
        let outs = self.run(&name, &bufs)?;
        anyhow::ensure!(
            outs.len() == 1 || outs.len() == 2,
            "grad artifact returned {} outputs",
            outs.len()
        );
        let g = Mat::from_vec(i_dim, r_dim, outs[0].to_vec::<f32>()?);
        // Production artifacts omit the monitoring loss (§Perf): the
        // training path only consumes G; loss curves come from eval_*.
        let loss_sum = match outs.get(1) {
            Some(l) => l.get_first_element::<f32>()? as f64,
            None => f64::NAN,
        };
        Ok((g, loss_sum))
    }

    fn eval(&mut self, loss: Loss, x: &[f32], us: &[&Mat]) -> anyhow::Result<f64> {
        let b = x.len();
        let r_dim = us[0].cols;
        let d_order = us.len();
        let name = Manifest::eval_name(loss, b, r_dim, d_order);
        let mut bufs = Vec::with_capacity(d_order + 1);
        bufs.push(self.buffer(x, &[b])?);
        for u in us {
            anyhow::ensure!(u.rows == b && u.cols == r_dim, "U shape mismatch");
            bufs.push(self.buffer(&u.data, &[b, r_dim])?);
        }
        let outs = self.run(&name, &bufs)?;
        anyhow::ensure!(outs.len() == 1, "eval artifact returned {} outputs", outs.len());
        Ok(outs[0].get_first_element::<f32>()? as f64)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Artifact-free stand-in for [`PjrtBackend`] when the `pjrt` feature is
/// off: same API, but construction fails with instructions, so call sites
/// (CLI `--backend pjrt`, examples, benches) compile unchanged and fail
/// gracefully at runtime. Use `--backend native` / [`native::NativeBackend`]
/// to run without artifacts.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtBackend {
    /// uninhabitable: `new()` always errors, so no stub instance exists
    _no_runtime: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtBackend {
    pub fn new(_artifact_dir: &Path) -> anyhow::Result<Self> {
        anyhow::bail!(
            "PJRT backend unavailable: this binary was built without the `pjrt` \
             cargo feature (requires the `xla` crate from the rust_pallas \
             toolchain image). Use the native backend instead \
             (`--backend native`), which mirrors the artifacts bit-faithfully."
        )
    }

    /// Number of compiled executables currently cached (always 0: the
    /// stub cannot compile anything).
    pub fn cached(&self) -> usize {
        0
    }
}

#[cfg(not(feature = "pjrt"))]
impl ComputeBackend for PjrtBackend {
    fn grad(
        &mut self,
        _loss: Loss,
        _xs: &[f32],
        _i_dim: usize,
        _s_dim: usize,
        _a: &Mat,
        _us: &[&Mat],
        _scale: f32,
    ) -> anyhow::Result<(Mat, f64)> {
        anyhow::bail!("PJRT backend stub: rebuild with `--features pjrt`")
    }

    fn eval(&mut self, _loss: Loss, _x: &[f32], _us: &[&Mat]) -> anyhow::Result<f64> {
        anyhow::bail!("PJRT backend stub: rebuild with `--features pjrt`")
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

/// Backend selector for CLI `--backend` flags.
pub struct NativeOrPjrt;

impl NativeOrPjrt {
    pub fn from_flag(flag: &str) -> anyhow::Result<Box<dyn ComputeBackend>> {
        match flag {
            "pjrt" => Ok(Box::new(PjrtBackend::new(&default_artifact_dir())?)),
            "native" => Ok(Box::new(native::NativeBackend::new())),
            other => anyhow::bail!("unknown backend '{other}' (pjrt|native)"),
        }
    }

    /// Default `--backend`/spec value: PJRT when built with the `pjrt`
    /// feature, otherwise the artifact-free native mirror.
    pub fn default_flag() -> &'static str {
        if cfg!(feature = "pjrt") {
            "pjrt"
        } else {
            "native"
        }
    }
}

/// Locate the artifact directory: `$CIDERTF_ARTIFACTS`, else `artifacts/`
/// relative to the workspace root (walking up from cwd).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CIDERTF_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_generated_file() {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 10);
        let g = &m.artifacts["grad_ls_i32_s16_r4_d3"];
        assert_eq!(g.op, "grad");
        assert_eq!(g.inputs[0], vec![32, 16]);
        assert_eq!(g.inputs[1], vec![32, 4]);
        assert_eq!(g.inputs.last().unwrap(), &Vec::<usize>::new()); // scalar
        assert_eq!(g.outputs[0], vec![32, 4]);
        assert!(m.has(&Manifest::eval_name(Loss::Logit, 64, 4, 3)));
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(Manifest::grad_name(Loss::Ls, 512, 256, 16, 3), "grad_ls_i512_s256_r16_d3");
        assert_eq!(Manifest::eval_name(Loss::Logit, 8192, 16, 3), "eval_logit_b8192_r16_d3");
    }
}
