//! The `cidertf node` daemon: one client of an experiment, over real
//! sockets.
//!
//! [`run_node`] executes exactly the float operations the unified
//! session loop (`engine::session::run_loop`) performs *for this
//! client* under the ideal network: the shared block-sampler stream is
//! replicated from the spec seed, all `k` clients are built so the
//! deterministic initialization matches, but only this node's client is
//! ever stepped — neighbor deltas arrive as wire frames instead of
//! in-process `Payload`s, and are applied in the same sorted-neighbor
//! order the in-process loop uses. The spec validation layer guarantees
//! the run is fault-free and honest (see [`crate::node`]'s bit-identity
//! contract), so lock-step framing is sound: every neighbor sends
//! exactly one frame per communicating `(round, mode)` — a payload, or
//! an explicit [`crate::node::TAG_SUPPRESSED`] marker when its event
//! trigger kept the delta home.
//!
//! Progress streams to an optional controller as NDJSON events
//! (`round_end`, `comm_bytes`, `eval`, then one `node_done` carrying the
//! full [`NodeOutcome`]) over a TCP control socket.

use std::collections::BTreeMap;

use crate::compress::Payload;
use crate::engine::checkpoint::snapshot_client;
use crate::engine::{apply_error_feedback, build_clients, consensus_phase, publish_one};
use crate::gossip::{decode_frame_parts, Message};
use crate::net::sim::VirtualClock;
use crate::node::fleet::{FleetConfig, NodeOutcome, NodePoint};
use crate::node::transport::{Conn, Listener, PeerConn, TransportKind};
use crate::node::{control_frame, TAG_HELLO, TAG_SUPPRESSED};
use crate::runtime::NativeOrPjrt;
use crate::sched::BlockSampler;
use crate::topology::Graph;
use crate::util::json::Json;

/// NDJSON event writer for the controller's control socket. With no
/// controller attached every emit is a no-op, so direct `cidertf node`
/// runs and in-process tests skip the I/O entirely.
struct Control {
    conn: Option<Conn>,
    id: usize,
}

impl Control {
    fn emit(&mut self, event: &str, fields: Vec<(&str, Json)>) -> anyhow::Result<()> {
        let Some(conn) = self.conn.as_mut() else { return Ok(()) };
        let mut obj = vec![
            ("event", Json::Str(event.to_string())),
            ("id", Json::Num(self.id as f64)),
        ];
        obj.extend(fields);
        conn.write_line(&Json::obj(obj).to_string())
            .map_err(|e| anyhow::anyhow!("node {}: control channel write failed: {e}", self.id))
    }
}

/// Run client `id` of `cfg` to completion: bind this node's listen
/// address, mesh up with its topology neighbors, and train lock-step
/// with the rest of the fleet. `control` is the controller's NDJSON
/// event address (TCP), or `None` for a standalone run.
pub fn run_node(
    cfg: &FleetConfig,
    id: usize,
    control: Option<&str>,
) -> anyhow::Result<NodeOutcome> {
    cfg.validate()?;
    anyhow::ensure!(id < cfg.spec.k, "node id {id} out of range (k = {})", cfg.spec.k);
    let listener = Listener::bind(cfg.transport_kind()?, cfg.addr_of(id)?)
        .map_err(|e| anyhow::anyhow!("node {id}: {e:#}"))?;
    run_node_with_listener(cfg, id, listener, control)
}

/// [`run_node`] with a pre-bound listener — the in-process tests bind
/// `127.0.0.1:0` themselves to dodge port races, then hand the resolved
/// listeners to one thread per node.
pub fn run_node_with_listener(
    cfg: &FleetConfig,
    id: usize,
    listener: Listener,
    control: Option<&str>,
) -> anyhow::Result<NodeOutcome> {
    cfg.validate()?;
    anyhow::ensure!(id < cfg.spec.k, "node id {id} out of range (k = {})", cfg.spec.k);
    let spec = &cfg.spec;
    let kind = cfg.transport_kind()?;
    let opts = cfg.dial_opts();

    let mut control = Control {
        id,
        conn: match control {
            None => None,
            Some(addr) => Some(
                crate::node::transport::dial(TransportKind::Tcp, addr, &opts)
                    .map_err(|e| anyhow::anyhow!("node {id}: control channel: {e:#}"))?,
            ),
        },
    };

    // deterministic construction, identical on every node: full client
    // set (only ours is ever stepped), graph, sampler, trigger schedule
    let tc = spec.to_train_config();
    let data = spec.dataset_data()?;
    let d_order = data.tensor.dims.len();
    anyhow::ensure!(tc.rank >= 1 && tc.k >= 1 && tc.algo.tau >= 1);
    let mut backend = NativeOrPjrt::from_flag(&spec.backend)?;
    backend.set_threads(tc.compute_threads);
    let graph = Graph::build(tc.topology, tc.k)?;
    let decentralized = tc.k > 1;
    let mut clients = build_clients(&tc, &data, &graph);
    let neighbors: Vec<usize> = graph.neighbors[id].clone();
    let mut own_mask = vec![false; tc.k];
    own_mask[id] = true;

    // ---- mesh up: dial every neighbor, then accept every neighbor ----
    // Dials complete against the peers' kernel backlogs even before
    // their accept loops start, so the symmetric order cannot deadlock;
    // retry-backoff inside `dial` rides out peers that boot later.
    let mut outbound: BTreeMap<usize, PeerConn> = BTreeMap::new();
    let mut inbound: BTreeMap<usize, Conn> = BTreeMap::new();
    if decentralized {
        for &j in &neighbors {
            let conn = PeerConn::connect(kind, cfg.addr_of(j)?, &opts, id)
                .map_err(|e| anyhow::anyhow!("node {id}: connecting to node {j}: {e:#}"))?;
            outbound.insert(j, conn);
        }
        for _ in 0..neighbors.len() {
            let mut conn = listener
                .accept(&opts)
                .map_err(|e| anyhow::anyhow!("node {id}: {e:#}"))?;
            let frame = conn
                .recv_frame()
                .map_err(|e| anyhow::anyhow!("node {id}: handshake read failed: {e:#}"))?;
            let (tag, from, _, _, _, _) = decode_frame_parts(&frame)?;
            anyhow::ensure!(
                tag == TAG_HELLO,
                "node {id}: expected HELLO on a fresh connection, got tag {tag:#04x}"
            );
            let from = from as usize;
            anyhow::ensure!(
                neighbors.contains(&from),
                "node {id}: HELLO from node {from}, which is not a topology neighbor"
            );
            anyhow::ensure!(
                inbound.insert(from, conn).is_none(),
                "node {id}: duplicate HELLO from node {from}"
            );
        }
    }

    // ---- the lock-step loop (run_loop's float ops, this client only) ----
    let mut block_sampler = BlockSampler::new(d_order, tc.seed, true);
    let trigger = tc.trigger_schedule();
    let all_modes: Vec<usize> = (0..d_order).collect();
    let mut clock = VirtualClock::default();
    let total_iters = tc.epochs * tc.iters_per_epoch;
    let eval_period = tc.iters_per_epoch * spec.eval_every.max(1);
    let mut points: Vec<NodePoint> = Vec::new();

    let mut eval_point = |clients: &mut Vec<_>,
                          backend: &mut dyn crate::runtime::ComputeBackend,
                          control: &mut Control,
                          points: &mut Vec<NodePoint>,
                          epoch: usize,
                          iter: usize,
                          time_s: f64|
     -> anyhow::Result<()> {
        let c: &mut crate::engine::client::ClientState = &mut clients[id];
        let loss = c.eval_loss(tc.loss, backend)?;
        let p = NodePoint { epoch, iter, time_s, loss, bytes: c.ledger.bytes };
        control.emit(
            "eval",
            vec![
                ("epoch", Json::Num(epoch as f64)),
                ("iter", Json::Num(iter as f64)),
                ("time_s", Json::Num(time_s)),
                ("loss", Json::Num(loss)),
                ("bytes", Json::u64(p.bytes)),
            ],
        )?;
        points.push(p);
        // run_loop stops on a non-finite *global* loss without writing a
        // final checkpoint; a non-finite local share makes the global
        // loss non-finite too, so failing the node keeps fleet and sim
        // in agreement (neither produces a merged/final checkpoint)
        anyhow::ensure!(
            loss.is_finite(),
            "node {id} diverged at iteration {iter} (local loss is not finite)"
        );
        Ok(())
    };

    eval_point(&mut clients, backend.as_mut(), &mut control, &mut points, 0, 0, clock.now())?;

    for t in 0..total_iters {
        // the shared mode sequence is drawn every round on every node so
        // the replicated sampler streams stay aligned
        let sampled_mode = block_sampler.next_mode();
        let modes: &[usize] =
            if tc.algo.block_random { std::slice::from_ref(&sampled_mode) } else { &all_modes };

        for &m in modes {
            let c = &mut clients[id];
            c.local_step(
                m,
                tc.loss,
                tc.fiber_samples,
                tc.gamma,
                tc.algo.momentum,
                backend.as_mut(),
            )?;
            if tc.algo.error_feedback {
                apply_error_feedback(c, m, tc.algo.compressor);
            }
        }
        clock.advance(tc.sim_iter_s);

        if decentralized && t % tc.algo.tau == 0 {
            let bytes_before = clients[id].ledger.bytes;
            for &m in modes {
                if m == 0 {
                    continue; // patient mode never travels (privacy)
                }
                let payload = publish_one(&mut clients[id], &graph, &tc, &trigger, t, m);
                let frame = match payload {
                    Some(p) => {
                        // own delta applies locally before broadcast,
                        // exactly as in the in-process loop
                        clients[id].estimates.as_mut().expect("estimates").apply_delta(id, m, &p);
                        Message { from: id, mode: m, round: t, payload: p }.encode_frame()
                    }
                    None => control_frame(TAG_SUPPRESSED, id, m, t),
                };
                for &j in &neighbors {
                    outbound
                        .get_mut(&j)
                        .expect("dialed at mesh-up")
                        .send(&frame)
                        .map_err(|e| anyhow::anyhow!("node {id}: sending to node {j}: {e:#}"))?;
                }
                // receive one frame per inbound neighbor and apply the
                // surviving deltas in sorted-neighbor order — the order
                // run_loop's delivery scan uses
                for &j in &neighbors {
                    let fr = inbound
                        .get_mut(&j)
                        .expect("accepted at mesh-up")
                        .recv_frame()
                        .map_err(|e| {
                            anyhow::anyhow!(
                                "node {id}: receiving from node {j} (round {t}, mode {m}): {e:#}"
                            )
                        })?;
                    let (tag, from, mode, round, logical_len, body) = decode_frame_parts(&fr)?;
                    anyhow::ensure!(
                        from as usize == j && mode as usize == m && round as usize == t,
                        "node {id}: protocol desync — got (from {from}, mode {mode}, round \
                         {round}) from node {j}, expected (from {j}, mode {m}, round {t})"
                    );
                    if tag == TAG_SUPPRESSED {
                        continue; // peer's trigger held its delta — zero update
                    }
                    let p = Payload::decode_body(tag, logical_len as usize, body)?;
                    let c = &mut clients[id];
                    c.estimates.as_mut().expect("estimates").apply_delta(j, m, &p);
                    c.net.delivered += 1;
                    clock.note_latency(0.0);
                }
                clock.flush_latency();
                consensus_phase(
                    &mut clients,
                    &graph,
                    &tc.aggregator,
                    tc.algo.rho,
                    m,
                    Some(&own_mask),
                );
            }
            let bytes_after = clients[id].ledger.bytes;
            if bytes_after > bytes_before {
                control.emit(
                    "comm_bytes",
                    vec![
                        ("t", Json::Num(t as f64)),
                        ("round_bytes", Json::u64(bytes_after - bytes_before)),
                        ("total_bytes", Json::u64(bytes_after)),
                    ],
                )?;
            }
        }

        control.emit(
            "round_end",
            vec![("t", Json::Num(t as f64)), ("time_s", Json::Num(clock.now()))],
        )?;

        if (t + 1) % eval_period == 0 || t + 1 == total_iters {
            let epoch = (t + 1) / tc.iters_per_epoch;
            eval_point(
                &mut clients,
                backend.as_mut(),
                &mut control,
                &mut points,
                epoch,
                t + 1,
                clock.now(),
            )?;
        }
    }

    let (sampler_rng, sampler_t) = block_sampler.state();
    let outcome = NodeOutcome {
        id,
        t: total_iters,
        time_s: clock.now(),
        sampler_rng,
        sampler_t,
        data_nnz: data.tensor.nnz() as u64,
        data_fp: data.fingerprint(),
        points,
        client: snapshot_client(&clients[id]),
    };
    control.emit("node_done", vec![("outcome", outcome.to_json())])?;
    Ok(outcome)
}
