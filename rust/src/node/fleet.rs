//! Fleet configuration and the deterministic merge of per-node results.
//!
//! A fleet config is one JSON file (schema [`FLEET_SCHEMA`]) naming the
//! experiment spec (inline under `"spec"` or by path under
//! `"spec_path"`), every node's listen address, and the socket timing
//! knobs. The transport itself is the spec's `transport` axis, resolved
//! through [`crate::registry::transports`] (typos get did-you-mean
//! suggestions).
//!
//! After every node reports its [`NodeOutcome`], [`merge_outcomes`]
//! cross-checks that the fleet stayed lock-step (same iteration count,
//! virtual clock, sampler stream, and dataset fingerprint on every node)
//! and assembles a [`SessionState`] whose checkpoint — written with the
//! spec's driver rewritten to `sim` — is **byte-identical** to the one
//! the in-process sim driver writes for the same spec.

use std::path::Path;

use crate::engine::checkpoint::SessionState;
use crate::engine::metrics::MetricPoint;
use crate::engine::spec::ExperimentSpec;
use crate::net::driver::DriverKind;
use crate::node::transport::{DialOpts, TransportKind};
use crate::util::json::Json;
use crate::util::rng::{state_from_json as rng_from_json, state_to_json as rng_json};

/// Schema tag every fleet config file must carry.
pub const FLEET_SCHEMA: &str = "cidertf-fleet-v1";

/// One node's identity and listen address.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeAddr {
    /// client id this node runs (0-based, one per spec `k`)
    pub id: usize,
    /// listen address — `host:port` for tcp, a filesystem path for uds
    pub addr: String,
}

/// Parsed and validated fleet configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// the experiment every node runs (driver must be `node`)
    pub spec: ExperimentSpec,
    /// one entry per client id, any order in the file; validated to
    /// cover exactly `0..spec.k`
    pub nodes: Vec<NodeAddr>,
    /// per-connection read timeout (ms; 0 = none)
    pub read_timeout_ms: u64,
    /// per-connection write timeout (ms; 0 = none)
    pub write_timeout_ms: u64,
    /// total budget for reaching a peer, dial retries included (ms)
    pub dial_timeout_ms: u64,
    /// sleep between dial retries (ms)
    pub backoff_ms: u64,
}

impl FleetConfig {
    /// Parse from JSON text. `base_dir` anchors a relative `spec_path`
    /// (pass the config file's directory).
    pub fn from_json_str(text: &str, base_dir: Option<&Path>) -> anyhow::Result<FleetConfig> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("fleet config: {e}"))?;
        j.ensure_known_keys(
            "fleet config",
            &[
                "schema",
                "spec",
                "spec_path",
                "nodes",
                "read_timeout_ms",
                "write_timeout_ms",
                "dial_timeout_ms",
                "backoff_ms",
            ],
        )?;
        let schema = j.req_str("schema")?;
        anyhow::ensure!(
            schema == FLEET_SCHEMA,
            "unsupported fleet config schema '{schema}' (want {FLEET_SCHEMA})"
        );
        let spec = match (j.get("spec"), j.get("spec_path")) {
            (Some(sj), None) => ExperimentSpec::from_json(sj)?,
            (None, Some(pj)) => {
                let rel = pj
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("fleet config: 'spec_path' must be a string"))?;
                let path = match base_dir {
                    Some(d) => d.join(rel),
                    None => std::path::PathBuf::from(rel),
                };
                ExperimentSpec::load(&path)?
            }
            (Some(_), Some(_)) => {
                anyhow::bail!("fleet config: give 'spec' or 'spec_path', not both")
            }
            (None, None) => anyhow::bail!("fleet config: missing 'spec' (or 'spec_path')"),
        };
        let mut nodes = Vec::new();
        for nj in j.req_array("nodes")? {
            nj.ensure_known_keys("fleet config node", &["id", "addr"])?;
            nodes.push(NodeAddr { id: nj.req_usize("id")?, addr: nj.req_str("addr")?.to_string() });
        }
        let opt_ms = |key: &str, default: u64| -> anyhow::Result<u64> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("fleet config: '{key}' must be a number")),
            }
        };
        let d = DialOpts::default();
        let cfg = FleetConfig {
            spec,
            nodes,
            read_timeout_ms: opt_ms("read_timeout_ms", d.read_timeout_ms)?,
            write_timeout_ms: opt_ms("write_timeout_ms", d.write_timeout_ms)?,
            dial_timeout_ms: opt_ms("dial_timeout_ms", d.dial_timeout_ms)?,
            backoff_ms: opt_ms("backoff_ms", d.backoff_ms)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load and validate a fleet config file (relative `spec_path`
    /// entries resolve against the file's directory).
    pub fn load(path: &Path) -> anyhow::Result<FleetConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read fleet config {}: {e}", path.display()))?;
        Self::from_json_str(&text, path.parent())
            .map_err(|e| anyhow::anyhow!("{}: {e:#}", path.display()))
    }

    /// Cross-field invariants: the spec must target the node driver and
    /// pass its own validation (which rejects faults, adversaries, and
    /// stop rules — the bit-identity contract), and the node list must
    /// cover client ids `0..k` exactly, each with a unique address.
    pub fn validate(&self) -> anyhow::Result<()> {
        self.spec.validate()?;
        anyhow::ensure!(
            self.spec.driver == DriverKind::Node,
            "fleet config: spec driver is '{}' — a fleet needs driver 'node'",
            self.spec.driver.name()
        );
        anyhow::ensure!(
            self.nodes.len() == self.spec.k,
            "fleet config: {} node entries for a spec with k = {}",
            self.nodes.len(),
            self.spec.k
        );
        let mut seen = vec![false; self.spec.k];
        for n in &self.nodes {
            anyhow::ensure!(
                n.id < self.spec.k,
                "fleet config: node id {} out of range (k = {})",
                n.id,
                self.spec.k
            );
            anyhow::ensure!(!seen[n.id], "fleet config: duplicate node id {}", n.id);
            seen[n.id] = true;
            anyhow::ensure!(!n.addr.is_empty(), "fleet config: node {} has an empty address", n.id);
        }
        for (i, a) in self.nodes.iter().enumerate() {
            for b in &self.nodes[i + 1..] {
                anyhow::ensure!(
                    a.addr != b.addr,
                    "fleet config: nodes {} and {} share address {}",
                    a.id,
                    b.id,
                    a.addr
                );
            }
        }
        Ok(())
    }

    /// The resolved socket family (the spec's `transport` axis).
    pub fn transport_kind(&self) -> anyhow::Result<TransportKind> {
        crate::registry::transports().resolve(&self.spec.transport)
    }

    /// The listen address of client `id`.
    pub fn addr_of(&self, id: usize) -> anyhow::Result<&str> {
        self.nodes
            .iter()
            .find(|n| n.id == id)
            .map(|n| n.addr.as_str())
            .ok_or_else(|| anyhow::anyhow!("no node entry for client id {id}"))
    }

    /// Socket timing knobs as a [`DialOpts`].
    pub fn dial_opts(&self) -> DialOpts {
        DialOpts {
            read_timeout_ms: self.read_timeout_ms,
            write_timeout_ms: self.write_timeout_ms,
            dial_timeout_ms: self.dial_timeout_ms,
            backoff_ms: self.backoff_ms,
        }
    }

    /// Serialize (inline spec form) — what `fleet spawn` materializes
    /// for its child processes and the tests round-trip.
    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| {
                Json::obj(vec![
                    ("id", Json::Num(n.id as f64)),
                    ("addr", Json::Str(n.addr.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Str(FLEET_SCHEMA.to_string())),
            ("spec", self.spec.to_json()),
            ("nodes", Json::Arr(nodes)),
            ("read_timeout_ms", Json::u64(self.read_timeout_ms)),
            ("write_timeout_ms", Json::u64(self.write_timeout_ms)),
            ("dial_timeout_ms", Json::u64(self.dial_timeout_ms)),
            ("backoff_ms", Json::u64(self.backoff_ms)),
        ])
    }
}

/// One node's share of a metric point: its own loss contribution and its
/// own cumulative uplink bytes at an eval boundary.
#[derive(Debug, Clone)]
pub struct NodePoint {
    /// epoch index (0 for the pre-training point)
    pub epoch: usize,
    /// iteration index the point was taken at
    pub iter: usize,
    /// virtual clock at the point (identical on every node)
    pub time_s: f64,
    /// this client's loss-estimator contribution
    pub loss: f64,
    /// this client's cumulative uplink bytes
    pub bytes: u64,
}

impl NodePoint {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::Num(self.epoch as f64)),
            ("iter", Json::Num(self.iter as f64)),
            ("time_s", Json::Num(self.time_s)),
            ("loss", Json::Num(self.loss)),
            ("bytes", Json::u64(self.bytes)),
        ])
    }

    fn from_json(j: &Json) -> anyhow::Result<NodePoint> {
        Ok(NodePoint {
            epoch: j.req_usize("epoch")?,
            iter: j.req_usize("iter")?,
            time_s: j.req_f64("time_s")?,
            loss: j.req_f64("loss")?,
            bytes: j.req_u64("bytes")?,
        })
    }
}

/// Everything one finished node hands back for the merge: its client
/// state snapshot (the same blob a checkpoint stores), its metric-point
/// shares, and the lock-step witnesses every node must agree on.
#[derive(Debug, Clone)]
pub struct NodeOutcome {
    /// the client id this node ran
    pub id: usize,
    /// iterations executed (must equal `epochs * iters_per_epoch`)
    pub t: usize,
    /// final virtual clock
    pub time_s: f64,
    /// final shared block-sampler RNG stream
    pub sampler_rng: ([u64; 4], Option<f64>),
    /// final shared block-sampler draw counter
    pub sampler_t: usize,
    /// nonzeros of the dataset this node trained on
    pub data_nnz: u64,
    /// content fingerprint of the dataset
    pub data_fp: u64,
    /// this node's metric-point shares, in recording order
    pub points: Vec<NodePoint>,
    /// the client state blob ([`crate::engine::checkpoint`] format)
    pub client: Json,
}

impl NodeOutcome {
    /// Serialize for the control channel / stdout.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("t", Json::Num(self.t as f64)),
            ("time_s", Json::Num(self.time_s)),
            ("sampler_rng", rng_json(self.sampler_rng)),
            ("sampler_t", Json::Num(self.sampler_t as f64)),
            ("data_nnz", Json::u64(self.data_nnz)),
            ("data_fp", Json::u64(self.data_fp)),
            ("points", Json::Arr(self.points.iter().map(NodePoint::to_json).collect())),
            ("client", self.client.clone()),
        ])
    }

    /// Parse a [`NodeOutcome::to_json`] blob.
    pub fn from_json(j: &Json) -> anyhow::Result<NodeOutcome> {
        Ok(NodeOutcome {
            id: j.req_usize("id")?,
            t: j.req_usize("t")?,
            time_s: j.req_f64("time_s")?,
            sampler_rng: rng_from_json(
                j.get("sampler_rng").ok_or_else(|| anyhow::anyhow!("missing 'sampler_rng'"))?,
            )?,
            sampler_t: j.req_usize("sampler_t")?,
            data_nnz: j.req_u64("data_nnz")?,
            data_fp: j.req_u64("data_fp")?,
            points: j
                .req_array("points")?
                .iter()
                .map(NodePoint::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
            client: j
                .get("client")
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("missing 'client'"))?,
        })
    }
}

/// Merge every node's outcome into the session state the sim driver
/// would have produced, returning it with the spec rewritten to
/// `driver: sim` — so `checkpoint::write_checkpoint` emits a file
/// byte-identical to an in-process run's final checkpoint.
///
/// The merge is also the fleet's lock-step audit: it refuses outcomes
/// that disagree on iteration count, virtual clock, sampler stream,
/// dataset fingerprint, or eval cadence (bit-compared, not
/// approximately).
pub fn merge_outcomes(
    spec: &ExperimentSpec,
    outcomes: &[NodeOutcome],
) -> anyhow::Result<(ExperimentSpec, SessionState)> {
    anyhow::ensure!(
        outcomes.len() == spec.k,
        "merge: {} node outcomes for a spec with k = {}",
        outcomes.len(),
        spec.k
    );
    let mut by_id: Vec<Option<&NodeOutcome>> = vec![None; spec.k];
    for o in outcomes {
        anyhow::ensure!(o.id < spec.k, "merge: outcome for unknown client id {}", o.id);
        anyhow::ensure!(by_id[o.id].is_none(), "merge: duplicate outcome for client id {}", o.id);
        by_id[o.id] = Some(o);
    }
    let ordered: Vec<&NodeOutcome> =
        by_id.into_iter().map(|o| o.expect("all ids covered")).collect();

    let first = ordered[0];
    for o in &ordered[1..] {
        anyhow::ensure!(
            o.t == first.t,
            "merge: node {} ran {} iterations, node {} ran {} — fleet lost lock-step",
            first.id,
            first.t,
            o.id,
            o.t
        );
        anyhow::ensure!(
            o.time_s.to_bits() == first.time_s.to_bits(),
            "merge: virtual clocks disagree between nodes {} and {}",
            first.id,
            o.id
        );
        anyhow::ensure!(
            o.sampler_rng == first.sampler_rng && o.sampler_t == first.sampler_t,
            "merge: block-sampler streams disagree between nodes {} and {}",
            first.id,
            o.id
        );
        anyhow::ensure!(
            o.data_nnz == first.data_nnz && o.data_fp == first.data_fp,
            "merge: dataset fingerprints disagree between nodes {} and {} — the nodes \
             did not train on the same data",
            first.id,
            o.id
        );
        anyhow::ensure!(
            o.points.len() == first.points.len(),
            "merge: node {} recorded {} metric points, node {} recorded {}",
            first.id,
            first.points.len(),
            o.id,
            o.points.len()
        );
    }

    // global metric points: losses sum in client-id order (the same
    // sequential accumulation `record_point` performs), bytes sum exactly
    let mut points: Vec<MetricPoint> = Vec::with_capacity(first.points.len());
    for (i, p0) in first.points.iter().enumerate() {
        let mut loss = 0.0f64;
        let mut bytes = 0u64;
        for o in &ordered {
            let p = &o.points[i];
            anyhow::ensure!(
                p.epoch == p0.epoch
                    && p.iter == p0.iter
                    && p.time_s.to_bits() == p0.time_s.to_bits(),
                "merge: metric point {i} differs between nodes {} and {} (eval cadence \
                 desync)",
                first.id,
                o.id
            );
            loss += p.loss;
            bytes += p.bytes;
        }
        points.push(MetricPoint {
            epoch: p0.epoch,
            iter: p0.iter,
            time_s: p0.time_s,
            loss,
            bytes,
            fms: None,
        });
    }

    let state = SessionState {
        t: first.t,
        time_s: first.time_s,
        sampler_rng: first.sampler_rng,
        sampler_t: first.sampler_t,
        net_model: Json::Null,
        adversary: Json::Null,
        data_nnz: Some(first.data_nnz),
        data_fp: Some(first.data_fp),
        points,
        clients: ordered.iter().map(|o| o.client.clone()).collect(),
    };
    let mut merged_spec = spec.clone();
    merged_spec.driver = DriverKind::Sim;
    Ok((merged_spec, state))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::AlgoConfig;
    use crate::losses::Loss;

    fn node_spec(k: usize) -> ExperimentSpec {
        ExperimentSpec::builder("tiny", Loss::Logit, AlgoConfig::cidertf(2))
            .k(k)
            .rank(4)
            .fiber_samples(16)
            .iters_per_epoch(10)
            .epochs(1)
            .eval_batch(64)
            .driver(DriverKind::Node)
            .build()
            .unwrap()
    }

    fn fleet_json(k: usize, transport: &str, nodes: &str) -> String {
        let mut spec = node_spec(k);
        spec.transport = transport.to_string();
        format!(
            r#"{{"schema":"cidertf-fleet-v1","spec":{},"nodes":[{}]}}"#,
            spec.to_json(),
            nodes
        )
    }

    #[test]
    fn fleet_config_round_trips() {
        let nodes = r#"{"id":0,"addr":"127.0.0.1:4801"},{"id":1,"addr":"127.0.0.1:4802"}"#;
        let text = fleet_json(2, "tcp", nodes);
        let cfg = FleetConfig::from_json_str(&text, None).unwrap();
        assert_eq!(cfg.nodes.len(), 2);
        assert_eq!(cfg.addr_of(1).unwrap(), "127.0.0.1:4802");
        assert_eq!(cfg.transport_kind().unwrap(), TransportKind::Tcp);
        // defaults applied
        assert_eq!(cfg.read_timeout_ms, DialOpts::default().read_timeout_ms);
        let back = FleetConfig::from_json_str(&cfg.to_json().to_string(), None).unwrap();
        assert_eq!(back.spec, cfg.spec);
        assert_eq!(back.nodes, cfg.nodes);
    }

    #[test]
    fn fleet_config_rejects_malformed_files() {
        // not JSON at all
        assert!(FleetConfig::from_json_str("not json", None).is_err());
        // unknown top-level key
        let text =
            fleet_json(1, "tcp", r#"{"id":0,"addr":"a"}"#).replacen('{', r#"{"surprise":1,"#, 1);
        let err = format!("{:#}", FleetConfig::from_json_str(&text, None).unwrap_err());
        assert!(err.contains("surprise"), "{err}");
        // duplicate node id, named in the error
        let text = fleet_json(2, "tcp", r#"{"id":1,"addr":"a"},{"id":1,"addr":"b"}"#);
        let err = format!("{:#}", FleetConfig::from_json_str(&text, None).unwrap_err());
        assert!(err.contains("duplicate node id 1"), "{err}");
        // wrong node count for k
        let text = fleet_json(2, "tcp", r#"{"id":0,"addr":"a"}"#);
        let err = format!("{:#}", FleetConfig::from_json_str(&text, None).unwrap_err());
        assert!(err.contains("1 node entries") && err.contains("k = 2"), "{err}");
        // shared address
        let text = fleet_json(2, "tcp", r#"{"id":0,"addr":"a"},{"id":1,"addr":"a"}"#);
        let err = format!("{:#}", FleetConfig::from_json_str(&text, None).unwrap_err());
        assert!(err.contains("share address"), "{err}");
        // typo'd transport gets a did-you-mean from the registry
        let text = fleet_json(1, "tpc", r#"{"id":0,"addr":"a"}"#);
        let err = format!("{:#}", FleetConfig::from_json_str(&text, None).unwrap_err());
        assert!(err.contains("did you mean 'tcp'"), "{err}");
        // wrong driver
        let mut spec = node_spec(1);
        spec.driver = DriverKind::Sim;
        let text = format!(
            r#"{{"schema":"cidertf-fleet-v1","spec":{},"nodes":[{{"id":0,"addr":"a"}}]}}"#,
            spec.to_json()
        );
        let err = format!("{:#}", FleetConfig::from_json_str(&text, None).unwrap_err());
        assert!(err.contains("needs driver 'node'"), "{err}");
    }

    fn outcome(id: usize, loss: f64) -> NodeOutcome {
        NodeOutcome {
            id,
            t: 10,
            time_s: 10.0,
            sampler_rng: ([1, 2, 3, 4], None),
            sampler_t: 10,
            data_nnz: 100,
            data_fp: 7,
            points: vec![NodePoint { epoch: 1, iter: 10, time_s: 10.0, loss, bytes: 64 }],
            client: Json::obj(vec![("stub", Json::Num(id as f64))]),
        }
    }

    #[test]
    fn merge_requires_lock_step_agreement() {
        let spec = node_spec(2);
        let (merged_spec, state) =
            merge_outcomes(&spec, &[outcome(1, 2.0), outcome(0, 1.0)]).unwrap();
        assert_eq!(merged_spec.driver, DriverKind::Sim);
        assert_eq!(state.t, 10);
        assert_eq!(state.points.len(), 1);
        // losses sum in id order, bytes sum exactly
        assert_eq!(state.points[0].loss, 1.0 + 2.0);
        assert_eq!(state.points[0].bytes, 128);
        // client blobs land in id order
        assert_eq!(state.clients[0].get("stub").and_then(Json::as_usize), Some(0));

        // outcome round-trips through its JSON form
        let o = outcome(0, 1.0);
        let back = NodeOutcome::from_json(&o.to_json()).unwrap();
        assert_eq!(back.id, o.id);
        assert_eq!(back.sampler_rng, o.sampler_rng);
        assert_eq!(back.points.len(), 1);

        // disagreement on any lock-step witness is refused
        let mut bad = outcome(1, 2.0);
        bad.t = 11;
        let err = format!("{:#}", merge_outcomes(&spec, &[outcome(0, 1.0), bad]).unwrap_err());
        assert!(err.contains("lock-step"), "{err}");
        let mut bad = outcome(1, 2.0);
        bad.data_fp = 8;
        assert!(merge_outcomes(&spec, &[outcome(0, 1.0), bad]).is_err());
        let err = format!(
            "{:#}",
            merge_outcomes(&spec, &[outcome(0, 1.0), outcome(0, 1.0)]).unwrap_err()
        );
        assert!(err.contains("duplicate outcome"), "{err}");
    }
}
