//! The `cidertf fleet` controller: spawn a local fleet of node daemons,
//! tail their event streams, and merge the results.
//!
//! `fleet spawn` launches one child process per client id (the current
//! executable re-invoked as `cidertf node`), hands each the controller's
//! control-socket address, and consumes their NDJSON event streams
//! (`round_end` / `comm_bytes` / `eval` / `node_done`). Progress lands
//! in `<out>/status.json` (schema [`STATUS_SCHEMA`], atomically
//! replaced) for `fleet status`, per-node stdout/stderr in
//! `<out>/node-<id>.log`, and child pids in `<out>/fleet.pid` for
//! `fleet stop`. When every node reports its outcome the controller
//! merges them ([`crate::node::fleet::merge_outcomes`]) and writes
//! `<out>/merged.ckpt.json` — byte-identical to the sim driver's final
//! checkpoint for the same spec.
//!
//! Deliberately no wall clock here (lint D004): pacing uses channel
//! receive timeouts and child exit polling, never `Instant::now`.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader};
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::Duration;

use crate::engine::checkpoint::write_checkpoint;
use crate::node::fleet::{merge_outcomes, FleetConfig, NodeOutcome};
use crate::util::benchkit::fmt_bytes;
use crate::util::json::Json;

/// Schema tag of `<out>/status.json`.
pub const STATUS_SCHEMA: &str = "cidertf-fleet-status-v1";

/// Filename of the merged checkpoint under the out directory.
pub const MERGED_CHECKPOINT: &str = "merged.ckpt.json";

/// Per-node progress snapshot for `status.json`.
#[derive(Debug, Clone, Default)]
struct NodeProgress {
    /// rounds finished (last `round_end` t + 1)
    rounds: u64,
    /// virtual clock at the last event
    time_s: f64,
    /// last reported local loss share
    loss: Option<f64>,
    /// node reported its final outcome
    done: bool,
}

fn write_status(
    out_dir: &Path,
    phase: &str,
    total_iters: usize,
    nodes: &BTreeMap<usize, NodeProgress>,
) -> anyhow::Result<()> {
    let rows: Vec<Json> = nodes
        .iter()
        .map(|(id, p)| {
            Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("rounds", Json::u64(p.rounds)),
                ("time_s", Json::Num(p.time_s)),
                ("loss", p.loss.map(Json::Num).unwrap_or(Json::Null)),
                ("done", Json::Bool(p.done)),
            ])
        })
        .collect();
    let status = Json::obj(vec![
        ("schema", Json::Str(STATUS_SCHEMA.to_string())),
        ("phase", Json::Str(phase.to_string())),
        ("total_iters", Json::Num(total_iters as f64)),
        ("nodes", Json::Arr(rows)),
    ]);
    let path = out_dir.join("status.json");
    let tmp = out_dir.join("status.json.tmp");
    std::fs::write(&tmp, status.to_pretty_string())
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .map_err(|e| anyhow::anyhow!("cannot move status into place at {}: {e}", path.display()))?;
    Ok(())
}

/// Launch the fleet described by `config_path`, stream its progress, and
/// on completion write the merged checkpoint under `out_dir`. Runs in
/// the foreground until the fleet finishes or fails.
pub fn spawn(config_path: &Path, out_dir: &Path) -> anyhow::Result<()> {
    let cfg = FleetConfig::load(config_path)?;
    std::fs::create_dir_all(out_dir)
        .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", out_dir.display()))?;
    let config_abs = config_path
        .canonicalize()
        .map_err(|e| anyhow::anyhow!("cannot resolve {}: {e}", config_path.display()))?;

    // control socket first, so every child can connect immediately
    let listener = TcpListener::bind("127.0.0.1:0")
        .map_err(|e| anyhow::anyhow!("cannot bind control socket: {e}"))?;
    let control_addr = listener.local_addr()?.to_string();

    let exe = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("cannot locate own executable: {e}"))?;
    let mut children: Vec<(usize, Child)> = Vec::with_capacity(cfg.spec.k);
    for id in 0..cfg.spec.k {
        let log = std::fs::File::create(out_dir.join(format!("node-{id}.log")))
            .map_err(|e| anyhow::anyhow!("cannot create node-{id}.log: {e}"))?;
        let child = Command::new(&exe)
            .arg("node")
            .arg("--config")
            .arg(&config_abs)
            .arg("--id")
            .arg(id.to_string())
            .arg("--control")
            .arg(&control_addr)
            .stdin(Stdio::null())
            .stdout(Stdio::from(log.try_clone()?))
            .stderr(Stdio::from(log))
            .spawn()
            .map_err(|e| anyhow::anyhow!("cannot spawn node {id}: {e}"))?;
        children.push((id, child));
    }
    let pid_lines: Vec<String> = children
        .iter()
        .map(|(_, c)| c.id().to_string())
        .chain(std::iter::once(std::process::id().to_string()))
        .collect();
    std::fs::write(out_dir.join("fleet.pid"), pid_lines.join("\n") + "\n")
        .map_err(|e| anyhow::anyhow!("cannot write fleet.pid: {e}"))?;
    println!(
        "fleet: {} nodes up (transport {}, control {control_addr}), logs in {}",
        cfg.spec.k,
        cfg.spec.transport,
        out_dir.display()
    );

    let result = drive(&cfg, &listener, &mut children, out_dir);
    if result.is_err() {
        for (_, c) in children.iter_mut() {
            let _ = c.kill();
        }
    }
    for (_, c) in children.iter_mut() {
        let _ = c.wait();
    }
    let _ = std::fs::remove_file(out_dir.join("fleet.pid"));
    result
}

/// Event-pump phase of [`spawn`]: accept one control connection per
/// node, fan their NDJSON lines into a channel, track progress, and
/// merge once every node is done.
fn drive(
    cfg: &FleetConfig,
    listener: &TcpListener,
    children: &mut [(usize, Child)],
    out_dir: &Path,
) -> anyhow::Result<()> {
    let k = cfg.spec.k;
    let total_iters = cfg.spec.epochs * cfg.spec.iters_per_epoch;
    let (tx, rx) = mpsc::channel::<anyhow::Result<Json>>();

    // accept control connections without blocking forever: a child that
    // dies before connecting must fail the launch, not hang it
    listener.set_nonblocking(true)?;
    let mut accepted = 0usize;
    while accepted < k {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                let tx = tx.clone();
                // lint: allow(raw-thread-spawn) — long-lived per-node control
                // reader blocked on socket I/O for the whole fleet run; the
                // shared compute pool must never host blocking reads
                std::thread::spawn(move || {
                    for line in BufReader::new(stream).lines() {
                        let sent = match line {
                            Ok(l) if l.trim().is_empty() => continue,
                            Ok(l) => tx.send(
                                Json::parse(&l)
                                    .map_err(|e| anyhow::anyhow!("bad control line: {e}")),
                            ),
                            Err(e) => {
                                tx.send(Err(anyhow::anyhow!("control read failed: {e}")))
                            }
                        };
                        if sent.is_err() {
                            break; // controller went away
                        }
                    }
                });
                accepted += 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                check_children(children, out_dir, &BTreeMap::new())?;
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => anyhow::bail!("control accept failed: {e}"),
        }
    }
    drop(tx); // readers hold the only senders now: disconnect == all streams closed

    let mut progress: BTreeMap<usize, NodeProgress> =
        (0..k).map(|i| (i, NodeProgress::default())).collect();
    // aggregate eval points keyed by iteration: (epoch, loss sum, bytes sum, reports)
    let mut evals: BTreeMap<usize, (usize, f64, u64, usize)> = BTreeMap::new();
    let mut outcomes: Vec<NodeOutcome> = Vec::with_capacity(k);
    write_status(out_dir, "running", total_iters, &progress)?;

    while outcomes.len() < k {
        match rx.recv_timeout(Duration::from_millis(500)) {
            Ok(line) => {
                let ev = line?;
                handle_event(&ev, &mut progress, &mut evals, &mut outcomes, k)?;
                let kind = ev.get("event").and_then(Json::as_str).unwrap_or("");
                if kind == "eval" || kind == "node_done" {
                    write_status(out_dir, "running", total_iters, &progress)?;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                check_children(children, out_dir, &progress)?;
                write_status(out_dir, "running", total_iters, &progress)?;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                check_children(children, out_dir, &progress)?;
                anyhow::bail!(
                    "all control streams closed after {}/{k} node outcomes — see the \
                     node-*.log files under {}",
                    outcomes.len(),
                    out_dir.display()
                );
            }
        }
    }

    let (merged_spec, state) = merge_outcomes(&cfg.spec, &outcomes)?;
    let ckpt = out_dir.join(MERGED_CHECKPOINT);
    write_checkpoint(&ckpt, &merged_spec, &state)?;
    write_status(out_dir, "done", total_iters, &progress)?;
    print_summary(&state, &ckpt);
    Ok(())
}

/// Fold one NDJSON event into the progress/outcome trackers, printing
/// aggregate eval lines once every node has reported an iteration.
fn handle_event(
    ev: &Json,
    progress: &mut BTreeMap<usize, NodeProgress>,
    evals: &mut BTreeMap<usize, (usize, f64, u64, usize)>,
    outcomes: &mut Vec<NodeOutcome>,
    k: usize,
) -> anyhow::Result<()> {
    let kind = ev.req_str("event")?;
    let id = ev.req_usize("id")?;
    anyhow::ensure!(id < k, "control event from unknown node id {id}");
    let slot = progress.get_mut(&id).expect("id range checked");
    match kind {
        "round_end" => {
            slot.rounds = ev.req_u64("t")? + 1;
            slot.time_s = ev.req_f64("time_s")?;
        }
        "comm_bytes" | "net_fault" => {}
        "eval" => {
            let iter = ev.req_usize("iter")?;
            let epoch = ev.req_usize("epoch")?;
            let loss = ev.req_f64("loss")?;
            slot.loss = Some(loss);
            slot.time_s = ev.req_f64("time_s")?;
            let agg = evals.entry(iter).or_insert((epoch, 0.0, 0, 0));
            agg.1 += loss;
            agg.2 += ev.req_u64("bytes")?;
            agg.3 += 1;
            if agg.3 == k {
                println!(
                    "epoch {:>3}  t={:>7}  loss={:.6e}  uplink={}",
                    agg.0,
                    iter,
                    agg.1,
                    fmt_bytes(agg.2 as f64)
                );
            }
        }
        "node_done" => {
            let outcome = NodeOutcome::from_json(
                ev.get("outcome").ok_or_else(|| anyhow::anyhow!("node_done without outcome"))?,
            )?;
            anyhow::ensure!(outcome.id == id, "node_done id mismatch");
            slot.done = true;
            slot.rounds = outcome.t as u64;
            slot.time_s = outcome.time_s;
            outcomes.push(outcome);
        }
        other => anyhow::bail!("unknown control event '{other}' from node {id}"),
    }
    Ok(())
}

/// Fail fast when a child exited without finishing its run. A `success`
/// exit is only fatal once paired with a missing outcome at disconnect
/// time — its `node_done` may still be in flight in the channel.
fn check_children(
    children: &mut [(usize, Child)],
    out_dir: &Path,
    progress: &BTreeMap<usize, NodeProgress>,
) -> anyhow::Result<()> {
    for (id, child) in children.iter_mut() {
        if let Some(status) = child.try_wait()? {
            let done = progress.get(id).map(|p| p.done).unwrap_or(false);
            if !status.success() && !done {
                anyhow::bail!(
                    "node {id} exited early ({status}) — see {}",
                    out_dir.join(format!("node-{id}.log")).display()
                );
            }
        }
    }
    Ok(())
}

/// Final console summary: merged loss curve tail plus the merged comm
/// ledgers and delivery stats from the per-client state blobs.
fn print_summary(state: &crate::engine::checkpoint::SessionState, ckpt: &Path) {
    let (mut bytes, mut messages, mut triggered, mut suppressed) = (0u64, 0u64, 0u64, 0u64);
    let (mut delivered, mut dropped) = (0u64, 0u64);
    for c in &state.clients {
        if let Some(l) = c.get("ledger") {
            bytes += l.get("bytes").and_then(Json::as_u64).unwrap_or(0);
            messages += l.get("messages").and_then(Json::as_u64).unwrap_or(0);
            triggered += l.get("triggered").and_then(Json::as_u64).unwrap_or(0);
            suppressed += l.get("suppressed").and_then(Json::as_u64).unwrap_or(0);
        }
        if let Some(n) = c.get("net") {
            delivered += n.get("delivered").and_then(Json::as_u64).unwrap_or(0);
            dropped += n.get("dropped").and_then(Json::as_u64).unwrap_or(0);
        }
    }
    let final_loss = state.points.last().map(|p| p.loss).unwrap_or(f64::NAN);
    println!(
        "network: delivered {delivered}, dropped {dropped}; uplink {}, msgs {messages} \
         (triggered {triggered}, suppressed {suppressed})",
        fmt_bytes(bytes as f64)
    );
    println!(
        "fleet done: final loss {final_loss:.6e}, virtual {:.1}s, merged checkpoint {}",
        state.time_s,
        ckpt.display()
    );
}

/// Print the current `<out>/status.json` (written atomically by a
/// running `fleet spawn`).
pub fn status(out_dir: &Path) -> anyhow::Result<()> {
    let path = out_dir.join("status.json");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        anyhow::anyhow!("cannot read {} (is a fleet running with --out here?): {e}", path.display())
    })?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let schema = j.req_str("schema")?;
    anyhow::ensure!(schema == STATUS_SCHEMA, "unsupported status schema '{schema}'");
    print!("{}", j.to_pretty_string());
    println!();
    Ok(())
}

/// Signal every process recorded in `<out>/fleet.pid` (the node
/// children, then the controller) and remove the pid file. Idempotent:
/// a missing pid file reports nothing to stop.
pub fn stop(out_dir: &Path) -> anyhow::Result<()> {
    let path: PathBuf = out_dir.join("fleet.pid");
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(_) => {
            println!("fleet stop: no {} — nothing to stop", path.display());
            return Ok(());
        }
    };
    let mut signalled = 0usize;
    for pid in text.lines().map(str::trim).filter(|l| !l.is_empty()) {
        anyhow::ensure!(
            pid.bytes().all(|b| b.is_ascii_digit()),
            "fleet.pid holds a non-numeric entry '{pid}'"
        );
        let ok = Command::new("kill")
            .arg(pid)
            .status()
            .map(|s| s.success())
            .unwrap_or(false);
        if ok {
            signalled += 1;
        }
    }
    std::fs::remove_file(&path)
        .map_err(|e| anyhow::anyhow!("cannot remove {}: {e}", path.display()))?;
    println!("fleet stop: signalled {signalled} process(es)");
    Ok(())
}
