//! Socket transport for the node daemon: framed send/recv over TCP or
//! Unix-domain sockets, dial with retry-backoff, and reconnect on peer
//! restart.
//!
//! This is the **only** file in `node/` that may read the wall clock
//! (lint rule D004's allowlist): dial deadlines and reconnect backoff
//! are genuinely about real elapsed time. Everything above this edge —
//! the daemon loop, the merge, the controller — stays deterministic.
//!
//! Frames are the length-prefixed envelope of
//! [`crate::gossip::Message::encode_frame`]: a u32 LE frame length, then
//! `magic "CT" | version | tag | from | mode | round | logical_len |
//! body`. [`Conn::send_frame`] writes a pre-encoded frame verbatim;
//! [`Conn::recv_frame`] reads the prefix and returns the frame bytes
//! after it (what [`crate::gossip::Message::decode_frame`] consumes).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::{Duration, Instant};

use crate::gossip::FRAME_HEADER_BYTES;

/// Hard cap on a single frame (sanity bound against corrupt length
/// prefixes; far above any real factor-delta payload).
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Which socket family carries the gossip mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// TCP over loopback or LAN — node addresses are `host:port`
    Tcp,
    /// Unix-domain sockets — node addresses are filesystem paths
    Uds,
}

impl TransportKind {
    /// CLI/registry name of this transport.
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Tcp => "tcp",
            TransportKind::Uds => "uds",
        }
    }
}

/// Socket timing knobs, straight from the fleet config (milliseconds;
/// `0` disables the corresponding timeout).
#[derive(Debug, Clone, Copy)]
pub struct DialOpts {
    /// per-connection read timeout
    pub read_timeout_ms: u64,
    /// per-connection write timeout
    pub write_timeout_ms: u64,
    /// total budget for reaching a peer (dial retries included)
    pub dial_timeout_ms: u64,
    /// sleep between dial retries
    pub backoff_ms: u64,
}

impl Default for DialOpts {
    fn default() -> Self {
        DialOpts {
            read_timeout_ms: 30_000,
            write_timeout_ms: 10_000,
            dial_timeout_ms: 15_000,
            backoff_ms: 50,
        }
    }
}

fn timeout(ms: u64) -> Option<Duration> {
    if ms > 0 {
        Some(Duration::from_millis(ms))
    } else {
        None
    }
}

/// A bound listening socket for one node's inbound mesh connections.
#[derive(Debug)]
pub enum Listener {
    /// TCP listener plus its bound address (resolves `:0` port requests)
    Tcp(TcpListener),
    /// UDS listener plus the socket path it is bound to
    Uds(UnixListener, String),
}

impl Listener {
    /// Bind `addr` under `kind`. A stale UDS socket file left by a
    /// crashed previous run is removed before binding.
    pub fn bind(kind: TransportKind, addr: &str) -> anyhow::Result<Listener> {
        match kind {
            TransportKind::Tcp => {
                let l = TcpListener::bind(addr)
                    .map_err(|e| anyhow::anyhow!("cannot listen on tcp address {addr}: {e}"))?;
                Ok(Listener::Tcp(l))
            }
            TransportKind::Uds => {
                if std::fs::metadata(addr).is_ok() {
                    std::fs::remove_file(addr).map_err(|e| {
                        anyhow::anyhow!("cannot remove stale socket file {addr}: {e}")
                    })?;
                }
                let l = UnixListener::bind(addr)
                    .map_err(|e| anyhow::anyhow!("cannot listen on uds path {addr}: {e}"))?;
                Ok(Listener::Uds(l, addr.to_string()))
            }
        }
    }

    /// The address peers should dial (for TCP this resolves a `:0` bind
    /// to the actual port, which the in-process tests rely on).
    pub fn local_addr(&self) -> anyhow::Result<String> {
        match self {
            Listener::Tcp(l) => Ok(l.local_addr()?.to_string()),
            Listener::Uds(_, path) => Ok(path.clone()),
        }
    }

    /// Accept one inbound connection and apply `opts` timeouts to it.
    pub fn accept(&self, opts: &DialOpts) -> anyhow::Result<Conn> {
        let mut conn = match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept().map_err(|e| anyhow::anyhow!("accept failed: {e}"))?;
                Conn::Tcp(s)
            }
            Listener::Uds(l, path) => {
                let (s, _) = l
                    .accept()
                    .map_err(|e| anyhow::anyhow!("accept on {path} failed: {e}"))?;
                Conn::Uds(s)
            }
        };
        conn.set_timeouts(opts)?;
        Ok(conn)
    }
}

/// One established mesh or control connection.
#[derive(Debug)]
pub enum Conn {
    /// a TCP stream
    Tcp(TcpStream),
    /// a Unix-domain stream
    Uds(UnixStream),
}

impl Conn {
    /// Apply read/write timeouts (0 = blocking forever).
    pub fn set_timeouts(&mut self, opts: &DialOpts) -> anyhow::Result<()> {
        match self {
            Conn::Tcp(s) => {
                s.set_read_timeout(timeout(opts.read_timeout_ms))?;
                s.set_write_timeout(timeout(opts.write_timeout_ms))?;
            }
            Conn::Uds(s) => {
                s.set_read_timeout(timeout(opts.read_timeout_ms))?;
                s.set_write_timeout(timeout(opts.write_timeout_ms))?;
            }
        }
        Ok(())
    }

    fn stream(&mut self) -> &mut dyn ReadWrite {
        match self {
            Conn::Tcp(s) => s,
            Conn::Uds(s) => s,
        }
    }

    /// Write one pre-encoded frame (length prefix included) verbatim.
    pub fn send_frame(&mut self, frame: &[u8]) -> std::io::Result<()> {
        let s = self.stream();
        s.write_all(frame)?;
        s.flush()
    }

    /// Write one NDJSON line (the control channel speaks newline-
    /// delimited JSON, not frames).
    pub fn write_line(&mut self, line: &str) -> std::io::Result<()> {
        let s = self.stream();
        s.write_all(line.as_bytes())?;
        s.write_all(b"\n")?;
        s.flush()
    }

    /// Read one frame: the u32 LE length prefix, then exactly that many
    /// bytes (returned without the prefix — ready for
    /// [`crate::gossip::Message::decode_frame`]).
    pub fn recv_frame(&mut self) -> anyhow::Result<Vec<u8>> {
        let s = self.stream();
        let mut len = [0u8; 4];
        s.read_exact(&mut len)
            .map_err(|e| anyhow::anyhow!("reading frame length: {e}"))?;
        let n = u32::from_le_bytes(len) as usize;
        anyhow::ensure!(
            (FRAME_HEADER_BYTES..=MAX_FRAME_BYTES).contains(&n),
            "frame length {n} outside [{FRAME_HEADER_BYTES}, {MAX_FRAME_BYTES}]"
        );
        let mut frame = vec![0u8; n];
        s.read_exact(&mut frame)
            .map_err(|e| anyhow::anyhow!("reading {n}-byte frame body: {e}"))?;
        Ok(frame)
    }
}

trait ReadWrite: Read + Write {}
impl<T: Read + Write> ReadWrite for T {}

/// Dial `addr` under `kind`, retrying with backoff until
/// `opts.dial_timeout_ms` elapses. The error names the unreachable
/// address so a misconfigured fleet file is diagnosable from the
/// message alone.
pub fn dial(kind: TransportKind, addr: &str, opts: &DialOpts) -> anyhow::Result<Conn> {
    let deadline = Instant::now() + Duration::from_millis(opts.dial_timeout_ms.max(1));
    let backoff = Duration::from_millis(opts.backoff_ms.max(1));
    loop {
        let attempt = match kind {
            TransportKind::Tcp => {
                TcpStream::connect(addr).map(Conn::Tcp).map_err(anyhow::Error::from)
            }
            TransportKind::Uds => {
                UnixStream::connect(addr).map(Conn::Uds).map_err(anyhow::Error::from)
            }
        };
        match attempt {
            Ok(mut conn) => {
                conn.set_timeouts(opts)?;
                return Ok(conn);
            }
            Err(e) => {
                if Instant::now() >= deadline {
                    anyhow::bail!(
                        "cannot reach peer at {} address {addr} within {}ms: {e}",
                        kind.name(),
                        opts.dial_timeout_ms
                    );
                }
                std::thread::sleep(backoff);
            }
        }
    }
}

/// An outbound mesh connection that survives a peer restart: a failed
/// send redials (with the configured backoff), replays the HELLO
/// handshake, and retries the frame once.
#[derive(Debug)]
pub struct PeerConn {
    conn: Conn,
    kind: TransportKind,
    addr: String,
    opts: DialOpts,
    hello: Vec<u8>,
}

impl PeerConn {
    /// Dial `addr` and introduce ourselves with a HELLO frame carrying
    /// `my_id`, so the accepting node can map this socket to a peer.
    pub fn connect(
        kind: TransportKind,
        addr: &str,
        opts: &DialOpts,
        my_id: usize,
    ) -> anyhow::Result<PeerConn> {
        let hello = crate::node::control_frame(crate::node::TAG_HELLO, my_id, 0, 0);
        let mut conn = dial(kind, addr, opts)?;
        conn.send_frame(&hello)
            .map_err(|e| anyhow::anyhow!("HELLO to {addr} failed: {e}"))?;
        Ok(PeerConn { conn, kind, addr: addr.to_string(), opts: *opts, hello })
    }

    /// Send one frame, transparently reconnecting (redial + HELLO +
    /// single resend) if the peer restarted under us.
    pub fn send(&mut self, frame: &[u8]) -> anyhow::Result<()> {
        if self.conn.send_frame(frame).is_ok() {
            return Ok(());
        }
        let mut conn = dial(self.kind, &self.addr, &self.opts)
            .map_err(|e| anyhow::anyhow!("reconnect to {} failed: {e:#}", self.addr))?;
        conn.send_frame(&self.hello)
            .and_then(|_| conn.send_frame(frame))
            .map_err(|e| anyhow::anyhow!("resend to {} after reconnect failed: {e}", self.addr))?;
        self.conn = conn;
        Ok(())
    }
}
