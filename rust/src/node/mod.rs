//! The deployment plane: one OS process per client over real sockets.
//!
//! The in-process drivers (`seq`/`par`/`sim`/`async`) all share one
//! address space; this module runs the same lock-step protocol with each
//! client as its own **`cidertf node` daemon**, gossiping canonical wire
//! frames ([`crate::gossip::Message::encode_frame`]) over TCP or
//! Unix-domain sockets. A static [`fleet::FleetConfig`] JSON file names
//! every node's listen address; the **`cidertf fleet`** controller
//! ([`controller`]) spawns a local fleet as child processes, tails each
//! node's event stream over a control socket, and merges the per-node
//! results into one checkpoint.
//!
//! **Bit-identity contract.** A fleet run of a fault-free, honest spec
//! (`fault: none`, `adversary: none`, default stop rules — enforced by
//! [`crate::engine::spec::ExperimentSpec::validate`]) produces a merged
//! checkpoint **byte-identical** to the `sim` driver's final checkpoint
//! on the same spec: every node replicates the shared block-sampler
//! stream, builds the same deterministic initial state, steps only its
//! own client, and applies neighbor deltas in the same sorted order the
//! in-process loop uses. Asserted in `tests/node_fleet.rs` and the CI
//! `fleet-smoke` job.
//!
//! Module map:
//! * [`transport`] — listeners/connections over TCP and UDS, framed
//!   send/recv, dial with retry-backoff, reconnect on peer restart. The
//!   only file in `node/` allowed to read the wall clock (lint D004).
//! * [`fleet`] — fleet-config parsing/validation, per-node outcome
//!   blobs, and the deterministic merge into a [`crate::engine::checkpoint`]
//!   session state.
//! * [`daemon`] — the long-running `cidertf node` loop for one client.
//! * [`controller`] — `cidertf fleet spawn|status|stop`.

pub mod controller;
pub mod daemon;
pub mod fleet;
pub mod transport;

/// Control-plane frame tag: the sender's event trigger suppressed this
/// round's delta (an explicit empty frame keeps the mesh lock-step, so a
/// receiver never blocks on a peer that chose not to publish). Never
/// valid inside [`crate::gossip::Message::decode_frame`] and never
/// charged to comm ledgers.
pub const TAG_SUPPRESSED: u8 = 0xFE;

/// Control-plane frame tag: connection handshake. The dialing node's id
/// rides in the frame's `from` word so the accepting side can map the
/// socket to a peer. Never charged to comm ledgers.
pub const TAG_HELLO: u8 = 0xFF;

/// Assemble a control frame (empty body) for [`TAG_SUPPRESSED`] /
/// [`TAG_HELLO`], reusing the standard length-prefixed envelope.
pub(crate) fn control_frame(tag: u8, from: usize, mode: usize, round: usize) -> Vec<u8> {
    crate::gossip::encode_frame_parts(tag, from as u32, mode as u32, round as u32, 0, &[])
}
