//! The sweep engine: declare a whole experiment grid, run it on a worker
//! pool, get a deterministic aggregate.
//!
//! The paper's results are all *grids* — Fig. 3–7 and Tables II–IV sweep
//! algorithms × datasets × losses × τ × K — and every one of them used to
//! be a hand-rolled sequential `for` loop over single runs. This module
//! replaces those loops with one declarative, parallel executor:
//!
//! * [`SweepSpec`] — a base [`ExperimentSpec`] plus axis grids (dataset /
//!   loss / algo / τ / K / topology / compressor / network / driver /
//!   trigger / γ / seed lists). [`SweepSpec::expand`] produces the
//!   cross-product of concrete `ExperimentSpec`s in a fixed nesting
//!   order (dataset outermost, seed innermost), so the **expansion
//!   index** of every run is stable across invocations. Serializes to
//!   JSON (schema [`SWEEP_SCHEMA`], `cidertf sweep --spec sweep.json`)
//!   with registry-backed did-you-mean errors on every named axis.
//! * [`run_specs`] — the one executor. A scoped worker pool pulls runs
//!   off an atomic queue; each worker drives a full
//!   [`Session`] with **`Arc`-shared datasets** (each distinct
//!   (dataset, value-kind) pair is loaded once on the main thread and
//!   shared read-only — PR 4's `Arc<ShardData>` data plane makes the
//!   per-run sharding a pointer copy, not a tensor copy). Per-run
//!   outputs (curve CSV, record JSON, optional JSONL stream) land under
//!   one sweep directory.
//! * **Determinism** — runs are independent and internally seeded, so
//!   the aggregate `sweep.jsonl` and the summary table are ordered by
//!   expansion index (never completion order) and contain only
//!   deterministic fields (no wall-clock times): their bytes are
//!   **identical whether the sweep ran with 1 worker or N**
//!   (test-asserted in `tests/sweep.rs`).
//! * **Resumability** — every finished run writes a
//!   `run_<index>_<label>.json` record (schema [`RUN_SCHEMA`]) embedding
//!   its exact spec; re-running the sweep skips runs whose record file
//!   matches and re-executes only the missing (or spec-drifted) ones.
//!
//! The harness figure/table drivers (`harness::fig3` … `fig7`,
//! `ablate`, `faults`) are now thin [`SweepSpec`] constructors fed to
//! this executor.

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::adversary::AdversarySchedule;
use crate::compress::Compressor;
use crate::data::Dataset;
use crate::gossip::Aggregator;
use crate::tensor::partition::Partitioner;
use crate::engine::metrics::RunRecord;
use crate::engine::session::{CsvObserver, JsonlObserver, Session};
use crate::engine::spec::{algo_from_json, algo_to_json, fs_component, ExperimentSpec};
use crate::engine::AlgoConfig;
use crate::factor::FactorSet;
use crate::losses::Loss;
use crate::net::driver::DriverKind;
use crate::net::sim::FaultConfig;
use crate::runtime::NativeOrPjrt;
use crate::topology::Topology;
use crate::util::benchkit::{fmt_bytes, Table};
use crate::util::json::Json;

/// Schema tag of a serialized [`SweepSpec`].
pub const SWEEP_SCHEMA: &str = "cidertf-sweep-v1";

/// Schema tag of a per-run record file (`run_<index>_<label>.json`).
pub const RUN_SCHEMA: &str = "cidertf-sweep-run-v1";

/// One point on the event-trigger schedule axis: λ₀ scale and growth α
/// (the paper grid-searches α in `[1, 2]`). A `lambda0_scale` of exactly
/// `0.0` means "trigger disabled" — expansion turns
/// `algo.event_triggered` off for that cell.
#[derive(Debug, Clone, PartialEq)]
pub struct TriggerPoint {
    /// scale on λ₀ = scale/γ (`0.0` = trigger disabled baseline)
    pub lambda0_scale: f64,
    /// threshold growth factor α
    pub alpha: f64,
}

/// A declarative experiment grid: a base [`ExperimentSpec`] plus one
/// value list per sweep axis. Empty axes keep the base value; non-empty
/// axes multiply the grid. See [`SweepSpec::expand`] for the expansion
/// order and the post-expansion policy passes.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    /// every field a cell does not override comes from here
    pub base: ExperimentSpec,
    /// dataset axis (registry names / `file:` / `csv:` specs)
    pub datasets: Vec<String>,
    /// loss axis
    pub losses: Vec<Loss>,
    /// algorithm axis (full Table II rows, including compressor/ρ/flags)
    pub algos: Vec<AlgoConfig>,
    /// local-round period axis (rewrites each algo's τ and `_t<τ>` name)
    pub taus: Vec<usize>,
    /// client-count axis
    pub ks: Vec<usize>,
    /// communication-graph axis
    pub topologies: Vec<Topology>,
    /// compressor-override axis (suffixes the algo name with the tag)
    pub compressors: Vec<Compressor>,
    /// network fault-envelope axis (`None` = ideal)
    pub networks: Vec<Option<FaultConfig>>,
    /// execution-path axis
    pub drivers: Vec<DriverKind>,
    /// patient-partitioner axis (non-IID heterogeneity)
    pub partitioners: Vec<Partitioner>,
    /// consensus-aggregator axis (Byzantine-robust alternatives)
    pub aggregators: Vec<Aggregator>,
    /// Byzantine-adversary axis (`None` = all-honest)
    pub adversaries: Vec<Option<AdversarySchedule>>,
    /// event-trigger schedule axis
    pub triggers: Vec<TriggerPoint>,
    /// learning-rate axis (mutually exclusive with `auto_gamma`)
    pub gammas: Vec<f64>,
    /// master-seed axis
    pub seeds: Vec<u64>,
    /// run centralized presets (gcp/bras_cpd/centralized_cidertf) with
    /// K = 1 regardless of the K axis (the harness convention)
    pub centralized_k1: bool,
    /// derive γ per cell from the grid-searched (dataset, loss) table
    /// ([`tuned_gamma`]), rescaled by 1-β for momentum runs — exactly
    /// what `Ctx::base_config` always did
    pub auto_gamma: bool,
    /// multiply `epochs` by this for block-randomized algos (they touch
    /// 1/D of the gradients per iteration; Fig. 7 matches total gradient
    /// work by setting this to the tensor order)
    pub block_random_epochs_scale: usize,
}

impl SweepSpec {
    /// A sweep over nothing: every axis empty, expansion = `[base]`.
    pub fn new(base: ExperimentSpec) -> Self {
        SweepSpec {
            base,
            datasets: Vec::new(),
            losses: Vec::new(),
            algos: Vec::new(),
            taus: Vec::new(),
            ks: Vec::new(),
            topologies: Vec::new(),
            compressors: Vec::new(),
            networks: Vec::new(),
            drivers: Vec::new(),
            partitioners: Vec::new(),
            aggregators: Vec::new(),
            adversaries: Vec::new(),
            triggers: Vec::new(),
            gammas: Vec::new(),
            seeds: Vec::new(),
            centralized_k1: false,
            auto_gamma: false,
            block_random_epochs_scale: 1,
        }
    }

    /// The tiny built-in grid behind `cidertf sweep --smoke`: 2 algos ×
    /// 2 seeds on the `tiny` tensor — 4 cheap runs that still exercise
    /// dataset sharing, the worker pool, and the deterministic aggregate.
    pub fn smoke() -> Self {
        let mut base = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        base.k = 2;
        base.rank = 4;
        base.fiber_samples = 16;
        base.eval_batch = 64;
        base.gamma = 0.5;
        base.epochs = 1;
        base.iters_per_epoch = 40;
        let mut spec = SweepSpec::new(base);
        spec.algos = vec![AlgoConfig::cidertf(2), AlgoConfig::dpsgd()];
        spec.seeds = vec![7, 8];
        spec
    }

    /// The robustness grid behind `cidertf sweep --smoke-robust`:
    /// (honest, sign_flip) × (mean, trimmed_mean) on the `tiny` tensor
    /// under a skewed partition — 4 cheap runs exercising the adversary
    /// plane, the robust consensus path, and a non-IID partitioner on
    /// the deterministic executor.
    pub fn robust_smoke() -> Self {
        let mut base = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        base.k = 4;
        base.rank = 4;
        base.fiber_samples = 16;
        base.eval_batch = 64;
        base.gamma = 0.5;
        base.epochs = 1;
        base.iters_per_epoch = 40;
        base.partitioner = Partitioner::Skewed(1.0);
        let mut spec = SweepSpec::new(base);
        spec.aggregators = vec![Aggregator::Mean, Aggregator::TrimmedMean(0.25)];
        spec.adversaries = vec![None, Some(AdversarySchedule::sign_flip(0.25))];
        spec.seeds = vec![7];
        spec
    }

    /// Cheap cross-axis invariants, checked before expansion.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.block_random_epochs_scale >= 1,
            "block_random_epochs_scale must be >= 1"
        );
        anyhow::ensure!(
            !(self.auto_gamma && !self.gammas.is_empty()),
            "auto_gamma and an explicit gamma axis are mutually exclusive"
        );
        for (i, t) in self.triggers.iter().enumerate() {
            anyhow::ensure!(
                t.lambda0_scale >= 0.0 && t.alpha >= 1.0,
                "triggers[{i}]: need lambda0_scale >= 0 and alpha >= 1"
            );
        }
        Ok(())
    }

    /// Number of grid cells [`SweepSpec::expand`] will produce.
    pub fn len(&self) -> usize {
        let dim = |n: usize| n.max(1);
        dim(self.datasets.len())
            * dim(self.losses.len())
            * dim(self.algos.len())
            * dim(self.taus.len())
            * dim(self.ks.len())
            * dim(self.topologies.len())
            * dim(self.compressors.len())
            * dim(self.networks.len())
            * dim(self.drivers.len())
            * dim(self.partitioners.len())
            * dim(self.aggregators.len())
            * dim(self.adversaries.len())
            * dim(self.triggers.len())
            * dim(self.gammas.len())
            * dim(self.seeds.len())
    }

    /// True when expansion is just `[base]`.
    pub fn is_empty(&self) -> bool {
        self.len() == 1
    }

    /// Expand to the cross-product of concrete specs. Nesting order is
    /// fixed — dataset → loss → algo → τ → K → topology → compressor →
    /// network → driver → partitioner → aggregator → adversary →
    /// trigger → γ → seed (dataset outermost, seed
    /// innermost) — so a run's expansion index is stable across
    /// invocations, which is what resumability and the deterministic
    /// aggregate key on. After the product, four policy passes run per
    /// cell: `centralized_k1`, `auto_gamma`, the block-random epoch
    /// scale, and a driver upgrade (a fault envelope on a lock-step
    /// driver moves to `sim`, mirroring the CLI's `--network` handling);
    /// every cell is then validated.
    pub fn expand(&self) -> anyhow::Result<Vec<ExperimentSpec>> {
        self.validate()?;
        let mut specs = vec![self.base.clone()];
        specs = apply_axis(specs, &self.datasets, |s, d| s.dataset = d.clone());
        specs = apply_axis(specs, &self.losses, |s, l| s.loss = *l);
        specs = apply_axis(specs, &self.algos, |s, a| s.algo = a.clone());
        specs = apply_axis(specs, &self.taus, |s, t| {
            s.algo.tau = *t;
            s.algo.name = retau_name(&s.algo.name, *t);
        });
        specs = apply_axis(specs, &self.ks, |s, k| s.k = *k);
        specs = apply_axis(specs, &self.topologies, |s, t| s.topology = *t);
        specs = apply_axis(specs, &self.compressors, |s, c| {
            s.algo.compressor = *c;
            s.algo.name = format!("{}_{}", s.algo.name, compressor_tag(c));
        });
        specs = apply_axis(specs, &self.networks, |s, f| s.fault = f.clone());
        specs = apply_axis(specs, &self.drivers, |s, d| s.driver = *d);
        specs = apply_axis(specs, &self.partitioners, |s, p| s.partitioner = p.clone());
        specs = apply_axis(specs, &self.aggregators, |s, a| s.aggregator = a.clone());
        specs = apply_axis(specs, &self.adversaries, |s, a| s.adversary = a.clone());
        specs = apply_axis(specs, &self.triggers, |s, t| {
            s.trigger_lambda0_scale = t.lambda0_scale.max(f64::MIN_POSITIVE);
            s.trigger_alpha = t.alpha;
            if t.lambda0_scale == 0.0 {
                s.algo.event_triggered = false;
            }
            s.algo.name = format!("{}_trig_s{}_a{}", s.algo.name, t.lambda0_scale, t.alpha);
        });
        specs = apply_axis(specs, &self.gammas, |s, g| s.gamma = *g);
        specs = apply_axis(specs, &self.seeds, |s, sd| s.seed = *sd);

        for (i, s) in specs.iter_mut().enumerate() {
            if self.centralized_k1 {
                s.k = centralized_k(&s.algo, s.k);
            }
            if self.auto_gamma {
                let mut gamma = tuned_gamma(&s.dataset, s.loss);
                if let Some(beta) = s.algo.momentum {
                    gamma *= 1.0 - beta;
                }
                s.gamma = gamma;
            }
            if self.block_random_epochs_scale > 1 && s.algo.block_random {
                s.epochs *= self.block_random_epochs_scale;
            }
            if s.fault.is_some()
                && matches!(s.driver, DriverKind::Sequential | DriverKind::Parallel)
            {
                s.driver = DriverKind::Sim;
            }
            // Byzantine cells need the reference loop: the barrier-parallel
            // driver rejects adversaries, and seq is bit-identical anyway
            if s.adversary.is_some() && s.driver == DriverKind::Parallel {
                s.driver = DriverKind::Sequential;
            }
            s.validate()
                .map_err(|e| anyhow::anyhow!("sweep cell {i} ({}): {e}", s.label()))?;
        }
        Ok(specs)
    }

    // ---- JSON layer ----

    /// Serialize (schema [`SWEEP_SCHEMA`]): the base spec verbatim, each
    /// axis as an array (algos as full objects, networks as fault
    /// objects or `null`, seeds as lossless strings).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str(SWEEP_SCHEMA.to_string())),
            ("base", self.base.to_json()),
            ("datasets", Json::arr_str(&self.datasets)),
            (
                "losses",
                Json::Arr(
                    self.losses.iter().map(|l| Json::Str(l.name().to_string())).collect(),
                ),
            ),
            ("algos", Json::Arr(self.algos.iter().map(algo_to_json).collect())),
            ("taus", Json::arr_usize(&self.taus)),
            ("ks", Json::arr_usize(&self.ks)),
            (
                "topologies",
                Json::Arr(
                    self.topologies.iter().map(|t| Json::Str(t.name().to_string())).collect(),
                ),
            ),
            (
                "compressors",
                Json::Arr(
                    self.compressors.iter().map(|c| Json::Str(c.spec_string())).collect(),
                ),
            ),
            (
                "networks",
                Json::Arr(
                    self.networks
                        .iter()
                        .map(|n| n.as_ref().map(FaultConfig::to_json).unwrap_or(Json::Null))
                        .collect(),
                ),
            ),
            (
                "drivers",
                Json::Arr(
                    self.drivers.iter().map(|d| Json::Str(d.name().to_string())).collect(),
                ),
            ),
            (
                "partitioners",
                Json::Arr(
                    self.partitioners.iter().map(|p| Json::Str(p.spec_string())).collect(),
                ),
            ),
            (
                "aggregators",
                Json::Arr(
                    self.aggregators.iter().map(|a| Json::Str(a.spec_string())).collect(),
                ),
            ),
            (
                "adversaries",
                Json::Arr(
                    self.adversaries
                        .iter()
                        .map(|a| {
                            a.as_ref().map(AdversarySchedule::to_json).unwrap_or(Json::Null)
                        })
                        .collect(),
                ),
            ),
            (
                "triggers",
                Json::Arr(
                    self.triggers
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("lambda0_scale", Json::Num(t.lambda0_scale)),
                                ("alpha", Json::Num(t.alpha)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("gammas", Json::arr_f64(&self.gammas)),
            ("seeds", Json::arr_u64(&self.seeds)),
            ("centralized_k1", Json::Bool(self.centralized_k1)),
            ("auto_gamma", Json::Bool(self.auto_gamma)),
            (
                "block_random_epochs_scale",
                Json::Num(self.block_random_epochs_scale as f64),
            ),
        ])
    }

    /// Deserialize the [`SweepSpec::to_json`] layout. Strict like the
    /// experiment spec: unknown keys error with a did-you-mean hint, and
    /// every named axis element resolves through its
    /// [`crate::registry`] table (so `"lozzy:0.2"` suggests `lossy`).
    /// Hand-written files may use strings on the algo axis
    /// (`"cidertf:8"`) and string scenario names on the network axis
    /// (`"lossy:0.2"`); serialization always emits the full objects.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        j.ensure_known_keys(
            "sweep",
            &[
                "schema",
                "base",
                "datasets",
                "losses",
                "algos",
                "taus",
                "ks",
                "topologies",
                "compressors",
                "networks",
                "drivers",
                "partitioners",
                "aggregators",
                "adversaries",
                "triggers",
                "gammas",
                "seeds",
                "centralized_k1",
                "auto_gamma",
                "block_random_epochs_scale",
            ],
        )?;
        if let Some(s) = j.get("schema").and_then(Json::as_str) {
            anyhow::ensure!(
                s == SWEEP_SCHEMA,
                "unsupported sweep schema '{s}' (want {SWEEP_SCHEMA})"
            );
        }
        let base = ExperimentSpec::from_json(
            j.get("base").ok_or_else(|| anyhow::anyhow!("missing 'base' spec"))?,
        )?;

        let mut algos = Vec::new();
        for (i, v) in arr(j, "algos")?.iter().enumerate() {
            let a = match v {
                Json::Str(s) => crate::registry::algos().resolve(s),
                obj => algo_from_json(obj),
            }
            .map_err(|e| anyhow::anyhow!("algos[{i}]: {e}"))?;
            algos.push(a);
        }
        let mut networks = Vec::new();
        for (i, v) in arr(j, "networks")?.iter().enumerate() {
            let n = match v {
                Json::Null => Ok(None),
                Json::Str(s) => crate::registry::networks().resolve(s),
                obj => FaultConfig::from_json(obj).map(Some),
            }
            .map_err(|e| anyhow::anyhow!("networks[{i}]: {e}"))?;
            networks.push(n);
        }
        let mut adversaries = Vec::new();
        for (i, v) in arr(j, "adversaries")?.iter().enumerate() {
            let a = match v {
                Json::Null => Ok(None),
                Json::Str(s) => crate::registry::adversaries().resolve(s),
                obj => AdversarySchedule::from_json(obj).map(Some),
            }
            .map_err(|e| anyhow::anyhow!("adversaries[{i}]: {e}"))?;
            adversaries.push(a);
        }
        let mut triggers = Vec::new();
        for (i, v) in arr(j, "triggers")?.iter().enumerate() {
            v.ensure_known_keys("trigger point", &["lambda0_scale", "alpha"])
                .map_err(|e| anyhow::anyhow!("triggers[{i}]: {e}"))?;
            triggers.push(TriggerPoint {
                lambda0_scale: v
                    .req_f64("lambda0_scale")
                    .map_err(|e| anyhow::anyhow!("triggers[{i}]: {e}"))?,
                alpha: v.req_f64("alpha").map_err(|e| anyhow::anyhow!("triggers[{i}]: {e}"))?,
            });
        }

        let spec = SweepSpec {
            base,
            datasets: str_list(j, "datasets")?,
            losses: crate::registry::losses().resolve_list(&str_list(j, "losses")?)?,
            algos,
            taus: usize_list(j, "taus")?,
            ks: usize_list(j, "ks")?,
            topologies: crate::registry::topologies()
                .resolve_list(&str_list(j, "topologies")?)?,
            compressors: crate::registry::compressors()
                .resolve_list(&str_list(j, "compressors")?)?,
            networks,
            drivers: crate::registry::drivers().resolve_list(&str_list(j, "drivers")?)?,
            partitioners: crate::registry::partitioners()
                .resolve_list(&str_list(j, "partitioners")?)?,
            aggregators: crate::registry::aggregators()
                .resolve_list(&str_list(j, "aggregators")?)?,
            adversaries,
            triggers,
            gammas: f64_list(j, "gammas")?,
            seeds: u64_list(j, "seeds")?,
            centralized_k1: opt_bool(j, "centralized_k1")?,
            auto_gamma: opt_bool(j, "auto_gamma")?,
            block_random_epochs_scale: match j.get("block_random_epochs_scale") {
                None => 1,
                Some(v) => v.as_usize().ok_or_else(|| {
                    anyhow::anyhow!("invalid 'block_random_epochs_scale' (integer expected)")
                })?,
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a sweep spec from JSON text.
    pub fn from_json_str(s: &str) -> anyhow::Result<Self> {
        let j = Json::parse(s).map_err(|e| anyhow::anyhow!("sweep spec: {e}"))?;
        Self::from_json(&j)
    }

    /// Load from a `--spec sweep.json` file.
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read sweep spec {}: {e}", path.display()))?;
        Self::from_json_str(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Write the sweep spec as pretty JSON.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_pretty_string())
            .map_err(|e| anyhow::anyhow!("cannot write sweep spec {}: {e}", path.display()))
    }
}

/// Cross one axis into the accumulated grid (no-op when the axis is
/// empty). Applying axes in sequence makes the *last* applied axis the
/// innermost loop of the expansion order.
fn apply_axis<T>(
    specs: Vec<ExperimentSpec>,
    values: &[T],
    set: impl Fn(&mut ExperimentSpec, &T),
) -> Vec<ExperimentSpec> {
    if values.is_empty() {
        return specs;
    }
    let mut out = Vec::with_capacity(specs.len() * values.len());
    for s in specs {
        for v in values {
            let mut cell = s.clone();
            set(&mut cell, v);
            out.push(cell);
        }
    }
    out
}

/// Rewrite an algo name's `_t<digits>` suffix for the τ axis (appends
/// when the name carries no period suffix, e.g. `dpsgd` → `dpsgd_t4`).
fn retau_name(name: &str, tau: usize) -> String {
    if let Some(pos) = name.rfind("_t") {
        let tail = &name[pos + 2..];
        if !tail.is_empty() && tail.bytes().all(|b| b.is_ascii_digit()) {
            return format!("{}_t{tau}", &name[..pos]);
        }
    }
    format!("{name}_t{tau}")
}

/// Filename-safe tag for the compressor-override axis.
fn compressor_tag(c: &Compressor) -> String {
    match c {
        Compressor::Sign => "sign".to_string(),
        Compressor::None => "dense".to_string(),
        Compressor::TopK { ratio } => format!("top{ratio}"),
    }
}

/// Grid-searched learning rate per (dataset, loss) — powers of two, as
/// the paper prescribes (§IV-A3); found by `cidertf tune`. The canonical
/// table — `harness::Ctx::gamma_for` delegates here.
pub fn tuned_gamma(dataset: &str, loss: Loss) -> f64 {
    match (dataset, loss) {
        ("tiny", Loss::Logit) => 0.5,
        ("tiny", Loss::Ls) => 2.0,
        (_, Loss::Logit) => 8.0,
        (_, Loss::Ls) => 8.0,
    }
}

/// Centralized-vs-decentralized K selection: the centralized presets
/// always run K = 1. Sweep expansion applies this when
/// [`SweepSpec::centralized_k1`] is set. The τ/compressor/trigger axes
/// rewrite algo names by *appending* suffixes (`bras_cpd` →
/// `bras_cpd_t2`), so the centralized family is matched by prefix —
/// a renamed centralized baseline must not silently run decentralized.
pub fn centralized_k(algo: &AlgoConfig, default_k: usize) -> usize {
    const CENTRALIZED: [&str; 3] = ["gcp", "bras_cpd", "centralized_cidertf"];
    let name = algo.name.as_str();
    let is_centralized = CENTRALIZED
        .iter()
        .any(|c| name == *c || (name.starts_with(c) && name.as_bytes()[c.len()] == b'_'));
    if is_centralized {
        1
    } else {
        default_k
    }
}

// ---- JSON list helpers ----

fn arr<'a>(j: &'a Json, key: &str) -> anyhow::Result<&'a [Json]> {
    match j.get(key) {
        None => Ok(&[]),
        Some(Json::Arr(a)) => Ok(a),
        Some(_) => anyhow::bail!("'{key}' must be an array"),
    }
}

fn str_list(j: &Json, key: &str) -> anyhow::Result<Vec<String>> {
    arr(j, key)?
        .iter()
        .enumerate()
        .map(|(i, v)| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("'{key}[{i}]' must be a string"))
        })
        .collect()
}

fn usize_list(j: &Json, key: &str) -> anyhow::Result<Vec<usize>> {
    arr(j, key)?
        .iter()
        .enumerate()
        .map(|(i, v)| {
            v.as_usize().ok_or_else(|| anyhow::anyhow!("'{key}[{i}]' must be an integer"))
        })
        .collect()
}

fn f64_list(j: &Json, key: &str) -> anyhow::Result<Vec<f64>> {
    arr(j, key)?
        .iter()
        .enumerate()
        .map(|(i, v)| {
            v.as_f64().ok_or_else(|| anyhow::anyhow!("'{key}[{i}]' must be a number"))
        })
        .collect()
}

fn u64_list(j: &Json, key: &str) -> anyhow::Result<Vec<u64>> {
    arr(j, key)?
        .iter()
        .enumerate()
        .map(|(i, v)| {
            v.as_u64().ok_or_else(|| anyhow::anyhow!("'{key}[{i}]' must be a u64"))
        })
        .collect()
}

fn opt_bool(j: &Json, key: &str) -> anyhow::Result<bool> {
    match j.get(key) {
        None => Ok(false),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("invalid '{key}' (bool expected)")),
    }
}

// ---------------------------------------------------------------------
// The executor
// ---------------------------------------------------------------------

/// How [`run_specs`] executes and where it writes.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// worker threads (clamped to `[1, pending runs]`)
    pub workers: usize,
    /// sweep directory: per-run CSV/record files + `sweep.jsonl`
    pub dir: PathBuf,
    /// skip runs whose record file already matches their spec
    pub resume: bool,
    /// write the per-run training-curve CSV (what the figures plot)
    pub curves: bool,
    /// stream per-run progress as `<label>.jsonl`
    pub per_run_jsonl: bool,
    /// suppress per-run completion lines (the summary table still prints)
    pub quiet: bool,
    /// datasets to seed the executor's cache with (keyed by
    /// [`dataset_cache_key`]) — a caller that already materialized a
    /// dataset (fig7's FMS reference run) hands over its `Arc` instead
    /// of letting the executor load a second copy
    pub preload: BTreeMap<(String, bool), Arc<Dataset>>,
}

impl SweepOptions {
    /// Defaults: `workers` threads into `dir`, resume on, curves on,
    /// per-run JSONL off, nothing preloaded.
    pub fn new(dir: impl Into<PathBuf>, workers: usize) -> Self {
        SweepOptions {
            workers,
            dir: dir.into(),
            resume: true,
            curves: true,
            per_run_jsonl: false,
            quiet: false,
            preload: BTreeMap::new(),
        }
    }
}

/// A sensible worker default: the machine's parallelism, capped at 8
/// (each run may itself allocate per-client state; the cap keeps memory
/// bounded on large hosts).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// One finished grid cell, aligned with the expansion order.
#[derive(Debug, Clone)]
pub struct SweepRunResult {
    /// expansion index (== position in [`SweepOutcome::runs`])
    pub index: usize,
    /// true when the run was restored from its record file, not executed
    pub skipped: bool,
    /// the run's metric record
    pub record: RunRecord,
}

/// Everything a finished sweep produced.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// the expanded specs, in expansion order
    pub runs: Vec<ExperimentSpec>,
    /// one result per run, same order
    pub results: Vec<SweepRunResult>,
    /// the deterministic aggregate (`<dir>/sweep.jsonl`)
    pub jsonl_path: PathBuf,
    /// the datasets the executor loaded this invocation, keyed by
    /// (dataset spec, is-least-squares) — empty when every run was
    /// restored from records. Callers needing the data post-sweep (e.g.
    /// fig6's tensor order) reuse these instead of re-loading.
    pub datasets: BTreeMap<(String, bool), Arc<Dataset>>,
}

impl SweepOutcome {
    /// The records in expansion order (what the old per-figure loops
    /// returned).
    pub fn into_records(self) -> Vec<RunRecord> {
        self.results.into_iter().map(|r| r.record).collect()
    }

    /// How many runs were restored from record files instead of re-run.
    pub fn skipped(&self) -> usize {
        self.results.iter().filter(|r| r.skipped).count()
    }

    /// The dataset for (name, loss): the executor's `Arc` when this
    /// invocation loaded it, otherwise loaded fresh (fully-restored
    /// sweeps load nothing up front).
    pub fn dataset(&self, name: &str, loss: Loss) -> anyhow::Result<Arc<Dataset>> {
        if let Some(d) = self.datasets.get(&(name.to_string(), loss == Loss::Ls)) {
            return Ok(Arc::clone(d));
        }
        let vk = if loss == Loss::Ls {
            crate::tensor::synth::ValueKind::Gaussian
        } else {
            crate::tensor::synth::ValueKind::Binary
        };
        Ok(Arc::new(crate::data::load_dataset(name, vk)?))
    }
}

/// A worker slot: `None` until its run executes, then the record or the
/// formatted error (errors cross the pool as strings; the vendored
/// `anyhow` error need not be `Send`).
type RunSlot = Option<Result<RunRecord, String>>;

/// Expand a [`SweepSpec`] and execute it — the one entry point the CLI
/// and every harness driver share.
pub fn execute(
    spec: &SweepSpec,
    opts: &SweepOptions,
    fms_reference: Option<&FactorSet>,
) -> anyhow::Result<SweepOutcome> {
    run_specs(spec.expand()?, opts, fms_reference)
}

/// Execute an explicit run list (what [`execute`] calls after expansion;
/// harness drivers that post-process their expanded specs call this
/// directly). Runs execute as jobs on the shared persistent worker pool
/// ([`crate::runtime::pool`]); datasets are loaded once per distinct
/// (dataset, value-kind) pair and `Arc`-shared read-only across workers.
/// The aggregate `sweep.jsonl` and summary table are ordered by
/// expansion index and carry no wall-clock fields, so their bytes do not
/// depend on the worker count.
pub fn run_specs(
    runs: Vec<ExperimentSpec>,
    opts: &SweepOptions,
    fms_reference: Option<&FactorSet>,
) -> anyhow::Result<SweepOutcome> {
    anyhow::ensure!(!runs.is_empty(), "sweep expanded to zero runs");
    std::fs::create_dir_all(&opts.dir)
        .map_err(|e| anyhow::anyhow!("cannot create sweep dir {}: {e}", opts.dir.display()))?;

    // deterministic per-run file stems (labels deduped by expansion index)
    let stems = run_stems(&runs);

    // resumability: restore finished runs whose record matches their spec
    let mut restored: Vec<Option<RunRecord>> = Vec::with_capacity(runs.len());
    let mut pending: Vec<usize> = Vec::new();
    for (i, spec) in runs.iter().enumerate() {
        let saved = if opts.resume {
            load_saved_record(&record_path(&opts.dir, i, &stems[i]), spec)
        } else {
            None
        };
        if saved.is_none() {
            pending.push(i);
        }
        restored.push(saved);
    }
    if !opts.quiet && pending.len() < runs.len() {
        println!(
            "resuming sweep: {} of {} runs already recorded in {}",
            runs.len() - pending.len(),
            runs.len(),
            opts.dir.display()
        );
    }

    // load each distinct dataset once, share read-only — only the ones
    // pending runs actually touch (a fully-restored sweep loads nothing
    // beyond what the caller preloaded)
    let mut datasets: BTreeMap<(String, bool), Arc<Dataset>> = opts.preload.clone();
    for &i in &pending {
        let spec = &runs[i];
        if let Entry::Vacant(slot) = datasets.entry(dataset_key(spec)) {
            let data = spec
                .dataset_data()
                .map_err(|e| anyhow::anyhow!("dataset '{}': {e}", spec.dataset))?;
            slot.insert(Arc::new(data));
        }
    }

    // the pool: one shared-worker-pool job per pending run
    // (`runtime::pool` — the same persistent threads the compute backend
    // uses; jobs after a failure bail out fast so the first error
    // surfaces without burning the rest of the grid)
    let slots: Vec<Mutex<RunSlot>> = runs.iter().map(|_| Mutex::new(None)).collect();
    if !pending.is_empty() {
        let n_workers = opts.workers.clamp(1, pending.len());
        let abort = AtomicBool::new(false);
        crate::runtime::pool::parallel_for(n_workers, pending.len(), &|slot| {
            // ordering: SeqCst — cold advisory abort flag, read once per
            // run; Relaxed is confined to runtime/pool.rs (D010).
            if abort.load(Ordering::SeqCst) {
                return;
            }
            let i = pending[slot];
            let outcome = execute_one(&runs[i], i, &stems[i], &datasets, opts, fms_reference)
                .map_err(|e| format!("{e:#}"));
            if outcome.is_err() {
                // ordering: SeqCst — see the matching load above.
                abort.store(true, Ordering::SeqCst);
            }
            *slots[i].lock().unwrap() = Some(outcome);
        });
    }

    // collect in expansion order; surface the first real error
    let raw: Vec<RunSlot> = slots.into_iter().map(|m| m.into_inner().unwrap()).collect();
    for (i, r) in raw.iter().enumerate() {
        if let Some(Err(msg)) = r {
            anyhow::bail!("sweep run {i} ({}) failed: {msg}", runs[i].label());
        }
    }
    let mut results = Vec::with_capacity(runs.len());
    for (i, (saved, executed)) in restored.into_iter().zip(raw).enumerate() {
        let (record, skipped) = match (saved, executed) {
            (Some(rec), _) => (rec, true),
            (None, Some(Ok(rec))) => (rec, false),
            (None, _) => anyhow::bail!(
                "sweep run {i} ({}) was never executed (pool aborted early)",
                runs[i].label()
            ),
        };
        results.push(SweepRunResult { index: i, skipped, record });
    }

    // deterministic aggregate + summary, both in expansion order
    crate::util::invariant::aggregate_expansion_order(results.iter().map(|r| r.index));
    let jsonl_path = opts.dir.join("sweep.jsonl");
    write_aggregate(&jsonl_path, &runs, &results)?;
    print_summary(&runs, &results);
    if !opts.quiet {
        println!(
            "sweep complete: {} runs ({} restored) -> {}",
            runs.len(),
            results.iter().filter(|r| r.skipped).count(),
            jsonl_path.display()
        );
    }
    Ok(SweepOutcome { runs, results, jsonl_path, datasets })
}

/// Dataset-cache key: the loader spec plus the value model the loss
/// selects (mirrors [`ExperimentSpec::dataset_data`]). Used for
/// [`SweepOptions::preload`] and [`SweepOutcome::datasets`].
pub fn dataset_cache_key(dataset: &str, loss: Loss) -> (String, bool) {
    (dataset.to_string(), loss == Loss::Ls)
}

fn dataset_key(spec: &ExperimentSpec) -> (String, bool) {
    dataset_cache_key(&spec.dataset, spec.loss)
}

/// Filename stems, one per run: the spec label, made filesystem-safe,
/// with the expansion index appended whenever two runs share a label
/// (e.g. the same config at several drop rates).
fn run_stems(runs: &[ExperimentSpec]) -> Vec<String> {
    let labels: Vec<String> = runs.iter().map(|s| fs_component(&s.label())).collect();
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    for l in &labels {
        *counts.entry(l.as_str()).or_insert(0) += 1;
    }
    labels
        .iter()
        .enumerate()
        .map(|(i, l)| {
            if counts[l.as_str()] > 1 {
                format!("{l}_r{i:03}")
            } else {
                l.clone()
            }
        })
        .collect()
}

fn record_path(dir: &Path, index: usize, stem: &str) -> PathBuf {
    dir.join(format!("run_{index:03}_{stem}.json"))
}

/// Reload a finished run's record, iff the file parses and the embedded
/// spec is *exactly* the spec we are about to run (any drift — profile,
/// seed, axis edit — forces a re-run).
fn load_saved_record(path: &Path, spec: &ExperimentSpec) -> Option<RunRecord> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    if j.get("schema").and_then(Json::as_str) != Some(RUN_SCHEMA) {
        return None;
    }
    if j.get("spec") != Some(&spec.to_json()) {
        return None;
    }
    RunRecord::from_json(j.get("record")?).ok()
}

/// Run one grid cell on this worker: resolve the backend from the
/// spec's flag, attach the per-run observers, drive the session on the
/// shared dataset, and persist the record file (atomically — write then
/// rename — so a crash never leaves a half-record that resume trusts).
fn execute_one(
    spec: &ExperimentSpec,
    index: usize,
    stem: &str,
    datasets: &BTreeMap<(String, bool), Arc<Dataset>>,
    opts: &SweepOptions,
    fms_reference: Option<&FactorSet>,
) -> anyhow::Result<RunRecord> {
    let data = datasets.get(&dataset_key(spec)).expect("dataset preloaded").as_ref();
    let mut backend = NativeOrPjrt::from_flag(&spec.backend)?;
    let mut session = Session::new(spec.clone());
    if opts.curves {
        session = session
            .observe(Box::new(CsvObserver::new(opts.dir.join(format!("{stem}.csv")))));
    }
    if opts.per_run_jsonl {
        session = session
            .observe(Box::new(JsonlObserver::new(opts.dir.join(format!("{stem}.jsonl")))));
    }
    let out = session.run_on(data, backend.as_mut(), fms_reference)?;

    let path = record_path(&opts.dir, index, stem);
    let body = Json::obj(vec![
        ("schema", Json::Str(RUN_SCHEMA.to_string())),
        ("index", Json::Num(index as f64)),
        ("spec", spec.to_json()),
        ("record", out.record.to_json()),
    ]);
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, body.to_pretty_string())
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .map_err(|e| anyhow::anyhow!("cannot move record into place {}: {e}", path.display()))?;

    if !opts.quiet {
        println!(
            "  [{index:>3}] {:<48} loss {:.3e}  uplink {}",
            spec.label(),
            out.record.final_loss(),
            fmt_bytes(out.record.total.bytes as f64)
        );
    }
    Ok(out.record)
}

/// Write `sweep.jsonl`: one header line, then one line per run in
/// expansion order. Only deterministic fields (no wall-clock seconds —
/// per-run CSVs keep those), so the file is byte-identical for any
/// worker count.
fn write_aggregate(
    path: &Path,
    runs: &[ExperimentSpec],
    results: &[SweepRunResult],
) -> anyhow::Result<()> {
    let mut out = String::new();
    let header = Json::obj(vec![
        ("event", Json::Str("sweep".to_string())),
        ("schema", Json::Str(SWEEP_SCHEMA.to_string())),
        ("runs", Json::Num(runs.len() as f64)),
    ]);
    out.push_str(&header.to_string());
    out.push('\n');
    for r in results {
        let spec = &runs[r.index];
        let rec = &r.record;
        let curve: Vec<Json> = rec
            .points
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("epoch".to_string(), Json::Num(p.epoch as f64));
                m.insert("iter".to_string(), Json::Num(p.iter as f64));
                m.insert("loss".to_string(), Json::Num(p.loss));
                m.insert("bytes".to_string(), Json::u64(p.bytes));
                if let Some(f) = p.fms {
                    m.insert("fms".to_string(), Json::Num(f));
                }
                Json::Obj(m)
            })
            .collect();
        let line = Json::obj(vec![
            ("event", Json::Str("run".to_string())),
            ("index", Json::Num(r.index as f64)),
            ("label", Json::Str(spec.label())),
            ("algo", Json::Str(rec.algo.clone())),
            ("dataset", Json::Str(rec.dataset.clone())),
            ("loss", Json::Str(rec.loss.clone())),
            ("topology", Json::Str(rec.topology.clone())),
            ("driver", Json::Str(spec.driver.name().to_string())),
            ("k", Json::Num(rec.k as f64)),
            ("tau", Json::Num(rec.tau as f64)),
            ("seed", Json::u64(spec.seed)),
            (
                "drop_rate",
                spec.fault
                    .as_ref()
                    .map(|f| Json::Num(f.drop_rate))
                    .unwrap_or(Json::Null),
            ),
            ("partitioner", Json::Str(spec.partitioner.spec_string())),
            ("aggregator", Json::Str(spec.aggregator.spec_string())),
            (
                "adversary",
                spec.adversary
                    .as_ref()
                    .map(|a| Json::Str(a.label_component()))
                    .unwrap_or(Json::Null),
            ),
            ("final_loss", Json::Num(rec.final_loss())),
            ("best_loss", Json::Num(rec.best_loss())),
            ("bytes", Json::u64(rec.total.bytes)),
            ("messages", Json::u64(rec.total.messages)),
            ("triggered", Json::u64(rec.total.triggered)),
            ("suppressed", Json::u64(rec.total.suppressed)),
            ("delivered", Json::u64(rec.net.delivered)),
            ("dropped", Json::u64(rec.net.dropped)),
            ("stale", Json::u64(rec.net.stale)),
            ("offline_rounds", Json::u64(rec.net.offline_rounds)),
            ("adversarial", Json::u64(rec.net.adversarial)),
            ("curve", Json::Arr(curve)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    std::fs::write(path, out)
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", path.display()))
}

/// Print the expansion-ordered summary table (deterministic columns
/// only — wall times live in the per-run CSVs).
fn print_summary(runs: &[ExperimentSpec], results: &[SweepRunResult]) {
    let table = Table::new(&[
        "idx", "algo", "dataset", "loss", "topo", "K", "tau", "driver", "final_loss", "uplink",
        "msgs",
    ]);
    for r in results {
        let spec = &runs[r.index];
        let rec = &r.record;
        table.row(&[
            r.index.to_string(),
            rec.algo.clone(),
            rec.dataset.clone(),
            rec.loss.clone(),
            rec.topology.clone(),
            rec.k.to_string(),
            rec.tau.to_string(),
            spec.driver.name().to_string(),
            format!("{:.3e}", rec.final_loss()),
            fmt_bytes(rec.total.bytes as f64),
            rec.total.messages.to_string(),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_base() -> ExperimentSpec {
        let mut base = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
        base.k = 2;
        base.rank = 4;
        base.fiber_samples = 16;
        base.eval_batch = 64;
        base.gamma = 0.5;
        base.epochs = 1;
        base.iters_per_epoch = 20;
        base
    }

    #[test]
    fn empty_axes_expand_to_base() {
        let spec = SweepSpec::new(tiny_base());
        assert!(spec.is_empty());
        let runs = spec.expand().unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0], spec.base);
    }

    #[test]
    fn expansion_order_is_outer_to_inner() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.datasets = vec!["tiny".into(), "synthetic".into()];
        spec.ks = vec![2, 4];
        spec.seeds = vec![1, 2];
        assert_eq!(spec.len(), 8);
        let runs = spec.expand().unwrap();
        assert_eq!(runs.len(), 8);
        // dataset outermost, seed innermost
        assert_eq!(runs[0].dataset, "tiny");
        assert_eq!((runs[0].k, runs[0].seed), (2, 1));
        assert_eq!((runs[1].k, runs[1].seed), (2, 2));
        assert_eq!((runs[2].k, runs[2].seed), (4, 1));
        assert_eq!(runs[4].dataset, "synthetic");
        assert_eq!((runs[7].k, runs[7].seed), (4, 2));
    }

    #[test]
    fn tau_axis_rewrites_algo_names() {
        assert_eq!(retau_name("cidertf_t4", 8), "cidertf_t8");
        assert_eq!(retau_name("cidertf_m_t2", 16), "cidertf_m_t16");
        assert_eq!(retau_name("dpsgd", 4), "dpsgd_t4");
        assert_eq!(retau_name("x_table", 3), "x_table_t3");
        let mut spec = SweepSpec::new(tiny_base());
        spec.algos = vec![AlgoConfig::cidertf(2)];
        spec.taus = vec![2, 8];
        let runs = spec.expand().unwrap();
        assert_eq!(runs[1].algo.tau, 8);
        assert_eq!(runs[1].algo.name, "cidertf_t8");
    }

    #[test]
    fn policy_passes_apply() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.algos = vec![AlgoConfig::gcp(), AlgoConfig::bras_cpd(), AlgoConfig::cidertf(2)];
        spec.ks = vec![8];
        spec.centralized_k1 = true;
        spec.auto_gamma = true;
        spec.block_random_epochs_scale = 3;
        let runs = spec.expand().unwrap();
        assert_eq!(runs[0].k, 1, "gcp runs centralized");
        assert_eq!(runs[2].k, 8, "cidertf keeps the K axis");
        assert_eq!(runs[2].gamma, tuned_gamma("tiny", Loss::Logit));
        assert_eq!(runs[0].epochs, 1, "gcp is not block-random");
        assert_eq!(runs[1].epochs, 3, "bras_cpd epochs scale by D");
        // fault on a lock-step driver upgrades to sim
        let mut spec = SweepSpec::new(tiny_base());
        spec.networks = vec![None, Some(FaultConfig::lossy(0.2))];
        let runs = spec.expand().unwrap();
        assert_eq!(runs[0].driver, DriverKind::Sequential);
        assert_eq!(runs[1].driver, DriverKind::Sim);
    }

    #[test]
    fn centralized_k1_survives_name_rewriting_axes() {
        // the tau axis renames bras_cpd -> bras_cpd_t2 before the policy
        // pass; a renamed centralized baseline must still run K = 1
        let mut spec = SweepSpec::new(tiny_base());
        spec.algos = vec![AlgoConfig::bras_cpd(), AlgoConfig::cidertf(2)];
        spec.taus = vec![2, 4];
        spec.ks = vec![8];
        spec.centralized_k1 = true;
        let runs = spec.expand().unwrap();
        assert_eq!(runs[0].algo.name, "bras_cpd_t2");
        assert_eq!(runs[0].k, 1, "renamed centralized baseline stays K=1");
        assert_eq!(runs[1].k, 1);
        assert_eq!(runs[2].k, 8, "cidertf keeps the K axis");
        // prefix matching must not swallow unrelated names
        let mut lookalike = AlgoConfig::dpsgd();
        lookalike.name = "bras_cpd2".into();
        assert_eq!(centralized_k(&lookalike, 8), 8);
        assert_eq!(centralized_k(&AlgoConfig::gcp(), 8), 1);
    }

    #[test]
    fn trigger_axis_disables_at_zero() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.triggers = vec![
            TriggerPoint { lambda0_scale: 0.0, alpha: 1.0 },
            TriggerPoint { lambda0_scale: 1.0, alpha: 1.3 },
        ];
        let runs = spec.expand().unwrap();
        assert!(!runs[0].algo.event_triggered);
        assert!(runs[0].trigger_lambda0_scale > 0.0, "λ₀ stays positive");
        assert!(runs[1].algo.event_triggered);
        assert_eq!(runs[1].trigger_alpha, 1.3);
        assert!(runs[1].algo.name.contains("_trig_s1_a1.3"), "{}", runs[1].algo.name);
    }

    #[test]
    fn sweep_json_round_trips() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.datasets = vec!["tiny".into()];
        spec.losses = vec![Loss::Logit, Loss::Ls];
        spec.algos = vec![AlgoConfig::cidertf(4), AlgoConfig::dpsgd()];
        spec.taus = vec![2, 4];
        spec.ks = vec![2, 4];
        spec.topologies = vec![Topology::Ring, Topology::Star];
        spec.compressors = vec![Compressor::Sign, Compressor::TopK { ratio: 16 }];
        spec.networks = vec![None, Some(FaultConfig::lossy(0.25))];
        spec.drivers = vec![DriverKind::Sim];
        spec.partitioners = vec![Partitioner::Even, Partitioner::Skewed(1.5)];
        spec.aggregators = vec![Aggregator::Mean, Aggregator::TrimmedMean(0.25)];
        spec.adversaries = vec![None, Some(AdversarySchedule::scaled_noise(0.3))];
        spec.triggers = vec![TriggerPoint { lambda0_scale: 1.0, alpha: 1.3 }];
        spec.gammas = vec![0.5, 0.25];
        spec.seeds = vec![1, 0xDEAD_BEEF_FEED_F00D];
        spec.centralized_k1 = true;
        spec.block_random_epochs_scale = 3;
        let pretty = spec.to_json().to_pretty_string();
        let back = SweepSpec::from_json_str(&pretty).unwrap();
        assert_eq!(back, spec);
        let compact = spec.to_json().to_string();
        assert_eq!(SweepSpec::from_json_str(&compact).unwrap(), spec);
    }

    #[test]
    fn sweep_json_accepts_string_axes_and_suggests_on_typos() {
        let base = tiny_base().to_json().to_string();
        let text = format!(
            r#"{{"schema":"cidertf-sweep-v1","base":{base},
                "algos":["cidertf:8","dpsgd"],"networks":[null,"lossy:0.3"]}}"#
        );
        let spec = SweepSpec::from_json_str(&text).unwrap();
        assert_eq!(spec.algos[0].tau, 8);
        assert!((spec.networks[1].as_ref().unwrap().drop_rate - 0.3).abs() < 1e-12);

        // the robustness axes accept registry string forms too
        let text = format!(
            r#"{{"schema":"cidertf-sweep-v1","base":{base},
                "adversaries":[null,"sign_flip:0.3"],
                "aggregators":["trimmed_mean:0.25"],
                "partitioners":["skewed:1.5"]}}"#
        );
        let spec = SweepSpec::from_json_str(&text).unwrap();
        assert_eq!(spec.adversaries[0], None);
        assert_eq!(spec.adversaries[1], Some(AdversarySchedule::sign_flip(0.3)));
        assert_eq!(spec.aggregators, vec![Aggregator::TrimmedMean(0.25)]);
        assert_eq!(spec.partitioners, vec![Partitioner::Skewed(1.5)]);
        let bad = format!(
            r#"{{"schema":"cidertf-sweep-v1","base":{base},"aggregators":["trimed_mean"]}}"#
        );
        let err = format!("{:#}", SweepSpec::from_json_str(&bad).unwrap_err());
        assert!(err.contains("trimmed_mean"), "did-you-mean missing: {err}");

        let bad = format!(
            r#"{{"schema":"cidertf-sweep-v1","base":{base},"networks":["lozzy:0.3"]}}"#
        );
        let err = format!("{:#}", SweepSpec::from_json_str(&bad).unwrap_err());
        assert!(err.contains("lossy"), "did-you-mean missing: {err}");

        let typo = format!(r#"{{"schema":"cidertf-sweep-v1","base":{base},"algoss":[]}}"#);
        let err = format!("{:#}", SweepSpec::from_json_str(&typo).unwrap_err());
        assert!(err.contains("algos"), "axis-key hint missing: {err}");
    }

    #[test]
    fn robustness_axes_expand_and_downgrade_parallel() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.drivers = vec![DriverKind::Parallel];
        spec.partitioners = vec![Partitioner::SiteVocab(0.3)];
        spec.aggregators = vec![Aggregator::Mean, Aggregator::CoordinateMedian];
        spec.adversaries = vec![None, Some(AdversarySchedule::sign_flip(0.25))];
        assert_eq!(spec.len(), 4);
        let runs = spec.expand().unwrap();
        // adversary innermost: (mean, honest), (mean, byz), (median, honest), ...
        assert_eq!(runs[0].driver, DriverKind::Parallel, "honest cells keep parallel");
        assert_eq!(runs[1].driver, DriverKind::Sequential, "Byzantine cells downgrade");
        assert!(runs.iter().all(|r| r.partitioner == Partitioner::SiteVocab(0.3)));
        assert_eq!(runs[2].aggregator, Aggregator::CoordinateMedian);
        assert_eq!(runs[3].adversary, Some(AdversarySchedule::sign_flip(0.25)));
        // the built-in robustness smoke grid expands with distinct stems
        let smoke = SweepSpec::robust_smoke();
        let runs = smoke.expand().unwrap();
        assert_eq!(runs.len(), 4);
        let stems = run_stems(&runs);
        for (i, a) in stems.iter().enumerate() {
            for b in stems.iter().skip(i + 1) {
                assert_ne!(a, b, "robust smoke labels must not collide");
            }
        }
    }

    #[test]
    fn auto_gamma_conflicts_with_gamma_axis() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.auto_gamma = true;
        spec.gammas = vec![0.5];
        assert!(spec.expand().is_err());
    }

    #[test]
    fn run_stems_disambiguate_label_collisions() {
        let base = tiny_base();
        let mut spec = SweepSpec::new(base);
        spec.networks = vec![None, Some(FaultConfig::lossy(0.1))];
        spec.drivers = vec![DriverKind::Sim];
        let runs = spec.expand().unwrap();
        // same label (network is not part of the label) -> indexed stems
        assert_eq!(runs[0].label(), runs[1].label());
        let stems = run_stems(&runs);
        assert_ne!(stems[0], stems[1]);
        assert!(stems[1].ends_with("_r001"), "{}", stems[1]);
    }
}
