//! CSV output substrate for experiment results (loss curves, ledgers, ...).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    n_cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, n_cols: header.len() })
    }

    /// Write a row of already-formatted fields.
    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(fields.len() == self.n_cols, "row arity {} != header {}", fields.len(), self.n_cols);
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    /// Write a row of f64s (common case for metric curves).
    pub fn row_f64(&mut self, fields: &[f64]) -> anyhow::Result<()> {
        let v: Vec<String> = fields.iter().map(|x| format_f64(*x)).collect();
        self.row(&v)
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Compact float formatting (no trailing zeros beyond precision needs).
pub fn format_f64(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("cidertf_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["epoch", "loss", "bytes"]).unwrap();
            w.row_f64(&[0.0, 1.25, 1024.0]).unwrap();
            w.row(&["1".into(), "0.5".into(), "2048".into()]).unwrap();
            w.flush().unwrap();
        }
        let s = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "epoch,loss,bytes");
        assert!(lines[1].starts_with("0,1.25"));
        assert_eq!(lines.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn arity_checked() {
        let dir = std::env::temp_dir().join("cidertf_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
