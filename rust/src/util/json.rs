//! Minimal JSON substrate (no `serde_json` available offline).
//!
//! Covers everything this crate needs: the AOT `manifest.json`, experiment
//! config files, and metric dumps. Full RFC 8259 parsing for the subset we
//! emit/consume (no surrogate-pair edge cases beyond basic \uXXXX).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset where it occurred.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Typed field lookups with contextful errors (for config/manifest use).
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field '{key}'"))
    }

    pub fn req_array(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }

    /// Encode a `u64` losslessly. JSON numbers ride on `f64` (exact only
    /// below 2^53), so full-range values — RNG seeds, state words — are
    /// written as decimal strings instead.
    pub fn u64(v: u64) -> Json {
        Json::Str(v.to_string())
    }

    /// Decode a `u64` written by [`Json::u64`], also accepting a plain
    /// in-range number (hand-written spec files).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Str(s) => s.parse().ok(),
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 9e15 => Some(*n as u64),
            _ => None,
        }
    }

    /// Typed `u64` field lookup (string or in-range number).
    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid u64 field '{key}'"))
    }

    /// Typed bool field lookup.
    pub fn req_bool(&self, key: &str) -> anyhow::Result<bool> {
        self.get(key)
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid bool field '{key}'"))
    }

    /// Reject objects carrying keys outside `allowed`, with a
    /// did-you-mean hint — so a typo'd field in a hand-written config
    /// file is an error instead of a silently-ignored default.
    /// Non-objects pass (their shape errors surface elsewhere).
    pub fn ensure_known_keys(&self, what: &str, allowed: &[&str]) -> anyhow::Result<()> {
        let Json::Obj(m) = self else { return Ok(()) };
        for k in m.keys() {
            if !allowed.contains(&k.as_str()) {
                match crate::registry::did_you_mean(k, allowed.iter().copied()) {
                    Some(s) => {
                        anyhow::bail!("unknown {what} field '{k}' — did you mean '{s}'?")
                    }
                    None => anyhow::bail!(
                        "unknown {what} field '{k}' (allowed: {})",
                        allowed.join(", ")
                    ),
                }
            }
        }
        Ok(())
    }

    // ---- builders ----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Array of lossless u64s (each encoded per [`Json::u64`]).
    pub fn arr_u64(xs: &[u64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::u64(x)).collect())
    }

    /// Array of strings.
    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 1-space indentation (matches python `indent=1`).
    pub fn to_pretty_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"grad_ls_i32","shapes":[[32,16],[32,4],[]],"scale":1.5,"ok":true,"none":null}"#;
        let j = Json::parse(src).unwrap();
        for s in [j.to_string(), j.to_pretty_string()] {
            assert_eq!(Json::parse(&s).unwrap(), j);
        }
    }

    #[test]
    fn parses_python_indent1_output() {
        let s = "{\n \"format\": \"hlo-text-v1\",\n \"artifacts\": [\n  {\n   \"op\": \"grad\",\n   \"I\": 32\n  }\n ]\n}";
        let j = Json::parse(s).unwrap();
        assert_eq!(j.req_str("format").unwrap(), "hlo-text-v1");
        assert_eq!(j.req_array("artifacts").unwrap()[0].req_usize("I").unwrap(), 32);
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let j = Json::parse(r#""élétensor φ""#).unwrap();
        assert_eq!(j.as_str(), Some("élétensor φ"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_pretty_string(), "[]");
    }

    #[test]
    fn integers_serialize_without_decimal_point() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
