//! Seedable PRNG substrate (no `rand` crate available offline).
//!
//! `Rng` is xoshiro256++ seeded through splitmix64 — fast, high quality for
//! simulation purposes, and fully deterministic across platforms, which the
//! experiment harness relies on (every figure is regenerated from a seed).

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second output of the Box-Muller transform
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (client k, epoch e, ... are folded into
    /// the seed); used so every client / round has its own generator.
    pub fn split(&self, stream: u64) -> Self {
        // Mix the current state with the stream id through splitmix.
        let mut sm = self.s[0] ^ self.s[2] ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = (((x as u128 * n as u128) >> 64) as u64, (x as u128 * n as u128) as u64);
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// `k` distinct indices from `[0, n)`.
    ///
    /// Uses Floyd's algorithm for small k, partial Fisher-Yates otherwise.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        let mut scratch = Vec::new();
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        self.sample_indices_into(n, k, &mut out, &mut scratch, &mut chosen);
        out
    }

    /// Buffer-based core of [`Rng::sample_indices`]: **identical draws
    /// from the same stream**, written into caller-owned buffers so the
    /// steady-state path (the engine's per-iteration fiber sampler) is
    /// allocation-free once the buffers reach working size.
    pub fn sample_indices_into(
        &mut self,
        n: usize,
        k: usize,
        out: &mut Vec<usize>,
        scratch: &mut Vec<usize>,
        chosen: &mut std::collections::HashSet<usize>,
    ) {
        assert!(k <= n, "cannot sample {k} of {n}");
        out.clear();
        if k * 8 >= n {
            // partial Fisher-Yates over a reused identity permutation
            scratch.clear();
            scratch.extend(0..n);
            for i in 0..k {
                let j = i + self.below(n - i);
                scratch.swap(i, j);
            }
            out.extend_from_slice(&scratch[..k]);
        } else {
            // Floyd: O(k) expected with a small hash set.
            chosen.clear();
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let v = if chosen.insert(t) { t } else { j };
                if v != t {
                    chosen.insert(v);
                }
                out.push(v);
            }
        }
    }

    /// `k` indices from `[0, n)` **with** replacement.
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.below(n)).collect()
    }

    /// Snapshot the full generator state (xoshiro words + the cached
    /// Box-Muller spare) for checkpointing. Restoring with
    /// [`Rng::from_state`] continues the stream bit-identically.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Rng { s, gauss_spare }
    }
}

/// Serialize an [`Rng::state`] snapshot (`{"s": [4 x u64-string],
/// "spare": f64|null}`) — the one encoding shared by every checkpoint
/// layer (client samplers, the block sampler, per-link fault machines).
pub fn state_to_json(state: ([u64; 4], Option<f64>)) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("s", Json::Arr(state.0.iter().map(|&w| Json::u64(w)).collect())),
        ("spare", state.1.map(Json::Num).unwrap_or(Json::Null)),
    ])
}

/// Inverse of [`state_to_json`].
pub fn state_from_json(j: &crate::util::json::Json) -> anyhow::Result<([u64; 4], Option<f64>)> {
    use crate::util::json::Json;
    let words_json = j.req_array("s")?;
    anyhow::ensure!(words_json.len() == 4, "rng state needs 4 words");
    let mut words = [0u64; 4];
    for (w, v) in words.iter_mut().zip(words_json.iter()) {
        *w = v.as_u64().ok_or_else(|| anyhow::anyhow!("bad rng state word"))?;
    }
    Ok((words, j.get("spare").and_then(Json::as_f64)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_constructions() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let base = Rng::new(9);
        let mut a = base.split(1);
        let mut b = base.split(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sum2 += z * z;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_complete() {
        let mut r = Rng::new(3);
        for (n, k) in [(100, 5), (100, 90), (16, 16), (1, 1), (1000, 2)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn sample_indices_into_matches_allocating_variant() {
        // reused buffers across many calls must produce the exact draws of
        // the allocating API on an identically-seeded stream (the engine's
        // trajectories depend on this)
        let mut a = Rng::new(55);
        let mut b = Rng::new(55);
        let mut out = Vec::new();
        let mut scratch = Vec::new();
        let mut chosen = std::collections::HashSet::new();
        for (n, k) in [(1000, 5), (100, 90), (16, 16), (1, 1), (5000, 64), (64, 8)] {
            a.sample_indices_into(n, k, &mut out, &mut scratch, &mut chosen);
            assert_eq!(out, b.sample_indices(n, k), "n={n} k={k}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(2);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
