//! Explicit SIMD lanes for the hot kernels, with runtime dispatch and a
//! bit-identical scalar fallback.
//!
//! Every kernel here exists in up to three bodies — scalar, SSE2, AVX2 —
//! selected once per process by [`level`] (`is_x86_feature_detected!` at
//! first use, overridable with the `CIDERTF_SIMD` env var for testing and
//! pinning). The contract that makes this safe to use everywhere,
//! including under the determinism firewall, is:
//!
//! **Every level computes bit-identical results.** The scalar kernels
//! already accumulate in a fixed 8-lane register layout reduced by a
//! fixed tree ([`LANES`], [`hsum`]) — exactly one AVX2 register, or two
//! SSE2 registers. The vector bodies perform the *same* per-lane IEEE
//! operations in the *same* order:
//!
//! * multiplies and adds stay separate instructions (`mul_ps` + `add_ps`,
//!   never FMA — rustc does not contract float expressions, and neither
//!   do we), so each lane sees the identical rounding sequence;
//! * horizontal reductions spill the accumulator register(s) to a
//!   `[f32; 8]` and run the *scalar* [`hsum`] tree — no `hadd` shuffles
//!   with a different association;
//! * remainder elements (`len % 8`) always take the scalar tail loop;
//! * elementwise kernels (axpy, Hadamard, consensus fold, sign codec)
//!   compute each output element from the same single-element expression
//!   as the scalar loop, so vector width cannot change any bit.
//!
//! The `simd_*` property tests at the bottom assert scalar ≡ SSE2 ≡ AVX2
//! bitwise across generated lengths (including every remainder-lane
//! count) on whatever hardware runs the suite.

use std::sync::OnceLock;

/// Accumulator lanes for vectorized reductions (one AVX2 f32 register).
pub const LANES: usize = 8;

/// Which instruction set the kernels run on. Ordering is capability:
/// `Scalar < Sse2 < Avx2`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Portable scalar bodies (the reference semantics).
    Scalar,
    /// 4-wide SSE2, two registers emulating the 8-lane layout.
    Sse2,
    /// 8-wide AVX2, one register per lane accumulator.
    Avx2,
}

impl Level {
    /// Stable lowercase name (`scalar`/`sse2`/`avx2`) — what
    /// `CIDERTF_SIMD` accepts and diagnostics print.
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Sse2 => "sse2",
            Level::Avx2 => "avx2",
        }
    }
}

/// Highest level the hardware supports. Under Miri this is pinned to
/// `Scalar`: the vector intrinsics are outside Miri's model, and the
/// Miri CI lane audits the scalar bodies (which every level's tail
/// loops and reductions share).
fn hw_level() -> Level {
    if cfg!(miri) {
        return Level::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Level::Avx2;
        }
        // SSE2 is part of the x86_64 baseline.
        Level::Sse2
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        Level::Scalar
    }
}

/// Resolve the process-wide dispatch level: the hardware maximum, capped
/// by `CIDERTF_SIMD` (`scalar`/`sse2`/`avx2`) when set. A request above
/// the hardware level falls back to the hardware level (results are
/// bit-identical at every level, so the cap is a perf/testing knob, not a
/// correctness one); an unrecognized value is ignored.
fn detect() -> Level {
    let hw = hw_level();
    match std::env::var("CIDERTF_SIMD") {
        Ok(v) => match v.as_str() {
            "scalar" => Level::Scalar,
            "sse2" => Level::Sse2.min(hw),
            "avx2" => Level::Avx2.min(hw),
            _ => hw,
        },
        Err(_) => hw,
    }
}

static LEVEL: OnceLock<Level> = OnceLock::new();

/// The cached process-wide dispatch level (detected on first call).
#[inline]
pub fn level() -> Level {
    *LEVEL.get_or_init(detect)
}

/// Deterministic horizontal sum of the lane accumulators (fixed tree).
/// Every level funnels its reduction through this exact association.
#[inline(always)]
pub fn hsum(acc: [f32; LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

// ---- scalar reference bodies -------------------------------------------

/// Lane-accumulated dot product (scalar body). The `LANES` independent
/// partial sums are the reference semantics every vector body replicates.
#[inline]
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let chunks = k / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let ar = &a[c * LANES..c * LANES + LANES];
        let br = &b[c * LANES..c * LANES + LANES];
        for l in 0..LANES {
            acc[l] += ar[l] * br[l];
        }
    }
    hsum(acc) + dot_tail(a, b, chunks * LANES)
}

/// Scalar tail shared by every level: elements `start..len` in order.
#[inline(always)]
fn dot_tail(a: &[f32], b: &[f32], start: usize) -> f32 {
    let mut tail = 0.0f32;
    for i in start..a.len() {
        tail += a[i] * b[i];
    }
    tail
}

/// 2x2 register-tiled dot micro-kernel (scalar body): the four dot
/// products `[a0·b0, a0·b1, a1·b0, a1·b1]` sharing every operand load,
/// each with the exact lane structure of [`dot`].
#[inline]
fn dot2x2_scalar(a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32], k: usize) -> [f32; 4] {
    let chunks = k / LANES;
    let mut acc00 = [0.0f32; LANES];
    let mut acc01 = [0.0f32; LANES];
    let mut acc10 = [0.0f32; LANES];
    let mut acc11 = [0.0f32; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        let (a0c, a1c) = (&a0[o..o + LANES], &a1[o..o + LANES]);
        let (b0c, b1c) = (&b0[o..o + LANES], &b1[o..o + LANES]);
        for l in 0..LANES {
            let (x0, x1) = (a0c[l], a1c[l]);
            let (y0, y1) = (b0c[l], b1c[l]);
            acc00[l] += x0 * y0;
            acc01[l] += x0 * y1;
            acc10[l] += x1 * y0;
            acc11[l] += x1 * y1;
        }
    }
    let t = dot2x2_tail(a0, a1, b0, b1, chunks * LANES, k);
    [hsum(acc00) + t[0], hsum(acc01) + t[1], hsum(acc10) + t[2], hsum(acc11) + t[3]]
}

#[inline(always)]
fn dot2x2_tail(a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32], start: usize, k: usize) -> [f32; 4] {
    let mut tail = [0.0f32; 4];
    for i in start..k {
        tail[0] += a0[i] * b0[i];
        tail[1] += a0[i] * b1[i];
        tail[2] += a1[i] * b0[i];
        tail[3] += a1[i] * b1[i];
    }
    tail
}

#[inline]
fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yv, xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * xv;
    }
}

#[inline]
fn add_assign_scalar(x: &[f32], y: &mut [f32]) {
    for (yv, xv) in y.iter_mut().zip(x.iter()) {
        *yv += xv;
    }
}

#[inline]
fn hadamard2_scalar(x: &[f32], y: &[f32], out: &mut [f32]) {
    for ((o, xv), yv) in out.iter_mut().zip(x.iter()).zip(y.iter()) {
        *o = xv * yv;
    }
}

#[inline]
fn hadamard_assign_scalar(x: &[f32], y: &mut [f32]) {
    for (yv, xv) in y.iter_mut().zip(x.iter()) {
        *yv *= xv;
    }
}

#[inline]
fn scaled_diff_acc_scalar(w: f32, hj: &[f32], hk: &[f32], a: &mut [f32]) {
    for ((av, &j), &k) in a.iter_mut().zip(hj.iter()).zip(hk.iter()) {
        *av += w * (j - k);
    }
}

#[inline]
fn sign_pack_scalar(data: &[f32], bits: &mut [u8]) {
    for (i, &v) in data.iter().enumerate() {
        if v >= 0.0 {
            bits[i >> 3] |= 1 << (i & 7);
        }
    }
}

#[inline]
fn sign_decode_add_scalar(scale: f32, bits: &[u8], t: &mut [f32]) {
    for (i, tv) in t.iter_mut().enumerate() {
        let bit = (bits[i >> 3] >> (i & 7)) & 1;
        *tv += if bit == 1 { scale } else { -scale };
    }
}

// ---- x86-64 vector bodies ----------------------------------------------
//
// Safety note shared by everything below: the `avx2` module's functions
// carry `#[target_feature(enable = "avx2")]` and are only ever reached
// through a `Level::Avx2` produced by `is_x86_feature_detected!("avx2")`;
// the `sse2` module relies on SSE2 being part of the x86_64 baseline.
// Every unchecked pointer is derived from a slice whose length the caller
// (the dispatch functions in this module) has already validated.

#[cfg(target_arch = "x86_64")]
mod sse2 {
    use super::{dot2x2_tail, dot_tail, hsum, LANES};
    use std::arch::x86_64::*;

    /// Spill the two half-registers to the 8-lane layout and reduce with
    /// the scalar tree.
    // SAFETY: SSE2 is baseline x86_64; both stores land in a local
    // stack array of exactly 8 lanes.
    #[inline(always)]
    unsafe fn hsum2(lo: __m128, hi: __m128) -> f32 {
        let mut lanes = [0.0f32; LANES];
        _mm_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
        hsum(lanes)
    }

    // SAFETY: SSE2 is baseline x86_64; the dispatcher asserts
    // `a.len() == b.len()`, and every load offset is `< chunks * LANES`.
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        let chunks = k / LANES;
        let mut acc_lo = _mm_setzero_ps();
        let mut acc_hi = _mm_setzero_ps();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        for c in 0..chunks {
            let o = c * LANES;
            acc_lo = _mm_add_ps(acc_lo, _mm_mul_ps(_mm_loadu_ps(ap.add(o)), _mm_loadu_ps(bp.add(o))));
            acc_hi = _mm_add_ps(
                acc_hi,
                _mm_mul_ps(_mm_loadu_ps(ap.add(o + 4)), _mm_loadu_ps(bp.add(o + 4))),
            );
        }
        hsum2(acc_lo, acc_hi) + dot_tail(a, b, chunks * LANES)
    }

    // SAFETY: SSE2 is baseline x86_64; the dispatcher asserts all four
    // slices hold at least `k` elements, and offsets stay `< k`.
    pub unsafe fn dot2x2(a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32], k: usize) -> [f32; 4] {
        let chunks = k / LANES;
        let mut acc = [[_mm_setzero_ps(); 2]; 4];
        for c in 0..chunks {
            let o = c * LANES;
            for half in 0..2 {
                let oo = o + 4 * half;
                let x0 = _mm_loadu_ps(a0.as_ptr().add(oo));
                let x1 = _mm_loadu_ps(a1.as_ptr().add(oo));
                let y0 = _mm_loadu_ps(b0.as_ptr().add(oo));
                let y1 = _mm_loadu_ps(b1.as_ptr().add(oo));
                acc[0][half] = _mm_add_ps(acc[0][half], _mm_mul_ps(x0, y0));
                acc[1][half] = _mm_add_ps(acc[1][half], _mm_mul_ps(x0, y1));
                acc[2][half] = _mm_add_ps(acc[2][half], _mm_mul_ps(x1, y0));
                acc[3][half] = _mm_add_ps(acc[3][half], _mm_mul_ps(x1, y1));
            }
        }
        let t = dot2x2_tail(a0, a1, b0, b1, chunks * LANES, k);
        [
            hsum2(acc[0][0], acc[0][1]) + t[0],
            hsum2(acc[1][0], acc[1][1]) + t[1],
            hsum2(acc[2][0], acc[2][1]) + t[2],
            hsum2(acc[3][0], acc[3][1]) + t[3],
        ]
    }

    // SAFETY: SSE2 is baseline x86_64; the dispatcher asserts
    // `x.len() == y.len()`, and vector offsets stay `< chunks * 4`.
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let chunks = n / 4;
        let av = _mm_set1_ps(alpha);
        let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 4;
            let v = _mm_add_ps(_mm_loadu_ps(yp.add(o)), _mm_mul_ps(av, _mm_loadu_ps(xp.add(o))));
            _mm_storeu_ps(yp.add(o), v);
        }
        super::axpy_scalar(alpha, &x[chunks * 4..], &mut y[chunks * 4..]);
    }

    // SAFETY: SSE2 is baseline x86_64; the dispatcher asserts
    // `x.len() == y.len()`, and vector offsets stay `< chunks * 4`.
    pub unsafe fn add_assign(x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let chunks = n / 4;
        let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 4;
            _mm_storeu_ps(yp.add(o), _mm_add_ps(_mm_loadu_ps(yp.add(o)), _mm_loadu_ps(xp.add(o))));
        }
        super::add_assign_scalar(&x[chunks * 4..], &mut y[chunks * 4..]);
    }

    // SAFETY: SSE2 is baseline x86_64; the dispatcher asserts `x`, `y`,
    // and `out` share a length, and vector offsets stay `< chunks * 4`.
    pub unsafe fn hadamard2(x: &[f32], y: &[f32], out: &mut [f32]) {
        let n = out.len();
        let chunks = n / 4;
        let (xp, yp, op) = (x.as_ptr(), y.as_ptr(), out.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 4;
            _mm_storeu_ps(op.add(o), _mm_mul_ps(_mm_loadu_ps(xp.add(o)), _mm_loadu_ps(yp.add(o))));
        }
        super::hadamard2_scalar(&x[chunks * 4..], &y[chunks * 4..], &mut out[chunks * 4..]);
    }

    // SAFETY: SSE2 is baseline x86_64; the dispatcher asserts
    // `x.len() == y.len()`, and vector offsets stay `< chunks * 4`.
    pub unsafe fn hadamard_assign(x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let chunks = n / 4;
        let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 4;
            _mm_storeu_ps(yp.add(o), _mm_mul_ps(_mm_loadu_ps(yp.add(o)), _mm_loadu_ps(xp.add(o))));
        }
        super::hadamard_assign_scalar(&x[chunks * 4..], &mut y[chunks * 4..]);
    }

    // SAFETY: SSE2 is baseline x86_64; the dispatcher asserts `hj`,
    // `hk`, and `a` share a length, and vector offsets stay in bounds.
    pub unsafe fn scaled_diff_acc(w: f32, hj: &[f32], hk: &[f32], a: &mut [f32]) {
        let n = a.len();
        let chunks = n / 4;
        let wv = _mm_set1_ps(w);
        let (jp, kp, ap) = (hj.as_ptr(), hk.as_ptr(), a.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 4;
            let d = _mm_sub_ps(_mm_loadu_ps(jp.add(o)), _mm_loadu_ps(kp.add(o)));
            _mm_storeu_ps(ap.add(o), _mm_add_ps(_mm_loadu_ps(ap.add(o)), _mm_mul_ps(wv, d)));
        }
        super::scaled_diff_acc_scalar(w, &hj[chunks * 4..], &hk[chunks * 4..], &mut a[chunks * 4..]);
    }

    // SAFETY: SSE2 is baseline x86_64; the dispatcher asserts `bits`
    // holds a byte per 8 lanes, and `iter_mut` bounds the loads to it.
    pub unsafe fn sign_pack(data: &[f32], bits: &mut [u8]) {
        let chunks = data.len() / 8;
        let zero = _mm_setzero_ps();
        let dp = data.as_ptr();
        for (c, byte) in bits.iter_mut().enumerate().take(chunks) {
            let o = c * 8;
            // cmpge is the ordered compare: false for NaN, true for -0.0,
            // exactly like the scalar `v >= 0.0`
            let lo = _mm_movemask_ps(_mm_cmpge_ps(_mm_loadu_ps(dp.add(o)), zero));
            let hi = _mm_movemask_ps(_mm_cmpge_ps(_mm_loadu_ps(dp.add(o + 4)), zero));
            *byte |= (lo | (hi << 4)) as u8;
        }
        super::sign_pack_tail(data, bits, chunks * 8);
    }

    // SAFETY: SSE2 is baseline x86_64; the dispatcher asserts `bits`
    // covers `t.len()` lanes, and store offsets stay `< chunks * 8`.
    pub unsafe fn sign_decode_add(scale: f32, bits: &[u8], t: &mut [f32]) {
        let chunks = t.len() / 8;
        let sv = _mm_castps_si128(_mm_set1_ps(scale));
        let signbit = _mm_set1_epi32(i32::MIN);
        let lanes_lo = _mm_setr_epi32(1, 2, 4, 8);
        let lanes_hi = _mm_setr_epi32(16, 32, 64, 128);
        let tp = t.as_mut_ptr();
        for c in 0..chunks {
            let byte = _mm_set1_epi32(bits[c] as i32);
            for (half, lanes) in [lanes_lo, lanes_hi].into_iter().enumerate() {
                let o = c * 8 + 4 * half;
                // bit set -> +scale; bit clear -> sign-flipped scale
                // (exactly `-scale`, for every scale including NaN/inf)
                let sel = _mm_cmpeq_epi32(_mm_and_si128(byte, lanes), lanes);
                let val = _mm_castsi128_ps(_mm_xor_si128(sv, _mm_andnot_si128(sel, signbit)));
                _mm_storeu_ps(tp.add(o), _mm_add_ps(_mm_loadu_ps(tp.add(o)), val));
            }
        }
        super::sign_decode_add_tail(scale, bits, t, chunks * 8);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{dot2x2_tail, dot_tail, hsum, LANES};
    use std::arch::x86_64::*;

    /// Spill the 8-lane register and reduce with the scalar tree (no
    /// `hadd` — its association differs from the reference).
    // SAFETY: callers hold the AVX2 target-feature contract; the store
    // lands in a local stack array of exactly 8 lanes.
    #[inline(always)]
    unsafe fn hsum8(acc: __m256) -> f32 {
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        hsum(lanes)
    }

    // SAFETY: callers reach this only via `Level::Avx2`, i.e. after
    // feature detection; the dispatcher asserts `a.len() == b.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        let chunks = k / LANES;
        let mut acc = _mm256_setzero_ps();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        for c in 0..chunks {
            let o = c * LANES;
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_loadu_ps(ap.add(o)), _mm256_loadu_ps(bp.add(o))));
        }
        hsum8(acc) + dot_tail(a, b, chunks * LANES)
    }

    // SAFETY: callers reach this only via `Level::Avx2` (feature
    // detected); the dispatcher asserts all four slices hold `k` items.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot2x2(a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32], k: usize) -> [f32; 4] {
        let chunks = k / LANES;
        let mut acc00 = _mm256_setzero_ps();
        let mut acc01 = _mm256_setzero_ps();
        let mut acc10 = _mm256_setzero_ps();
        let mut acc11 = _mm256_setzero_ps();
        for c in 0..chunks {
            let o = c * LANES;
            let x0 = _mm256_loadu_ps(a0.as_ptr().add(o));
            let x1 = _mm256_loadu_ps(a1.as_ptr().add(o));
            let y0 = _mm256_loadu_ps(b0.as_ptr().add(o));
            let y1 = _mm256_loadu_ps(b1.as_ptr().add(o));
            acc00 = _mm256_add_ps(acc00, _mm256_mul_ps(x0, y0));
            acc01 = _mm256_add_ps(acc01, _mm256_mul_ps(x0, y1));
            acc10 = _mm256_add_ps(acc10, _mm256_mul_ps(x1, y0));
            acc11 = _mm256_add_ps(acc11, _mm256_mul_ps(x1, y1));
        }
        let t = dot2x2_tail(a0, a1, b0, b1, chunks * LANES, k);
        [hsum8(acc00) + t[0], hsum8(acc01) + t[1], hsum8(acc10) + t[2], hsum8(acc11) + t[3]]
    }

    // SAFETY: callers reach this only via `Level::Avx2` (feature
    // detected); the dispatcher asserts `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let chunks = n / 8;
        let av = _mm256_set1_ps(alpha);
        let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 8;
            let v = _mm256_add_ps(
                _mm256_loadu_ps(yp.add(o)),
                _mm256_mul_ps(av, _mm256_loadu_ps(xp.add(o))),
            );
            _mm256_storeu_ps(yp.add(o), v);
        }
        super::axpy_scalar(alpha, &x[chunks * 8..], &mut y[chunks * 8..]);
    }

    // SAFETY: callers reach this only via `Level::Avx2` (feature
    // detected); the dispatcher asserts `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let chunks = n / 8;
        let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 8;
            _mm256_storeu_ps(
                yp.add(o),
                _mm256_add_ps(_mm256_loadu_ps(yp.add(o)), _mm256_loadu_ps(xp.add(o))),
            );
        }
        super::add_assign_scalar(&x[chunks * 8..], &mut y[chunks * 8..]);
    }

    // SAFETY: callers reach this only via `Level::Avx2` (feature
    // detected); the dispatcher asserts the three lengths match.
    #[target_feature(enable = "avx2")]
    pub unsafe fn hadamard2(x: &[f32], y: &[f32], out: &mut [f32]) {
        let n = out.len();
        let chunks = n / 8;
        let (xp, yp, op) = (x.as_ptr(), y.as_ptr(), out.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 8;
            _mm256_storeu_ps(
                op.add(o),
                _mm256_mul_ps(_mm256_loadu_ps(xp.add(o)), _mm256_loadu_ps(yp.add(o))),
            );
        }
        super::hadamard2_scalar(&x[chunks * 8..], &y[chunks * 8..], &mut out[chunks * 8..]);
    }

    // SAFETY: callers reach this only via `Level::Avx2` (feature
    // detected); the dispatcher asserts `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn hadamard_assign(x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let chunks = n / 8;
        let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 8;
            _mm256_storeu_ps(
                yp.add(o),
                _mm256_mul_ps(_mm256_loadu_ps(yp.add(o)), _mm256_loadu_ps(xp.add(o))),
            );
        }
        super::hadamard_assign_scalar(&x[chunks * 8..], &mut y[chunks * 8..]);
    }

    // SAFETY: callers reach this only via `Level::Avx2` (feature
    // detected); the dispatcher asserts the three lengths match.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scaled_diff_acc(w: f32, hj: &[f32], hk: &[f32], a: &mut [f32]) {
        let n = a.len();
        let chunks = n / 8;
        let wv = _mm256_set1_ps(w);
        let (jp, kp, ap) = (hj.as_ptr(), hk.as_ptr(), a.as_mut_ptr());
        for c in 0..chunks {
            let o = c * 8;
            let d = _mm256_sub_ps(_mm256_loadu_ps(jp.add(o)), _mm256_loadu_ps(kp.add(o)));
            _mm256_storeu_ps(
                ap.add(o),
                _mm256_add_ps(_mm256_loadu_ps(ap.add(o)), _mm256_mul_ps(wv, d)),
            );
        }
        super::scaled_diff_acc_scalar(w, &hj[chunks * 8..], &hk[chunks * 8..], &mut a[chunks * 8..]);
    }

    // SAFETY: callers reach this only via `Level::Avx2` (feature
    // detected); the dispatcher asserts `bits` holds a byte per 8 lanes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sign_pack(data: &[f32], bits: &mut [u8]) {
        let chunks = data.len() / 8;
        let zero = _mm256_setzero_ps();
        let dp = data.as_ptr();
        for (c, byte) in bits.iter_mut().enumerate().take(chunks) {
            // _CMP_GE_OQ: ordered greater-or-equal — false for NaN, true
            // for -0.0, exactly like the scalar `v >= 0.0`; movemask lane
            // order matches the scalar bit order `1 << (i & 7)`
            let ge = _mm256_cmp_ps::<_CMP_GE_OQ>(_mm256_loadu_ps(dp.add(c * 8)), zero);
            *byte |= _mm256_movemask_ps(ge) as u8;
        }
        super::sign_pack_tail(data, bits, chunks * 8);
    }

    // SAFETY: callers reach this only via `Level::Avx2` (feature
    // detected); the dispatcher asserts `bits` covers `t.len()` lanes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sign_decode_add(scale: f32, bits: &[u8], t: &mut [f32]) {
        let chunks = t.len() / 8;
        let sv = _mm256_castps_si256(_mm256_set1_ps(scale));
        let signbit = _mm256_set1_epi32(i32::MIN);
        let lanes = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
        let tp = t.as_mut_ptr();
        for c in 0..chunks {
            let byte = _mm256_set1_epi32(bits[c] as i32);
            // bit set -> +scale; bit clear -> sign-flipped scale (exactly
            // `-scale`, for every scale including NaN/inf)
            let sel = _mm256_cmpeq_epi32(_mm256_and_si256(byte, lanes), lanes);
            let val = _mm256_castsi256_ps(_mm256_xor_si256(sv, _mm256_andnot_si256(sel, signbit)));
            let o = c * 8;
            _mm256_storeu_ps(tp.add(o), _mm256_add_ps(_mm256_loadu_ps(tp.add(o)), val));
        }
        super::sign_decode_add_tail(scale, bits, t, chunks * 8);
    }
}

/// Scalar tail for the sign packer: elements `start..`.
#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn sign_pack_tail(data: &[f32], bits: &mut [u8], start: usize) {
    for i in start..data.len() {
        if data[i] >= 0.0 {
            bits[i >> 3] |= 1 << (i & 7);
        }
    }
}

/// Scalar tail for the sign decoder: elements `start..`.
#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn sign_decode_add_tail(scale: f32, bits: &[u8], t: &mut [f32], start: usize) {
    for (i, tv) in t.iter_mut().enumerate().skip(start) {
        let bit = (bits[i >> 3] >> (i & 7)) & 1;
        *tv += if bit == 1 { scale } else { -scale };
    }
}

// ---- dispatch entry points ---------------------------------------------

/// Lane-accumulated dot product `a · b` at `lv` (bit-identical across
/// levels).
#[inline]
pub fn dot(lv: Level, a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    match lv {
        Level::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline x86_64; lengths asserted above.
        Level::Sse2 => unsafe { sse2::dot(a, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by feature detection.
        Level::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => dot_scalar(a, b),
    }
}

/// The four dot products `[a0·b0, a0·b1, a1·b0, a1·b1]` over length `k`,
/// sharing operand loads (the GEMM 2x2 register tile).
#[inline]
pub fn dot2x2(lv: Level, a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32], k: usize) -> [f32; 4] {
    assert!(a0.len() >= k && a1.len() >= k && b0.len() >= k && b1.len() >= k);
    match lv {
        Level::Scalar => dot2x2_scalar(a0, a1, b0, b1, k),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline x86_64; lengths asserted above.
        Level::Sse2 => unsafe { sse2::dot2x2(a0, a1, b0, b1, k) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by feature detection.
        Level::Avx2 => unsafe { avx2::dot2x2(a0, a1, b0, b1, k) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => dot2x2_scalar(a0, a1, b0, b1, k),
    }
}

/// `y += alpha * x` (elementwise — identical at every level by
/// construction).
#[inline]
pub fn axpy(lv: Level, alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    match lv {
        Level::Scalar => axpy_scalar(alpha, x, y),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline x86_64; lengths asserted above.
        Level::Sse2 => unsafe { sse2::axpy(alpha, x, y) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by feature detection.
        Level::Avx2 => unsafe { avx2::axpy(alpha, x, y) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => axpy_scalar(alpha, x, y),
    }
}

/// `y += x` (no multiply — the dense-payload receive path).
#[inline]
pub fn add_assign(lv: Level, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    match lv {
        Level::Scalar => add_assign_scalar(x, y),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline x86_64; lengths asserted above.
        Level::Sse2 => unsafe { sse2::add_assign(x, y) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by feature detection.
        Level::Avx2 => unsafe { avx2::add_assign(x, y) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => add_assign_scalar(x, y),
    }
}

/// `out = x ⊙ y` (fused two-operand Hadamard).
#[inline]
pub fn hadamard2(lv: Level, x: &[f32], y: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    assert_eq!(y.len(), out.len());
    match lv {
        Level::Scalar => hadamard2_scalar(x, y, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline x86_64; lengths asserted above.
        Level::Sse2 => unsafe { sse2::hadamard2(x, y, out) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by feature detection.
        Level::Avx2 => unsafe { avx2::hadamard2(x, y, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => hadamard2_scalar(x, y, out),
    }
}

/// `y *= x` (in-place Hadamard).
#[inline]
pub fn hadamard_assign(lv: Level, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    match lv {
        Level::Scalar => hadamard_assign_scalar(x, y),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline x86_64; lengths asserted above.
        Level::Sse2 => unsafe { sse2::hadamard_assign(x, y) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by feature detection.
        Level::Avx2 => unsafe { avx2::hadamard_assign(x, y) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => hadamard_assign_scalar(x, y),
    }
}

/// The consensus fold `a += w * (hj - hk)` (gossip Alg. 1 line 18 inner
/// loop).
#[inline]
pub fn scaled_diff_acc(lv: Level, w: f32, hj: &[f32], hk: &[f32], a: &mut [f32]) {
    assert_eq!(hj.len(), a.len());
    assert_eq!(hk.len(), a.len());
    match lv {
        Level::Scalar => scaled_diff_acc_scalar(w, hj, hk, a),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline x86_64; lengths asserted above.
        Level::Sse2 => unsafe { sse2::scaled_diff_acc(w, hj, hk, a) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by feature detection.
        Level::Avx2 => unsafe { avx2::scaled_diff_acc(w, hj, hk, a) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scaled_diff_acc_scalar(w, hj, hk, a),
    }
}

/// Set bit `i` of `bits` for every `data[i] >= 0.0` (the sign-compressor
/// pack loop). `bits` must be zeroed by the caller and hold
/// `data.len().div_ceil(8)` bytes; bits are OR-ed in, matching the scalar
/// loop exactly (NaN packs as negative, -0.0 as positive).
#[inline]
pub fn sign_pack(lv: Level, data: &[f32], bits: &mut [u8]) {
    assert!(bits.len() >= data.len().div_ceil(8));
    match lv {
        Level::Scalar => sign_pack_scalar(data, bits),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline x86_64; bit capacity asserted above.
        Level::Sse2 => unsafe { sse2::sign_pack(data, bits) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by feature detection.
        Level::Avx2 => unsafe { avx2::sign_pack(data, bits) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => sign_pack_scalar(data, bits),
    }
}

/// `t[i] += bit(i) ? scale : -scale` (the sign-payload receive path).
#[inline]
pub fn sign_decode_add(lv: Level, scale: f32, bits: &[u8], t: &mut [f32]) {
    assert!(bits.len() >= t.len().div_ceil(8));
    match lv {
        Level::Scalar => sign_decode_add_scalar(scale, bits, t),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline x86_64; bit capacity asserted above.
        Level::Sse2 => unsafe { sse2::sign_decode_add(scale, bits, t) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by feature detection.
        Level::Avx2 => unsafe { avx2::sign_decode_add(scale, bits, t) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => sign_decode_add_scalar(scale, bits, t),
    }
}

/// Every level at or below the hardware's — what the bit-identity
/// property tests sweep. Always contains at least `Level::Scalar`.
pub fn available_levels() -> Vec<Level> {
    let hw = hw_level();
    [Level::Scalar, Level::Sse2, Level::Avx2].into_iter().filter(|&l| l <= hw).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Random vector with occasional special values — the codec paths
    /// must keep NaN/inf/-0.0 semantics identical across levels.
    fn hostile_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n)
            .map(|_| match rng.below(16) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                3 => -0.0,
                4 => 0.0,
                _ => rng.normal_f32(),
            })
            .collect()
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn detection_reports_something_sane() {
        let lv = level();
        assert!(available_levels().contains(&lv) || lv == Level::Scalar);
        assert!(!Level::Scalar.name().is_empty());
        assert_eq!(Level::Avx2.name(), "avx2");
    }

    #[test]
    fn simd_dot_bit_identical_across_levels_and_lengths() {
        let mut rng = Rng::new(0x51D0);
        // every remainder-lane count around the 8-lane boundary, plus
        // longer mixed shapes
        for n in [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 12, 15, 16, 17, 23, 31, 32, 33, 64, 100, 257] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let want = dot(Level::Scalar, &a, &b);
            for lv in available_levels() {
                let got = dot(lv, &a, &b);
                assert_eq!(got.to_bits(), want.to_bits(), "dot n={n} level={}", lv.name());
            }
        }
    }

    #[test]
    fn simd_dot2x2_bit_identical_across_levels() {
        let mut rng = Rng::new(0x51D1);
        for k in [1, 4, 7, 8, 9, 16, 24, 29, 33, 65] {
            let a0: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let a1: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let b0: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let b1: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let want = dot2x2(Level::Scalar, &a0, &a1, &b0, &b1, k);
            for lv in available_levels() {
                let got = dot2x2(lv, &a0, &a1, &b0, &b1, k);
                for c in 0..4 {
                    assert_eq!(
                        got[c].to_bits(),
                        want[c].to_bits(),
                        "dot2x2 k={k} cell={c} level={}",
                        lv.name()
                    );
                }
            }
        }
    }

    #[test]
    fn simd_elementwise_kernels_bit_identical_across_levels() {
        let mut rng = Rng::new(0x51D2);
        for n in [0, 1, 3, 5, 8, 11, 16, 27, 40, 129] {
            let x = hostile_vec(n, &mut rng);
            let y0 = hostile_vec(n, &mut rng);
            let z = hostile_vec(n, &mut rng);
            let alpha = rng.normal_f32();
            for lv in available_levels() {
                // axpy
                let mut want = y0.clone();
                axpy_scalar(alpha, &x, &mut want);
                let mut got = y0.clone();
                axpy(lv, alpha, &x, &mut got);
                assert!(bits_eq(&got, &want), "axpy n={n} level={}", lv.name());
                // add_assign
                let mut want = y0.clone();
                add_assign_scalar(&x, &mut want);
                let mut got = y0.clone();
                add_assign(lv, &x, &mut got);
                assert!(bits_eq(&got, &want), "add_assign n={n} level={}", lv.name());
                // hadamard2
                let mut want = vec![0.0f32; n];
                hadamard2_scalar(&x, &z, &mut want);
                let mut got = vec![0.0f32; n];
                hadamard2(lv, &x, &z, &mut got);
                assert!(bits_eq(&got, &want), "hadamard2 n={n} level={}", lv.name());
                // hadamard_assign
                let mut want = y0.clone();
                hadamard_assign_scalar(&x, &mut want);
                let mut got = y0.clone();
                hadamard_assign(lv, &x, &mut got);
                assert!(bits_eq(&got, &want), "hadamard_assign n={n} level={}", lv.name());
                // consensus fold
                let mut want = y0.clone();
                scaled_diff_acc_scalar(alpha, &x, &z, &mut want);
                let mut got = y0.clone();
                scaled_diff_acc(lv, alpha, &x, &z, &mut got);
                assert!(bits_eq(&got, &want), "scaled_diff_acc n={n} level={}", lv.name());
            }
        }
    }

    #[test]
    fn simd_sign_codec_bit_identical_across_levels() {
        let mut rng = Rng::new(0x51D3);
        for n in [0, 1, 5, 7, 8, 9, 15, 16, 17, 31, 64, 101] {
            let data = hostile_vec(n, &mut rng);
            let mut want_bits = vec![0u8; n.div_ceil(8)];
            sign_pack_scalar(&data, &mut want_bits);
            for lv in available_levels() {
                let mut got_bits = vec![0u8; n.div_ceil(8)];
                sign_pack(lv, &data, &mut got_bits);
                assert_eq!(got_bits, want_bits, "sign_pack n={n} level={}", lv.name());
            }
            for scale in [0.37f32, -0.0, f32::NAN, f32::INFINITY] {
                let t0 = hostile_vec(n, &mut rng);
                let mut want = t0.clone();
                sign_decode_add_scalar(scale, &want_bits, &mut want);
                for lv in available_levels() {
                    let mut got = t0.clone();
                    sign_decode_add(lv, scale, &want_bits, &mut got);
                    assert!(
                        bits_eq(&got, &want),
                        "sign_decode_add n={n} scale={scale} level={}",
                        lv.name()
                    );
                }
            }
        }
    }
}
