//! Bench harness substrate (no `criterion` available offline).
//!
//! Two modes:
//! * [`bench`] — classic timing micro-bench with warmup, returning
//!   mean/p50/p95 per iteration; used by `micro_hotpaths`.
//! * [`Table`] — a row printer for the per-figure experiment benches, which
//!   report *domain* metrics (loss reached, bytes communicated, wall time)
//!   in the same rows/series the paper's plots show.

use std::time::Instant;

/// Timing statistics for one benchmark, in nanoseconds per iteration.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns)
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly for ~`target_ms` ms (after 10% warmup), collect
/// per-iteration timings, and report stats. `f` should return something
/// observable to prevent the optimizer from deleting the work; we
/// `black_box` it.
pub fn bench<T>(name: &str, target_ms: u64, mut f: impl FnMut() -> T) -> Stats {
    // Warmup + calibration: find iterations per sample.
    let t0 = Instant::now();
    let mut calib_iters = 0usize;
    while t0.elapsed().as_millis() < (target_ms / 10).max(5) as u128 {
        std::hint::black_box(f());
        calib_iters += 1;
    }
    let per_iter_ns = (t0.elapsed().as_nanos() as f64 / calib_iters as f64).max(1.0);
    // Aim for <= 200 samples over the target duration.
    let sample_iters = ((target_ms as f64 * 1e6) / per_iter_ns / 200.0).ceil().max(1.0) as usize;

    let mut samples = Vec::new();
    let bench_start = Instant::now();
    let mut total_iters = 0usize;
    while bench_start.elapsed().as_millis() < target_ms as u128 && samples.len() < 1000 {
        let s = Instant::now();
        for _ in 0..sample_iters {
            std::hint::black_box(f());
        }
        samples.push(s.elapsed().as_nanos() as f64 / sample_iters as f64);
        total_iters += sample_iters;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    let stats = Stats {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: mean,
        p50_ns: pct(0.5),
        p95_ns: pct(0.95),
        min_ns: samples[0],
    };
    stats.print();
    stats
}

/// Aligned table printer for experiment benches.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        let headers: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
        let widths = headers.iter().map(|h| h.len().max(12)).collect();
        let t = Table { headers, widths };
        t.print_header();
        t
    }

    fn print_header(&self) {
        let cells: Vec<String> = self
            .headers
            .iter()
            .zip(&self.widths)
            .map(|(h, w)| format!("{h:>w$}"))
            .collect();
        println!("{}", cells.join("  "));
        println!("{}", "-".repeat(cells.join("  ").len()));
    }

    pub fn row(&self, fields: &[String]) {
        let cells: Vec<String> = fields
            .iter()
            .zip(&self.widths)
            .map(|(f, w)| format!("{f:>w$}"))
            .collect();
        println!("{}", cells.join("  "));
    }
}

/// Human-readable byte counts.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1e3 {
        format!("{b:.0} B")
    } else if b < 1e6 {
        format!("{:.1} kB", b / 1e3)
    } else if b < 1e9 {
        format!("{:.2} MB", b / 1e6)
    } else {
        format!("{:.2} GB", b / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop-ish", 20, || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns * 1.0001);
        assert!(s.iters > 100);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_bytes(2_000_000.0).contains("MB"));
    }
}
