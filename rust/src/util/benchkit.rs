//! Bench harness substrate (no `criterion` available offline).
//!
//! Three pieces:
//! * [`bench`] — classic timing micro-bench with warmup, returning
//!   mean/p50/p95 per iteration; used by `micro_hotpaths` and the `bench`
//!   CLI subcommand.
//! * [`Table`] — a row printer for the per-figure experiment benches, which
//!   report *domain* metrics (loss reached, bytes communicated, wall time)
//!   in the same rows/series the paper's plots show.
//! * [`BenchRun`] / [`append_bench_json`] — the persistent perf gate:
//!   every `cidertf bench` invocation appends one run to `BENCH.json`
//!   (schema [`BENCH_SCHEMA`]) so the repo carries its own perf
//!   trajectory across PRs. See ARCHITECTURE.md §"BENCH.json".

// The one module where wall-clock reads are the whole point: the xtask
// wall-clock lint (D004) allowlists this file, and the clippy
// disallowed-methods backstop is waived for the same reason.
#![allow(clippy::disallowed_methods)]

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

/// Timing statistics for one benchmark, in nanoseconds per iteration.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    /// JSON object for BENCH.json:
    /// `{name, iters, mean_ns, p50_ns, p95_ns, min_ns}`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("p50_ns".to_string(), Json::Num(self.p50_ns));
        m.insert("p95_ns".to_string(), Json::Num(self.p95_ns));
        m.insert("min_ns".to_string(), Json::Num(self.min_ns));
        Json::Obj(m)
    }
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns)
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly for ~`target_ms` ms (after 10% warmup), collect
/// per-iteration timings, and report stats. `f` should return something
/// observable to prevent the optimizer from deleting the work; we
/// `black_box` it.
pub fn bench<T>(name: &str, target_ms: u64, mut f: impl FnMut() -> T) -> Stats {
    // Warmup + calibration: find iterations per sample.
    let t0 = Instant::now();
    let mut calib_iters = 0usize;
    while t0.elapsed().as_millis() < (target_ms / 10).max(5) as u128 {
        std::hint::black_box(f());
        calib_iters += 1;
    }
    let per_iter_ns = (t0.elapsed().as_nanos() as f64 / calib_iters as f64).max(1.0);
    // Aim for <= 200 samples over the target duration.
    let sample_iters = ((target_ms as f64 * 1e6) / per_iter_ns / 200.0).ceil().max(1.0) as usize;

    let mut samples = Vec::new();
    let bench_start = Instant::now();
    let mut total_iters = 0usize;
    while bench_start.elapsed().as_millis() < target_ms as u128 && samples.len() < 1000 {
        let s = Instant::now();
        for _ in 0..sample_iters {
            std::hint::black_box(f());
        }
        samples.push(s.elapsed().as_nanos() as f64 / sample_iters as f64);
        total_iters += sample_iters;
    }
    let stats = stats_from_samples(name, total_iters, samples);
    stats.print();
    stats
}

/// Percentile reduction over raw per-iteration samples. NaN samples
/// (possible if a caller derives timings arithmetically) sort last
/// instead of panicking the comparator, so percentiles stay meaningful
/// over the finite prefix.
fn stats_from_samples(name: &str, total_iters: usize, mut samples: Vec<f64>) -> Stats {
    samples.sort_by(crate::util::order::nan_last_f64);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Stats {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: mean,
        p50_ns: pct(0.5),
        p95_ns: pct(0.95),
        min_ns: samples[0],
    }
}

/// Aligned table printer for experiment benches.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        let headers: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
        let widths = headers.iter().map(|h| h.len().max(12)).collect();
        let t = Table { headers, widths };
        t.print_header();
        t
    }

    fn print_header(&self) {
        let cells: Vec<String> = self
            .headers
            .iter()
            .zip(&self.widths)
            .map(|(h, w)| format!("{h:>w$}"))
            .collect();
        println!("{}", cells.join("  "));
        println!("{}", "-".repeat(cells.join("  ").len()));
    }

    pub fn row(&self, fields: &[String]) {
        let cells: Vec<String> = fields
            .iter()
            .zip(&self.widths)
            .map(|(f, w)| format!("{f:>w$}"))
            .collect();
        println!("{}", cells.join("  "));
    }
}

/// BENCH.json top-level schema identifier.
pub const BENCH_SCHEMA: &str = "cidertf-bench-v1";

/// One `cidertf bench` invocation: a set of micro/e2e [`Stats`] plus
/// derived scalars (e.g. the blocked-vs-naive gradient speedup).
///
/// Serialized shape (one element of the top-level `runs` array):
/// ```json
/// {
///   "created_unix": 1730000000,
///   "mode": "full" | "smoke",
///   "benches": [ { "name": ..., "iters": ..., "mean_ns": ...,
///                  "p50_ns": ..., "p95_ns": ..., "min_ns": ... } ],
///   "derived": { "grad_speedup_blocked_vs_naive": 2.7 }
/// }
/// ```
pub struct BenchRun {
    /// `"full"` or `"smoke"`
    pub mode: String,
    pub benches: Vec<Stats>,
    /// derived named scalars (speedups, ratios)
    pub derived: Vec<(String, f64)>,
}

impl BenchRun {
    pub fn to_json(&self) -> Json {
        let created = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut m = BTreeMap::new();
        m.insert("created_unix".to_string(), Json::Num(created as f64));
        m.insert("mode".to_string(), Json::Str(self.mode.clone()));
        m.insert(
            "benches".to_string(),
            Json::Arr(self.benches.iter().map(Stats::to_json).collect()),
        );
        let mut d = BTreeMap::new();
        for (k, v) in &self.derived {
            d.insert(k.clone(), Json::Num(*v));
        }
        m.insert("derived".to_string(), Json::Obj(d));
        Json::Obj(m)
    }
}

/// Append `run` to the BENCH.json at `path`
/// (`{"schema": "cidertf-bench-v1", "runs": [...]}`), creating the file if
/// missing.
///
/// The write is atomic (temp file + rename in the same directory) so an
/// interrupted bench can never leave a truncated file behind, and an
/// existing file whose history cannot be carried forward — unparseable
/// *or* a foreign/newer schema — is preserved as `<path>.bak` instead of
/// being silently wiped: the accumulated perf trajectory is the whole
/// point of this file.
pub fn append_bench_json(path: &Path, run: &BenchRun) -> anyhow::Result<()> {
    let mut runs: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        let keep = match Json::parse(&text) {
            Ok(j) if j.get("schema").and_then(|s| s.as_str()) == Some(BENCH_SCHEMA) => {
                if let Some(Json::Arr(a)) = j.get("runs") {
                    runs = a.clone();
                }
                true
            }
            Ok(_) => false,  // parseable, but not our schema
            Err(_) => false, // corrupt/truncated
        };
        if !keep {
            let backup = path.with_extension("json.bak");
            std::fs::rename(path, &backup)
                .map_err(|re| anyhow::anyhow!("cannot back up {path:?}: {re}"))?;
            eprintln!(
                "warning: {} is not a {BENCH_SCHEMA} file; preserved as {} and starting fresh",
                path.display(),
                backup.display()
            );
        }
    }
    runs.push(run.to_json());
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(BENCH_SCHEMA.to_string()));
    top.insert("runs".to_string(), Json::Arr(runs));
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, Json::Obj(top).to_pretty_string())
        .map_err(|e| anyhow::anyhow!("cannot write {tmp:?}: {e}"))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("cannot move {tmp:?} into place: {e}"))?;
    Ok(())
}

/// Human-readable byte counts.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1e3 {
        format!("{b:.0} B")
    } else if b < 1e6 {
        format!("{:.1} kB", b / 1e3)
    } else if b < 1e9 {
        format!("{:.2} MB", b / 1e6)
    } else {
        format!("{:.2} GB", b / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop-ish", 20, || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns * 1.0001);
        assert!(s.iters > 100);
    }

    #[test]
    fn nan_poisoned_samples_do_not_panic_the_percentile_sort() {
        // regression: the sample sort used partial_cmp().unwrap(), which
        // panics on NaN; it must now push NaNs last and keep the finite
        // order statistics intact
        let s = stats_from_samples("poisoned", 40, vec![3.0, f64::NAN, 1.0, 2.0, f64::NAN]);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.p50_ns, 3.0);
        assert!(s.p95_ns.is_nan(), "NaNs sort to the tail percentiles");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_bytes(2_000_000.0).contains("MB"));
    }

    fn fake_stats(name: &str) -> Stats {
        Stats {
            name: name.to_string(),
            iters: 100,
            mean_ns: 1234.5,
            p50_ns: 1200.0,
            p95_ns: 1500.0,
            min_ns: 1100.0,
        }
    }

    #[test]
    fn bench_json_appends_runs() {
        let dir = std::env::temp_dir().join(format!("cidertf_benchkit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH.json");
        let _ = std::fs::remove_file(&path);
        let run = BenchRun {
            mode: "smoke".to_string(),
            benches: vec![fake_stats("a"), fake_stats("b")],
            derived: vec![("speedup".to_string(), 2.5)],
        };
        append_bench_json(&path, &run).unwrap();
        append_bench_json(&path, &run).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("schema").and_then(|s| s.as_str()), Some(BENCH_SCHEMA));
        let Some(Json::Arr(runs)) = j.get("runs") else { panic!("runs missing") };
        assert_eq!(runs.len(), 2, "append must extend, not overwrite");
        let b0 = runs[0].get("benches").unwrap();
        let Json::Arr(entries) = b0 else { panic!("benches not an array") };
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].get("name").and_then(|n| n.as_str()), Some("a"));
        assert_eq!(entries[0].get("mean_ns").and_then(|n| n.as_f64()), Some(1234.5));
        assert_eq!(
            runs[0].get("derived").unwrap().get("speedup").and_then(|n| n.as_f64()),
            Some(2.5)
        );
        let _ = std::fs::remove_file(&path);
    }
}
